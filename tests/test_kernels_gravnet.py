"""Shape sweep + property tests: GravNet aggregation kernel vs oracle."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_support import given, settings, st
from _numerics import assert_bitwise, assert_close

from repro.kernels import ops, ref


def _case(rng, n, ds, df, frac_valid=0.8):
    s = jnp.asarray(rng.normal(size=(n, ds)), jnp.float32)
    f = jnp.asarray(rng.normal(size=(n, df)), jnp.float32)
    mask = jnp.asarray(rng.uniform(size=n) < frac_valid, jnp.float32)
    return s, f, mask


@pytest.mark.parametrize("n,ds,df,k", [
    (32, 4, 16, 8), (90, 4, 22, 6), (128, 4, 32, 8), (128, 8, 64, 16),
    (256, 3, 24, 4), (30, 2, 8, 3),
])
def test_gravnet_sweep(n, ds, df, k):
    rng = np.random.default_rng(n * 100 + ds * 10 + k)
    s, f, mask = _case(rng, n, ds, df)
    got = ops.gravnet_aggregate(s, f, mask, k=k, backend="pallas_interpret",
                                bm=32)
    want = ref.gravnet_aggregate_ref(s, f, mask, k=k)
    assert_close(got, want, dtype=jnp.float32)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gravnet_dtypes(dtype):
    rng = np.random.default_rng(7)
    s, f, mask = _case(rng, 64, 4, 16)
    got = ops.gravnet_aggregate(s.astype(dtype), f.astype(dtype), mask, k=8,
                                backend="pallas_interpret", bm=32)
    want = ref.gravnet_aggregate_ref(s.astype(dtype), f.astype(dtype), mask,
                                     k=8)
    assert_close(got, want, dtype=dtype)


def test_gravnet_all_invalid_rows_zero():
    rng = np.random.default_rng(3)
    s, f, _ = _case(rng, 32, 4, 8)
    mask = jnp.zeros(32, jnp.float32)
    got = ops.gravnet_aggregate(s, f, mask, k=4, backend="pallas_interpret",
                                bm=32)
    assert_bitwise(got, np.zeros_like(np.asarray(got)))


def test_gravnet_single_valid_node_has_no_neighbors():
    rng = np.random.default_rng(4)
    s, f, _ = _case(rng, 32, 4, 8)
    mask = jnp.zeros(32, jnp.float32).at[5].set(1.0)
    got = np.asarray(ops.gravnet_aggregate(s, f, mask, k=4,
                                           backend="pallas_interpret", bm=32))
    assert_bitwise(got[5], np.zeros_like(got[5]))  # self excluded -> nothing


@settings(max_examples=20, deadline=None)
@given(n=st.integers(8, 96), k=st.integers(1, 8),
       seed=st.integers(0, 2**31 - 1))
def test_gravnet_property_matches_oracle(n, k, seed):
    rng = np.random.default_rng(seed)
    s, f, mask = _case(rng, n, 4, 12)
    got = ops.gravnet_aggregate(s, f, mask, k=k, backend="pallas_interpret",
                                bm=16)
    want = ref.gravnet_aggregate_ref(s, f, mask, k=k)
    assert_close(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_gravnet_property_padding_rows_inert(seed):
    """Appending masked-out rows never changes valid rows' outputs."""
    rng = np.random.default_rng(seed)
    s, f, mask = _case(rng, 48, 4, 8, frac_valid=1.0)
    base = np.asarray(ops.gravnet_aggregate(s, f, mask, k=4,
                                            backend="pallas_interpret", bm=16))
    s2 = jnp.concatenate([s, jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)])
    f2 = jnp.concatenate([f, jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)])
    m2 = jnp.concatenate([mask, jnp.zeros(16, jnp.float32)])
    ext = np.asarray(ops.gravnet_aggregate(s2, f2, m2, k=4,
                                           backend="pallas_interpret", bm=16))
    assert_close(ext[:48], base, dtype=jnp.float32)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_gravnet_property_permutation_equivariant(seed):
    """Permuting nodes permutes outputs identically."""
    rng = np.random.default_rng(seed)
    s, f, mask = _case(rng, 40, 4, 8)
    perm = rng.permutation(40)
    base = np.asarray(ref.gravnet_aggregate_ref(s, f, mask, k=5))
    permd = np.asarray(ref.gravnet_aggregate_ref(s[perm], f[perm], mask[perm],
                                                 k=5))
    assert_close(permd, base[perm], dtype=jnp.float32)


def test_gravnet_weights_decay_with_distance():
    """A far-away cluster contributes ~0 relative to near neighbors."""
    rng = np.random.default_rng(9)
    near = rng.normal(size=(16, 4)).astype(np.float32) * 0.1
    far = near + 100.0
    s = jnp.asarray(np.concatenate([near, far]))
    f = jnp.asarray(rng.normal(size=(32, 8)), jnp.float32)
    mask = jnp.ones(32, jnp.float32)
    out = np.asarray(ref.gravnet_aggregate_ref(s, f, mask, k=20))
    # for a near node, mean-agg uses only <=15 near neighbors (plus zeros):
    # removing the far cluster entirely must not change it
    out_near_only = np.asarray(ref.gravnet_aggregate_ref(
        s[:16], f[:16], mask[:16], k=20))
    assert_close(out[:16], out_near_only, rtol=1e-3, atol=1e-4)
