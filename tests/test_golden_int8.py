"""Golden-vector regression test for the quantized GravNet block.

``tests/golden/gravnet_block_int8.npz`` pins one fixed-seed event all
the way through the *unfused calibrated int8 chain*: weights quantized
per-channel with ``quantize_weight``, activation scales derived
calibration-style (absmax of an fp reference run → ``activation_scale``),
and the expected output computed by composing the per-op reference
kernels exactly as the unfused executor does. The fixture freezes
today's numerics so any later change to rounding, scale derivation, or
kernel epilogues shows up as a diff against committed bytes.

Regenerate (after an *intentional* numerics change) with:

    REPRO_REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest \
        tests/test_golden_int8.py -q
"""
import os
import pathlib

import jax.numpy as jnp
import numpy as np
import pytest
from _numerics import (assert_calibration_close, assert_close,
                       backend_sweep, int8_flip_tolerance)

from repro.core.quantization import activation_scale, quantize_weight
from repro.kernels import ops
from repro.kernels import ref as kref

GOLDEN = pathlib.Path(__file__).parent / "golden" / "gravnet_block_int8.npz"

# fixture problem: one event at the mid occupancy bucket
_N, _DH, _DS, _DF, _DOUT, _K, _SEED = 32, 24, 3, 10, 24, 6, 2026


def _generate() -> dict:
    rng = np.random.default_rng(_SEED)
    x = jnp.asarray(rng.normal(size=(_N, _DH)) * 0.4, jnp.float32)
    mask = jnp.asarray(rng.uniform(size=(_N,)) < 0.8, jnp.float32)
    ws = jnp.asarray(rng.normal(size=(_DH, _DS)) * 0.3, jnp.float32)
    bs = jnp.asarray(rng.normal(size=(_DS,)) * 0.1, jnp.float32)
    wf = jnp.asarray(rng.normal(size=(_DH, _DF)) * 0.3, jnp.float32)
    bf = jnp.asarray(rng.normal(size=(_DF,)) * 0.1, jnp.float32)
    wo = jnp.asarray(rng.normal(size=(_DH + 2 * _DF, _DOUT)) * 0.3,
                     jnp.float32)
    bo = jnp.asarray(rng.normal(size=(_DOUT,)) * 0.1, jnp.float32)

    # calibration-style scale derivation from an fp reference run
    x_scale = activation_scale(float(jnp.max(jnp.abs(x))))
    s_fp = kref.fused_dense_ref(x, ws, bs, activation="none",
                                out_dtype=jnp.float32)
    f_fp = kref.fused_dense_ref(x, wf, bf, activation="none",
                                out_dtype=jnp.float32)
    agg_fp = kref.gravnet_aggregate_ref(s_fp, f_fp, mask, k=_K)
    agg_scale = activation_scale(float(jnp.max(jnp.abs(agg_fp))))
    h_fp = jnp.concatenate([x, agg_fp], axis=-1)
    h_scale = activation_scale(float(jnp.max(jnp.abs(h_fp))))

    ws_q, ws_scale = quantize_weight(ws)
    wf_q, wf_scale = quantize_weight(wf)
    wo_q, wo_scale = quantize_weight(wo)

    # expected output: the unfused calibrated chain, per-op references
    xq = jnp.clip(jnp.round(x / x_scale), -127, 127).astype(jnp.int8)
    xs = jnp.asarray([[x_scale]], jnp.float32)
    s = kref.fused_dense_int8_ref(xq, ws_q, bs, xs, ws_scale,
                                  activation="none")
    f = kref.fused_dense_int8_ref(xq, wf_q, bf, xs, wf_scale,
                                  activation="none")
    agg = kref.gravnet_aggregate_ref(s, f, mask, k=_K)
    agg = jnp.clip(jnp.round(agg / agg_scale), -127, 127) * agg_scale
    h = jnp.concatenate([x, agg], axis=-1)
    hq = jnp.clip(jnp.round(h / h_scale), -127, 127).astype(jnp.int8)
    hs = jnp.asarray([[h_scale]], jnp.float32)
    y = kref.fused_dense_int8_ref(hq, wo_q, bo, hs, wo_scale,
                                  activation="relu")

    return dict(x=np.asarray(x), mask=np.asarray(mask),
                ws_q=np.asarray(ws_q), bs=np.asarray(bs),
                wf_q=np.asarray(wf_q), bf=np.asarray(bf),
                wo_q=np.asarray(wo_q), bo=np.asarray(bo),
                ws_scale=np.asarray(ws_scale),
                wf_scale=np.asarray(wf_scale),
                wo_scale=np.asarray(wo_scale),
                x_scale=np.float32(x_scale),
                agg_scale=np.float32(agg_scale),
                h_scale=np.float32(h_scale),
                k=np.int32(_K), y=np.asarray(y))


@pytest.fixture(scope="module")
def golden():
    if os.environ.get("REPRO_REGEN_GOLDEN") == "1":
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        np.savez(GOLDEN, **_generate())
    if not GOLDEN.exists():
        pytest.fail(f"missing golden fixture {GOLDEN}; regenerate with "
                    "REPRO_REGEN_GOLDEN=1")
    with np.load(GOLDEN) as z:
        return {k: z[k] for k in z.files}


def _kernel_args(g):
    return ((jnp.asarray(g["x"]), jnp.asarray(g["mask"]),
             jnp.asarray(g["ws_q"]), jnp.asarray(g["bs"]),
             jnp.asarray(g["wf_q"]), jnp.asarray(g["bf"]),
             jnp.asarray(g["wo_q"]), jnp.asarray(g["bo"]),
             jnp.asarray(g["ws_scale"]), jnp.asarray(g["wf_scale"]),
             jnp.asarray(g["wo_scale"])),
            dict(x_scale=float(g["x_scale"]),
                 agg_scale=float(g["agg_scale"]),
                 h_scale=float(g["h_scale"]), k=int(g["k"])))


def test_golden_fixture_is_current(golden):
    """Regenerating from source reproduces the committed bytes — the
    fixture and the calibration/quantization code have not drifted."""
    fresh = _generate()
    assert set(fresh) == set(golden)
    for name, arr in fresh.items():
        np.testing.assert_array_equal(arr, golden[name], err_msg=name)


def test_ref_oracle_matches_golden(golden):
    """The fused-block oracle reproduces the unfused-chain golden
    output near-exactly (same grids, same int32 accumulation)."""
    args, sc = _kernel_args(golden)
    y = kref.gravnet_block_int8_ref(*args, **sc)
    assert_close(y, golden["y"], dtype="int8")


@pytest.mark.parametrize("backend", backend_sweep())
def test_fused_kernel_matches_golden(backend, golden):
    """The fused megakernel reproduces the golden unfused-chain output
    within calibration tolerance on every available backend."""
    args, sc = _kernel_args(golden)
    y = ops.gravnet_block_int8(*args, backend=backend, **sc)
    quantum = int8_flip_tolerance(float(golden["h_scale"]),
                                  golden["wo_scale"])
    assert_calibration_close(y, golden["y"], quantum=quantum,
                             context=backend)
