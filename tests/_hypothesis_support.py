"""Property-based tests degrade to skips when ``hypothesis`` is absent.

The container image does not always ship hypothesis (it is listed in
``requirements-dev.txt``); importing it unconditionally made every
module that declares a property test fail at *collection*, taking the
whole tier-1 suite down with it.  Test modules import the decorators
from here instead: with hypothesis installed they are the real thing,
without it ``@given`` turns the test into an explicit skip while the
rest of the module keeps running.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for ``hypothesis.strategies``: every attribute is a
        callable returning None (the strategies are never drawn from)."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*_a, **_k):
        return lambda fn: fn

    def given(*_a, **_k):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():
                pass
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco
