"""Differential tests for the edge-aggregation kernel.

The jnp reference (``edge_aggregate_ref``) is itself differentially
pinned to the model-zoo scatter ops (``models.gnn.common.scatter_sum``
/ ``scatter_mean``) so the deploy path and the eager GNN forwards agree
by construction; the Pallas one-hot-incidence kernel is then swept
against the reference on every backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import edge_aggregate_ref
from repro.models.gnn import common as C
from tests._numerics import assert_close, backend_sweep

jax.config.update("jax_platform_name", "cpu")


def _problem(n, e, d, *, seed=0, full_mask=False):
    rng = np.random.default_rng(seed)
    msgs = jnp.asarray(rng.normal(size=(e, d)), jnp.float32)
    ei = jnp.asarray(rng.integers(0, n, size=(2, e)), jnp.int32)
    mask = (jnp.ones((e,), jnp.float32) if full_mask
            else jnp.asarray(rng.uniform(size=(e,)) < 0.7, jnp.float32))
    return msgs, ei, mask


@pytest.mark.parametrize("reduce", ["sum", "mean"])
def test_ref_matches_model_zoo_scatter(reduce):
    n, e, d = 32, 96, 8
    msgs, ei, mask = _problem(n, e, d)
    got = edge_aggregate_ref(msgs, ei, n, mask, reduce=reduce)
    scatter = C.scatter_sum if reduce == "sum" else C.scatter_mean
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(scatter(msgs, ei, n, mask)))


@pytest.mark.parametrize("backend", backend_sweep())
@pytest.mark.parametrize("reduce", ["sum", "mean"])
def test_kernel_matches_ref(backend, reduce):
    n, e, d = 32, 128, 16
    msgs, ei, mask = _problem(n, e, d)
    want = edge_aggregate_ref(msgs, ei, n, mask, reduce=reduce)
    got = ops.edge_aggregate(msgs, ei, n, mask, reduce=reduce,
                             backend=backend)
    assert_close(got, want, dtype="float32",
                 context=f"{backend}/{reduce}")


@pytest.mark.parametrize("backend", backend_sweep())
def test_kernel_none_mask_and_ragged_shapes(backend):
    # n not a multiple of bm, e not a multiple of be: the wrapper pads
    n, e, d = 50, 90, 6
    msgs, ei, _ = _problem(n, e, d, seed=3)
    want = edge_aggregate_ref(msgs, ei, n, reduce="sum")
    got = ops.edge_aggregate(msgs, ei, n, reduce="sum", bm=32, be=None,
                             backend=backend)
    assert_close(got, want, dtype="float32", context=backend)


@pytest.mark.parametrize("backend", backend_sweep())
@pytest.mark.parametrize("reduce", ["sum", "mean"])
def test_batched_matches_per_event_loop(backend, reduce):
    b, n, e, d = 3, 32, 64, 8
    rng = np.random.default_rng(1)
    msgs = jnp.asarray(rng.normal(size=(b, e, d)), jnp.float32)
    ei = jnp.asarray(rng.integers(0, n, size=(b, 2, e)), jnp.int32)
    mask = jnp.asarray(rng.uniform(size=(b, e)) < 0.7, jnp.float32)
    got = ops.edge_aggregate_batched(msgs, ei, n, mask, reduce=reduce,
                                     backend=backend)
    for i in range(b):
        want = ops.edge_aggregate(msgs[i], ei[i], n, mask[i],
                                  reduce=reduce, backend=backend)
        # same cell body, same schedule -> bitwise across the batch dim
        np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(want),
                                      err_msg=f"{backend}/{reduce}/ev{i}")


@pytest.mark.parametrize("backend",
                         [b for b in backend_sweep() if b != "xla"])
def test_edge_chunking_is_close(backend):
    # a non-default be splits the f32 accumulation into ordered chunks;
    # tolerance-level agreement is the claim (association may move ulps)
    n, e, d = 32, 256, 8
    msgs, ei, mask = _problem(n, e, d, seed=7)
    want = ops.edge_aggregate(msgs, ei, n, mask, backend=backend)
    got = ops.edge_aggregate(msgs, ei, n, mask, be=64, backend=backend)
    assert_close(got, want, dtype="float32", context=f"{backend}/be=64")


@pytest.mark.parametrize("backend", backend_sweep())
def test_padded_edges_do_not_contribute(backend):
    n, e, d = 16, 48, 4
    msgs, ei, mask = _problem(n, e, d, seed=5)
    # zero the masked edges' payload entirely: identical result proves
    # masked slots never leak through the incidence matmul
    got = ops.edge_aggregate(msgs, ei, n, mask, backend=backend)
    zeroed = msgs * mask[:, None]
    got2 = ops.edge_aggregate(zeroed, ei, n, mask, backend=backend)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(got2))
