"""Tests: IR verification pass + trigger monitor."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import caloclusternet as ccn
from repro.core.graph_ir import Graph, Operator
from repro.core.passes.fusion import fuse
from repro.core.passes.verify import GraphVerificationError, verify
from repro.serving.monitor import TriggerMonitor, event_display


def test_verify_accepts_caloclusternet_graph():
    cfg = ccn.CCNConfig(n_hits=16)
    params = ccn.init(jax.random.PRNGKey(0), cfg)
    g = ccn.to_graph(params, cfg)
    dims = verify(g)
    assert dims["enc1"] == cfg.d_hidden
    assert dims[f"gn0_agg"] == 2 * cfg.d_flr
    # fusion output verifies too
    verify(fuse(g))


def test_verify_rejects_weight_mismatch():
    g = Graph()
    g.add(Operator(name="in", op_type="input", out_dim=4,
                   attrs={"feature": "hits"}))
    g.add(Operator(name="l", op_type="linear", inputs=["in"],
                   params={"w": jnp.zeros((8, 3))}, out_dim=3))
    g.add(Operator(name="out", op_type="output", inputs=["l"],
                   attrs={"head_names": ["y"]}, out_dim=3))
    with pytest.raises(GraphVerificationError, match="d_in=8"):
        verify(g)


def test_verify_rejects_bad_slice_and_missing_output():
    g = Graph()
    g.add(Operator(name="in", op_type="input", out_dim=4,
                   attrs={"feature": "hits"}))
    g.add(Operator(name="s", op_type="slice", inputs=["in"],
                   attrs={"start": 2, "size": 4}, out_dim=4))
    with pytest.raises(GraphVerificationError, match="slice"):
        verify(g)
    g2 = Graph()
    g2.add(Operator(name="in", op_type="input", out_dim=4,
                    attrs={"feature": "hits"}))
    with pytest.raises(GraphVerificationError, match="no output"):
        verify(g2)


def test_monitor_and_display():
    mon = TriggerMonitor(window=64)
    rng = np.random.default_rng(0)
    for i in range(50):
        n = int(rng.integers(0, 4))
        res = {
            "trigger": np.asarray(n > 0),
            "n_clusters": np.asarray(n),
            "cluster_valid": np.arange(8) < n,
            "cluster_e": rng.uniform(0, 2, 8).astype(np.float32),
            "cluster_beta": rng.uniform(0, 1, 8).astype(np.float32),
            "cluster_xy": rng.normal(size=(8, 2)).astype(np.float32),
        }
        mon.record(res, latency_s=1e-5 * (1 + i % 3))
    snap = mon.snapshot()
    assert snap["events"] == 50
    assert 0.0 <= snap["trigger_rate"] <= 1.0
    assert snap["latency_p99_us"] >= snap["latency_p50_us"]
    disp = event_display(res, event_id=7, truth=True)
    assert disp["event"] == 7 and len(disp["clusters"]) == n
    for c in disp["clusters"]:
        assert set(c) == {"theta", "phi", "energy", "beta"}
