"""Differential tests for the ragged kNN kernel pair.

``knn_build`` (segment-masked neighbor selection) is pinned **bitwise**
against the jnp oracle ``knn_build_ref`` — both run the same iterated
argmin with ties broken toward the lowest column index, so idx and d2
must agree exactly, on every backend. ``knn_aggregate`` runs the same
sequential per-slot accumulation as its oracle, but XLA's multiply-add
fusion may move last ulps between compilations, so the aggregation
claim is tolerance-level (``_numerics.DTYPE_TOLERANCES``). Batched vs.
per-bin launches share one cell body and are compared bitwise. A
golden fixture freezes today's selection order; tuning-key /
candidate / warm-up coverage mirrors the other kernel families.

Regenerate the fixture (after an *intentional* contract change) with:

    REPRO_REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest \
        tests/test_knn_build.py -q
"""
from __future__ import annotations

import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _numerics import assert_bitwise, assert_close, backend_sweep

from repro.kernels import ops
from repro.kernels.ref import knn_aggregate_ref, knn_build_ref

jax.config.update("jax_platform_name", "cpu")

GOLDEN = pathlib.Path(__file__).parent / "golden" / "knn_build.npz"

_N, _DS, _DF, _K, _SEED = 32, 4, 10, 6, 2026


def _problem(n=_N, ds=_DS, df=_DF, *, seed=0, events=3, batch=None):
    """A bin-packed problem: ``events`` contiguous segments first (in
    order, like ``bin_pack`` lays them out), then a −1 padding tail."""
    rng = np.random.default_rng(seed)
    b = batch or 1
    seg = np.full((b, n), -1, np.int32)
    for i in range(b):
        cuts = np.sort(rng.integers(1, n, size=events - 1))
        fill = int(rng.integers(n // 2, n + 1))
        seg[i, :fill] = np.searchsorted(cuts, np.arange(fill),
                                        side="right")
    s = rng.normal(size=(b, n, ds)).astype(np.float32)
    f = rng.normal(size=(b, n, df)).astype(np.float32)
    if batch is None:
        return jnp.asarray(s[0]), jnp.asarray(f[0]), jnp.asarray(seg[0])
    return jnp.asarray(s), jnp.asarray(f), jnp.asarray(seg)


# ------------------------------------------------------- kernel vs oracle ----
@pytest.mark.parametrize("backend", backend_sweep())
@pytest.mark.parametrize("k", [2, 6])
def test_build_matches_ref_bitwise(backend, k):
    s, _, seg = _problem(seed=1)
    want_idx, want_d2 = knn_build_ref(s, seg, k=k)
    idx, d2 = ops.knn_build(s, seg, k=k, backend=backend)
    assert_bitwise(idx, want_idx, context=f"{backend}/k={k}/idx")
    assert_bitwise(d2, want_d2, context=f"{backend}/k={k}/d2")


@pytest.mark.parametrize("backend", backend_sweep())
def test_aggregate_matches_ref(backend):
    s, f, seg = _problem(seed=2)
    idx, d2 = knn_build_ref(s, seg, k=_K)
    want = knn_aggregate_ref(f, idx, d2, scale=10.0)
    got = ops.knn_aggregate(f, idx, d2, scale=10.0, backend=backend)
    assert_close(got, want, dtype="float32", context=backend)


def test_tie_break_is_lowest_column_index():
    """Two equidistant candidates: the selection must take the lower
    row index first — the pinned contract that makes bin packing
    order-preserving (and ragged == padded tie-for-tie)."""
    s = jnp.asarray([[0.0], [1.0], [-1.0], [1.0]], jnp.float32)
    seg = jnp.zeros((4,), jnp.int32)
    idx, d2 = knn_build_ref(s, seg, k=3)
    # row 0's candidates: rows 1, 2, 3 all at distance 1 -> order 1,2,3
    np.testing.assert_array_equal(np.asarray(idx[0]), [1, 2, 3])
    np.testing.assert_array_equal(np.asarray(d2[0]), [1.0, 1.0, 1.0])
    for backend in backend_sweep():
        gi, gd = ops.knn_build(s, seg, k=3, bm=4, backend=backend)
        assert_bitwise(gi, idx, context=backend)
        assert_bitwise(gd, d2, context=backend)


def test_exhausted_slots_are_sentinels():
    """An event smaller than k+1 rows runs out of candidates: the
    remaining slots must carry the 1e30 sentinel the aggregation (and
    any downstream consumer) gates on."""
    s, _, _ = _problem(seed=3)
    seg = np.full((_N,), -1, np.int32)
    seg[:3] = 0          # one 3-hit event -> only 2 real neighbors
    idx, d2 = knn_build_ref(s, jnp.asarray(seg), k=_K)
    d2 = np.asarray(d2)
    assert (d2[:3, 2:] >= 0.5e30).all()
    assert (d2[:3, :2] < 0.5e30).all()
    assert (d2[3:] >= 0.5e30).all()   # padding rows select nothing


@pytest.mark.parametrize("backend", backend_sweep())
def test_cross_segment_selection_is_impossible(backend):
    s, _, seg = _problem(seed=4)
    idx, d2 = ops.knn_build(s, seg, k=_K, backend=backend)
    idx, d2, seg = np.asarray(idx), np.asarray(d2), np.asarray(seg)
    valid = d2 < 0.5e30
    rows, slots = np.nonzero(valid)
    assert rows.size                        # sanity: something selected
    np.testing.assert_array_equal(seg[idx[rows, slots]], seg[rows])
    assert (idx[rows, slots] != rows).all()  # self never selected


# -------------------------------------------------- batched vs per-bin ----
@pytest.mark.parametrize("backend", backend_sweep())
def test_batched_matches_per_bin_loop(backend):
    s, f, seg = _problem(seed=5, batch=4)
    bi, bd = ops.knn_build_batched(s, seg, k=_K, backend=backend)
    agg = ops.knn_aggregate_batched(f, bi, bd, scale=10.0,
                                    backend=backend)
    for i in range(s.shape[0]):
        wi, wd = ops.knn_build(s[i], seg[i], k=_K, backend=backend)
        assert_bitwise(bi[i], wi, context=f"{backend}/bin{i}/idx")
        assert_bitwise(bd[i], wd, context=f"{backend}/bin{i}/d2")
        wa = ops.knn_aggregate(f[i], wi, wd, scale=10.0, backend=backend)
        assert_bitwise(agg[i], wa, context=f"{backend}/bin{i}/agg")


@pytest.mark.parametrize("backend",
                         [b for b in backend_sweep() if b != "xla"])
def test_non_default_bm_is_bitwise(backend):
    """The row tile only splits the query axis; selection state is
    per-row, so every bm must reproduce the default bitwise."""
    s, f, seg = _problem(seed=6)
    idx0, d20 = ops.knn_build(s, seg, k=_K, backend=backend)
    agg0 = ops.knn_aggregate(f, idx0, d20, backend=backend)
    for bm in (8, 16):
        idx, d2 = ops.knn_build(s, seg, k=_K, bm=bm, backend=backend)
        assert_bitwise(idx, idx0, context=f"{backend}/bm={bm}")
        assert_bitwise(d2, d20, context=f"{backend}/bm={bm}")
        agg = ops.knn_aggregate(f, idx, d2, bm=bm, backend=backend)
        assert_bitwise(agg, agg0, context=f"{backend}/bm={bm}/agg")


# ----------------------------------------------------------- golden ----
def _generate() -> dict:
    s, f, seg = _problem(seed=_SEED)
    idx, d2 = knn_build_ref(s, seg, k=_K)
    agg = knn_aggregate_ref(f, idx, d2, scale=10.0)
    return dict(s=np.asarray(s), f=np.asarray(f), seg=np.asarray(seg),
                k=np.int32(_K), idx=np.asarray(idx), d2=np.asarray(d2),
                agg=np.asarray(agg))


@pytest.fixture(scope="module")
def golden():
    if os.environ.get("REPRO_REGEN_GOLDEN") == "1":
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        np.savez(GOLDEN, **_generate())
    if not GOLDEN.exists():
        pytest.fail(f"missing golden fixture {GOLDEN}; regenerate with "
                    "REPRO_REGEN_GOLDEN=1")
    with np.load(GOLDEN) as z:
        return {k: z[k] for k in z.files}


def test_golden_fixture_is_current(golden):
    fresh = _generate()
    assert set(fresh) == set(golden)
    for name, arr in fresh.items():
        np.testing.assert_array_equal(arr, golden[name], err_msg=name)


@pytest.mark.parametrize("backend", backend_sweep())
def test_kernels_match_golden(backend, golden):
    """Selection order (idx, d2 — bitwise) and aggregation (tolerance)
    against the committed bytes: any change to the tie-break contract
    or the accumulation arithmetic shows up as a fixture diff."""
    idx, d2 = ops.knn_build(jnp.asarray(golden["s"]),
                            jnp.asarray(golden["seg"]),
                            k=int(golden["k"]), backend=backend)
    assert_bitwise(idx, golden["idx"], context=f"{backend}/idx")
    assert_bitwise(d2, golden["d2"], context=f"{backend}/d2")
    agg = ops.knn_aggregate(jnp.asarray(golden["f"]), idx, d2,
                            scale=10.0, backend=backend)
    assert_close(agg, golden["agg"], dtype="float32", context=backend)


# ------------------------------------------------- tuning integration ----
def test_tuning_keys_and_candidates():
    from repro.tuning import knn_aggregate_key, knn_build_key
    from repro.tuning.candidates import (default_knn_aggregate,
                                         default_knn_build,
                                         knn_aggregate_candidates,
                                         knn_build_candidates)
    k1 = knn_build_key(32, 4, 8, "float32", "xla")
    assert k1.encode() == "knn_build|32x4x8|float32|xla"
    kb = knn_build_key(32, 4, 8, "float32", "xla", batch=8)
    assert kb.encode() == "knn_build|8x32x4x8|float32|xla"
    ka = knn_aggregate_key(32, 22, 8, "float32", "pallas", batch=8)
    assert ka.encode() == "knn_aggregate|8x32x22x8|float32|pallas"
    for cands, default in ((knn_build_candidates(32),
                            default_knn_build(32)),
                           (knn_aggregate_candidates(32),
                            default_knn_aggregate(32))):
        assert cands[0] == default        # heuristic default leads
        assert all(32 % c["bm"] == 0 for c in cands)
        assert len(cands) == len({tuple(sorted(c.items()))
                                  for c in cands})


def test_autotune_records_winners(tmp_path):
    from repro.tuning import TuningCache, knn_aggregate_key
    from repro.tuning.autotune import tune_knn_aggregate, tune_knn_build
    cache = TuningCache(tmp_path / "tc.json")
    cfg = tune_knn_build(16, 4, 4, dtype="float32", backend="xla",
                         cache=cache, iters=1)
    assert "bm" in cfg and len(cache) == 1
    cfg = tune_knn_aggregate(16, 8, 4, scale=7.5, dtype="float32",
                             backend="xla", cache=cache, iters=1)
    assert "scale" not in cfg             # the binder reads knobs only
    entry = cache.entry(knn_aggregate_key(16, 8, 4, "float32", "xla"))
    assert entry.config["scale"] == 7.5   # …but warm-up can replay it
    assert len(cache) == 2


def test_warmup_replays_knn_entries():
    from repro.tuning import (TuningCache, knn_aggregate_key,
                              knn_build_key, warm_from_cache)
    cache = TuningCache()
    cache.put(knn_build_key(16, 4, 4, "float32", "xla"), {"bm": 16})
    cache.put(knn_build_key(16, 4, 4, "float32", "xla", batch=2),
              {"bm": 16})
    cache.put(knn_aggregate_key(16, 8, 4, "float32", "xla"),
              {"bm": 16, "scale": 5.0})
    assert warm_from_cache(cache) == 3
    assert warm_from_cache(cache, kernels=("knn_build",)) == 2


def test_deployed_graph_emits_knn_problems():
    """The raggedized deploy graph advertises knn tuning problems with
    the batched (bins-leading) shapes — the five-way agreement between
    registry, cache keys, candidates, autotuner, and warm-up."""
    import repro.core.caloclusternet as ccn
    from repro.core.pipeline import Requirements, deploy
    cfg = ccn.current_detector_config()
    params = ccn.init(jax.random.PRNGKey(0), cfg)
    g = ccn.to_graph(params, cfg)
    req = Requirements(design_point=3, platform="cpu",
                       precision_policy="fp", n_hits=cfg.n_hits,
                       target_throughput=5e4, max_latency_s=2e-3)
    rp = deploy(g, req, batch=4, ragged=True,
                fuse_gravnet_block=False)
    from repro.tuning.autotune import graph_kernel_problems
    probs = graph_kernel_problems(rp.pipe.graph, n_rows=cfg.n_hits,
                                  backend="xla", batch=4)
    kinds = {p.kernel for p in probs}
    assert "knn_build" in kinds and "knn_aggregate" in kinds
    for p in probs:
        if p.kernel.startswith("knn_"):
            assert p.shape[0] == 4        # bins-leading batched shape
            assert p.shape[1] == cfg.n_hits
