"""Property-based tests for ``repro.core.quantization`` — the
algebraic contracts the quantized megakernel's calibration rests on.

The fused int8 block bakes ``activation_scale`` outputs as kernel
constants and ships ``quantize_weight`` results as operands, so these
invariants (idempotence, range clamps, round-trip bounds, STE
pass-through) are load-bearing for the deployed numerics, not just
QAT. Each property runs as a hypothesis test when hypothesis is
installed (``_hypothesis_support`` degrades them to skips otherwise)
plus a deterministic seed sweep that always executes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_support import given, settings, st
from _numerics import assert_bitwise, assert_close

from repro.core.quantization import (activation_scale, fake_quant,
                                     quantize_weight)


def _rand(seed, shape=(64,), spread=3.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) * spread, jnp.float32)


# ----------------------------------------------------------- fake_quant ----
def _check_idempotent(x, scale):
    once = fake_quant(x, scale=scale)
    twice = fake_quant(once, scale=scale)
    # grid points are fixed points: q*s/s re-rounds to exactly q
    assert_bitwise(twice, once, context="fake_quant idempotence")


def _check_range_and_grid(x, scale, bits=8):
    qmax = 2.0 ** (bits - 1) - 1.0
    y = np.asarray(fake_quant(x, scale=scale, bits=bits), np.float64)
    assert np.max(np.abs(y)) <= qmax * scale * (1 + 1e-6), \
        "output escapes the clamp range"
    steps = y / float(scale)
    assert np.max(np.abs(steps - np.round(steps))) < 1e-3, \
        "output is off the quantization grid"


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("scale", [0.004, 0.02, 0.3])
def test_fake_quant_idempotent_and_clamped(seed, scale):
    x = _rand(seed)
    _check_idempotent(x, scale)
    _check_range_and_grid(x, scale)


def test_fake_quant_auto_scale_covers_absmax():
    """Without an explicit scale the absmax sample maps to the top
    grid step, so the clamp never clips calibration data."""
    x = _rand(9)
    y = fake_quant(x)
    assert_close(jnp.max(jnp.abs(y)), jnp.max(jnp.abs(x)), rtol=1e-5,
                 atol=1e-7)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       scale=st.floats(1e-4, 1.0, allow_nan=False, allow_infinity=False))
def test_fake_quant_property_idempotent(seed, scale):
    x = _rand(seed)
    _check_idempotent(x, scale)
    _check_range_and_grid(x, scale)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), bits=st.integers(2, 8))
def test_fake_quant_property_bitwidth_clamp(seed, bits):
    x = _rand(seed, spread=10.0)
    _check_range_and_grid(x, 0.05, bits=bits)


# --------------------------------------------------------- STE gradient ----
def test_ste_gradient_passes_through_in_range():
    """QAT contract: inside the clamp the quantizer is gradient-
    transparent (d fake_quant/dx == 1), outside it the clip zeroes the
    gradient — with an explicit, non-clipping scale both regimes are
    exact up to one f32 rounding of scale * (1/scale)."""
    x = jnp.asarray([-1.5, -0.3, 0.0, 0.4, 1.2], jnp.float32)
    scale = 0.02    # qmax*scale = 2.54 > max|x|: nothing clips
    g = jax.grad(lambda v: jnp.sum(fake_quant(v, scale=scale)))(x)
    assert_close(g, jnp.ones_like(x), rtol=1e-6, atol=1e-6)
    far = jnp.asarray([5.0, -7.0], jnp.float32)     # beyond the clamp
    g_far = jax.grad(lambda v: jnp.sum(fake_quant(v, scale=scale)))(far)
    assert_bitwise(g_far, jnp.zeros_like(far))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_ste_gradient_property_in_range(seed):
    x = _rand(seed, spread=1.0)
    scale = float(jnp.max(jnp.abs(x))) / 100.0 + 1e-6   # nothing clips
    g = jax.grad(lambda v: jnp.sum(fake_quant(v, scale=scale)))(x)
    assert_close(g, jnp.ones_like(x), rtol=1e-6, atol=1e-6)


# ------------------------------------------------------ quantize_weight ----
def _check_weight_roundtrip(w):
    w_q, scale = quantize_weight(w)
    assert w_q.dtype == jnp.int8 and scale.dtype == jnp.float32
    q = np.asarray(w_q, np.float64)
    assert np.all(np.abs(q) <= 127)
    s = np.asarray(scale, np.float64)
    assert np.all(s > 0)
    # per-output-channel round-trip error is at most half a step
    err = np.abs(q * s[None, :] - np.asarray(w, np.float64))
    assert np.all(err <= s[None, :] * 0.5 + 1e-7), \
        f"round-trip error {err.max():.3e} exceeds scale/2"


@pytest.mark.parametrize("seed", [0, 5, 17])
def test_quantize_weight_roundtrip(seed):
    _check_weight_roundtrip(_rand(seed, shape=(24, 10), spread=0.4))


def test_quantize_weight_tiny_column_floor():
    """An all-zero column hits the 1e-8 scale floor instead of
    dividing by zero, and round-trips to exact zeros."""
    w = jnp.zeros((8, 3), jnp.float32).at[:, 1].set(0.25)
    w_q, scale = quantize_weight(w)
    assert float(scale[0]) > 0 and float(scale[2]) > 0
    assert_bitwise(w_q[:, 0], jnp.zeros(8, jnp.int8))
    _check_weight_roundtrip(w)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       din=st.integers(1, 32), dout=st.integers(1, 16))
def test_quantize_weight_property_roundtrip(seed, din, dout):
    _check_weight_roundtrip(_rand(seed, shape=(din, dout), spread=0.5))


# ----------------------------------------------------- activation_scale ----
def test_activation_scale_monotone_with_floor():
    """Larger calibration absmax never shrinks the scale, and the
    1e-8 floor keeps degenerate (all-zero) calibration data from
    producing a zero or negative scale."""
    xs = [0.0, 1e-12, 1e-8, 1e-3, 0.5, 3.0, 1e4]
    scales = [activation_scale(v) for v in xs]
    assert all(s > 0 for s in scales)
    assert all(a <= b + 1e-18 for a, b in zip(scales, scales[1:]))
    assert scales[0] == scales[1] == activation_scale(1e-9)  # floored


@settings(max_examples=40, deadline=None)
@given(a=st.floats(0, 1e6, allow_nan=False, allow_infinity=False),
       b=st.floats(0, 1e6, allow_nan=False, allow_infinity=False))
def test_activation_scale_property_monotone(a, b):
    lo, hi = sorted((a, b))
    assert 0 < activation_scale(lo) <= activation_scale(hi)


def test_activation_scale_maps_absmax_to_top_step():
    """The scale maps the observed absmax onto the top int8 step, so a
    calibrated tensor quantizes without clipping: absmax/scale = 127."""
    for absmax in (0.01, 0.7, 42.0):
        assert abs(absmax / activation_scale(absmax) - 127.0) < 1e-3
