"""Property-test battery for the ragged (padding-free) event path.

Layers covered, bottom up:

- CSR utilities (``data/ragged.py``): pack→unpack round-trip identity
  for arbitrary occupancy mixes including 0-hit and max-hit events,
  offset monotonicity/consistency, the shared ``group_by_segment``
  CSR builder, and bin-packing reversibility;
- kernel semantics: packed kNN neighbor selection is invariant to the
  order events arrive in the batch (bin packing preserves within-event
  row order, so per-event results cannot depend on bin layout);
- megakernel parity: ``gravnet_block_ragged`` on a packed bin matches
  the padded ``gravnet_block`` on the same event within the
  ``_numerics.py`` f32 tolerances, on xla AND pallas_interpret;
- deployment: ``deploy(ragged=True)`` matches the bucketed deployment
  end to end on every occupancy profile tested, and the
  bucket-overflow blind spot is pinned — an event exceeding every
  bucket cap is *routed* (to the largest bucket, truncated, by
  contract) while the ragged path serves the same event exactly.

Property tests use hypothesis when installed
(``tests/_hypothesis_support.py``); seed-sweep versions of the same
invariants always run.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_support import given, settings, st
from _numerics import assert_bitwise, assert_close

from repro.data.ragged import (RaggedBatch, bin_pack, bins_needed,
                               group_by_segment, offsets_from_counts,
                               pack_events, unpack_binned, unpack_events,
                               validate_ragged)

jax.config.update("jax_platform_name", "cpu")

_N = 32     # detector hit capacity used throughout
_D = 4

PARITY_BACKENDS = ("xla", "pallas_interpret")


def _ragged_from_counts(counts, d=_D, *, seed=0) -> RaggedBatch:
    rng = np.random.default_rng(seed)
    offs = offsets_from_counts(counts)
    feats = rng.normal(size=(int(offs[-1]), d)).astype(np.float32)
    return RaggedBatch(feats=feats, offsets=offs)


# ------------------------------------------------------------ CSR layer ----
def _roundtrip(counts, seed):
    rb = _ragged_from_counts(counts, seed=seed)
    validate_ragged(rb)
    offs = np.asarray(rb.offsets)
    assert offs[0] == 0 and offs[-1] == rb.feats.shape[0]
    assert (np.diff(offs) >= 0).all()            # monotone
    np.testing.assert_array_equal(rb.counts(), counts)

    feats, mask = unpack_events(rb, _N)
    rb2 = pack_events(feats, mask)
    np.testing.assert_array_equal(rb2.offsets, rb.offsets)
    np.testing.assert_array_equal(rb2.feats, rb.feats)    # bit-exact

    bp = bin_pack(rb, _N)
    assert bp.feats.shape[0] == max(bins_needed(counts, _N), 1)
    # the index planes invert the packing exactly
    back = unpack_binned(bp.feats, bp.segids, bp.slots, rb.n_events, _N)
    np.testing.assert_array_equal(back, feats)
    np.testing.assert_array_equal(
        unpack_binned(bp.mask[..., None], bp.segids, bp.slots,
                      rb.n_events, _N)[..., 0], mask)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, _N), min_size=1, max_size=10),
       st.integers(0, 2 ** 16))
def test_csr_roundtrip_property(counts, seed):
    """pack→unpack identity + offset invariants for arbitrary
    occupancy mixes (hypothesis draws include 0-hit and max-hit)."""
    _roundtrip(counts, seed)


@pytest.mark.parametrize("seed", range(4))
def test_csr_roundtrip_seed_sweep(seed):
    """Always-on version of the round-trip property; the mixes pin the
    edge cases explicitly: all-empty, max-hit, and a skewed mix."""
    for counts in ([0], [0, 0, 0], [_N], [_N, 0, _N],
                   [1, _N, 0, 7, _N // 2, 0]):
        _roundtrip(counts, seed)


def test_offsets_reject_malformed():
    with pytest.raises(ValueError):
        offsets_from_counts([-1])
    with pytest.raises(ValueError):
        validate_ragged(RaggedBatch(np.zeros((3, 2), np.float32),
                                    np.asarray([0, 2])))     # offs[-1] != R
    with pytest.raises(ValueError):
        validate_ragged(RaggedBatch(np.zeros((3, 2), np.float32),
                                    np.asarray([0, 2, 1, 3])))  # not monotone
    with pytest.raises(ValueError):
        bin_pack(_ragged_from_counts([_N + 1], seed=0), _N)  # event > bin


def test_group_by_segment_is_stable():
    """The shared CSR builder (ragged packer + GraphSAGE sampler):
    rows group contiguously by segment with relative order preserved."""
    vals = np.arange(10)
    segs = np.asarray([2, 0, 1, 0, 2, 1, 0, 2, 1, 0])
    grouped, offs = group_by_segment(vals, segs, 3)
    np.testing.assert_array_equal(offs, [0, 4, 7, 10])
    np.testing.assert_array_equal(grouped, [1, 3, 6, 9, 2, 5, 8, 0, 4, 7])
    # segments with zero members still get (empty) CSR ranges
    _, offs = group_by_segment(vals[:2], np.asarray([3, 3]), 5)
    np.testing.assert_array_equal(offs, [0, 0, 0, 0, 2, 2])
    with pytest.raises(ValueError):
        group_by_segment(vals, segs, 2)          # id out of range


# -------------------------------------------- kNN permutation invariance ----
def _per_event_knn(s_events, k):
    """Reference: each event kNN'd alone (segids all-0)."""
    from repro.kernels.ref import knn_build_ref
    out = []
    for se in s_events:
        idx, d2 = knn_build_ref(jnp.asarray(se),
                                jnp.zeros((se.shape[0],), jnp.int32), k=k)
        out.append((np.asarray(idx), np.asarray(d2)))
    return out


def _packed_knn_by_event(s_events, order, k, *, backend):
    """Pack events in ``order`` and express each event's kNN result in
    within-event slot coordinates (layout-independent form)."""
    from repro.kernels import ops
    counts = [s_events[e].shape[0] for e in order]
    rb = RaggedBatch(
        feats=np.concatenate([s_events[e] for e in order]),
        offsets=offsets_from_counts(counts))
    bp = bin_pack(rb, _N)
    idx, d2 = ops.knn_build_batched(
        jnp.asarray(bp.feats), jnp.asarray(bp.segids), k=k,
        backend=backend)
    idx, d2 = np.asarray(idx), np.asarray(d2)
    per_event = {}
    for b in range(bp.segids.shape[0]):
        for r in range(_N):
            e = bp.segids[b, r]
            if e < 0:
                continue
            valid = d2[b, r] < 0.5e30
            # neighbor bin-rows -> within-event slots (same bin always:
            # selection is segment-masked)
            nslots = np.where(valid, bp.slots[b, idx[b, r]], -1)
            per_event.setdefault(int(order[e]), []).append(
                (int(bp.slots[b, r]), nslots, np.asarray(d2[b, r])))
    return per_event


@pytest.mark.parametrize("backend", PARITY_BACKENDS)
@pytest.mark.parametrize("perm_seed", [0, 1, 2])
def test_packed_knn_invariant_to_event_order(backend, perm_seed):
    """Permuting the events of a batch (hence the whole bin layout)
    must not change any event's neighbor structure: packed results in
    within-event slot coordinates equal the event-alone reference."""
    rng = np.random.default_rng(11)
    k = 4
    s_events = [rng.normal(size=(int(c), 3)).astype(np.float32)
                for c in (7, _N, 12, 5, 20)]
    ref = _per_event_knn(s_events, k)
    order = np.random.default_rng(perm_seed).permutation(len(s_events))
    got = _packed_knn_by_event(s_events, order, k, backend=backend)
    for e, rows in got.items():
        ridx, rd2 = ref[e]
        for slot, nslots, d2row in rows:
            valid = rd2[slot] < 0.5e30
            np.testing.assert_array_equal(
                nslots[valid], ridx[slot][valid],
                err_msg=f"{backend}/event{e}/slot{slot}")
            assert_bitwise(d2row, rd2[slot],
                           context=f"{backend}/event{e}/slot{slot}/d2")


# --------------------------------------------------- megakernel parity ----
@pytest.mark.parametrize("backend", PARITY_BACKENDS)
@pytest.mark.parametrize("occ", [3, 17, _N])
def test_ragged_block_matches_padded_block(backend, occ):
    """gravnet_block_ragged on a packed bin == padded gravnet_block on
    the same event, within the f32 dtype table, on xla AND
    pallas_interpret — the kernel-level ragged-vs-padded contract."""
    from repro.kernels import ops
    rng = np.random.default_rng(5)
    dh, ds, df, dout, k = 24, 3, 10, 24, 6
    x = rng.normal(size=(occ, dh)).astype(np.float32)
    ws = (rng.normal(size=(dh, ds)) * 0.3).astype(np.float32)
    bs = rng.normal(size=(ds,)).astype(np.float32) * 0.1
    wf = (rng.normal(size=(dh, df)) * 0.3).astype(np.float32)
    bf = rng.normal(size=(df,)).astype(np.float32) * 0.1
    wo = (rng.normal(size=(dh + 2 * df, dout)) * 0.3).astype(np.float32)
    bo = rng.normal(size=(dout,)).astype(np.float32) * 0.1

    xp = np.zeros((_N, dh), np.float32)
    xp[:occ] = x
    maskp = np.zeros((_N,), np.float32)
    maskp[:occ] = 1.0
    want = ops.gravnet_block(jnp.asarray(xp), jnp.asarray(maskp),
                             ws, bs, wf, bf, wo, bo, k=k,
                             backend=backend)

    seg = np.full((1, _N), -1, np.int32)
    seg[0, :occ] = 0
    got = ops.gravnet_block_ragged(jnp.asarray(xp[None]),
                                   jnp.asarray(seg), ws, bs, wf, bf,
                                   wo, bo, k=k, backend=backend)
    assert_close(got[0, :occ], np.asarray(want)[:occ], dtype="float32",
                 context=f"{backend}/occ={occ}")
    # padding rows are zeroed, not garbage
    np.testing.assert_array_equal(np.asarray(got[0, occ:]), 0.0)


# -------------------------------------------------- deployed end to end ----
def _deploys():
    import repro.core.caloclusternet as ccn
    from repro.core.pipeline import Requirements, deploy, deploy_bucketed
    cfg = ccn.current_detector_config()
    params = ccn.init(jax.random.PRNGKey(1), cfg)
    g = ccn.to_graph(params, cfg)
    req = Requirements(design_point=3, platform="cpu",
                       precision_policy="fp", n_hits=cfg.n_hits,
                       target_throughput=5e4, max_latency_s=2e-3)
    return cfg, g, req, deploy, deploy_bucketed


def _profile_feeds(cfg, occupancies, *, batch=8, seed=3):
    from repro.data.belle2 import current_detector, generate, with_occupancy
    gen = with_occupancy(current_detector(), occupancies)
    data = generate(gen, batch, seed=seed)
    return {"hits": data["feats"], "mask": data["mask"]}


@pytest.mark.parametrize("occupancies", [(4, 8), (9, 17, 25), (32,)])
def test_deployed_ragged_matches_bucketed(occupancies):
    """deploy(ragged=True) == deploy_bucketed within the numerics
    tables for every occupancy profile tested: per-event valid head
    rows and the condensation outputs agree."""
    cfg, g, req, deploy, deploy_bucketed = _deploys()
    feeds = _profile_feeds(cfg, occupancies)
    bucketed = deploy_bucketed(g, req, buckets=(8, 16, 32), microbatch=4)
    ragged = deploy(g, req, batch=4, ragged=True)
    want = bucketed(feeds)
    got = ragged(feeds)
    counts = np.asarray(feeds["mask"]).sum(axis=1).astype(int)
    for h in ("beta", "coords", "energy", "cls"):
        wh, gh = np.asarray(want[h]), np.asarray(got[h])
        for e, c in enumerate(counts):
            assert_close(gh[e, :c], wh[e, :c], dtype="float32",
                         context=f"{occupancies}/{h}/event{e}")
    for name in want["cps"]:
        assert_close(np.asarray(got["cps"][name], np.float32),
                     np.asarray(want["cps"][name], np.float32),
                     dtype="float32", context=f"cps/{name}")


def test_deployed_ragged_matches_padded_on_interpret():
    """One end-to-end parity run through the Pallas kernel bodies
    (interpret mode): ragged vs the single full-width padded
    executable."""
    cfg, g, req, deploy, _ = _deploys()
    feeds = _profile_feeds(cfg, (9, 17, 25), batch=4)
    padded = deploy(g, req, batch=4,
                    kernel_backend="pallas_interpret")(feeds)
    got = deploy(g, req, batch=4, ragged=True,
                 kernel_backend="pallas_interpret")(feeds)
    counts = np.asarray(feeds["mask"]).sum(axis=1).astype(int)
    for h in ("beta", "coords", "energy", "cls"):
        for e, c in enumerate(counts):
            assert_close(np.asarray(got[h])[e, :c],
                         np.asarray(padded[h])[e, :c], dtype="float32",
                         context=f"{h}/event{e}")


def test_bucket_overflow_routed_not_dropped_and_ragged_exact():
    """The bucket-overflow blind spot, pinned: an event exceeding
    every bucket cap is *routed* to the largest bucket (and truncated
    there — the documented fallback), while the ragged path serves the
    identical event exactly (it matches the full-width padded
    pipeline on every hit)."""
    from repro.serving.router import pick_bucket
    buckets = (8, 16, 24)
    assert pick_bucket(30, buckets) == 24        # routed, never an error
    assert pick_bucket(0, buckets) == 8
    assert pick_bucket(24, buckets) == 24

    cfg, g, req, deploy, deploy_bucketed = _deploys()
    rng = np.random.default_rng(9)
    occ = 30                                      # > every bucket cap
    feeds = {"hits": rng.normal(size=(2, cfg.n_hits, cfg.d_in)
                                ).astype(np.float32),
             "mask": np.zeros((2, cfg.n_hits), np.float32)}
    feeds["mask"][:, :occ] = 1.0

    bucketed = deploy_bucketed(g, req, buckets=buckets, microbatch=2)
    assert bucketed.classify(occ) == 24
    wb = bucketed(feeds)                          # served, not dropped
    assert np.asarray(wb["beta"]).shape[1] == 24  # truncation contract

    padded = deploy(g, req, batch=2)(feeds)
    got = deploy(g, req, batch=2, ragged=True)(feeds)
    for h in ("beta", "coords", "energy", "cls"):
        for e in range(2):
            assert_close(np.asarray(got[h])[e, :occ],
                         np.asarray(padded[h])[e, :occ],
                         dtype="float32", context=f"{h}/event{e}")
    for name in padded["cps"]:
        assert_close(np.asarray(got["cps"][name], np.float32),
                     np.asarray(padded["cps"][name], np.float32),
                     dtype="float32", context=f"cps/{name}")


def test_launch_splitting_never_truncates():
    """More events than one launch holds: the plan splits into several
    launches and every event still comes back (max_events caps a
    launch, not the submission)."""
    cfg, g, req, deploy, _ = _deploys()
    ragged = deploy(g, req, batch=2, ragged=True, max_events=3)
    rng = np.random.default_rng(2)
    b = 11                                        # forces >= 4 launches
    feeds = {"hits": rng.normal(size=(b, cfg.n_hits, cfg.d_in)
                                ).astype(np.float32),
             "mask": (rng.uniform(size=(b, cfg.n_hits)) < 0.5
                      ).astype(np.float32)}
    plan = ragged._plan_launches(
        np.asarray(feeds["mask"]).sum(axis=1).astype(int))
    assert len(plan) >= 4
    assert plan[0][0] == 0 and plan[-1][1] == b
    assert all(a == c for (_, a), (c, _) in zip(plan, plan[1:]))
    out = ragged(feeds)
    assert np.asarray(out["beta"]).shape[0] == b
    want = deploy(g, req, batch=2)(feeds)
    mask = np.asarray(feeds["mask"]) > 0
    counts = mask.sum(axis=1).astype(int)
    # the ragged path compacts each event's valid hits, the padded one
    # keeps original positions — compare valid rows in order
    for e, c in enumerate(counts):
        assert_close(np.asarray(out["beta"])[e, :c],
                     np.asarray(want["beta"])[e][mask[e]], dtype="float32",
                     context=f"event{e}")


def test_raggedize_refuses_batchnorm():
    from repro.core.graph_ir import Graph, Operator
    from repro.core.op_registry import GraphVerificationError
    from repro.core.passes.ragged import raggedize
    g = Graph()
    g.add(Operator(name="x", op_type="input", out_dim=4,
                   attrs={"feature": "x"}))
    g.add(Operator(name="bn", op_type="batchnorm", inputs=["x"],
                   out_dim=4,
                   params={"scale": np.ones(4, np.float32),
                           "bias": np.zeros(4, np.float32),
                           "mean": np.zeros(4, np.float32),
                           "var": np.ones(4, np.float32)}))
    with pytest.raises(GraphVerificationError):
        raggedize(g)


def test_ragged_requires_fp_policy():
    cfg, g, req, deploy, _ = _deploys()
    req = dataclasses.replace(req, precision_policy="mixed")
    with pytest.raises(NotImplementedError):
        deploy(g, req, ragged=True)
