"""Tuning cache + autotuner + regression gate tests.

Covers the hard invariants of the tuning subsystem:

- cache hit/miss semantics and JSON round-trip determinism;
- graceful fallback on missing / corrupt / stale cache files;
- ``kernel_optimize`` with an *empty* cache reproduces the heuristic
  bindings bit-for-bit (tuning is an overlay, never a behavior change);
- cached winners actually bind (and are marked as searched);
- replica warm-up replays cached shapes at startup, best-effort;
- the benchmark-regression comparator passes/fails correctly, and the
  harness runner exits nonzero on broken sections.
"""
from __future__ import annotations

import json
import os
import sys

import jax
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))   # benchmarks/ lives at the repo root

from repro.core import caloclusternet as ccn
from repro.core.passes.kernel_opt import kernel_optimize
from repro.core.passes.mapping import map_templates
from repro.core.passes.partition import partition
from repro.core.quantization import apply_precision_policy
from repro.tuning import (SCHEMA_VERSION, KernelKey, TuningCache,
                          fused_dense_key, gravnet_key, make_warmup,
                          tune_fused_dense, warm_from_cache)
from repro.tuning.candidates import default_fused_dense


# ------------------------------------------------------------------ cache ----
def test_cache_hit_and_miss(tmp_path):
    cache = TuningCache(tmp_path / "tc.json")
    key = fused_dense_key(128, 64, 64, "float32", "xla")
    assert cache.lookup(key) is None                     # miss
    cache.put(key, {"variant": "flattened"}, us=12.5, candidates=3)
    assert cache.lookup(key) == {"variant": "flattened"}  # hit
    # a different backend/dtype/shape is a distinct problem
    assert cache.lookup(fused_dense_key(128, 64, 64, "int8", "xla")) is None
    assert cache.lookup(fused_dense_key(256, 64, 64, "float32", "xla")) is None
    assert key in cache and len(cache) == 1


def test_cache_round_trip_determinism(tmp_path):
    p = tmp_path / "tc.json"
    cache = TuningCache()
    cache.put(fused_dense_key(128, 64, 64, "int8", "xla"),
              {"variant": "looped", "bm": 32, "bn": 128, "bk": 128},
              us=60.0, default_us=100.0, candidates=4)
    cache.put(gravnet_key(128, 4, 22, 8, "float32", "xla"),
              {"bm": 64}, us=300.0, candidates=5)
    cache.save(p)
    first = p.read_bytes()
    loaded = TuningCache.load(p)
    assert loaded.load_error is None
    assert {k.encode() for k in loaded.entries()} \
        == {k.encode() for k in cache.entries()}
    for k, e in cache.entries().items():
        le = loaded.entry(k)
        assert le.config == e.config and le.us == e.us \
            and le.default_us == e.default_us \
            and le.candidates == e.candidates
    loaded.save(p)                       # re-serialize → byte-identical
    assert p.read_bytes() == first


def test_cache_key_encode_decode():
    key = KernelKey("flash_attention", (8, 512, 512, 64), "float32",
                    "pallas")
    assert KernelKey.decode(key.encode()) == key


def test_cache_missing_file_is_empty(tmp_path):
    cache = TuningCache.load(tmp_path / "nope.json")
    assert len(cache) == 0 and cache.load_error is None


def test_cache_corrupt_file_falls_back(tmp_path):
    p = tmp_path / "tc.json"
    p.write_text("{this is not json")
    cache = TuningCache.load(p)
    assert len(cache) == 0
    assert cache.load_error and "tc.json" in cache.load_error
    # wrong top-level type
    p.write_text("[1, 2, 3]")
    assert TuningCache.load(p).load_error is not None


def test_cache_stale_schema_ignored(tmp_path):
    p = tmp_path / "tc.json"
    p.write_text(json.dumps({
        "schema": SCHEMA_VERSION + 1,
        "entries": {"fused_dense|1x1x1|float32|xla":
                    {"config": {"variant": "flattened"}}},
    }))
    cache = TuningCache.load(p)
    assert len(cache) == 0 and "stale" in cache.load_error


def test_cache_skips_malformed_entries(tmp_path):
    p = tmp_path / "tc.json"
    good = fused_dense_key(64, 32, 32, "float32", "xla")
    p.write_text(json.dumps({
        "schema": SCHEMA_VERSION,
        "entries": {
            good.encode(): {"config": {"variant": "flattened"}},
            "garbage-key": {"config": {}},
            "fused_dense|1x2x3|f32|xla": "not-a-dict",
        },
    }))
    cache = TuningCache.load(p)
    assert cache.lookup(good) == {"variant": "flattened"}
    assert len(cache) == 1


# ------------------------------------------------------- kernel_opt overlay ----
def _optimized_graph(tuning_cache=None, backend="xla"):
    cfg = ccn.CCNConfig()
    params = ccn.init(jax.random.PRNGKey(0), cfg)
    g = ccn.to_graph(params, cfg)
    g = partition(g)
    g = apply_precision_policy(g, policy="mixed")
    g = map_templates(g)
    for op in g:
        op.attrs_opt["P"] = 1
    return cfg, kernel_optimize(g, n_rows=cfg.n_hits,
                                tuning_cache=tuning_cache, backend=backend)


def test_kernel_opt_empty_cache_bit_for_bit():
    """An empty cache must reproduce the heuristic bindings exactly."""
    _, g_none = _optimized_graph(tuning_cache=None)
    _, g_empty = _optimized_graph(tuning_cache=TuningCache())
    a = {op.name: dict(op.attrs_opt) for op in g_none}
    b = {op.name: dict(op.attrs_opt) for op in g_empty}
    assert a == b
    assert not any("tuned" in v for v in b.values())


def test_kernel_opt_binds_cached_winner():
    cfg = ccn.CCNConfig()
    cache = TuningCache()
    # seed a winner for every fused_dense problem + the gravnet row-tile
    from repro.core.passes.kernel_opt import (fused_dense_dtype,
                                              fused_dense_shape)
    _, g_heur = _optimized_graph(tuning_cache=None)
    tuned_cfg = {"variant": "looped", "bm": 32, "bn": 128, "bk": 128}
    for op in g_heur:
        if op.template == "fused_dense":
            rows, d_in, d_out = fused_dense_shape(op, cfg.n_hits)
            cache.put(fused_dense_key(rows, d_in, d_out,
                                      fused_dense_dtype(op), "xla"),
                      tuned_cfg)
        elif op.op_type == "gravnet_aggregate":
            cache.put(gravnet_key(cfg.n_hits, op.attrs["d_s"],
                                  op.attrs["d_f"], op.attrs["k"],
                                  "float32", "xla"), {"bm": 64})
    _, g = _optimized_graph(tuning_cache=cache)
    denses = [op for op in g if op.template == "fused_dense"]
    assert denses
    for op in denses:
        assert op.attrs_opt["variant"] == "looped"
        assert op.attrs_opt["bm"] == 32
        assert op.attrs_opt.get("tuned") is True
    gn = [op for op in g if op.op_type == "gravnet_aggregate"]
    assert gn and all(op.attrs_opt.get("bm") == 64 for op in gn)


def test_kernel_opt_cache_for_other_backend_is_a_miss():
    cfg = ccn.CCNConfig()
    cache = TuningCache()
    from repro.core.passes.kernel_opt import (fused_dense_dtype,
                                              fused_dense_shape)
    _, g_heur = _optimized_graph(tuning_cache=None)
    for op in g_heur:
        if op.template == "fused_dense":
            rows, d_in, d_out = fused_dense_shape(op, cfg.n_hits)
            cache.put(fused_dense_key(rows, d_in, d_out,
                                      fused_dense_dtype(op), "pallas"),
                      {"variant": "looped", "bm": 8, "bn": 128, "bk": 128})
    _, g = _optimized_graph(tuning_cache=cache, backend="xla")
    heur = {op.name: dict(op.attrs_opt) for op in g_heur}
    got = {op.name: dict(op.attrs_opt) for op in g}
    assert got == heur          # pallas entries never bind for xla


# -------------------------------------------------------------- autotuner ----
def test_tune_fused_dense_prefers_default_under_min_gain(tmp_path):
    """With an unreachable min_gain the searched winner must be exactly
    the heuristic default — noise can never de-tune the pipeline.
    (pallas_interpret: a backend where the launch knobs are live.)"""
    cache = TuningCache()
    cfg = tune_fused_dense(16, 8, 8, backend="pallas_interpret",
                           cache=cache, iters=1, min_gain=10.0)
    assert cfg == default_fused_dense(16, 8, 8)
    key = fused_dense_key(16, 8, 8, "float32", "pallas_interpret")
    entry = cache.entry(key)
    assert entry is not None and entry.candidates >= 2
    assert entry.us is not None and entry.default_us is not None


def test_tune_on_knob_inert_backend_records_default_only():
    """The 'xla' wrappers ignore variant/blocks, so searching there
    would record timer noise as winners: the tuner must pin the
    heuristic default and measure it once."""
    cache = TuningCache()
    cfg = tune_fused_dense(16, 8, 8, backend="xla", cache=cache, iters=1)
    assert cfg == default_fused_dense(16, 8, 8)
    entry = cache.entry(fused_dense_key(16, 8, 8, "float32", "xla"))
    assert entry.candidates == 1 and entry.us == entry.default_us


def test_tune_fused_dense_int8_default_is_executor_default():
    from repro.tuning.candidates import fused_dense_int8_candidates
    cands = fused_dense_int8_candidates(128, 64, 64)
    assert cands[0] == {"variant": "looped", "bm": 128, "bn": 128,
                       "bk": 512}
    assert all(c["variant"] == "looped" for c in cands)


# ----------------------------------------------------------------- warm-up ----
def test_warm_from_cache_replays_entries():
    cache = TuningCache()
    cache.put(fused_dense_key(16, 8, 8, "float32", "xla"),
              {"variant": "flattened"})
    cache.put(gravnet_key(16, 4, 6, 4, "float32", "xla"), {"bm": 16})
    # stale/impossible entry must be skipped, not raise
    cache.put(KernelKey("fused_dense", (16, 8), "float32", "xla"),
              {"variant": "flattened"})
    assert warm_from_cache(cache) == 2
    assert warm_from_cache(cache, backend="pallas") == 0
    assert warm_from_cache(cache, kernels=("gravnet",)) == 1


def test_replica_engine_runs_warmup_before_traffic():
    import numpy as np

    from repro.serving import ShardedTriggerService
    calls = []
    cache = TuningCache()
    cache.put(fused_dense_key(16, 8, 8, "float32", "xla"),
              {"variant": "flattened"})

    def warmup():
        calls.append(len(calls))
        return make_warmup(cache, backend="xla")()

    svc = ShardedTriggerService(
        lambda feeds: {"y": feeds["x"] * 2.0}, n_replicas=2, microbatch=4,
        window_s=1e-3, devices=None, warmup_fn=warmup)
    try:
        # once per distinct device — both replicas share the default
        # device, so the second warm-up would re-execute a hot cache
        assert calls == [0]
        assert svc.replicas[0].warmed == 1
        assert svc.replicas[1].warmed == 0
        fut = svc.submit({"x": np.ones((3,), np.float32)})
        assert fut.result(timeout=30)["y"].sum() == 6.0
    finally:
        svc.close()


def test_replica_engine_survives_failing_warmup():
    import numpy as np

    from repro.serving import ShardedTriggerService

    def bad_warmup():
        raise RuntimeError("stale cache entry")

    svc = ShardedTriggerService(
        lambda feeds: {"y": feeds["x"] + 1.0}, n_replicas=1, microbatch=2,
        window_s=1e-3, devices=None, warmup_fn=bad_warmup)
    try:
        assert svc.replicas[0].warmed == 0
        fut = svc.submit({"x": np.zeros((2,), np.float32)})
        assert fut.result(timeout=30)["y"].sum() == 2.0
    finally:
        svc.close()


# -------------------------------------------------------- regression gate ----
def _bench(calib, **metrics):
    return {"schema": 1, "backend": "cpu", "calibration_s": calib,
            "metrics": metrics}


def test_regression_compare_passes_within_threshold():
    from benchmarks.regression import compare
    base = _bench(0.01, a_s=0.10, b_s=0.20)
    fresh = _bench(0.01, a_s=0.11, b_s=0.19)
    assert compare(base, fresh, 0.25) == []


def test_regression_compare_fails_on_slowdown():
    from benchmarks.regression import compare
    base = _bench(0.01, a_s=0.10, b_s=0.20)
    fresh = _bench(0.01, a_s=0.26, b_s=0.20)    # 2.6x on metric a
    regs = compare(base, fresh, 0.25)
    assert [r["metric"] for r in regs] == ["a_s"]
    assert regs[0]["slowdown"] == pytest.approx(2.6)


def test_regression_compare_normalizes_by_calibration():
    from benchmarks.regression import compare
    base = _bench(0.01, a_s=0.10)
    # machine is uniformly 2x slower: calibration scales too → no fail
    fresh = _bench(0.02, a_s=0.20)
    assert compare(base, fresh, 0.25) == []
    # metric slowed 2x on the same-speed machine → fail
    fresh2 = _bench(0.01, a_s=0.20)
    assert len(compare(base, fresh2, 0.25)) == 1


def test_regression_compare_flags_missing_metric():
    from benchmarks.regression import compare
    base = _bench(0.01, a_s=0.10, gone_s=0.10)
    fresh = _bench(0.01, a_s=0.10)
    regs = compare(base, fresh, 0.25)
    assert regs == [{"metric": "gone_s", "missing": True}]


def test_regression_check_exit_codes(tmp_path):
    from benchmarks import regression
    base_p = tmp_path / "base.json"
    fresh_p = tmp_path / "fresh.json"
    out_p = tmp_path / "out.json"
    base_p.write_text(json.dumps(_bench(0.01, a_s=0.10)))
    fresh_p.write_text(json.dumps(_bench(0.01, a_s=0.10)))
    ok = regression.main(["--check", "--baseline", str(base_p),
                          "--fresh", str(fresh_p), "--out", str(out_p)])
    assert ok == 0 and out_p.exists()
    bad = regression.main(["--check", "--baseline", str(base_p),
                           "--fresh", str(fresh_p),
                           "--inject-slowdown", "2.0",
                           "--out", str(out_p)])
    assert bad == 1
    missing = regression.main(["--check",
                               "--baseline", str(tmp_path / "none.json"),
                               "--fresh", str(fresh_p)])
    assert missing == 2


def test_committed_baseline_is_loadable():
    from benchmarks.regression import BASELINE_PATH, _load
    base = _load(BASELINE_PATH)
    assert base["metrics"] and base["calibration_s"] > 0


# ---------------------------------------------------------- bench harness ----
def test_run_harness_unknown_section_exits_nonzero(capsys):
    from benchmarks import run as bench_run
    assert bench_run.main(["no_such_section"]) == 2


def test_run_harness_failing_section_exits_nonzero(monkeypatch, capsys):
    import benchmarks.kernels_bench as kb
    from benchmarks import run as bench_run

    def boom():
        raise RuntimeError("section is broken")

    monkeypatch.setattr(kb, "run", boom)
    assert bench_run.main(["kernels"]) == 1
    out = capsys.readouterr().out
    assert "kernels,nan,ERROR" in out
