"""Tests: optimizer (+int8 states), checkpoint (atomic/elastic/async),
serving engine (in-order, batching, hedging), data pipeline."""
import os
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_support import given, settings, st

from repro.checkpoint import CheckpointManager, restore, save
from repro.checkpoint.manager import latest_step
from repro.data import Prefetcher
from repro.data.belle2 import Belle2Config, generate
from repro.data.graphs import NeighborSampler, build_triplets, powerlaw_graph
from repro.data.lm import lm_batch
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         cosine_warmup, compressed_psum)
from repro.serving import TriggerServingEngine


# ---------------------------------------------------------------- optim ----
def _quad_params(key):
    return {"a": jax.random.normal(key, (8, 4)),
            "b": jax.random.normal(key, (4,))}


@pytest.mark.parametrize("quant", [False, True])
def test_adamw_converges(quant):
    cfg = AdamWConfig(quantize_states=quant, weight_decay=0.0)
    params = _quad_params(jax.random.PRNGKey(0))
    target = _quad_params(jax.random.PRNGKey(1))

    def loss(p):
        return sum(jnp.sum((p[k] - target[k]) ** 2) for k in p)

    state = adamw_init(params, cfg)
    l0 = float(loss(params))
    for i in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(g, state, params, lr=0.05, cfg=cfg)
    assert float(loss(params)) < l0 * 0.01


def test_adamw_quantized_tracks_fp():
    cfg_q = AdamWConfig(quantize_states=True, weight_decay=0.0)
    cfg_f = AdamWConfig(quantize_states=False, weight_decay=0.0)
    p_q = p_f = _quad_params(jax.random.PRNGKey(2))
    s_q, s_f = adamw_init(p_q, cfg_q), adamw_init(p_f, cfg_f)

    def loss(p):
        return jnp.sum(p["a"] ** 2) + jnp.sum(jnp.sin(p["b"]) ** 2)

    for _ in range(50):
        p_q, s_q, _ = adamw_update(jax.grad(loss)(p_q), s_q, p_q,
                                   lr=0.01, cfg=cfg_q)
        p_f, s_f, _ = adamw_update(jax.grad(loss)(p_f), s_f, p_f,
                                   lr=0.01, cfg=cfg_f)
    # trajectories drift (quantization noise compounds) but must stay
    # close and reach the same loss level
    for k in p_q:
        np.testing.assert_allclose(np.asarray(p_q[k]), np.asarray(p_f[k]),
                                   atol=0.15)
    lq, lf = float(loss(p_q)), float(loss(p_f))
    assert abs(lq - lf) / lf < 0.05


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(clip_norm=1e-3, weight_decay=0.0)
    p = {"a": jnp.ones((4,))}
    s = adamw_init(p, cfg)
    g = {"a": jnp.full((4,), 1e6)}
    p2, s, aux = adamw_update(g, s, p, lr=1.0, cfg=cfg)
    assert float(aux["grad_norm"]) > 1e5
    assert np.all(np.isfinite(np.asarray(p2["a"])))


def test_cosine_schedule_shape():
    lr = cosine_warmup(peak_lr=1.0, warmup_steps=10, total_steps=100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert float(lr(100)) < float(lr(50)) < float(lr(10))
    assert abs(float(lr(100)) - 0.1) < 1e-6


def test_compressed_psum_error_feedback():
    """Over repeated rounds, error feedback keeps the mean unbiased."""
    mesh = jax.make_mesh((1,), ("dp",))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(64,)),
                    jnp.float32)
    err = jnp.zeros_like(x)
    acc_c = jnp.zeros_like(x)
    acc_t = jnp.zeros_like(x)

    def one(x, err):
        shard_map = getattr(jax, "shard_map", None)
        if shard_map is None:  # pre-0.6 jax keeps it in experimental
            from jax.experimental.shard_map import shard_map
        f = shard_map(
            lambda a, e: compressed_psum(a, e, "dp", 1), mesh=mesh,
            in_specs=(jax.sharding.PartitionSpec(),) * 2,
            out_specs=(jax.sharding.PartitionSpec(),) * 2)
        return f(x, err)

    for i in range(20):
        xi = x * (1 + 0.1 * i)
        out, err = one(xi, err)
        acc_c = acc_c + out
        acc_t = acc_t + xi
    # cumulative compressed sum tracks the true sum tightly
    rel = float(jnp.max(jnp.abs(acc_c - acc_t)) / jnp.max(jnp.abs(acc_t)))
    assert rel < 5e-3


# ----------------------------------------------------------- checkpoint ----
def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    tree = {"w": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.int32)}}
    save(str(tmp_path), 7, tree)
    like = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
    out, step = restore(str(tmp_path), 7, like)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_checkpoint_corruption_detected(tmp_path):
    tree = {"w": jnp.ones((4, 4))}
    save(str(tmp_path), 1, tree)
    leaf = os.path.join(str(tmp_path), "step_00000001", "leaf_00000.npy")
    arr = np.load(leaf)
    arr[0, 0] = 123.0
    np.save(leaf, arr)
    with pytest.raises(IOError):
        restore(str(tmp_path), 1, tree)


def test_checkpoint_manager_rotation_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_=True)
    tree = {"w": jnp.ones((8,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, jax.tree_util.tree_map(lambda a: a * s, tree))
    mgr.wait()
    mgr._gc()
    assert mgr.latest() == 4
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step"))
    assert len(steps) == 2
    out, _ = mgr.restore_latest(tree)
    np.testing.assert_array_equal(np.asarray(out["w"]), 4.0)


def test_checkpoint_elastic_resharding(tmp_path):
    """Save replicated, restore with an explicit (1-dev) sharding."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    save(str(tmp_path), 3, tree)
    specs = {"w": jax.sharding.PartitionSpec("data", None)}
    out, _ = restore(str(tmp_path), 3, tree, mesh=mesh, shardings=specs)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))
    assert latest_step(str(tmp_path)) == 3


# -------------------------------------------------------------- serving ----
def _echo_infer(feeds):
    time.sleep(0.002)
    return {"y": feeds["x"] * 2.0, "idx": feeds["x"][:, 0]}


def test_serving_in_order_and_batched():
    eng = TriggerServingEngine(_echo_infer, microbatch=8, window_s=2e-3)
    futs = []
    for i in range(50):
        futs.append(eng.submit({"x": np.full((3,), float(i), np.float32)}))
    results = [f.result(timeout=10) for f in futs]
    eng.drain()
    for i, r in enumerate(results):
        np.testing.assert_array_equal(r["y"], np.full((3,), 2.0 * i))
    assert eng.stats.batches <= 50 / 2  # actually batched
    s = eng.stats.summary()
    assert s["p99_us"] is not None and s["completed"] == 50
    eng.close()


def test_serving_deadline_pads_partial_batches():
    eng = TriggerServingEngine(_echo_infer, microbatch=16, window_s=1e-3)
    f = eng.submit({"x": np.ones((3,), np.float32)})
    r = f.result(timeout=5)
    np.testing.assert_array_equal(r["y"], 2.0)
    assert eng.stats.padded_events >= 15
    eng.close()


def test_serving_hedging_on_straggler():
    calls = {"n": 0}

    def flaky(feeds):
        calls["n"] += 1
        if calls["n"] == 1:
            time.sleep(0.5)  # straggler on first call
        return {"y": feeds["x"]}

    eng = TriggerServingEngine(flaky, microbatch=4, window_s=1e-3,
                               hedge_after_s=0.05)
    futs = [eng.submit({"x": np.full((2,), float(i), np.float32)})
            for i in range(4)]
    [f.result(timeout=10) for f in futs]
    assert eng.stats.hedged >= 1
    eng.close()


# ----------------------------------------------------------------- data ----
def test_belle2_generator_properties():
    cfg = Belle2Config(n_crystals=576, grid=(24, 24), n_hits=32,
                       noise_rate=8.0)
    b = generate(cfg, 16, seed=0)
    assert b["feats"].shape == (16, 32, 4)
    # energies sorted descending among valid hits
    e = b["feats"][..., 0]
    m = b["mask"]
    for ev in range(16):
        valid = e[ev][m[ev] > 0]
        assert np.all(np.diff(valid) <= 1e-6)
    # determinism
    b2 = generate(cfg, 16, seed=0)
    np.testing.assert_array_equal(b["feats"], b2["feats"])
    # object ids consistent with classes
    assert set(np.unique(b["cls"])) <= {0, 1, 2}


def test_lm_batch_deterministic_and_shifted():
    a = lm_batch(1000, 4, 16, seed=3, step=7)
    b = lm_batch(1000, 4, 16, seed=3, step=7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (4, 16)
    assert (a["tokens"] < 1000).all()


def test_neighbor_sampler_shapes_and_membership():
    g = powerlaw_graph(200, 1000, d_feat=8, n_classes=3, seed=0)
    s = NeighborSampler(g["edge_index"], 200, g["nodes"], g["labels"],
                        fanouts=(5, 3), seed=0)
    batch = s.sample(np.arange(10))
    assert batch["feats"].shape == (10 + 50 + 150, 8)
    assert batch["labels"].shape == (10,)
    assert len(batch["edges"]) == 2
    assert batch["edges"][0].shape == (2, 50)
    assert batch["edges"][1].shape == (2, 150)
    # sampled neighbors are real in-neighbors (or self for isolated)
    src, dst = g["edge_index"]
    nbrs = {i: set(src[dst == i]) | {i} for i in range(200)}
    e0 = batch["edges"][0]
    all_nodes = np.concatenate([np.arange(10)[: 0]]) if False else None
    # frontier-0 nodes are the seeds; check a few edges
    seeds = np.arange(10)
    frontier1 = batch["feats"][10:60]
    for j in range(50):
        dst_local = e0[1, j]
        assert 0 <= dst_local < 10


def test_triplet_builder():
    ei = np.asarray([[0, 1, 2], [1, 2, 0]], np.int32)  # 0->1->2->0 cycle
    trips, tm = build_triplets(ei, np.ones(3, np.float32), max_triplets=8)
    n = int(tm.sum())
    assert n == 3  # each edge has exactly one incoming predecessor
    for t in range(n):
        kj, ji = trips[0, t], trips[1, t]
        assert ei[1, kj] == ei[0, ji]      # shared middle node
        assert ei[0, kj] != ei[1, ji]      # k != i


def test_prefetcher_straggler_fallback():
    def slow_gen():
        yield {"x": 1}
        time.sleep(1.0)
        yield {"x": 2}

    pf = Prefetcher(slow_gen(), depth=1, deadline_s=0.1)
    assert pf.get()["x"] == 1
    out = pf.get()  # generator stalls -> last good batch
    assert out["x"] in (1, 2)
    assert pf.stats["stragglers"] >= (1 if out["x"] == 1 else 0)
    pf.close()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_belle2_labels_within_bounds(seed):
    cfg = Belle2Config(n_crystals=576, grid=(24, 24), n_hits=32,
                       noise_rate=8.0, mean_clusters=1.5)
    b = generate(cfg, 2, seed=seed)
    assert (b["object_id"] < cfg.max_clusters).all()
    assert (b["object_id"] >= -1).all()
    assert (b["feats"][..., 0] >= 0).all()
