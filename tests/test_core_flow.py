"""Tests for the deployment flow: IR, passes, pipeline, quantization, CPS."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_support import given, settings, st

from repro.core import caloclusternet as ccn
from repro.core.condensation import condensation_loss
from repro.core.graph_ir import Graph, Operator
from repro.core.passes import fuse, partition
from repro.core.passes.mapping import map_templates
from repro.core.passes.parallelize import Requirements, parallelize
from repro.core.passes.partition import segments
from repro.core.pipeline import deploy
from repro.core.quantization import (apply_precision_policy, fake_quant,
                                     quantize_weight)

CFG = ccn.CCNConfig(n_hits=32)


@pytest.fixture(scope="module")
def setup():
    params = ccn.init(jax.random.PRNGKey(0), CFG)
    rng = np.random.default_rng(0)
    feats = jnp.asarray(rng.normal(size=(4, CFG.n_hits, CFG.d_in)),
                        jnp.float32)
    mask = jnp.asarray(rng.uniform(size=(4, CFG.n_hits)) < 0.7, jnp.float32)
    ref = ccn.apply(params, feats, mask, CFG)
    return params, feats, mask, ref


# ------------------------------------------------------------------ IR ----
def test_graph_topo_validation():
    g = Graph()
    g.add(Operator(name="a", op_type="input", out_dim=4))
    with pytest.raises(ValueError):
        g.add(Operator(name="b", op_type="relu", inputs=["missing"]))
    with pytest.raises(ValueError):
        g.add(Operator(name="a", op_type="relu", inputs=["a"]))


def test_export_graph_structure(setup):
    params, *_ = setup
    g = ccn.to_graph(params, CFG)
    assert len(g.inputs()) == 2 and len(g.outputs()) == 1
    g.validate()
    # parallel dense pairs (gravnet S/FLR, four heads) multicast their input
    assert len(g.multicast_ops()) >= 3


# -------------------------------------------------------------- fusion ----
def test_fusion_removes_multicast_and_relu(setup):
    params, *_ = setup
    g = ccn.to_graph(params, CFG)
    n_relu_before = sum(1 for op in g if op.op_type == "relu")
    assert n_relu_before > 0
    f = fuse(g)
    assert sum(1 for op in f if op.op_type == "relu") == 0
    # head multicast removed: the four heads became one dense + slices
    merged = [op for op in f if op.op_type == "dense"
              and "head_" in op.name and "+" in op.name]
    assert merged and merged[0].out_dim == sum(CFG.head_dims.values())


def test_fusion_is_semantics_preserving(setup):
    params, feats, mask, ref = setup
    g = ccn.to_graph(params, CFG)
    feeds = {"hits": feats, "mask": mask}
    for dp in (1, 2):
        req = Requirements(design_point=dp, platform="cpu",
                           precision_policy="fp", n_hits=CFG.n_hits,
                           target_throughput=1e4)
        out = deploy(g, req)(feeds)
        np.testing.assert_allclose(np.asarray(out["beta"][..., 0]),
                                   np.asarray(ref["beta_logit"]),
                                   rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_fusion_property_random_mlp_graph(seed):
    """Fusing a random linear/relu chain graph preserves the output."""
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    dims = [4] + [int(rng.integers(2, 16)) for _ in range(3)]
    g = Graph()
    g.add(Operator(name="hits", op_type="input", out_dim=dims[0],
                   attrs={"feature": "hits"}))
    prev, d_prev = "hits", dims[0]
    for i, d in enumerate(dims[1:]):
        key, k2 = jax.random.split(key)
        w = jax.random.normal(k2, (d_prev, d)) * 0.3
        g.add(Operator(name=f"l{i}", op_type="linear", inputs=[prev],
                       params={"w": w, "b": jnp.zeros((d,))}, out_dim=d))
        if rng.uniform() < 0.7:
            g.add(Operator(name=f"r{i}", op_type="relu", inputs=[f"l{i}"],
                           out_dim=d))
            prev = f"r{i}"
        else:
            prev = f"l{i}"
        d_prev = d
    g.add(Operator(name="out", op_type="output", inputs=[prev],
                   attrs={"head_names": ["y"]}, out_dim=d_prev))
    feeds = {"hits": jnp.asarray(rng.normal(size=(2, 8, dims[0])),
                                 jnp.float32)}
    outs = []
    for dp in (1, 3):
        req = Requirements(design_point=dp, platform="cpu",
                           precision_policy="fp", n_hits=8,
                           target_throughput=1e3)
        outs.append(np.asarray(deploy(g, req)(feeds)["y"]))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-4, atol=1e-5)


# ----------------------------------------------------------- partition ----
def test_partition_targets_and_segments(setup):
    params, *_ = setup
    g = partition(fuse(ccn.to_graph(params, CFG)))
    for op in g:
        if op.op_type in ("gravnet_aggregate", "cps", "input", "output"):
            assert op.target == "xla", op.name
        if op.op_type == "dense":
            assert op.target == "mxu", op.name
    segs = segments(g)
    assert len(segs) == 7  # the paper's seven segments
    targets = [s["target"] for s in segs]
    assert targets == ["xla", "mxu", "xla", "mxu", "xla", "mxu", "xla"]


def test_partition_tpu_native_reduces_segments(setup):
    params, *_ = setup
    g = fuse(ccn.to_graph(params, CFG))
    n_faithful = len(segments(partition(g)))
    n_native = len(segments(partition(g, tpu_native_gravnet=True)))
    assert n_native < n_faithful


# ------------------------------------------------------- parallelization ----
def test_parallelize_meets_reachable_target(setup):
    params, *_ = setup
    g = map_templates(apply_precision_policy(
        partition(fuse(ccn.to_graph(params, CFG))), policy="fp"))
    req = Requirements(target_throughput=1e5, platform="tpu",
                       n_hits=CFG.n_hits)
    gp = parallelize(g, req)
    meta = gp.meta["parallelization"]
    assert meta["model_throughput_ev_s"] >= req.target_throughput
    assert meta["P_mxu"] in {2 ** i for i in range(9)}
    # smallest-P property: halving the chosen P must miss the target
    if meta["P_mxu"] > 1 and meta["P_xla"] > 1:
        req2 = Requirements(target_throughput=1e5, platform="tpu",
                            n_hits=CFG.n_hits, max_p=meta["P_mxu"] // 2)
        gp2 = parallelize(g, req2)
        m2 = gp2.meta["parallelization"]
        assert (m2["model_throughput_ev_s"] < req.target_throughput
                or m2["P_mxu"] + m2["P_xla"] <= meta["P_mxu"] + meta["P_xla"])


# ------------------------------------------------------------- mapping ----
def test_mapping_inserts_retiles(setup):
    params, *_ = setup
    g = map_templates(apply_precision_policy(
        partition(fuse(ccn.to_graph(params, CFG))), policy="fp"))
    retiles = [op for op in g if op.op_type == "retile"]
    assert retiles  # xla<->mxu boundaries need layout changes
    for op in g:
        assert op.template is not None


# --------------------------------------------------------- quantization ----
def test_fake_quant_grid_and_ste():
    x = jnp.linspace(-1.0, 1.0, 101)
    y = fake_quant(x, bits=8)
    assert float(jnp.max(jnp.abs(y - x))) <= 1.0 / 127 + 1e-6
    g = jax.grad(lambda v: jnp.sum(fake_quant(v, bits=8)))(x)
    # STE: unit gradient strictly inside the clip range (0.5 subgradient
    # exactly at the saturation boundary is fine)
    np.testing.assert_allclose(np.asarray(g[1:-1]), 1.0)


def test_quantize_weight_roundtrip():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
    wq, ws = quantize_weight(w)
    assert wq.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(wq, np.float32) * np.asarray(ws),
                               np.asarray(w), atol=float(ws.max()) * 0.51)


def test_mixed_policy_boundary_bf16(setup):
    params, *_ = setup
    g = apply_precision_policy(partition(fuse(ccn.to_graph(params, CFG))),
                               policy="mixed")
    segs = segments(g)
    first, last = segs[0]["id"], segs[-1]["id"]
    for op in g:
        if op.segment in (first, last) or op.op_type in ("input", "output",
                                                         "cps"):
            assert op.precision == "bf16"
        else:
            assert op.precision == "int8"


def test_mixed_precision_pipeline_close_to_fp(setup):
    params, feats, mask, ref = setup
    g = ccn.to_graph(params, CFG)
    feeds = {"hits": feats, "mask": mask}
    req = Requirements(design_point=3, platform="cpu",
                       precision_policy="mixed", n_hits=CFG.n_hits,
                       target_throughput=1e4)
    out = deploy(g, req, calibration_feeds=feeds)(feeds)
    # int8 interior: coarse but bounded deviation (paper: preserved quality)
    err = np.max(np.abs(np.asarray(out["beta"][..., 0])
                        - np.asarray(ref["beta_logit"])))
    assert err < 0.15


# ------------------------------------------------------------------ CPS ----
def test_cps_respects_thresholds(setup):
    params, feats, mask, ref = setup
    res = ccn.cps(ref, mask, CFG)
    beta = jax.nn.sigmoid(ref["beta_logit"]) * mask
    valid = np.asarray(res["cluster_valid"])
    bsel = np.asarray(res["cluster_beta"])
    assert np.all(bsel[valid] > CFG.t_beta)
    # selected points are mutually >= t_dist apart
    xy = np.asarray(res["cluster_xy"])
    for b in range(xy.shape[0]):
        pts = xy[b][valid[b]]
        if len(pts) > 1:
            d = np.linalg.norm(pts[:, None] - pts[None, :], axis=-1)
            d += np.eye(len(pts)) * 1e9
            assert d.min() > CFG.t_dist
    assert np.asarray(res["n_clusters"]).max() <= CFG.k_max


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_cps_property_count_matches_validmask(seed):
    rng = np.random.default_rng(seed)
    outputs = {
        "beta_logit": jnp.asarray(rng.normal(size=(2, 32)), jnp.float32),
        "coords": jnp.asarray(rng.normal(size=(2, 32, 2)), jnp.float32),
        "energy": jnp.asarray(rng.uniform(0, 2, size=(2, 32)), jnp.float32),
    }
    mask = jnp.asarray(rng.uniform(size=(2, 32)) < 0.8, jnp.float32)
    res = ccn.cps(outputs, mask, CFG)
    np.testing.assert_array_equal(
        np.asarray(res["cluster_valid"]).sum(-1),
        np.asarray(res["n_clusters"]))


# ------------------------------------------------------------- training ----
def test_condensation_loss_decreases(setup):
    params, feats, mask, _ = setup
    rng = np.random.default_rng(0)
    labels = {
        "object_id": jnp.asarray(rng.integers(-1, 3, size=(4, CFG.n_hits)),
                                 jnp.int32),
        "energy": jnp.asarray(rng.uniform(0, 2, size=(4, CFG.n_hits)),
                              jnp.float32),
        "cls": jnp.asarray(rng.integers(0, 3, size=(4, CFG.n_hits)),
                           jnp.int32),
    }

    def loss_fn(p):
        out = ccn.apply(p, feats, mask, CFG)
        return condensation_loss(out, labels, mask, k_max=CFG.k_max)[0]

    l0, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(l0))
    p2 = jax.tree_util.tree_map(lambda p, g: p - 0.05 * g, params, grads)
    l1 = loss_fn(p2)
    assert float(l1) < float(l0)
