"""End-to-end system test: design flow -> compiled pipeline -> real-time
serving engine, on synthetic Belle II events (the paper's demonstrator
in miniature)."""
import numpy as np
import jax

from repro.core import caloclusternet as ccn
from repro.core.passes.parallelize import Requirements
from repro.core.pipeline import deploy
from repro.data.belle2 import Belle2Config, generate
from repro.serving import TriggerServingEngine


def test_trigger_pipeline_through_serving_engine():
    cfg = ccn.CCNConfig(n_hits=32, n_crystals=576)
    gen = Belle2Config(n_crystals=576, grid=(24, 24), n_hits=32,
                       noise_rate=8.0)
    params = ccn.init(jax.random.PRNGKey(0), cfg)
    graph = ccn.to_graph(params, cfg)
    calib = generate(gen, 32, seed=1)
    feeds = {"hits": calib["feats"], "mask": calib["mask"]}
    req = Requirements(design_point=3, platform="cpu",
                       precision_policy="mixed", n_hits=cfg.n_hits,
                       target_throughput=2e4, max_latency_s=2e-3)
    pipe = deploy(graph, req, calibration_feeds=feeds)

    def infer(batch):
        return pipe({"hits": batch["hits"], "mask": batch["mask"]})

    # warm up compile outside the engine
    infer({"hits": calib["feats"][:max(pipe.microbatch, 8)],
           "mask": calib["mask"][:max(pipe.microbatch, 8)]})

    eng = TriggerServingEngine(infer, microbatch=max(pipe.microbatch, 8),
                               window_s=5e-3)
    events = generate(gen, 40, seed=2)
    futs = [eng.submit({"hits": events["feats"][i],
                        "mask": events["mask"][i]}) for i in range(40)]
    results = [f.result(timeout=120) for f in futs]
    eng.drain()
    # in-order, complete, structurally sound
    assert eng.stats.completed == 40
    for r in results:
        assert set(r) >= {"beta", "coords", "energy", "cls", "cps"}
        assert r["cps"]["cluster_xy"].shape == (cfg.k_max, 2)
        assert np.isfinite(np.asarray(r["coords"])).all()
    # engine result i must equal direct pipeline result for event i
    direct = pipe({"hits": events["feats"], "mask": events["mask"]})
    for i in (0, 7, 39):
        np.testing.assert_allclose(
            np.asarray(results[i]["coords"]),
            np.asarray(direct["coords"][i]), rtol=1e-5, atol=1e-5)
    eng.close()


def test_deployed_pipeline_matches_functional_trigger_decisions():
    """fp-precision deployed pipeline == functional model, bit-for-bit
    trigger decisions (the paper's sw/emu/hw agreement analogue)."""
    cfg = ccn.CCNConfig(n_hits=32, n_crystals=576)
    gen = Belle2Config(n_crystals=576, grid=(24, 24), n_hits=32,
                       noise_rate=8.0)
    params = ccn.init(jax.random.PRNGKey(3), cfg)
    graph = ccn.to_graph(params, cfg)
    events = generate(gen, 24, seed=5)
    feeds = {"hits": events["feats"], "mask": events["mask"]}
    req = Requirements(design_point=2, platform="cpu",
                       precision_policy="fp", n_hits=cfg.n_hits,
                       target_throughput=1e4, max_latency_s=2e-3)
    out = deploy(graph, req)(feeds)
    ref = ccn.apply(params, feeds["hits"], feeds["mask"], cfg)
    cps_ref = ccn.cps(ref, feeds["mask"], cfg)
    np.testing.assert_array_equal(np.asarray(out["cps"]["trigger"]),
                                  np.asarray(cps_ref["trigger"]))
    np.testing.assert_array_equal(np.asarray(out["cps"]["n_clusters"]),
                                  np.asarray(cps_ref["n_clusters"]))
