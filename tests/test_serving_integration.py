"""End-to-end system test: design flow -> compiled pipeline -> real-time
serving engine, on synthetic Belle II events (the paper's demonstrator
in miniature); plus unit coverage for the sharded layer (router
policies, merged in-order release, padded-event accounting)."""
import threading
import time

import numpy as np
import jax
import pytest

from repro.core import caloclusternet as ccn
from repro.core.passes.parallelize import Requirements
from repro.core.pipeline import deploy
from repro.data.belle2 import Belle2Config, generate
from repro.serving import ShardedTriggerService, TriggerServingEngine


def test_trigger_pipeline_through_serving_engine():
    cfg = ccn.CCNConfig(n_hits=32, n_crystals=576)
    gen = Belle2Config(n_crystals=576, grid=(24, 24), n_hits=32,
                       noise_rate=8.0)
    params = ccn.init(jax.random.PRNGKey(0), cfg)
    graph = ccn.to_graph(params, cfg)
    calib = generate(gen, 32, seed=1)
    feeds = {"hits": calib["feats"], "mask": calib["mask"]}
    req = Requirements(design_point=3, platform="cpu",
                       precision_policy="mixed", n_hits=cfg.n_hits,
                       target_throughput=2e4, max_latency_s=2e-3)
    pipe = deploy(graph, req, calibration_feeds=feeds)

    def infer(batch):
        return pipe({"hits": batch["hits"], "mask": batch["mask"]})

    # warm up compile outside the engine
    infer({"hits": calib["feats"][:max(pipe.microbatch, 8)],
           "mask": calib["mask"][:max(pipe.microbatch, 8)]})

    eng = TriggerServingEngine(infer, microbatch=max(pipe.microbatch, 8),
                               window_s=5e-3)
    events = generate(gen, 40, seed=2)
    futs = [eng.submit({"hits": events["feats"][i],
                        "mask": events["mask"][i]}) for i in range(40)]
    results = [f.result(timeout=120) for f in futs]
    eng.drain()
    # in-order, complete, structurally sound
    assert eng.stats.completed == 40
    for r in results:
        assert set(r) >= {"beta", "coords", "energy", "cls", "cps"}
        assert r["cps"]["cluster_xy"].shape == (cfg.k_max, 2)
        assert np.isfinite(np.asarray(r["coords"])).all()
    # engine result i must equal direct pipeline result for event i
    direct = pipe({"hits": events["feats"], "mask": events["mask"]})
    for i in (0, 7, 39):
        np.testing.assert_allclose(
            np.asarray(results[i]["coords"]),
            np.asarray(direct["coords"][i]), rtol=1e-5, atol=1e-5)
    eng.close()


# ------------------------------------------------------- sharded layer ----
def _echo_with_delay(feeds):
    """Identity inference whose service time is carried in the event:
    lets a test force specific replicas to finish out of order."""
    time.sleep(float(np.max(feeds["delay"])))
    return {"y": feeds["x"]}


def test_sharded_inorder_release_under_out_of_order_completion():
    """Replica 0's batches are made much slower than the others', so
    later-submitted events finish computing first — the merged release
    stage must still resolve futures in global submission order."""
    svc = ShardedTriggerService(_echo_with_delay, n_replicas=4,
                                microbatch=4, window_s=2e-3,
                                policy="round_robin", devices=None)
    n = 32
    order, lock = [], threading.Lock()

    def track(i):
        def cb(_fut):
            with lock:
                order.append(i)
        return cb

    futs = []
    for i in range(n):
        # round_robin: event i -> replica i % 4; replica 0 is the slow one
        delay = 0.15 if i % 4 == 0 else 0.01
        fut = svc.submit({"x": np.float32(i), "delay": np.float32(delay)})
        fut.add_done_callback(track(i))
        futs.append(fut)
    results = [f.result(timeout=60) for f in futs]
    svc.drain()
    assert order == sorted(order), "release stage broke submission order"
    for i, r in enumerate(results):
        assert float(r["y"]) == float(i)
    # out-of-order completion actually happened: fast replicas completed
    # batches whose events could not be released until replica 0 caught up
    assert svc.stats.completed == n
    svc.close()


def test_router_round_robin_even_assignment():
    svc = ShardedTriggerService(
        lambda feeds: {"y": feeds["x"]}, n_replicas=3, microbatch=2,
        window_s=2e-3, policy="round_robin", devices=None)
    futs = [svc.submit({"x": np.float32(i)}) for i in range(12)]
    for f in futs:
        f.result(timeout=30)
    svc.drain()
    assert [r.stats.submitted for r in svc.replicas] == [4, 4, 4]
    assert svc.stats.completed == 12
    svc.close()


def test_router_least_loaded_prefers_idle_replica():
    svc = ShardedTriggerService(_echo_with_delay, n_replicas=2,
                                microbatch=1, window_s=1e-3,
                                policy="least_loaded", devices=None)
    slow = svc.submit({"x": np.float32(0), "delay": np.float32(0.3)})
    time.sleep(0.05)  # let the slow event reach replica 0's dispatch
    fast = svc.submit({"x": np.float32(1), "delay": np.float32(0.0)})
    slow.result(timeout=30)
    fast.result(timeout=30)
    svc.drain()
    assert svc.replicas[0].stats.submitted == 1
    assert svc.replicas[1].stats.submitted == 1
    svc.close()


def test_padded_event_accounting():
    eng = TriggerServingEngine(lambda feeds: {"y": feeds["x"]},
                               microbatch=8, window_s=5e-2)
    futs = [eng.submit({"x": np.float32(i)}) for i in range(5)]
    for f in futs:
        f.result(timeout=30)
    eng.drain()
    s = eng.stats
    assert s.completed == 5
    # every launched batch is zero-padded to the micro-batch size; only
    # real events are ever released
    assert s.padded_events == 8 * s.batches - 5
    assert s.summary()["padded_events"] == s.padded_events
    eng.close()


def test_failed_batch_isolates_and_preserves_order():
    """An inference fault fails only that batch's futures; later events
    still release, so one poisoned batch cannot wedge the service."""
    def infer(feeds):
        if np.max(feeds["x"]) < 0:
            raise RuntimeError("poisoned batch")
        return {"y": feeds["x"]}

    svc = ShardedTriggerService(infer, n_replicas=1, microbatch=1,
                                window_s=1e-3, devices=None)
    bad = svc.submit({"x": np.float32(-1)})
    good = svc.submit({"x": np.float32(2)})
    with pytest.raises(RuntimeError, match="poisoned"):
        bad.result(timeout=30)
    assert float(good.result(timeout=30)["y"]) == 2.0
    svc.drain()
    assert svc.replicas[0].stats.failed == 1
    assert svc.stats.completed == 1
    svc.close()


def test_aggregate_stats_report_per_replica_budget():
    svc = ShardedTriggerService(_echo_with_delay, n_replicas=2,
                                microbatch=4, window_s=2e-3,
                                devices=None)
    futs = [svc.submit({"x": np.float32(i), "delay": np.float32(0.005)})
            for i in range(16)]
    for f in futs:
        f.result(timeout=30)
    svc.drain()
    s = svc.stats.summary()
    assert s["replicas"] == 2 and len(s["per_replica"]) == 2
    assert s["completed"] == 16
    bud = s["budget"]
    for k in ("queue_wait_us_mean", "dispatch_us_mean", "compute_us_mean"):
        assert bud[k] is not None and bud[k] >= 0.0
    # per-replica budgets carry the same breakdown
    for rs in s["per_replica"]:
        assert rs["budget"]["compute_us_mean"] > 0.0
    svc.close()


def test_sharded_service_matches_direct_pipeline():
    """Two virtual replicas sharing one deployed executable produce, in
    submission order, exactly the per-event results of a direct batched
    pipeline call."""
    cfg = ccn.CCNConfig(n_hits=32, n_crystals=576)
    gen = Belle2Config(n_crystals=576, grid=(24, 24), n_hits=32,
                       noise_rate=8.0)
    params = ccn.init(jax.random.PRNGKey(1), cfg)
    graph = ccn.to_graph(params, cfg)
    calib = generate(gen, 32, seed=4)
    req = Requirements(design_point=3, platform="cpu",
                       precision_policy="fp", n_hits=cfg.n_hits,
                       target_throughput=2e4, max_latency_s=2e-3)
    pipe = deploy(graph, req)

    def infer(batch):
        return pipe({"hits": batch["hits"], "mask": batch["mask"]})

    mb = max(pipe.microbatch, 8)
    infer({"hits": calib["feats"][:mb], "mask": calib["mask"][:mb]})
    svc = ShardedTriggerService(infer, n_replicas=2, microbatch=mb,
                                window_s=5e-3, devices=None)
    events = generate(gen, 24, seed=6)
    futs = [svc.submit({"hits": events["feats"][i],
                        "mask": events["mask"][i]}) for i in range(24)]
    results = [f.result(timeout=120) for f in futs]
    svc.drain()
    direct = pipe({"hits": events["feats"], "mask": events["mask"]})
    for i in range(24):
        np.testing.assert_allclose(
            np.asarray(results[i]["coords"]),
            np.asarray(direct["coords"][i]), rtol=1e-5, atol=1e-5)
    assert svc.stats.completed == 24
    assert sum(r.stats.submitted for r in svc.replicas) == 24
    svc.close()


def test_deployed_pipeline_matches_functional_trigger_decisions():
    """fp-precision deployed pipeline == functional model, bit-for-bit
    trigger decisions (the paper's sw/emu/hw agreement analogue)."""
    cfg = ccn.CCNConfig(n_hits=32, n_crystals=576)
    gen = Belle2Config(n_crystals=576, grid=(24, 24), n_hits=32,
                       noise_rate=8.0)
    params = ccn.init(jax.random.PRNGKey(3), cfg)
    graph = ccn.to_graph(params, cfg)
    events = generate(gen, 24, seed=5)
    feeds = {"hits": events["feats"], "mask": events["mask"]}
    req = Requirements(design_point=2, platform="cpu",
                       precision_policy="fp", n_hits=cfg.n_hits,
                       target_throughput=1e4, max_latency_s=2e-3)
    out = deploy(graph, req)(feeds)
    ref = ccn.apply(params, feeds["hits"], feeds["mask"], cfg)
    cps_ref = ccn.cps(ref, feeds["mask"], cfg)
    np.testing.assert_array_equal(np.asarray(out["cps"]["trigger"]),
                                  np.asarray(cps_ref["trigger"]))
    np.testing.assert_array_equal(np.asarray(out["cps"]["n_clusters"]),
                                  np.asarray(cps_ref["n_clusters"]))
