"""End-to-end deploy of the model-zoo GNNs through the pattern-keyed
flow: exporter registry, edge-typed IR lowering, and deployed-vs-eager
numerics on every backend.

The acceptance claim of the model-agnostic flow: a model joins deploy()
by registering a ``to_graph`` exporter, and the compiled pipeline
reproduces the eager ``apply`` within the shared dtype tolerances —
with no model-specific branches in any pass.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import caloclusternet as ccn
from repro.core.graph_ir import export_graph, exporters
from repro.core.op_registry import UnknownOperatorError
from repro.core.pipeline import deploy
from repro.core.passes.parallelize import Requirements
from repro.models.gnn import gatedgcn, graphsage
from tests._numerics import assert_close, backend_sweep

jax.config.update("jax_platform_name", "cpu")

N, E, B = 32, 128, 3     # E = 4N, the registry's default edge budget

GGCN_CFG = gatedgcn.GatedGCNConfig(n_layers=2, d_hidden=16, d_in=8,
                                   d_edge_in=4, n_classes=4)
SAGE_CFG = graphsage.GraphSAGEConfig(n_layers=2, d_hidden=16, d_in=12,
                                     n_classes=5, normalize=True)


def _req():
    return Requirements(design_point=3, platform="cpu",
                        precision_policy="fp", n_hits=N,
                        target_throughput=1e4)


def _edge_feeds(d_in, d_edge_in=None, *, seed=0):
    rng = np.random.default_rng(seed)
    feeds = {
        "nodes": jnp.asarray(rng.normal(size=(B, N, d_in)), jnp.float32),
        "edge_index": jnp.asarray(rng.integers(0, N, size=(B, 2, E)),
                                  jnp.int32),
        "node_mask": jnp.asarray(rng.uniform(size=(B, N)) < 0.8,
                                 jnp.float32),
        "edge_mask": jnp.asarray(rng.uniform(size=(B, E)) < 0.7,
                                 jnp.float32),
    }
    if d_edge_in is not None:
        feeds["edges"] = jnp.asarray(rng.normal(size=(B, E, d_edge_in)),
                                     jnp.float32)
    return feeds


def _event(feeds, b):
    return {k: v[b] for k, v in feeds.items()}


# ------------------------------------------------------------- registry ----
def test_exporter_registry_lists_models():
    names = exporters()
    for name in ("caloclusternet", "gatedgcn", "graphsage"):
        assert name in names, names


def test_export_graph_unknown_model():
    with pytest.raises(KeyError, match="no exporter 'resnet'"):
        export_graph("resnet", {}, None)


def test_export_graph_matches_direct_to_graph():
    params = ccn.init(jax.random.PRNGKey(0), ccn.CCNConfig())
    via_registry = export_graph("caloclusternet", params, ccn.CCNConfig())
    direct = ccn.to_graph(params, ccn.CCNConfig())
    assert ([(o.name, o.op_type, o.inputs) for o in via_registry]
            == [(o.name, o.op_type, o.inputs) for o in direct])


def test_export_preflight_rejects_unregistered_ops():
    from repro.core.graph_ir import Graph, Operator, register_exporter

    def bad_export(params, cfg):
        g = Graph()
        g.add(Operator(name="x", op_type="input", out_dim=4,
                       attrs={"feature": "x"}))
        g.add(Operator(name="mystery", op_type="septic_pool",
                       inputs=["x"], out_dim=4))
        g.add(Operator(name="out", op_type="output", inputs=["mystery"],
                       attrs={"head_names": ["y"]}, out_dim=4))
        g.validate()
        return g

    register_exporter("_test_bad_model", bad_export)
    with pytest.raises(UnknownOperatorError,
                       match=r"mystery \('septic_pool'\)"):
        export_graph("_test_bad_model", {}, None)


def test_gatedgcn_export_rejects_graph_readout():
    cfg = gatedgcn.GatedGCNConfig(n_layers=1, d_hidden=8, d_in=4,
                                  readout="graph")
    params = gatedgcn.init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="readout='node'"):
        gatedgcn.to_graph(params, cfg)


# ------------------------------------------------- deployed vs eager ----
@pytest.mark.parametrize("backend", backend_sweep())
def test_gatedgcn_deploy_matches_eager(backend):
    params = gatedgcn.init(jax.random.PRNGKey(1), GGCN_CFG)
    g = export_graph("gatedgcn", params, GGCN_CFG)
    pipe = deploy(g, _req(), kernel_backend=backend)
    feeds = _edge_feeds(GGCN_CFG.d_in, GGCN_CFG.d_edge_in)
    got = pipe(feeds)["logits"]
    assert got.shape == (B, N, GGCN_CFG.n_classes)
    for b in range(B):
        want = gatedgcn.apply(params, _event(feeds, b), GGCN_CFG)
        assert_close(got[b], want, dtype="float32",
                     context=f"{backend}/event{b}")


@pytest.mark.parametrize("backend", backend_sweep())
def test_graphsage_deploy_matches_eager(backend):
    params = graphsage.init(jax.random.PRNGKey(2), SAGE_CFG)
    g = export_graph("graphsage", params, SAGE_CFG)
    pipe = deploy(g, _req(), kernel_backend=backend)
    feeds = _edge_feeds(SAGE_CFG.d_in, seed=4)
    got = pipe(feeds)["logits"]
    assert got.shape == (B, N, SAGE_CFG.n_classes)
    for b in range(B):
        want = graphsage.apply(params, _event(feeds, b), SAGE_CFG)
        assert_close(got[b], want, dtype="float32",
                     context=f"{backend}/event{b}")


def test_gatedgcn_deploy_batched_executable():
    """The batch-packed executable (one whole-batch launch per segment)
    agrees with the per-event-shaped one."""
    params = gatedgcn.init(jax.random.PRNGKey(1), GGCN_CFG)
    g = export_graph("gatedgcn", params, GGCN_CFG)
    feeds = _edge_feeds(GGCN_CFG.d_in, GGCN_CFG.d_edge_in, seed=9)
    lo = deploy(export_graph("gatedgcn", params, GGCN_CFG), _req(),
                kernel_backend="xla")(feeds)["logits"]
    hi = deploy(g, _req(), kernel_backend="xla", batch=B)(feeds)["logits"]
    assert_close(hi, lo, dtype="float32", context="batched-vs-looped")


def test_gatedgcn_deploy_all_design_points():
    """Every design point lowers the edge-typed ops (partition, fuse,
    parallelize, kernel_opt all see them) and agrees with eager."""
    params = gatedgcn.init(jax.random.PRNGKey(3), GGCN_CFG)
    feeds = _edge_feeds(GGCN_CFG.d_in, GGCN_CFG.d_edge_in, seed=6)
    want = gatedgcn.apply(params, _event(feeds, 0), GGCN_CFG)
    for dp in (1, 2, 3):
        req = Requirements(design_point=dp, platform="cpu",
                           precision_policy="fp", n_hits=N,
                           target_throughput=1e4)
        g = export_graph("gatedgcn", params, GGCN_CFG)
        got = deploy(g, req, kernel_backend="xla")(feeds)["logits"]
        assert_close(got[0], want, dtype="float32", context=f"dp{dp}")
