"""Flash-attention Pallas kernel vs softmax oracle (interpret mode)."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_support import given, settings, st

from repro.kernels import ref
from repro.kernels.ops import flash_attention


def _qkv(rng, bh, s, t, d, dtype=jnp.float32):
    q = jnp.asarray(rng.normal(size=(bh, s, d)), dtype)
    k = jnp.asarray(rng.normal(size=(bh, t, d)), dtype)
    v = jnp.asarray(rng.normal(size=(bh, t, d)), dtype)
    return q, k, v


@pytest.mark.parametrize("s,t,d,bq,bk", [
    (32, 32, 16, 16, 16), (64, 64, 32, 16, 32), (128, 128, 64, 64, 64),
    (48, 48, 16, 16, 16), (16, 16, 8, 16, 16),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_oracle(s, t, d, bq, bk, causal):
    rng = np.random.default_rng(s * 100 + d)
    q, k, v = _qkv(rng, 3, s, t, d)
    got = flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                          backend="pallas_interpret")
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    rng = np.random.default_rng(0)
    q, k, v = _qkv(rng, 2, 32, 32, 16, dtype)
    got = flash_attention(q, k, v, bq=16, bk=16,
                          backend="pallas_interpret")
    want = ref.flash_attention_ref(q, k, v)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@settings(max_examples=10, deadline=None)
@given(s=st.sampled_from([16, 32, 48]), d=st.sampled_from([8, 16]),
       seed=st.integers(0, 2**31 - 1))
def test_flash_property(s, d, seed):
    rng = np.random.default_rng(seed)
    q, k, v = _qkv(rng, 2, s, s, d)
    got = flash_attention(q, k, v, bq=16, bk=16,
                          backend="pallas_interpret")
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_flash_rowsums_one():
    """Softmax invariant: with v = ones, output is exactly ones."""
    rng = np.random.default_rng(1)
    q, k, _ = _qkv(rng, 2, 32, 32, 16)
    v = jnp.ones((2, 32, 16), jnp.float32)
    got = flash_attention(q, k, v, bq=16, bk=16,
                          backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(got), 1.0, rtol=1e-5)
