"""GravNet-block megakernel fusion: kernel equivalence, the fusion-pass
rewrite and its lossless-fusion guards, tuning-key plumbing, and the
attention → flash_attention executor route.

The headline invariant (docs/kernels.md): a fused ``gravnet_block``
launch is **bitwise-equal in f32** to the unfused dense(S)/dense(F) →
gravnet_aggregate → concat → dense(out) chain, for every occupancy
bucket, micro-batch width, and k — verified end to end through the
deployed executor, not just at the ops layer.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import caloclusternet as ccn
from repro.core.graph_ir import Graph, Operator
from repro.core.passes.fusion import fuse
from repro.core.passes.parallelize import Requirements
from repro.core.passes.verify import GraphVerificationError, verify
from repro.core.pipeline import deploy, _cut_hits
from repro.kernels import ops, ref


def _block_operands(seed=0, b=4, n=16, dh=24, ds=3, df=10, dout=24, k=6):
    rng = np.random.default_rng(seed)
    return dict(
        x=jnp.asarray(rng.normal(size=(b, n, dh)), jnp.float32),
        mask=jnp.asarray(rng.uniform(size=(b, n)) < 0.8, jnp.float32),
        ws=jnp.asarray(rng.normal(size=(dh, ds)) * 0.3, jnp.float32),
        bs=jnp.asarray(rng.normal(size=(ds,)), jnp.float32),
        wf=jnp.asarray(rng.normal(size=(dh, df)) * 0.3, jnp.float32),
        bf=jnp.asarray(rng.normal(size=(df,)), jnp.float32),
        wo=jnp.asarray(rng.normal(size=(dh + 2 * df, dout)) * 0.3,
                       jnp.float32),
        bo=jnp.asarray(rng.normal(size=(dout,)), jnp.float32),
    ), k


# ------------------------------------------------------ kernel equivalence ----
def test_gravnet_block_batched_bitwise_matches_per_event():
    o, k = _block_operands()
    batched = ops.gravnet_block_batched(**o, k=k,
                                        backend="pallas_interpret")
    looped = jnp.stack([
        ops.gravnet_block(o["x"][i], o["mask"][i], o["ws"], o["bs"],
                          o["wf"], o["bf"], o["wo"], o["bo"], k=k,
                          backend="pallas_interpret")
        for i in range(o["x"].shape[0])])
    assert bool(jnp.all(batched == looped))   # bitwise, f32


def test_gravnet_block_matches_unfused_kernel_chain_bitwise():
    """Megakernel output == the three unfused kernel launches it
    replaces, at the exact shapes the executor would run them."""
    o, k = _block_operands()
    b, n, dh = o["x"].shape
    ds, df = o["ws"].shape[1], o["wf"].shape[1]
    fused = ops.gravnet_block_batched(**o, k=k,
                                      backend="pallas_interpret")
    wide = jnp.concatenate([o["ws"], o["wf"]], axis=1)
    bwide = jnp.concatenate([o["bs"], o["bf"]], axis=0)
    sf = ops.fused_dense(o["x"].reshape(b * n, dh), wide, bwide,
                         activation="none", variant="flattened",
                         backend="pallas_interpret"
                         ).reshape(b, n, ds + df)
    agg = ops.gravnet_aggregate_batched(sf[..., :ds], sf[..., ds:],
                                        o["mask"], k=k,
                                        backend="pallas_interpret")
    h = jnp.concatenate([o["x"], agg], axis=-1)
    unfused = ops.fused_dense(h.reshape(b * n, dh + 2 * df), o["wo"],
                              o["bo"], activation="relu",
                              variant="flattened",
                              backend="pallas_interpret"
                              ).reshape(b, n, -1)
    assert bool(jnp.all(fused == unfused))


def test_gravnet_block_xla_path_matches_ref():
    o, k = _block_operands()
    got = ops.gravnet_block_batched(**o, k=k, backend="xla")
    # same jit boundary as the wrapper -> same compiled program, bitwise
    want = jax.jit(lambda **kw: ref.gravnet_block_ref(**kw, k=k))(**o)
    assert bool(jnp.all(got == want))
    # and the eager oracle within float tolerance
    eager = ref.gravnet_block_ref(**o, k=k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(eager),
                               rtol=1e-5, atol=1e-6)


def test_gravnet_block_bn_split_bitwise_bk_split_close():
    o, k = _block_operands()
    base = ops.gravnet_block_batched(**o, k=k,
                                     backend="pallas_interpret")
    bn = ops.gravnet_block_batched(**o, k=k, bn=8,
                                   backend="pallas_interpret")
    assert bool(jnp.all(bn == base))          # column split: bitwise
    bk = ops.gravnet_block_batched(**o, k=k, bk=16,
                                   backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(bk), np.asarray(base),
                               rtol=1e-5, atol=1e-6)   # K split: ulp


def test_gravnet_block_row_tiling_matches_unfused_same_bm():
    o, k = _block_operands(n=16)
    fused = ops.gravnet_block_batched(**o, k=k, bm=8,
                                      backend="pallas_interpret")
    b, n, _ = o["x"].shape
    s = ref.fused_dense_ref(o["x"], o["ws"], o["bs"], activation="none",
                            out_dtype=jnp.float32)
    f = ref.fused_dense_ref(o["x"], o["wf"], o["bf"], activation="none",
                            out_dtype=jnp.float32)
    agg = ops.gravnet_aggregate_batched(s, f, o["mask"], k=k, bm=8,
                                        backend="pallas_interpret")
    h = jnp.concatenate([o["x"], agg], axis=-1)
    want = ops.fused_dense(h.reshape(b * n, -1), o["wo"], o["bo"],
                           activation="relu", variant="flattened",
                           backend="pallas_interpret").reshape(b, n, -1)
    assert bool(jnp.all(fused == want))


# ----------------------------------------- deployed bitwise acceptance ----
@pytest.mark.parametrize("batch,k", [(1, 4), (1, 8), (8, 4), (8, 8)])
def test_deployed_fused_bitwise_equals_unfused_every_bucket(batch, k):
    """The acceptance sweep: deploy(fuse_gravnet_block=True/False) at
    every occupancy bucket and compare outputs bitwise (f32) through
    the Pallas (interpret) kernel path."""
    cfg = dataclasses.replace(ccn.current_detector_config(), k=k)
    params = ccn.init(jax.random.PRNGKey(1), cfg)
    g = ccn.to_graph(params, cfg)
    rng = np.random.default_rng(7)
    nb = max(batch, 2)
    feeds = {
        "hits": jnp.asarray(rng.normal(size=(nb, cfg.n_hits, cfg.d_in)),
                            jnp.float32),
        "mask": jnp.asarray(rng.uniform(size=(nb, cfg.n_hits)) < 0.7,
                            jnp.float32),
    }
    for bucket in (8, 16, 32):
        req = Requirements(design_point=3, platform="cpu",
                           precision_policy="fp", n_hits=bucket,
                           target_throughput=5e4, max_latency_s=2e-3)
        fb = _cut_hits(feeds, bucket)
        fused = deploy(g, req, kernel_backend="pallas_interpret",
                       batch=batch)(fb)
        unfused = deploy(g, req, kernel_backend="pallas_interpret",
                         batch=batch, fuse_gravnet_block=False)(fb)
        for head in ("beta", "coords", "energy", "cls"):
            a, b = np.asarray(fused[head]), np.asarray(unfused[head])
            assert np.array_equal(a, b), (bucket, head,
                                          np.abs(a - b).max())


def test_deployed_fused_bitwise_on_xla_backend():
    cfg = ccn.current_detector_config()
    params = ccn.init(jax.random.PRNGKey(2), cfg)
    g = ccn.to_graph(params, cfg)
    rng = np.random.default_rng(3)
    feeds = {
        "hits": jnp.asarray(rng.normal(size=(8, cfg.n_hits, cfg.d_in)),
                            jnp.float32),
        "mask": jnp.asarray(rng.uniform(size=(8, cfg.n_hits)) < 0.7,
                            jnp.float32),
    }
    req = Requirements(design_point=3, platform="cpu",
                       precision_policy="fp", n_hits=cfg.n_hits,
                       target_throughput=5e4, max_latency_s=2e-3)
    fused = deploy(g, req, batch=8)(feeds)
    unfused = deploy(g, req, batch=8, fuse_gravnet_block=False)(feeds)
    for head in ("beta", "coords", "energy", "cls"):
        assert np.array_equal(np.asarray(fused[head]),
                              np.asarray(unfused[head]))


# --------------------------------------------------- fusion-pass rewrite ----
def _ccn_graph(**over):
    cfg = dataclasses.replace(ccn.current_detector_config(), **over)
    params = ccn.init(jax.random.PRNGKey(0), cfg)
    return ccn.to_graph(params, cfg), cfg


def test_fuse_gravnet_block_rewrites_both_blocks():
    g, cfg = _ccn_graph()
    f = fuse(g, gravnet_block=True)
    blocks = [op for op in f if op.op_type == "gravnet_block"]
    assert len(blocks) == cfg.n_gravnet_blocks
    assert not any(op.op_type == "gravnet_aggregate" for op in f)
    for blk in blocks:
        assert blk.attrs["concat_x"] is True
        assert blk.attrs["activation"] == "relu"
        assert blk.attrs["d_hidden"] == cfg.d_hidden
        assert set(blk.params) == {"ws", "bs", "wf", "bf", "wo", "bo"}
    verify(f)
    # default stays the legacy rewrite, bit-for-bit
    legacy = fuse(g)
    assert [op.name for op in legacy] == [op.name for op in fuse(g)]
    assert not any(op.op_type == "gravnet_block" for op in legacy)


def test_fuse_gravnet_block_preserves_semantics():
    g, cfg = _ccn_graph()
    rng = np.random.default_rng(0)
    feats = jnp.asarray(rng.normal(size=(2, cfg.n_hits, cfg.d_in)),
                        jnp.float32)
    mask = jnp.asarray(rng.uniform(size=(2, cfg.n_hits)) < 0.7,
                       jnp.float32)
    feeds = {"hits": feats, "mask": mask}
    req = Requirements(design_point=2, platform="cpu",
                       precision_policy="fp", n_hits=cfg.n_hits,
                       target_throughput=1e4)
    out = deploy(g, req)(feeds)
    want = ccn.apply(ccn.init(jax.random.PRNGKey(0), cfg), feats, mask,
                     cfg)
    np.testing.assert_allclose(np.asarray(out["beta"][..., 0]),
                               np.asarray(want["beta_logit"]),
                               rtol=1e-4, atol=1e-5)


def test_block_pattern_skips_tapped_aggregate():
    """An extra consumer on the aggregate output (e.g. a monitor tap)
    must keep the chain unfused — the tap needs the materialized
    tensor."""
    g, cfg = _ccn_graph()
    g = g.clone()
    tap = Operator(name="agg_tap", op_type="relu", inputs=["gn0_agg"],
                   out_dim=2 * cfg.d_flr)
    g.insert_after("gn0_agg", tap)
    f = fuse(g, gravnet_block=True)
    names = {op.name for op in f}
    assert "gn0_agg" in names                 # block 0 stayed unfused
    blocks = [op for op in f if op.op_type == "gravnet_block"]
    assert [b.name for b in blocks] == ["gn1_agg.block"]   # block 1 fused


def test_block_pattern_skips_tapped_projection():
    g, cfg = _ccn_graph()
    g = g.clone()
    tap = Operator(name="s_tap", op_type="relu", inputs=["gn0_s"],
                   out_dim=cfg.d_s)
    g.insert_after("gn0_s", tap)
    f = fuse(g, gravnet_block=True)
    assert "gn0_agg" in {op.name for op in f}
    assert [op.name for op in f if op.op_type == "gravnet_block"] \
        == ["gn1_agg.block"]


def test_linear_with_extra_consumer_does_not_fuse_relu():
    """linear → relu only fuses when the relu is the sole consumer."""
    g = Graph()
    g.add(Operator(name="in", op_type="input", out_dim=4,
                   attrs={"feature": "x"}))
    w = jnp.ones((4, 4), jnp.float32)
    g.add(Operator(name="lin", op_type="linear", inputs=["in"],
                   params={"w": w, "b": jnp.zeros((4,))}, out_dim=4))
    g.add(Operator(name="act", op_type="relu", inputs=["lin"], out_dim=4))
    g.add(Operator(name="tap", op_type="relu", inputs=["lin"], out_dim=4))
    g.add(Operator(name="out", op_type="output", inputs=["act", "tap"],
                   attrs={"head_names": ["a", "b"]}, out_dim=8))
    f = fuse(g)
    assert "lin+relu" not in {op.name for op in f}
    assert sum(1 for op in f if op.op_type == "relu") == 2


@pytest.mark.parametrize("mismatch", ["activation", "precision"])
def test_parallel_dense_merge_refuses_mismatch(mismatch):
    g = Graph()
    g.add(Operator(name="in", op_type="input", out_dim=4,
                   attrs={"feature": "x"}))
    w = jnp.ones((4, 3), jnp.float32)
    a = Operator(name="da", op_type="dense", inputs=["in"],
                 params={"w": w, "b": jnp.zeros((3,))}, out_dim=3,
                 attrs={"activation": "relu"})
    b = Operator(name="db", op_type="dense", inputs=["in"],
                 params={"w": w, "b": jnp.zeros((3,))}, out_dim=3,
                 attrs={"activation": "relu"})
    if mismatch == "activation":
        b.attrs["activation"] = "none"
    else:
        b.precision = "int8"
    g.add(a)
    g.add(b)
    g.add(Operator(name="out", op_type="output", inputs=["da", "db"],
                   attrs={"head_names": ["a", "b"]}, out_dim=6))
    f = fuse(g)
    assert {"da", "db"} <= {op.name for op in f}   # no merge happened


def test_verify_rejects_malformed_gravnet_block():
    g, _ = _ccn_graph()
    f = fuse(g, gravnet_block=True)
    bad = f.clone()
    blk = [op for op in bad if op.op_type == "gravnet_block"][0]
    blk.params["wo"] = blk.params["wo"][:-1]   # wrong epilogue K
    with pytest.raises(GraphVerificationError):
        verify(bad)


def test_mixed_precision_keeps_unfused_chain():
    """The int8 interior is the calibrated unfused pipeline; the fp
    megakernel must not silently replace it."""
    g, cfg = _ccn_graph()
    rng = np.random.default_rng(0)
    feeds = {
        "hits": jnp.asarray(rng.normal(size=(4, cfg.n_hits, cfg.d_in)),
                            jnp.float32),
        "mask": jnp.asarray(rng.uniform(size=(4, cfg.n_hits)) < 0.7,
                            jnp.float32),
    }
    req = Requirements(design_point=3, platform="cpu",
                       precision_policy="mixed", n_hits=cfg.n_hits,
                       target_throughput=1e4)
    pipe = deploy(g, req, calibration_feeds=feeds)   # default fuse on
    assert not any(op.op_type == "gravnet_block" for op in pipe.graph)


# ----------------------------------------------------------- tuning keys ----
def test_gravnet_block_key_batch_dimension():
    from repro.tuning import gravnet_block_key
    from repro.tuning.cache import KernelKey
    k1 = gravnet_block_key(32, 64, 22, 8, "float32", "xla")
    kb = gravnet_block_key(32, 64, 22, 8, "float32", "xla", batch=8)
    assert k1.shape == (32, 64, 22, 8)
    assert kb.shape == (8, 32, 64, 22, 8)      # the 5-dim batched key
    assert KernelKey.decode(kb.encode()) == kb


def test_kernel_opt_binds_cached_block_winner_and_miss_is_default():
    from repro.tuning import TuningCache, gravnet_block_key
    g, cfg = _ccn_graph()
    req = Requirements(design_point=3, platform="cpu",
                       precision_policy="fp", n_hits=cfg.n_hits,
                       target_throughput=5e4, max_latency_s=2e-3)
    # empty cache: no (bm, bn, bk) bindings on the block ops
    pipe0 = deploy(g, req, batch=8, tuning_cache=TuningCache(),
                   kernel_backend="xla")
    for op in pipe0.graph:
        if op.op_type == "gravnet_block":
            assert not any(kn in op.attrs_opt for kn in ("bm", "bn", "bk"))
    cache = TuningCache()
    cache.put(gravnet_block_key(cfg.n_hits, cfg.d_hidden, cfg.d_flr,
                                cfg.k, "float32", "xla", batch=8),
              {"bm": 16, "bn": 32, "d_s": cfg.d_s, "d_out": cfg.d_hidden})
    pipe = deploy(g, req, batch=8, tuning_cache=cache,
                  kernel_backend="xla")
    blocks = [op for op in pipe.graph if op.op_type == "gravnet_block"]
    assert blocks
    for op in blocks:
        assert op.attrs_opt["bm"] == 16 and op.attrs_opt["bn"] == 32
        assert "d_s" not in op.attrs_opt       # replay hints never bind


def test_tune_and_warmup_roundtrip_block_key(tmp_path):
    from repro.tuning import (TuningCache, gravnet_block_key,
                              tune_gravnet_block, warm_from_cache)
    cache = TuningCache(tmp_path / "c.json")
    cfg = tune_gravnet_block(16, 24, 3, 10, 24, 4, batch=3,
                             backend="xla", cache=cache, iters=1)
    assert "bm" in cfg
    key = gravnet_block_key(16, 24, 10, 4, "float32", "xla", batch=3)
    assert key in cache
    entry = cache.entry(key)
    assert entry.config["d_s"] == 3 and entry.config["d_out"] == 24
    assert warm_from_cache(cache, backend="xla") == 1
    # per-event (4-dim) key replays too
    cache.put(gravnet_block_key(16, 24, 10, 4, "float32", "xla"),
              {"bm": 16, "d_s": 3, "d_out": 24})
    assert warm_from_cache(cache, backend="xla") == 2


def test_autotune_graph_searches_block_problems():
    from repro.tuning import TuningCache, autotune_graph
    g, cfg = _ccn_graph()
    req = Requirements(design_point=3, platform="cpu",
                       precision_policy="fp", n_hits=cfg.n_hits,
                       target_throughput=5e4, max_latency_s=2e-3)
    pipe = deploy(g, req, batch=4)
    cache = TuningCache()
    autotune_graph(pipe.graph, n_rows=cfg.n_hits, backend="xla",
                   cache=cache, batch=4, iters=1)
    kinds = {k.kernel for k in cache.entries()}
    assert "gravnet_block" in kinds and "gravnet" not in kinds


# -------------------------------------------- attention executor route ----
def _attention_graph(n=16, d=8, seed=0):
    rng = np.random.default_rng(seed)
    g = Graph()
    g.add(Operator(name="tok", op_type="input", out_dim=d,
                   attrs={"feature": "tok"}))
    for nm in ("q", "k", "v"):
        w = jnp.asarray(rng.normal(size=(d, d)) * 0.3, jnp.float32)
        g.add(Operator(name=nm, op_type="linear", inputs=["tok"],
                       params={"w": w, "b": jnp.zeros((d,))}, out_dim=d))
    g.add(Operator(name="attn", op_type="attention",
                   inputs=["q", "k", "v"], attrs={"causal": True},
                   out_dim=d))
    g.add(Operator(name="out", op_type="output", inputs=["attn"],
                   attrs={"head_names": ["y"]}, out_dim=d))
    g.validate()
    return g


def test_attention_op_deploys_through_flash_kernel():
    """The flash_attention kernel is reachable from the graph executor:
    ``attention``-typed ops dispatch through it (docs/kernels.md)."""
    g = _attention_graph()
    rng = np.random.default_rng(1)
    tok = jnp.asarray(rng.normal(size=(2, 16, 8)), jnp.float32)
    req = Requirements(design_point=3, platform="cpu",
                       precision_policy="fp", n_hits=16,
                       target_throughput=1e3)
    out = deploy(g, req)({"tok": tok})["y"]
    qkv = [ref.fused_dense_ref(tok, g[nm].params["w"], g[nm].params["b"],
                               activation="none")
           for nm in ("q", "k", "v")]
    want = ref.flash_attention_ref(*qkv, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    # interpret backend exercises the Pallas flash kernel body
    out_i = deploy(g, req,
                   kernel_backend="pallas_interpret")({"tok": tok})["y"]
    np.testing.assert_allclose(np.asarray(out_i), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_attention_emits_flash_tuning_key_and_binds_blocks():
    from repro.tuning import (TuningCache, flash_attention_key,
                              graph_kernel_problems)
    g = _attention_graph()
    req = Requirements(design_point=3, platform="cpu",
                       precision_policy="fp", n_hits=16,
                       target_throughput=1e3)
    pipe = deploy(g, req, batch=2)
    keys = graph_kernel_problems(pipe.graph, n_rows=16, backend="xla",
                                 batch=2)
    fk = [k for k in keys if k.kernel == "flash_attention"]
    assert fk and fk[0].shape == (2, 16, 16, 8)
    cache = TuningCache()
    cache.put(flash_attention_key(2, 16, 16, 8, "float32", "xla"),
              {"bq": 16, "bk": 16})
    pipe2 = deploy(g, req, batch=2, tuning_cache=cache,
                   kernel_backend="xla")
    attn = [op for op in pipe2.graph if op.op_type == "attention"][0]
    assert attn.attrs_opt["bq"] == 16 and attn.attrs_opt["bk"] == 16
