"""GravNet-block megakernel fusion: kernel equivalence, the fusion-pass
rewrite and its lossless-fusion guards, tuning-key plumbing, and the
attention → flash_attention executor route.

The headline invariants (docs/kernels.md):

- a fused f32 ``gravnet_block`` launch is **bitwise-equal** to the
  unfused dense(S)/dense(F) → gravnet_aggregate → concat → dense(out)
  chain, for every occupancy bucket, micro-batch width, and k;
- the quantized ``gravnet_block_int8`` launch matches the calibrated
  unfused int8 chain within **calibration tolerance** (independently
  derived requantization grids may flip boundary values by one step)
  across the same sweep —

both verified end to end through the deployed executor, not just at
the ops layer, using the shared assertions in ``tests/_numerics.py``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _numerics import (assert_bitwise, assert_calibration_close,
                       assert_close, assert_ulp_close, backend_sweep,
                       int8_flip_tolerance)

from repro.core import caloclusternet as ccn
from repro.core.graph_ir import Graph, Operator
from repro.core.passes.fusion import fuse
from repro.core.passes.parallelize import Requirements
from repro.core.passes.verify import GraphVerificationError, verify
from repro.core.pipeline import deploy, _cut_hits
from repro.kernels import ops, ref


def _block_operands(seed=0, b=4, n=16, dh=24, ds=3, df=10, dout=24, k=6):
    rng = np.random.default_rng(seed)
    return dict(
        x=jnp.asarray(rng.normal(size=(b, n, dh)), jnp.float32),
        mask=jnp.asarray(rng.uniform(size=(b, n)) < 0.8, jnp.float32),
        ws=jnp.asarray(rng.normal(size=(dh, ds)) * 0.3, jnp.float32),
        bs=jnp.asarray(rng.normal(size=(ds,)), jnp.float32),
        wf=jnp.asarray(rng.normal(size=(dh, df)) * 0.3, jnp.float32),
        bf=jnp.asarray(rng.normal(size=(df,)), jnp.float32),
        wo=jnp.asarray(rng.normal(size=(dh + 2 * df, dout)) * 0.3,
                       jnp.float32),
        bo=jnp.asarray(rng.normal(size=(dout,)), jnp.float32),
    ), k


# ------------------------------------------------------ kernel equivalence ----
def test_gravnet_block_batched_bitwise_matches_per_event():
    o, k = _block_operands()
    batched = ops.gravnet_block_batched(**o, k=k,
                                        backend="pallas_interpret")
    looped = jnp.stack([
        ops.gravnet_block(o["x"][i], o["mask"][i], o["ws"], o["bs"],
                          o["wf"], o["bf"], o["wo"], o["bo"], k=k,
                          backend="pallas_interpret")
        for i in range(o["x"].shape[0])])
    assert_bitwise(batched, looped)   # f32


def test_gravnet_block_matches_unfused_kernel_chain_bitwise():
    """Megakernel output == the three unfused kernel launches it
    replaces, at the exact shapes the executor would run them."""
    o, k = _block_operands()
    b, n, dh = o["x"].shape
    ds, df = o["ws"].shape[1], o["wf"].shape[1]
    fused = ops.gravnet_block_batched(**o, k=k,
                                      backend="pallas_interpret")
    wide = jnp.concatenate([o["ws"], o["wf"]], axis=1)
    bwide = jnp.concatenate([o["bs"], o["bf"]], axis=0)
    sf = ops.fused_dense(o["x"].reshape(b * n, dh), wide, bwide,
                         activation="none", variant="flattened",
                         backend="pallas_interpret"
                         ).reshape(b, n, ds + df)
    agg = ops.gravnet_aggregate_batched(sf[..., :ds], sf[..., ds:],
                                        o["mask"], k=k,
                                        backend="pallas_interpret")
    h = jnp.concatenate([o["x"], agg], axis=-1)
    unfused = ops.fused_dense(h.reshape(b * n, dh + 2 * df), o["wo"],
                              o["bo"], activation="relu",
                              variant="flattened",
                              backend="pallas_interpret"
                              ).reshape(b, n, -1)
    assert_bitwise(fused, unfused)


def test_gravnet_block_xla_path_matches_ref():
    o, k = _block_operands()
    got = ops.gravnet_block_batched(**o, k=k, backend="xla")
    # same jit boundary as the wrapper -> same compiled program, bitwise
    want = jax.jit(lambda **kw: ref.gravnet_block_ref(**kw, k=k))(**o)
    assert_bitwise(got, want)
    # and the eager oracle within float tolerance
    eager = ref.gravnet_block_ref(**o, k=k)
    assert_close(got, eager, rtol=1e-5, atol=1e-6)


def test_gravnet_block_bn_split_bitwise_bk_split_close():
    o, k = _block_operands()
    base = ops.gravnet_block_batched(**o, k=k,
                                     backend="pallas_interpret")
    bn = ops.gravnet_block_batched(**o, k=k, bn=8,
                                   backend="pallas_interpret")
    assert_bitwise(bn, base, context="column split")
    bk = ops.gravnet_block_batched(**o, k=k, bk=16,
                                   backend="pallas_interpret")
    assert_ulp_close(bk, base, max_ulp=16, context="K split")


def test_gravnet_block_row_tiling_matches_unfused_same_bm():
    o, k = _block_operands(n=16)
    fused = ops.gravnet_block_batched(**o, k=k, bm=8,
                                      backend="pallas_interpret")
    b, n, _ = o["x"].shape
    s = ref.fused_dense_ref(o["x"], o["ws"], o["bs"], activation="none",
                            out_dtype=jnp.float32)
    f = ref.fused_dense_ref(o["x"], o["wf"], o["bf"], activation="none",
                            out_dtype=jnp.float32)
    agg = ops.gravnet_aggregate_batched(s, f, o["mask"], k=k, bm=8,
                                        backend="pallas_interpret")
    h = jnp.concatenate([o["x"], agg], axis=-1)
    want = ops.fused_dense(h.reshape(b * n, -1), o["wo"], o["bo"],
                           activation="relu", variant="flattened",
                           backend="pallas_interpret").reshape(b, n, -1)
    assert_bitwise(fused, want)


# ------------------------------------------ int8 kernel equivalence ----
def _int8_block_operands(seed=0, **kw):
    """f32 block operands + per-channel quantized weights + the baked
    activation scales the calibration pass would derive."""
    from repro.core.quantization import quantize_weight
    o, k = _block_operands(seed, **kw)
    q = {}
    for nm in ("ws", "wf", "wo"):
        q[nm + "_q"], q[nm + "_scale"] = quantize_weight(o[nm])
    scales = dict(x_scale=0.02, agg_scale=0.01, h_scale=0.02)
    return o, q, scales, k


def _unfused_int8_chain(o, q, sc, k, backend):
    """The calibrated unfused int8 chain, composed from the per-op
    kernels exactly as the executor runs it: quantize x → int8 S/F
    projections (dequantized, no output snap) → f32 aggregate →
    requantization snap → concat(x, agg) → quantize h → int8 out
    dense."""
    b, n, dh = o["x"].shape
    ds, df = o["ws"].shape[1], o["wf"].shape[1]
    xq = jnp.clip(jnp.round(o["x"] / sc["x_scale"]), -127,
                  127).astype(jnp.int8)
    xs = jnp.asarray([[sc["x_scale"]]], jnp.float32)
    s = ops.fused_dense_int8(xq.reshape(b * n, dh), q["ws_q"], o["bs"],
                             xs, q["ws_scale"], activation="none",
                             backend=backend).reshape(b, n, ds)
    f = ops.fused_dense_int8(xq.reshape(b * n, dh), q["wf_q"], o["bf"],
                             xs, q["wf_scale"], activation="none",
                             backend=backend).reshape(b, n, df)
    agg = ops.gravnet_aggregate_batched(s, f, o["mask"], k=k,
                                        backend=backend)
    agg = jnp.clip(jnp.round(agg / sc["agg_scale"]), -127,
                   127) * sc["agg_scale"]
    h = jnp.concatenate([o["x"], agg], axis=-1)
    hq = jnp.clip(jnp.round(h / sc["h_scale"]), -127,
                  127).astype(jnp.int8)
    hs = jnp.asarray([[sc["h_scale"]]], jnp.float32)
    return ops.fused_dense_int8(hq.reshape(b * n, dh + 2 * df),
                                q["wo_q"], o["bo"], hs, q["wo_scale"],
                                activation="relu",
                                backend=backend).reshape(b, n, -1)


@pytest.mark.parametrize("backend", backend_sweep())
def test_gravnet_block_int8_matches_unfused_int8_chain(backend):
    """The quantized-megakernel headline: one fused launch matches the
    calibrated unfused int8 kernel chain within calibration tolerance
    (requantization boundary values may snap one step apart) on every
    available backend."""
    o, q, sc, k = _int8_block_operands()
    fused = ops.gravnet_block_int8_batched(
        o["x"], o["mask"], q["ws_q"], o["bs"], q["wf_q"], o["bf"],
        q["wo_q"], o["bo"], q["ws_scale"], q["wf_scale"], q["wo_scale"],
        k=k, backend=backend, **sc)
    want = _unfused_int8_chain(o, q, sc, k, backend)
    quantum = int8_flip_tolerance(sc["h_scale"], q["wo_scale"])
    assert_calibration_close(fused, want, quantum=quantum,
                             context=backend)


def test_gravnet_block_int8_batched_bitwise_matches_per_event():
    o, q, sc, k = _int8_block_operands()
    batched = ops.gravnet_block_int8_batched(
        o["x"], o["mask"], q["ws_q"], o["bs"], q["wf_q"], o["bf"],
        q["wo_q"], o["bo"], q["ws_scale"], q["wf_scale"], q["wo_scale"],
        k=k, backend="pallas_interpret", **sc)
    looped = jnp.stack([
        ops.gravnet_block_int8(
            o["x"][i], o["mask"][i], q["ws_q"], o["bs"], q["wf_q"],
            o["bf"], q["wo_q"], o["bo"], q["ws_scale"], q["wf_scale"],
            q["wo_scale"], k=k, backend="pallas_interpret", **sc)
        for i in range(o["x"].shape[0])])
    assert_bitwise(batched, looped)


def test_gravnet_block_int8_matches_ref_oracle():
    o, q, sc, k = _int8_block_operands()
    got = ops.gravnet_block_int8_batched(
        o["x"], o["mask"], q["ws_q"], o["bs"], q["wf_q"], o["bf"],
        q["wo_q"], o["bo"], q["ws_scale"], q["wf_scale"], q["wo_scale"],
        k=k, backend="pallas_interpret", **sc)
    want = ref.gravnet_block_int8_ref(
        o["x"], o["mask"], q["ws_q"], o["bs"], q["wf_q"], o["bf"],
        q["wo_q"], o["bo"], q["ws_scale"], q["wf_scale"], q["wo_scale"],
        k=k, **sc)
    quantum = int8_flip_tolerance(sc["h_scale"], q["wo_scale"])
    assert_calibration_close(got, want, quantum=quantum)


def test_gravnet_block_int8_bn_split_bitwise_bk_split_bitwise():
    """int32 epilogue accumulation makes BOTH splits exact — a numerics
    upgrade over the f32 block, whose bk split only holds to ulps."""
    o, q, sc, k = _int8_block_operands()
    args = (o["x"], o["mask"], q["ws_q"], o["bs"], q["wf_q"], o["bf"],
            q["wo_q"], o["bo"], q["ws_scale"], q["wf_scale"],
            q["wo_scale"])
    base = ops.gravnet_block_int8_batched(*args, k=k,
                                          backend="pallas_interpret",
                                          **sc)
    bn = ops.gravnet_block_int8_batched(*args, k=k, bn=8,
                                        backend="pallas_interpret", **sc)
    assert_bitwise(bn, base, context="column split")
    bk = ops.gravnet_block_int8_batched(*args, k=k, bk=16,
                                        backend="pallas_interpret", **sc)
    assert_bitwise(bk, base, context="K split (exact in int32)")


def test_gravnet_block_int8_requantized_output():
    o, q, sc, k = _int8_block_operands()
    args = (o["x"], o["mask"], q["ws_q"], o["bs"], q["wf_q"], o["bf"],
            q["wo_q"], o["bo"], q["ws_scale"], q["wf_scale"],
            q["wo_scale"])
    out_scale = 0.05
    got = ops.gravnet_block_int8_batched(*args, k=k, out_dtype=jnp.int8,
                                         out_scale=out_scale,
                                         backend="pallas_interpret",
                                         **sc)
    want = ref.gravnet_block_int8_ref(*args, k=k, out_dtype=jnp.int8,
                                      out_scale=out_scale, **sc)
    assert got.dtype == jnp.int8 and want.dtype == jnp.int8
    # flips upstream of the output requant surface as whole int8 steps,
    # so compare dequantized values with the flip bound widened by one
    # output quantum
    quantum = (int8_flip_tolerance(sc["h_scale"], q["wo_scale"])
               + out_scale)
    assert_calibration_close(np.asarray(got, np.float64) * out_scale,
                             np.asarray(want, np.float64) * out_scale,
                             quantum=quantum)


# ----------------------------------------- deployed bitwise acceptance ----
@pytest.mark.parametrize("batch,k", [(1, 4), (1, 8), (8, 4), (8, 8)])
def test_deployed_fused_bitwise_equals_unfused_every_bucket(batch, k):
    """The acceptance sweep: deploy(fuse_gravnet_block=True/False) at
    every occupancy bucket and compare outputs bitwise (f32) through
    the Pallas (interpret) kernel path."""
    cfg = dataclasses.replace(ccn.current_detector_config(), k=k)
    params = ccn.init(jax.random.PRNGKey(1), cfg)
    g = ccn.to_graph(params, cfg)
    rng = np.random.default_rng(7)
    nb = max(batch, 2)
    feeds = {
        "hits": jnp.asarray(rng.normal(size=(nb, cfg.n_hits, cfg.d_in)),
                            jnp.float32),
        "mask": jnp.asarray(rng.uniform(size=(nb, cfg.n_hits)) < 0.7,
                            jnp.float32),
    }
    for bucket in (8, 16, 32):
        req = Requirements(design_point=3, platform="cpu",
                           precision_policy="fp", n_hits=bucket,
                           target_throughput=5e4, max_latency_s=2e-3)
        fb = _cut_hits(feeds, bucket)
        fused = deploy(g, req, kernel_backend="pallas_interpret",
                       batch=batch)(fb)
        unfused = deploy(g, req, kernel_backend="pallas_interpret",
                         batch=batch, fuse_gravnet_block=False)(fb)
        for head in ("beta", "coords", "energy", "cls"):
            assert_bitwise(fused[head], unfused[head],
                           context=f"bucket={bucket} head={head}")


def test_deployed_fused_bitwise_on_xla_backend():
    cfg = ccn.current_detector_config()
    params = ccn.init(jax.random.PRNGKey(2), cfg)
    g = ccn.to_graph(params, cfg)
    rng = np.random.default_rng(3)
    feeds = {
        "hits": jnp.asarray(rng.normal(size=(8, cfg.n_hits, cfg.d_in)),
                            jnp.float32),
        "mask": jnp.asarray(rng.uniform(size=(8, cfg.n_hits)) < 0.7,
                            jnp.float32),
    }
    req = Requirements(design_point=3, platform="cpu",
                       precision_policy="fp", n_hits=cfg.n_hits,
                       target_throughput=5e4, max_latency_s=2e-3)
    fused = deploy(g, req, batch=8)(feeds)
    unfused = deploy(g, req, batch=8, fuse_gravnet_block=False)(feeds)
    for head in ("beta", "coords", "energy", "cls"):
        assert_bitwise(fused[head], unfused[head], context=head)


# ------------------------------------- deployed int8 acceptance sweep ----
@pytest.mark.parametrize("backend", backend_sweep())
@pytest.mark.parametrize("batch", [1, 8])
def test_deployed_int8_fused_matches_unfused_every_bucket(batch, backend):
    """The quantized acceptance sweep: under the mixed policy with
    calibration data, ``deploy`` now emits the fused int8 block by
    default; ``fuse_int8=False`` reproduces the legacy unfused
    calibrated chain. The two must agree within calibration tolerance
    (the fused block's scales are re-derived by ``_calibrate_block``
    and may place requantization boundaries one ulp apart) at every
    occupancy bucket, micro-batch width, and backend."""
    g, cfg = _ccn_graph()
    rng = np.random.default_rng(7)
    nb = max(batch, 4)
    feeds = {
        "hits": jnp.asarray(rng.normal(size=(nb, cfg.n_hits, cfg.d_in)),
                            jnp.float32),
        "mask": jnp.asarray(rng.uniform(size=(nb, cfg.n_hits)) < 0.7,
                            jnp.float32),
    }
    for bucket in (8, 16, 32):
        req = Requirements(design_point=3, platform="cpu",
                           precision_policy="mixed", n_hits=bucket,
                           target_throughput=5e4, max_latency_s=2e-3)
        fb = _cut_hits(feeds, bucket)
        fused = deploy(g, req, kernel_backend=backend, batch=batch,
                       calibration_feeds=fb)
        unfused = deploy(g, req, kernel_backend=backend, batch=batch,
                         calibration_feeds=fb, fuse_int8=False)
        blocks = [op for op in fused.graph
                  if op.op_type == "gravnet_block"]
        assert len(blocks) == cfg.n_gravnet_blocks
        for blk in blocks:
            assert blk.precision == "int8"
            assert {"ws_q", "wf_q", "wo_q", "ws_scale", "wf_scale",
                    "wo_scale"} <= set(blk.params)
            for a in ("in_scale", "agg_scale", "h_scale"):
                assert blk.attrs[a] > 0.0
        assert not any(op.op_type == "gravnet_block"
                       for op in unfused.graph)
        # flips=4: a flip inside block 0 can shift block 1's inputs
        # and stack with block 1's own boundary flips
        quantum = max(int8_flip_tolerance(blk.attrs["h_scale"],
                                          blk.params["wo_scale"],
                                          flips=4)
                      for blk in blocks)
        yf, yu = fused(fb), unfused(fb)
        for head in ("beta", "coords", "energy", "cls"):
            assert_calibration_close(
                yf[head], yu[head], quantum=quantum,
                context=f"{backend} bucket={bucket} head={head}")


# --------------------------------------------------- fusion-pass rewrite ----
def _ccn_graph(**over):
    cfg = dataclasses.replace(ccn.current_detector_config(), **over)
    params = ccn.init(jax.random.PRNGKey(0), cfg)
    return ccn.to_graph(params, cfg), cfg


def test_fuse_gravnet_block_rewrites_both_blocks():
    g, cfg = _ccn_graph()
    f = fuse(g, gravnet_block=True)
    blocks = [op for op in f if op.op_type == "gravnet_block"]
    assert len(blocks) == cfg.n_gravnet_blocks
    assert not any(op.op_type == "gravnet_aggregate" for op in f)
    for blk in blocks:
        assert blk.attrs["concat_x"] is True
        assert blk.attrs["activation"] == "relu"
        assert blk.attrs["d_hidden"] == cfg.d_hidden
        assert set(blk.params) == {"ws", "bs", "wf", "bf", "wo", "bo"}
    verify(f)
    # default stays the legacy rewrite, bit-for-bit
    legacy = fuse(g)
    assert [op.name for op in legacy] == [op.name for op in fuse(g)]
    assert not any(op.op_type == "gravnet_block" for op in legacy)


def test_fuse_gravnet_block_preserves_semantics():
    g, cfg = _ccn_graph()
    rng = np.random.default_rng(0)
    feats = jnp.asarray(rng.normal(size=(2, cfg.n_hits, cfg.d_in)),
                        jnp.float32)
    mask = jnp.asarray(rng.uniform(size=(2, cfg.n_hits)) < 0.7,
                       jnp.float32)
    feeds = {"hits": feats, "mask": mask}
    req = Requirements(design_point=2, platform="cpu",
                       precision_policy="fp", n_hits=cfg.n_hits,
                       target_throughput=1e4)
    out = deploy(g, req)(feeds)
    want = ccn.apply(ccn.init(jax.random.PRNGKey(0), cfg), feats, mask,
                     cfg)
    np.testing.assert_allclose(np.asarray(out["beta"][..., 0]),
                               np.asarray(want["beta_logit"]),
                               rtol=1e-4, atol=1e-5)


def test_block_pattern_skips_tapped_aggregate():
    """An extra consumer on the aggregate output (e.g. a monitor tap)
    must keep the chain unfused — the tap needs the materialized
    tensor."""
    g, cfg = _ccn_graph()
    g = g.clone()
    tap = Operator(name="agg_tap", op_type="relu", inputs=["gn0_agg"],
                   out_dim=2 * cfg.d_flr)
    g.insert_after("gn0_agg", tap)
    f = fuse(g, gravnet_block=True)
    names = {op.name for op in f}
    assert "gn0_agg" in names                 # block 0 stayed unfused
    blocks = [op for op in f if op.op_type == "gravnet_block"]
    assert [b.name for b in blocks] == ["gn1_agg.block"]   # block 1 fused


def test_block_pattern_skips_tapped_projection():
    g, cfg = _ccn_graph()
    g = g.clone()
    tap = Operator(name="s_tap", op_type="relu", inputs=["gn0_s"],
                   out_dim=cfg.d_s)
    g.insert_after("gn0_s", tap)
    f = fuse(g, gravnet_block=True)
    assert "gn0_agg" in {op.name for op in f}
    assert [op.name for op in f if op.op_type == "gravnet_block"] \
        == ["gn1_agg.block"]


def test_linear_with_extra_consumer_does_not_fuse_relu():
    """linear → relu only fuses when the relu is the sole consumer."""
    g = Graph()
    g.add(Operator(name="in", op_type="input", out_dim=4,
                   attrs={"feature": "x"}))
    w = jnp.ones((4, 4), jnp.float32)
    g.add(Operator(name="lin", op_type="linear", inputs=["in"],
                   params={"w": w, "b": jnp.zeros((4,))}, out_dim=4))
    g.add(Operator(name="act", op_type="relu", inputs=["lin"], out_dim=4))
    g.add(Operator(name="tap", op_type="relu", inputs=["lin"], out_dim=4))
    g.add(Operator(name="out", op_type="output", inputs=["act", "tap"],
                   attrs={"head_names": ["a", "b"]}, out_dim=8))
    f = fuse(g)
    assert "lin+relu" not in {op.name for op in f}
    assert sum(1 for op in f if op.op_type == "relu") == 2


@pytest.mark.parametrize("mismatch", ["activation", "precision"])
def test_parallel_dense_merge_refuses_mismatch(mismatch):
    g = Graph()
    g.add(Operator(name="in", op_type="input", out_dim=4,
                   attrs={"feature": "x"}))
    w = jnp.ones((4, 3), jnp.float32)
    a = Operator(name="da", op_type="dense", inputs=["in"],
                 params={"w": w, "b": jnp.zeros((3,))}, out_dim=3,
                 attrs={"activation": "relu"})
    b = Operator(name="db", op_type="dense", inputs=["in"],
                 params={"w": w, "b": jnp.zeros((3,))}, out_dim=3,
                 attrs={"activation": "relu"})
    if mismatch == "activation":
        b.attrs["activation"] = "none"
    else:
        b.precision = "int8"
    g.add(a)
    g.add(b)
    g.add(Operator(name="out", op_type="output", inputs=["da", "db"],
                   attrs={"head_names": ["a", "b"]}, out_dim=6))
    f = fuse(g)
    assert {"da", "db"} <= {op.name for op in f}   # no merge happened


def test_verify_rejects_malformed_gravnet_block():
    g, _ = _ccn_graph()
    f = fuse(g, gravnet_block=True)
    bad = f.clone()
    blk = [op for op in bad if op.op_type == "gravnet_block"][0]
    blk.params["wo"] = blk.params["wo"][:-1]   # wrong epilogue K
    with pytest.raises(GraphVerificationError):
        verify(bad)


def _mixed_feeds(cfg, seed=0, nb=4):
    rng = np.random.default_rng(seed)
    return {
        "hits": jnp.asarray(rng.normal(size=(nb, cfg.n_hits, cfg.d_in)),
                            jnp.float32),
        "mask": jnp.asarray(rng.uniform(size=(nb, cfg.n_hits)) < 0.7,
                            jnp.float32),
    }


def _mixed_req(cfg):
    return Requirements(design_point=3, platform="cpu",
                        precision_policy="mixed", n_hits=cfg.n_hits,
                        target_throughput=1e4)


def test_mixed_precision_with_calibration_fuses_int8_block():
    """With calibration data present, the mixed policy's int8 interior
    lowers onto the *quantized* megakernel: the blocks carry quantized
    weights, per-channel scale vectors, and the three baked activation
    scales the kernel requantizes with."""
    g, cfg = _ccn_graph()
    pipe = deploy(g, _mixed_req(cfg),
                  calibration_feeds=_mixed_feeds(cfg))   # default fuse on
    blocks = [op for op in pipe.graph if op.op_type == "gravnet_block"]
    assert len(blocks) == cfg.n_gravnet_blocks
    for blk in blocks:
        assert blk.precision == "int8"
        assert {"ws_q", "wf_q", "wo_q", "ws_scale", "wf_scale",
                "wo_scale"} <= set(blk.params)
        for a in ("in_scale", "agg_scale", "h_scale"):
            assert a in blk.attrs and blk.attrs[a] > 0.0


def test_fuse_int8_escape_hatch_reproduces_legacy_unfused_chain():
    """``fuse_int8=False`` (and ``fuse_gravnet_block=False``) restore
    the legacy mixed deployment: no fused block ops, and the tuning
    problems the graph emits are the legacy unfused families — no
    ``gravnet_block*`` keys."""
    from repro.tuning import graph_kernel_problems
    g, cfg = _ccn_graph()
    feeds = _mixed_feeds(cfg)
    pipe = deploy(g, _mixed_req(cfg), calibration_feeds=feeds,
                  fuse_int8=False)
    assert not any(op.op_type == "gravnet_block" for op in pipe.graph)
    keys = graph_kernel_problems(pipe.graph, n_rows=cfg.n_hits,
                                 backend="xla", batch=4)
    kinds = {k.kernel for k in keys}
    assert "gravnet" in kinds
    assert not any(k.startswith("gravnet_block") for k in kinds)
    # fuse_gravnet_block=False implies the same unfused graph
    pipe2 = deploy(g, _mixed_req(cfg), calibration_feeds=feeds,
                   fuse_gravnet_block=False)
    assert [op.name for op in pipe2.graph] == \
        [op.name for op in pipe.graph]


def test_mixed_without_calibration_is_rejected():
    """The relaxed fusion condition keys off ``calibration_feeds is
    not None`` — sound because ``deploy`` refuses a mixed deployment
    without calibration data outright (an uncalibrated int8 interior
    could otherwise be silently frozen into a fused kernel)."""
    g, cfg = _ccn_graph()
    with pytest.raises(ValueError, match="calibration"):
        deploy(g, _mixed_req(cfg))   # default fuse on, no feeds


# ------------------------------------ int8 fusion guard (direct fuse) ----
def _int8_chain_graph(*, calibrated=True, uniform=True, tap_agg=False,
                      dh=12, ds=3, df=5, dout=12, k=4):
    """A hand-built calibrated int8 block chain for exercising the
    precision-set-aware guard through ``fuse`` directly (the deploy
    flow fuses before the precision policy runs, so only direct fusion
    of an already-calibrated graph reaches these branches)."""
    from repro.core.quantization import quantize_weight
    rng = np.random.default_rng(11)
    g = Graph()
    g.add(Operator(name="x", op_type="input", out_dim=dh,
                   attrs={"feature": "x"}))
    g.add(Operator(name="m", op_type="input", out_dim=1,
                   attrs={"feature": "m"}))

    def _dense(name, inp, d_in, d_out, activation):
        w = jnp.asarray(rng.normal(size=(d_in, d_out)) * 0.3, jnp.float32)
        b = jnp.asarray(rng.normal(size=(d_out,)) * 0.1, jnp.float32)
        op = Operator(name=name, op_type="dense", inputs=[inp],
                      params={"w": w, "b": b}, out_dim=d_out,
                      attrs={"activation": activation},
                      precision="int8")
        if calibrated:
            op.params["w_q"], op.params["w_scale"] = quantize_weight(w)
            op.attrs["in_scale"] = 0.02
        return op

    g.add(_dense("s", "x", dh, ds, "none"))
    g.add(_dense("f", "x", dh, df, "none"))
    agg = Operator(name="agg", op_type="gravnet_aggregate",
                   inputs=["s", "f", "m"],
                   attrs={"k": k, "scale": 10.0, "d_s": ds, "d_f": df},
                   out_dim=2 * df, precision="int8")
    if calibrated:
        agg.attrs["act_scale"] = 0.01
    g.add(agg)
    g.add(Operator(name="cat", op_type="concat", inputs=["x", "agg"],
                   out_dim=dh + 2 * df, precision="int8"))
    g.add(_dense("blk_out", "cat", dh + 2 * df, dout, "relu"))
    if not uniform:
        g["f"].precision = "bf16"
    heads, head_names = ["blk_out"], ["y"]
    if tap_agg:
        g.add(Operator(name="agg_tap", op_type="relu", inputs=["agg"],
                       out_dim=2 * df))
        heads.append("agg_tap")
        head_names.append("tap")
    g.add(Operator(name="out", op_type="output", inputs=heads,
                   attrs={"head_names": head_names},
                   out_dim=dout + (2 * df if tap_agg else 0)))
    g.validate()
    return g


def test_fuse_calibrated_int8_chain_carries_quantization():
    """Direct fusion of an already-calibrated uniform-int8 chain is
    allowed and must carry the quantized weights + scales over, so the
    fused block is executable without re-calibrating."""
    f = fuse(_int8_chain_graph(), gravnet_block=True)
    blocks = [op for op in f if op.op_type == "gravnet_block"]
    assert len(blocks) == 1
    blk = blocks[0]
    assert blk.precision == "int8"
    assert {"ws_q", "wf_q", "wo_q", "ws_scale", "wf_scale",
            "wo_scale"} <= set(blk.params)
    assert blk.attrs["in_scale"] == 0.02
    assert blk.attrs["agg_scale"] == 0.01
    assert blk.attrs["h_scale"] == 0.02    # the out dense's in_scale


def test_fuse_refuses_uncalibrated_int8_chain():
    f = fuse(_int8_chain_graph(calibrated=False), gravnet_block=True)
    assert not any(op.op_type == "gravnet_block" for op in f)
    assert any(op.op_type == "gravnet_aggregate" for op in f)


def test_fuse_refuses_mixed_member_precisions():
    f = fuse(_int8_chain_graph(uniform=False), gravnet_block=True)
    assert not any(op.op_type == "gravnet_block" for op in f)


def test_fuse_refuses_tapped_int8_aggregate():
    f = fuse(_int8_chain_graph(tap_agg=True), gravnet_block=True)
    assert not any(op.op_type == "gravnet_block" for op in f)


# ----------------------------------------------------------- tuning keys ----
def test_gravnet_block_key_batch_dimension():
    from repro.tuning import gravnet_block_key
    from repro.tuning.cache import KernelKey
    k1 = gravnet_block_key(32, 64, 22, 8, "float32", "xla")
    kb = gravnet_block_key(32, 64, 22, 8, "float32", "xla", batch=8)
    assert k1.shape == (32, 64, 22, 8)
    assert kb.shape == (8, 32, 64, 22, 8)      # the 5-dim batched key
    assert KernelKey.decode(kb.encode()) == kb


def test_kernel_opt_binds_cached_block_winner_and_miss_is_default():
    from repro.tuning import TuningCache, gravnet_block_key
    g, cfg = _ccn_graph()
    req = Requirements(design_point=3, platform="cpu",
                       precision_policy="fp", n_hits=cfg.n_hits,
                       target_throughput=5e4, max_latency_s=2e-3)
    # empty cache: no (bm, bn, bk) bindings on the block ops
    pipe0 = deploy(g, req, batch=8, tuning_cache=TuningCache(),
                   kernel_backend="xla")
    for op in pipe0.graph:
        if op.op_type == "gravnet_block":
            assert not any(kn in op.attrs_opt for kn in ("bm", "bn", "bk"))
    cache = TuningCache()
    cache.put(gravnet_block_key(cfg.n_hits, cfg.d_hidden, cfg.d_flr,
                                cfg.k, "float32", "xla", batch=8),
              {"bm": 16, "bn": 32, "d_s": cfg.d_s, "d_out": cfg.d_hidden})
    pipe = deploy(g, req, batch=8, tuning_cache=cache,
                  kernel_backend="xla")
    blocks = [op for op in pipe.graph if op.op_type == "gravnet_block"]
    assert blocks
    for op in blocks:
        assert op.attrs_opt["bm"] == 16 and op.attrs_opt["bn"] == 32
        assert "d_s" not in op.attrs_opt       # replay hints never bind


def test_tune_and_warmup_roundtrip_block_key(tmp_path):
    from repro.tuning import (TuningCache, gravnet_block_key,
                              tune_gravnet_block, warm_from_cache)
    cache = TuningCache(tmp_path / "c.json")
    cfg = tune_gravnet_block(16, 24, 3, 10, 24, 4, batch=3,
                             backend="xla", cache=cache, iters=1)
    assert "bm" in cfg
    key = gravnet_block_key(16, 24, 10, 4, "float32", "xla", batch=3)
    assert key in cache
    entry = cache.entry(key)
    assert entry.config["d_s"] == 3 and entry.config["d_out"] == 24
    assert warm_from_cache(cache, backend="xla") == 1
    # per-event (4-dim) key replays too
    cache.put(gravnet_block_key(16, 24, 10, 4, "float32", "xla"),
              {"bm": 16, "d_s": 3, "d_out": 24})
    assert warm_from_cache(cache, backend="xla") == 2


def test_autotune_graph_searches_block_problems():
    from repro.tuning import TuningCache, autotune_graph
    g, cfg = _ccn_graph()
    req = Requirements(design_point=3, platform="cpu",
                       precision_policy="fp", n_hits=cfg.n_hits,
                       target_throughput=5e4, max_latency_s=2e-3)
    pipe = deploy(g, req, batch=4)
    cache = TuningCache()
    autotune_graph(pipe.graph, n_rows=cfg.n_hits, backend="xla",
                   cache=cache, batch=4, iters=1)
    kinds = {k.kernel for k in cache.entries()}
    assert "gravnet_block" in kinds and "gravnet" not in kinds


# ------------------------------------------------- int8 tuning keys ----
def test_gravnet_block_int8_key_is_distinct_family():
    from repro.tuning import gravnet_block_int8_key, gravnet_block_key
    from repro.tuning.cache import KernelKey
    k8 = gravnet_block_int8_key(32, 64, 22, 8, "xla", batch=8)
    assert k8.kernel == "gravnet_block_int8" and k8.dtype == "int8"
    assert k8.shape == (8, 32, 64, 22, 8)
    assert KernelKey.decode(k8.encode()) == k8
    # never collides with the f32 family even at identical dims
    kf = gravnet_block_key(32, 64, 22, 8, "float32", "xla", batch=8)
    assert k8 != kf and k8.encode() != kf.encode()


def test_kernel_opt_binds_cached_int8_block_winner():
    """A deployed mixed-precision pipeline looks up the dtype-tagged
    int8 key — never the f32 one — and binds only the launch knobs."""
    from repro.tuning import (TuningCache, gravnet_block_int8_key,
                              gravnet_block_key)
    g, cfg = _ccn_graph()
    feeds = _mixed_feeds(cfg)
    cache = TuningCache()
    cache.put(gravnet_block_int8_key(cfg.n_hits, cfg.d_hidden, cfg.d_flr,
                                     cfg.k, "xla", batch=4),
              {"bm": 16, "bn": 32, "d_s": cfg.d_s, "d_out": cfg.d_hidden})
    # an f32 winner at the same dims must NOT leak onto int8 blocks
    cache.put(gravnet_block_key(cfg.n_hits, cfg.d_hidden, cfg.d_flr,
                                cfg.k, "float32", "xla", batch=4),
              {"bm": 8, "bk": 64})
    pipe = deploy(g, _mixed_req(cfg), batch=4, tuning_cache=cache,
                  kernel_backend="xla", calibration_feeds=feeds)
    blocks = [op for op in pipe.graph if op.op_type == "gravnet_block"]
    assert blocks
    for op in blocks:
        assert op.precision == "int8"
        assert op.attrs_opt["bm"] == 16 and op.attrs_opt["bn"] == 32
        assert "bk" not in op.attrs_opt     # the f32 entry did not bind
        assert "d_s" not in op.attrs_opt    # replay hints never bind


def test_tune_and_warmup_roundtrip_int8_block_key(tmp_path):
    from repro.tuning import (TuningCache, gravnet_block_int8_key,
                              tune_gravnet_block, warm_from_cache)
    cache = TuningCache(tmp_path / "c.json")
    cfg = tune_gravnet_block(16, 24, 3, 10, 24, 4, batch=3, dtype="int8",
                             backend="xla", cache=cache, iters=1)
    assert "bm" in cfg
    key = gravnet_block_int8_key(16, 24, 10, 4, "xla", batch=3)
    assert key in cache
    entry = cache.entry(key)
    assert entry.config["d_s"] == 3 and entry.config["d_out"] == 24
    assert warm_from_cache(cache, backend="xla") == 1
    # per-event (4-dim) int8 key replays too
    cache.put(gravnet_block_int8_key(16, 24, 10, 4, "xla"),
              {"bm": 16, "d_s": 3, "d_out": 24})
    assert warm_from_cache(cache, backend="xla") == 2


def test_autotune_graph_searches_int8_block_problems():
    from repro.tuning import TuningCache, autotune_graph
    g, cfg = _ccn_graph()
    pipe = deploy(g, _mixed_req(cfg), batch=4,
                  calibration_feeds=_mixed_feeds(cfg))
    cache = TuningCache()
    autotune_graph(pipe.graph, n_rows=cfg.n_hits, backend="xla",
                   cache=cache, batch=4, iters=1)
    kinds = {k.kernel for k in cache.entries()}
    assert "gravnet_block_int8" in kinds
    assert "gravnet_block" not in kinds and "gravnet" not in kinds


# -------------------------------------------- attention executor route ----
def _attention_graph(n=16, d=8, seed=0):
    rng = np.random.default_rng(seed)
    g = Graph()
    g.add(Operator(name="tok", op_type="input", out_dim=d,
                   attrs={"feature": "tok"}))
    for nm in ("q", "k", "v"):
        w = jnp.asarray(rng.normal(size=(d, d)) * 0.3, jnp.float32)
        g.add(Operator(name=nm, op_type="linear", inputs=["tok"],
                       params={"w": w, "b": jnp.zeros((d,))}, out_dim=d))
    g.add(Operator(name="attn", op_type="attention",
                   inputs=["q", "k", "v"], attrs={"causal": True},
                   out_dim=d))
    g.add(Operator(name="out", op_type="output", inputs=["attn"],
                   attrs={"head_names": ["y"]}, out_dim=d))
    g.validate()
    return g


def test_attention_op_deploys_through_flash_kernel():
    """The flash_attention kernel is reachable from the graph executor:
    ``attention``-typed ops dispatch through it (docs/kernels.md)."""
    g = _attention_graph()
    rng = np.random.default_rng(1)
    tok = jnp.asarray(rng.normal(size=(2, 16, 8)), jnp.float32)
    req = Requirements(design_point=3, platform="cpu",
                       precision_policy="fp", n_hits=16,
                       target_throughput=1e3)
    out = deploy(g, req)({"tok": tok})["y"]
    qkv = [ref.fused_dense_ref(tok, g[nm].params["w"], g[nm].params["b"],
                               activation="none")
           for nm in ("q", "k", "v")]
    want = ref.flash_attention_ref(*qkv, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    # interpret backend exercises the Pallas flash kernel body
    out_i = deploy(g, req,
                   kernel_backend="pallas_interpret")({"tok": tok})["y"]
    np.testing.assert_allclose(np.asarray(out_i), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_attention_emits_flash_tuning_key_and_binds_blocks():
    from repro.tuning import (TuningCache, flash_attention_key,
                              graph_kernel_problems)
    g = _attention_graph()
    req = Requirements(design_point=3, platform="cpu",
                       precision_policy="fp", n_hits=16,
                       target_throughput=1e3)
    pipe = deploy(g, req, batch=2)
    keys = graph_kernel_problems(pipe.graph, n_rows=16, backend="xla",
                                 batch=2)
    fk = [k for k in keys if k.kernel == "flash_attention"]
    assert fk and fk[0].shape == (2, 16, 16, 8)
    cache = TuningCache()
    cache.put(flash_attention_key(2, 16, 16, 8, "float32", "xla"),
              {"bq": 16, "bk": 16})
    pipe2 = deploy(g, req, batch=2, tuning_cache=cache,
                   kernel_backend="xla")
    attn = [op for op in pipe2.graph if op.op_type == "attention"][0]
    assert attn.attrs_opt["bq"] == 16 and attn.attrs_opt["bk"] == 16
