"""Shared differential-numerics assertions for the kernel test suite.

Every fused-vs-unfused and kernel-vs-oracle comparison in the tests
used to carry its own copy of the tolerance logic (bitwise for f32
column splits, rtol/atol pairs per dtype, looser bounds for bf16).
With the quantized megakernel adding a third comparison regime —
*calibration tolerance*, where independently derived requantization
grids may legitimately disagree by whole quantization steps on
boundary values — the logic lives here once, so every test states
**which** equivalence it claims instead of re-inventing bounds:

- ``assert_bitwise``       : exact equality — fused rewrites that
                             reassociate nothing (column splits, the
                             f32 megakernel vs its unfused chain).
- ``assert_close``         : dtype-derived rtol/atol — kernel vs eager
                             oracle where jit fusion may move last
                             ulps (pass ``dtype=`` or explicit tols).
- ``assert_ulp_close``     : bounded ulp distance for f32 — tighter
                             than rtol/atol near zero, used for
                             K-reduction splits.
- ``assert_calibration_close``: the int8 regime — requires agreement
                             up to a caller-computed requantization
                             quantum and a small fraction of affected
                             elements (``int8_flip_tolerance`` derives
                             the quantum from the baked scales).
- ``backend_sweep``        : the backends a differential test should
                             run — ``xla`` (jnp reference), the
                             interpret-mode Pallas body, and the real
                             ``pallas`` path when a TPU is attached.
"""
from __future__ import annotations

import jax
import numpy as np

#: dtype name -> (rtol, atol) for kernel-vs-oracle comparisons. int8
#: accumulates exactly in int32; only the elementwise dequant epilogue
#: can differ, hence the near-exact bound.
DTYPE_TOLERANCES = {
    "float32": (1e-5, 1e-5),
    "bfloat16": (3e-2, 3e-2),
    "float64": (1e-12, 1e-12),
    "int8": (1e-6, 1e-6),
}


def backend_sweep() -> tuple[str, ...]:
    """Backends a differential test should sweep: the jnp reference
    composition, the Pallas kernel body under the CPU interpreter, and
    the compiled Mosaic path when an accelerator is actually present
    (it cannot execute on CPU CI hosts)."""
    backends = ["xla", "pallas_interpret"]
    if any(d.platform == "tpu" for d in jax.devices()):
        backends.append("pallas")
    return tuple(backends)


def tolerance(dtype) -> tuple[float, float]:
    """(rtol, atol) for a dtype given as a name or a jnp/np dtype."""
    name = getattr(dtype, "__name__", None) or np.dtype(dtype).name
    return DTYPE_TOLERANCES[name]


def _as64(x):
    return np.asarray(x, np.float64)


def assert_bitwise(got, want, *, context: str = "") -> None:
    """Exact equality — the claim fused rewrites make when they
    reassociate nothing."""
    g, w = np.asarray(got), np.asarray(want)
    if np.array_equal(g, w):
        return
    d = np.abs(_as64(g) - _as64(w))
    raise AssertionError(
        f"bitwise mismatch{' (' + context + ')' if context else ''}: "
        f"{int((d > 0).sum())}/{d.size} elements differ, "
        f"max|diff|={d.max():.3e}")


def assert_close(got, want, *, dtype=None, rtol: float | None = None,
                 atol: float | None = None, context: str = "") -> None:
    """rtol/atol comparison with dtype-derived defaults. Explicit
    ``rtol``/``atol`` override the table; with neither given the
    ``got`` array's own dtype picks the row."""
    g, w = np.asarray(got), np.asarray(want)
    if rtol is None or atol is None:
        trt, tat = tolerance(dtype if dtype is not None else g.dtype)
        rtol = trt if rtol is None else rtol
        atol = tat if atol is None else atol
    np.testing.assert_allclose(_as64(g), _as64(w), rtol=rtol, atol=atol,
                               err_msg=context)


def ulp_distance(got, want) -> np.ndarray:
    """Elementwise ulp distance between two f32 arrays, via the
    monotone int32 reinterpretation of IEEE floats (negative floats
    map below positives, so the distance is well-defined across
    zero)."""
    g = np.asarray(got, np.float32).view(np.int32).astype(np.int64)
    w = np.asarray(want, np.float32).view(np.int32).astype(np.int64)
    g = np.where(g < 0, np.int64(-(2 ** 31)) - g, g)
    w = np.where(w < 0, np.int64(-(2 ** 31)) - w, w)
    return np.abs(g - w)


def assert_ulp_close(got, want, *, max_ulp: int = 4, atol: float = 1e-6,
                     context: str = "") -> None:
    """f32 comparison in ulps — the right bound for K-reduction splits
    whose only freedom is summation order. Ulp distance diverges for
    values straddling zero (e.g. post-relu outputs a reassociated sum
    leaves at ±ε), so elements within ``atol`` absolutely pass
    regardless of their ulp distance."""
    d = ulp_distance(got, want)
    d = np.where(np.abs(_as64(got) - _as64(want)) <= atol, 0, d)
    if d.max() <= max_ulp:
        return
    raise AssertionError(
        f"ulp mismatch{' (' + context + ')' if context else ''}: "
        f"max ulp distance {int(d.max())} > {max_ulp} "
        f"({int((d > max_ulp).sum())}/{d.size} elements over)")


def int8_flip_tolerance(h_scale, wo_scale, *, flips: int = 2) -> float:
    """Worst-case output movement when requantization boundary values
    land on different sides of the grid in two implementations: each
    single-step flip of one quantized epilogue input moves an output
    element by at most ``h_scale * 127 * max(wo_scale)`` (the largest
    |int8 weight| times its channel scale). ``flips`` bounds how many
    independent flips may stack on one element."""
    return float(flips) * float(h_scale) * 127.0 * float(
        np.max(np.asarray(wo_scale, np.float64)))


def assert_calibration_close(got, want, *, quantum: float,
                             max_flip_frac: float = 0.05,
                             tight: float = 1e-5,
                             context: str = "") -> None:
    """The int8 fused-vs-unfused regime: independently derived
    requantization grids agree exactly almost everywhere, but values
    within an ulp of a grid boundary may snap to adjacent steps.
    Asserts every element is within ``quantum`` (the caller-computed
    flip bound, see ``int8_flip_tolerance``) and that at most
    ``max_flip_frac`` of elements differ by more than ``tight``."""
    d = np.abs(_as64(got) - _as64(want))
    tag = f" ({context})" if context else ""
    if d.max() > quantum + tight:
        raise AssertionError(
            f"calibration mismatch{tag}: max|diff|={d.max():.3e} exceeds "
            f"quantum bound {quantum:.3e}")
    frac = float(np.mean(d > tight))
    if frac > max_flip_frac:
        raise AssertionError(
            f"calibration mismatch{tag}: {frac:.1%} of elements flipped "
            f"(> {max_flip_frac:.1%} allowed)")
