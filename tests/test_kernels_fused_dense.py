"""Shape/dtype sweep + property tests: fused_dense Pallas kernel vs oracle."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_support import given, settings, st
from _numerics import assert_close, tolerance

from repro.kernels import ops, ref


def _rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (100, 70, 50), (128, 128, 128),
                                   (33, 257, 65), (1, 512, 7), (256, 64, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("variant", ["looped", "flattened"])
def test_fused_dense_sweep(m, k, n, dtype, variant):
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    x, w = _rand(rng, (m, k), dtype), _rand(rng, (k, n), dtype)
    b = _rand(rng, (n,), dtype)
    got = ops.fused_dense(x, w, b, variant=variant,
                          backend="pallas_interpret", bm=32, bn=32, bk=32)
    want = ref.fused_dense_ref(x, w, b)
    rt, _ = tolerance(dtype)
    assert_close(got, want, rtol=rt, atol=rt * 10)


@pytest.mark.parametrize("activation", ["relu", "gelu", "silu", "none"])
def test_fused_dense_activations(activation):
    rng = np.random.default_rng(0)
    x, w = _rand(rng, (32, 48), jnp.float32), _rand(rng, (48, 16), jnp.float32)
    got = ops.fused_dense(x, w, None, activation=activation,
                          backend="pallas_interpret", bm=16, bn=16, bk=16)
    want = ref.fused_dense_ref(x, w, None, activation=activation)
    assert_close(got, want, dtype=jnp.float32)


@pytest.mark.parametrize("m,k,n", [(32, 64, 32), (64, 96, 40), (17, 33, 9)])
@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.int8])
def test_fused_dense_int8_sweep(m, k, n, out_dtype):
    rng = np.random.default_rng(m + k + n)
    xq = jnp.asarray(rng.integers(-127, 128, size=(m, k)), jnp.int8)
    wq = jnp.asarray(rng.integers(-127, 128, size=(k, n)), jnp.int8)
    xs = jnp.asarray([[0.02]], jnp.float32)
    ws = jnp.asarray(rng.uniform(0.001, 0.05, size=(n,)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    got = ops.fused_dense_int8(xq, wq, b, xs, ws, out_dtype=out_dtype,
                               out_scale=0.1, backend="pallas_interpret",
                               bm=16, bn=16, bk=16)
    want = ref.fused_dense_int8_ref(xq, wq, b, xs, ws, out_dtype=out_dtype,
                                    out_scale=0.1)
    # int8 x int8 -> int32 accumulation is exact; epilogue is elementwise.
    assert_close(got, want, dtype="int8")


def test_fused_dense_matches_unfused():
    """Fusion must be semantics-preserving: Dense == relu(Linear)."""
    rng = np.random.default_rng(1)
    x, w = _rand(rng, (64, 32), jnp.float32), _rand(rng, (32, 24), jnp.float32)
    b = _rand(rng, (24,), jnp.float32)
    fused = ops.fused_dense(x, w, b, backend="pallas_interpret", bm=32,
                            bn=8, bk=32)
    unfused = jax.nn.relu(x @ w + b)
    assert_close(fused, unfused, dtype=jnp.float32)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 48), k=st.integers(1, 48), n=st.integers(1, 48),
       seed=st.integers(0, 2**31 - 1))
def test_fused_dense_property_padding_invariant(m, k, n, seed):
    """Arbitrary (non-tile-aligned) shapes agree with the oracle."""
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, (m, k), jnp.float32), _rand(rng, (k, n), jnp.float32)
    got = ops.fused_dense(x, w, None, backend="pallas_interpret",
                          bm=16, bn=16, bk=16)
    want = ref.fused_dense_ref(x, w, None)
    assert_close(got, want, dtype=jnp.float32)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_fused_dense_int8_requant_roundtrip(seed):
    """Requantized int8 output stays within one quantization step of f32."""
    rng = np.random.default_rng(seed)
    xq = jnp.asarray(rng.integers(-64, 64, size=(16, 32)), jnp.int8)
    wq = jnp.asarray(rng.integers(-64, 64, size=(32, 16)), jnp.int8)
    xs = jnp.asarray([[0.01]], jnp.float32)
    ws = jnp.asarray(rng.uniform(0.001, 0.02, size=(16,)), jnp.float32)
    out_scale = 0.05
    y_f = ops.fused_dense_int8(xq, wq, None, xs, ws, out_dtype=jnp.float32,
                               backend="pallas_interpret", bm=16, bn=16, bk=16)
    y_q = ops.fused_dense_int8(xq, wq, None, xs, ws, out_dtype=jnp.int8,
                               out_scale=out_scale,
                               backend="pallas_interpret", bm=16, bn=16, bk=16)
    deq = np.asarray(y_q, np.float32) * out_scale
    clipped = np.clip(np.asarray(y_f), -127 * out_scale, 127 * out_scale)
    assert np.max(np.abs(deq - clipped)) <= out_scale * 0.5 + 1e-6
