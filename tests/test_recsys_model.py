"""MIND tests: embedding bag, capsule routing, label-aware attention,
retrieval scoring."""
import numpy as np
import jax
import jax.numpy as jnp
from _hypothesis_support import given, settings, st

from repro.data.recsys import mind_batch
from repro.models import recsys


CFG = recsys.MINDConfig(n_items=300, n_user_tags=60, embed_dim=16,
                        n_interests=4, hist_len=8, tag_bag=4)


def _batch(seed=0, b=8):
    return {k: jnp.asarray(v) for k, v in mind_batch(
        n_items=CFG.n_items, n_user_tags=CFG.n_user_tags,
        hist_len=CFG.hist_len, tag_bag=CFG.tag_bag, batch=b, seed=seed,
        step=0).items()}


def test_embedding_bag_modes():
    rng = np.random.default_rng(0)
    tbl = jnp.asarray(rng.normal(size=(20, 8)), jnp.float32)
    ids = jnp.asarray([1, 2, -1, 4, 5, 6], jnp.int32)
    seg = jnp.asarray([0, 0, 0, 1, 1, 1], jnp.int32)
    mean = recsys.embedding_bag(tbl, ids, segment_ids=seg, num_segments=2)
    np.testing.assert_allclose(np.asarray(mean[0]),
                               np.asarray((tbl[1] + tbl[2]) / 2),
                               rtol=1e-6)
    total = recsys.embedding_bag(tbl, ids, segment_ids=seg,
                                 num_segments=2, mode="sum")
    np.testing.assert_allclose(np.asarray(total[1]),
                               np.asarray(tbl[4] + tbl[5] + tbl[6]),
                               rtol=1e-6)
    # weights
    w = jnp.asarray([2.0, 0.0, 1.0, 1.0, 1.0, 1.0], jnp.float32)
    ws = recsys.embedding_bag(tbl, ids, weights=w, segment_ids=seg,
                              num_segments=2, mode="sum")
    np.testing.assert_allclose(np.asarray(ws[0]), np.asarray(2.0 * tbl[1]),
                               rtol=1e-6)


def test_capsules_masked_behaviors_inert():
    p = recsys.init(jax.random.PRNGKey(0), CFG)
    b = _batch()
    u1 = recsys.extract_interests(p, b["behav_ids"], b["behav_mask"], CFG)
    # scramble the MASKED positions: output must not change
    ids2 = np.asarray(b["behav_ids"]).copy()
    m = np.asarray(b["behav_mask"]) == 0
    ids2[m] = (ids2[m] + 17) % CFG.n_items
    u2 = recsys.extract_interests(p, jnp.asarray(ids2), b["behav_mask"],
                                  CFG)
    np.testing.assert_allclose(np.asarray(u1), np.asarray(u2), rtol=1e-5,
                               atol=1e-6)


def test_label_aware_attention_prefers_aligned_capsule():
    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.normal(size=(1, 4, 16)), jnp.float32)
    target = u[:, 2] * 3.0  # aligned with capsule 2
    uv = recsys.label_aware_attention(u, target, CFG)
    sims = np.asarray(jnp.einsum("bkd,bd->bk", u, uv))[0]
    assert sims.argmax() == 2


def test_topk_retrieval_contains_target_after_training():
    p = recsys.init(jax.random.PRNGKey(0), CFG)
    b = _batch(b=16)

    @jax.jit
    def step(p):
        l, g = jax.value_and_grad(
            lambda q: recsys.loss_fn(q, b, CFG)[0])(p)
        return l, jax.tree_util.tree_map(lambda a, gg: a - 0.5 * gg, p, g)

    for _ in range(150):
        loss, p = step(p)
    b["cand_ids"] = jnp.arange(CFG.n_items, dtype=jnp.int32)
    _, idx = recsys.serve_topk(p, b, CFG, k=10)
    hits = sum(int(b["target"][i]) in set(np.asarray(idx[i]))
               for i in range(16))
    assert hits >= 12  # recall@10 >= 0.75 on the train batch


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_scores_max_over_interests(seed):
    p = recsys.init(jax.random.PRNGKey(seed % 97), CFG)
    b = _batch(seed=seed, b=4)
    b["cand_ids"] = jnp.arange(50, dtype=jnp.int32)
    u = recsys.user_capsules(p, b, CFG)
    ce = jnp.take(p["item_emb"], b["cand_ids"], axis=0)
    manual = np.asarray(jnp.einsum("bkd,cd->bkc", u, ce).max(axis=1))
    got = np.asarray(recsys.score_candidates(p, b, CFG))
    np.testing.assert_allclose(got, manual, rtol=1e-5, atol=1e-6)
