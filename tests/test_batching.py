"""Occupancy-bucketed, batch-packed inference path.

Covers the invariants docs/architecture.md promises:

- the batched kernels (leading event grid dimension) are bitwise-equal
  in f32 to a loop of per-event launches;
- bucket classification edge cases: 0-hit event, event exactly on a
  bucket boundary, event overflowing the largest bucket;
- a bucketed deployment reproduces the single-pipeline CPS decisions;
- the bucketed serving service dispatches per occupancy, keeps global
  order, and pre-compiles every bucket before traffic;
- the Belle II occupancy knob actually spreads events over buckets;
- tuning keys/warm-up carry the batch/bucket dimensions.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import caloclusternet as ccn
from repro.core.passes.parallelize import Requirements
from repro.core.pipeline import deploy, deploy_bucketed
from repro.data.belle2 import current_detector, generate, with_occupancy
from repro.kernels import ops
from repro.serving import ShardedTriggerService, event_occupancy, pick_bucket


# ------------------------------------------------- kernel equivalence ----
@pytest.mark.parametrize("b,n,bm", [(4, 32, 16), (3, 24, 8), (8, 16, 16)])
def test_gravnet_batched_bitwise_matches_per_event(b, n, bm):
    rng = np.random.default_rng(b * 100 + n)
    s = jnp.asarray(rng.normal(size=(b, n, 4)), jnp.float32)
    f = jnp.asarray(rng.normal(size=(b, n, 22)), jnp.float32)
    mask = jnp.asarray(rng.uniform(size=(b, n)) < 0.7, jnp.float32)
    batched = ops.gravnet_aggregate_batched(
        s, f, mask, k=6, bm=bm, backend="pallas_interpret")
    looped = jnp.stack([
        ops.gravnet_aggregate(s[i], f[i], mask[i], k=6, bm=bm,
                              backend="pallas_interpret")
        for i in range(b)])
    assert bool(jnp.all(batched == looped))   # bitwise, f32


def test_gravnet_batched_zero_hit_event_in_batch():
    """A fully-masked event inside a batch must aggregate to zeros
    without contaminating its neighbors."""
    rng = np.random.default_rng(0)
    s = jnp.asarray(rng.normal(size=(3, 16, 4)), jnp.float32)
    f = jnp.asarray(rng.normal(size=(3, 16, 8)), jnp.float32)
    mask = jnp.asarray(rng.uniform(size=(3, 16)) < 0.8, jnp.float32)
    mask = mask.at[1].set(0.0)
    out = ops.gravnet_aggregate_batched(s, f, mask, k=4, bm=16,
                                        backend="pallas_interpret")
    assert bool(jnp.all(out[1] == 0.0))
    solo = ops.gravnet_aggregate(s[0], f[0], mask[0], k=4, bm=16,
                                 backend="pallas_interpret")
    assert bool(jnp.all(out[0] == solo))


@pytest.mark.parametrize("variant", ["flattened", "looped"])
def test_fused_dense_batched_bitwise_matches_per_event(variant):
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(4, 32, 24)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(24, 40)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(40,)), jnp.float32)
    kw = dict(bm=16, bn=128, bk=128) if variant == "looped" else {}
    batched = ops.fused_dense_batched(x, w, b, variant=variant,
                                      backend="pallas_interpret", **kw)
    looped = jnp.stack([
        ops.fused_dense(x[i], w, b, variant=variant,
                        backend="pallas_interpret", **kw)
        for i in range(4)])
    assert bool(jnp.all(batched == looped))   # bitwise, f32
    want = np.maximum(np.einsum("bmk,kn->bmn", np.asarray(x),
                                np.asarray(w)) + np.asarray(b), 0.0)
    np.testing.assert_allclose(np.asarray(batched), want, rtol=1e-5,
                               atol=1e-5)


# ------------------------------------------------- bucket classification ----
def test_pick_bucket_edges():
    buckets = (8, 16, 32)
    assert pick_bucket(0, buckets) == 8          # 0-hit event
    assert pick_bucket(7, buckets) == 8
    assert pick_bucket(8, buckets) == 8          # exactly on boundary
    assert pick_bucket(9, buckets) == 16
    assert pick_bucket(16, buckets) == 16        # boundary again
    assert pick_bucket(32, buckets) == 32
    assert pick_bucket(33, buckets) == 32        # overflow -> largest
    assert pick_bucket(10_000, buckets) == 32
    with pytest.raises(ValueError):
        pick_bucket(1, ())


def test_event_occupancy_counts_nonzero_mask():
    ev = {"hits": np.zeros((32, 4), np.float32),
          "mask": np.concatenate([np.ones(5), np.zeros(27)]
                                 ).astype(np.float32)}
    assert event_occupancy(ev) == 5
    ev["mask"][:] = 0
    assert event_occupancy(ev) == 0


# ------------------------------------------------- bucketed deployment ----
@pytest.fixture(scope="module")
def trigger_setup():
    cfg = ccn.current_detector_config()
    gen = current_detector()
    params = ccn.init(jax.random.PRNGKey(0), cfg)
    graph = ccn.to_graph(params, cfg)
    req = Requirements(design_point=3, platform="cpu",
                       precision_policy="fp", n_hits=cfg.n_hits,
                       target_throughput=2e4, max_latency_s=2e-3)
    events = generate(with_occupancy(gen, (4, 8, 16, 32)), 24, seed=11)
    feeds = {"hits": events["feats"], "mask": events["mask"]}
    return cfg, gen, graph, req, events, feeds


def test_bucketed_pipeline_matches_single(trigger_setup):
    cfg, gen, graph, req, events, feeds = trigger_setup
    single = deploy(graph, req)
    bucketed = deploy_bucketed(graph, req, buckets=(8, 16, 32),
                               microbatch=4, calibration_feeds=feeds)
    out_s = single(feeds)
    out_b = bucketed(feeds)
    for key in ("trigger", "n_clusters"):
        assert (np.asarray(out_b["cps"][key])
                == np.asarray(out_s["cps"][key])).all()
    np.testing.assert_allclose(np.asarray(out_b["cps"]["cluster_e"]),
                               np.asarray(out_s["cps"]["cluster_e"]),
                               rtol=1e-6, atol=1e-6)


def test_bucketed_pipeline_zero_hit_and_overflow(trigger_setup):
    cfg, gen, graph, req, events, feeds = trigger_setup
    bucketed = deploy_bucketed(graph, req, buckets=(8, 16),
                               microbatch=2, calibration_feeds=feeds)
    # 0-hit event -> smallest bucket; full 32-hit event overflows the
    # largest bucket (16) and must still produce a decision
    hits = np.asarray(feeds["hits"][:2]).copy()
    mask = np.asarray(feeds["mask"][:2]).copy()
    hits[0], mask[0] = 0.0, 0.0          # 0 hits
    mask[1] = 1.0                        # 32 nonzero hits > largest bucket
    assert bucketed.classify(0) == 8
    assert bucketed.classify(32) == 16
    out = bucketed({"hits": hits, "mask": mask})
    trig = np.asarray(out["cps"]["trigger"])
    assert trig.shape == (2,)
    assert not bool(trig[0])             # nothing to trigger on
    # overflow event matches the largest-bucket executable run directly
    direct = bucketed.pipes[16]({"hits": jnp.asarray(hits[1:2, :16]),
                                 "mask": jnp.asarray(mask[1:2, :16])})
    assert bool(trig[1]) == bool(np.asarray(direct["cps"]["trigger"])[0])


def test_bucketed_pipeline_warmup_counts_buckets(trigger_setup):
    cfg, gen, graph, req, events, feeds = trigger_setup
    bucketed = deploy_bucketed(graph, req, buckets=(8, 32), microbatch=2,
                               calibration_feeds=feeds)
    assert bucketed.warmup() == 2


# --------------------------------------------------- bucketed serving ----
def test_bucketed_service_dispatch_and_order(trigger_setup):
    cfg, gen, graph, req, events, feeds = trigger_setup
    bucketed = deploy_bucketed(graph, req, buckets=(8, 16, 32),
                               microbatch=4, calibration_feeds=feeds)
    svc = ShardedTriggerService(buckets=bucketed, n_replicas=1,
                                microbatch=4, window_s=5e-3, devices=None)
    try:
        # every bucket executable pre-compiled before traffic
        assert sum(r.warmed for r in svc.replicas) == 3
        n = feeds["hits"].shape[0]
        futs = [svc.submit({"hits": np.asarray(feeds["hits"][i]),
                            "mask": np.asarray(feeds["mask"][i])})
                for i in range(n)]
        res = [f.result(timeout=60) for f in futs]
        svc.drain()
        want = np.asarray(bucketed(feeds)["cps"]["trigger"])
        got = np.asarray([bool(r["cps"]["trigger"]) for r in res])
        assert (got == want).all()       # in-order AND bucket-correct
        summ = svc.bucket_summary()
        assert [s["bucket"] for s in summ] == [8, 16, 32]
        assert sum(s["submitted"] for s in summ) == n
        assert all(s["completed"] == s["submitted"] for s in summ)
        occ = np.count_nonzero(np.asarray(feeds["mask"]) > 0, axis=1)
        for s in summ:
            expect = sum(1 for o in occ
                         if pick_bucket(int(o), (8, 16, 32)) == s["bucket"])
            assert s["submitted"] == expect
    finally:
        svc.close()


def test_bucketed_service_rejects_empty_and_classify_guard():
    with pytest.raises(ValueError):
        ShardedTriggerService(buckets={}, microbatch=2)
    with pytest.raises(ValueError):   # conflicting arguments
        ShardedTriggerService(lambda f: f, buckets={8: lambda f: f},
                              microbatch=2)
    with pytest.raises(ValueError):   # neither argument
        ShardedTriggerService(microbatch=2)
    svc = ShardedTriggerService(lambda feeds: feeds, microbatch=2,
                                devices=None)
    try:
        with pytest.raises(RuntimeError):
            svc.classify({"mask": np.ones(4, np.float32)})
    finally:
        svc.close()


# ----------------------------------------------------- occupancy knob ----
def test_belle2_occupancy_knob_spreads_buckets():
    gen = with_occupancy(current_detector(), (4, 8, 16, 32),
                         (0.4, 0.3, 0.2, 0.1))
    ev = generate(gen, 96, seed=3)
    occ = np.count_nonzero(ev["mask"] > 0, axis=1)
    assert occ.max() <= 32
    buckets = {pick_bucket(int(o), (4, 8, 16, 32)) for o in occ}
    assert len(buckets) >= 3             # real spread, not one tier
    # deterministic per seed
    ev2 = generate(gen, 96, seed=3)
    assert (ev["feats"] == ev2["feats"]).all()


def test_belle2_occupancy_default_unchanged():
    gen = current_detector()
    a = generate(gen, 8, seed=5)
    b = generate(dataclasses.replace(gen, occupancy=None), 8, seed=5)
    assert (a["feats"] == b["feats"]).all()


def test_belle2_occupancy_invalid_profile_raises():
    gen = dataclasses.replace(current_detector(), occupancy=((8, -1.0),))
    with pytest.raises(ValueError):
        generate(gen, 2, seed=0)


# ---------------------------------------------------- tuning batch keys ----
def test_gravnet_key_batch_dimension():
    from repro.tuning import gravnet_key
    k1 = gravnet_key(32, 4, 22, 8, "float32", "xla")
    kb = gravnet_key(32, 4, 22, 8, "float32", "xla", batch=8)
    assert k1.shape == (32, 4, 22, 8)          # legacy shape preserved
    assert kb.shape == (8, 32, 4, 22, 8)
    assert k1 != kb
    from repro.tuning.cache import KernelKey
    assert KernelKey.decode(kb.encode()) == kb


def test_kernel_opt_batch_folds_into_dense_rows(trigger_setup):
    cfg, gen, graph, req, events, feeds = trigger_setup
    from repro.core.passes.kernel_opt import fused_dense_shape
    from repro.tuning import graph_kernel_problems
    # legacy (unfused-GravNet) executable: gravnet keys carry the batch
    pipe = deploy(graph, req, batch=8, fuse_gravnet_block=False)
    for op in pipe.graph:
        if op.template == "fused_dense":
            rows, _, _ = fused_dense_shape(op, cfg.n_hits, 8)
            assert rows == 8 * cfg.n_hits
    keys = graph_kernel_problems(pipe.graph, n_rows=cfg.n_hits,
                                 backend="xla", batch=8)
    gk = [k for k in keys if k.kernel == "gravnet"]
    assert gk and all(k.shape[0] == 8 for k in gk)
    # default (fused) executable: the megakernel keys carry it instead
    pipe_f = deploy(graph, req, batch=8)
    keys_f = graph_kernel_problems(pipe_f.graph, n_rows=cfg.n_hits,
                                   backend="xla", batch=8)
    bk = [k for k in keys_f if k.kernel == "gravnet_block"]
    assert bk and all(k.shape[0] == 8 for k in bk)
    assert not any(k.kernel == "gravnet" for k in keys_f)


def test_warmup_replays_batched_gravnet_key():
    from repro.tuning import TuningCache, gravnet_key, warm_from_cache
    cache = TuningCache()
    cache.put(gravnet_key(16, 4, 6, 4, "float32", "xla", batch=3),
              {"bm": 16})
    assert warm_from_cache(cache, backend="xla") == 1


def test_tune_gravnet_batched_records_batched_key(tmp_path):
    from repro.tuning import TuningCache, gravnet_key, tune_gravnet
    cache = TuningCache(tmp_path / "c.json")
    cfg = tune_gravnet(16, 4, 6, 4, batch=3, backend="xla", cache=cache,
                       iters=1)
    assert "bm" in cfg
    assert gravnet_key(16, 4, 6, 4, "float32", "xla", batch=3) in cache
