"""Docs link integrity: the CI docs job runs ``tools/check_docs.py``;
this keeps the same invariant enforceable locally via tier-1."""
import importlib.util
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _load_checker():
    spec = importlib.util.spec_from_file_location(
        "check_docs", REPO / "tools" / "check_docs.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_github_slugification():
    m = _load_checker()
    assert m.github_slug("Autotuning & performance gates") \
        == "autotuning--performance-gates"
    assert m.github_slug("The design flow: from model to deployed "
                         "pipeline") \
        == "the-design-flow-from-model-to-deployed-pipeline"
    assert m.github_slug("Reading `ServingStats.summary()`") \
        == "reading-servingstatssummary"


def test_anchor_extraction_skips_code_fences():
    m = _load_checker()
    text = "# Real\n```\n# not a heading\n```\n## Also Real\n"
    assert m.anchors_of(text) == {"real", "also-real"}


def test_repo_docs_links_resolve(capsys):
    m = _load_checker()
    rc = m.main()
    out = capsys.readouterr()
    assert rc == 0, f"broken docs links:\n{out.err}"


def test_checker_flags_broken_links(tmp_path, monkeypatch):
    m = _load_checker()
    (tmp_path / "docs").mkdir()
    (tmp_path / "README.md").write_text(
        "# T\n[gone](docs/missing.md) [bad](#no-such-anchor)\n")
    monkeypatch.setattr(m, "REPO", tmp_path)
    monkeypatch.setattr(m, "DOC_FILES", ["README.md"])
    assert m.main() == 1
