"""GNN model tests: message-passing oracle checks, equivariance
properties, sampler-vs-full consistency, triplet machinery."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_support import given, settings, st

from repro.data.graphs import (build_triplets, geometric_graph,
                               powerlaw_graph)
from repro.models.gnn import (common as C, dimenet, gatedgcn, graphsage,
                              nequip, sph)


def _graph(seed=0, n=24, e=60, d=16):
    rng = np.random.default_rng(seed)
    return {
        "nodes": jnp.asarray(rng.normal(size=(n, d)), jnp.float32),
        "edge_index": jnp.asarray(rng.integers(0, n, size=(2, e)),
                                  jnp.int32),
        "node_mask": jnp.ones(n, jnp.float32),
        "edge_mask": jnp.ones(e, jnp.float32),
        "labels": jnp.asarray(rng.integers(0, 5, size=n), jnp.int32),
    }


# ------------------------------------------------------- segment ops vs dense
def test_scatter_ops_match_dense_adjacency():
    g = _graph()
    n = 24
    src, dst = np.asarray(g["edge_index"])
    a = np.zeros((n, n), np.float32)
    for s, d in zip(src, dst):
        a[d, s] += 1.0
    x = np.asarray(g["nodes"])
    want = a @ x
    got = C.scatter_sum(jnp.take(g["nodes"], g["edge_index"][0], axis=0),
                        g["edge_index"], n)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5,
                               atol=1e-5)


def test_scatter_softmax_normalizes():
    g = _graph()
    scores = jnp.asarray(np.random.default_rng(0).normal(size=60),
                         jnp.float32)
    w = C.scatter_softmax(scores, g["edge_index"], 24, g["edge_mask"])
    sums = jax.ops.segment_sum(w, g["edge_index"][1], num_segments=24)
    nz = np.asarray(sums) > 0
    np.testing.assert_allclose(np.asarray(sums)[nz], 1.0, rtol=1e-5)


# ----------------------------------------------------------------- models ----
def test_gatedgcn_isolated_nodes_stable():
    g = _graph()
    g["edge_mask"] = jnp.zeros_like(g["edge_mask"])  # no edges at all
    cfg = gatedgcn.GatedGCNConfig(n_layers=2, d_hidden=16, d_in=16,
                                  n_classes=5)
    p = gatedgcn.init(jax.random.PRNGKey(0), cfg)
    logits = gatedgcn.apply(p, g, cfg)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_graphsage_sampled_approximates_full():
    """On a full-fanout sampler, sampled and full-graph GraphSAGE agree
    in distribution (same parameters; spot-check finiteness + shapes)."""
    from repro.data.graphs import NeighborSampler
    gg = powerlaw_graph(64, 512, d_feat=8, n_classes=3, seed=1)
    cfg = graphsage.GraphSAGEConfig(n_layers=2, d_hidden=16, d_in=8,
                                    n_classes=3, sample_sizes=(4, 3))
    p = graphsage.init(jax.random.PRNGKey(1), cfg)
    s = NeighborSampler(gg["edge_index"], 64, gg["nodes"], gg["labels"],
                        fanouts=cfg.sample_sizes, seed=0)
    batch = jax.tree_util.tree_map(jnp.asarray, s.sample(np.arange(6)))
    logits = graphsage.apply_sampled(p, batch, cfg)
    assert logits.shape == (6, 3)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_dimenet_triplet_angle_invariance():
    """DimeNet energies are invariant under global rotation+translation
    (distances/angles only)."""
    gg = geometric_graph(20, cutoff=1.8, box=3.0, n_species=4, seed=3,
                         max_edges=96)
    trips, tm = build_triplets(gg["edge_index"], gg["edge_mask"],
                               max_triplets=256)
    g = {k: jnp.asarray(v) for k, v in gg.items()}
    g["triplets"], g["triplet_mask"] = jnp.asarray(trips), jnp.asarray(tm)
    cfg = dimenet.DimeNetConfig(n_blocks=2, d_hidden=16, n_bilinear=4)
    p = dimenet.init(jax.random.PRNGKey(2), cfg)
    e0, _ = dimenet.apply(p, g, cfg)
    R = jnp.asarray(sph._random_rotation(np.random.default_rng(4)),
                    jnp.float32)
    g2 = dict(g)
    g2["positions"] = g["positions"] @ R.T + 2.5
    e1, _ = dimenet.apply(p, g2, cfg)
    np.testing.assert_allclose(float(e0), float(e1), rtol=1e-4)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10000))
def test_nequip_equivariance_property(seed):
    cfg = nequip.NequIPConfig(n_layers=2, mult=4, n_rbf=4)
    gg = geometric_graph(12, cutoff=1.8, box=2.5, n_species=4, seed=seed,
                         max_edges=64)
    g = {k: jnp.asarray(v) for k, v in gg.items()}
    p = nequip.init(jax.random.PRNGKey(seed % 100), cfg)
    e0, _ = nequip.apply(p, g, cfg)
    f0 = nequip.forces(p, g, cfg)
    R = jnp.asarray(sph._random_rotation(np.random.default_rng(seed + 1)),
                    jnp.float32)
    g2 = dict(g)
    g2["positions"] = g["positions"] @ R.T + 1.0
    e1, _ = nequip.apply(p, g2, cfg)
    f1 = nequip.forces(p, g2, cfg)
    assert abs(float(e0 - e1)) < 1e-4 * max(1.0, abs(float(e0)))
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f0) @ np.asarray(R).T,
                               rtol=1e-3, atol=1e-4)


def test_intertwiner_uniqueness_and_orthogonality():
    for (l1, l2, l3) in [(1, 1, 0), (1, 1, 1), (2, 1, 2), (2, 2, 2)]:
        w = sph.intertwiner(l1, l2, l3)
        assert w is not None
        np.testing.assert_allclose(np.linalg.norm(w), 1.0, rtol=1e-10)
    assert sph.intertwiner(0, 0, 2) is None  # triangle violation


def test_nequip_path_enumeration():
    cfg = nequip.NequIPConfig(l_max=2)
    irreps, paths = nequip._paths(cfg)
    assert len(irreps) == 3
    # parity rule: (1,-) ⊗ Y1(-) -> only even-parity targets
    for (l1, p1, l2, l3, p3) in paths:
        assert p1 * ((-1) ** l2) == p3
        assert abs(l1 - l2) <= l3 <= l1 + l2
    # exactly 11 admissible (l1,p1)⊗Y_l2→(l3,p3) paths at l_max=2 with
    # hidden irreps 0e/1o/2e (e.g. (1,−)⊗Y1→(1,−) is parity-forbidden)
    assert len(paths) == 11


def test_gatedgcn_transform_then_gather_equivalent():
    """Beyond-paper optimization is exactly semantics-preserving."""
    g = _graph(seed=5)
    cfg_a = gatedgcn.GatedGCNConfig(n_layers=3, d_hidden=16, d_in=16,
                                    n_classes=5)
    cfg_b = gatedgcn.GatedGCNConfig(n_layers=3, d_hidden=16, d_in=16,
                                    n_classes=5,
                                    transform_then_gather=True)
    p = gatedgcn.init(jax.random.PRNGKey(5), cfg_a)
    la = gatedgcn.apply(p, g, cfg_a)
    lb = gatedgcn.apply(p, g, cfg_b)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                               rtol=1e-4, atol=1e-5)
