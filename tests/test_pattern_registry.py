"""Non-regression contract for the pattern-keyed pass refactor.

The deploy passes dispatch on registered op patterns
(``repro.core.op_registry``) instead of hard-coding CaloClusterNet's
shape. The contract of that refactor is that CaloClusterNet's deploy
path did not move: ``tests/golden/ccn_flow.json`` pins the pass-emitted
graphs (op names, templates, targets, segments, precisions, binding
knobs) and the tuning-cache keys for every deploy mode, and
``tests/golden/ccn_flow_outputs.npz`` pins the fused f32 and calibrated
int8 outputs byte-for-byte. The committed fixtures were generated with
the *pre-refactor* passes, so regenerating them in-process and
comparing proves the pattern-keyed passes reproduce the legacy flow
bit-for-bit.

Regenerate (after an *intentional* flow change) with:

    REPRO_REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest \
        tests/test_pattern_registry.py -q
"""
import json
import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import caloclusternet as ccn
from repro.core.graph_ir import Graph, Operator
from repro.core.passes.parallelize import Requirements
from repro.core.pipeline import deploy
from repro.tuning.autotune import graph_kernel_problems

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"
FLOW_JSON = GOLDEN_DIR / "ccn_flow.json"
OUT_NPZ = GOLDEN_DIR / "ccn_flow_outputs.npz"

CFG = ccn.CCNConfig(n_hits=32)

# every deploy mode whose emitted graph + tuning keys are pinned:
# (precision policy, fuse_gravnet_block, fuse_int8, needs calibration)
MODES = {
    "fp_fused": ("fp", True, True, False),
    "fp_unfused": ("fp", False, True, False),
    "mixed_fused": ("mixed", True, True, True),
    "mixed_unfused": ("mixed", True, False, True),
}


def _feeds():
    rng = np.random.default_rng(0)
    feats = jnp.asarray(rng.normal(size=(4, CFG.n_hits, CFG.d_in)),
                        jnp.float32)
    mask = jnp.asarray(rng.uniform(size=(4, CFG.n_hits)) < 0.7,
                       jnp.float32)
    return {"hits": feats, "mask": mask}


def _jsonable(v):
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return None  # arrays / configs: identity is pinned via params+outputs


def _graph_record(g: Graph) -> list[dict]:
    return [{
        "name": op.name,
        "op_type": op.op_type,
        "inputs": list(op.inputs),
        "out_dim": op.out_dim,
        "target": op.target,
        "segment": op.segment,
        "precision": op.precision,
        "template": op.template,
        "attrs": {k: _jsonable(v) for k, v in sorted(op.attrs.items())},
        "attrs_opt": {k: _jsonable(v)
                      for k, v in sorted(op.attrs_opt.items())},
    } for op in g]


def _key_record(g: Graph) -> dict:
    return {f"{backend}/batch{batch}": [
        k.encode() for k in graph_kernel_problems(
            g, n_rows=CFG.n_hits, backend=backend, batch=batch)]
        for backend in ("xla", "pallas") for batch in (1, 8)}


def _deploy(mode: str):
    policy, fuse_block, fuse_int8, calib = MODES[mode]
    req = Requirements(design_point=3, platform="cpu",
                       precision_policy=policy, n_hits=CFG.n_hits,
                       target_throughput=1e4)
    params = ccn.init(jax.random.PRNGKey(0), CFG)
    g = ccn.to_graph(params, CFG)
    feeds = _feeds()
    return deploy(g, req,
                  calibration_feeds=feeds if calib else None,
                  fuse_gravnet_block=fuse_block,
                  fuse_int8=fuse_int8), feeds


def _flatten_out(prefix: str, out: dict, into: dict):
    for k, v in out.items():
        if isinstance(v, dict):
            _flatten_out(f"{prefix}.{k}", v, into)
        else:
            into[f"{prefix}.{k}"] = np.asarray(v)


def _capture():
    flow = {}
    arrays: dict[str, np.ndarray] = {}
    for mode in MODES:
        pipe, feeds = _deploy(mode)
        flow[mode] = {"graph": _graph_record(pipe.graph),
                      "tuning_keys": _key_record(pipe.graph)}
        if mode in ("fp_fused", "mixed_fused"):
            _flatten_out(mode, pipe(feeds), arrays)
    return flow, arrays


@pytest.fixture(scope="module")
def golden():
    if os.environ.get("REPRO_REGEN_GOLDEN") == "1":
        GOLDEN_DIR.mkdir(parents=True, exist_ok=True)
        flow, arrays = _capture()
        with open(FLOW_JSON, "w") as f:
            json.dump(flow, f, indent=1, sort_keys=True)
            f.write("\n")
        np.savez(OUT_NPZ, **arrays)
    if not (FLOW_JSON.exists() and OUT_NPZ.exists()):
        pytest.fail(f"missing golden fixtures under {GOLDEN_DIR}; "
                    "regenerate with REPRO_REGEN_GOLDEN=1")
    with open(FLOW_JSON) as f:
        flow = json.load(f)
    with np.load(OUT_NPZ) as z:
        arrays = {k: z[k] for k in z.files}
    return flow, arrays


@pytest.fixture(scope="module")
def fresh():
    flow, arrays = _capture()
    # normalize through the same JSON round-trip the fixture took
    return json.loads(json.dumps(flow)), arrays


@pytest.mark.parametrize("mode", sorted(MODES))
def test_graph_matches_golden(mode, golden, fresh):
    """Pass-emitted graphs (names, templates, targets, segments,
    precisions, binding knobs) are identical to the pre-refactor flow."""
    want = golden[0][mode]["graph"]
    got = fresh[0][mode]["graph"]
    assert [o["name"] for o in got] == [o["name"] for o in want]
    for w, g in zip(want, got):
        assert g == w, f"{mode}: op {w['name']} diverged"


@pytest.mark.parametrize("mode", sorted(MODES))
def test_tuning_keys_match_golden(mode, golden, fresh):
    """Tuning-cache keys per backend/micro-batch are pinned: a renamed
    or re-shaped key would silently orphan every cached config."""
    assert fresh[0][mode]["tuning_keys"] == golden[0][mode]["tuning_keys"]


def test_outputs_bitwise_identical(golden, fresh):
    """Fused f32 and calibrated int8 deployed outputs reproduce the
    pre-refactor bytes exactly."""
    want, got = golden[1], fresh[1]
    assert set(got) == set(want)
    for name in sorted(want):
        np.testing.assert_array_equal(got[name], want[name],
                                      err_msg=name)


# ----------------------------------------------- unknown-op diagnostics ----
def test_deploy_rejects_unknown_op_with_actionable_error():
    """A graph holding an op no pass recognizes fails fast with the op
    type and node name in the message, not a deep KeyError."""
    g = Graph()
    g.add(Operator(name="hits", op_type="input", out_dim=4,
                   attrs={"feature": "hits"}))
    g.add(Operator(name="mystery", op_type="hyperbolic_conv",
                   inputs=["hits"], out_dim=4))
    g.add(Operator(name="out", op_type="output", inputs=["mystery"],
                   attrs={"head_names": ["y"]}, out_dim=4))
    req = Requirements(design_point=3, platform="cpu",
                       precision_policy="fp", n_hits=8,
                       target_throughput=1e3)
    with pytest.raises(Exception) as exc:
        deploy(g, req)
    msg = str(exc.value)
    assert "hyperbolic_conv" in msg and "mystery" in msg
