"""Heterogeneous-model serving: one ShardedTriggerService dispatching
per-route to *different deployed pipelines* (the CCN trigger next to an
edge-based GNN) behind a single global in-order release stage."""
import numpy as np
import jax
import pytest

from repro.core import caloclusternet as ccn
from repro.core.graph_ir import export_graph
from repro.core.passes.parallelize import Requirements
from repro.core.pipeline import deploy
from repro.models.gnn import gatedgcn
from repro.serving import ShardedTriggerService

jax.config.update("jax_platform_name", "cpu")

N, E = 32, 128
CCN_CFG = ccn.CCNConfig(n_hits=N, n_crystals=576)
GGCN_CFG = gatedgcn.GatedGCNConfig(n_layers=2, d_hidden=16, d_in=8,
                                   d_edge_in=4, n_classes=4)


def _req():
    return Requirements(design_point=3, platform="cpu",
                        precision_policy="fp", n_hits=N,
                        target_throughput=1e4)


@pytest.fixture(scope="module")
def pipes():
    ccn_params = ccn.init(jax.random.PRNGKey(0), CCN_CFG)
    ggcn_params = gatedgcn.init(jax.random.PRNGKey(1), GGCN_CFG)
    ccn_pipe = deploy(export_graph("caloclusternet", ccn_params, CCN_CFG),
                      _req())
    ggcn_pipe = deploy(export_graph("gatedgcn", ggcn_params, GGCN_CFG),
                       _req())
    return ccn_pipe, ggcn_pipe


def _ccn_events(n, *, seed=0):
    rng = np.random.default_rng(seed)
    return [{"hits": rng.normal(size=(N, CCN_CFG.d_in)).astype(np.float32),
             "mask": (rng.uniform(size=(N,)) < 0.8).astype(np.float32)}
            for _ in range(n)]


def _ggcn_events(n, *, seed=1):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        out.append({
            "nodes": rng.normal(size=(N, GGCN_CFG.d_in)).astype(np.float32),
            "edge_index": rng.integers(0, N, size=(2, E)).astype(np.int32),
            "edges": rng.normal(
                size=(E, GGCN_CFG.d_edge_in)).astype(np.float32),
            "node_mask": (rng.uniform(size=(N,)) < 0.8).astype(np.float32),
            "edge_mask": (rng.uniform(size=(E,)) < 0.7).astype(np.float32),
        })
    return out


def _stack(events):
    return {k: np.stack([e[k] for e in events]) for k in events[0]}


def test_routed_service_serves_heterogeneous_models(pipes):
    ccn_pipe, ggcn_pipe = pipes
    svc = ShardedTriggerService(
        routes={"ccn": ccn_pipe, "gatedgcn": ggcn_pipe},
        microbatch=4, window_s=2e-3, devices=None)
    n_per = 10
    ccn_ev, ggcn_ev = _ccn_events(n_per), _ggcn_events(n_per)
    futs = []
    for i in range(n_per):        # interleave the two model streams
        futs.append(("ccn", svc.submit(ccn_ev[i], route="ccn")))
        futs.append(("gatedgcn",
                     svc.submit(ggcn_ev[i], route="gatedgcn")))
    results = [(r, f.result(timeout=120)) for r, f in futs]
    svc.drain()

    # each route's result i equals the direct pipeline on event i
    direct_ccn = ccn_pipe(_stack(ccn_ev))
    direct_ggcn = ggcn_pipe(_stack(ggcn_ev))
    for i in range(n_per):
        route, out = results[2 * i]
        assert route == "ccn" and set(out) >= {"beta", "coords", "cps"}
        np.testing.assert_allclose(np.asarray(out["coords"]),
                                   np.asarray(direct_ccn["coords"][i]),
                                   rtol=1e-5, atol=1e-5)
        route, out = results[2 * i + 1]
        assert route == "gatedgcn" and set(out) == {"logits"}
        np.testing.assert_allclose(np.asarray(out["logits"]),
                                   np.asarray(direct_ggcn["logits"][i]),
                                   rtol=1e-5, atol=1e-5)

    summary = {row["route"]: row for row in svc.route_summary()}
    assert set(summary) == {"ccn", "gatedgcn"}
    for name in summary:
        assert summary[name]["submitted"] == n_per
        assert summary[name]["completed"] == n_per
    assert svc.stats.completed == 2 * n_per
    svc.close()


def test_single_route_needs_no_route_argument(pipes):
    _, ggcn_pipe = pipes
    svc = ShardedTriggerService(routes={"gatedgcn": ggcn_pipe},
                                microbatch=4, window_s=2e-3, devices=None)
    ev = _ggcn_events(3, seed=7)
    outs = [svc.submit(e).result(timeout=120) for e in ev]
    svc.drain()
    direct = ggcn_pipe(_stack(ev))
    for i, out in enumerate(outs):
        np.testing.assert_allclose(np.asarray(out["logits"]),
                                   np.asarray(direct["logits"][i]),
                                   rtol=1e-5, atol=1e-5)
    svc.close()


def test_route_argument_validation():
    def echo(feeds):
        return {"y": feeds["x"]}

    svc = ShardedTriggerService(routes={"a": echo, "b": echo},
                                microbatch=2, window_s=1e-3, devices=None)
    ev = {"x": np.zeros((4,), np.float32)}
    with pytest.raises(ValueError, match="route= is required"):
        svc.submit(ev)
    with pytest.raises(KeyError, match="unknown route 'c'"):
        svc.submit(ev, route="c")
    assert svc.submit(ev, route="a").result(timeout=30)["y"].shape == (4,)
    svc.drain()
    svc.close()

    plain = ShardedTriggerService(echo, microbatch=2, window_s=1e-3,
                                  devices=None)
    with pytest.raises(ValueError, match="no routes"):
        plain.submit(ev, route="a")
    plain.close()

    with pytest.raises(ValueError, match="exactly one of"):
        ShardedTriggerService(echo, routes={"a": echo}, microbatch=2)
    with pytest.raises(ValueError, match="at least one route"):
        ShardedTriggerService(routes={}, microbatch=2)
