"""Fault-tolerant serving: deterministic fault injection, breaker
state machine, health-aware routing, failover re-dispatch, load
shedding, and shutdown-under-load — the chaos suite.

CI runs this file under several ``REPRO_CHAOS_SEED`` values (the
``chaos`` job's seed matrix), so the invariants below hold against
more than one deterministic failure schedule, not one lucky seed."""
import os
import threading
import time

import numpy as np
import pytest

from repro.serving import (BreakerConfig, FaultPlan, FaultSpec,
                           InjectedFault, ReplicaHealth, Router,
                           ShardedTriggerService, ShedError,
                           pick_bucket, pick_bucket_sorted)

SEED = int(os.environ.get("REPRO_CHAOS_SEED", "0"))


def _echo(feeds):
    return {"y": feeds["x"]}


def _echo_slow(delay):
    def infer(feeds):
        time.sleep(delay)
        return {"y": feeds["x"]}
    return infer


def _ev(i):
    return {"x": np.float32(i)}


def _svc(infer, **kw):
    kw.setdefault("microbatch", 1)
    kw.setdefault("window_s", 1e-3)
    kw.setdefault("devices", None)
    return ShardedTriggerService(infer, **kw)


# ------------------------------------------------------ FaultPlan spec ----
def test_fault_plan_parse_roundtrip():
    plan = FaultPlan.parse(
        "fail@3;stall:p=0.05,s=0.02;wedge:replica=1+2;corrupt:p=0.01;"
        "kill@0,7;seed=9")
    assert plan.seed == 9
    kinds = [s.kind for s in plan.specs]
    assert kinds == ["fail", "stall", "wedge", "corrupt", "kill"]
    assert plan.specs[0].at == (3,)
    assert plan.specs[1].rate == 0.05
    assert plan.specs[1].duration_s == 0.02
    assert plan.specs[2].replicas == (1, 2)
    assert plan.specs[4].at == (0, 7)
    # describe() re-parses to the same plan
    again = FaultPlan.parse(plan.describe())
    assert again.seed == plan.seed
    assert [s.describe() for s in again.specs] \
        == [s.describe() for s in plan.specs]


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("explode")
    with pytest.raises(ValueError, match="rate"):
        FaultSpec("fail", rate=1.5)
    with pytest.raises(ValueError, match="index-triggered"):
        FaultSpec("kill", rate=0.1)
    with pytest.raises(ValueError, match="unknown fault-spec key"):
        FaultPlan.parse("fail:q=0.1")


def test_injector_replay_is_bit_identical():
    """Same (seed, replica) -> the same decision log; different
    replicas of one plan draw independent streams."""
    spec = "fail:p=0.3;stall:p=0.2,s=0.0;corrupt:p=0.1"
    a = FaultPlan.parse(spec, seed=SEED).for_replica(0)
    b = FaultPlan.parse(spec, seed=SEED).for_replica(0)
    fa, fb = a.wrap(_echo), b.wrap(_echo)
    for i in range(200):
        for f in (fa, fb):
            try:
                f(_ev(i))
            except InjectedFault:
                pass
    assert a.log == b.log and len(a.log) > 0
    assert a.counts == b.counts
    other = FaultPlan.parse(spec, seed=SEED).for_replica(1)
    fo = other.wrap(_echo)
    for i in range(200):
        try:
            fo(_ev(i))
        except InjectedFault:
            pass
    assert other.log != a.log


def test_fail_at_exact_batch_index():
    """``fail@1`` with a serialized lane fails exactly batch 1."""
    plan = FaultPlan.parse("fail@1", seed=SEED)
    svc = _svc(_echo, n_replicas=1, inflight=1, faults=plan)
    outcomes = []
    for i in range(3):   # one event per batch (microbatch=1)
        f = svc.submit(_ev(i))
        try:
            f.result(timeout=30)
            outcomes.append("ok")
        except InjectedFault:
            outcomes.append("fail")
    svc.drain()
    assert outcomes == ["ok", "fail", "ok"]
    assert plan.counts()["fail"] == 1
    svc.close()


def test_stall_injects_latency():
    plan = FaultPlan.parse("stall@0:s=0.25", seed=SEED)
    svc = _svc(_echo, n_replicas=1, inflight=1, faults=plan)
    t0 = time.perf_counter()
    assert float(svc.submit(_ev(1)).result(timeout=30)["y"]) == 1.0
    assert time.perf_counter() - t0 >= 0.25
    svc.drain()
    svc.close()


def test_corrupt_poisons_output():
    plan = FaultPlan.parse("corrupt@0", seed=SEED)
    svc = _svc(lambda feeds: {"y": feeds["x"],
                              "trig": feeds["x"] > 100.0},
               n_replicas=1, inflight=1, faults=plan)
    bad = svc.submit(_ev(1)).result(timeout=30)
    good = svc.submit(_ev(1)).result(timeout=30)
    svc.drain()
    assert np.isnan(np.asarray(bad["y"])).all()
    assert np.asarray(bad["trig"]).all()     # bools poisoned to True
    assert float(good["y"]) == 1.0           # only batch 0 corrupted
    svc.close()


def test_wedge_blocks_until_released():
    plan = FaultPlan.parse("wedge@0", seed=SEED)
    svc = _svc(_echo, n_replicas=1, faults=plan)
    fut = svc.submit(_ev(7))
    for _ in range(200):                     # wait for the hang
        if plan.wedged:
            break
        time.sleep(0.01)
    assert plan.wedged == 1
    assert not fut.done()
    # the wedge names the stuck lane in the drain diagnostics
    with pytest.raises(TimeoutError, match=r"replica 0.*in_flight"):
        svc.drain(timeout=0.3)
    plan.release()
    assert float(fut.result(timeout=30)["y"]) == 7.0
    svc.drain()
    assert plan.wedged == 0
    svc.close()


def test_wedge_duration_cap_self_releases():
    plan = FaultPlan.parse("wedge@0:s=0.1", seed=SEED)
    svc = _svc(_echo, n_replicas=1, faults=plan)
    t0 = time.perf_counter()
    assert float(svc.submit(_ev(3)).result(timeout=30)["y"]) == 3.0
    assert time.perf_counter() - t0 >= 0.1
    assert not plan.released                 # no manual release needed
    svc.drain()
    svc.close()


# ------------------------------------------------- breaker state machine ----
class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_breaker_trips_probes_and_closes():
    clk = _Clock()
    h = ReplicaHealth(0, BreakerConfig(fail_threshold=3, open_s=0.25),
                      clock=clk)
    assert h.state() == "closed" and h.available()
    h.record_failure()
    h.record_failure()
    assert h.state() == "closed"             # below the threshold
    h.record_failure()
    assert h.state() == "open" and not h.available()
    assert h.trips == 1
    clk.t = 0.3                              # cool-down expires
    assert h.state() == "half_open"
    assert h.available()                     # one probe token
    h.note_dispatch()
    assert not h.available()                 # token consumed
    h.record_success()
    assert h.state() == "closed" and h.available()
    assert h.snapshot()["consecutive_failures"] == 0


def test_breaker_reopen_backs_off_exponentially():
    clk = _Clock()
    cfg = BreakerConfig(fail_threshold=1, open_s=0.25, backoff=2.0,
                        max_open_s=0.8)
    h = ReplicaHealth(0, cfg, clock=clk)
    h.record_failure()                       # trip: cooldown 0.25
    assert h.snapshot()["cooldown_s"] == pytest.approx(0.25)
    clk.t = 0.3
    assert h.state() == "half_open"
    h.record_failure()                       # probe fails: 0.5
    assert h.state() == "open"
    assert h.snapshot()["cooldown_s"] == pytest.approx(0.5)
    clk.t = 0.9
    assert h.state() == "half_open"
    h.record_failure()                       # 1.0 capped to 0.8
    assert h.snapshot()["cooldown_s"] == pytest.approx(0.8)
    assert h.trips == 3


def test_breaker_ewma_trip_without_consecutive_failures():
    clk = _Clock()
    cfg = BreakerConfig(fail_threshold=100, ewma_alpha=0.5,
                        ewma_threshold=0.5, min_samples=4)
    h = ReplicaHealth(0, cfg, clock=clk)
    for _ in range(3):                       # F S F S ... rate ~0.5
        h.record_failure()
        h.record_success()
    h.record_failure()
    assert h.state() == "open"               # EWMA tripped it
    assert h.snapshot()["consecutive_failures"] < 100


# ----------------------------------------------------- health-aware pick ----
class _FakeReplica:
    def __init__(self, replica_id, load=0):
        self.replica_id = replica_id
        self._load = load

    def load(self):
        return self._load


def _tripped(rid, clk):
    h = ReplicaHealth(rid, BreakerConfig(fail_threshold=1), clock=clk)
    h.record_failure()
    return h


def test_router_skips_open_lane():
    clk = _Clock()
    reps = [_FakeReplica(0), _FakeReplica(1)]
    healths = {0: ReplicaHealth(0, BreakerConfig(), clock=clk),
               1: _tripped(1, clk)}
    for policy in ("round_robin", "least_loaded"):
        r = Router(reps, policy, healths=healths)
        assert [r.pick(s).replica_id for s in range(6)] == [0] * 6


def test_router_least_bad_when_all_open():
    clk = _Clock()
    h0, h1 = _tripped(0, clk), _tripped(1, clk)
    h1.record_failure()                      # lane 1 is sicker
    r = Router([_FakeReplica(0), _FakeReplica(1)], "round_robin",
               healths={0: h0, 1: h1})
    # every breaker open: the stream keeps flowing to the least-bad lane
    assert [r.pick(s).replica_id for s in range(4)] == [0] * 4


def test_router_without_healths_unchanged():
    reps = [_FakeReplica(0, load=5), _FakeReplica(1, load=1)]
    assert Router(reps, "round_robin").pick(3).replica_id == 1
    assert Router(reps, "least_loaded").pick(0).replica_id == 1


# ------------------------------------------------- failover re-dispatch ----
def test_failover_rescues_dead_replica_traffic():
    plan = FaultPlan.parse("fail:p=1.0,replica=1", seed=SEED)
    svc = _svc(_echo, n_replicas=2, microbatch=2, faults=plan,
               breaker=True, max_retries=2)
    futs = [svc.submit(_ev(i)) for i in range(24)]
    results = [f.result(timeout=60) for f in futs]   # nothing raises
    svc.drain()
    for i, r in enumerate(results):
        assert float(r["y"]) == float(i)
    s = svc.stats.summary()
    assert s["retried"] > 0 and s["failed_over"] > 0
    assert s["retried"] == s["failed_over"]
    ft = svc.fault_tolerance_summary()
    assert ft["breaker"]["enabled"]
    assert svc.healths[1].trips >= 1
    svc.close()


def test_retry_budget_bounds_all_dead_fleet():
    """Every lane dead: retries stay bounded, every future resolves
    with the injected error instead of ping-ponging forever."""
    plan = FaultPlan.parse("fail:p=1.0", seed=SEED)
    svc = _svc(_echo, n_replicas=2, faults=plan, breaker=True,
               max_retries=1)
    futs = [svc.submit(_ev(i)) for i in range(8)]
    for f in futs:
        assert isinstance(f.exception(timeout=60), InjectedFault)
    svc.drain()
    # each event dispatched at most 1 + max_retries times
    assert svc.stats.summary()["retried"] <= 8 * 1
    svc.close()


# -------------------------------------------------------- load shedding ----
def test_shed_on_full_queue():
    svc = _svc(_echo_slow(0.05), n_replicas=1, queue_depth=1,
               inflight=1, shed=True)
    futs = [svc.submit(_ev(i)) for i in range(12)]
    shed = ok = 0
    for f in futs:
        exc = f.exception(timeout=60)
        if exc is None:
            ok += 1
        else:
            assert isinstance(exc, ShedError)
            assert "queue full" in str(exc)
            shed += 1
    svc.drain()
    assert shed > 0 and ok > 0 and shed + ok == 12
    assert svc.stats.summary()["shed"] == shed
    svc.close()


def test_deadline_expired_event_is_shed():
    svc = _svc(_echo, n_replicas=1)
    late = svc.submit(_ev(0), deadline_s=0.0)
    on_time = svc.submit(_ev(1), deadline_s=30.0)
    assert isinstance(late.exception(timeout=30), ShedError)
    assert "deadline" in str(late.exception())
    assert float(on_time.result(timeout=30)["y"]) == 1.0
    svc.drain()
    assert svc.stats.summary()["shed"] == 1
    svc.close()


def test_healthy_path_counters_stay_zero():
    """No faults, no breaker: the new ledgers read zero and the
    original counters are untouched."""
    svc = _svc(_echo, n_replicas=2, microbatch=2)
    futs = [svc.submit(_ev(i)) for i in range(16)]
    for f in futs:
        f.result(timeout=30)
    svc.drain()
    s = svc.stats.summary()
    assert s["completed"] == 16
    assert s["shed"] == s["retried"] == s["failed_over"] == 0
    ft = svc.fault_tolerance_summary()
    assert not ft["breaker"]["enabled"]
    assert ft["breaker"]["states"] == {}
    svc.close()


def test_monitor_snapshot_carries_fault_counters():
    plan = FaultPlan.parse("fail:p=1.0,replica=1", seed=SEED)
    svc = _svc(_echo, n_replicas=2, faults=plan, breaker=True,
               max_retries=2, monitor=True)
    futs = [svc.submit(_ev(i)) for i in range(8)]
    for f in futs:
        f.result(timeout=60)
    svc.drain()
    snap = svc.monitor_snapshot()
    serving = snap["serving"]
    assert serving["retried"] > 0
    assert serving["max_retries"] == 2
    assert set(serving["breaker"]["states"]) == {"0", "1"}
    svc.close()


# -------------------------------------------------- shutdown under load ----
def _resolution_ledger(futs):
    counts = [0] * len(futs)
    lock = threading.Lock()

    def make(i):
        def cb(_f):
            with lock:
                counts[i] += 1
        return cb

    for i, f in enumerate(futs):
        f.add_done_callback(make(i))
    return counts


def test_close_with_hedged_batches_in_flight():
    svc = _svc(_echo_slow(0.08), n_replicas=2, microbatch=2,
               hedge_after_s=0.01)
    futs = [svc.submit(_ev(i)) for i in range(12)]
    counts = _resolution_ledger(futs)
    time.sleep(0.05)                          # hedges are now in flight
    svc.close()
    assert all(f.done() for f in futs)
    assert counts == [1] * 12                 # exactly-once resolution


def test_hedge_pool_shutdown_race_fails_batch_cleanly():
    """The close()-vs-dispatch race: a hedge submit into a shut-down
    pool becomes a per-batch failure, never an unresolved future."""
    svc = _svc(_echo, n_replicas=1, hedge_after_s=0.05)
    svc.replicas[0]._hedge_pool.shutdown(wait=False)
    fut = svc.submit(_ev(0))
    exc = fut.exception(timeout=30)
    assert isinstance(exc, RuntimeError)
    assert "hedge pool shut down" in str(exc)
    svc.close()


@pytest.mark.parametrize("loop", ["deadline", "streaming"])
def test_batcher_killed_mid_batch(loop):
    """``kill@0`` murders the batcher/launcher thread at its first
    checkpoint: the collected batch fails exactly once, queued events
    resolve at close, nothing deadlocks."""
    plan = FaultPlan.parse("kill@0", seed=SEED)
    svc = _svc(_echo, n_replicas=1, microbatch=4, faults=plan,
               loop=loop)
    futs = [svc.submit(_ev(i)) for i in range(4)]
    counts = _resolution_ledger(futs)
    assert isinstance(futs[0].exception(timeout=30), InjectedFault)
    svc.close()                               # resolves any stragglers
    assert all(f.done() for f in futs)
    assert counts == [1] * 4
    assert plan.counts()["kill"] == 1


def test_streaming_failover_rescues_dead_replica():
    plan = FaultPlan.parse("fail:p=1.0,replica=1", seed=SEED)
    svc = _svc(_echo, n_replicas=2, microbatch=2, loop="streaming",
               faults=plan, breaker=True, max_retries=2)
    futs = [svc.submit(_ev(i)) for i in range(24)]
    for i, f in enumerate(futs):
        assert float(f.result(timeout=60)["y"]) == float(i)
    svc.drain()
    assert svc.stats.summary()["failed_over"] > 0
    svc.close()


# ------------------------------------------------------- bucket helpers ----
def test_pick_bucket_sorted_matches_pick_bucket():
    buckets = (8, 32, 128)
    for occ in (0, 1, 8, 9, 32, 33, 128, 4096):
        assert pick_bucket_sorted(occ, buckets) \
            == pick_bucket(occ, buckets)


# ------------------------------------------------------ chaos invariant ----
def test_chaos_invariant_exactly_once_in_order():
    """The CI-gated invariant: 10% transient failures on every lane
    plus one hard-dead replica of four — every event resolves exactly
    once, releases in submission order, the overwhelming majority
    succeed via failover, and the service drains without deadlock."""
    n = 240
    plan = FaultPlan.parse("fail:p=0.1;fail:p=1.0,replica=3",
                           seed=SEED)
    svc = _svc(_echo_slow(0.002), n_replicas=4, microbatch=4,
               window_s=2e-3, faults=plan, breaker=True, max_retries=3)
    order, lock = [], threading.Lock()
    futs = []

    def track(i):
        def cb(_f):
            with lock:
                order.append(i)
        return cb

    for i in range(n):
        f = svc.submit(_ev(i))
        f.add_done_callback(track(i))
        futs.append(f)
    done = [f.exception(timeout=120) for f in futs]
    svc.drain(timeout=60)
    ok = sum(1 for e in done if e is None)
    assert len(order) == n                      # exactly-once
    assert order == sorted(order)               # submission order
    assert svc._releaser.released == n
    assert ok >= int(0.85 * n)                  # failover absorbs faults
    for e in done:
        assert e is None or isinstance(e, InjectedFault)
    assert svc.healths[3].trips >= 1            # dead lane tripped
    s = svc.stats.summary()
    assert s["completed"] == ok
    assert s["retried"] >= s["failed_over"] > 0
    svc.close()
