"""Transformer family tests: attention modes, decode equivalence, MoE
dispatch variants, prefill↔decode consistency, unrolled-vs-scan layers."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import transformer as tr


def _cfg(**kw):
    base = dict(name="t", n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                d_ff=96, vocab=97, block_q=8, loss_chunk=8,
                rope_theta=1e4, compute_dtype=jnp.float32)
    base.update(kw)
    return tr.TransformerConfig(**base)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    return cfg, params, toks


def test_attention_modes_agree(setup):
    cfg, params, toks = setup
    x0, _ = tr.forward(params, toks, cfg)
    for mode in ("full", "unrolled_tri"):
        cfg2 = _cfg(attn_mode=mode)
        x, _ = tr.forward(params, toks, cfg2)
        np.testing.assert_allclose(np.asarray(x), np.asarray(x0),
                                   rtol=2e-5, atol=2e-5)


def test_unrolled_layers_match_scan(setup):
    cfg, params, toks = setup
    x0, _ = tr.forward(params, toks, cfg)
    cfg2 = _cfg(unroll_layers=True)
    x, _ = tr.forward(params, toks, cfg2)
    np.testing.assert_allclose(np.asarray(x), np.asarray(x0),
                               rtol=2e-5, atol=2e-5)


def test_decode_matches_full_forward(setup):
    cfg, params, toks = setup
    B, S = toks.shape
    cache = tr.init_cache(cfg, B, S + 4, dtype=jnp.float32)
    logits = None
    for t in range(S):
        logits, cache = tr.decode_step(params, cache, toks[:, t:t + 1],
                                       cfg)
    xfull, _ = tr.forward(params, toks, cfg)
    ref = xfull[:, -1] @ params["lm_head"]
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_prefill_matches_decode_path(setup):
    cfg, params, toks = setup
    B, S = toks.shape
    logits_pf, cache_pf = tr.prefill(params, toks, cfg)
    # continue decoding one step from the prefilled cache; compare with
    # fully-incremental decode
    pad = 8
    cache_pf = jax.tree_util.tree_map(
        lambda a: (jnp.pad(a, [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)])
                   if a.ndim == 5 else a), cache_pf)
    nxt = jnp.full((B, 1), 3, jnp.int32)
    l1, _ = tr.decode_step(params, cache_pf, nxt, cfg)

    cache = tr.init_cache(cfg, B, S + pad, dtype=jnp.float32)
    for t in range(S):
        logits_inc, cache = tr.decode_step(params, cache, toks[:, t:t + 1],
                                           cfg)
    np.testing.assert_allclose(np.asarray(logits_pf),
                               np.asarray(logits_inc), rtol=1e-4,
                               atol=1e-4)
    l2, _ = tr.decode_step(params, cache, nxt, cfg)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-4,
                               atol=1e-4)


def test_moe_einsum_vs_scatter_dispatch():
    moe_e = tr.MoEConfig(n_experts=8, top_k=2, group_size=32,
                         capacity_factor=8.0, dispatch="einsum")
    moe_s = tr.MoEConfig(n_experts=8, top_k=2, group_size=32,
                         capacity_factor=8.0, dispatch="scatter")
    cfg_e = _cfg(moe=moe_e, n_layers=2, d_ff=48)
    cfg_s = _cfg(moe=moe_s, n_layers=2, d_ff=48)
    params = tr.init_params(jax.random.PRNGKey(2), cfg_e)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0, 97)
    x1, _ = tr.forward(params, toks, cfg_e)
    x2, _ = tr.forward(params, toks, cfg_s)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2), rtol=1e-5,
                               atol=1e-5)


def test_moe_vmap_groups_matches_map():
    moe_a = tr.MoEConfig(n_experts=4, top_k=2, group_size=8)
    moe_b = tr.MoEConfig(n_experts=4, top_k=2, group_size=8,
                         vmap_groups=True)
    cfg_a, cfg_b = _cfg(moe=moe_a, d_ff=32), _cfg(moe=moe_b, d_ff=32)
    params = tr.init_params(jax.random.PRNGKey(4), cfg_a)
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 32), 0, 97)
    x1, _ = tr.forward(params, toks, cfg_a)
    x2, _ = tr.forward(params, toks, cfg_b)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2), rtol=1e-5,
                               atol=1e-5)


def test_moe_capacity_drops_tokens():
    """With tiny capacity, some tokens are dropped (output = residual
    passthrough), never NaN."""
    moe = tr.MoEConfig(n_experts=2, top_k=1, group_size=32,
                       capacity_factor=0.25)
    cfg = _cfg(moe=moe, n_layers=1, d_ff=32)
    params = tr.init_params(jax.random.PRNGKey(6), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(7), (1, 32), 0, 97)
    x, _ = tr.forward(params, toks, cfg)
    assert np.all(np.isfinite(np.asarray(x)))


def test_gqa_head_counts():
    """MQA (kv=1) and MHA (kv=H) both work."""
    for kv in (1, 4):
        cfg = _cfg(n_kv_heads=kv)
        params = tr.init_params(jax.random.PRNGKey(8), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(9), (2, 16), 0, 97)
        loss, _ = tr.loss_fn(params, {"tokens": toks,
                                      "labels": jnp.roll(toks, -1, 1)},
                             cfg)
        assert np.isfinite(float(loss))


def test_model_flops_sane():
    cfg = _cfg()
    f_train = tr.model_flops(cfg, 4, 128, training=True)
    f_fwd = tr.model_flops(cfg, 4, 128, training=False)
    assert f_train == pytest.approx(3 * f_fwd)
    f_dec = tr.model_flops(cfg, 4, 1, training=False, decode=True,
                           kv_len=1024)
    assert f_dec > 0
