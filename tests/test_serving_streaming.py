"""Streaming replica loop: rolling-batch admission, global in-order
release under interleaved bucket completions, drain/close exactly-once
release, and deadline-loop parity via the ``loop=`` escape hatch."""
import threading
import time

import numpy as np
import jax
import pytest

from repro.core import caloclusternet as ccn
from repro.core.passes.parallelize import Requirements
from repro.core.pipeline import deploy
from repro.data.belle2 import Belle2Config, generate
from repro.serving import (LOOPS, ReplicaEngine, ShardedTriggerService,
                           StreamingReplicaEngine)


def _ids(feeds):
    """Recover the integer event ids packed into a launch (padding
    rows carry id 0)."""
    return [int(v) for v in np.asarray(feeds["x"]).ravel() if v > 0]


# ------------------------------------------------- rolling admission ----
def test_rolling_admission_joins_next_launch():
    """An event submitted while a launch is in flight must ride the
    *next* launch — no deadline tick, no batch-boundary wait.  The
    huge window proves the streaming loop never consults it."""
    gate = threading.Event()
    launched = threading.Event()
    launches = []

    def infer(feeds):
        launches.append(_ids(feeds))
        if len(launches) == 1:
            launched.set()
            assert gate.wait(timeout=30)
        return {"y": feeds["x"]}

    svc = ShardedTriggerService(infer, n_replicas=1, microbatch=4,
                                window_s=60.0, devices=None,
                                inflight=1, loop="streaming")
    try:
        f1 = svc.submit({"x": np.array([1.0], np.float32)})
        assert launched.wait(timeout=30)   # event 1 is in flight
        f2 = svc.submit({"x": np.array([2.0], np.float32)})
        f3 = svc.submit({"x": np.array([3.0], np.float32)})
        # the pipeline is gated (inflight=1), so 2 and 3 can only be
        # queued; releasing the gate must sweep both into one launch.
        gate.set()
        for f in (f1, f2, f3):
            f.result(timeout=30)
        svc.drain()
        assert launches == [[1], [2, 3]]
        assert svc.stats.batches == 2
    finally:
        svc.close()


# --------------------------------- in-order release across buckets ----
def test_global_inorder_release_under_interleaved_buckets():
    """A slow small-occupancy bucket and a fast large-occupancy bucket
    complete out of order; the shared releaser must still resolve
    futures in global submission order."""
    def make_echo(delay_s):
        def infer(feeds):
            time.sleep(delay_s)
            return {"y": feeds["mask"]}
        return infer

    svc = ShardedTriggerService(
        buckets={4: make_echo(20e-3), 8: make_echo(1e-3)},
        n_replicas=1, microbatch=2, window_s=60.0, devices=None,
        loop="streaming")
    try:
        n = 16
        order, lock = [], threading.Lock()

        def track(i):
            def cb(_fut):
                with lock:
                    order.append(i)
            return cb

        futs = []
        for i in range(n):
            occ = 2 if i % 2 == 0 else 6   # alternate buckets
            mask = np.zeros(8, np.float32)
            mask[:occ] = 1.0
            fut = svc.submit({"mask": mask})
            fut.add_done_callback(track(i))
            futs.append(fut)
        res = [f.result(timeout=60) for f in futs]
        svc.drain()
        assert order == list(range(n))
        # bucket routing cut each event's feeds to its bucket shape
        for i, r in enumerate(res):
            assert r["y"].shape == ((4,) if i % 2 == 0 else (8,))
    finally:
        svc.close()


# ------------------------------------------- drain / close semantics ----
def test_drain_with_backlog_releases_every_event_once():
    calls = []

    def infer(feeds):
        time.sleep(2e-3)
        calls.append(1)
        return {"y": feeds["x"]}

    svc = ShardedTriggerService(infer, n_replicas=1, microbatch=4,
                                window_s=60.0, devices=None,
                                loop="streaming")
    try:
        n = 40
        released, lock = [], threading.Lock()

        def track(i):
            def cb(_fut):
                with lock:
                    released.append(i)
            return cb

        futs = []
        for i in range(n):
            fut = svc.submit({"x": np.full(2, i + 1, np.float32)})
            fut.add_done_callback(track(i))
            futs.append(fut)
        svc.drain()
        assert all(f.done() for f in futs)
        assert sorted(released) == list(range(n))    # exactly once
        assert released == list(range(n))            # and in order
        assert svc.stats.completed == n
    finally:
        svc.close()


def test_close_with_backlog_resolves_every_future_exactly_once():
    """close() with events still queued/staged/in flight: every
    accepted event resolves exactly once — completed or failed, never
    silently dropped."""
    def infer(feeds):
        time.sleep(5e-3)
        return {"y": feeds["x"]}

    svc = ShardedTriggerService(infer, n_replicas=1, microbatch=2,
                                window_s=60.0, devices=None,
                                inflight=1, loop="streaming")
    n = 20
    resolved, lock = [], threading.Lock()

    def track(i):
        def cb(_fut):
            with lock:
                resolved.append(i)
        return cb

    futs = []
    for i in range(n):
        fut = svc.submit({"x": np.full(2, i + 1, np.float32)})
        fut.add_done_callback(track(i))
        futs.append(fut)
    svc.close()   # immediately, with a deep backlog
    assert all(f.done() for f in futs)
    assert sorted(resolved) == list(range(n))
    ok = sum(1 for f in futs if f.exception() is None)
    err = n - ok
    assert ok + err == n
    assert err >= 1          # the backlog cannot all have completed
    assert svc.stats.completed == ok
    assert sum(r.stats.failed for r in svc.replicas) == err


# ------------------------------------------------------- escape hatch ----
def test_loop_selection_and_default():
    svc = ShardedTriggerService(lambda f: f, n_replicas=1, microbatch=2,
                                devices=None)
    try:
        assert svc.loop == "deadline"
        assert type(svc.replicas[0]) is ReplicaEngine
    finally:
        svc.close()
    svc = ShardedTriggerService(lambda f: f, n_replicas=1, microbatch=2,
                                devices=None, loop="streaming")
    try:
        assert svc.loop == "streaming"
        assert isinstance(svc.replicas[0], StreamingReplicaEngine)
    finally:
        svc.close()


def test_invalid_loop_and_streaming_rejects_hedge():
    assert set(LOOPS) == {"deadline", "streaming"}
    with pytest.raises(ValueError, match="unknown replica loop"):
        ShardedTriggerService(lambda f: f, microbatch=2, devices=None,
                              loop="bogus")
    with pytest.raises(ValueError, match="hedge_after_s"):
        ShardedTriggerService(lambda f: f, microbatch=2, devices=None,
                              hedge_after_s=1e-3, loop="streaming")


# --------------------------------------------- deployed-pipeline e2e ----
def test_streaming_loop_matches_direct_pipeline():
    """Real compiled trigger pipeline through the streaming loop (two
    replicas, monitoring on): results must match the direct pipeline
    call event for event, and the monitor tap must see every event."""
    cfg = ccn.CCNConfig(n_hits=16, n_crystals=144)
    gen = Belle2Config(n_crystals=144, grid=(12, 12), n_hits=16,
                       noise_rate=4.0)
    params = ccn.init(jax.random.PRNGKey(0), cfg)
    graph = ccn.to_graph(params, cfg)
    calib = generate(gen, 16, seed=1)
    feeds = {"hits": calib["feats"], "mask": calib["mask"]}
    req = Requirements(design_point=3, platform="cpu",
                       precision_policy="fp", n_hits=cfg.n_hits,
                       target_throughput=2e4, max_latency_s=2e-3)
    pipe = deploy(graph, req, calibration_feeds=feeds)

    def infer(batch):
        return pipe({"hits": batch["hits"], "mask": batch["mask"]})

    mb = max(pipe.microbatch, 4)
    infer({"hits": calib["feats"][:mb], "mask": calib["mask"][:mb]})

    svc = ShardedTriggerService(infer, n_replicas=2, microbatch=mb,
                                window_s=60.0, devices=None,
                                loop="streaming", monitor=True)
    try:
        events = generate(gen, 24, seed=2)
        futs = [svc.submit({"hits": events["feats"][i],
                            "mask": events["mask"][i]})
                for i in range(24)]
        results = [f.result(timeout=120) for f in futs]
        svc.drain()
        direct = pipe({"hits": events["feats"], "mask": events["mask"]})
        for i in range(24):
            np.testing.assert_allclose(
                np.asarray(results[i]["coords"]),
                np.asarray(direct["coords"][i]), rtol=1e-5, atol=1e-5)
            assert (bool(results[i]["cps"]["trigger"])
                    == bool(np.asarray(direct["cps"]["trigger"])[i]))
        snap = svc.monitor_snapshot()
        assert snap["events"] == 24
    finally:
        svc.close()
