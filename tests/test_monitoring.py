"""Tests: real-time monitoring subsystem — event-display geometry,
snapshot clock consistency, truth-matched accounting, batched
recording, the stats clocks, and the HTTP endpoint wired into a live
``ShardedTriggerService``."""
import json
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.data.belle2 import Belle2Config, current_detector
from repro.serving import (MonitorServer, MonitorSnapshot,
                           ShardedTriggerService, TriggerMonitor,
                           detector_grid, event_display)


def _cps(n_valid=2, xy=None, k=4):
    xy = np.zeros((k, 2), np.float32) if xy is None else np.asarray(xy)
    return {
        "trigger": np.asarray(n_valid > 0),
        "n_clusters": np.asarray(n_valid),
        "cluster_valid": (np.arange(k) < n_valid).astype(np.float32),
        "cluster_e": np.linspace(1.0, 2.0, k).astype(np.float32),
        "cluster_beta": np.full(k, 0.5, np.float32),
        "cluster_xy": xy.astype(np.float32),
    }


# ----------------------------------------------------- event_display ----
def test_detector_grid_resolution():
    assert detector_grid(None) == (56, 156)
    assert detector_grid(Belle2Config()) == (56, 156)
    assert detector_grid(current_detector()) == (24, 24)

    class CCNLike:        # CCNConfig carries n_crystals, not grid
        n_crystals = 576
    assert detector_grid(CCNLike()) == (24, 24)
    with pytest.raises(ValueError, match="cannot infer"):
        detector_grid(object())


@pytest.mark.parametrize("det,grid", [(current_detector(), (24, 24)),
                                      (Belle2Config(), (56, 156))])
def test_event_display_uses_detector_grid(det, grid):
    res = _cps(n_valid=2, xy=[[0.0, 0.25], [-0.25, 0.0],
                              [0, 0], [0, 0]])
    d = event_display(res, event_id=5, detector=det)
    nt, nph = grid
    assert d["grid"] == [nt, nph]
    assert d["event"] == 5 and len(d["clusters"]) == 2
    c0, c1 = d["clusters"]
    # (xy + 0.5) * grid, per-axis
    assert c0["theta"] == pytest.approx(0.5 * nt)
    assert c0["phi"] == pytest.approx(0.75 * nph)
    assert c1["theta"] == pytest.approx(0.25 * nt)
    assert c1["phi"] == pytest.approx(0.5 * nph)


def test_event_display_clips_out_of_range_coords():
    res = _cps(n_valid=2, xy=[[-3.0, 7.0], [0.6, -0.51],
                              [0, 0], [0, 0]])
    for det in (current_detector(), Belle2Config()):
        nt, nph = detector_grid(det)
        d = event_display(res, event_id=0, detector=det)
        for c in d["clusters"]:
            assert 0.0 <= c["theta"] <= nt
            assert 0.0 <= c["phi"] <= nph
        # clipped exactly to the detector extent, not wrapped
        assert d["clusters"][0]["theta"] == 0.0
        assert d["clusters"][0]["phi"] == nph
        assert d["clusters"][1]["theta"] == nt
        assert d["clusters"][1]["phi"] == 0.0


def test_event_display_truth_flag_optional():
    d = event_display(_cps(), event_id=1)
    assert "truth" not in d
    d = event_display(_cps(), event_id=1, truth=False)
    assert d["truth"] is False


# ---------------------------------------------------------- snapshot ----
def test_snapshot_clock_consistency_single_reading():
    """snapshot() reads the clock exactly once: wall_s, window_s and
    rate_ev_s are all derived from the same ``now``, and the rate is
    windowed (events in window / window span), not lifetime."""
    t = [100.0]

    def clock():
        return t[0]

    mon = TriggerMonitor(window=1024, clock=clock)
    for i in range(10):
        t[0] = 100.0 + i          # one event per "second"
        mon.record(_cps(), latency_s=1e-5)
    t[0] = 120.0                  # long idle gap before the snapshot
    snap = mon.snapshot()
    assert snap["events"] == 10
    assert snap["window_events"] == 10
    assert snap["wall_s"] == pytest.approx(20.0)       # since t0=100
    assert snap["window_s"] == pytest.approx(20.0)     # first event at 100
    # windowed rate == window_events / window_s, from the same clock
    assert snap["rate_ev_s"] == pytest.approx(
        snap["window_events"] / snap["window_s"])
    # lifetime-rate bug would have produced the same number here; the
    # distinction shows once the window slides — see below.


def test_snapshot_rate_is_windowed_not_lifetime():
    t = [0.0]
    mon = TriggerMonitor(window=8, clock=lambda: t[0])
    # 100 events in the first second, then 8 events over 8 seconds
    for i in range(100):
        t[0] = i * 0.01
        mon.record(_cps())
    for i in range(8):
        t[0] = 2.0 + i
        mon.record(_cps())
    t[0] = 10.0
    snap = mon.snapshot()
    assert snap["events"] == 108                # lifetime preserved
    # the lifetime rate would be 10.8 ev/s; the windowed rate covers
    # the last 8 events spread over 8 s ending 1 s before the snapshot
    assert snap["rate_ev_s"] == pytest.approx(8 / 8.0)


def test_truth_matched_efficiency_and_fake_rate():
    mon = TriggerMonitor(window=256)
    # 4 signal-fired, 2 signal-missed, 3 background-quiet, 1 bg-fired
    for _ in range(4):
        mon.record(_cps(n_valid=1), truth=True)     # fired, signal
    for _ in range(2):
        mon.record(_cps(n_valid=0), truth=True)     # quiet, signal
    for _ in range(3):
        mon.record(_cps(n_valid=0), truth=False)    # quiet, background
    mon.record(_cps(n_valid=1), truth=False)        # fired, background
    mon.record(_cps(n_valid=1))                     # no truth bit
    snap = mon.snapshot()
    assert snap["truth_events"] == 10
    assert snap["efficiency"] == pytest.approx(4 / 6)
    assert snap["fake_rate"] == pytest.approx(1 / 4)
    assert snap["events"] == 11


def test_record_batch_matches_per_event_recording():
    k = 4
    b = 6
    rng = np.random.default_rng(0)
    batch = {
        "trigger": np.asarray([1, 0, 1, 1, 0, 1], bool),
        "n_clusters": np.asarray([2, 0, 1, 3, 0, 2]),
        "cluster_valid": (np.arange(k)[None, :]
                          < np.asarray([2, 0, 1, 3, 0, 2])[:, None]),
        "cluster_e": rng.uniform(0.1, 2.0, (b, k)).astype(np.float32),
        "cluster_beta": rng.uniform(0, 1, (b, k)).astype(np.float32),
        "cluster_xy": rng.uniform(-0.4, 0.4, (b, k, 2))
        .astype(np.float32),
    }
    truths = [True, False, True, None, False, True]
    lats = [1e-5 * (i + 1) for i in range(b)]
    m_batch = TriggerMonitor(window=64)
    m_batch.record_batch(batch, b, latencies_s=lats, truths=truths,
                         event_ids=list(range(b)))
    m_event = TriggerMonitor(window=64)
    for i in range(b):
        m_event.record({kk: vv[i] for kk, vv in batch.items()},
                       latency_s=lats[i], truth=truths[i], event_id=i)
    sb, se = m_batch.snapshot(), m_event.snapshot()
    for key in ("events", "window_events", "trigger_rate",
                "clusters_per_event", "cluster_e_mean", "truth_events",
                "efficiency", "fake_rate", "latency_p50_us",
                "latency_p99_us"):
        assert sb[key] == pytest.approx(se[key]), key
    db, de = m_batch.displays(), m_event.displays()
    assert len(db) == len(de) == b
    for rb, re_ in zip(db, de):
        assert rb["event"] == re_["event"]
        assert rb["clusters"] == re_["clusters"]
        assert rb.get("truth") == re_.get("truth")


def test_padding_rows_never_reach_the_monitor():
    k = 4
    batch = {
        "trigger": np.asarray([1, 1, 0, 0], bool),  # rows 2,3 padding
        "n_clusters": np.asarray([1, 1, 0, 0]),
        "cluster_valid": np.zeros((4, k)),
        "cluster_e": np.zeros((4, k)),
        "cluster_beta": np.zeros((4, k)),
        "cluster_xy": np.zeros((4, k, 2)),
    }
    mon = TriggerMonitor(window=64)
    mon.record_batch(batch, 2)
    snap = mon.snapshot()
    assert snap["events"] == 2
    assert snap["trigger_rate"] == 1.0


def test_display_ring_is_bounded_and_keeps_most_recent():
    mon = TriggerMonitor(window=4096, display_n=8)
    for i in range(50):
        mon.record(_cps(), event_id=i)
    recs = mon.displays()
    assert len(recs) == 8
    assert [r["event"] for r in recs] == list(range(42, 50))
    assert [r["event"] for r in mon.displays(3)] == [47, 48, 49]
    assert mon.displays(0) == []


def test_display_every_thins_both_paths():
    k = 4
    batch = {
        "trigger": np.ones(8, bool),
        "n_clusters": np.ones(8, np.int32),
        "cluster_valid": np.ones((8, k)),
        "cluster_e": np.ones((8, k), np.float32),
        "cluster_beta": np.full((8, k), 0.5, np.float32),
        "cluster_xy": np.zeros((8, k, 2), np.float32),
    }
    mb = TriggerMonitor(window=64, display_every=4)
    mb.record_batch(batch, 8, event_ids=list(range(8)))
    assert [r["event"] for r in mb.displays()] == [0, 4]
    me = TriggerMonitor(window=64, display_every=4)
    for i in range(8):
        me.record(_cps(), event_id=i)
    assert [r["event"] for r in me.displays()] == [0, 4]


def test_windowed_stats_slide():
    mon = TriggerMonitor(window=10)
    for _ in range(20):
        mon.record(_cps(n_valid=0))     # quiet events first
    for _ in range(10):
        mon.record(_cps(n_valid=2))     # window now all-firing
    snap = mon.snapshot()
    assert snap["events"] == 30
    assert snap["trigger_rate"] == 1.0
    assert snap["clusters_per_event"] == 2.0


def test_merge_pools_across_monitors():
    m1, m2 = TriggerMonitor(window=64), TriggerMonitor(window=64)
    for _ in range(4):
        m1.record(_cps(n_valid=1), latency_s=1e-5, truth=True)
    for _ in range(4):
        m2.record(_cps(n_valid=0), latency_s=3e-5, truth=True)
    snap = MonitorSnapshot.merge([m1, m2])
    assert snap["events"] == 8
    assert snap["trigger_rate"] == pytest.approx(0.5)
    assert snap["efficiency"] == pytest.approx(0.5)
    assert snap["truth_events"] == 8
    assert snap["latency_p50_us"] == pytest.approx(20.0, rel=0.01)


# ----------------------------------------------- service integration ----
def _cps_infer(feeds):
    x = feeds["x"]
    b = x.shape[0]
    k = 4
    fired = x > 0
    return {"cps": {
        "trigger": fired,
        "n_clusters": fired.astype(np.int32) * 2,
        "cluster_valid": np.tile(np.arange(k) < 2, (b, 1))
        * fired[:, None],
        "cluster_e": np.ones((b, k), np.float32),
        "cluster_beta": np.full((b, k), 0.5, np.float32),
        "cluster_xy": np.zeros((b, k, 2), np.float32),
    }}


def test_sharded_service_records_and_serves_snapshot():
    """End to end: monitored service -> merged snapshot and /snapshot
    endpoint agree with the engine's own serving stats; /events NDJSON
    and the HTML display are served."""
    svc = ShardedTriggerService(
        _cps_infer, n_replicas=2, microbatch=4, window_s=1e-3,
        devices=None, monitor={"detector": current_detector()})
    n = 48
    futs = []
    for i in range(n):
        fired = i % 3 != 0
        futs.append(svc.submit({"x": np.float32(1.0 if fired else -1.0)},
                               truth=fired))
    for f in futs:
        f.result(timeout=60)
    svc.drain()
    snap = svc.monitor_snapshot()
    s = svc.stats.summary()
    assert snap["events"] == s["completed"] == n
    assert snap["efficiency"] == 1.0 and snap["fake_rate"] == 0.0
    assert snap["trigger_rate"] == pytest.approx(2 / 3)
    assert snap["clusters_per_event"] == pytest.approx(4 / 3)
    displays = svc.event_displays(8)
    assert len(displays) == 8
    assert svc.event_displays(0) == []
    assert all(r["grid"] == [24, 24] for r in displays)
    seqs = [r["event"] for r in displays]
    assert seqs == sorted(seqs)

    with MonitorServer.for_service(svc, port=0) as server:
        live = json.load(urllib.request.urlopen(
            server.url + "/snapshot", timeout=10))
        assert live["events"] == s["completed"]
        assert live["efficiency"] == 1.0
        nd = urllib.request.urlopen(
            server.url + "/events?n=5", timeout=10).read().decode()
        recs = [json.loads(line) for line in nd.splitlines() if line]
        assert len(recs) == 5
        assert all({"event", "trigger", "clusters", "grid"} <= set(r)
                   for r in recs)
        html = urllib.request.urlopen(
            server.url + "/", timeout=10).read().decode()
        assert "<svg" in html
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(server.url + "/nope", timeout=10)
    svc.close()


def test_monitor_off_by_default_and_guarded():
    svc = ShardedTriggerService(lambda f: {"y": f["x"]}, n_replicas=1,
                                microbatch=2, window_s=1e-3,
                                devices=None)
    assert not svc.monitoring and svc.monitors == []
    with pytest.raises(RuntimeError, match="monitoring is off"):
        svc.monitor_snapshot()
    fut = svc.submit({"x": np.float32(1)}, truth=True)  # truth ignored
    fut.result(timeout=30)
    svc.drain()
    assert svc._truth == {}
    svc.close()


def test_monitor_tolerates_cps_less_payloads():
    svc = ShardedTriggerService(lambda f: {"y": f["x"]}, n_replicas=1,
                                microbatch=2, window_s=1e-3,
                                devices=None, monitor=True)
    futs = [svc.submit({"x": np.float32(i)}) for i in range(6)]
    for f in futs:
        f.result(timeout=30)
    svc.drain()
    snap = svc.monitor_snapshot()
    assert snap["events"] == 6
    assert snap["trigger_rate"] is None
    assert snap["latency_p50_us"] is not None
    svc.close()


def test_failed_batches_clean_truth_side_channel():
    def infer(feeds):
        if np.max(feeds["x"]) < 0:
            raise RuntimeError("poisoned batch")
        return _cps_infer(feeds)

    svc = ShardedTriggerService(infer, n_replicas=1, microbatch=1,
                                window_s=1e-3, devices=None,
                                monitor=True)
    bad = svc.submit({"x": np.float32(-1)}, truth=True)
    good = svc.submit({"x": np.float32(2)}, truth=True)
    with pytest.raises(RuntimeError, match="poisoned"):
        bad.result(timeout=30)
    good.result(timeout=30)
    svc.drain()
    snap = svc.monitor_snapshot()
    assert snap["events"] == 1            # failed event not recorded
    assert svc._truth == {}               # no leaked truth entries
    svc.close()


# ------------------------------------------------------- stats clocks ----
def test_aggregate_throughput_clock_starts_at_first_submission():
    svc = ShardedTriggerService(lambda f: {"y": f["x"]}, n_replicas=1,
                                microbatch=8, window_s=1e-3,
                                devices=None)
    assert svc.stats.throughput_ev_s() == 0.0
    idle = 0.3
    time.sleep(idle)                  # service idles before traffic
    n = 64
    t0 = time.perf_counter()
    futs = [svc.submit({"x": np.float32(i)}) for i in range(n)]
    for f in futs:
        f.result(timeout=30)
    svc.drain()
    serve_dt = time.perf_counter() - t0
    thr = svc.stats.throughput_ev_s()
    # construction-time clocking would cap throughput at n/idle
    assert thr > n / (idle + serve_dt) * 0.9
    assert thr > n / idle
    # the per-replica clock starts at first enqueue too
    assert svc.replicas[0].stats.summary()["throughput_ev_s"] > n / idle
    svc.close()
