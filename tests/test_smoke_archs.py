"""Per-architecture smoke tests: a REDUCED config of each assigned arch
runs one real forward/train step on CPU; asserts output shapes + no NaNs.
Also sanity-checks cell construction (abstract args + specs align)."""
import numpy as np
import jax
import pytest

from repro import configs

ALL_ARCHS = configs.ASSIGNED + ["caloclusternet"]


def _all_finite(tree):
    for leaf in jax.tree_util.tree_leaves(tree):
        arr = np.asarray(leaf)
        if arr.dtype.kind == "f":
            assert np.all(np.isfinite(arr)), "non-finite values"
    return True


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_step(arch):
    mod = configs.get_arch(arch)
    out = mod.smoke_run(seed=0)
    assert _all_finite(out)
    assert np.isfinite(float(out["loss"]))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_cells_constructible(arch):
    """Every declared shape builds a Cell whose abstract args and specs
    have identical tree structure (required for in_shardings)."""
    mod = configs.get_arch(arch)
    for shape in mod.SHAPES:
        cell = mod.cell(shape)
        args = cell.abstract_args()
        specs = cell.spec_args()
        ta = jax.tree_util.tree_structure(
            jax.tree_util.tree_map(lambda _: 0, args))
        ts = jax.tree_util.tree_structure(
            jax.tree_util.tree_map(
                lambda _: 0, specs,
                is_leaf=lambda x: isinstance(
                    x, jax.sharding.PartitionSpec)))
        assert ta == ts, f"{cell.name}: args/specs structure mismatch"
        assert cell.model_flops > 0
        assert cell.kind in ("train", "prefill", "decode", "serve")


def test_registry_covers_40_cells():
    cells = list(configs.all_cells())
    assert len(cells) == 40
