"""GravNet-block fusion benchmark: fused megakernel vs the unfused
dense→aggregate→dense chain, across occupancy buckets × micro-batches.

Three measurements per (bucket, microbatch) point:

  block_*  — the GravNet-block operator chain at *launch granularity*:
             every kernel wrapper call is its own dispatch, exactly as
             each ``pallas_call`` is its own launch on TPU hardware.
             Unfused = 3 launches (S/F projection dense, aggregate,
             output dense); fused = 1 megakernel launch. This is the
             quantity the megakernel changes and the one the ``--check``
             gate enforces (fused ≥ 1.2× unfused events/s at
             micro-batch ≥ 8).
  int8_*   — the same A/B for the *quantized* block: the fused
             ``gravnet_block_int8`` megakernel vs the calibrated
             unfused int8 chain (quantize → merged int8 S/F dense →
             aggregate → requantization snap → quantize → int8 output
             dense). The unfused side pays the inter-kernel
             requantization glue the megakernel keeps in VMEM, so the
             gate is the same ≥ 1.2× at micro-batch ≥ 8.
  pipe_*   — the full deployed pipeline (whole-pipeline jit), fused vs
             ``deploy(fuse_gravnet_block=False)``. On CPU the XLA
             whole-program jit already hides launch boundaries, so this
             mostly guards against end-to-end regressions; the real
             end-to-end gate is ``serving_scaling.py`` vs
             ``BENCH_baseline.json``.

Per-deployment launch counts (kernel-launching operators per event)
are derived from the deployed graphs and recorded alongside: the CCN
GravNet block goes 3 → 1 launches per block.

    PYTHONPATH=src python benchmarks/fusion.py --out BENCH_fusion.json
    PYTHONPATH=src python -m benchmarks.run fusion
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

if __package__ in (None, ""):   # script invocation: put repo root first
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import row

BUCKETS = (8, 16, 32)
MICROBATCHES = (1, 8, 16)


def _time_ab(fn_a, fn_b, *, warmup: int = 2, iters: int = 7):
    """Interleaved min-of-N A/B timing. Alternating single-call samples
    cancel machine-load drift between the two sides, and the minimum is
    the least-noisy estimator of intrinsic cost (scheduler noise on a
    busy host is strictly additive — same rationale as
    ``tuning.autotune._time_call`` and ``regression.py``)."""
    import time

    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn_a())
        jax.block_until_ready(fn_b())
    ta, tb = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a())
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b())
        tb.append(time.perf_counter() - t0)
    return min(ta), min(tb)

# operators that launch a kernel (one dispatch each on the Pallas path)
_KERNEL_OPS = ("dense", "linear", "gravnet_aggregate", "gravnet_block",
               "attention")


def launch_counts(graph) -> dict:
    """Kernel launches per micro-batch step, total and per GravNet
    block (the paper's fusion story in one number: 3 → 1)."""
    total = sum(1 for op in graph if op.op_type in _KERNEL_OPS)
    per_block_unfused = [
        op for op in graph
        if op.op_type in ("gravnet_aggregate", "gravnet_block")]
    n_blocks = len(per_block_unfused)
    block_launches = 0
    for op in per_block_unfused:
        if op.op_type == "gravnet_block":
            block_launches += 1
        else:
            # the aggregate plus its projection + output denses
            block_launches += 3
    return {"total": total, "gravnet_blocks": n_blocks,
            "per_block": (block_launches / n_blocks) if n_blocks else 0}


def run(out_path: str | None = None, iters: int = 5):
    import jax
    import jax.numpy as jnp
    import numpy as np

    import repro.core.caloclusternet as ccn
    from repro.core.passes.parallelize import Requirements
    from repro.core.pipeline import _cut_hits, deploy
    from repro.data.belle2 import current_detector, generate
    from repro.kernels import ops

    cfg = ccn.current_detector_config()
    gen = current_detector()
    params = ccn.init(jax.random.PRNGKey(0), cfg)
    graph = ccn.to_graph(params, cfg)
    data = generate(gen, max(MICROBATCHES), seed=3)
    feeds = {"hits": data["feats"], "mask": data["mask"]}
    req = Requirements(design_point=3, platform="cpu",
                       precision_policy="fp", n_hits=cfg.n_hits,
                       target_throughput=5e4, max_latency_s=2e-3)
    rng = np.random.default_rng(0)
    dh, ds, df, k = cfg.d_hidden, cfg.d_s, cfg.d_flr, cfg.k
    ws = jnp.asarray(rng.normal(size=(dh, ds)) * 0.3, jnp.float32)
    bs = jnp.asarray(rng.normal(size=(ds,)), jnp.float32)
    wf = jnp.asarray(rng.normal(size=(dh, df)) * 0.3, jnp.float32)
    bf = jnp.asarray(rng.normal(size=(df,)), jnp.float32)
    wo = jnp.asarray(rng.normal(size=(dh + 2 * df, dh)) * 0.3, jnp.float32)
    bo = jnp.asarray(rng.normal(size=(dh,)), jnp.float32)
    wide = jnp.concatenate([ws, wf], axis=1)
    bwide = jnp.concatenate([bs, bf], axis=0)

    # quantized operands for the int8 A/B: per-channel weights plus
    # representative baked activation scales (speed is scale-invariant)
    from repro.core.quantization import quantize_weight
    ws_q, ws_s = quantize_weight(ws)
    wf_q, wf_s = quantize_weight(wf)
    wo_q, wo_s = quantize_weight(wo)
    wide_q, wide_s = quantize_weight(wide)
    x_scale, agg_scale, h_scale = 0.02, 0.01, 0.02
    xs_arr = jnp.asarray([[x_scale]], jnp.float32)
    hs_arr = jnp.asarray([[h_scale]], jnp.float32)

    trajectory = []
    for bucket in BUCKETS:
        req_b = dataclasses.replace(req, n_hits=bucket)
        fb = _cut_hits(feeds, bucket)
        for mb in MICROBATCHES:
            chunk = jax.tree_util.tree_map(lambda a: a[:mb], fb)
            x = jnp.asarray(rng.normal(size=(mb, bucket, dh)), jnp.float32)
            mask = jnp.asarray(rng.uniform(size=(mb, bucket)) < 0.8,
                               jnp.float32)

            # -- block chain at launch granularity (one dispatch per
            #    kernel wrapper call, as on hardware) ----------------
            def block_fused():
                return ops.gravnet_block_batched(
                    x, mask, ws, bs, wf, bf, wo, bo, k=k)

            def block_unfused():
                sf = ops.fused_dense(
                    x.reshape(mb * bucket, dh), wide, bwide,
                    activation="none", variant="flattened"
                ).reshape(mb, bucket, ds + df)
                agg = ops.gravnet_aggregate_batched(
                    sf[..., :ds], sf[..., ds:], mask, k=k)
                h = jnp.concatenate([x, agg], axis=-1)
                return ops.fused_dense(
                    h.reshape(mb * bucket, dh + 2 * df), wo, bo,
                    activation="relu", variant="flattened"
                ).reshape(mb, bucket, dh)

            t_bf, t_bu = _time_ab(block_fused, block_unfused,
                                  iters=iters)

            # -- quantized block chain, same launch granularity ------
            def int8_fused():
                return ops.gravnet_block_int8_batched(
                    x, mask, ws_q, bs, wf_q, bf, wo_q, bo,
                    ws_s, wf_s, wo_s, x_scale=x_scale,
                    agg_scale=agg_scale, h_scale=h_scale, k=k)

            def int8_unfused():
                xq = jnp.clip(jnp.round(x / x_scale), -127,
                              127).astype(jnp.int8)
                sf = ops.fused_dense_int8(
                    xq.reshape(mb * bucket, dh), wide_q, bwide,
                    xs_arr, wide_s, activation="none"
                ).reshape(mb, bucket, ds + df)
                agg = ops.gravnet_aggregate_batched(
                    sf[..., :ds], sf[..., ds:], mask, k=k)
                agg = jnp.clip(jnp.round(agg / agg_scale), -127,
                               127) * agg_scale
                h = jnp.concatenate([x, agg], axis=-1)
                hq = jnp.clip(jnp.round(h / h_scale), -127,
                              127).astype(jnp.int8)
                return ops.fused_dense_int8(
                    hq.reshape(mb * bucket, dh + 2 * df), wo_q, bo,
                    hs_arr, wo_s, activation="relu"
                ).reshape(mb, bucket, dh)

            t_qf, t_qu = _time_ab(int8_fused, int8_unfused,
                                  iters=iters)

            # -- full pipeline, fused vs escape hatch ----------------
            fused_pipe = deploy(graph, req_b, batch=mb)
            unfused_pipe = deploy(graph, req_b, batch=mb,
                                  fuse_gravnet_block=False)
            t_pf, t_pu = _time_ab(lambda: fused_pipe(chunk),
                                  lambda: unfused_pipe(chunk),
                                  iters=iters)

            lc_f = launch_counts(fused_pipe.graph)
            lc_u = launch_counts(unfused_pipe.graph)
            point = {
                "bucket": bucket, "microbatch": mb,
                "block_fused_us": t_bf * 1e6,
                "block_unfused_us": t_bu * 1e6,
                "block_fused_ev_s": mb / t_bf,
                "block_unfused_ev_s": mb / t_bu,
                "block_speedup": t_bu / t_bf,
                "int8_fused_us": t_qf * 1e6,
                "int8_unfused_us": t_qu * 1e6,
                "int8_fused_ev_s": mb / t_qf,
                "int8_unfused_ev_s": mb / t_qu,
                "int8_speedup": t_qu / t_qf,
                "pipe_fused_us": t_pf * 1e6,
                "pipe_unfused_us": t_pu * 1e6,
                "pipe_speedup": t_pu / t_pf,
                "launches_fused": lc_f["total"],
                "launches_unfused": lc_u["total"],
                "launches_per_block_fused": lc_f["per_block"],
                "launches_per_block_unfused": lc_u["per_block"],
            }
            trajectory.append(point)
            row(f"fusion_b{bucket}_mb{mb}_block", t_bf * 1e6,
                f"vs unfused {t_bu * 1e6:.1f}us "
                f"speedup {point['block_speedup']:.2f}x "
                f"launches/block {lc_u['per_block']:.0f}->"
                f"{lc_f['per_block']:.0f}")
            row(f"fusion_b{bucket}_mb{mb}_int8_block", t_qf * 1e6,
                f"vs unfused {t_qu * 1e6:.1f}us "
                f"speedup {point['int8_speedup']:.2f}x")
            row(f"fusion_b{bucket}_mb{mb}_pipeline", t_pf * 1e6,
                f"vs unfused {t_pu * 1e6:.1f}us "
                f"speedup {point['pipe_speedup']:.2f}x")

    if out_path:
        with open(out_path, "w") as f:
            json.dump({"detector": "current", "buckets": list(BUCKETS),
                       "microbatches": list(MICROBATCHES),
                       "trajectory": trajectory}, f, indent=1)
        print(f"[fusion] wrote {out_path}", file=sys.stderr)
    return trajectory


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--check", action="store_true",
                    help="fail unless the fused block (f32 AND int8) "
                         "wins >= 1.2x at every bucket for microbatch "
                         ">= 8 (and the fused pipeline does not "
                         "regress)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    traj = run(args.out, iters=args.iters)
    if args.check:
        bad = [p for p in traj
               if p["microbatch"] >= 8 and p["block_speedup"] < 1.2]
        if bad:
            raise SystemExit(
                "fusion: fused block below the 1.2x gate at "
                + ", ".join(f"b{p['bucket']}/mb{p['microbatch']} "
                            f"({p['block_speedup']:.2f}x)" for p in bad))
        bad8 = [p for p in traj
                if p["microbatch"] >= 8 and p["int8_speedup"] < 1.2]
        if bad8:
            raise SystemExit(
                "fusion: fused int8 block below the 1.2x gate at "
                + ", ".join(f"b{p['bucket']}/mb{p['microbatch']} "
                            f"({p['int8_speedup']:.2f}x)" for p in bad8))
        # end-to-end guard: the fused pipeline must not get slower
        # (generous bound — 2-core CI wall time is noisy; the strict
        # end-to-end gate is serving_scaling vs BENCH_baseline)
        slow = [p for p in traj
                if p["microbatch"] >= 8 and p["pipe_speedup"] < 0.75]
        if slow:
            raise SystemExit(
                "fusion: fused pipeline regressed at "
                + ", ".join(f"b{p['bucket']}/mb{p['microbatch']} "
                            f"({p['pipe_speedup']:.2f}x)" for p in slow))


if __name__ == "__main__":
    main()
