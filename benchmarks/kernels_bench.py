"""Kernel-level microbenchmarks (paper §III-A kernel-level optimization):
fused vs unfused dense, gravnet aggregation vs unfused reference path,
int8 vs fp32 — CPU XLA wall time + derived MXU utilization estimates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.kernels import ops, ref
from repro.launch.mesh import PEAK_FLOPS_BF16


def run():
    rows = []
    rng = np.random.default_rng(0)
    # trigger-scale fused dense (128 hits x 64->64), batched 4096 events
    m, k, n = 4096 * 128, 64, 64
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(n,)), jnp.float32)

    fused = jax.jit(lambda x_: ops.fused_dense(x_, w, b, backend="xla"))
    t, _ = time_fn(fused, x)
    fl = 2.0 * m * k * n
    rows.append(row("kernel_fused_dense_fp32", t * 1e6,
                    f"{fl / t / 1e9:.1f} GFLOP/s cpu; "
                    f"tpu-roofline {fl / PEAK_FLOPS_BF16 * 1e6:.2f} us"))

    unfused = jax.jit(lambda x_: jnp.maximum(x_ @ w + b, 0.0))
    t2, _ = time_fn(unfused, x)
    rows.append(row("kernel_unfused_linear_relu", t2 * 1e6,
                    f"fused speedup {t2 / t:.2f}x"))

    # int8 path
    xq = jnp.asarray(rng.integers(-127, 127, size=(m, k)), jnp.int8)
    wq = jnp.asarray(rng.integers(-127, 127, size=(k, n)), jnp.int8)
    xs = jnp.asarray([[0.02]], jnp.float32)
    ws = jnp.asarray(rng.uniform(0.001, 0.05, size=(n,)), jnp.float32)
    fq = jax.jit(lambda a: ops.fused_dense_int8(a, wq, b, xs, ws,
                                                backend="xla"))
    t3, _ = time_fn(fq, xq)
    rows.append(row("kernel_fused_dense_int8", t3 * 1e6,
                    f"vs fp32 {t / t3:.2f}x cpu"))

    # gravnet aggregation (upgrade scale: 128 hits, k=8)
    B, N, ds, df = 256, 128, 4, 22
    s = jnp.asarray(rng.normal(size=(B, N, ds)), jnp.float32)
    f = jnp.asarray(rng.normal(size=(B, N, df)), jnp.float32)
    mask = jnp.asarray(rng.uniform(size=(B, N)) < 0.8, jnp.float32)
    gv = jax.jit(jax.vmap(lambda a, b_, m_: ops.gravnet_aggregate(
        a, b_, m_, k=8, backend="xla")))
    t4, _ = time_fn(gv, s, f, mask)
    gfl = 2.0 * B * N * N * (ds + 8 * df)
    rows.append(row("kernel_gravnet_aggregate", t4 / B * 1e6,
                    f"{gfl / t4 / 1e9:.1f} GFLOP/s cpu per-event-us"))
    return rows


if __name__ == "__main__":
    run()
