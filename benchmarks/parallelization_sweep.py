"""Spatial-parallelization sweep (paper §III-A): throughput vs P.

The paper exhaustively searches P ∈ {2^n} for the smallest factor meeting
the target. This reproduces the search curve: analytic throughput model
per P (TPU) + measured CPU events/s at the corresponding micro-batch.
"""
from __future__ import annotations

import jax

from benchmarks.common import row, time_fn
from repro.core import caloclusternet as ccn
from repro.core.passes import fuse, partition
from repro.core.passes.mapping import map_templates
from repro.core.passes.parallelize import Requirements, parallelize
from repro.core.pipeline import CompiledPipeline, deploy
from repro.core.quantization import apply_precision_policy
from repro.data.belle2 import Belle2Config, generate


def run(max_p: int = 32):
    cfg = ccn.CCNConfig()
    params = ccn.init(jax.random.PRNGKey(0), cfg)
    graph = ccn.to_graph(params, cfg)
    gen = Belle2Config()
    data = generate(gen, 128, seed=5)
    feeds = {"hits": data["feats"], "mask": data["mask"]}
    rows = []
    g0 = map_templates(apply_precision_policy(
        partition(fuse(graph)), policy="fp"))
    p = 1
    while p <= max_p:
        req = Requirements(design_point=3, platform="cpu",
                           precision_policy="fp", n_hits=cfg.n_hits,
                           max_p=p, target_throughput=1e12)  # force P=max
        gp = parallelize(g0, req)
        from repro.core.passes.kernel_opt import kernel_optimize
        gk = kernel_optimize(gp, n_rows=cfg.n_hits)
        pipe = CompiledPipeline(gk, req, "xla")
        t, _ = time_fn(lambda: pipe(feeds))
        ev_s = 128 / t
        # analytic TPU throughput at this P
        req_t = Requirements(design_point=3, platform="tpu",
                             precision_policy="fp", n_hits=cfg.n_hits,
                             max_p=p, target_throughput=1e12)
        gt = parallelize(g0, req_t)
        model = gt.meta["parallelization"]["model_throughput_ev_s"]
        rows.append(row(f"p_sweep_P{p}", t / 128 * 1e6,
                        f"cpu {ev_s:,.0f} ev/s; tpu-model "
                        f"{model:,.0f} ev/s/chip"))
        p *= 4
    return rows


if __name__ == "__main__":
    run()
