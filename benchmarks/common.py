"""Shared benchmark utilities."""
from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, warmup: int = 2, iters: int = 5):
    """Median wall time of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


def row(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.2f},{derived}")
    return {"name": name, "us_per_call": us_per_call, "derived": derived}
