"""Autotuner benchmark: search the kernel-config space for every
problem the deployed trigger pipeline emits (plus an LM flash-attention
prefill cell) and report tuned-vs-default times.

Prints harness CSV rows (``name,us_per_call,derived``) and, with
``--out``, writes the tuning trajectory JSON:

    PYTHONPATH=src python benchmarks/tuning_bench.py --out BENCH_tuning.json
    PYTHONPATH=src python -m benchmarks.run tuning
"""
from __future__ import annotations

import argparse
import json
import os
import sys

if __package__ in (None, ""):   # script invocation: put repo root first
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import row


def run(out_path: str | None = None, iters: int = 5):
    import jax

    import repro.core.caloclusternet as ccn
    from repro.core.passes.parallelize import Requirements
    from repro.core.pipeline import deploy
    from repro.data.belle2 import Belle2Config, generate
    from repro.tuning import TuningCache, autotune_graph, tune_flash_attention

    cfg = ccn.CCNConfig()
    params = ccn.init(jax.random.PRNGKey(0), cfg)
    graph = ccn.to_graph(params, cfg)
    data = generate(Belle2Config(), 64, seed=3)
    feeds = {"hits": data["feats"], "mask": data["mask"]}
    req = Requirements(design_point=3, platform="cpu",
                       precision_policy="mixed", n_hits=cfg.n_hits,
                       target_throughput=5e4, max_latency_s=2e-3)
    pipe = deploy(graph, req, calibration_feeds=feeds)

    cache = TuningCache()
    n = autotune_graph(pipe.graph, n_rows=cfg.n_hits, backend=pipe.backend,
                       cache=cache, iters=iters)
    # beyond the trigger pipeline: an LM prefill attention cell
    tune_flash_attention(8, 512, 512, 64, backend="xla", cache=cache,
                         iters=iters)
    # one real multi-candidate search: interpret-mode Pallas, where the
    # launch knobs change the launched kernel even on CPU (the 'xla'
    # rows above record heuristic defaults only — knob-inert backend)
    from repro.tuning import tune_fused_dense
    tune_fused_dense(128, 64, 64, backend="pallas_interpret", cache=cache,
                     iters=max(1, iters // 2))

    rows = []
    trajectory = []
    for key, e in sorted(cache.entries().items(),
                         key=lambda kv: kv[0].encode()):
        speedup = e.default_us / e.us if e.us else 1.0
        rows.append(row(f"tuning_{key.encode().replace(',', ';')}", e.us,
                        f"default {e.default_us:.1f}us "
                        f"speedup {speedup:.2f}x "
                        f"({e.candidates} candidates) -> {e.config}"))
        trajectory.append({
            "key": key.encode(), "config": e.config, "us": e.us,
            "default_us": e.default_us, "speedup": speedup,
            "candidates": e.candidates,
        })
    if out_path:
        with open(out_path, "w") as f:
            json.dump(trajectory, f, indent=1)
            f.write("\n")
        print(f"# tuning trajectory ({n} graph problems) -> {out_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="write the tuning trajectory JSON here")
    ap.add_argument("--iters", type=int, default=5)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(out_path=args.out, iters=args.iters)
