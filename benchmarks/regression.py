"""Benchmark-regression gate: compare a fresh run against the committed
``BENCH_baseline.json`` and exit nonzero on a >25% slowdown of any
tracked metric.

Usage:

    PYTHONPATH=src python benchmarks/regression.py --update
        # (re)measure and write BENCH_baseline.json — run on the
        # machine class you want to gate against, commit the result
    PYTHONPATH=src python benchmarks/regression.py --check
        # measure fresh, compare, exit 1 on regression; writes the
        # fresh run to BENCH_regression.json for CI artifacts

Cross-machine robustness: every run also times a fixed calibration
matmul; metrics are compared as *scores* (metric / calibration), so a
uniformly slower CI runner does not trip the gate — only a metric that
regressed relative to the machine's own speed does. Tracked workloads
are sized ≥ tens of ms per call and timed min-of-N, keeping relative
noise well under the 25% threshold.

Flake control: ``--update`` measures the whole suite ``--runs`` times
(default 3) and takes per-metric medians, so a lucky fast sample can
never become an unbeatable baseline; ``--check`` re-measures once when
it sees a regression and keeps the per-metric best before failing, so
a single slow sample cannot fail the gate either. One-sided noise is
the enemy on shared runners — both knobs bias toward the intrinsic
cost.

``--inject-slowdown F`` multiplies fresh metric times by F (not the
calibration) — the self-test that proves the gate actually fails.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

DEFAULT_THRESHOLD = 0.25
SCHEMA_VERSION = 1
_HERE = os.path.dirname(os.path.abspath(__file__))
_REPO = os.path.dirname(_HERE)
BASELINE_PATH = os.path.join(_REPO, "BENCH_baseline.json")
FRESH_PATH = os.path.join(_REPO, "BENCH_regression.json")


def _time_min(fn, *, warmup: int = 2, iters: int = 7) -> float:
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return min(ts)


# ----------------------------------------------------------- measurement ----
def measure(verbose: bool = True) -> dict:
    """Tracked metrics (seconds per call) + the calibration time."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops

    rng = np.random.default_rng(0)

    def say(name, t):
        if verbose:
            print(f"[regression] {name}: {t * 1e3:.2f} ms")

    # calibration: a fixed f32 matmul — pure machine speed, never gated.
    # The operand is a jit *argument* (a closed-over constant would be
    # folded at compile time and measure nothing).
    a = jnp.asarray(rng.normal(size=(1024, 1024)), jnp.float32)
    bmat = jnp.asarray(rng.normal(size=(1024, 1024)), jnp.float32)
    mm = jax.jit(lambda x: x @ bmat)
    calib_s = _time_min(lambda: mm(a))
    say("calibration_matmul", calib_s)

    metrics: dict[str, float] = {}

    # 1. trigger-scale fused dense, batched events (kernel hot path)
    m, k, n = 1024 * 128, 64, 64
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    metrics["fused_dense_fp32_s"] = _time_min(
        lambda: ops.fused_dense(x, w, b, backend="xla"))
    say("fused_dense_fp32", metrics["fused_dense_fp32_s"])

    # 2. int8 fused dense (the paper's 8-bit interior precision)
    xq = jnp.asarray(rng.integers(-127, 127, size=(m, k)), jnp.int8)
    wq = jnp.asarray(rng.integers(-127, 127, size=(k, n)), jnp.int8)
    xs = jnp.asarray([[0.02]], jnp.float32)
    ws = jnp.asarray(rng.uniform(1e-3, 5e-2, size=(n,)), jnp.float32)
    metrics["fused_dense_int8_s"] = _time_min(
        lambda: ops.fused_dense_int8(xq, wq, b, xs, ws, backend="xla"))
    say("fused_dense_int8", metrics["fused_dense_int8_s"])

    # 3. gravnet aggregation over a batch of events (GNN hot path)
    B, N, ds, df = 256, 128, 4, 22
    s = jnp.asarray(rng.normal(size=(B, N, ds)), jnp.float32)
    f = jnp.asarray(rng.normal(size=(B, N, df)), jnp.float32)
    mask = jnp.asarray(rng.uniform(size=(B, N)) < 0.8, jnp.float32)
    gv = jax.jit(jax.vmap(lambda a_, b_, m_: ops.gravnet_aggregate(
        a_, b_, m_, k=8, backend="xla")))
    metrics["gravnet_aggregate_s"] = _time_min(lambda: gv(s, f, mask))
    say("gravnet_aggregate", metrics["gravnet_aggregate_s"])

    # 4. flash-attention reference path (LM prefill hot path). Sized to
    # tens of ms: single-digit-ms workloads flake past the 25% gate on
    # shared CI runners.
    q = jnp.asarray(rng.normal(size=(8, 1024, 64)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(8, 1024, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(8, 1024, 64)), jnp.float32)
    metrics["flash_attention_s"] = _time_min(
        lambda: ops.flash_attention(q, kk, v, backend="xla"))
    say("flash_attention", metrics["flash_attention_s"])

    # 5. end-to-end deployed trigger pipeline (design ③, mixed precision)
    import repro.core.caloclusternet as ccn
    from repro.core.passes.parallelize import Requirements
    from repro.core.pipeline import deploy
    from repro.data.belle2 import Belle2Config, generate
    cfg = ccn.CCNConfig()
    params = ccn.init(jax.random.PRNGKey(0), cfg)
    graph = ccn.to_graph(params, cfg)
    data = generate(Belle2Config(), 256, seed=11)
    feeds = {"hits": data["feats"], "mask": data["mask"]}
    calib_feeds = {"hits": data["feats"][:32], "mask": data["mask"][:32]}
    req = Requirements(design_point=3, platform="cpu",
                       precision_policy="mixed", n_hits=cfg.n_hits,
                       target_throughput=5e4, max_latency_s=2e-3)
    pipe = deploy(graph, req, calibration_feeds=calib_feeds)
    metrics["pipeline_design3_s"] = _time_min(
        lambda: pipe(feeds), warmup=1, iters=3)
    say("pipeline_design3", metrics["pipeline_design3_s"])

    return {
        "schema": SCHEMA_VERSION,
        "backend": jax.default_backend(),
        "calibration_s": calib_s,
        "metrics": metrics,
    }


# ------------------------------------------------------------- comparison ----
def compare(baseline: dict, fresh: dict,
            threshold: float = DEFAULT_THRESHOLD) -> list[dict]:
    """Regressions: fresh score (metric/calibration) worse than baseline
    score by more than ``threshold`` relative. Metrics missing from the
    fresh run count as regressions (a deleted benchmark must not
    silently shrink coverage); new fresh metrics are ignored until
    ``--update`` adds them to the baseline."""
    regressions = []
    base_cal = float(baseline["calibration_s"])
    fresh_cal = float(fresh["calibration_s"])
    for name, base_t in baseline["metrics"].items():
        fresh_t = fresh["metrics"].get(name)
        if fresh_t is None:
            regressions.append({"metric": name, "missing": True})
            continue
        base_score = float(base_t) / base_cal
        fresh_score = float(fresh_t) / fresh_cal
        ratio = fresh_score / base_score if base_score > 0 \
            else float("inf")
        if ratio > 1.0 + threshold:
            regressions.append({
                "metric": name, "missing": False,
                "baseline_s": float(base_t), "fresh_s": float(fresh_t),
                "baseline_score": base_score, "fresh_score": fresh_score,
                "slowdown": ratio,
            })
    return regressions


def _median_combine(runs: list[dict]) -> dict:
    """Per-metric median across whole-suite runs; calibration keeps the
    min (the best estimate of intrinsic machine speed)."""
    import statistics
    out = dict(runs[0])
    out["calibration_s"] = min(r["calibration_s"] for r in runs)
    out["metrics"] = {
        name: statistics.median(r["metrics"][name] for r in runs)
        for name in runs[0]["metrics"]
    }
    return out


def _load(path: str) -> dict:
    with open(path) as f:
        d = json.load(f)
    if d.get("schema") != SCHEMA_VERSION:
        raise ValueError(f"{path}: schema {d.get('schema')!r} != "
                         f"{SCHEMA_VERSION}")
    return d


def _dump(d: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(d, f, indent=1, sort_keys=True)
        f.write("\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    mode = ap.add_mutually_exclusive_group(required=True)
    mode.add_argument("--check", action="store_true",
                      help="measure and compare against the baseline")
    mode.add_argument("--update", action="store_true",
                      help="measure and (re)write the baseline")
    ap.add_argument("--baseline", default=BASELINE_PATH)
    ap.add_argument("--out", default=FRESH_PATH,
                    help="where --check writes the fresh measurement")
    ap.add_argument("--fresh", default=None,
                    help="compare this saved run instead of measuring")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="relative slowdown that fails the gate "
                         "(default 0.25 = 25%%)")
    ap.add_argument("--inject-slowdown", type=float, default=None,
                    metavar="F", help="multiply fresh metric times by F "
                    "(gate self-test)")
    ap.add_argument("--runs", type=int, default=3,
                    help="--update: whole-suite runs to median over")
    ap.add_argument("--retries", type=int, default=1,
                    help="--check: re-measures before failing")
    args = ap.parse_args(argv)

    if args.update:
        result = _median_combine([measure() for _ in range(args.runs)])
        _dump(result, args.baseline)
        print(f"[regression] baseline -> {args.baseline} "
              f"({len(result['metrics'])} metrics, median of "
              f"{args.runs} runs)")
        return 0

    if not os.path.exists(args.baseline):
        print(f"[regression] FAIL: no baseline at {args.baseline} "
              f"(run --update and commit it)")
        return 2
    baseline = _load(args.baseline)
    fresh = _load(args.fresh) if args.fresh else measure()
    if args.inject_slowdown is not None:
        fresh = dict(fresh)
        fresh["metrics"] = {k: v * args.inject_slowdown
                            for k, v in fresh["metrics"].items()}
        print(f"[regression] injected {args.inject_slowdown}x slowdown "
              f"into fresh metrics (self-test)")
    regs = compare(baseline, fresh, args.threshold)
    # flake control: a regression verdict gets re-measured before it
    # fails the gate (never when replaying a saved run or self-testing
    # with an injected slowdown — a retry would erase the injection)
    can_retry = args.fresh is None and args.inject_slowdown is None
    retries_left = args.retries if can_retry else 0
    while regs and retries_left > 0:
        retries_left -= 1
        print(f"[regression] {len(regs)} regression(s) — re-measuring "
              f"to rule out a flake")
        again = measure()
        fresh["calibration_s"] = min(fresh["calibration_s"],
                                     again["calibration_s"])
        fresh["metrics"] = {
            k: min(v, again["metrics"].get(k, v))
            for k, v in fresh["metrics"].items()}
        regs = compare(baseline, fresh, args.threshold)
    if args.out:
        _dump(fresh, args.out)
        print(f"[regression] fresh run -> {args.out}")
    for name, base_t in sorted(baseline["metrics"].items()):
        fresh_t = fresh["metrics"].get(name)
        if fresh_t is None:
            print(f"[regression] {name}: MISSING from fresh run")
            continue
        ratio = (float(fresh_t) / float(fresh["calibration_s"])) / \
                (float(base_t) / float(baseline["calibration_s"]))
        flag = " << REGRESSION" if ratio > 1.0 + args.threshold else ""
        print(f"[regression] {name}: base {float(base_t) * 1e3:.2f} ms, "
              f"fresh {float(fresh_t) * 1e3:.2f} ms, "
              f"normalized x{ratio:.2f}{flag}")
    if regs:
        print(f"[regression] FAIL: {len(regs)} metric(s) regressed "
              f"beyond {args.threshold:.0%}")
        return 1
    print(f"[regression] OK: {len(baseline['metrics'])} metrics within "
          f"{args.threshold:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
