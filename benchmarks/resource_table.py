"""Table I analogue: per-design resource utilization.

The paper reports FF/LUT/DSP/BRAM + AIE tile/compute/memory utilization.
The TPU resource vector: per-segment FLOPs/event, activation bytes/event,
weight bytes, VMEM working set (vs the 128 MiB v5e budget), segment count
per target, and the parallelization factors — emitted per design point
for both detector variants.
"""
from __future__ import annotations

import jax

from benchmarks.common import row
from repro.core import caloclusternet as ccn
from repro.core.passes.parallelize import Requirements
from repro.core.pipeline import deploy
from repro.data.belle2 import Belle2Config, generate


def run():
    rows = []
    for detector, cfg, gen in (
            ("current", ccn.current_detector_config(),
             Belle2Config(n_crystals=576, grid=(24, 24), n_hits=32,
                          noise_rate=8.0)),
            ("upgrade", ccn.CCNConfig(), Belle2Config())):
        params = ccn.init(jax.random.PRNGKey(0), cfg)
        graph = ccn.to_graph(params, cfg)
        data = generate(gen, 32, seed=3)
        calib = {"hits": data["feats"], "mask": data["mask"]}
        for dp in (1, 2, 3):
            req = Requirements(design_point=dp, platform="tpu",
                               precision_policy="mixed",
                               n_hits=cfg.n_hits, target_throughput=3e6,
                               max_latency_s=10e-6)
            pipe = deploy(graph, req, calibration_feeds=calib,
                          kernel_backend="xla")
            rep = pipe.resource_report()
            tot_fl = sum(r["flops_per_event"] for r in rep)
            tot_vmem = sum(r["vmem_working_set"] for r in rep)
            mxu_segs = sum(1 for r in rep if r["target"] == "mxu")
            xla_segs = len(rep) - mxu_segs
            int8_ops = sum(1 for op in pipe.graph
                           if op.precision == "int8")
            rows.append(row(
                f"tableI_design{dp}_{detector}",
                pipe.model_latency() * 1e6,
                f"segments={len(rep)} (mxu={mxu_segs} xla={xla_segs}) "
                f"P={pipe.par['P_mxu']}/{pipe.par['P_xla']} "
                f"flops/ev={tot_fl:,.0f} "
                f"vmem={tot_vmem / (1 << 20):.2f}MiB "
                f"({100 * tot_vmem / (128 << 20):.1f}% of v5e) "
                f"int8_ops={int8_ops}"))
    return rows


if __name__ == "__main__":
    run()
