"""Fault-tolerance degradation benchmark: throughput + tail latency
under injected failures and a dead replica.

A trigger host cannot assume healthy lanes: a device wedges, a driver
resets, a replica dies mid-run.  This benchmark drives the sharded
service with the same open-loop generator as ``serving_latency`` (no
coordinated omission) while a seeded :class:`repro.serving.FaultPlan`
injects batch failures, and measures how gracefully the service
degrades with the circuit breaker + failover re-dispatch enabled:

  rate=0.00..0.20 — each dispatched batch fails with probability p on
                    every replica (transient-fault curve);
  one_dead        — one replica of four fails every batch it touches
                    (hard lane loss); the breaker must open on it and
                    failover must re-dispatch its traffic.

Writes ``BENCH_faults.json`` with per-scenario ok-throughput, p99
latency, error/shed counts, and the fault-tolerance counters.
``--check`` enforces the chaos gates CI runs on every PR:

  * exactly-once — every submitted event resolves exactly once, and
    the shared releaser's released count equals the submission count,
    in every scenario (faulty batches included);
  * degradation floor — with 1 of 4 replicas dead, ok-event throughput
    stays >= ``--min-dead-ratio`` (default 0.6x) of the healthy run's,
    and the client-visible error fraction stays <= ``--max-err-frac``
    (default 5%).

Usage:
    PYTHONPATH=src python benchmarks/serving_faults.py \
        --out BENCH_faults.json --check
    PYTHONPATH=src python -m benchmarks.run faults
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import sys
import threading
import time

import numpy as np

if __package__ in (None, ""):   # script invocation: put repo root first
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from repro.serving import FaultPlan, ShardedTriggerService

# sized for CI: ~0.5 s of streamed traffic per scenario, offered well
# below the healthy lane capacity (and below the 3-replica capacity of
# the one-dead scenario), so the degradation ratio measures fault
# handling — retries, breaker trips, error leakage — not saturation.
OFFERED_EV_S = 3000.0
EVENTS = 1500
N_REPLICAS = 4
MICROBATCH = 8
SERVICE_US = 1500.0
WINDOW_MS = 4.0
MAX_RETRIES = 2
FAULT_RATES = (0.0, 0.05, 0.1, 0.2)
MIN_DEAD_RATIO = 0.6
MAX_ERR_FRAC = 0.05
ATTEMPTS = 3


def synthetic_infer(service_us: float):
    """Fixed-service-time lane (releases the GIL like a device
    dispatch), then a trivial numpy decision so the result is
    event-shaped."""

    def infer(feeds):
        time.sleep(service_us * 1e-6)
        x = feeds["hits"]
        energy = x.sum(axis=tuple(range(1, x.ndim)))
        return {"trigger": energy > 0.0, "energy": energy}

    return infer


def _pct(xs, p):
    return float(np.percentile(np.asarray(xs, float), p))


def run_scenario(name: str, plan_spec: str | None, *, seed: int,
                 offered_ev_s: float, events: int, n_replicas: int,
                 microbatch: int, service_us: float,
                 window_ms: float, max_retries: int) -> dict:
    """Stream ``events`` through one faulted service at the offered
    rate; return throughput/latency plus the fault-tolerance ledger."""
    faults = FaultPlan.parse(plan_spec, seed=seed) if plan_spec else None
    svc = ShardedTriggerService(synthetic_infer(service_us),
                                n_replicas=n_replicas,
                                microbatch=microbatch,
                                window_s=window_ms * 1e-3, devices=None,
                                inflight=2, faults=faults, breaker=True,
                                max_retries=max_retries)
    event = {"hits": np.ones((32, 4), np.float32)}
    # warm the lanes outside the measured window; warm futures may hit
    # an injected fault (one_dead), so tolerate exceptions here.
    warm = [svc.submit(dict(event)) for _ in range(2 * microbatch)]
    for f in warm:
        f.exception(timeout=60)
    svc.drain()
    warm_errs = sum(1 for f in warm if f.exception() is not None)

    done_at = [0.0] * events
    resolved = [0] * events   # exactly-once ledger: callback fire count
    done_evt = threading.Event()
    remaining = [events]
    lock = threading.Lock()

    def make_cb(i):
        def cb(_fut):
            done_at[i] = time.perf_counter()
            with lock:
                resolved[i] += 1
                remaining[0] -= 1
                if not remaining[0]:
                    done_evt.set()
        return cb

    interarrival = 1.0 / offered_ev_s
    sched = [0.0] * events
    futs = []
    # keep the collector out of the measured window (same treatment as
    # serving_latency: a gen-2 pause dwarfs the latencies under test)
    gc_was_enabled = gc.isenabled()
    gc.collect()
    gc.disable()
    try:
        t0 = time.perf_counter() + 5 * interarrival
        for i in range(events):
            target = t0 + i * interarrival
            now = time.perf_counter()
            if target > now:
                time.sleep(target - now)
            sched[i] = target
            fut = svc.submit(event)
            fut.add_done_callback(make_cb(i))
            futs.append(fut)
        completed = done_evt.wait(timeout=120)
    finally:
        if gc_was_enabled:
            gc.enable()
    assert completed, f"scenario {name!r} did not complete"
    svc.drain()
    ok = sum(1 for f in futs if f.exception() is None)
    err = events - ok
    agg = svc.stats.summary()
    ft = svc.fault_tolerance_summary()
    # releaser accounting: warm + measured submissions all released
    released = svc._releaser.released
    submitted = len(warm) + events
    exactly_once = (all(n == 1 for n in resolved)
                    and released == submitted)
    svc.close()

    ok_lats = [done_at[i] - sched[i]
               for i in range(events) if futs[i].exception() is None]
    wall = max(done_at) - t0
    return {
        "scenario": name,
        "plan": plan_spec or "",
        "seed": seed,
        "events": events,
        "ok": ok,
        "err": err,
        "err_frac": err / events,
        "warm_errs": warm_errs,
        "ok_ev_s": ok / wall,
        "p50_ms": _pct(ok_lats, 50) * 1e3 if ok_lats else float("nan"),
        "p99_ms": _pct(ok_lats, 99) * 1e3 if ok_lats else float("nan"),
        "shed": agg["shed"],
        "retried": agg["retried"],
        "failed_over": agg["failed_over"],
        "breaker_trips": sum(h.trips for h in svc.healths.values()),
        "breaker": ft["breaker"],
        "exactly_once": exactly_once,
    }


def _measure_all(*, seed, offered_ev_s, events, n_replicas, microbatch,
                 service_us, window_ms, max_retries,
                 fault_rates) -> list[dict]:
    """One full sweep: the transient-fault-rate curve, then the
    dead-replica scenario, all back to back on the same host."""
    scenarios = []
    print("scenario,ok_ev_s,p99_ms,err_frac,retried,failed_over,"
          "breaker_trips")
    specs = [(f"rate={r:.2f}", f"fail:p={r}" if r else None)
             for r in fault_rates]
    specs.append(("one_dead", f"fail:p=1.0,replica={n_replicas - 1}"))
    for name, spec in specs:
        r = run_scenario(name, spec, seed=seed,
                         offered_ev_s=offered_ev_s, events=events,
                         n_replicas=n_replicas, microbatch=microbatch,
                         service_us=service_us, window_ms=window_ms,
                         max_retries=max_retries)
        scenarios.append(r)
        print(f"{name},{r['ok_ev_s']:.0f},{r['p99_ms']:.1f},"
              f"{r['err_frac']:.3f},{r['retried']},{r['failed_over']},"
              f"{r['breaker_trips']}")
    return scenarios


def run(out_path: str | None = None, *, check: bool = False,
        seed: int = 0, offered_ev_s: float = OFFERED_EV_S,
        events: int = EVENTS, n_replicas: int = N_REPLICAS,
        microbatch: int = MICROBATCH, service_us: float = SERVICE_US,
        window_ms: float = WINDOW_MS, max_retries: int = MAX_RETRIES,
        fault_rates=FAULT_RATES, min_dead_ratio: float = MIN_DEAD_RATIO,
        max_err_frac: float = MAX_ERR_FRAC,
        attempts: int = ATTEMPTS) -> dict:
    """Degradation sweep; raises RuntimeError when ``check`` is set and
    a chaos gate fails.

    The exactly-once gate is deterministic and never retried away; the
    throughput-ratio gate can be poisoned by a one-off host stall, so
    a missed ratio re-runs the whole sweep (up to ``attempts``) — a
    real fault-handling regression fails every sweep, host noise
    doesn't."""
    for attempt in range(max(attempts, 1)):
        if attempt:
            print(f"[serving_faults] ratio gate missed, retrying "
                  f"(attempt {attempt + 1}/{attempts})")
        scenarios = _measure_all(
            seed=seed, offered_ev_s=offered_ev_s, events=events,
            n_replicas=n_replicas, microbatch=microbatch,
            service_us=service_us, window_ms=window_ms,
            max_retries=max_retries, fault_rates=fault_rates)
        by_name = {s["scenario"]: s for s in scenarios}
        healthy = by_name["rate=0.00"]
        one_dead = by_name["one_dead"]
        ratio = one_dead["ok_ev_s"] / healthy["ok_ev_s"]
        exactly_once = all(s["exactly_once"] for s in scenarios)
        err_ok = one_dead["err_frac"] <= max_err_frac
        ratio_ok = ratio >= min_dead_ratio
        gate_ok = exactly_once and err_ok and ratio_ok
        if not exactly_once or gate_ok:
            break   # retries only paper over throughput noise
    result = {
        "mode": "synthetic",
        "offered_ev_s": offered_ev_s,
        "events": events,
        "n_replicas": n_replicas,
        "microbatch": microbatch,
        "service_us": service_us,
        "max_retries": max_retries,
        "seed": seed,
        "scenarios": scenarios,
        "degradation": {
            "healthy_ok_ev_s": healthy["ok_ev_s"],
            "one_dead_ok_ev_s": one_dead["ok_ev_s"],
            "ratio": ratio,
        },
        "totals": {
            "shed": sum(s["shed"] for s in scenarios),
            "retried": sum(s["retried"] for s in scenarios),
            "failed_over": sum(s["failed_over"] for s in scenarios),
            "breaker_trips": sum(s["breaker_trips"] for s in scenarios),
        },
        "check": {
            "min_dead_ratio": min_dead_ratio,
            "max_err_frac": max_err_frac,
            "exactly_once": exactly_once,
            "dead_ratio_ok": ratio_ok,
            "dead_err_frac_ok": err_ok,
            "pass": gate_ok,
        },
    }
    if out_path:
        with open(out_path, "w") as f:
            json.dump(result, f, indent=1)
            f.write("\n")
        print(f"[serving_faults] wrote {out_path}")
    print(f"[serving_faults] one-dead/healthy ok-throughput = "
          f"{one_dead['ok_ev_s']:.0f}/{healthy['ok_ev_s']:.0f} ev/s "
          f"(ratio {ratio:.2f}, gate >= {min_dead_ratio}), one-dead "
          f"err_frac {one_dead['err_frac']:.3f} (gate <= "
          f"{max_err_frac}), exactly_once={exactly_once}")
    if check and not gate_ok:
        raise RuntimeError(
            f"serving_faults chaos gate failed: exactly_once="
            f"{exactly_once}, one-dead ratio {ratio:.2f} "
            f"(floor {min_dead_ratio}), one-dead err_frac "
            f"{one_dead['err_frac']:.3f} (limit {max_err_frac})")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=0,
                    help="FaultPlan seed (deterministic replay)")
    ap.add_argument("--offered", type=float, default=OFFERED_EV_S,
                    help="open-loop offered load, events/s")
    ap.add_argument("--events", type=int, default=EVENTS)
    ap.add_argument("--replicas", type=int, default=N_REPLICAS)
    ap.add_argument("--microbatch", type=int, default=MICROBATCH)
    ap.add_argument("--service-us", type=float, default=SERVICE_US,
                    help="synthetic per-launch service time")
    ap.add_argument("--window-ms", type=float, default=WINDOW_MS)
    ap.add_argument("--max-retries", type=int, default=MAX_RETRIES)
    ap.add_argument("--min-dead-ratio", type=float,
                    default=MIN_DEAD_RATIO,
                    help="--check fails unless one-dead ok-throughput "
                         ">= this fraction of the healthy run's")
    ap.add_argument("--max-err-frac", type=float, default=MAX_ERR_FRAC,
                    help="--check fails when the one-dead scenario "
                         "leaks more than this client error fraction")
    ap.add_argument("--attempts", type=int, default=ATTEMPTS,
                    help="sweep retries before the ratio gate fails "
                         "(rides out one-off host stalls)")
    ap.add_argument("--out", default="/tmp/serving_faults.json")
    ap.add_argument("--check", action="store_true",
                    help="enforce the chaos gates")
    args = ap.parse_args()
    try:
        run(args.out, check=args.check, seed=args.seed,
            offered_ev_s=args.offered, events=args.events,
            n_replicas=args.replicas, microbatch=args.microbatch,
            service_us=args.service_us, window_ms=args.window_ms,
            max_retries=args.max_retries,
            min_dead_ratio=args.min_dead_ratio,
            max_err_frac=args.max_err_frac, attempts=args.attempts)
    except RuntimeError as e:
        raise SystemExit(str(e))


if __name__ == "__main__":
    main()
