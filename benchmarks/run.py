"""Benchmark harness entry point: one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  fig5_*      — Fig 5a/5b: designs ①②③ vs baselines (latency/throughput)
  tableI_*    — Table I: per-design resource utilization
  p_sweep_*   — §III-A spatial-parallelization search curve
  kernel_*    — kernel-level optimization microbenchmarks
  roofline_*  — §Roofline terms per (arch × shape) from the dry-run
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (design_points, kernels_bench,
                            parallelization_sweep, resource_table,
                            roofline)
    print("name,us_per_call,derived")
    only = sys.argv[1] if len(sys.argv) > 1 else None
    sections = {
        "design_points": lambda: (design_points.run("upgrade"),
                                  design_points.run("current")),
        "resource_table": resource_table.run,
        "parallelization_sweep": parallelization_sweep.run,
        "kernels": kernels_bench.run,
        "roofline": roofline.run,
    }
    for name, fn in sections.items():
        if only and only != name:
            continue
        try:
            fn()
        except Exception as e:  # report and continue
            print(f"{name},nan,ERROR {e!r}")


if __name__ == '__main__':
    main()
