"""Benchmark harness entry point: one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  fig5_*      — Fig 5a/5b: designs ①②③ vs baselines (latency/throughput)
  tableI_*    — Table I: per-design resource utilization
  p_sweep_*   — §III-A spatial-parallelization search curve
  kernel_*    — kernel-level optimization microbenchmarks
  roofline_*  — §Roofline terms per (arch × shape) from the dry-run
  tuning_*    — autotuned vs default kernel configs (tuning cache)
  batching_*  — per-event vs batch-packed launches across occupancy
                buckets (the occupancy-bucketed serving path)

A failing section is still reported as a ``name,nan,ERROR ...`` row (so
one broken figure never hides the others), but the run exits nonzero —
CI must see a broken benchmark section, not a green job with NaN rows.
"""
from __future__ import annotations

import sys


def main(argv: list[str] | None = None) -> int:
    from benchmarks import (batching, design_points, kernels_bench,
                            parallelization_sweep, resource_table,
                            roofline, tuning_bench)
    argv = sys.argv[1:] if argv is None else argv
    print("name,us_per_call,derived")
    only = argv[0] if argv else None
    sections = {
        "design_points": lambda: (design_points.run("upgrade"),
                                  design_points.run("current")),
        "resource_table": resource_table.run,
        "parallelization_sweep": parallelization_sweep.run,
        "kernels": kernels_bench.run,
        "roofline": roofline.run,
        "tuning": tuning_bench.run,
        "batching": batching.run,
    }
    if only is not None and only not in sections:
        print(f"unknown section {only!r}; have: {', '.join(sections)}",
              file=sys.stderr)
        return 2
    failed = []
    for name, fn in sections.items():
        if only and only != name:
            continue
        try:
            fn()
        except Exception as e:  # report and continue to the next section
            print(f"{name},nan,ERROR {e!r}")
            failed.append(name)
    if failed:
        print(f"FAILED sections: {', '.join(failed)}", file=sys.stderr)
        return 1
    return 0


if __name__ == '__main__':
    sys.exit(main())
