"""Benchmark harness entry point: one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  fig5_*      — Fig 5a/5b: designs ①②③ vs baselines (latency/throughput)
  tableI_*    — Table I: per-design resource utilization
  p_sweep_*   — §III-A spatial-parallelization search curve
  kernel_*    — kernel-level optimization microbenchmarks
  roofline_*  — §Roofline terms per (arch × shape) from the dry-run
  tuning_*    — autotuned vs default kernel configs (tuning cache)
  batching_*  — per-event vs batch-packed launches across occupancy
                buckets (the occupancy-bucketed serving path)
  fusion_*    — fused GravNet-block megakernel vs the unfused
                dense→aggregate→dense chain (launch-count fusion)
  latency     — open-loop p50/p95/p99 serving latency, streaming vs
                deadline replica loop, with the p99 SLO gate enforced
  faults      — fault-injection degradation curve (throughput + p99 vs
                fault rate, plus one dead replica of four) with the
                chaos gates enforced (exactly-once, >=0.6x floor)

A failing section is still reported as a ``name,nan,ERROR ...`` row (so
one broken figure never hides the others), but the run exits nonzero —
CI must see a broken benchmark section, not a green job with NaN rows.

Every run also writes ``BENCH_summary.json``: one entry per executed
section (ok flag, a scalar headline score where the section defines
one, wall seconds) stamped with the git sha and a timestamp, so the
perf trajectory across PRs is machine-readable instead of scattered
per-file.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

_HERE = os.path.dirname(os.path.abspath(__file__))
_REPO = os.path.dirname(_HERE)
SUMMARY_PATH = os.path.join(_REPO, "BENCH_summary.json")


def _git_sha() -> str:
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, cwd=_REPO,
                             timeout=10)
        return out.stdout.strip() or "unknown"
    except Exception:   # noqa: BLE001 — summary must never break the run
        return "unknown"


def _score(fn, result):
    """Headline scalar per section (higher = better); None when the
    section's return value does not define one."""
    try:
        return fn(result)
    except Exception:   # noqa: BLE001
        return None


# per-section headline-score extractors, applied to the section's
# return value (all are defensive — a reshaped return yields None,
# never a crashed summary)
_SCORES = {
    "batching": lambda r: max(p["speedup"] for p in r
                              if p["microbatch"] >= 8),
    "fusion": lambda r: min(min(p["block_speedup"], p["int8_speedup"])
                            for p in r if p["microbatch"] >= 8),
    # p99 speedup of the streaming loop over the deadline loop
    "latency": lambda r: (r["loops"]["deadline"]["p99_us"]
                          / r["loops"]["streaming"]["p99_us"]),
    # one-dead-replica ok-throughput as a fraction of healthy
    "faults": lambda r: r["degradation"]["ratio"],
}


def main(argv: list[str] | None = None) -> int:
    from benchmarks import (batching, design_points, fusion, kernels_bench,
                            parallelization_sweep, resource_table,
                            roofline, serving_faults, serving_latency,
                            tuning_bench)
    argv = sys.argv[1:] if argv is None else argv
    print("name,us_per_call,derived")
    only = argv[0] if argv else None
    sections = {
        "design_points": lambda: (design_points.run("upgrade"),
                                  design_points.run("current")),
        "resource_table": resource_table.run,
        "parallelization_sweep": parallelization_sweep.run,
        "kernels": kernels_bench.run,
        "roofline": roofline.run,
        "tuning": tuning_bench.run,
        "batching": batching.run,
        "fusion": fusion.run,
        # check=True: a missed p99 SLO raises, so the section reports
        # failed and the run exits nonzero
        "latency": lambda: serving_latency.run(
            os.path.join(_REPO, "BENCH_latency.json"), check=True),
        # check=True: a chaos-gate miss (exactly-once violation or a
        # degradation floor breach) raises, failing the run
        "faults": lambda: serving_faults.run(
            os.path.join(_REPO, "BENCH_faults.json"), check=True),
    }
    if only is not None and only not in sections:
        print(f"unknown section {only!r}; have: {', '.join(sections)}",
              file=sys.stderr)
        return 2
    failed = []
    summary: dict[str, dict] = {}
    for name, fn in sections.items():
        if only and only != name:
            continue
        t0 = time.perf_counter()
        try:
            result = fn()
            entry = {"ok": True,
                     "score": _score(_SCORES[name], result)
                     if name in _SCORES else None}
        except Exception as e:  # report and continue to the next section
            print(f"{name},nan,ERROR {e!r}")
            failed.append(name)
            entry = {"ok": False, "score": None}
        entry["seconds"] = round(time.perf_counter() - t0, 3)
        summary[name] = entry
    try:
        with open(SUMMARY_PATH, "w") as f:
            json.dump({"schema": 1, "git_sha": _git_sha(),
                       "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
                       "sections": summary}, f, indent=1)
            f.write("\n")
        print(f"[run] wrote {SUMMARY_PATH}", file=sys.stderr)
    except OSError as e:
        print(f"[run] WARNING: could not write summary: {e}",
              file=sys.stderr)
    if failed:
        print(f"FAILED sections: {', '.join(failed)}", file=sys.stderr)
        return 1
    return 0


if __name__ == '__main__':
    sys.exit(main())
