"""Serving scaling sweep: replica count x micro-batch size.

Drives the sharded trigger service over a grid of (replicas,
microbatch) operating points and emits a JSON trajectory with
aggregate and per-replica throughput/latency-budget stats — the
scaling analogue of the paper's Fig. 5 throughput curves.

Two inference backends:

  synthetic (default) — a fixed-service-time model of an accelerator
      lane (``--service-us`` per batch, GIL-free wait + a small numpy
      trigger computation).  Replica scaling is then governed purely by
      the serving layer, so aggregate throughput must grow
      monotonically with replica count at fixed micro-batch — the
      acceptance check this benchmark enforces with ``--check``.
  pipeline — a real ``deploy()``-produced CaloClusterNet executable
      shared by all (virtual) replicas; useful for profiling the
      serving layer against actual compute, but thread scaling then
      depends on how much the backend releases the GIL.

A third mode measures **batch packing** (the occupancy-bucketed
serving path): ``--mode batching`` streams current-detector events
through the service once with ``microbatch=1`` against the per-event
executable (the pre-batching baseline: one launch per event) and once
per requested micro-batch against the matching batch-packed
executable (``deploy(batch=mb)``, one launch per micro-batch), and
records the events/s speedup in the JSON's ``batching`` section.
``--check`` then requires ≥1.5× for every micro-batch ≥ 8.

Usage:
    PYTHONPATH=src python benchmarks/serving_scaling.py \
        --out /tmp/serving_scaling.json --check
    PYTHONPATH=src python benchmarks/serving_scaling.py \
        --mode batching --out /tmp/serving_batching.json --check
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.serving import ShardedTriggerService


# ----------------------------------------------------------- inference ----
def synthetic_infer(service_us: float):
    """Fixed-service-time lane: sleep models the accelerator occupancy
    (releases the GIL, like a real device dispatch), then a trivial
    numpy trigger decision so the result shape is event-like."""

    def infer(feeds):
        time.sleep(service_us * 1e-6)
        x = feeds["hits"]
        energy = x.sum(axis=tuple(range(1, x.ndim)))
        return {"trigger": energy > 0.0, "energy": energy}

    return infer


def pipeline_infer(batch: int = 1):
    """Current-detector CaloClusterNet executable; ``batch > 1``
    deploys the batch-packed form (one launch per micro-batch)."""
    import jax

    from repro.core import caloclusternet as ccn
    from repro.core.passes.parallelize import Requirements
    from repro.core.pipeline import deploy
    from repro.data.belle2 import Belle2Config, generate

    cfg = ccn.CCNConfig(n_hits=32, n_crystals=576)
    gen = Belle2Config(n_crystals=576, grid=(24, 24), n_hits=32,
                       noise_rate=8.0)
    params = ccn.init(jax.random.PRNGKey(0), cfg)
    graph = ccn.to_graph(params, cfg)
    calib = generate(gen, 32, seed=1)
    req = Requirements(design_point=3, platform="cpu",
                       precision_policy="mixed", n_hits=cfg.n_hits,
                       target_throughput=2e4, max_latency_s=2e-3)
    pipe = deploy(graph, req, calibration_feeds={
        "hits": calib["feats"], "mask": calib["mask"]}, batch=batch)

    def infer(feeds):
        return pipe({"hits": feeds["hits"], "mask": feeds["mask"]})

    def make_event(rng):
        i = rng.integers(0, 32)
        return {"hits": calib["feats"][i], "mask": calib["mask"][i]}

    return infer, make_event


# --------------------------------------------------------------- sweep ----
def run_point(infer, make_event, *, replicas, microbatch, events,
              window_s, policy):
    rng = np.random.default_rng(0)
    evs = [make_event(rng) for _ in range(events)]
    # construct after event generation so the stats clocks (which back
    # aggregate/per-replica throughput_ev_s) start at streaming time
    svc = ShardedTriggerService(infer, n_replicas=replicas,
                                microbatch=microbatch, window_s=window_s,
                                policy=policy, devices="auto")
    t0 = time.perf_counter()
    futs = [svc.submit(e) for e in evs]
    for f in futs:
        f.result(timeout=300)
    wall = time.perf_counter() - t0
    svc.drain()
    summary = svc.stats.summary()
    svc.close()
    return {
        "replicas": replicas,
        "microbatch": microbatch,
        "events": events,
        "wall_s": wall,
        "throughput_ev_s": events / wall,
        "aggregate": summary,
    }


# ------------------------------------------------------------ batching ----
def run_batching(args):
    """Per-event baseline vs batch-packed micro-batches through the
    real serving stack on the current-detector config."""
    mbs = sorted(mb for mb in args.microbatches if mb > 1)
    points = []
    for mb in [1] + mbs:
        infer, make_event = pipeline_infer(batch=mb)
        # warm the compile cache so the measurement is steady-state
        e = make_event(np.random.default_rng(0))
        infer({k: np.stack([v] * mb) for k, v in e.items()})
        pt = run_point(infer, make_event, replicas=1, microbatch=mb,
                       events=args.events,
                       window_s=args.window_ms * 1e-3, policy=args.policy)
        points.append(pt)
    base = points[0]["throughput_ev_s"]
    section = []
    print("microbatch,throughput_ev_s,speedup_vs_per_event")
    for pt in points:
        speedup = pt["throughput_ev_s"] / base
        section.append({
            "microbatch": pt["microbatch"],
            "events": pt["events"],
            "throughput_ev_s": pt["throughput_ev_s"],
            "per_event_ev_s": base,
            "speedup_vs_per_event": speedup,
            "aggregate": pt["aggregate"],
        })
        print(f"{pt['microbatch']},{pt['throughput_ev_s']:.0f},"
              f"{speedup:.2f}")
    return section


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["synthetic", "pipeline", "batching"],
                    default="synthetic")
    ap.add_argument("--replicas", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--microbatches", type=int, nargs="+",
                    default=[8, 16, 32])
    ap.add_argument("--events", type=int, default=1024)
    ap.add_argument("--service-us", type=float, default=20000.0,
                    help="synthetic per-batch service time; keep it "
                         "large enough that lane capacity (not host "
                         "python overhead) is the binding constraint")
    ap.add_argument("--window-ms", type=float, default=50.0)
    ap.add_argument("--policy", default="round_robin",
                    choices=["round_robin", "least_loaded"])
    ap.add_argument("--out", default="/tmp/serving_scaling.json")
    ap.add_argument("--check", action="store_true",
                    help="fail unless aggregate throughput is monotone "
                         "in replica count at every micro-batch size")
    args = ap.parse_args()

    if args.mode == "batching":
        section = run_batching(args)
        result = {"mode": "batching", "detector": "current",
                  "events": args.events, "batching": section}
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"[serving_scaling] wrote {args.out}")
        if args.check:
            bad = [p for p in section
                   if p["microbatch"] >= 8
                   and p["speedup_vs_per_event"] < 1.5]
            for p in section:
                print(f"[serving_scaling] batching mb={p['microbatch']} "
                      f"{p['throughput_ev_s']:.0f} ev/s "
                      f"({p['speedup_vs_per_event']:.2f}x per-event)")
            if bad:
                raise SystemExit(
                    "serving_scaling: batch packing under 1.5x vs the "
                    f"per-event baseline at {[p['microbatch'] for p in bad]}")
        return

    if args.mode == "synthetic":
        infer = synthetic_infer(args.service_us)

        def make_event(rng):
            return {"hits": rng.normal(size=(32, 4)).astype(np.float32)}
    else:
        infer, make_event = pipeline_infer()
        # warm the compile cache for every micro-batch shape up front
        for mb in args.microbatches:
            e = make_event(np.random.default_rng(0))
            infer({k: np.stack([v] * mb) for k, v in e.items()})

    print("replicas,microbatch,events,wall_s,throughput_ev_s,"
          "p99_us,queue_wait_us,dispatch_us,compute_us")
    trajectory = []
    for mb in args.microbatches:
        for r in args.replicas:
            pt = run_point(infer, make_event, replicas=r, microbatch=mb,
                           events=args.events,
                           window_s=args.window_ms * 1e-3,
                           policy=args.policy)
            trajectory.append(pt)
            agg = pt["aggregate"]
            bud = agg["budget"]
            print(f"{r},{mb},{pt['events']},{pt['wall_s']:.3f},"
                  f"{pt['throughput_ev_s']:.0f},{agg['p99_us']:.0f},"
                  f"{bud['queue_wait_us_mean']:.0f},"
                  f"{bud['dispatch_us_mean']:.0f},"
                  f"{bud['compute_us_mean']:.0f}")

    result = {"mode": args.mode, "events": args.events,
              "service_us": args.service_us if args.mode == "synthetic"
              else None,
              "policy": args.policy, "trajectory": trajectory}
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"[serving_scaling] wrote {args.out}")

    if args.check:
        ok = True
        for mb in args.microbatches:
            pts = sorted((p for p in trajectory if p["microbatch"] == mb),
                         key=lambda p: p["replicas"])
            tps = [p["throughput_ev_s"] for p in pts]
            mono = all(b >= a for a, b in zip(tps, tps[1:]))
            print(f"[serving_scaling] mb={mb} throughput "
                  f"{[f'{t:.0f}' for t in tps]} monotone={mono}")
            ok &= mono
        if not ok:
            raise SystemExit("serving_scaling: throughput not monotone "
                             "in replica count")


if __name__ == "__main__":
    main()
