"""Batch-packing benchmark: per-event vs batch-packed launches across
occupancy buckets.

The paper sustains 2.94 M events/s on one statically scheduled
pipeline; the serving analogue here is that a queued micro-batch must
NOT pay one executable launch per event. This benchmark deploys the
current-detector CaloClusterNet once per occupancy bucket and, for
each (bucket, microbatch) pair, times

  per_event    — ``microbatch`` sequential launches of the batch-1
                 executable (the pre-bucketing serving behavior);
  batch_packed — one launch of the batch-packed executable
                 (``deploy(batch=microbatch)``), i.e. the leading
                 event grid dimension of the batched kernels.

``--mode ragged`` benchmarks the padding-free path instead: the same
model deployed bucketed (``deploy_bucketed``) vs ragged
(``deploy(ragged=True)``) on a *high-variance* occupancy profile whose
event sizes sit just past the bucket caps — the mix where bucket
quantization is weakest (every event pays the next bucket up, or
overflows to the largest). ``--check`` gates the ragged path at
``RAGGED_MIN_SPEEDUP`` × the bucketed events/s.

Prints harness CSV rows (``name,us_per_call,derived``) and, with
``--out``, writes the trajectory JSON consumed by CI:

    PYTHONPATH=src python benchmarks/batching.py --out BENCH_batching.json
    PYTHONPATH=src python benchmarks/batching.py --mode ragged \
        --out BENCH_ragged.json
    PYTHONPATH=src python -m benchmarks.run batching
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

if __package__ in (None, ""):   # script invocation: put repo root first
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import row, time_fn

BUCKETS = (8, 16, 32)
MICROBATCHES = (1, 8, 16)

# ragged-vs-bucketed comparison: occupancies one past each bucket cap,
# so every event pays the next bucket up (or the overflow fallback)
RAGGED_OCCUPANCIES = (9, 17, 25)
RAGGED_BATCH = 32
RAGGED_MICROBATCH = 8
RAGGED_MIN_SPEEDUP = 1.2


def run(out_path: str | None = None, iters: int = 5):
    import jax

    import repro.core.caloclusternet as ccn
    from repro.core.passes.parallelize import Requirements
    from repro.core.pipeline import _cut_hits, deploy
    from repro.data.belle2 import current_detector, generate

    cfg = ccn.current_detector_config()
    gen = current_detector()
    params = ccn.init(jax.random.PRNGKey(0), cfg)
    graph = ccn.to_graph(params, cfg)
    data = generate(gen, max(MICROBATCHES), seed=3)
    feeds = {"hits": data["feats"], "mask": data["mask"]}
    req = Requirements(design_point=3, platform="cpu",
                       precision_policy="fp", n_hits=cfg.n_hits,
                       target_throughput=5e4, max_latency_s=2e-3)

    trajectory = []
    for bucket in BUCKETS:
        req_b = dataclasses.replace(req, n_hits=bucket)
        fb = _cut_hits(feeds, bucket)
        single = deploy(graph, req_b)
        for mb in MICROBATCHES:
            chunk = jax.tree_util.tree_map(lambda a: a[:mb], fb)
            events = [jax.tree_util.tree_map(lambda a: a[i:i + 1], fb)
                      for i in range(mb)]

            def per_event_loop():
                return [single(e) for e in events]

            t_loop, _ = time_fn(per_event_loop, iters=iters)
            if mb == 1:
                t_pack, packed = t_loop, single
            else:
                packed = deploy(graph, req_b, batch=mb)
                t_pack, _ = time_fn(packed, chunk, iters=iters)
            ev_s_loop = mb / t_loop
            ev_s_pack = mb / t_pack
            speedup = t_loop / t_pack
            row(f"batching_b{bucket}_mb{mb}_per_event", t_loop * 1e6,
                f"{ev_s_loop:.0f} ev/s")
            row(f"batching_b{bucket}_mb{mb}_batch_packed", t_pack * 1e6,
                f"{ev_s_pack:.0f} ev/s speedup {speedup:.2f}x")
            trajectory.append({
                "bucket": bucket, "microbatch": mb,
                "per_event_us": t_loop * 1e6,
                "batch_packed_us": t_pack * 1e6,
                "per_event_ev_s": ev_s_loop,
                "batch_packed_ev_s": ev_s_pack,
                "speedup": speedup,
            })

    if out_path:
        with open(out_path, "w") as f:
            json.dump({"detector": "current", "buckets": list(BUCKETS),
                       "microbatches": list(MICROBATCHES),
                       "trajectory": trajectory}, f, indent=1)
        print(f"[batching] wrote {out_path}", file=sys.stderr)
    return trajectory


def run_ragged(out_path: str | None = None, iters: int = 5):
    import jax

    import repro.core.caloclusternet as ccn
    from repro.core.passes.parallelize import Requirements
    from repro.core.pipeline import deploy, deploy_bucketed
    from repro.data.belle2 import current_detector, generate, with_occupancy

    cfg = ccn.current_detector_config()
    gen = with_occupancy(current_detector(), RAGGED_OCCUPANCIES)
    params = ccn.init(jax.random.PRNGKey(0), cfg)
    graph = ccn.to_graph(params, cfg)
    data = generate(gen, RAGGED_BATCH, seed=3)
    feeds = {"hits": data["feats"], "mask": data["mask"]}
    req = Requirements(design_point=3, platform="cpu",
                       precision_policy="fp", n_hits=cfg.n_hits,
                       target_throughput=5e4, max_latency_s=2e-3)

    bucketed = deploy_bucketed(graph, req, buckets=BUCKETS,
                               microbatch=RAGGED_MICROBATCH)
    ragged = deploy(graph, req, batch=RAGGED_MICROBATCH, ragged=True)
    t_bucket, _ = time_fn(bucketed, feeds, iters=iters)
    t_ragged, _ = time_fn(ragged, feeds, iters=iters)
    ev_s_bucket = RAGGED_BATCH / t_bucket
    ev_s_ragged = RAGGED_BATCH / t_ragged
    speedup = t_bucket / t_ragged
    row("ragged_bucketed", t_bucket * 1e6, f"{ev_s_bucket:.0f} ev/s")
    row("ragged_packed", t_ragged * 1e6,
        f"{ev_s_ragged:.0f} ev/s speedup {speedup:.2f}x")
    result = {
        "mode": "ragged", "detector": "current",
        "occupancies": list(RAGGED_OCCUPANCIES),
        "buckets": list(BUCKETS),
        "batch": RAGGED_BATCH, "microbatch": RAGGED_MICROBATCH,
        "bucketed_us": t_bucket * 1e6, "ragged_us": t_ragged * 1e6,
        "bucketed_ev_s": ev_s_bucket, "ragged_ev_s": ev_s_ragged,
        "speedup": speedup, "min_speedup": RAGGED_MIN_SPEEDUP,
    }
    if out_path:
        with open(out_path, "w") as f:
            json.dump(result, f, indent=1)
        print(f"[batching] wrote {out_path}", file=sys.stderr)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--mode", choices=("bucketed", "ragged"),
                    default="bucketed")
    ap.add_argument("--check", action="store_true",
                    help="bucketed: fail unless batch packing wins at "
                         "every bucket for microbatch >= 8; ragged: "
                         "fail unless the ragged path clears "
                         f"{RAGGED_MIN_SPEEDUP}x the bucketed events/s")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.mode == "ragged":
        res = run_ragged(args.out, iters=args.iters)
        if args.check and res["speedup"] < RAGGED_MIN_SPEEDUP:
            raise SystemExit(
                f"ragged: {res['speedup']:.2f}x < required "
                f"{RAGGED_MIN_SPEEDUP}x vs bucketed on the "
                f"high-variance profile {RAGGED_OCCUPANCIES}")
        return
    traj = run(args.out, iters=args.iters)
    if args.check:
        bad = [p for p in traj
               if p["microbatch"] >= 8 and p["speedup"] < 1.0]
        if bad:
            raise SystemExit(f"batching: batch packing lost at {bad}")


if __name__ == "__main__":
    main()
