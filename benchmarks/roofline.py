"""§Roofline table: read reports/dryrun/*.json, emit the per-cell
three-term roofline (compute/memory/collective seconds), dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs ratio, and MFU-style roofline fraction.
"""
from __future__ import annotations

import glob
import json
import os

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "reports",
                          "dryrun")


def load(report_dir=None):
    recs = []
    for p in sorted(glob.glob(os.path.join(report_dir or REPORT_DIR,
                                           "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def table(recs, mesh="pod16x16"):
    rows = []
    for r in recs:
        if r["mesh"] != mesh:
            continue
        rf = r["roofline"]
        rows.append({
            "cell": f"{r['arch']}:{r['shape']}",
            "kind": r["kind"],
            "t_compute_ms": rf["t_compute_s"] * 1e3,
            "t_memory_ms": rf["t_memory_s"] * 1e3,
            "t_collective_ms": rf["t_collective_s"] * 1e3,
            "dominant": rf["dominant"],
            "useful": rf["useful_flops_ratio"],
            "mfu": rf["roofline_fraction_mfu"],
        })
    return rows


def run(report_dir=None):
    from benchmarks.common import row
    recs = load(report_dir)
    out = []
    for r in table(recs):
        out.append(row(
            f"roofline_{r['cell']}",
            max(r["t_compute_ms"], r["t_memory_ms"],
                r["t_collective_ms"]) * 1e3,
            f"dom={r['dominant']} C={r['t_compute_ms']:.3f}ms "
            f"M={r['t_memory_ms']:.3f}ms X={r['t_collective_ms']:.3f}ms "
            f"useful={r['useful']:.2f} mfu={r['mfu']:.3f}"))
    if not out:
        print("roofline: no dry-run reports found "
              "(run python -m repro.launch.dryrun first)")
    return out


def markdown(recs, mesh="pod16x16"):
    lines = ["| cell | kind | compute | memory | collective | dominant "
             "| useful F | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for r in table(recs, mesh):
        lines.append(
            f"| {r['cell']} | {r['kind']} | {r['t_compute_ms']:.3f} ms "
            f"| {r['t_memory_ms']:.3f} ms | {r['t_collective_ms']:.3f} ms "
            f"| **{r['dominant']}** | {r['useful']:.2f} "
            f"| {r['mfu']:.3f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    run()
