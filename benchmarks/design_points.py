"""Fig 5a/5b analogue: latency + throughput for designs ①②③ vs the
no-flow baseline, measured on CPU XLA + derived for TPU v5e from the
analytic pipeline model.

Paper claims to reproduce (ordering/shape, §IV):
  - design ① is SLOWER than the baseline (heterogeneous-partitioning
    overhead: per-segment dispatch, no cross-boundary fusion);
  - design ② recovers with fusion + spatial parallelization;
  - design ③ is fastest (kernel-level optimization at identical
    resource allocation — here: flattened kernels, retile cancellation,
    int8 chaining, whole-pipeline jit).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import row, time_fn
from repro.core import caloclusternet as ccn
from repro.core.passes.parallelize import Requirements
from repro.core.pipeline import deploy
from repro.data.belle2 import Belle2Config, generate

N_EVENTS = 256


def run(detector: str = "upgrade", events: int = N_EVENTS):
    if detector == "current":
        cfg = ccn.current_detector_config()
        gen = Belle2Config(n_crystals=576, grid=(24, 24), n_hits=32,
                           noise_rate=8.0)
    else:
        cfg = ccn.CCNConfig()
        gen = Belle2Config()
    params = ccn.init(jax.random.PRNGKey(0), cfg)
    data = generate(gen, events, seed=11)
    feeds = {"hits": data["feats"], "mask": data["mask"]}
    calib = {"hits": data["feats"][:32], "mask": data["mask"][:32]}
    graph = ccn.to_graph(params, cfg)
    rows = []

    # no-flow baseline (the GPU/TensorRT reference analogue): direct jit
    @jax.jit
    def baseline(h, m):
        out = ccn.apply(params, h, m, cfg)
        return ccn.cps(out, m, cfg)

    t, _ = time_fn(lambda: baseline(feeds["hits"], feeds["mask"]), iters=3)
    rows.append(row(f"fig5_baseline_xla_{detector}",
                    t / events * 1e6,
                    "no-flow fp32 reference"))

    base_ev_s = events / t
    for dp in (1, 2, 3):
        req = Requirements(design_point=dp, platform="cpu",
                           precision_policy="mixed", n_hits=cfg.n_hits,
                           target_throughput=5e4, max_latency_s=2e-3)
        pipe = deploy(graph, req, calibration_feeds=calib)
        t, _ = time_fn(lambda: pipe(feeds), iters=3)
        ev_s = events / t
        # derived TPU numbers from the analytic model (per chip)
        req_tpu = Requirements(design_point=dp, platform="tpu",
                               precision_policy="mixed",
                               n_hits=cfg.n_hits, target_throughput=3e6,
                               max_latency_s=10e-6)
        pipe_tpu = deploy(graph, req_tpu, calibration_feeds=calib,
                          kernel_backend="xla")
        rows.append(row(
            f"fig5_design{dp}_{detector}", t / events * 1e6,
            f"cpu {ev_s:,.0f} ev/s ({ev_s / base_ev_s:.2f}x baseline); "
            f"tpu-model {pipe_tpu.model_throughput():,.0f} ev/s/chip "
            f"lat {pipe_tpu.model_latency() * 1e6:.2f} us (<=10us) "
            f"P={pipe_tpu.par['P_mxu']}/{pipe_tpu.par['P_xla']}"))

    # beyond-paper: TPU-native gravnet partitioning at design ③
    req = Requirements(design_point=3, platform="cpu",
                       precision_policy="mixed", n_hits=cfg.n_hits,
                       target_throughput=5e4, max_latency_s=2e-3,
                       tpu_native_gravnet=True)
    pipe = deploy(graph, req, calibration_feeds=calib)
    t, _ = time_fn(lambda: pipe(feeds), iters=3)
    req_tpu = Requirements(design_point=3, platform="tpu",
                           precision_policy="mixed", n_hits=cfg.n_hits,
                           target_throughput=3e6, max_latency_s=10e-6,
                           tpu_native_gravnet=True)
    pipe_tpu = deploy(graph, req_tpu, calibration_feeds=calib,
                      kernel_backend="xla")
    rows.append(row(
        f"fig5_design3_tpunative_{detector}", t / events * 1e6,
        f"cpu {events / t:,.0f} ev/s; tpu-model "
        f"{pipe_tpu.model_throughput():,.0f} ev/s/chip "
        f"lat {pipe_tpu.model_latency() * 1e6:.2f} us"))
    return rows


if __name__ == "__main__":
    run()
