"""Open-loop serving-latency benchmark: tail latency at fixed offered
load — the trigger-system SLO the paper's 7.15 µs figure represents.

Throughput alone cannot gate a trigger runtime: the paper's number is
an end-to-end *latency* budget sustained under continuous load.  This
benchmark drives the sharded service with an **open-loop** generator —
events are submitted on a fixed schedule (``offered`` events/s),
independent of completions, and each event's latency is measured from
its *scheduled* arrival time, so a backed-up service cannot hide its
tail by slowing the generator (no coordinated omission).

Both replica loops run against the same synthetic fixed-service-time
lane (a GIL-releasing sleep per launch, like a real device dispatch),
so the measured difference is purely the serving layer:

  deadline  — the original micro-batch loop: an event waits for the
              batch to fill or the window deadline to expire;
  streaming — the persistent dataflow pipeline: rolling batching, an
              arriving event joins the next in-flight launch.

Writes ``BENCH_latency.json`` with p50/p95/p99 end-to-end latency and
achieved events/s per loop.  ``--check`` enforces the SLO gate CI runs
on every PR: at the fixed offered load, streaming p99 must be at most
``--max-p99-ratio`` (default 0.75×) of the deadline p99, at
equal-or-better achieved events/s.

Usage:
    PYTHONPATH=src python benchmarks/serving_latency.py \
        --out BENCH_latency.json --check
    PYTHONPATH=src python -m benchmarks.run latency
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import sys
import threading
import time

import numpy as np

if __package__ in (None, ""):   # script invocation: put repo root first
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from repro.serving import ShardedTriggerService

# defaults sized for CI: ~1.2 s of streamed traffic per loop flavor,
# comfortably below the synthetic lane's capacity so the gate measures
# loop latency, not saturation behavior.
OFFERED_EV_S = 2000.0
EVENTS = 2400
MICROBATCH = 16
SERVICE_US = 1500.0
WINDOW_MS = 6.0
MAX_P99_RATIO = 0.75
ATTEMPTS = 3


def synthetic_infer(service_us: float):
    """Fixed-service-time lane (releases the GIL like a device
    dispatch), then a trivial numpy decision so the result is
    event-shaped."""

    def infer(feeds):
        time.sleep(service_us * 1e-6)
        x = feeds["hits"]
        energy = x.sum(axis=tuple(range(1, x.ndim)))
        return {"trigger": energy > 0.0, "energy": energy}

    return infer


def _pct(xs, p):
    return float(np.percentile(np.asarray(xs, float), p))


def run_loop(loop: str, *, offered_ev_s: float, events: int,
             microbatch: int, service_us: float, window_ms: float,
             inflight: int = 2) -> dict:
    """Stream ``events`` through one service at the offered rate and
    return client-side latency percentiles + achieved throughput."""
    import jax  # noqa: F401 — pay the lazy import before timing starts

    infer = synthetic_infer(service_us)
    svc = ShardedTriggerService(infer, n_replicas=1,
                                microbatch=microbatch,
                                window_s=window_ms * 1e-3, devices=None,
                                inflight=inflight, loop=loop)
    event = {"hits": np.ones((32, 4), np.float32)}
    # warm the lane (thread ramp-up, ring allocation, first-launch
    # paths) outside the measured window
    warm = [svc.submit(dict(event)) for _ in range(2 * microbatch)]
    for f in warm:
        f.result(timeout=60)
    svc.drain()

    done_at = [0.0] * events
    done_evt = threading.Event()
    remaining = [events]
    lock = threading.Lock()

    def make_cb(i):
        def cb(_fut):
            done_at[i] = time.perf_counter()
            with lock:
                remaining[0] -= 1
                if not remaining[0]:
                    done_evt.set()
        return cb

    interarrival = 1.0 / offered_ev_s
    sched = [0.0] * events
    futs = []
    # A CPython gen-2 collection stalls every thread for tens (observed:
    # hundreds) of ms — two orders of magnitude above the latencies
    # under test, hitting whichever loop it lands on. Collect up front,
    # then keep the collector out of the measured window (both loops
    # get identical treatment; a production trigger host would pin the
    # collector the same way).
    gc_was_enabled = gc.isenabled()
    gc.collect()
    gc.disable()
    try:
        t0 = time.perf_counter() + 5 * interarrival
        for i in range(events):
            target = t0 + i * interarrival
            now = time.perf_counter()
            if target > now:
                time.sleep(target - now)
            # open loop: latency counts from the *scheduled* arrival,
            # so generator lag and submit-side backpressure are charged
            # to the service, never hidden.
            sched[i] = target
            fut = svc.submit(event)
            fut.add_done_callback(make_cb(i))
            futs.append(fut)
        completed = done_evt.wait(timeout=120)
    finally:
        if gc_was_enabled:
            gc.enable()
    assert completed, "latency run did not complete"
    failed = sum(1 for f in futs if f.exception() is not None)
    svc.drain()
    agg = svc.stats.summary()
    svc.close()

    lats = [done_at[i] - sched[i] for i in range(events)]
    wall = max(done_at) - t0
    return {
        "loop": loop,
        "events": events,
        "failed": failed,
        "offered_ev_s": offered_ev_s,
        "achieved_ev_s": events / wall,
        "p50_us": _pct(lats, 50) * 1e6,
        "p95_us": _pct(lats, 95) * 1e6,
        "p99_us": _pct(lats, 99) * 1e6,
        "mean_us": float(np.mean(lats)) * 1e6,
        "batches": agg["batches"],
        "mean_batch_fill": events / max(agg["batches"], 1),
        "budget": agg["budget"],
    }


def _measure_pair(*, offered_ev_s, events, microbatch, service_us,
                  window_ms) -> dict:
    """One paired A/B measurement (both loops back to back, so they
    see the same host conditions)."""
    loops = {}
    print("loop,p50_us,p95_us,p99_us,achieved_ev_s,mean_batch_fill")
    for loop in ("deadline", "streaming"):
        r = run_loop(loop, offered_ev_s=offered_ev_s, events=events,
                     microbatch=microbatch, service_us=service_us,
                     window_ms=window_ms)
        loops[loop] = r
        print(f"{loop},{r['p50_us']:.0f},{r['p95_us']:.0f},"
              f"{r['p99_us']:.0f},{r['achieved_ev_s']:.0f},"
              f"{r['mean_batch_fill']:.1f}")
    return loops


def run(out_path: str | None = None, *, check: bool = False,
        offered_ev_s: float = OFFERED_EV_S, events: int = EVENTS,
        microbatch: int = MICROBATCH, service_us: float = SERVICE_US,
        window_ms: float = WINDOW_MS,
        max_p99_ratio: float = MAX_P99_RATIO,
        attempts: int = ATTEMPTS) -> dict:
    """A/B at fixed offered load; raises RuntimeError when ``check``
    is set and the streaming loop misses the SLO gate.

    A shared CI runner occasionally stalls the whole process for
    hundreds of ms (CPU contention — the collector is already pinned
    during the window); at ~1.3x capacity headroom one such stall
    backs the pipeline up for the rest of the run and poisons every
    percentile.  A failed attempt is therefore retried as a fresh
    *paired* A/B (up to ``attempts``): a real loop regression fails
    every pair, host noise doesn't.
    """
    trials = []
    for attempt in range(max(attempts, 1)):
        if attempt:
            print(f"[serving_latency] gate missed, retrying "
                  f"(attempt {attempt + 1}/{attempts})")
        loops = _measure_pair(offered_ev_s=offered_ev_s, events=events,
                              microbatch=microbatch,
                              service_us=service_us, window_ms=window_ms)
        d, s = loops["deadline"], loops["streaming"]
        ratio = s["p99_us"] / d["p99_us"]
        # 2% measurement-jitter allowance on "equal-or-better"
        # throughput; both loops complete the same open-loop schedule,
        # so achieved rates only differ by tail-drain time.
        tp_ok = s["achieved_ev_s"] >= 0.98 * d["achieved_ev_s"]
        gate_ok = (ratio <= max_p99_ratio and tp_ok
                   and not d["failed"] and not s["failed"])
        trials.append({"p99_ratio": ratio, "pass": gate_ok})
        if gate_ok:
            break
    result = {
        "mode": "synthetic",
        "offered_ev_s": offered_ev_s,
        "events": events,
        "microbatch": microbatch,
        "service_us": service_us,
        "window_ms": window_ms,
        "loops": loops,
        "p99_ratio_streaming_vs_deadline": ratio,
        "check": {"max_p99_ratio": max_p99_ratio,
                  "throughput_equal_or_better": tp_ok,
                  "attempts": trials,
                  "pass": gate_ok},
    }
    if out_path:
        with open(out_path, "w") as f:
            json.dump(result, f, indent=1)
            f.write("\n")
        print(f"[serving_latency] wrote {out_path}")
    print(f"[serving_latency] p99 streaming/deadline = "
          f"{s['p99_us']:.0f}/{d['p99_us']:.0f} us "
          f"(ratio {ratio:.2f}, gate <= {max_p99_ratio}), throughput "
          f"{s['achieved_ev_s']:.0f} vs {d['achieved_ev_s']:.0f} ev/s")
    if check and not gate_ok:
        raise RuntimeError(
            f"serving_latency SLO gate failed: p99 ratio {ratio:.2f} "
            f"(limit {max_p99_ratio}), throughput ok={tp_ok}, "
            f"failed events deadline={d['failed']} "
            f"streaming={s['failed']}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--offered", type=float, default=OFFERED_EV_S,
                    help="open-loop offered load, events/s")
    ap.add_argument("--events", type=int, default=EVENTS)
    ap.add_argument("--microbatch", type=int, default=MICROBATCH)
    ap.add_argument("--service-us", type=float, default=SERVICE_US,
                    help="synthetic per-launch service time")
    ap.add_argument("--window-ms", type=float, default=WINDOW_MS,
                    help="deadline-loop batching window")
    ap.add_argument("--max-p99-ratio", type=float, default=MAX_P99_RATIO,
                    help="--check fails unless streaming p99 <= this "
                         "fraction of the deadline p99")
    ap.add_argument("--attempts", type=int, default=ATTEMPTS,
                    help="paired A/B retries before the gate fails "
                         "(rides out one-off host stalls)")
    ap.add_argument("--out", default="/tmp/serving_latency.json")
    ap.add_argument("--check", action="store_true",
                    help="enforce the p99 SLO gate")
    args = ap.parse_args()
    try:
        run(args.out, check=args.check, offered_ev_s=args.offered,
            events=args.events, microbatch=args.microbatch,
            service_us=args.service_us, window_ms=args.window_ms,
            max_p99_ratio=args.max_p99_ratio, attempts=args.attempts)
    except RuntimeError as e:
        raise SystemExit(str(e))


if __name__ == "__main__":
    main()
