"""Monitoring-overhead benchmark: monitored vs unmonitored serving.

Streaming-dataflow trigger systems only get to keep their monitoring
if it rides the hot path with bounded overhead (DGNNFlow-style online
rate/efficiency counters).  This benchmark quantifies what
``ShardedTriggerService(monitor=...)`` costs, two ways:

1. **A/B throughput** (reported): interleaved unmonitored/monitored
   service passes over the same synthetic CPS-shaped events, with
   truth bits submitted, a live ``MonitorServer`` polling
   ``/snapshot``, and a full fold forced at the end.  On small shared
   CI machines the thread-based serving stack is strongly bimodal
   (per-pass throughput swings ±40% with identical code — batch
   formation depends on which thread wins the cores), so the A/B
   medians are informative, not gateable at the 5% level.

2. **Per-event monitoring cost** (gated): a deterministic
   single-threaded measurement of everything monitoring adds per
   event — the submit-side truth staging, the per-batch truth pops +
   ``record_raw`` staging, and the reader-side fold + periodic
   snapshot aggregation.  Charging *all* of it against the unmonitored
   baseline is an upper bound: in the live service the fold runs on
   the monitoring reader's thread and overlaps serving idle time.
   ``overhead_frac = cost_per_event * unmonitored_rate`` is what
   ``--check`` enforces (default bound 5%).

Usage:
    PYTHONPATH=src python benchmarks/monitoring_overhead.py \
        --out BENCH_monitoring.json --check
"""
from __future__ import annotations

import argparse
import json
import sys
import threading
import time
import urllib.request

import numpy as np

from repro.serving import MonitorServer, ShardedTriggerService, TriggerMonitor


def make_infer(service_us: float, k_max: int = 8):
    """Synthetic lane emitting CPS-shaped batches, so the monitored
    arm pays the full recording path (trigger bit, cluster stats,
    display ring) per event.  ``service_us`` > 0 adds a sleep modelling
    accelerator occupancy per batch."""

    def infer(feeds):
        if service_us > 0:
            time.sleep(service_us * 1e-6)
        x = feeds["hits"]
        b = x.shape[0]
        e = x.sum(axis=tuple(range(1, x.ndim)))
        n = np.minimum(np.maximum(e, 0.0) * 2.0, k_max).astype(np.int32)
        valid = np.arange(k_max)[None, :] < n[:, None]
        return {"cps": {
            "trigger": e > 0.5,
            "n_clusters": n,
            "cluster_valid": valid,
            "cluster_e": np.abs(x[:, :k_max, 0]),
            "cluster_beta": np.clip(np.abs(x[:, :k_max, 1]), 0, 1),
            "cluster_xy": np.clip(x[:, :k_max, 2:4], -0.5, 0.5),
        }}

    return infer


# --------------------------------------------------- deterministic cost ----
def hotpath_cost_us(*, microbatch: int, n_batches: int = 512,
                    snapshot_every: int = 32) -> dict:
    """Per-event monitoring cost, measured single-threaded.

    Times exactly what ``monitor=`` *adds* to a service: the
    submit-side truth staging, the replica batch-side truth pops +
    ``record_raw``, and the reader-side fold/aggregate via
    ``snapshot()`` every ``snapshot_every`` batches (a 20 Hz dashboard
    at paper-scale rates polls far less often per event than that).
    The batch item tuples are pre-built — the unmonitored replica loop
    constructs those regardless."""
    infer = make_infer(0.0)
    rng = np.random.default_rng(3)
    feeds = {"hits": rng.normal(size=(microbatch, 32, 4))
             .astype(np.float32)}
    cps = infer(feeds)["cps"]
    mon = TriggerMonitor(window=4096, display_n=64)
    truth_map: dict[int, bool] = {}
    ts = time.perf_counter()
    batches = [[(b * microbatch + j, ts, ts, None, None)
                for j in range(microbatch)] for b in range(n_batches)]
    t0 = time.perf_counter()
    for b, items in enumerate(batches):
        for it in items:                  # submit-side extra
            truth_map[it[0]] = True
        # replica batch-side extras
        truths = [truth_map.pop(it[0], None) for it in items]
        rec = {k: np.asarray(v) for k, v in cps.items()}
        mon.record_raw(rec, [(it[0], it[1]) for it in items],
                       time.perf_counter(), truths)
        if b % snapshot_every == 0:       # reader-side fold + aggregate
            mon.snapshot()
    snap = mon.snapshot()
    dt = time.perf_counter() - t0
    n_ev = n_batches * microbatch
    assert snap["events"] == n_ev
    return {"cost_us_per_event": dt / n_ev * 1e6,
            "cost_events": n_ev, "snapshot_every": snapshot_every}


# ------------------------------------------------------- A/B throughput ----
def run_pass(infer, events, truth, *, replicas, microbatch, monitored,
             poll_hz: float = 10.0):
    n = len(truth)
    svc = ShardedTriggerService(
        infer, n_replicas=replicas, microbatch=microbatch,
        window_s=5e-3, queue_depth=n + microbatch, inflight=1,
        devices=None, monitor={"display_n": 64} if monitored else False)
    server = poller = None
    stop = threading.Event()
    if monitored:
        server = MonitorServer.for_service(svc, port=0)

        def poll():
            while not stop.is_set():
                try:
                    urllib.request.urlopen(
                        f"{server.url}/snapshot", timeout=5).read()
                except OSError:
                    pass
                stop.wait(1.0 / poll_hz)

        poller = threading.Thread(target=poll, daemon=True)
        poller.start()
    t0 = time.perf_counter()
    futs = [svc.submit(events[i], truth=truth[i] if monitored
                       else None) for i in range(n)]
    for f in futs:
        f.result(timeout=300)
    dt = time.perf_counter() - t0
    svc.drain()
    if monitored:
        snap = svc.monitor_snapshot()    # force the full fold
        assert snap["events"] == n, "monitor lost events"
        stop.set()
        poller.join(timeout=5)
        server.close()
    svc.close()
    return n / dt


def measure(args):
    infer = make_infer(args.service_us)
    rng = np.random.default_rng(11)
    events = [{"hits": rng.normal(size=(args.n_hits, 4))
               .astype(np.float32)} for _ in range(args.events)]
    # plain-bool truth bits: preparing truth is the caller's business,
    # not monitoring overhead, so keep np->bool casts out of the loop
    truth = [bool(x) for x in rng.uniform(size=args.events) > 0.5]
    kw = dict(replicas=args.replicas, microbatch=args.microbatch)
    # untimed warmup of both arms: the first pass pays thread-pool and
    # numpy warmup that would otherwise skew whichever arm runs first
    run_pass(infer, events[:256], truth[:256], monitored=False, **kw)
    run_pass(infer, events[:256], truth[:256], monitored=True, **kw)
    un, mon = [], []
    for t in range(args.trials):
        u = run_pass(infer, events, truth, monitored=False, **kw)
        m = run_pass(infer, events, truth, monitored=True, **kw)
        un.append(u)
        mon.append(m)
        print(f"[monitoring] pair {t}: unmonitored {u:,.0f} ev/s | "
              f"monitored {m:,.0f} ev/s | ratio {m / u:.3f}")
    # median of three cost runs: the loop is ~25 ms, so a transient
    # frequency/throttle spike must not set the gated number
    cost = sorted((hotpath_cost_us(microbatch=args.microbatch)
                   for _ in range(3)),
                  key=lambda c: c["cost_us_per_event"])[1]
    u_med = float(np.median(un))
    overhead = cost["cost_us_per_event"] * 1e-6 * u_med
    return {
        "events": args.events, "trials": args.trials,
        "replicas": args.replicas, "microbatch": args.microbatch,
        "service_us": args.service_us,
        "unmonitored_ev_s": u_med,
        "monitored_ev_s": float(np.median(mon)),
        "unmonitored_trials_ev_s": un, "monitored_trials_ev_s": mon,
        "ab_ratio_median": float(np.median(
            [m / u for u, m in zip(un, mon)])),
        "monitor_cost_us_per_event": cost["cost_us_per_event"],
        # the gated number: deterministic per-event monitoring cost as
        # a fraction of the unmonitored per-event budget
        "overhead_frac": overhead,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=4096)
    ap.add_argument("--trials", type=int, default=3,
                    help="interleaved unmonitored/monitored A/B pairs")
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--microbatch", type=int, default=32)
    ap.add_argument("--n-hits", type=int, default=32)
    ap.add_argument("--service-us", type=float, default=0.0,
                    help="synthetic accelerator occupancy per batch "
                         "(0 = pure serving CPU, the most adversarial "
                         "case for monitoring overhead)")
    ap.add_argument("--max-overhead", type=float, default=0.05,
                    help="--check bound on the deterministic "
                         "per-event-cost overhead fraction")
    ap.add_argument("--out", default=None, metavar="PATH")
    ap.add_argument("--check", action="store_true")
    args = ap.parse_args()

    res = measure(args)
    if args.check and res["overhead_frac"] > args.max_overhead:
        # one re-measure: a noisy run must not fail CI by itself
        print(f"[monitoring] overhead {res['overhead_frac']:.1%} > "
              f"{args.max_overhead:.0%}; re-measuring once")
        res = measure(args)
    print(f"[monitoring] A/B median: unmonitored "
          f"{res['unmonitored_ev_s']:,.0f} ev/s | monitored "
          f"{res['monitored_ev_s']:,.0f} ev/s "
          f"(ratio {res['ab_ratio_median']:.3f})")
    print(f"[monitoring] hot-path cost "
          f"{res['monitor_cost_us_per_event']:.2f} us/event -> "
          f"overhead {res['overhead_frac']:.2%} of the unmonitored "
          f"budget (bound {args.max_overhead:.0%})")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=1)
        print(f"[monitoring] -> {args.out}")
    if args.check and res["overhead_frac"] > args.max_overhead:
        print("[monitoring] FAIL: monitoring overhead exceeds bound")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
