"""Jit'd public wrappers for the kernel layer.

Backend selection:
  - 'xla'              : the jnp reference path (differentiable; what the
                         dry-run lowers — XLA fuses it on TPU as well).
  - 'pallas'           : real Mosaic TPU lowering (requires TPU devices).
  - 'pallas_interpret' : kernel body interpreted op-by-op on CPU — used by
                         the test suite to validate the TPU kernels here.
  - 'auto'             : 'pallas' on TPU backends, else 'xla'.

All wrappers pad to tile boundaries and slice back, so callers can use
arbitrary shapes.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.edge_aggregate import (edge_aggregate_batched_pallas,
                                          edge_aggregate_pallas)
from repro.kernels.fused_dense import (fused_dense_batched_pallas,
                                       fused_dense_int8_pallas,
                                       fused_dense_pallas)
from repro.kernels.gravnet import (gravnet_aggregate_batched_pallas,
                                   gravnet_aggregate_pallas)
from repro.kernels.gravnet_block import (gravnet_block_batched_pallas,
                                         gravnet_block_int8_batched_pallas,
                                         gravnet_block_int8_pallas,
                                         gravnet_block_pallas)
from repro.kernels.knn_build import (knn_aggregate_batched_pallas,
                                     knn_aggregate_pallas,
                                     knn_build_batched_pallas,
                                     knn_build_pallas)


def _resolve(backend: str) -> str:
    if backend != "auto":
        return backend
    # REPRO_BACKEND pins the 'auto' resolution — CI runs one tier-1 leg
    # with REPRO_BACKEND=pallas_interpret so every kernel body is
    # exercised in interpret mode on every PR. Process-start semantics:
    # the env var is read at trace time inside the jit'd wrappers, so
    # set it before the first kernel call — flipping it mid-process
    # does not invalidate already-traced 'auto' executables.
    env = os.environ.get("REPRO_BACKEND")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _pad_to(x, m, axis):
    r = (-x.shape[axis]) % m
    if r == 0:
        return x
    pw = [(0, 0)] * x.ndim
    pw[axis] = (0, r)
    return jnp.pad(x, pw)


# ------------------------------------------------------------ fused dense ----
@functools.partial(jax.jit, static_argnames=("activation", "variant", "bm",
                                             "bn", "bk", "backend"))
def fused_dense(x, w, b=None, *, activation="relu", variant="looped",
                bm=128, bn=128, bk=512, backend="auto"):
    """act(x @ w + b) with the Pallas fused-dense kernel (or jnp ref)."""
    backend = _resolve(backend)
    if backend == "xla":
        return _ref.fused_dense_ref(x, w, b, activation=activation)
    interpret = backend == "pallas_interpret"
    m, kdim = x.shape
    n = w.shape[1]
    if variant == "looped":
        xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
        wp = _pad_to(_pad_to(w, bk, 0), bn, 1)
        bp = None if b is None else _pad_to(b, bn, 0)
    else:  # flattened keeps exact shapes (whole-operand kernel)
        xp, wp, bp = x, w, b
    y = fused_dense_pallas(xp, wp, bp, activation=activation, variant=variant,
                           bm=bm, bn=bn, bk=bk, out_dtype=x.dtype,
                           interpret=interpret)
    return y[:m, :n]


@functools.partial(jax.jit, static_argnames=("activation", "bm", "bn", "bk",
                                             "out_dtype", "out_scale",
                                             "backend"))
def fused_dense_int8(x_q, w_q, b, x_scale, w_scale, *, activation="relu",
                     bm=128, bn=128, bk=512, out_dtype=jnp.float32,
                     out_scale=1.0, backend="auto"):
    backend = _resolve(backend)
    if backend == "xla":
        return _ref.fused_dense_int8_ref(x_q, w_q, b, x_scale, w_scale,
                                         activation=activation,
                                         out_dtype=out_dtype,
                                         out_scale=out_scale)
    interpret = backend == "pallas_interpret"
    m, kdim = x_q.shape
    n = w_q.shape[1]
    xp = _pad_to(_pad_to(x_q, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w_q, bk, 0), bn, 1)
    bp = None if b is None else _pad_to(b, bn, 0)
    wsp = _pad_to(w_scale, bn, 0)
    y = fused_dense_int8_pallas(xp, wp, bp, x_scale.reshape(1, 1), wsp,
                                activation=activation, bm=bm, bn=bn, bk=bk,
                                out_dtype=out_dtype, out_scale=out_scale,
                                interpret=interpret)
    return y[:m, :n]


# ----------------------------------------------------------------- gravnet ----
@functools.partial(jax.jit, static_argnames=("k", "scale", "bm", "backend"))
def gravnet_aggregate(s, f, mask, *, k=8, scale=10.0, bm=None,
                      backend="auto"):
    """GravNet potential-weighted mean+max neighbor aggregation.

    s:(N,ds) learned coords, f:(N,df) learned features, mask:(N,) validity
    -> (N, 2·df) = concat(mean_agg, max_agg).
    """
    backend = _resolve(backend)
    if backend == "xla":
        return _ref.gravnet_aggregate_ref(s, f, mask, k=k, scale=scale)
    interpret = backend == "pallas_interpret"
    n = s.shape[0]
    bm = bm or min(n, 128)
    sp = _pad_to(s, bm, 0)
    fp = _pad_to(f, bm, 0)
    mp = _pad_to(mask.astype(jnp.float32), bm, 0)
    y = gravnet_aggregate_pallas(sp, fp, mp, k=k, scale=scale, bm=bm,
                                 interpret=interpret)
    return y[:n]


@functools.partial(jax.jit, static_argnames=("k", "scale", "bm", "backend"))
def gravnet_aggregate_batched(s, f, mask, *, k=8, scale=10.0, bm=None,
                              backend="auto"):
    """Micro-batched GravNet aggregation — one launch per micro-batch.

    s:(B,N,ds), f:(B,N,df), mask:(B,N) -> (B, N, 2·df). The batched
    Pallas kernel runs grid (B, N/bm) with per-event masking, so
    neighbor selection stays block-diagonal (no cross-event edges) and
    f32 results match a loop of per-event calls bitwise.
    """
    backend = _resolve(backend)
    if backend == "xla":
        return jax.vmap(lambda a, b_, m: _ref.gravnet_aggregate_ref(
            a, b_, m, k=k, scale=scale))(s, f, mask)
    interpret = backend == "pallas_interpret"
    n = s.shape[1]
    bm = bm or min(n, 128)
    sp = _pad_to(s, bm, 1)
    fp = _pad_to(f, bm, 1)
    mp = _pad_to(mask.astype(jnp.float32), bm, 1)
    y = gravnet_aggregate_batched_pallas(sp, fp, mp, k=k, scale=scale,
                                         bm=bm, interpret=interpret)
    return y[:, :n]


@functools.partial(jax.jit, static_argnames=("activation", "variant", "bm",
                                             "bn", "bk", "backend"))
def fused_dense_batched(x, w, b=None, *, activation="relu",
                        variant="flattened", bm=128, bn=128, bk=512,
                        backend="auto"):
    """act(x @ w + b) over a micro-batch x:(B,M,K) in one launch.

    ``flattened`` keeps one event per grid cell (whole-operand VMEM
    residency, weights shared); ``looped`` row-packs the batch into a
    (B·M, K) matmul. Dense has no cross-row coupling, so both are exact
    batch packings of the per-event kernel.
    """
    backend = _resolve(backend)
    if backend == "xla":
        return _ref.fused_dense_ref(x, w, b, activation=activation)
    interpret = backend == "pallas_interpret"
    bsz, m, kdim = x.shape
    n = w.shape[1]
    if variant == "looped":
        xp = _pad_to(_pad_to(x.reshape(bsz * m, kdim), bm, 0), bk, 1)
        wp = _pad_to(_pad_to(w, bk, 0), bn, 1)
        bp = None if b is None else _pad_to(b, bn, 0)
        y = fused_dense_pallas(xp, wp, bp, activation=activation,
                               variant="looped", bm=bm, bn=bn, bk=bk,
                               out_dtype=x.dtype, interpret=interpret)
        return y[:bsz * m, :n].reshape(bsz, m, n)
    y = fused_dense_batched_pallas(x, w, b, activation=activation,
                                   variant="flattened", out_dtype=x.dtype,
                                   interpret=interpret)
    return y[..., :n]


# --------------------------------------------------------------- kNN build ----
def _pad_segids(segids, m, axis):
    """Pad segment ids with −1 (the padding sentinel) so padded rows
    are valid candidates for nothing."""
    r = (-segids.shape[axis]) % m
    if r == 0:
        return segids
    pw = [(0, 0)] * segids.ndim
    pw[axis] = (0, r)
    return jnp.pad(segids, pw, constant_values=-1)


@functools.partial(jax.jit, static_argnames=("k", "bm", "backend"))
def knn_build(s, segids, *, k=8, bm=None, backend="auto"):
    """Ragged kNN graph building for one packed bin.

    s:(N,ds) learned coords, segids:(N,) int32 event ids (−1 = padding)
    -> (idx:(N,k) int32, d2:(N,k) f32): per row, the k nearest
    *same-event* rows (iterated argmin, ties → lowest index, self
    excluded); exhausted slots carry d2 = 1e30 (consumers gate on d2).
    """
    backend = _resolve(backend)
    if backend == "xla":
        return _ref.knn_build_ref(s, segids, k=k)
    interpret = backend == "pallas_interpret"
    n = s.shape[0]
    bm = bm or min(n, 128)
    sp = _pad_to(s, bm, 0)
    segp = _pad_segids(segids.astype(jnp.int32), bm, 0)
    idx, d2 = knn_build_pallas(sp, segp, k=k, bm=bm, interpret=interpret)
    return idx[:n], d2[:n]


@functools.partial(jax.jit, static_argnames=("k", "bm", "backend"))
def knn_build_batched(s, segids, *, k=8, bm=None, backend="auto"):
    """Batched ragged kNN graph building — one launch for all bins.

    s:(B,N,ds), segids:(B,N) -> (idx:(B,N,k), d2:(B,N,k)). Grid
    (B, N/bm) with the shared selection cell, so f32 results match a
    loop of per-bin calls bitwise.
    """
    backend = _resolve(backend)
    if backend == "xla":
        return jax.vmap(lambda a, g: _ref.knn_build_ref(a, g, k=k))(
            s, segids)
    interpret = backend == "pallas_interpret"
    n = s.shape[1]
    bm = bm or min(n, 128)
    sp = _pad_to(s, bm, 1)
    segp = _pad_segids(segids.astype(jnp.int32), bm, 1)
    idx, d2 = knn_build_batched_pallas(sp, segp, k=k, bm=bm,
                                       interpret=interpret)
    return idx[:, :n], d2[:, :n]


@functools.partial(jax.jit, static_argnames=("scale", "bm", "backend"))
def knn_aggregate(f, idx, d2, *, scale=10.0, bm=None, backend="auto"):
    """Gaussian-potential mean/max aggregation over built neighbor
    indices. f:(N,df), idx/d2:(N,k) from ``knn_build`` -> (N, 2·df) —
    the same accumulation arithmetic as the gravnet megakernel."""
    backend = _resolve(backend)
    if backend == "xla":
        return _ref.knn_aggregate_ref(f, idx, d2, scale=scale)
    interpret = backend == "pallas_interpret"
    n = f.shape[0]
    bm = bm or min(n, 128)
    fp = _pad_to(f, bm, 0)
    ip = _pad_to(idx, bm, 0)
    r = (-n) % bm
    dp = (d2 if r == 0 else
          jnp.pad(d2, ((0, r), (0, 0)), constant_values=1e30))
    y = knn_aggregate_pallas(fp, ip, dp, scale=scale, bm=bm,
                             interpret=interpret)
    return y[:n]


@functools.partial(jax.jit, static_argnames=("scale", "bm", "backend"))
def knn_aggregate_batched(f, idx, d2, *, scale=10.0, bm=None,
                          backend="auto"):
    """Batched neighbor aggregation — one launch for all bins.
    f:(B,N,df), idx/d2:(B,N,k) -> (B, N, 2·df); bitwise equal to a
    loop of per-bin calls (shared cell body)."""
    backend = _resolve(backend)
    if backend == "xla":
        return jax.vmap(lambda a, i, dd: _ref.knn_aggregate_ref(
            a, i, dd, scale=scale))(f, idx, d2)
    interpret = backend == "pallas_interpret"
    n = f.shape[1]
    bm = bm or min(n, 128)
    fp = _pad_to(f, bm, 1)
    ip = _pad_to(idx, bm, 1)
    r = (-n) % bm
    dp = (d2 if r == 0 else
          jnp.pad(d2, ((0, 0), (0, r), (0, 0)), constant_values=1e30))
    y = knn_aggregate_batched_pallas(fp, ip, dp, scale=scale, bm=bm,
                                     interpret=interpret)
    return y[:, :n]


@functools.partial(jax.jit, static_argnames=("k", "scale", "activation",
                                             "concat_x", "bm", "backend"))
def gravnet_block_ragged(x, segids, ws, bs, wf, bf, wo, bo, *, k=8,
                         scale=10.0, activation="relu", concat_x=True,
                         bm=None, backend="auto"):
    """Ragged-aware GravNet block over bin-packed events.

    x:(B,N,dh) packed hidden activations, segids:(B,N) int32 event ids
    (−1 padding) -> (B, N, d_out). S/F projections feed the on-device
    kNN graph build (``knn_build_batched``), whose indices drive the
    potential-weighted aggregation — the learned-coordinate neighbor
    path of the megakernel, with segment-id masking instead of
    bucket-max padding. Padding rows are zeroed on output. Real rows
    match the padded megakernel within f32 tolerance (bitwise through
    selection + aggregation; the projection/epilogue denses launch
    separately here, tested in tests/test_ragged_props.py)."""
    backend = _resolve(backend)
    ws, bs, wf, bf, wo, bo = _gnblock_weight_barrier(ws, bs, wf, bf, wo, bo)
    b, n, dh = x.shape
    x2 = x.reshape(b * n, dh)
    s = fused_dense(x2, ws, bs, activation="none",
                    backend=backend).reshape(b, n, -1)
    f = fused_dense(x2, wf, bf, activation="none",
                    backend=backend).reshape(b, n, -1)
    idx, d2 = knn_build_batched(s, segids, k=k, bm=bm, backend=backend)
    agg = knn_aggregate_batched(f, idx, d2, scale=scale, bm=bm,
                                backend=backend)
    h = jnp.concatenate([x, agg], axis=-1) if concat_x else agg
    y = fused_dense(h.reshape(b * n, h.shape[-1]), wo, bo,
                    activation=activation, backend=backend)
    y = y.reshape(b, n, -1)
    return y * (segids >= 0).astype(y.dtype)[..., None]


# ------------------------------------------------------------ gravnet block ----
def _gnblock_weight_barrier(*weights):
    """XLA CPU specializes dot codegen when a weight is a compile-time
    constant (the whole-pipeline jit closes over the params), which can
    change f32 accumulation bits vs the same dot with runtime operands.
    The barrier pins argument-style codegen so the fused block is
    bitwise-stable across jit contexts — and bitwise-equal to the
    unfused chain, whose kernels see the weights at different shapes
    that happen not to trigger the specialization."""
    return jax.lax.optimization_barrier(weights)


@functools.partial(jax.jit, static_argnames=("k", "scale", "activation",
                                             "concat_x", "bm", "bn", "bk",
                                             "backend"))
def gravnet_block(x, mask, ws, bs, wf, bf, wo, bo, *, k=8, scale=10.0,
                  activation="relu", concat_x=True, bm=None, bn=None,
                  bk=None, backend="auto"):
    """One fused GravNet block (megakernel): S/F projection prologue →
    k-NN aggregation → output dense epilogue, one launch.

    x:(N,dh) hidden activations, mask:(N,) validity -> (N, d_out).
    """
    backend = _resolve(backend)
    if backend == "xla":
        return _ref.gravnet_block_ref(x, mask, ws, bs, wf, bf, wo, bo,
                                      k=k, scale=scale,
                                      activation=activation,
                                      concat_x=concat_x)
    interpret = backend == "pallas_interpret"
    n = x.shape[0]
    bm = bm or min(n, 128)
    xp = _pad_to(x, bm, 0)
    mp = _pad_to(mask.astype(jnp.float32), bm, 0)
    ws, bs, wf, bf, wo, bo = _gnblock_weight_barrier(ws, bs, wf, bf, wo, bo)
    y = gravnet_block_pallas(xp, mp, ws, bs, wf, bf, wo, bo, k=k,
                             scale=scale, activation=activation,
                             concat_x=concat_x, bm=bm, bn=bn, bk=bk,
                             interpret=interpret)
    return y[:n]


@functools.partial(jax.jit, static_argnames=("k", "scale", "activation",
                                             "concat_x", "bm", "bn", "bk",
                                             "backend"))
def gravnet_block_batched(x, mask, ws, bs, wf, bf, wo, bo, *, k=8,
                          scale=10.0, activation="relu", concat_x=True,
                          bm=None, bn=None, bk=None, backend="auto"):
    """Micro-batched fused GravNet block — one launch per micro-batch.

    x:(B,N,dh), mask:(B,N) -> (B, N, d_out). The batched kernel runs
    grid (B, N/bm) with per-event masking (block-diagonal neighbor
    selection) and weights shared across the event grid; f32 results
    match a loop of per-event calls bitwise.
    """
    backend = _resolve(backend)
    if backend == "xla":
        return _ref.gravnet_block_ref(x, mask, ws, bs, wf, bf, wo, bo,
                                      k=k, scale=scale,
                                      activation=activation,
                                      concat_x=concat_x)
    interpret = backend == "pallas_interpret"
    n = x.shape[1]
    bm = bm or min(n, 128)
    xp = _pad_to(x, bm, 1)
    mp = _pad_to(mask.astype(jnp.float32), bm, 1)
    ws, bs, wf, bf, wo, bo = _gnblock_weight_barrier(ws, bs, wf, bf, wo, bo)
    y = gravnet_block_batched_pallas(xp, mp, ws, bs, wf, bf, wo, bo, k=k,
                                     scale=scale, activation=activation,
                                     concat_x=concat_x, bm=bm, bn=bn,
                                     bk=bk, interpret=interpret)
    return y[:, :n]


@functools.partial(jax.jit, static_argnames=(
    "x_scale", "agg_scale", "h_scale", "k", "scale", "activation",
    "concat_x", "bm", "bn", "bk", "out_dtype", "out_scale", "backend"))
def gravnet_block_int8(x, mask, ws_q, bs, wf_q, bf, wo_q, bo, ws_scale,
                       wf_scale, wo_scale, *, x_scale, agg_scale, h_scale,
                       k=8, scale=10.0, activation="relu", concat_x=True,
                       bm=None, bn=None, bk=None, out_dtype=jnp.float32,
                       out_scale=1.0, backend="auto"):
    """Quantized fused GravNet block (megakernel): VMEM requant → int8
    S/F prologue → aggregation → int8 output-dense epilogue, one
    launch. x:(N,dh) f32 activations, mask:(N,) → (N, d_out).

    The calibrated per-tensor activation scales (``x_scale``,
    ``agg_scale``, ``h_scale``) are static — baked into the kernel as
    compile-time constants; int8 weights carry f32 per-output-channel
    scale vectors."""
    backend = _resolve(backend)
    if backend == "xla":
        return _ref.gravnet_block_int8_ref(
            x, mask, ws_q, bs, wf_q, bf, wo_q, bo, ws_scale, wf_scale,
            wo_scale, x_scale=x_scale, agg_scale=agg_scale,
            h_scale=h_scale, k=k, scale=scale, activation=activation,
            concat_x=concat_x, out_dtype=out_dtype, out_scale=out_scale)
    interpret = backend == "pallas_interpret"
    n = x.shape[0]
    bm = bm or min(n, 128)
    xp = _pad_to(x, bm, 0)
    mp = _pad_to(mask.astype(jnp.float32), bm, 0)
    (ws_q, bs, wf_q, bf, wo_q, bo, ws_scale, wf_scale,
     wo_scale) = _gnblock_weight_barrier(ws_q, bs, wf_q, bf, wo_q, bo,
                                         ws_scale, wf_scale, wo_scale)
    y = gravnet_block_int8_pallas(
        xp, mp, ws_q, bs, wf_q, bf, wo_q, bo, ws_scale, wf_scale,
        wo_scale, x_scale=x_scale, agg_scale=agg_scale, h_scale=h_scale,
        k=k, scale=scale, activation=activation, concat_x=concat_x,
        bm=bm, bn=bn, bk=bk, out_dtype=out_dtype, out_scale=out_scale,
        interpret=interpret)
    return y[:n]


@functools.partial(jax.jit, static_argnames=(
    "x_scale", "agg_scale", "h_scale", "k", "scale", "activation",
    "concat_x", "bm", "bn", "bk", "out_dtype", "out_scale", "backend"))
def gravnet_block_int8_batched(x, mask, ws_q, bs, wf_q, bf, wo_q, bo,
                               ws_scale, wf_scale, wo_scale, *, x_scale,
                               agg_scale, h_scale, k=8, scale=10.0,
                               activation="relu", concat_x=True, bm=None,
                               bn=None, bk=None, out_dtype=jnp.float32,
                               out_scale=1.0, backend="auto"):
    """Micro-batched quantized GravNet block — one launch per
    micro-batch. x:(B,N,dh) f32, mask:(B,N) → (B, N, d_out)."""
    backend = _resolve(backend)
    if backend == "xla":
        return _ref.gravnet_block_int8_ref(
            x, mask, ws_q, bs, wf_q, bf, wo_q, bo, ws_scale, wf_scale,
            wo_scale, x_scale=x_scale, agg_scale=agg_scale,
            h_scale=h_scale, k=k, scale=scale, activation=activation,
            concat_x=concat_x, out_dtype=out_dtype, out_scale=out_scale)
    interpret = backend == "pallas_interpret"
    n = x.shape[1]
    bm = bm or min(n, 128)
    xp = _pad_to(x, bm, 1)
    mp = _pad_to(mask.astype(jnp.float32), bm, 1)
    (ws_q, bs, wf_q, bf, wo_q, bo, ws_scale, wf_scale,
     wo_scale) = _gnblock_weight_barrier(ws_q, bs, wf_q, bf, wo_q, bo,
                                         ws_scale, wf_scale, wo_scale)
    y = gravnet_block_int8_batched_pallas(
        xp, mp, ws_q, bs, wf_q, bf, wo_q, bo, ws_scale, wf_scale,
        wo_scale, x_scale=x_scale, agg_scale=agg_scale, h_scale=h_scale,
        k=k, scale=scale, activation=activation, concat_x=concat_x,
        bm=bm, bn=bn, bk=bk, out_dtype=out_dtype, out_scale=out_scale,
        interpret=interpret)
    return y[:, :n]


# ---------------------------------------------------------- edge aggregate ----
@functools.partial(jax.jit, static_argnames=("n_nodes", "reduce", "bm",
                                             "be", "backend"))
def edge_aggregate(messages, edge_index, n_nodes, edge_mask=None, *,
                   reduce="sum", bm=None, be=None, backend="auto"):
    """Masked segment-sum/mean of per-edge messages into nodes.

    messages:(E,d), edge_index:(2,E) int32 (src,dst), edge_mask:(E,)
    -> (n_nodes, d). The Pallas path lowers the scatter as a one-hot
    incidence matmul (see kernels/edge_aggregate.py).
    """
    backend = _resolve(backend)
    if backend == "xla":
        return _ref.edge_aggregate_ref(messages, edge_index, n_nodes,
                                       edge_mask, reduce=reduce)
    interpret = backend == "pallas_interpret"
    e = messages.shape[0]
    mask = (jnp.ones((e,), jnp.float32) if edge_mask is None
            else edge_mask.astype(jnp.float32))
    bm = bm or min(n_nodes, 128)
    be = be or e
    mp = _pad_to(messages, be, 0)
    dp = _pad_to(edge_index[1].astype(jnp.int32), be, 0)
    kp = _pad_to(mask, be, 0)
    n_pad = n_nodes + ((-n_nodes) % bm)
    y = edge_aggregate_pallas(mp, dp, kp, n_nodes=n_pad, reduce=reduce,
                              bm=bm, be=be, interpret=interpret)
    return y[:n_nodes]


@functools.partial(jax.jit, static_argnames=("n_nodes", "reduce", "bm",
                                             "be", "backend"))
def edge_aggregate_batched(messages, edge_index, n_nodes, edge_mask=None, *,
                           reduce="sum", bm=None, be=None, backend="auto"):
    """Micro-batched edge aggregation — one launch per micro-batch.

    messages:(B,E,d), edge_index:(B,2,E), edge_mask:(B,E)
    -> (B, n_nodes, d). The batched kernel runs grid (B, N/bm) with one
    event's edge list per cell, so aggregation is block-diagonal across
    the micro-batch by construction.
    """
    backend = _resolve(backend)
    if backend == "xla":
        if edge_mask is None:
            return jax.vmap(lambda m, ei: _ref.edge_aggregate_ref(
                m, ei, n_nodes, reduce=reduce))(messages, edge_index)
        return jax.vmap(lambda m, ei, km: _ref.edge_aggregate_ref(
            m, ei, n_nodes, km, reduce=reduce))(messages, edge_index,
                                                edge_mask)
    interpret = backend == "pallas_interpret"
    b, e, _ = messages.shape
    mask = (jnp.ones((b, e), jnp.float32) if edge_mask is None
            else edge_mask.astype(jnp.float32))
    bm = bm or min(n_nodes, 128)
    be = be or e
    mp = _pad_to(messages, be, 1)
    dp = _pad_to(edge_index[:, 1, :].astype(jnp.int32), be, 1)
    kp = _pad_to(mask, be, 1)
    n_pad = n_nodes + ((-n_nodes) % bm)
    y = edge_aggregate_batched_pallas(mp, dp, kp, n_nodes=n_pad,
                                      reduce=reduce, bm=bm, be=be,
                                      interpret=interpret)
    return y[:, :n_nodes]


# --------------------------------------------------------- flash attention ----
@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "backend"))
def flash_attention(q, k, v, *, causal=True, bq=128, bk=128,
                    backend="auto"):
    """Blockwise (flash) attention. q:(BH,S,D), k/v:(BH,T,D)."""
    backend = _resolve(backend)
    if backend == "xla":
        return _ref.flash_attention_ref(q, k, v, causal=causal)
    from repro.kernels.flash_attention import flash_attention_pallas
    interpret = backend == "pallas_interpret"
    s, t = q.shape[1], k.shape[1]
    bq2, bk2 = min(bq, s), min(bk, t)
    ps, pt = (-s) % bq2, (-t) % bk2
    qp = _pad_to(q, bq2, 1)
    kp = _pad_to(k, bk2, 1)
    vp = _pad_to(v, bk2, 1)
    if pt and not causal:
        raise ValueError("non-causal flash requires T % bk == 0")
    y = flash_attention_pallas(qp, kp, vp, causal=causal, bq=bq2, bk=bk2,
                               interpret=interpret)
    return y[:, :s]
