"""Pallas TPU kernels for the compute hot spots the paper optimizes:

- fused_dense : the AIE Dense operator (fusion output), fp + int8,
                'looped' (grid-pipelined) and 'flattened'
                (chess_flatten_loop analogue) variants.
- gravnet     : GravNetConv neighbor selection + potential-weighted
                aggregation, reformulated MXU-natively (argmin/one-hot
                matmul instead of kNN gather).
- gravnet_block : the fused GravNet-block *megakernel* — S/F dense
                prologue → aggregation → output-dense epilogue in one
                launch (the operator-fusion pass's block rewrite).

Both kernels also have *batched* entry points (``fused_dense_batched``,
``gravnet_aggregate_batched``) with a leading event grid dimension so a
whole serving micro-batch amortizes one launch; per-event masking keeps
GravNet neighbor selection block-diagonal (see docs/kernels.md).

ops.py holds the jit'd public wrappers (backend='xla'|'pallas'|
'pallas_interpret'|'auto'); ref.py holds the pure-jnp oracles.
"""
from repro.kernels.ops import (fused_dense, fused_dense_batched,
                               fused_dense_int8, gravnet_aggregate,
                               gravnet_aggregate_batched, gravnet_block,
                               gravnet_block_batched)
