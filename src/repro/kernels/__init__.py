"""Pallas TPU kernels for the compute hot spots the paper optimizes:

- fused_dense : the AIE Dense operator (fusion output), fp + int8,
                'looped' (grid-pipelined) and 'flattened'
                (chess_flatten_loop analogue) variants.
- gravnet     : GravNetConv neighbor selection + potential-weighted
                aggregation, reformulated MXU-natively (argmin/one-hot
                matmul instead of kNN gather).

ops.py holds the jit'd public wrappers (backend='xla'|'pallas'|
'pallas_interpret'|'auto'); ref.py holds the pure-jnp oracles.
"""
from repro.kernels.ops import (fused_dense, fused_dense_int8,
                               gravnet_aggregate)
