"""Pallas TPU kernels: on-device kNN graph building for ragged events.

The bucketed path pads every event to its bucket's hit count and lets
``kernels/gravnet.py`` fuse selection and aggregation per event. The
ragged path instead bin-packs *whole events* into fixed ``capacity``-row
bins (``data/ragged.py``) and splits GravNet into two kernels:

  **knn_build**     — neighbor *selection* in the learned coordinate
                      space: per packed row, the k nearest same-event
                      rows (iterated row-argmin with knockout — the
                      same MXU-friendly schedule as the gravnet
                      kernel), emitting neighbor indices + squared
                      distances. Segment ids replace the validity
                      mask: a candidate column is valid iff it carries
                      the *same event id* as the row and is not the
                      row itself, so selection stays block-diagonal
                      per event even when several events share a bin
                      (pad rows carry segid −1 and match nothing).
  **knn_aggregate** — Gaussian-potential mean/max aggregation of the
                      learned features over those indices, via one-hot
                      matmul (MXU), reproducing ``_gravnet_cell``'s
                      arithmetic bit-for-bit.

TIE-BREAK CONTRACT (pinned by tests/test_knn_build.py): at each of the
k selection steps the *lowest column index* among the minimal
distances wins (``jnp.argmin`` semantics), then the winner is knocked
out. Because bin packing keeps an event's hits contiguous and
in-order, within-event relative column order — and therefore every
tie-break — is identical to the padded per-event launch, which is what
makes ragged and padded outputs bitwise-equal in f32 on real rows
(tested). Rows with fewer than k same-event candidates pad their
remaining slots with distance ``1e30``; the aggregate weighs those
slots 0 (exactly the gravnet kernel's exhausted-candidate behavior).

Grid/blocking mirrors kernels/gravnet.py: rows are tiled ``bm`` per
step with the full per-bin operands VMEM-resident; the batched forms
add a leading bin/event grid dimension with block size 1, so one
launch serves the whole packed micro-batch. Cell bodies are shared
verbatim between the per-bin and batched kernels (batched-vs-looped is
bitwise, tested).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _knn_select_cell(si, sj, segi, segj, i, *, k, bm):
    """One row-block of neighbor selection: si:(bm,ds) rows against
    sj:(n,ds) candidates with segment ids segi:(bm,)/segj:(n,).
    Returns (idx:(bm,k) i32, d2:(bm,k) f32). Shared verbatim by the
    per-bin and batched kernels."""
    n = sj.shape[0]
    d2 = (jnp.sum(si * si, axis=1, keepdims=True)
          + jnp.sum(sj * sj, axis=1)[None, :]
          - 2.0 * jnp.dot(si, sj.T, preferred_element_type=jnp.float32))
    col = jax.lax.broadcasted_iota(jnp.int32, (bm, n), 1)
    row = jax.lax.broadcasted_iota(jnp.int32, (bm, n), 0) + i * bm
    # same-event candidates only; exclude self and padding (segid < 0)
    invalid = ((segj[None, :] != segi[:, None]) | (col == row)
               | (segj[None, :] < 0))
    big = jnp.float32(1e30)
    d2 = jnp.where(invalid, big, jnp.maximum(d2, 0.0))

    kcol = jax.lax.broadcasted_iota(jnp.int32, (bm, k), 1)
    idx_acc = jnp.zeros((bm, k), jnp.int32)
    d2_acc = jnp.full((bm, k), big, jnp.float32)

    def body(t, carry):
        d2, idx_acc, d2_acc = carry
        dmin = jnp.min(d2, axis=1)                          # (bm,)
        amin = jnp.argmin(d2, axis=1).astype(jnp.int32)     # ties -> lowest
        idx_acc = jnp.where(kcol == t, amin[:, None], idx_acc)
        d2_acc = jnp.where(kcol == t, dmin[:, None], d2_acc)
        d2 = jnp.where(col == amin[:, None], big, d2)       # knockout
        return d2, idx_acc, d2_acc

    _, idx_acc, d2_acc = jax.lax.fori_loop(0, k, body,
                                           (d2, idx_acc, d2_acc))
    return idx_acc, d2_acc


def _knn_agg_cell(fj, idx, d2, *, k, scale, bm, out_dtype):
    """One row-block of Gaussian-potential aggregation over selected
    neighbors: fj:(n,df) features, idx/d2:(bm,k) from the selection
    cell. One-hot matmul per step — the same accumulation schedule as
    ``gravnet._gravnet_cell``, hence bitwise-equal in f32 when fed
    that kernel's selection order."""
    n, df = fj.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (bm, n), 1)
    kcol = jax.lax.broadcasted_iota(jnp.int32, (bm, k), 1)
    big = jnp.float32(1e30)
    mean_acc = jnp.zeros((bm, df), jnp.float32)
    max_acc = jnp.full((bm, df), -big, jnp.float32)

    def body(t, carry):
        mean_acc, max_acc = carry
        sel = kcol == t
        amin = jnp.sum(jnp.where(sel, idx, 0), axis=1)       # (bm,)
        dmin = jnp.sum(jnp.where(sel, d2, 0.0), axis=1)      # (bm,)
        onehot = (col == amin[:, None]).astype(jnp.float32)  # (bm, n)
        fsel = jnp.dot(onehot, fj, preferred_element_type=jnp.float32)
        valid = dmin < big * 0.5
        w = jnp.where(valid, jnp.exp(-scale * dmin), 0.0)
        wf = w[:, None] * fsel
        mean_acc = mean_acc + wf
        max_acc = jnp.maximum(max_acc,
                              jnp.where(valid[:, None], wf, -big))
        return mean_acc, max_acc

    mean_acc, max_acc = jax.lax.fori_loop(0, k, body, (mean_acc, max_acc))
    mean = mean_acc / jnp.float32(k)
    maxv = jnp.where(max_acc <= -big * 0.5, 0.0, max_acc)
    return jnp.concatenate([mean, maxv], axis=1).astype(out_dtype)


# ------------------------------------------------------- selection kernels ----
def _knn_build_kernel(si_ref, s_ref, segi_ref, seg_ref, idx_ref, d2_ref,
                      *, k, bm):
    idx, d2 = _knn_select_cell(
        si_ref[...].astype(jnp.float32),       # (bm, ds) row block
        s_ref[...].astype(jnp.float32),        # (n, ds)  all coords
        segi_ref[...][:, 0],                   # (bm,)    row segids
        seg_ref[...][:, 0],                    # (n,)     all segids
        pl.program_id(0), k=k, bm=bm)
    idx_ref[...] = idx
    d2_ref[...] = d2


def _knn_build_kernel_batched(si_ref, s_ref, segi_ref, seg_ref, idx_ref,
                              d2_ref, *, k, bm):
    # leading block dim is 1 (one bin per grid cell along axis 0)
    idx, d2 = _knn_select_cell(
        si_ref[0].astype(jnp.float32),
        s_ref[0].astype(jnp.float32),
        segi_ref[0][:, 0],
        seg_ref[0][:, 0],
        pl.program_id(1), k=k, bm=bm)
    idx_ref[0] = idx
    d2_ref[0] = d2


def knn_build_pallas(s, segids, *, k=8, bm=None, interpret=False):
    """Neighbor selection for one packed bin. s:(N,ds), segids:(N,) i32
    -> (idx:(N,k) i32, d2:(N,k) f32). Caller pads N to a multiple of
    ``bm``; padding rows carry segid −1 and select nothing."""
    n, ds = s.shape
    bm = bm or min(n, 128)
    assert n % bm == 0, (n, bm)
    seg2 = segids.reshape(n, 1).astype(jnp.int32)
    kern = functools.partial(_knn_build_kernel, k=k, bm=bm)
    return pl.pallas_call(
        kern,
        grid=(n // bm,),
        out_shape=(jax.ShapeDtypeStruct((n, k), jnp.int32),
                   jax.ShapeDtypeStruct((n, k), jnp.float32)),
        in_specs=[
            pl.BlockSpec((bm, ds), lambda i: (i, 0)),   # row block
            pl.BlockSpec((n, ds), lambda i: (0, 0)),    # all coords
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),    # row segids
            pl.BlockSpec((n, 1), lambda i: (0, 0)),     # all segids
        ],
        out_specs=(pl.BlockSpec((bm, k), lambda i: (i, 0)),
                   pl.BlockSpec((bm, k), lambda i: (i, 0))),
        interpret=interpret,
    )(s, s, seg2, seg2)


def knn_build_batched_pallas(s, segids, *, k=8, bm=None, interpret=False):
    """Batched neighbor selection in ONE launch. s:(B,N,ds),
    segids:(B,N) -> (idx:(B,N,k), d2:(B,N,k)). Grid (B, N/bm); each
    cell sees one bin's operands (same cell body as the per-bin form,
    so batched-vs-looped is bitwise)."""
    b, n, ds = s.shape
    bm = bm or min(n, 128)
    assert n % bm == 0, (n, bm)
    seg2 = segids.reshape(b, n, 1).astype(jnp.int32)
    kern = functools.partial(_knn_build_kernel_batched, k=k, bm=bm)
    return pl.pallas_call(
        kern,
        grid=(b, n // bm),
        out_shape=(jax.ShapeDtypeStruct((b, n, k), jnp.int32),
                   jax.ShapeDtypeStruct((b, n, k), jnp.float32)),
        in_specs=[
            pl.BlockSpec((1, bm, ds), lambda e, i: (e, i, 0)),
            pl.BlockSpec((1, n, ds), lambda e, i: (e, 0, 0)),
            pl.BlockSpec((1, bm, 1), lambda e, i: (e, i, 0)),
            pl.BlockSpec((1, n, 1), lambda e, i: (e, 0, 0)),
        ],
        out_specs=(pl.BlockSpec((1, bm, k), lambda e, i: (e, i, 0)),
                   pl.BlockSpec((1, bm, k), lambda e, i: (e, i, 0))),
        interpret=interpret,
    )(s, s, seg2, seg2)


# ----------------------------------------------------- aggregation kernels ----
def _knn_agg_kernel(f_ref, idx_ref, d2_ref, o_ref, *, k, scale, bm,
                    out_dtype):
    o_ref[...] = _knn_agg_cell(
        f_ref[...].astype(jnp.float32),        # (n, df) all features
        idx_ref[...],                          # (bm, k) neighbor ids
        d2_ref[...].astype(jnp.float32),       # (bm, k) distances
        k=k, scale=scale, bm=bm, out_dtype=out_dtype)


def _knn_agg_kernel_batched(f_ref, idx_ref, d2_ref, o_ref, *, k, scale,
                            bm, out_dtype):
    o_ref[0] = _knn_agg_cell(
        f_ref[0].astype(jnp.float32),
        idx_ref[0],
        d2_ref[0].astype(jnp.float32),
        k=k, scale=scale, bm=bm, out_dtype=out_dtype)


def knn_aggregate_pallas(f, idx, d2, *, scale=10.0, bm=None, out_dtype=None,
                         interpret=False):
    """Aggregate one packed bin. f:(N,df), idx/d2:(N,k) -> (N, 2·df)."""
    n, df = f.shape
    k = idx.shape[1]
    out_dtype = out_dtype or f.dtype
    bm = bm or min(n, 128)
    assert n % bm == 0, (n, bm)
    kern = functools.partial(_knn_agg_kernel, k=k, scale=scale, bm=bm,
                             out_dtype=out_dtype)
    return pl.pallas_call(
        kern,
        grid=(n // bm,),
        out_shape=jax.ShapeDtypeStruct((n, 2 * df), out_dtype),
        in_specs=[
            pl.BlockSpec((n, df), lambda i: (0, 0)),    # all features
            pl.BlockSpec((bm, k), lambda i: (i, 0)),    # row indices
            pl.BlockSpec((bm, k), lambda i: (i, 0)),    # row distances
        ],
        out_specs=pl.BlockSpec((bm, 2 * df), lambda i: (i, 0)),
        interpret=interpret,
    )(f, idx, d2)


def knn_aggregate_batched_pallas(f, idx, d2, *, scale=10.0, bm=None,
                                 out_dtype=None, interpret=False):
    """Batched aggregation in ONE launch. f:(B,N,df), idx/d2:(B,N,k)
    -> (B, N, 2·df). Grid (B, N/bm), shared cell body."""
    b, n, df = f.shape
    k = idx.shape[2]
    out_dtype = out_dtype or f.dtype
    bm = bm or min(n, 128)
    assert n % bm == 0, (n, bm)
    kern = functools.partial(_knn_agg_kernel_batched, k=k, scale=scale,
                             bm=bm, out_dtype=out_dtype)
    return pl.pallas_call(
        kern,
        grid=(b, n // bm),
        out_shape=jax.ShapeDtypeStruct((b, n, 2 * df), out_dtype),
        in_specs=[
            pl.BlockSpec((1, n, df), lambda e, i: (e, 0, 0)),
            pl.BlockSpec((1, bm, k), lambda e, i: (e, i, 0)),
            pl.BlockSpec((1, bm, k), lambda e, i: (e, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, 2 * df), lambda e, i: (e, i, 0)),
        interpret=interpret,
    )(f, idx, d2)
