"""Pallas TPU kernel: GravNet neighbor aggregation (dynamic-GNN hot spot).

GravNetConv (Qasim et al., arXiv:1902.07987; used by CaloClusterNet) per
node i: find the k nearest neighbors of s_i in a *learned* coordinate
space, weight their learned features f_j by a Gaussian potential
w_ij = exp(-scale * d²_ij), and aggregate with both mean and max.

HARDWARE ADAPTATION (GPU/FPGA → TPU): the reference implementations use a
kNN index build + irregular gather — the part the paper keeps on FPGA
fabric because it is data-dependent. TPUs have no efficient dynamic
row-gather inside a kernel, but they have an MXU. We therefore reformulate
neighbor selection as **k iterations of (row-argmin → one-hot → matmul)**:

    for t in 1..k:
        dmin, amin = min/argmin over candidate distances   (VPU reduce)
        f_sel      = one_hot(amin) @ F                     (MXU matmul)
        accumulate mean/max of exp(-scale·dmin) · f_sel
        knock out the selected column (set distance to +inf)

For trigger-scale graphs (N ≤ a few hundred, k ≤ 16) this is strictly
regular, statically scheduled compute — which is exactly the property the
paper's partitioner rewards; on TPU the whole GravNetConv becomes eligible
for the "regular" (MXU) partition instead of being pinned to the
irregular side. Cost: k·N²·d_f MACs ≈ MXU noise at these sizes.

Grid: rows are tiled (bm per step); the full S/F/mask operands stay VMEM
resident (N ≤ ~4096 fits comfortably: 4096×(d_s+d_f)×4B ≪ 128 MiB).

BATCHED (occupancy-bucketed) FORM: ``gravnet_aggregate_batched_pallas``
adds a leading *event* grid dimension — grid (B, N/bm) — so one kernel
launch processes a whole serving micro-batch. Each grid cell still sees
exactly one event's operands (BlockSpecs slice the batch axis one event
at a time), so neighbor selection stays block-diagonal by construction:
no cross-event edges are even representable, and per-event masking is
unchanged. The cell body is byte-identical to the per-event kernel
(shared ``_gravnet_cell``), which is what makes the batched path
bitwise-equal in f32 to a loop of per-event launches (tested).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gravnet_cell(si, sj, fj, maskj, i, *, k, scale, bm, out_dtype):
    """One row-block of one event: si:(bm,ds) against sj:(n,ds)/fj:(n,df)
    with validity maskj:(n,); ``i`` is the row-block index within the
    event. Shared verbatim by the per-event and batched kernels."""
    n = sj.shape[0]
    df = fj.shape[1]

    # Pairwise squared distances for this row block: (bm, n).
    d2 = (jnp.sum(si * si, axis=1, keepdims=True)
          + jnp.sum(sj * sj, axis=1)[None, :]
          - 2.0 * jnp.dot(si, sj.T, preferred_element_type=jnp.float32))
    col = jax.lax.broadcasted_iota(jnp.int32, (bm, n), 1)
    row = jax.lax.broadcasted_iota(jnp.int32, (bm, n), 0) + i * bm
    invalid = (maskj[None, :] <= 0) | (col == row)   # exclude self + padding
    big = jnp.float32(1e30)
    d2 = jnp.where(invalid, big, jnp.maximum(d2, 0.0))

    mean_acc = jnp.zeros((bm, df), jnp.float32)
    max_acc = jnp.full((bm, df), -big, jnp.float32)

    def body(_, carry):
        d2, mean_acc, max_acc = carry
        dmin = jnp.min(d2, axis=1)                          # (bm,)
        amin = jnp.argmin(d2, axis=1).astype(jnp.int32)     # (bm,)
        onehot = (col == amin[:, None]).astype(jnp.float32)  # (bm, n)
        fsel = jnp.dot(onehot, fj, preferred_element_type=jnp.float32)
        valid = dmin < big * 0.5
        w = jnp.where(valid, jnp.exp(-scale * dmin), 0.0)    # (bm,)
        wf = w[:, None] * fsel
        mean_acc = mean_acc + wf
        max_acc = jnp.maximum(max_acc,
                              jnp.where(valid[:, None], wf, -big))
        d2 = jnp.where(col == amin[:, None], big, d2)
        return d2, mean_acc, max_acc

    d2, mean_acc, max_acc = jax.lax.fori_loop(0, k, body,
                                              (d2, mean_acc, max_acc))
    mean = mean_acc / jnp.float32(k)
    maxv = jnp.where(max_acc <= -big * 0.5, 0.0, max_acc)
    return jnp.concatenate([mean, maxv], axis=1).astype(out_dtype)


def _gravnet_kernel(si_ref, s_ref, f_ref, mask_ref, o_ref, *, k, scale, bm,
                    out_dtype):
    o_ref[...] = _gravnet_cell(
        si_ref[...].astype(jnp.float32),       # (bm, ds) row block
        s_ref[...].astype(jnp.float32),        # (n, ds)  all coords
        f_ref[...].astype(jnp.float32),        # (n, df)  all features
        mask_ref[...][:, 0],                   # (n,)     validity
        pl.program_id(0), k=k, scale=scale, bm=bm, out_dtype=out_dtype)


def _gravnet_kernel_batched(si_ref, s_ref, f_ref, mask_ref, o_ref, *, k,
                            scale, bm, out_dtype):
    # leading block dim is 1 (one event per grid cell along axis 0);
    # [0] drops it so the cell body is identical to the per-event form
    o_ref[0] = _gravnet_cell(
        si_ref[0].astype(jnp.float32),
        s_ref[0].astype(jnp.float32),
        f_ref[0].astype(jnp.float32),
        mask_ref[0][:, 0],
        pl.program_id(1), k=k, scale=scale, bm=bm, out_dtype=out_dtype)


def gravnet_aggregate_pallas(s, f, mask, *, k=8, scale=10.0, bm=None,
                             out_dtype=None, interpret=False):
    """GravNet aggregation. s:(N,ds) f:(N,df) mask:(N,) -> (N, 2·df).

    Rows with mask<=0 are candidates for neither selection nor output use;
    caller pads N to a multiple of ``bm``. Self-edges are excluded.
    """
    n, _ = s.shape
    df = f.shape[1]
    out_dtype = out_dtype or f.dtype
    bm = bm or min(n, 128)
    assert n % bm == 0, (n, bm)
    mask2 = mask.reshape(n, 1).astype(jnp.float32)
    kern = functools.partial(_gravnet_kernel, k=k, scale=scale, bm=bm,
                             out_dtype=out_dtype)
    return pl.pallas_call(
        kern,
        grid=(n // bm,),
        out_shape=jax.ShapeDtypeStruct((n, 2 * df), out_dtype),
        in_specs=[
            pl.BlockSpec((bm, s.shape[1]), lambda i: (i, 0)),   # row block
            pl.BlockSpec((n, s.shape[1]), lambda i: (0, 0)),    # all coords
            pl.BlockSpec((n, df), lambda i: (0, 0)),            # all feats
            pl.BlockSpec((n, 1), lambda i: (0, 0)),             # mask
        ],
        out_specs=pl.BlockSpec((bm, 2 * df), lambda i: (i, 0)),
        interpret=interpret,
    )(s, s, f, mask2)


def gravnet_aggregate_batched_pallas(s, f, mask, *, k=8, scale=10.0,
                                     bm=None, out_dtype=None,
                                     interpret=False):
    """Micro-batched GravNet aggregation in ONE kernel launch.

    s:(B,N,ds) f:(B,N,df) mask:(B,N) -> (B, N, 2·df). Grid is
    (B, N/bm): the leading grid dimension walks events, so the whole
    micro-batch amortizes a single launch while every cell sees exactly
    one event's operands — neighbor selection is block-diagonal and no
    cross-event edge can form. f32 results are bitwise identical to B
    per-event launches (same cell body, same schedule).
    """
    b, n, ds = s.shape
    df = f.shape[2]
    out_dtype = out_dtype or f.dtype
    bm = bm or min(n, 128)
    assert n % bm == 0, (n, bm)
    mask2 = mask.reshape(b, n, 1).astype(jnp.float32)
    kern = functools.partial(_gravnet_kernel_batched, k=k, scale=scale,
                             bm=bm, out_dtype=out_dtype)
    return pl.pallas_call(
        kern,
        grid=(b, n // bm),
        out_shape=jax.ShapeDtypeStruct((b, n, 2 * df), out_dtype),
        in_specs=[
            pl.BlockSpec((1, bm, ds), lambda e, i: (e, i, 0)),   # row block
            pl.BlockSpec((1, n, ds), lambda e, i: (e, 0, 0)),    # all coords
            pl.BlockSpec((1, n, df), lambda e, i: (e, 0, 0)),    # all feats
            pl.BlockSpec((1, n, 1), lambda e, i: (e, 0, 0)),     # mask
        ],
        out_specs=pl.BlockSpec((1, bm, 2 * df), lambda e, i: (e, i, 0)),
        interpret=interpret,
    )(s, s, f, mask2)
