"""Pallas TPU kernel: GravNet neighbor aggregation (dynamic-GNN hot spot).

GravNetConv (Qasim et al., arXiv:1902.07987; used by CaloClusterNet) per
node i: find the k nearest neighbors of s_i in a *learned* coordinate
space, weight their learned features f_j by a Gaussian potential
w_ij = exp(-scale * d²_ij), and aggregate with both mean and max.

HARDWARE ADAPTATION (GPU/FPGA → TPU): the reference implementations use a
kNN index build + irregular gather — the part the paper keeps on FPGA
fabric because it is data-dependent. TPUs have no efficient dynamic
row-gather inside a kernel, but they have an MXU. We therefore reformulate
neighbor selection as **k iterations of (row-argmin → one-hot → matmul)**:

    for t in 1..k:
        dmin, amin = min/argmin over candidate distances   (VPU reduce)
        f_sel      = one_hot(amin) @ F                     (MXU matmul)
        accumulate mean/max of exp(-scale·dmin) · f_sel
        knock out the selected column (set distance to +inf)

For trigger-scale graphs (N ≤ a few hundred, k ≤ 16) this is strictly
regular, statically scheduled compute — which is exactly the property the
paper's partitioner rewards; on TPU the whole GravNetConv becomes eligible
for the "regular" (MXU) partition instead of being pinned to the
irregular side. Cost: k·N²·d_f MACs ≈ MXU noise at these sizes.

Grid: rows are tiled (bm per step); the full S/F/mask operands stay VMEM
resident (N ≤ ~4096 fits comfortably: 4096×(d_s+d_f)×4B ≪ 128 MiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gravnet_kernel(si_ref, s_ref, f_ref, mask_ref, o_ref, *, k, scale, bm,
                    out_dtype):
    i = pl.program_id(0)
    si = si_ref[...].astype(jnp.float32)           # (bm, ds) row block
    sj = s_ref[...].astype(jnp.float32)            # (n, ds)  all coords
    fj = f_ref[...].astype(jnp.float32)            # (n, df)  all features
    maskj = mask_ref[...][:, 0]                    # (n,)     validity
    n = sj.shape[0]
    df = fj.shape[1]

    # Pairwise squared distances for this row block: (bm, n).
    d2 = (jnp.sum(si * si, axis=1, keepdims=True)
          + jnp.sum(sj * sj, axis=1)[None, :]
          - 2.0 * jnp.dot(si, sj.T, preferred_element_type=jnp.float32))
    col = jax.lax.broadcasted_iota(jnp.int32, (bm, n), 1)
    row = jax.lax.broadcasted_iota(jnp.int32, (bm, n), 0) + i * bm
    invalid = (maskj[None, :] <= 0) | (col == row)   # exclude self + padding
    big = jnp.float32(1e30)
    d2 = jnp.where(invalid, big, jnp.maximum(d2, 0.0))

    mean_acc = jnp.zeros((bm, df), jnp.float32)
    max_acc = jnp.full((bm, df), -big, jnp.float32)

    def body(_, carry):
        d2, mean_acc, max_acc = carry
        dmin = jnp.min(d2, axis=1)                          # (bm,)
        amin = jnp.argmin(d2, axis=1).astype(jnp.int32)     # (bm,)
        onehot = (col == amin[:, None]).astype(jnp.float32)  # (bm, n)
        fsel = jnp.dot(onehot, fj, preferred_element_type=jnp.float32)
        valid = dmin < big * 0.5
        w = jnp.where(valid, jnp.exp(-scale * dmin), 0.0)    # (bm,)
        wf = w[:, None] * fsel
        mean_acc = mean_acc + wf
        max_acc = jnp.maximum(max_acc,
                              jnp.where(valid[:, None], wf, -big))
        d2 = jnp.where(col == amin[:, None], big, d2)
        return d2, mean_acc, max_acc

    d2, mean_acc, max_acc = jax.lax.fori_loop(0, k, body,
                                              (d2, mean_acc, max_acc))
    mean = mean_acc / jnp.float32(k)
    maxv = jnp.where(max_acc <= -big * 0.5, 0.0, max_acc)
    o_ref[...] = jnp.concatenate([mean, maxv], axis=1).astype(out_dtype)


def gravnet_aggregate_pallas(s, f, mask, *, k=8, scale=10.0, bm=None,
                             out_dtype=None, interpret=False):
    """GravNet aggregation. s:(N,ds) f:(N,df) mask:(N,) -> (N, 2·df).

    Rows with mask<=0 are candidates for neither selection nor output use;
    caller pads N to a multiple of ``bm``. Self-edges are excluded.
    """
    n, _ = s.shape
    df = f.shape[1]
    out_dtype = out_dtype or f.dtype
    bm = bm or min(n, 128)
    assert n % bm == 0, (n, bm)
    mask2 = mask.reshape(n, 1).astype(jnp.float32)
    kern = functools.partial(_gravnet_kernel, k=k, scale=scale, bm=bm,
                             out_dtype=out_dtype)
    return pl.pallas_call(
        kern,
        grid=(n // bm,),
        out_shape=jax.ShapeDtypeStruct((n, 2 * df), out_dtype),
        in_specs=[
            pl.BlockSpec((bm, s.shape[1]), lambda i: (i, 0)),   # row block
            pl.BlockSpec((n, s.shape[1]), lambda i: (0, 0)),    # all coords
            pl.BlockSpec((n, df), lambda i: (0, 0)),            # all feats
            pl.BlockSpec((n, 1), lambda i: (0, 0)),             # mask
        ],
        out_specs=pl.BlockSpec((bm, 2 * df), lambda i: (i, 0)),
        interpret=interpret,
    )(s, s, f, mask2)
