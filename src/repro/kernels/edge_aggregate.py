"""Pallas TPU kernel: masked edge aggregation (segment-sum / segment-mean).

Edge-based GNNs (GatedGCN, GraphSAGE, …) aggregate per-edge messages
into destination nodes: ``out[i] = Σ_{e: dst[e]=i} mask[e] · msg[e]``
(mean divides by the valid in-degree). The reference implementations
lower this as an irregular scatter — the data-dependent part that keeps
message passing off systolic hardware.

HARDWARE ADAPTATION (GPU/FPGA → TPU): the same reformulation that makes
GravNet's kNN gather MXU-native (kernels/gravnet.py) applies to edge
scatter. For a block of ``bm`` destination rows, build the one-hot
incidence slab

    onehot[r, e] = (dst[e] == row_r) · mask[e]          (VPU compare)
    out_block    = onehot @ messages                    (MXU matmul)

so the whole scatter becomes a statically scheduled dense matmul of
shape (bm, E) × (E, d). The mask rides inside the incidence slab, which
reproduces the reference's ``messages * mask`` weighting exactly (and
for mean, ``row_sum(onehot)`` is exactly the reference's masked edge
count). Cost: N·E MACs per feature column — MXU noise at trigger-scale
graphs (N ≤ a few hundred, E ≈ 4N).

Knobs: ``bm`` tiles destination rows per grid step; ``be`` splits the
edge axis into VMEM-bounded chunks accumulated in order (an f32
association knob like fused-dense ``bk`` — a non-default ``be`` must
win on measured time; the default single chunk matches the reference's
one-shot segment reduction up to matmul summation order).

BATCHED FORM: ``edge_aggregate_batched_pallas`` adds a leading event
grid dimension — grid (B, N/bm) — sharing the same cell body, so each
cell sees exactly one event's edge list and aggregation stays
block-diagonal across the micro-batch by construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _edge_aggregate_cell(msgs, dst, maskv, i, *, bm, be, reduce, out_dtype):
    """One destination-row block: msgs:(E,d) against dst/maskv:(E,);
    ``i`` is the row-block index within the event. Shared verbatim by
    the per-event and batched kernels."""
    e, d = msgs.shape
    rows = jax.lax.broadcasted_iota(jnp.int32, (bm, be), 0) + i * bm
    acc = jnp.zeros((bm, d), jnp.float32)
    cnt = jnp.zeros((bm,), jnp.float32)
    for c in range(e // be):  # static unrolled edge-chunk loop
        mc = msgs[c * be:(c + 1) * be]
        dc = dst[c * be:(c + 1) * be]
        kc = maskv[c * be:(c + 1) * be]
        onehot = ((rows == dc[None, :]).astype(jnp.float32)
                  * kc[None, :])                          # (bm, be)
        acc = acc + jnp.dot(onehot, mc,
                            preferred_element_type=jnp.float32)
        if reduce == "mean":
            cnt = cnt + jnp.sum(onehot, axis=1)
    if reduce == "mean":
        acc = acc / jnp.maximum(cnt, 1.0)[:, None]
    return acc.astype(out_dtype)


def _edge_aggregate_kernel(m_ref, d_ref, k_ref, o_ref, *, bm, be, reduce,
                           out_dtype):
    o_ref[...] = _edge_aggregate_cell(
        m_ref[...].astype(jnp.float32),    # (e, d) all messages
        d_ref[...][:, 0],                  # (e,)   destination ids
        k_ref[...][:, 0],                  # (e,)   edge validity
        pl.program_id(0), bm=bm, be=be, reduce=reduce, out_dtype=out_dtype)


def _edge_aggregate_kernel_batched(m_ref, d_ref, k_ref, o_ref, *, bm, be,
                                   reduce, out_dtype):
    # leading block dim is 1 (one event per grid cell along axis 0);
    # [0] drops it so the cell body is identical to the per-event form
    o_ref[0] = _edge_aggregate_cell(
        m_ref[0].astype(jnp.float32),
        d_ref[0][:, 0],
        k_ref[0][:, 0],
        pl.program_id(1), bm=bm, be=be, reduce=reduce, out_dtype=out_dtype)


def edge_aggregate_pallas(messages, dst, mask, *, n_nodes, reduce="sum",
                          bm=None, be=None, out_dtype=None,
                          interpret=False):
    """Edge aggregation. messages:(E,d), dst:(E,), mask:(E,) ->
    (n_nodes, d). Caller pads n_nodes to a multiple of ``bm`` and E to
    a multiple of ``be``; padded edges carry mask 0."""
    e, d = messages.shape
    out_dtype = out_dtype or messages.dtype
    bm = bm or min(n_nodes, 128)
    be = be or e
    assert n_nodes % bm == 0, (n_nodes, bm)
    assert e % be == 0, (e, be)
    dst2 = dst.reshape(e, 1).astype(jnp.int32)
    mask2 = mask.reshape(e, 1).astype(jnp.float32)
    kern = functools.partial(_edge_aggregate_kernel, bm=bm, be=be,
                             reduce=reduce, out_dtype=out_dtype)
    return pl.pallas_call(
        kern,
        grid=(n_nodes // bm,),
        out_shape=jax.ShapeDtypeStruct((n_nodes, d), out_dtype),
        in_specs=[
            pl.BlockSpec((e, d), lambda i: (0, 0)),    # all messages
            pl.BlockSpec((e, 1), lambda i: (0, 0)),    # destinations
            pl.BlockSpec((e, 1), lambda i: (0, 0)),    # edge mask
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        interpret=interpret,
    )(messages, dst2, mask2)


def edge_aggregate_batched_pallas(messages, dst, mask, *, n_nodes,
                                  reduce="sum", bm=None, be=None,
                                  out_dtype=None, interpret=False):
    """Micro-batched edge aggregation in ONE kernel launch.

    messages:(B,E,d), dst:(B,E), mask:(B,E) -> (B, n_nodes, d). Grid is
    (B, N/bm): the leading grid dimension walks events, so each cell
    sees exactly one event's edge list — no cross-event edge can form.
    """
    b, e, d = messages.shape
    out_dtype = out_dtype or messages.dtype
    bm = bm or min(n_nodes, 128)
    be = be or e
    assert n_nodes % bm == 0, (n_nodes, bm)
    assert e % be == 0, (e, be)
    dst2 = dst.reshape(b, e, 1).astype(jnp.int32)
    mask2 = mask.reshape(b, e, 1).astype(jnp.float32)
    kern = functools.partial(_edge_aggregate_kernel_batched, bm=bm, be=be,
                             reduce=reduce, out_dtype=out_dtype)
    return pl.pallas_call(
        kern,
        grid=(b, n_nodes // bm),
        out_shape=jax.ShapeDtypeStruct((b, n_nodes, d), out_dtype),
        in_specs=[
            pl.BlockSpec((1, e, d), lambda ev, i: (ev, 0, 0)),
            pl.BlockSpec((1, e, 1), lambda ev, i: (ev, 0, 0)),
            pl.BlockSpec((1, e, 1), lambda ev, i: (ev, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, d), lambda ev, i: (ev, i, 0)),
        interpret=interpret,
    )(messages, dst2, mask2)
