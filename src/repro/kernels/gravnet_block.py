"""Pallas TPU megakernel: one whole GravNet block per launch.

The deployed CaloClusterNet spends its latency budget in the GravNet
blocks, yet the unfused executor runs each block as 3–4 separate
launches — S/F projection dense(s), ``gravnet_aggregate``, and the
post-aggregation dense — materializing every intermediate to HBM
between them. LL-GNN (arXiv:2209.14065) shows that collapsing GNN
layer boundaries is the key to sub-microsecond latency; this kernel
applies the same move on TPU by fusing

    dense(S-proj) ∥ dense(F-proj) → k-NN aggregate → dense(out)+act

into ONE ``pallas_call``:

- **prologue** — the S/F projections run as matmuls on the
  VMEM-resident ``x`` operand: ``S = x @ Ws + bs`` (per row block AND
  for the full event, since every query block aggregates against all
  nodes) and ``F = x @ Wf + bf``. Neither S nor F ever reaches HBM.
- **body** — the k-NN aggregation reuses ``gravnet._gravnet_cell``
  *verbatim* (same argmin/one-hot/matmul schedule, same row tile
  ``bm``), so the aggregation is bitwise-identical in f32 to the
  standalone gravnet kernel at the same ``bm``.
- **epilogue** — the output dense consumes ``concat(x_block, agg)``
  (``concat_x=True``, the CaloClusterNet shape) or ``agg`` alone, adds
  the bias, applies the activation, and writes the only HBM output.
  Optional ``(bn, bk)`` blocking tiles the epilogue matmul for the
  autotuner; the defaults run one whole-operand dot, which keeps the
  fused output bitwise-equal (f32) to the unfused chain (tested).

BATCHED (occupancy-bucketed) FORM: ``gravnet_block_batched_pallas``
adds the same leading *event* grid dimension as the batched gravnet
kernel — grid ``(B, N/bm)`` — so one launch serves a whole serving
micro-batch. Each cell sees exactly one event's operands (weights are
shared across the event grid; their BlockSpecs ignore the indices), so
aggregation stays block-diagonal by construction.

The S/F prologue is recomputed per row block when ``bm < N`` (every
query block needs all N projected rows). At trigger scale that trade
is free — the recomputed matmuls are (N, d_hidden) @ (d_hidden, d_s/f)
with d_s ≤ 4, d_f ≤ 32 — and it is what keeps the kernel free of
cross-grid-step communication.

QUANTIZED (int8) FORM: ``gravnet_block_int8_pallas`` /
``gravnet_block_int8_batched_pallas`` run the same schedule in the
mixed-precision interior's arithmetic, with the three calibrated
per-tensor activation scales baked in as kernel *constants* (python
floats closed over at trace time — no scalar operands to fetch):

- the f32 input rows quantize to int8 in VMEM with ``x_scale`` (the
  producer's calibrated activation scale), exactly as the unfused
  calibrated dense does on entry;
- the S/F prologue runs int8×int8→int32 MXU dots, dequantized through
  ``x_scale · w_scale[col]`` (+bias) to f32 — the unfused chain never
  requantizes S/F (the merged projection's output feeds retile/slice
  views, which break the int8 emit chain), so neither does the kernel;
- the aggregation body is the same f32 ``_gravnet_cell``; its output
  snaps to the int8 grid via ``agg_scale`` (the aggregate op's
  calibrated activation scale), modeling 8-bit fabric arithmetic;
- the epilogue quantizes ``concat(x, agg)`` with ``h_scale`` in VMEM
  and runs the output dense as int8×int8→int32 dots (the (bn, bk)
  epilogue blocking stays available — int32 partial sums make even the
  ``bk`` K-split *exact*, unlike the f32 epilogue), dequantizing
  through ``h_scale · wo_scale[col]`` + bias + activation. The only
  HBM write is the final f32 (or requantized int8) output.

Everything between the HBM read of x and the HBM write of y — both
quantize steps, three int8 matmuls, the aggregation, the requant snap
— lives in VMEM/registers for the grid cell's lifetime.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fused_dense import _activate
from repro.kernels.gravnet import _gravnet_cell


def _epilogue_dense(h, wo, bo, *, bn, bk, activation, out_dtype):
    """act(h @ wo + bo) with optional (bn, bk) epilogue blocking.

    Defaults (bn=bk=None) run one whole-operand dot — bitwise identical
    to the unfused fused_dense kernel's matmul. ``bn`` splits output
    columns (still bitwise: column decomposition leaves each element's
    K reduction intact); ``bk`` splits the K reduction itself, whose
    f32 partial-sum association may differ in the last ulp — it is an
    autotuner-only option that must win on measured time to bind.
    """
    dcat, dout = wo.shape
    bn = dout if bn is None else min(bn, dout)
    bk = dcat if bk is None else min(bk, dcat)
    cols = []
    for j0 in range(0, dout, bn):
        j1 = min(j0 + bn, dout)
        parts = [jnp.dot(h[:, k0:min(k0 + bk, dcat)],
                         wo[k0:min(k0 + bk, dcat), j0:j1],
                         preferred_element_type=jnp.float32)
                 for k0 in range(0, dcat, bk)]
        acc = parts[0]
        for p in parts[1:]:
            acc = acc + p
        cols.append(acc)
    y = cols[0] if len(cols) == 1 else jnp.concatenate(cols, axis=1)
    y = y + bo.astype(jnp.float32)
    return _activate(y, activation).astype(out_dtype)


def _gravnet_block_cell(xi, xall, maskj, ws, bs, wf, bf, wo, bo, i, *, k,
                        scale, bm, bn, bk, activation, concat_x, out_dtype):
    """One row block of one event, prologue → aggregate → epilogue.

    xi:(bm,dh) query rows, xall:(n,dh) all rows, maskj:(n,) validity;
    ``i`` is the row-block index within the event. All arithmetic f32.
    """
    s_all = (jnp.dot(xall, ws, preferred_element_type=jnp.float32)
             + bs.astype(jnp.float32))
    f_all = (jnp.dot(xall, wf, preferred_element_type=jnp.float32)
             + bf.astype(jnp.float32))
    # the query rows' coordinates: recomputed from the row block (f32
    # matmul rows are independent, so this equals s_all's rows bitwise)
    si = (jnp.dot(xi, ws, preferred_element_type=jnp.float32)
          + bs.astype(jnp.float32))
    agg = _gravnet_cell(si, s_all, f_all, maskj, i, k=k, scale=scale,
                        bm=bm, out_dtype=jnp.float32)
    h = jnp.concatenate([xi, agg], axis=1) if concat_x else agg
    return _epilogue_dense(h, wo, bo, bn=bn, bk=bk, activation=activation,
                           out_dtype=out_dtype)


def _gravnet_block_kernel(xi_ref, x_ref, mask_ref, ws_ref, bs_ref, wf_ref,
                          bf_ref, wo_ref, bo_ref, o_ref, *, k, scale, bm,
                          bn, bk, activation, concat_x, out_dtype):
    o_ref[...] = _gravnet_block_cell(
        xi_ref[...].astype(jnp.float32),       # (bm, dh) query rows
        x_ref[...].astype(jnp.float32),        # (n, dh)  all rows
        mask_ref[...][:, 0],                   # (n,)     validity
        ws_ref[...].astype(jnp.float32), bs_ref[...],
        wf_ref[...].astype(jnp.float32), bf_ref[...],
        wo_ref[...].astype(jnp.float32), bo_ref[...],
        pl.program_id(0), k=k, scale=scale, bm=bm, bn=bn, bk=bk,
        activation=activation, concat_x=concat_x, out_dtype=out_dtype)


def _gravnet_block_kernel_batched(xi_ref, x_ref, mask_ref, ws_ref, bs_ref,
                                  wf_ref, bf_ref, wo_ref, bo_ref, o_ref, *,
                                  k, scale, bm, bn, bk, activation,
                                  concat_x, out_dtype):
    # leading block dim is 1 (one event per grid cell along axis 0);
    # [0] drops it so the cell body is identical to the per-event form
    o_ref[0] = _gravnet_block_cell(
        xi_ref[0].astype(jnp.float32),
        x_ref[0].astype(jnp.float32),
        mask_ref[0][:, 0],
        ws_ref[...].astype(jnp.float32), bs_ref[...],
        wf_ref[...].astype(jnp.float32), bf_ref[...],
        wo_ref[...].astype(jnp.float32), bo_ref[...],
        pl.program_id(1), k=k, scale=scale, bm=bm, bn=bn, bk=bk,
        activation=activation, concat_x=concat_x, out_dtype=out_dtype)


def gravnet_block_pallas(x, mask, ws, bs, wf, bf, wo, bo, *, k=8,
                         scale=10.0, activation="relu", concat_x=True,
                         bm=None, bn=None, bk=None, out_dtype=None,
                         interpret=False):
    """One GravNet block, one launch. x:(N,dh) mask:(N,) -> (N, d_out).

    ws:(dh,ds)/bs:(ds,) and wf:(dh,df)/bf:(df,) are the S/F projection
    params; wo:(dh+2·df, d_out) (or (2·df, d_out) with concat_x=False)
    and bo:(d_out,) the output dense. Caller pads N to a multiple of
    ``bm`` (``ops.gravnet_block`` does).
    """
    n, dh = x.shape
    ds, df = ws.shape[1], wf.shape[1]
    dcat, dout = wo.shape
    out_dtype = out_dtype or x.dtype
    bm = bm or min(n, 128)
    assert n % bm == 0, (n, bm)
    assert dcat == (dh + 2 * df if concat_x else 2 * df), (dcat, dh, df)
    mask2 = mask.reshape(n, 1).astype(jnp.float32)
    bs2, bf2, bo2 = (bs.reshape(1, ds), bf.reshape(1, df),
                     bo.reshape(1, dout))
    kern = functools.partial(_gravnet_block_kernel, k=k, scale=scale, bm=bm,
                             bn=bn, bk=bk, activation=activation,
                             concat_x=concat_x, out_dtype=out_dtype)
    return pl.pallas_call(
        kern,
        grid=(n // bm,),
        out_shape=jax.ShapeDtypeStruct((n, dout), out_dtype),
        in_specs=[
            pl.BlockSpec((bm, dh), lambda i: (i, 0)),      # query rows
            pl.BlockSpec((n, dh), lambda i: (0, 0)),       # all rows
            pl.BlockSpec((n, 1), lambda i: (0, 0)),        # mask
            pl.BlockSpec((dh, ds), lambda i: (0, 0)),      # Ws
            pl.BlockSpec((1, ds), lambda i: (0, 0)),       # bs
            pl.BlockSpec((dh, df), lambda i: (0, 0)),      # Wf
            pl.BlockSpec((1, df), lambda i: (0, 0)),       # bf
            pl.BlockSpec((dcat, dout), lambda i: (0, 0)),  # Wo
            pl.BlockSpec((1, dout), lambda i: (0, 0)),     # bo
        ],
        out_specs=pl.BlockSpec((bm, dout), lambda i: (i, 0)),
        interpret=interpret,
    )(x, x, mask2, ws, bs2, wf, bf2, wo, bo2)


def gravnet_block_batched_pallas(x, mask, ws, bs, wf, bf, wo, bo, *, k=8,
                                 scale=10.0, activation="relu",
                                 concat_x=True, bm=None, bn=None, bk=None,
                                 out_dtype=None, interpret=False):
    """Micro-batched GravNet block in ONE kernel launch.

    x:(B,N,dh) mask:(B,N) -> (B, N, d_out). Grid is (B, N/bm): the
    leading grid dimension walks events (weights shared across cells),
    so the whole micro-batch amortizes a single launch while every
    cell sees exactly one event's operands. f32 results are bitwise
    identical to B per-event launches (same cell body, same schedule).
    """
    b, n, dh = x.shape
    ds, df = ws.shape[1], wf.shape[1]
    dcat, dout = wo.shape
    out_dtype = out_dtype or x.dtype
    bm = bm or min(n, 128)
    assert n % bm == 0, (n, bm)
    assert dcat == (dh + 2 * df if concat_x else 2 * df), (dcat, dh, df)
    mask2 = mask.reshape(b, n, 1).astype(jnp.float32)
    bs2, bf2, bo2 = (bs.reshape(1, ds), bf.reshape(1, df),
                     bo.reshape(1, dout))
    kern = functools.partial(_gravnet_block_kernel_batched, k=k,
                             scale=scale, bm=bm, bn=bn, bk=bk,
                             activation=activation, concat_x=concat_x,
                             out_dtype=out_dtype)
    return pl.pallas_call(
        kern,
        grid=(b, n // bm),
        out_shape=jax.ShapeDtypeStruct((b, n, dout), out_dtype),
        in_specs=[
            pl.BlockSpec((1, bm, dh), lambda e, i: (e, i, 0)),   # queries
            pl.BlockSpec((1, n, dh), lambda e, i: (e, 0, 0)),    # all rows
            pl.BlockSpec((1, n, 1), lambda e, i: (e, 0, 0)),     # mask
            pl.BlockSpec((dh, ds), lambda e, i: (0, 0)),         # Ws
            pl.BlockSpec((1, ds), lambda e, i: (0, 0)),          # bs
            pl.BlockSpec((dh, df), lambda e, i: (0, 0)),         # Wf
            pl.BlockSpec((1, df), lambda e, i: (0, 0)),          # bf
            pl.BlockSpec((dcat, dout), lambda e, i: (0, 0)),     # Wo
            pl.BlockSpec((1, dout), lambda e, i: (0, 0)),        # bo
        ],
        out_specs=pl.BlockSpec((1, bm, dout), lambda e, i: (e, i, 0)),
        interpret=interpret,
    )(x, x, mask2, ws, bs2, wf, bf2, wo, bo2)


# ------------------------------------------------------------- int8 form ----
def _quant_act(v, scale):
    """f32 activations → int8 on the calibrated grid (symmetric,
    saturating at ±127) — the same snap the unfused calibrated dense
    applies on entry. ``scale`` is a baked python float."""
    return jnp.clip(jnp.round(v / scale), -127.0, 127.0).astype(jnp.int8)


def _int8_proj(xq, w_q, w_scale, b, x_scale):
    """int8×int8→int32 MXU dot, dequantized per output channel:
    ``acc · (x_scale · w_scale[col]) + b`` in f32. Same expression
    order as the unfused int8 dense kernel's epilogue, so the f32
    results agree bitwise (the int32 accumulation is exact)."""
    acc = jax.lax.dot_general(xq, w_q, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    scale = x_scale * w_scale.astype(jnp.float32)       # (1, d)
    return acc.astype(jnp.float32) * scale + b.astype(jnp.float32)


def _epilogue_dense_int8(hq, wo_q, bo, wo_scale, *, h_scale, bn, bk,
                         activation, out_dtype, out_scale):
    """Quantized output dense with optional (bn, bk) epilogue blocking.

    Unlike the f32 epilogue, *every* split here is exact: int32 partial
    sums associate freely, so ``bk`` K-splits are bitwise-identical to
    the whole-operand dot — the int8 autotuner may bind any block shape
    without a numerics caveat. Dequant (per-channel scale + bias +
    activation) and the optional int8 requant stay in VMEM.
    """
    dcat, dout = wo_q.shape
    bn = dout if bn is None else min(bn, dout)
    bk = dcat if bk is None else min(bk, dcat)
    cols = []
    for j0 in range(0, dout, bn):
        j1 = min(j0 + bn, dout)
        parts = [jax.lax.dot_general(hq[:, k0:min(k0 + bk, dcat)],
                                     wo_q[k0:min(k0 + bk, dcat), j0:j1],
                                     (((1,), (0,)), ((), ())),
                                     preferred_element_type=jnp.int32)
                 for k0 in range(0, dcat, bk)]
        acc = parts[0]
        for p in parts[1:]:
            acc = acc + p
        cols.append(acc)
    acc = cols[0] if len(cols) == 1 else jnp.concatenate(cols, axis=1)
    scale = h_scale * wo_scale.astype(jnp.float32)      # (1, dout)
    y = acc.astype(jnp.float32) * scale + bo.astype(jnp.float32)
    y = _activate(y, activation)
    if out_dtype == jnp.int8:
        y = jnp.clip(jnp.round(y / out_scale), -127.0, 127.0)
    return y.astype(out_dtype)


def _gravnet_block_int8_cell(xi, xall, maskj, ws_q, bs, wf_q, bf, wo_q, bo,
                             ws_s, wf_s, wo_s, i, *, k, scale, bm, bn, bk,
                             activation, concat_x, x_scale, agg_scale,
                             h_scale, out_scale, out_dtype):
    """One row block of one event, quantized: VMEM requant → int8 S/F
    prologue → f32 aggregate → int8-grid snap → int8 epilogue.

    Mirrors the unfused calibrated chain op for op: S/F dequantize to
    f32 *without* an output snap (the unfused merged projection feeds
    retiles, which keep its output f32), the aggregate output snaps via
    ``agg_scale``, and ``h = concat(original f32 x, snapped agg)``
    requantizes with ``h_scale`` — the concat's calibrated scale.
    """
    q_all = _quant_act(xall, x_scale)
    qi = _quant_act(xi, x_scale)
    s_all = _int8_proj(q_all, ws_q, ws_s, bs, x_scale)
    f_all = _int8_proj(q_all, wf_q, wf_s, bf, x_scale)
    si = _int8_proj(qi, ws_q, ws_s, bs, x_scale)
    agg = _gravnet_cell(si, s_all, f_all, maskj, i, k=k, scale=scale,
                        bm=bm, out_dtype=jnp.float32)
    agg = jnp.clip(jnp.round(agg / agg_scale), -127.0, 127.0) * agg_scale
    h = jnp.concatenate([xi, agg], axis=1) if concat_x else agg
    hq = _quant_act(h, h_scale)
    return _epilogue_dense_int8(hq, wo_q, bo, wo_s, h_scale=h_scale, bn=bn,
                                bk=bk, activation=activation,
                                out_dtype=out_dtype, out_scale=out_scale)


def _gravnet_block_int8_kernel(xi_ref, x_ref, mask_ref, ws_ref, bs_ref,
                               wf_ref, bf_ref, wo_ref, bo_ref, wss_ref,
                               wfs_ref, wos_ref, o_ref, *, k, scale, bm, bn,
                               bk, activation, concat_x, x_scale, agg_scale,
                               h_scale, out_scale, out_dtype):
    o_ref[...] = _gravnet_block_int8_cell(
        xi_ref[...].astype(jnp.float32),       # (bm, dh) query rows
        x_ref[...].astype(jnp.float32),        # (n, dh)  all rows
        mask_ref[...][:, 0],                   # (n,)     validity
        ws_ref[...], bs_ref[...], wf_ref[...], bf_ref[...],
        wo_ref[...], bo_ref[...],
        wss_ref[...], wfs_ref[...], wos_ref[...],
        pl.program_id(0), k=k, scale=scale, bm=bm, bn=bn, bk=bk,
        activation=activation, concat_x=concat_x, x_scale=x_scale,
        agg_scale=agg_scale, h_scale=h_scale, out_scale=out_scale,
        out_dtype=out_dtype)


def _gravnet_block_int8_kernel_batched(xi_ref, x_ref, mask_ref, ws_ref,
                                       bs_ref, wf_ref, bf_ref, wo_ref,
                                       bo_ref, wss_ref, wfs_ref, wos_ref,
                                       o_ref, *, k, scale, bm, bn, bk,
                                       activation, concat_x, x_scale,
                                       agg_scale, h_scale, out_scale,
                                       out_dtype):
    o_ref[0] = _gravnet_block_int8_cell(
        xi_ref[0].astype(jnp.float32),
        x_ref[0].astype(jnp.float32),
        mask_ref[0][:, 0],
        ws_ref[...], bs_ref[...], wf_ref[...], bf_ref[...],
        wo_ref[...], bo_ref[...],
        wss_ref[...], wfs_ref[...], wos_ref[...],
        pl.program_id(1), k=k, scale=scale, bm=bm, bn=bn, bk=bk,
        activation=activation, concat_x=concat_x, x_scale=x_scale,
        agg_scale=agg_scale, h_scale=h_scale, out_scale=out_scale,
        out_dtype=out_dtype)


def gravnet_block_int8_pallas(x, mask, ws_q, bs, wf_q, bf, wo_q, bo,
                              ws_scale, wf_scale, wo_scale, *, x_scale,
                              agg_scale, h_scale, k=8, scale=10.0,
                              activation="relu", concat_x=True, bm=None,
                              bn=None, bk=None, out_dtype=jnp.float32,
                              out_scale=1.0, interpret=False):
    """Quantized GravNet block, one launch. x:(N,dh) f32 → (N, d_out).

    ``ws_q``/``wf_q``/``wo_q`` are int8 per-output-channel quantized
    weights with f32 scale vectors ``*_scale``; ``x_scale``/
    ``agg_scale``/``h_scale`` are the calibrated per-tensor activation
    scales, baked in as compile-time constants. Caller pads N to a
    multiple of ``bm`` (``ops.gravnet_block_int8`` does).
    """
    n, dh = x.shape
    ds, df = ws_q.shape[1], wf_q.shape[1]
    dcat, dout = wo_q.shape
    bm = bm or min(n, 128)
    assert n % bm == 0, (n, bm)
    assert dcat == (dh + 2 * df if concat_x else 2 * df), (dcat, dh, df)
    mask2 = mask.reshape(n, 1).astype(jnp.float32)
    bs2, bf2, bo2 = (bs.reshape(1, ds), bf.reshape(1, df),
                     bo.reshape(1, dout))
    wss2, wfs2, wos2 = (ws_scale.reshape(1, ds), wf_scale.reshape(1, df),
                        wo_scale.reshape(1, dout))
    kern = functools.partial(
        _gravnet_block_int8_kernel, k=k, scale=scale, bm=bm, bn=bn, bk=bk,
        activation=activation, concat_x=concat_x,
        x_scale=float(x_scale), agg_scale=float(agg_scale),
        h_scale=float(h_scale), out_scale=float(out_scale),
        out_dtype=out_dtype)
    return pl.pallas_call(
        kern,
        grid=(n // bm,),
        out_shape=jax.ShapeDtypeStruct((n, dout), out_dtype),
        in_specs=[
            pl.BlockSpec((bm, dh), lambda i: (i, 0)),      # query rows
            pl.BlockSpec((n, dh), lambda i: (0, 0)),       # all rows
            pl.BlockSpec((n, 1), lambda i: (0, 0)),        # mask
            pl.BlockSpec((dh, ds), lambda i: (0, 0)),      # Ws (int8)
            pl.BlockSpec((1, ds), lambda i: (0, 0)),       # bs
            pl.BlockSpec((dh, df), lambda i: (0, 0)),      # Wf (int8)
            pl.BlockSpec((1, df), lambda i: (0, 0)),       # bf
            pl.BlockSpec((dcat, dout), lambda i: (0, 0)),  # Wo (int8)
            pl.BlockSpec((1, dout), lambda i: (0, 0)),     # bo
            pl.BlockSpec((1, ds), lambda i: (0, 0)),       # ws_scale
            pl.BlockSpec((1, df), lambda i: (0, 0)),       # wf_scale
            pl.BlockSpec((1, dout), lambda i: (0, 0)),     # wo_scale
        ],
        out_specs=pl.BlockSpec((bm, dout), lambda i: (i, 0)),
        interpret=interpret,
    )(x, x, mask2, ws_q, bs2, wf_q, bf2, wo_q, bo2, wss2, wfs2, wos2)


def gravnet_block_int8_batched_pallas(x, mask, ws_q, bs, wf_q, bf, wo_q,
                                      bo, ws_scale, wf_scale, wo_scale, *,
                                      x_scale, agg_scale, h_scale, k=8,
                                      scale=10.0, activation="relu",
                                      concat_x=True, bm=None, bn=None,
                                      bk=None, out_dtype=jnp.float32,
                                      out_scale=1.0, interpret=False):
    """Micro-batched quantized GravNet block in ONE kernel launch.

    x:(B,N,dh) f32, mask:(B,N) → (B, N, d_out). Same (B, N/bm) event
    grid as the f32 batched form; weights, per-channel scale vectors,
    and the baked activation scales are shared across the event grid.
    """
    b, n, dh = x.shape
    ds, df = ws_q.shape[1], wf_q.shape[1]
    dcat, dout = wo_q.shape
    bm = bm or min(n, 128)
    assert n % bm == 0, (n, bm)
    assert dcat == (dh + 2 * df if concat_x else 2 * df), (dcat, dh, df)
    mask2 = mask.reshape(b, n, 1).astype(jnp.float32)
    bs2, bf2, bo2 = (bs.reshape(1, ds), bf.reshape(1, df),
                     bo.reshape(1, dout))
    wss2, wfs2, wos2 = (ws_scale.reshape(1, ds), wf_scale.reshape(1, df),
                        wo_scale.reshape(1, dout))
    kern = functools.partial(
        _gravnet_block_int8_kernel_batched, k=k, scale=scale, bm=bm, bn=bn,
        bk=bk, activation=activation, concat_x=concat_x,
        x_scale=float(x_scale), agg_scale=float(agg_scale),
        h_scale=float(h_scale), out_scale=float(out_scale),
        out_dtype=out_dtype)
    return pl.pallas_call(
        kern,
        grid=(b, n // bm),
        out_shape=jax.ShapeDtypeStruct((b, n, dout), out_dtype),
        in_specs=[
            pl.BlockSpec((1, bm, dh), lambda e, i: (e, i, 0)),   # queries
            pl.BlockSpec((1, n, dh), lambda e, i: (e, 0, 0)),    # all rows
            pl.BlockSpec((1, n, 1), lambda e, i: (e, 0, 0)),     # mask
            pl.BlockSpec((dh, ds), lambda e, i: (0, 0)),         # Ws (int8)
            pl.BlockSpec((1, ds), lambda e, i: (0, 0)),          # bs
            pl.BlockSpec((dh, df), lambda e, i: (0, 0)),         # Wf (int8)
            pl.BlockSpec((1, df), lambda e, i: (0, 0)),          # bf
            pl.BlockSpec((dcat, dout), lambda e, i: (0, 0)),     # Wo (int8)
            pl.BlockSpec((1, dout), lambda e, i: (0, 0)),        # bo
            pl.BlockSpec((1, ds), lambda e, i: (0, 0)),          # ws_scale
            pl.BlockSpec((1, df), lambda e, i: (0, 0)),          # wf_scale
            pl.BlockSpec((1, dout), lambda e, i: (0, 0)),        # wo_scale
        ],
        out_specs=pl.BlockSpec((1, bm, dout), lambda e, i: (e, i, 0)),
        interpret=interpret,
    )(x, x, mask2, ws_q, bs2, wf_q, bf2, wo_q, bo2, wss2, wfs2, wos2)
