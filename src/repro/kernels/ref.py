"""Pure-jnp oracles for every Pallas kernel (the ground truth for tests).

These are also the differentiable implementations used on the training
path and the implementations the dry-run lowers (Mosaic needs real TPUs;
the jnp path is mathematically identical and XLA fuses it aggressively).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_BIG = jnp.float32(1e30)


# ------------------------------------------------------------ fused dense ----
def _activate(y, activation):
    if activation in (None, "none", "linear"):
        return y
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "gelu":
        return jax.nn.gelu(y)
    if activation == "silu":
        return jax.nn.silu(y)
    raise ValueError(activation)


def fused_dense_ref(x, w, b=None, *, activation="relu", out_dtype=None):
    out_dtype = out_dtype or x.dtype
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    return _activate(y, activation).astype(out_dtype)


def fused_dense_int8_ref(x_q, w_q, b, x_scale, w_scale, *, activation="relu",
                         out_dtype=jnp.float32, out_scale=1.0):
    acc = jax.lax.dot_general(x_q, w_q, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    scale = x_scale.reshape(()).astype(jnp.float32) * w_scale.astype(jnp.float32)
    y = acc.astype(jnp.float32) * scale[None, :]
    if b is not None:
        y = y + b.astype(jnp.float32)
    y = _activate(y, activation)
    if out_dtype == jnp.int8:
        y = jnp.clip(jnp.round(y / out_scale), -127.0, 127.0)
    return y.astype(out_dtype)


# ----------------------------------------------------------------- gravnet ----
def gravnet_aggregate_onehot_ref(s, f, mask, *, k=8, scale=10.0,
                                 out_dtype=None):
    """jnp mirror of the TPU kernel algorithm (iterated argmin + one-hot
    MATMUL selection, no top_k/gather) — the MXU-native lowering used by
    the tpu_native_gravnet partitioning mode."""
    out_dtype = out_dtype or f.dtype
    sf = s.astype(jnp.float32)
    ff = f.astype(jnp.float32)
    n = sf.shape[0]
    df = ff.shape[1]
    d2 = (jnp.sum(sf * sf, 1)[:, None] + jnp.sum(sf * sf, 1)[None, :]
          - 2.0 * sf @ sf.T)
    d2 = jnp.maximum(d2, 0.0)
    invalid = (mask[None, :] <= 0) | jnp.eye(n, dtype=bool)
    d2 = jnp.where(invalid, _BIG, d2)
    col = jnp.arange(n)[None, :]

    # static python loop (k <= 16): fully unrolled like the Pallas
    # kernel's schedule, and exact under XLA cost analysis (a fori_loop
    # body would be counted once — EXPERIMENTS.md §Methodology 2)
    mean_acc = jnp.zeros((n, df), jnp.float32)
    max_acc = jnp.full((n, df), -_BIG, jnp.float32)
    for _ in range(k):
        dmin = jnp.min(d2, axis=1)
        amin = jnp.argmin(d2, axis=1)
        onehot = (col == amin[:, None]).astype(jnp.float32)
        fsel = onehot @ ff
        valid = dmin < _BIG * 0.5
        w = jnp.where(valid, jnp.exp(-scale * dmin), 0.0)
        wf = w[:, None] * fsel
        mean_acc = mean_acc + wf
        max_acc = jnp.maximum(max_acc, jnp.where(valid[:, None], wf,
                                                 -_BIG))
        d2 = jnp.where(col == amin[:, None], _BIG, d2)
    mean = mean_acc / k
    mx = jnp.where(max_acc <= -_BIG * 0.5, 0.0, max_acc)
    return jnp.concatenate([mean, mx], axis=1).astype(out_dtype)


def gravnet_aggregate_ref(s, f, mask, *, k=8, scale=10.0, out_dtype=None):
    """Oracle using explicit top_k + take_along_axis (GPU/FPGA-style)."""
    out_dtype = out_dtype or f.dtype
    sf = s.astype(jnp.float32)
    ff = f.astype(jnp.float32)
    n = sf.shape[0]
    d2 = (jnp.sum(sf * sf, axis=1)[:, None] + jnp.sum(sf * sf, axis=1)[None, :]
          - 2.0 * sf @ sf.T)
    d2 = jnp.maximum(d2, 0.0)
    invalid = (mask[None, :] <= 0) | jnp.eye(n, dtype=bool)
    d2 = jnp.where(invalid, _BIG, d2)
    k_eff = min(k, n)  # fewer candidates than k: pad with invalid slots
    neg_d2k, idx = jax.lax.top_k(-d2, k_eff)                # (n, k_eff)
    d2k = -neg_d2k
    if k_eff < k:
        d2k = jnp.pad(d2k, ((0, 0), (0, k - k_eff)), constant_values=_BIG)
        idx = jnp.pad(idx, ((0, 0), (0, k - k_eff)))
    valid = d2k < _BIG * 0.5                                 # (n, k)
    w = jnp.where(valid, jnp.exp(-scale * d2k), 0.0)         # (n, k)
    fk = jnp.take(ff, idx, axis=0)                           # (n, k, df)
    wf = w[..., None] * fk
    mean = jnp.sum(jnp.where(valid[..., None], wf, 0.0), axis=1) / k
    mx = jnp.max(jnp.where(valid[..., None], wf, -_BIG), axis=1)
    mx = jnp.where(mx <= -_BIG * 0.5, 0.0, mx)
    return jnp.concatenate([mean, mx], axis=1).astype(out_dtype)


# -------------------------------------------------------------- kNN build ----
def knn_build_ref(s, segids, *, k=8):
    """jnp oracle for the ragged neighbor-selection kernel
    (kernels/knn_build.py). s:(N,ds), segids:(N,) int (−1 = padding)
    -> (idx:(N,k) i32, d2:(N,k) f32).

    Pins the TIE-BREAK CONTRACT: k iterations of row-argmin with
    knockout, ties broken toward the *lowest column index*
    (``jnp.argmin``). A candidate is valid iff it shares the row's
    segment id, is not the row itself, and is not padding; rows with
    fewer than k candidates fill remaining slots with d2 = 1e30 and
    idx = argmin of an all-invalid row (0 after full knockout wraps —
    consumers must gate on d2, never on idx alone).
    """
    sf = s.astype(jnp.float32)
    seg = segids.astype(jnp.int32)
    n = sf.shape[0]
    d2 = (jnp.sum(sf * sf, 1)[:, None] + jnp.sum(sf * sf, 1)[None, :]
          - 2.0 * sf @ sf.T)
    d2 = jnp.maximum(d2, 0.0)
    invalid = ((seg[None, :] != seg[:, None]) | jnp.eye(n, dtype=bool)
               | (seg[None, :] < 0))
    d2 = jnp.where(invalid, _BIG, d2)
    col = jnp.arange(n)[None, :]
    idx_cols, d2_cols = [], []
    for _ in range(k):             # static loop, mirrors the kernel
        dmin = jnp.min(d2, axis=1)
        amin = jnp.argmin(d2, axis=1).astype(jnp.int32)
        idx_cols.append(amin)
        d2_cols.append(dmin)
        d2 = jnp.where(col == amin[:, None], _BIG, d2)
    return jnp.stack(idx_cols, axis=1), jnp.stack(d2_cols, axis=1)


def knn_aggregate_ref(f, idx, d2, *, scale=10.0, out_dtype=None):
    """jnp oracle for the ragged aggregation kernel: Gaussian-potential
    mean/max over the selected neighbors. f:(N,df), idx/d2:(N,k)
    -> (N, 2·df). Invalid slots (d2 >= 1e30/2) weigh 0. Accumulates
    neighbor-by-neighbor in slot order — the same sequence of adds the
    Pallas cell (and ``_gravnet_cell``) performs — so oracle and kernel
    agree to the last ULP (exact up to XLA's multiply-add fusion)."""
    out_dtype = out_dtype or f.dtype
    ff = f.astype(jnp.float32)
    n, k = idx.shape
    mean_acc = jnp.zeros((n, ff.shape[1]), jnp.float32)
    max_acc = jnp.full((n, ff.shape[1]), -_BIG, jnp.float32)
    for t in range(k):
        dmin = d2[:, t]
        fsel = jnp.take(ff, idx[:, t], axis=0)               # (n, df)
        valid = dmin < _BIG * 0.5
        w = jnp.where(valid, jnp.exp(-scale * dmin), 0.0)
        wf = w[:, None] * fsel
        mean_acc = mean_acc + wf
        max_acc = jnp.maximum(max_acc, jnp.where(valid[:, None], wf, -_BIG))
    mean = mean_acc / k
    mx = jnp.where(max_acc <= -_BIG * 0.5, 0.0, max_acc)
    return jnp.concatenate([mean, mx], axis=1).astype(out_dtype)


# ------------------------------------------------------------ gravnet block ----
def gravnet_block_ref(x, mask, ws, bs, wf, bf, wo, bo, *, k=8, scale=10.0,
                      activation="relu", concat_x=True, out_dtype=None):
    """Oracle for the fused GravNet-block megakernel: the *unfused*
    dense(S) ∥ dense(F) → aggregate → dense(out) chain, composed from
    the same per-op oracles the unfused executor dispatches. Accepts
    per-event (N, dh) or batched (B, N, dh) operands."""
    out_dtype = out_dtype or x.dtype
    s = fused_dense_ref(x, ws, bs, activation="none",
                        out_dtype=jnp.float32)
    f = fused_dense_ref(x, wf, bf, activation="none",
                        out_dtype=jnp.float32)

    def agg_one(ss, ff, mm):
        return gravnet_aggregate_ref(ss, ff, mm, k=k, scale=scale,
                                     out_dtype=jnp.float32)

    agg = (jax.vmap(agg_one)(s, f, mask) if x.ndim == 3
           else agg_one(s, f, mask))
    h = (jnp.concatenate([x.astype(jnp.float32), agg], axis=-1)
         if concat_x else agg)
    return fused_dense_ref(h, wo, bo, activation=activation,
                           out_dtype=out_dtype)


def gravnet_block_int8_ref(x, mask, ws_q, bs, wf_q, bf, wo_q, bo, ws_scale,
                           wf_scale, wo_scale, *, x_scale, agg_scale,
                           h_scale, k=8, scale=10.0, activation="relu",
                           concat_x=True, out_dtype=jnp.float32,
                           out_scale=1.0):
    """Oracle for the quantized megakernel: the *unfused calibrated
    int8 chain*, composed from the same per-op oracles the mixed
    executor dispatches — quantize x with the producer's ``x_scale``,
    int8 S/F projections dequantized to f32 (no output snap, matching
    the executor where the merged projection's retile consumers keep
    its output f32), f32 aggregate snapped to the int8 grid with
    ``agg_scale``, then the output dense quantizing ``concat(x, agg)``
    with ``h_scale``. Accepts per-event (N, dh) or batched (B, N, dh)
    f32 operands; weights are int8 with per-output-channel scales."""
    xf = x.astype(jnp.float32)
    xq = jnp.clip(jnp.round(xf / x_scale), -127.0, 127.0).astype(jnp.int8)
    xsc = jnp.asarray(x_scale, jnp.float32)
    lead = xq.shape[:-1]
    xq2 = xq.reshape(-1, xq.shape[-1])
    s = fused_dense_int8_ref(xq2, ws_q, bs, xsc, ws_scale,
                             activation="none").reshape(*lead, -1)
    f = fused_dense_int8_ref(xq2, wf_q, bf, xsc, wf_scale,
                             activation="none").reshape(*lead, -1)

    def agg_one(ss, ff, mm):
        return gravnet_aggregate_ref(ss, ff, mm, k=k, scale=scale,
                                     out_dtype=jnp.float32)

    agg = (jax.vmap(agg_one)(s, f, mask) if x.ndim == 3
           else agg_one(s, f, mask))
    agg = jnp.clip(jnp.round(agg / agg_scale), -127.0, 127.0) * agg_scale
    h = jnp.concatenate([xf, agg], axis=-1) if concat_x else agg
    hq = jnp.clip(jnp.round(h / h_scale), -127.0, 127.0).astype(jnp.int8)
    hq2 = hq.reshape(-1, hq.shape[-1])
    y = fused_dense_int8_ref(hq2, wo_q, bo, jnp.asarray(h_scale, jnp.float32),
                             wo_scale, activation=activation,
                             out_dtype=out_dtype, out_scale=out_scale)
    return y.reshape(*lead, y.shape[-1])


# ---------------------------------------------------------- edge aggregate ----
def edge_aggregate_ref(messages, edge_index, n_nodes, edge_mask=None, *,
                       reduce="sum", out_dtype=None):
    """Masked segment-sum/mean of per-edge messages into destination
    nodes — the jnp mirror of ``models.gnn.common.scatter_sum`` /
    ``scatter_mean`` (padded edges carry mask 0 and point at node 0).

    messages:(E,d), edge_index:(2,E) int (src,dst), mask:(E,) -> (n,d).
    """
    out_dtype = out_dtype or messages.dtype
    msgs = messages.astype(jnp.float32)
    if edge_mask is not None:
        msgs = msgs * edge_mask.astype(jnp.float32)[:, None]
    dst = edge_index[1]
    out = jax.ops.segment_sum(msgs, dst, num_segments=n_nodes)
    if reduce == "mean":
        ones = jnp.ones((messages.shape[0],), jnp.float32)
        if edge_mask is not None:
            ones = ones * edge_mask.astype(jnp.float32)
        cnt = jax.ops.segment_sum(ones, dst, num_segments=n_nodes)
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out.astype(out_dtype)


# --------------------------------------------------------- flash attention ----
def flash_attention_ref(q, k, v, *, causal=True):
    """Plain softmax attention oracle. q:(BH,S,D) k,v:(BH,T,D)."""
    d = q.shape[-1]
    s = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(d))
    if causal:
        sq, t = q.shape[1], k.shape[1]
        mask = jnp.arange(t)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bst,btd->bsd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
