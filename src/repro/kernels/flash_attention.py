"""Pallas TPU kernel: blockwise (flash) causal attention.

Beyond-paper kernel for the LM prefill cells (§Roofline shows prefill is
memory-bound at baseline: the jnp path materializes (Bq, T) score tiles
through HBM). Standard streaming-softmax schedule:

  grid = (B·H, S/bq, T/bk)   (kv innermost — TPU 'arbitrary' dim, so the
                              VMEM scratch carries across kv steps)
  per (q-block, kv-block):
    s   = q·kᵀ / sqrt(d)  (+ causal mask)
    m'  = max(m, rowmax(s));  p = exp(s − m')
    l   = l·exp(m − m') + rowsum(p)
    acc = acc·exp(m − m') + p·v
  epilogue (last kv block): o = acc / l

Causal skipping of fully-masked kv blocks is done with `pl.when`
(zero-work guard); the q/kv block shapes are MXU-aligned (128 lanes).
Validated in interpret mode against ref softmax attention (tests).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal, nk, bq, bk, scale, out_dtype):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _step():
        q = q_ref[0].astype(jnp.float32)             # (bq, d)
        k = k_ref[0].astype(jnp.float32)             # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                 # (bq, bk)
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 0)
            cols = ki * bk + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 1)
            s = jnp.where(cols <= rows, s, -1e30)
        m_prev = m_ref[...]                           # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                        # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)               # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1,
                                                  keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # kv blocks strictly after the q block contribute nothing
        pl.when(ki * bk <= qi * bq + bq - 1)(_step)
    else:
        _step()

    @pl.when(ki == nk - 1)
    def _epilogue():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(out_dtype)


def flash_attention_pallas(q, k, v, *, causal=True, bq=128, bk=128,
                           interpret=False):
    """q: (BH, S, D), k/v: (BH, T, D) -> (BH, S, D).

    S % bq == 0 and T % bk == 0 (ops wrapper pads); same-head layout
    (GQA callers repeat/reshape kv beforehand)."""
    bh, s, d = q.shape
    t = k.shape[1]
    bq = min(bq, s)
    bk = min(bk, t)
    assert s % bq == 0 and t % bk == 0
    nq, nk = s // bq, t // bk
    scale = 1.0 / math.sqrt(d)
    kern = functools.partial(_flash_kernel, causal=causal, nk=nk, bq=bq,
                             bk=bk, scale=scale, out_dtype=q.dtype)
    return pl.pallas_call(
        kern,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running denom
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
