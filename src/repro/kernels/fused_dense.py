"""Pallas TPU kernel: fused Dense = matmul + bias + activation (+ int8 path).

This is the direct analogue of the paper's AIE ``Dense`` operator (the
result of the operator-fusion pass: Linear + ReLU fused, parallel Linears
merged into one wide matmul). Two variants mirror the paper's kernel-level
optimization study:

- ``looped``    — grid-tiled (M/bm, N/bn, K/bk) matmul with an f32 VMEM
                  accumulator; the general high-throughput form (the AIE
                  "loop-pipelined" kernel).
- ``flattened`` — single-grid-cell kernel with the whole operand set
                  resident in VMEM and no K loop; for the tiny
                  trigger-scale matrices (≤ a few hundred rows) where
                  per-iteration scheduling overhead dominates — the
                  ``chess_flatten_loop`` analogue (trades program/VMEM
                  footprint for issue efficiency).

The int8 kernel implements the paper's 8-bit interior precision: int8 ×
int8 → int32 MXU accumulation, per-channel weight scales + per-tensor
activation scale dequant in the epilogue, optional requantization to int8
for kernel-to-kernel handoff inside a partition.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _activate(y, activation: str | None):
    if activation in (None, "none", "linear"):
        return y
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "gelu":
        return jax.nn.gelu(y)
    if activation == "silu":
        return jax.nn.silu(y)
    raise ValueError(f"unknown activation {activation!r}")


# ------------------------------------------------------------- fp kernels ----
def _looped_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, activation, nk,
                   out_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        y = acc_ref[...]
        if b_ref is not None:
            y = y + b_ref[...].astype(jnp.float32)
        o_ref[...] = _activate(y, activation).astype(out_dtype)


def _flattened_kernel(x_ref, w_ref, b_ref, o_ref, *, activation, out_dtype):
    y = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    if b_ref is not None:
        y = y + b_ref[...].astype(jnp.float32)
    o_ref[...] = _activate(y, activation).astype(out_dtype)


def _flattened_kernel_batched(x_ref, w_ref, b_ref, o_ref, *, activation,
                              out_dtype):
    # leading block dim 1 = one event per grid cell; weights/bias are
    # shared across the event grid (their BlockSpecs ignore the index)
    y = jnp.dot(x_ref[0], w_ref[...], preferred_element_type=jnp.float32)
    if b_ref is not None:
        y = y + b_ref[...].astype(jnp.float32)
    o_ref[0] = _activate(y, activation).astype(out_dtype)


def fused_dense_pallas(x, w, b=None, *, activation="relu", variant="looped",
                       bm=128, bn=128, bk=512, out_dtype=None,
                       interpret=False):
    """y = act(x @ w + b). x:(M,K) w:(K,N) b:(N,)|None.

    Dims must tile evenly (``ops.fused_dense`` pads); out_dtype defaults to
    x.dtype.
    """
    m, kdim = x.shape
    _, n = w.shape
    out_dtype = out_dtype or x.dtype
    b2 = None if b is None else b.reshape(1, n)
    has_b = b2 is not None

    if variant == "flattened":
        if has_b:
            kern = functools.partial(_flattened_kernel, activation=activation,
                                     out_dtype=out_dtype)
        else:
            kern = lambda x_ref, w_ref, o_ref: _flattened_kernel(  # noqa: E731
                x_ref, w_ref, None, o_ref, activation=activation,
                out_dtype=out_dtype)
        in_specs = [pl.BlockSpec((m, kdim), lambda: (0, 0)),
                    pl.BlockSpec((kdim, n), lambda: (0, 0))]
        if has_b:
            in_specs.append(pl.BlockSpec((1, n), lambda: (0, 0)))
        return pl.pallas_call(
            kern,
            out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((m, n), lambda: (0, 0)),
            interpret=interpret,
        )(*((x, w, b2) if has_b else (x, w)))

    assert variant == "looped", variant
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, kdim)
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0, \
        (m, n, kdim, bm, bn, bk)
    nk = kdim // bk
    if has_b:
        kern = functools.partial(_looped_kernel, activation=activation, nk=nk,
                                 out_dtype=out_dtype)
    else:
        kern = lambda x_ref, w_ref, o_ref, acc_ref: _looped_kernel(  # noqa: E731
            x_ref, w_ref, None, o_ref, acc_ref, activation=activation, nk=nk,
            out_dtype=out_dtype)
    in_specs = [pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
                pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))]
    if has_b:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn, nk),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(*((x, w, b2) if has_b else (x, w)))


def fused_dense_batched_pallas(x, w, b=None, *, activation="relu",
                               variant="flattened", bm=128, bn=128, bk=512,
                               out_dtype=None, interpret=False):
    """Micro-batched fused dense in ONE kernel launch.

    x:(B,M,K) w:(K,N) b:(N,)|None -> (B,M,N). Two batch-packing forms,
    mirroring the per-event variants:

    - ``flattened`` — grid (B,): the leading grid dimension walks one
      event per cell with the whole per-event operand set VMEM-resident
      (weights shared across cells). Keeps the tiny-matrix issue
      efficiency of the flattened kernel while amortizing the launch
      over the micro-batch.
    - ``looped``    — events are *row-packed*: (B,M,K) reshapes to
      (B·M, K) and reuses the grid-tiled looped kernel, so the MXU sees
      one tall matmul (dense ops have no cross-row coupling, so packing
      is exact). The caller's (bm, bn, bk) tile the packed shape.
    """
    bsz, m, kdim = x.shape
    _, n = w.shape
    out_dtype = out_dtype or x.dtype
    if variant == "looped":
        y = fused_dense_pallas(x.reshape(bsz * m, kdim), w, b,
                               activation=activation, variant="looped",
                               bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
                               interpret=interpret)
        return y.reshape(bsz, m, n)
    assert variant == "flattened", variant
    b2 = None if b is None else b.reshape(1, n)
    has_b = b2 is not None
    if has_b:
        kern = functools.partial(_flattened_kernel_batched,
                                 activation=activation, out_dtype=out_dtype)
    else:
        kern = lambda x_ref, w_ref, o_ref: _flattened_kernel_batched(  # noqa: E731
            x_ref, w_ref, None, o_ref, activation=activation,
            out_dtype=out_dtype)
    in_specs = [pl.BlockSpec((1, m, kdim), lambda e: (e, 0, 0)),
                pl.BlockSpec((kdim, n), lambda e: (0, 0))]
    if has_b:
        in_specs.append(pl.BlockSpec((1, n), lambda e: (0, 0)))
    return pl.pallas_call(
        kern,
        grid=(bsz,),
        out_shape=jax.ShapeDtypeStruct((bsz, m, n), out_dtype),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, m, n), lambda e: (e, 0, 0)),
        interpret=interpret,
    )(*((x, w, b2) if has_b else (x, w)))


# ----------------------------------------------------------- int8 kernels ----
def _looped_kernel_q(x_ref, w_ref, b_ref, xs_ref, ws_ref, o_ref, acc_ref, *,
                     activation, nk, out_dtype, out_scale):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        scale = xs_ref[0, 0] * ws_ref[...].astype(jnp.float32)   # (1, bn)
        y = acc_ref[...].astype(jnp.float32) * scale
        if b_ref is not None:
            y = y + b_ref[...].astype(jnp.float32)
        y = _activate(y, activation)
        if out_dtype == jnp.int8:
            y = jnp.clip(jnp.round(y / out_scale), -127.0, 127.0)
        o_ref[...] = y.astype(out_dtype)


def fused_dense_int8_pallas(x_q, w_q, b, x_scale, w_scale, *,
                            activation="relu", bm=128, bn=128, bk=512,
                            out_dtype=jnp.float32, out_scale=1.0,
                            interpret=False):
    """Quantized fused dense.

    x_q:(M,K) int8, w_q:(K,N) int8, x_scale:(1,1) f32 per-tensor,
    w_scale:(N,) f32 per-channel, b:(N,) f32 (dequantized domain) or None.
    ``out_dtype=int8`` requantizes with ``out_scale`` for in-partition
    kernel-to-kernel handoff; f32/bf16 dequantizes at partition boundaries
    (the paper's 16-bit boundary precision).
    """
    m, kdim = x_q.shape
    _, n = w_q.shape
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, kdim)
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0
    nk = kdim // bk
    b2 = None if b is None else b.reshape(1, n)
    has_b = b2 is not None
    ws2 = w_scale.reshape(1, n)
    if has_b:
        kern = functools.partial(_looped_kernel_q, activation=activation,
                                 nk=nk, out_dtype=out_dtype,
                                 out_scale=out_scale)
    else:
        kern = lambda x_ref, w_ref, xs_ref, ws_ref, o_ref, acc_ref: (  # noqa: E731
            _looped_kernel_q(x_ref, w_ref, None, xs_ref, ws_ref, o_ref,
                             acc_ref, activation=activation, nk=nk,
                             out_dtype=out_dtype, out_scale=out_scale))
    in_specs = [pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
                pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))]
    args = [x_q, w_q]
    if has_b:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
        args.append(b2)
    in_specs += [pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
                 pl.BlockSpec((1, bn), lambda i, j, k: (0, j))]
    args += [x_scale, ws2]
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn, nk),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(*args)
