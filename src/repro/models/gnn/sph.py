"""Real spherical harmonics (l ≤ 3) and SO(3) intertwiners (CG tensors).

Instead of porting Racah algebra + complex→real basis transforms, the
Clebsch-Gordan intertwiners are derived **numerically** at import time:
w[i,j,k] must satisfy, for every rotation R,

    Σ_{i',j'} D^{l1}(R)[i',i] · D^{l2}(R)[j',j] · w[i',j',k]
        = Σ_{k'} D^{l3}(R)[k,k'] · w[i,j,k']

The Wigner-D matrices in the *real* SH basis are themselves solved by
least squares from Y_l(R·x) = D^l(R) · Y_l(x) over sampled directions.
Stacking the linear constraint for several random rotations and taking
the SVD null space yields the (unique up to sign/scale) intertwiner.
Everything is deterministic (fixed seed) and cached; correctness is
guaranteed by the rotation-equivariance property tests in
``tests/test_gnn_models.py``.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

_SQ = np.sqrt


def real_sph_np(l: int, u: np.ndarray) -> np.ndarray:  # noqa: E741
    """Orthonormal real spherical harmonics on unit vectors u (..., 3)."""
    x, y, z = u[..., 0], u[..., 1], u[..., 2]
    if l == 0:
        return np.full(u.shape[:-1] + (1,), 0.5 / _SQ(np.pi))
    if l == 1:
        c = _SQ(3.0 / (4 * np.pi))
        return np.stack([c * y, c * z, c * x], axis=-1)
    if l == 2:
        c1 = 0.5 * _SQ(15.0 / np.pi)
        c2 = 0.25 * _SQ(5.0 / np.pi)
        c3 = 0.25 * _SQ(15.0 / np.pi)
        r2 = x * x + y * y + z * z
        return np.stack([
            c1 * x * y, c1 * y * z, c2 * (3 * z * z - r2),
            c1 * x * z, c3 * (x * x - y * y)], axis=-1)
    if l == 3:
        # only needed for tests / l_max extensions
        c = [0.25 * _SQ(35 / (2 * np.pi)), 0.5 * _SQ(105 / np.pi),
             0.25 * _SQ(21 / (2 * np.pi)), 0.25 * _SQ(7 / np.pi),
             0.25 * _SQ(21 / (2 * np.pi)), 0.25 * _SQ(105 / np.pi),
             0.25 * _SQ(35 / (2 * np.pi))]
        return np.stack([
            c[0] * y * (3 * x * x - y * y), c[1] * x * y * z,
            c[2] * y * (5 * z * z - 1), c[3] * z * (5 * z * z - 3),
            c[4] * x * (5 * z * z - 1), c[5] * z * (x * x - y * y),
            c[6] * x * (x * x - 3 * y * y)], axis=-1)
    raise NotImplementedError(l)


def real_sph(l: int, u):  # noqa: E741  (jnp version)
    x, y, z = u[..., 0], u[..., 1], u[..., 2]
    if l == 0:
        return jnp.full(u.shape[:-1] + (1,), 0.5 / float(_SQ(np.pi)),
                        dtype=u.dtype)
    if l == 1:
        c = float(_SQ(3.0 / (4 * np.pi)))
        return jnp.stack([c * y, c * z, c * x], axis=-1)
    if l == 2:
        c1 = float(0.5 * _SQ(15.0 / np.pi))
        c2 = float(0.25 * _SQ(5.0 / np.pi))
        c3 = float(0.25 * _SQ(15.0 / np.pi))
        r2 = x * x + y * y + z * z
        return jnp.stack([
            c1 * x * y, c1 * y * z, c2 * (3 * z * z - r2),
            c1 * x * z, c3 * (x * x - y * y)], axis=-1)
    raise NotImplementedError(l)


def _random_rotation(rng) -> np.ndarray:
    a = rng.normal(size=(3, 3))
    q, r = np.linalg.qr(a)
    q = q * np.sign(np.diag(r))
    if np.linalg.det(q) < 0:
        q[:, 0] = -q[:, 0]
    return q


@functools.lru_cache(maxsize=None)
def wigner_d(l: int, key: int = 0) -> "tuple":  # noqa: E741
    raise RuntimeError("use wigner_d_for")


def wigner_d_for(l: int, rot: np.ndarray) -> np.ndarray:  # noqa: E741
    """Real-basis Wigner D: Y_l(R·x) = D @ Y_l(x), solved by lstsq."""
    rng = np.random.default_rng(1234 + l)
    xs = rng.normal(size=(max(64, 8 * (2 * l + 1)), 3))
    xs /= np.linalg.norm(xs, axis=1, keepdims=True)
    a = real_sph_np(l, xs)                       # (M, 2l+1)
    b = real_sph_np(l, xs @ rot.T)               # (M, 2l+1)
    d, *_ = np.linalg.lstsq(a, b, rcond=None)    # a @ d ≈ b  -> D = d.T
    return d.T


@functools.lru_cache(maxsize=None)
def intertwiner(l1: int, l2: int, l3: int) -> np.ndarray | None:
    """w[i,j,k] (unit-norm, sign-fixed) or None if the triple is empty."""
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        return None
    n1, n2, n3 = 2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1
    rng = np.random.default_rng(42)
    rows = []
    for _ in range(6):
        rot = _random_rotation(rng)
        d1 = wigner_d_for(l1, rot)
        d2 = wigner_d_for(l2, rot)
        d3 = wigner_d_for(l3, rot)
        # constraint on vec(w) with index order (i,j,k):
        #   [ (D1⊗D2)^T ⊗ I_n3  -  I_{n1·n2} ⊗ D3 ] vec(w) = 0
        d12 = np.kron(d1, d2)                    # [(i',j'),(i,j)]
        m = np.kron(d12.T, np.eye(n3)) - np.kron(np.eye(n1 * n2), d3)
        rows.append(m)
    m = np.concatenate(rows, axis=0)
    _, s, vt = np.linalg.svd(m)
    rank = int(np.sum(s > 1e-8 * max(s[0], 1.0)))
    null = vt[rank:]
    if null.shape[0] == 0:
        return None
    w = null[0].reshape(n1, n2, n3)
    w = w / np.linalg.norm(w)
    # deterministic sign: first nonzero entry positive
    nz = w.flat[np.argmax(np.abs(w) > 1e-10)]
    if nz < 0:
        w = -w
    return w


def intertwiner_jnp(l1: int, l2: int, l3: int):
    w = intertwiner(l1, l2, l3)
    return None if w is None else jnp.asarray(w, jnp.float32)
