"""DimeNet (Gasteiger et al., arXiv:2003.03123): directional message
passing with radial-Bessel (n_radial=6) and spherical-Fourier-Bessel
(n_spherical=7 × n_radial) bases, bilinear interaction (n_bilinear=8),
n_blocks=6, d_hidden=128.

Kernel regime: **triplet gather** — messages live on *edges* m_{ji};
each interaction block aggregates over triplets (k→j→i):

    m'_{ji} = f_upd( m_{ji},  Σ_{k∈N(j)\\{i}}  f_int(m_{kj}, rbf_{ji},
                                                sbf_{kji}) )

Triplets are precomputed index pairs into the edge list
(``trip_kj``, ``trip_ji``), padded to a static budget with a mask — not
expressible as SpMM, exactly the regime the taxonomy calls out.

Per-node outputs (atom energies) are edge-aggregated with an RBF gate and
summed per graph for the total energy; forces come from autodiff.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import DP, TP
from repro.models.gnn import common as C
from repro.nn import dense_init, dense_apply, mlp_init, mlp_apply


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0
    d_in: int = 0            # 0 -> one-hot species embedding
    n_species: int = 16


def _sbf(d, angle, cfg):
    """Spherical Fourier-Bessel-style 2D basis (n_spherical × n_radial):
    Chebyshev angular polynomials cos(l·θ) × radial Bessel — the
    (documented) simplification of the exact spherical Bessel roots."""
    rbf = C.bessel_rbf(d, n_rbf=cfg.n_radial, cutoff=cfg.cutoff)  # (T, R)
    ls = jnp.arange(cfg.n_spherical, dtype=jnp.float32)
    ang = jnp.cos(angle[:, None] * ls + 0.0)                       # (T, S)
    out = ang[:, :, None] * rbf[:, None, :]                        # (T,S,R)
    return out.reshape(d.shape[0], cfg.n_spherical * cfg.n_radial)


def init(key, cfg: DimeNetConfig):
    h = cfg.d_hidden
    nsr = cfg.n_spherical * cfg.n_radial
    ks = jax.random.split(key, 8 + 8 * cfg.n_blocks)
    p = {
        "embed_z": dense_init(ks[0], cfg.n_species if cfg.d_in == 0
                              else cfg.d_in, h),
        "embed_rbf": dense_init(ks[1], cfg.n_radial, h),
        "embed_msg": dense_init(ks[2], 3 * h, h),
        "out_rbf": dense_init(ks[3], cfg.n_radial, h, bias=False),
        "out_mlp": mlp_init(ks[4], [h, h, 1]),
        "blocks": [],
    }
    for i in range(cfg.n_blocks):
        b = 8 + 8 * i
        p["blocks"].append({
            "w_kj": dense_init(ks[b + 0], h, h),
            "w_ji": dense_init(ks[b + 1], h, h),
            "w_rbf": dense_init(ks[b + 2], cfg.n_radial, h, bias=False),
            "w_sbf": dense_init(ks[b + 3], nsr, cfg.n_bilinear,
                                bias=False),
            "w_bil": jax.random.normal(ks[b + 4],
                                       (cfg.n_bilinear, h, h)) * 0.05,
            "w_out1": dense_init(ks[b + 5], h, h),
            "w_out2": dense_init(ks[b + 6], h, h),
        })
    return p


PARAM_RULES = [
    (r"blocks/.*/w", P(DP, TP)),
    (r"embed_", P(DP, TP)),
    (r"out_", P(DP, None)),
]


def apply(params, graph, cfg: DimeNetConfig):
    """graph: nodes 'species' (N,) int or 'nodes' (N,d), positions (N,3),
    edge_index (2,E), triplets (2,T) [kj_edge, ji_edge], masks.
    Returns per-graph energy (scalar) and per-node energies."""
    ei = graph["edge_index"]
    em = graph["edge_mask"]
    nm = graph["node_mask"]
    tm = graph["triplet_mask"]
    trip = graph["triplets"]                       # (2, T) edge ids
    n = nm.shape[0]
    act = jax.nn.swish

    vec, d, unit = C.edge_vectors(graph["positions"], ei)
    rbf = C.bessel_rbf(d, n_rbf=cfg.n_radial, cutoff=cfg.cutoff) \
        * em[:, None]

    # triplet angle between edges (k->j) and (j->i)
    u_kj = jnp.take(unit, trip[0], axis=0)
    u_ji = jnp.take(unit, trip[1], axis=0)
    cosang = jnp.clip((-u_kj * u_ji).sum(-1), -1.0, 1.0)
    angle = jnp.arccos(cosang)
    d_kj = jnp.take(d, trip[0], axis=0)
    sbf = _sbf(d_kj, angle, cfg) * tm[:, None]     # (T, S*R)

    if cfg.d_in == 0:
        z = jax.nn.one_hot(graph["species"], cfg.n_species)
    else:
        z = graph["nodes"]
    hz = act(dense_apply(params["embed_z"], z))    # (N, H)
    hrbf = act(dense_apply(params["embed_rbf"], rbf))
    m = act(dense_apply(params["embed_msg"], jnp.concatenate(
        [jnp.take(hz, ei[0], 0), jnp.take(hz, ei[1], 0), hrbf], -1)))
    m = m * em[:, None]                            # (E, H)

    energy_n = jnp.zeros((n,), jnp.float32)
    for bp in params["blocks"]:
        x_kj = act(dense_apply(bp["w_kj"], m))
        g_rbf = dense_apply(bp["w_rbf"], rbf)      # (E, H)
        x_ji = act(dense_apply(bp["w_ji"], m)) * g_rbf
        # triplet interaction: gather kj messages, bilinear with sbf
        t_kj = jnp.take(x_kj, trip[0], axis=0)     # (T, H)
        s8 = dense_apply(bp["w_sbf"], sbf)         # (T, n_bilinear)
        inter = jnp.einsum("tb,th,bhg->tg", s8, t_kj, bp["w_bil"])
        inter = inter * tm[:, None]
        agg = jax.ops.segment_sum(inter, trip[1],
                                  num_segments=m.shape[0])  # (E, H)
        m = m + act(dense_apply(bp["w_out1"], x_ji + agg))
        m = (m + act(dense_apply(bp["w_out2"], m))) * em[:, None]
        # output block: edge -> node with rbf gate
        contrib = C.scatter_sum(g_rbf * m, ei, n, em)
        energy_n = energy_n + mlp_apply(params["out_mlp"],
                                        act(contrib))[:, 0]
    energy_n = energy_n * nm
    return energy_n.sum(), energy_n


def loss_fn(params, graph, cfg: DimeNetConfig):
    e, e_n = apply(params, graph, cfg)
    err = e - graph["energy"]
    loss = err ** 2
    return loss, {"loss": loss, "energy": e}


def batched_loss_fn(params, graphs, cfg: DimeNetConfig):
    """For the 'molecule' shape: vmapped batch of small graphs."""
    losses, metrics = jax.vmap(
        lambda g: loss_fn(params, g, cfg))(graphs)
    return losses.mean(), {k: v.mean() for k, v in metrics.items()}
