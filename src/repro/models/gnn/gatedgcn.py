"""GatedGCN (Bresson & Laurent, arXiv:1711.07553; benchmarking-gnns
arXiv:2003.00982 config: 16 layers, d_hidden=70, gated aggregator).

Layer (with edge features, residual, batch-norm as in benchmarking-gnns):
    ê_ij = A h_i + B h_j + C e_ij
    e'_ij = e_ij + ReLU(BN(ê_ij))
    η_ij = σ(ê_ij) / (Σ_{j'} σ(ê_ij') + ε)     (gated aggregation)
    h'_i = h_i + ReLU(BN(U h_i + Σ_j η_ij ⊙ V h_j))
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.graph_ir import Graph, Operator, register_exporter
from repro.dist.sharding import DP, TP
from repro.models.gnn import common as C
from repro.nn import dense_init, dense_apply


@dataclasses.dataclass(frozen=True)
class GatedGCNConfig:
    name: str = "gatedgcn"
    n_layers: int = 16
    d_hidden: int = 70
    d_in: int = 1433
    d_edge_in: int = 1
    n_classes: int = 7
    readout: str = "node"      # 'node' (classification) | 'graph'
    transform_then_gather: bool = False
    # beyond-paper (§Perf D): A/B/V are linear, so transform per NODE
    # (3·N·d²) then gather beats gather-then-transform per EDGE (3·E·d²)
    # whenever E > N (reddit: 492×). Mathematically identical (tested).


def init(key, cfg: GatedGCNConfig):
    ks = jax.random.split(key, 4 + 6 * cfg.n_layers)
    p = {
        "embed_h": dense_init(ks[0], cfg.d_in, cfg.d_hidden),
        "embed_e": dense_init(ks[1], cfg.d_edge_in, cfg.d_hidden),
        "head": dense_init(ks[2], cfg.d_hidden, cfg.n_classes),
        "layers": [],
    }
    for i in range(cfg.n_layers):
        base = 4 + 6 * i
        p["layers"].append({
            "A": dense_init(ks[base + 0], cfg.d_hidden, cfg.d_hidden),
            "B": dense_init(ks[base + 1], cfg.d_hidden, cfg.d_hidden),
            "Ce": dense_init(ks[base + 2], cfg.d_hidden, cfg.d_hidden),
            "U": dense_init(ks[base + 3], cfg.d_hidden, cfg.d_hidden),
            "V": dense_init(ks[base + 4], cfg.d_hidden, cfg.d_hidden),
        })
    return p


PARAM_RULES = [
    (r"embed_h/w", P(DP, TP)),
    (r"layers/.*/w", P(DP, TP)),
    (r"head/w", P(DP, None)),
]


def apply(params, graph, cfg: GatedGCNConfig):
    nodes, ei = graph["nodes"], graph["edge_index"]
    nm, em = graph["node_mask"], graph["edge_mask"]
    n = nodes.shape[0]
    h = dense_apply(params["embed_h"], nodes)
    e = dense_apply(params["embed_e"], graph.get(
        "edges", jnp.ones((ei.shape[1], cfg.d_edge_in), h.dtype)))
    for lp in params["layers"]:
        if cfg.transform_then_gather:
            ai = jnp.take(dense_apply(lp["A"], h), ei[1], axis=0)
            bj = jnp.take(dense_apply(lp["B"], h), ei[0], axis=0)
            vj = jnp.take(dense_apply(lp["V"], h), ei[0], axis=0)
            ehat = ai + bj + dense_apply(lp["Ce"], e)
        else:  # paper-faithful gather-then-transform (per-edge denses)
            hi = C.gather_dst(h, ei)   # i = destination
            hj = C.gather_src(h, ei)   # j = source
            ehat = (dense_apply(lp["A"], hi) + dense_apply(lp["B"], hj)
                    + dense_apply(lp["Ce"], e))
            vj = dense_apply(lp["V"], hj)
        e = e + jax.nn.relu(C.masked_batchnorm(ehat, em))
        sig = jax.nn.sigmoid(ehat) * em[:, None]
        denom = C.scatter_sum(sig, ei, n) + 1e-6
        eta = sig / jnp.take(denom, ei[1], axis=0)
        msg = C.scatter_sum(eta * vj, ei, n, em)
        h = h + jax.nn.relu(C.masked_batchnorm(
            dense_apply(lp["U"], h) + msg, nm))
    if cfg.readout == "graph":
        pooled = (h * nm[:, None]).sum(0) / jnp.maximum(nm.sum(), 1.0)
        return dense_apply(params["head"], pooled)
    return dense_apply(params["head"], h)


def to_graph(params, cfg: GatedGCNConfig):
    """Export as a dataflow graph for the deployment flow
    (repro.core.pipeline) — numerically identical in fp mode (tested).

    Every layer expands into the edge-typed IR ops the pattern-keyed
    passes dispatch on: ``gather_edge`` endpoint gathers,
    ``edge_aggregate`` segment reductions (the Pallas one-hot-incidence
    kernel), ``eltwise`` gate algebra and ``batchnorm``. The export
    always uses the gather-then-transform topology; it is
    mathematically identical to ``transform_then_gather`` (the two
    modes share parameters). Only ``readout='node'`` deploys — graph
    pooling has no IR op yet."""
    if cfg.readout != "node":
        raise ValueError(
            f"gatedgcn export supports readout='node' only, "
            f"got {cfg.readout!r}")
    g = Graph()
    dh = cfg.d_hidden

    def lin(name, inp, p, d_out):
        g.add(Operator(name=name, op_type="linear", inputs=[inp],
                       params=dict(p), out_dim=d_out))
        return name

    def elt(name, fn, inputs, d, **extra):
        g.add(Operator(name=name, op_type="eltwise", inputs=list(inputs),
                       attrs={"fn": fn, **extra}, out_dim=d))
        return name

    def gather(name, inp, endpoint):
        g.add(Operator(name=name, op_type="gather_edge",
                       inputs=[inp, "edge_index"],
                       attrs={"endpoint": endpoint}, out_dim=dh))
        return name

    def bn(name, inp, mask):
        g.add(Operator(name=name, op_type="batchnorm",
                       inputs=[inp, mask], out_dim=dh))
        return name

    g.add(Operator(name="nodes", op_type="input", out_dim=cfg.d_in,
                   attrs={"feature": "nodes"}))
    g.add(Operator(name="edge_index", op_type="input", out_dim=2,
                   attrs={"feature": "edge_index"}))
    g.add(Operator(name="edges", op_type="input", out_dim=cfg.d_edge_in,
                   attrs={"feature": "edges"}))
    g.add(Operator(name="node_mask", op_type="input", out_dim=1,
                   attrs={"feature": "node_mask"}))
    g.add(Operator(name="edge_mask", op_type="input", out_dim=1,
                   attrs={"feature": "edge_mask"}))
    h = lin("embed_h", "nodes", params["embed_h"], dh)
    e = lin("embed_e", "edges", params["embed_e"], dh)
    for i, lp in enumerate(params["layers"]):
        hi = gather(f"l{i}_hi", h, "dst")
        hj = gather(f"l{i}_hj", h, "src")
        ehat = elt(f"l{i}_ehat", "add",
                   [lin(f"l{i}_A", hi, lp["A"], dh),
                    lin(f"l{i}_B", hj, lp["B"], dh),
                    lin(f"l{i}_Ce", e, lp["Ce"], dh)], dh)
        ebn = bn(f"l{i}_ebn", ehat, "edge_mask")
        g.add(Operator(name=f"l{i}_ebn_relu", op_type="relu",
                       inputs=[ebn], out_dim=dh))
        e = elt(f"l{i}_e", "add", [e, f"l{i}_ebn_relu"], dh)
        sig = elt(f"l{i}_sigm", "mask",
                  [elt(f"l{i}_sig", "sigmoid", [ehat], dh),
                   "edge_mask"], dh)
        g.add(Operator(name=f"l{i}_denom", op_type="edge_aggregate",
                       inputs=[sig, "edge_index"],
                       attrs={"reduce": "sum"}, out_dim=dh))
        deps = elt(f"l{i}_denom_eps", "add_const", [f"l{i}_denom"], dh,
                   const=1e-6)
        eta = elt(f"l{i}_eta", "div",
                  [sig, gather(f"l{i}_deng", deps, "dst")], dh)
        msg = elt(f"l{i}_msg", "mul",
                  [eta, lin(f"l{i}_V", hj, lp["V"], dh)], dh)
        g.add(Operator(name=f"l{i}_agg", op_type="edge_aggregate",
                       inputs=[msg, "edge_index", "edge_mask"],
                       attrs={"reduce": "sum"}, out_dim=dh))
        pre = elt(f"l{i}_pre", "add",
                  [lin(f"l{i}_U", h, lp["U"], dh), f"l{i}_agg"], dh)
        hbn = bn(f"l{i}_hbn", pre, "node_mask")
        g.add(Operator(name=f"l{i}_hbn_relu", op_type="relu",
                       inputs=[hbn], out_dim=dh))
        h = elt(f"l{i}_h", "add", [h, f"l{i}_hbn_relu"], dh)
    head = lin("head", h, params["head"], cfg.n_classes)
    g.add(Operator(name="out", op_type="output", inputs=[head],
                   attrs={"head_names": ["logits"]},
                   out_dim=cfg.n_classes))
    g.validate()
    g.meta["config"] = cfg
    return g


def loss_fn(params, graph, cfg: GatedGCNConfig):
    logits = apply(params, graph, cfg)
    labels = graph["labels"]
    if cfg.readout == "graph":     # graph-level classification (scalar
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))  # label)
        loss = -logp[labels]
        acc = (logits.argmax(-1) == labels).astype(jnp.float32)
        return loss, {"loss": loss, "acc": acc}
    nm = graph["node_mask"] * graph.get("train_mask",
                                        jnp.ones_like(graph["node_mask"]))
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ce = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    loss = (ce * nm).sum() / jnp.maximum(nm.sum(), 1.0)
    acc = ((logits.argmax(-1) == labels) * nm).sum() / \
        jnp.maximum(nm.sum(), 1.0)
    return loss, {"loss": loss, "acc": acc}


register_exporter("gatedgcn", to_graph)
