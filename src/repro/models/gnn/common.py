"""Shared GNN substrate: segment-op message passing over edge lists.

JAX sparse is BCOO-only, so message passing here is built from first
principles: gather source-node features by ``edge_index[0]``, transform,
``jax.ops.segment_sum / segment_max`` into destination nodes — this IS the
system (see kernel_taxonomy §GNN). All shapes are static: graphs are
padded to fixed (N, E) budgets with node/edge masks, which keeps every
train/serve step recompile-free and shardable.

Batch format (a "GraphsTuple-lite"):
  nodes      (N, d)      float
  edge_index (2, E)      int32 (src, dst); padded edges point at node 0
  node_mask  (N,)        float
  edge_mask  (E,)        float
  positions  (N, 3)      float (geometric archs)
  labels / energy / ...  per-task extras

Distribution: edges are sharded over the dp axis (edge-parallel
message passing); each shard segment-sums into the full node range and the
partial node aggregates are summed by GSPMD (an all-reduce over dp) —
the standard 1D edge-partitioning scheme for full-graph training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_src(nodes, edge_index):
    return jnp.take(nodes, edge_index[0], axis=0)


def gather_dst(nodes, edge_index):
    return jnp.take(nodes, edge_index[1], axis=0)


def scatter_sum(messages, edge_index, n_nodes, edge_mask=None):
    if edge_mask is not None:
        messages = messages * edge_mask[:, None]
    return jax.ops.segment_sum(messages, edge_index[1],
                               num_segments=n_nodes)


def scatter_mean(messages, edge_index, n_nodes, edge_mask=None):
    s = scatter_sum(messages, edge_index, n_nodes, edge_mask)
    ones = jnp.ones((messages.shape[0],), messages.dtype)
    if edge_mask is not None:
        ones = ones * edge_mask
    cnt = jax.ops.segment_sum(ones, edge_index[1], num_segments=n_nodes)
    return s / jnp.maximum(cnt, 1.0)[:, None]


def scatter_max(messages, edge_index, n_nodes, edge_mask=None):
    if edge_mask is not None:
        messages = jnp.where(edge_mask[:, None] > 0, messages, -1e30)
    m = jax.ops.segment_max(messages, edge_index[1], num_segments=n_nodes)
    return jnp.where(m <= -1e29, 0.0, m)


def scatter_softmax(scores, edge_index, n_nodes, edge_mask=None):
    """Edge-softmax (per destination node)."""
    if edge_mask is not None:
        scores = jnp.where(edge_mask > 0, scores, -1e30)
    mx = jax.ops.segment_max(scores, edge_index[1], num_segments=n_nodes)
    ex = jnp.exp(scores - jnp.take(mx, edge_index[1], axis=0))
    if edge_mask is not None:
        ex = ex * edge_mask
    z = jax.ops.segment_sum(ex, edge_index[1], num_segments=n_nodes)
    return ex / jnp.maximum(jnp.take(z, edge_index[1], axis=0), 1e-16)


def masked_batchnorm(x, mask, *, eps=1e-5):
    """BatchNorm over valid nodes/edges (batch statistics; the
    benchmarking-gnns training-mode normalization)."""
    m = mask[:, None]
    n = jnp.maximum(m.sum(), 1.0)
    mu = (x * m).sum(0) / n
    var = (((x - mu) ** 2) * m).sum(0) / n
    return (x - mu) * jax.lax.rsqrt(var + eps) * m


def edge_vectors(positions, edge_index, *, eps=1e-9):
    """(E,3) displacement vectors src->dst, their lengths, and unit dirs."""
    r = gather_dst(positions, edge_index) - gather_src(positions, edge_index)
    d = jnp.sqrt(jnp.maximum((r * r).sum(-1), eps))
    return r, d, r / d[:, None]


def bessel_rbf(d, *, n_rbf: int, cutoff: float):
    """DimeNet/NequIP radial Bessel basis with cosine cutoff envelope."""
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    x = jnp.maximum(d, 1e-6)[:, None] / cutoff
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(jnp.pi * n * x) / \
        jnp.maximum(d, 1e-6)[:, None]
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(x, 0, 1)) + 1.0)
    return basis * env


def cosine_cutoff(d, cutoff: float):
    x = jnp.clip(d / cutoff, 0.0, 1.0)
    return 0.5 * (jnp.cos(jnp.pi * x) + 1.0)
