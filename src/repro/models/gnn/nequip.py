"""NequIP (Batzner et al., arXiv:2101.03164): E(3)-equivariant interatomic
potential. Config: 5 layers, 32 channels, l_max=2, n_rbf=8, cutoff=5 Å.

Features are direct sums of irreps (l, parity) with equal multiplicity:
hidden = 32×(0,+) ⊕ 32×(1,−) ⊕ 32×(2,+). An interaction layer computes,
per edge, the tensor product of source features with spherical harmonics
of the edge direction (filter parity (−1)^l2), weighted channel-wise by an
MLP of the radial basis ("uvu" connectivity), scatter-sums messages into
destination nodes, then applies a linear self-interaction per irrep and a
gate nonlinearity (scalars: SiLU; l>0: sigmoid-gated by dedicated scalar
channels). Energies are the sum of per-atom scalar readouts; forces are
−∂E/∂positions via autodiff (rotation equivariance is property-tested).

Kernel regime: **irrep tensor product** (taxonomy §GNN regime 3). The CG
contraction einsum('emi,ej,ijk->emk') over precomputed intertwiners is the
hot spot; paths are enumerated statically at init.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import DP, TP
from repro.models.gnn import common as C
from repro.models.gnn.sph import intertwiner_jnp, real_sph
from repro.nn import dense_init, dense_apply, mlp_init, mlp_apply

# hidden irreps: (l, parity)
IRREPS = ((0, 1), (1, -1), (2, 1))


@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str = "nequip"
    n_layers: int = 5
    mult: int = 32              # channels per irrep ("d_hidden=32")
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 16
    radial_hidden: int = 64


def _paths(cfg: NequIPConfig):
    """Enumerate allowed (l1,p1) ⊗ Y_l2 -> (l3,p3) tensor-product paths."""
    irreps = [ir for ir in IRREPS if ir[0] <= cfg.l_max]
    paths = []
    for (l1, p1) in irreps:
        for l2 in range(cfg.l_max + 1):
            p2 = (-1) ** l2
            for (l3, p3) in irreps:
                if p1 * p2 != p3 or not abs(l1 - l2) <= l3 <= l1 + l2:
                    continue
                if intertwiner_jnp(l1, l2, l3) is None:
                    continue
                paths.append((l1, p1, l2, l3, p3))
    return irreps, paths


def init(key, cfg: NequIPConfig):
    irreps, paths = _paths(cfg)
    m = cfg.mult
    ks = jax.random.split(key, 6 + 4 * cfg.n_layers)
    p = {"embed_z": dense_init(ks[0], cfg.n_species, m, bias=False),
         "readout1": dense_init(ks[1], m, m),
         "readout2": mlp_init(ks[2], [m, m, 1]),
         "layers": []}
    for i in range(cfg.n_layers):
        k1, k2, k3, k4 = jax.random.split(ks[6 + i], 4)
        n_gates = m * sum(1 for (l, _) in irreps if l > 0)
        lp = {
            # radial MLP -> per-path per-channel weights
            "radial": mlp_init(k1, [cfg.n_rbf, cfg.radial_hidden,
                                    len(paths) * m]),
            # self-interaction: channel mixing per target irrep
            "self": {f"l{l}p{pr}": dense_init(
                jax.random.fold_in(k2, 10 * l + pr), m, m, bias=(l == 0))
                for (l, pr) in irreps},
            # gate scalars for l>0 irreps from the scalar channels
            "gate": dense_init(k3, m, n_gates),
            "skip": {f"l{l}p{pr}": dense_init(
                jax.random.fold_in(k4, 10 * l + pr), m, m, bias=False)
                for (l, pr) in irreps},
        }
        p["layers"].append(lp)
    return p


PARAM_RULES = [
    (r"layers/.*/w", P(DP, TP)),
    (r"readout", P(DP, None)),
    (r"embed_z/w", P(DP, TP)),
]


def _feat_zero(n, cfg, dtype=jnp.float32):
    irreps, _ = _paths(cfg)
    return {f"l{l}p{p}": jnp.zeros((n, cfg.mult, 2 * l + 1), dtype)
            for (l, p) in irreps}


def apply(params, graph, cfg: NequIPConfig):
    """graph: species (N,), positions (N,3), edge_index (2,E), masks.
    Returns (total_energy, per_atom_energy)."""
    irreps, paths = _paths(cfg)
    ei = graph["edge_index"]
    nm, em = graph["node_mask"], graph["edge_mask"]
    n = nm.shape[0]
    m = cfg.mult

    vec, d, unit = C.edge_vectors(graph["positions"], ei)
    rbf = C.bessel_rbf(d, n_rbf=cfg.n_rbf, cutoff=cfg.cutoff)
    env = C.cosine_cutoff(d, cfg.cutoff) * em                   # (E,)
    ylm = {l2: real_sph(l2, unit) for l2 in range(cfg.l_max + 1)}

    z = jax.nn.one_hot(graph["species"], cfg.n_species)
    h = _feat_zero(n, cfg)
    h["l0p1"] = dense_apply(params["embed_z"], z)[:, :, None]   # (N,m,1)

    for lp in params["layers"]:
        w_all = mlp_apply(lp["radial"], rbf,
                          activation=jax.nn.silu)               # (E, P*m)
        w_all = w_all.reshape(-1, len(paths), m) * env[:, None, None]
        msg = {k: jnp.zeros_like(v) for k, v in h.items()}
        for pi, (l1, p1, l2, l3, p3) in enumerate(paths):
            w = w_all[:, pi, :]                                 # (E, m)
            src = jnp.take(h[f"l{l1}p{p1}"], ei[0], axis=0)     # (E,m,2l1+1)
            cg = intertwiner_jnp(l1, l2, l3)                    # (i,j,k)
            contrib = jnp.einsum("emi,ej,ijk->emk", src, ylm[l2], cg)
            contrib = contrib * w[:, :, None]
            key = f"l{l3}p{p3}"
            msg[key] = msg[key] + jax.ops.segment_sum(
                contrib, ei[1], num_segments=n)
        # self-interaction + skip + gate
        new_h = {}
        scal = msg["l0p1"][:, :, 0]
        gates = jax.nn.sigmoid(dense_apply(lp["gate"], scal))   # (N, gates)
        gi = 0
        for (l, pr) in irreps:
            key = f"l{l}p{pr}"
            mixed = jnp.einsum("nmi,mk->nki", msg[key],
                               lp["self"][key]["w"])
            if l == 0 and "b" in lp["self"][key]:
                mixed = mixed + lp["self"][key]["b"][None, :, None]
            skip = jnp.einsum("nmi,mk->nki", h[key], lp["skip"][key]["w"])
            if l == 0:
                new_h[key] = skip + jax.nn.silu(mixed)
            else:
                g = gates[:, gi * m:(gi + 1) * m]
                new_h[key] = skip + mixed * g[:, :, None]
                gi += 1
        h = {k: v * nm[:, None, None] for k, v in new_h.items()}

    atom_scal = jax.nn.silu(dense_apply(params["readout1"],
                                        h["l0p1"][:, :, 0]))
    e_atom = mlp_apply(params["readout2"], atom_scal,
                       activation=jax.nn.silu)[:, 0] * nm
    return e_atom.sum(), e_atom


def loss_fn(params, graph, cfg: NequIPConfig, *, force_weight=0.0):
    if force_weight > 0:
        def e_fn(pos):
            g = dict(graph)
            g["positions"] = pos
            return apply(params, g, cfg)[0]
        e, forces_neg = jax.value_and_grad(e_fn)(graph["positions"])
        loss = (e - graph["energy"]) ** 2
        if "forces" in graph:
            fmse = (((-forces_neg - graph["forces"]) ** 2)
                    * graph["node_mask"][:, None]).sum() / \
                jnp.maximum(graph["node_mask"].sum(), 1.0)
            loss = loss + force_weight * fmse
        return loss, {"loss": loss, "energy": e}
    e, _ = apply(params, graph, cfg)
    loss = (e - graph["energy"]) ** 2
    return loss, {"loss": loss, "energy": e}


def forces(params, graph, cfg: NequIPConfig):
    def e_fn(pos):
        g = dict(graph)
        g["positions"] = pos
        return apply(params, g, cfg)[0]
    return -jax.grad(e_fn)(graph["positions"])
