from repro.models.gnn import common, dimenet, gatedgcn, graphsage, nequip
