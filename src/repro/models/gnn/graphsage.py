"""GraphSAGE (Hamilton et al., arXiv:1706.02216) — mean aggregator,
2 layers, d_hidden=128, neighbor-sampling 25-10 (the Reddit config).

Two operating modes sharing the same parameters:
- full-graph: message passing over a (padded) global edge list;
- sampled minibatch: fixed-fanout layered subgraph from
  ``repro.data.graphs.NeighborSampler`` (dst nodes first, then fanout
  frontiers), processed layer-by-layer exactly like the paper.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.graph_ir import Graph, Operator, register_exporter
from repro.dist.sharding import DP, TP
from repro.models.gnn import common as C
from repro.nn import dense_init, dense_apply


@dataclasses.dataclass(frozen=True)
class GraphSAGEConfig:
    name: str = "graphsage-reddit"
    n_layers: int = 2
    d_hidden: int = 128
    d_in: int = 602
    n_classes: int = 41
    sample_sizes: tuple = (25, 10)
    normalize: bool = True


def init(key, cfg: GraphSAGEConfig):
    ks = jax.random.split(key, cfg.n_layers + 1)
    p = {"layers": [], "head": dense_init(ks[-1], cfg.d_hidden,
                                          cfg.n_classes)}
    d = cfg.d_in
    for i in range(cfg.n_layers):
        p["layers"].append(
            {"w": dense_init(ks[i], 2 * d, cfg.d_hidden)})
        d = cfg.d_hidden
    return p


PARAM_RULES = [
    (r"layers/.*/w", P(DP, TP)),
    (r"head/w", P(DP, None)),
]


def _sage_layer(lp, h, ei, n, nm, em, *, normalize):
    neigh = C.scatter_mean(jnp.take(h, ei[0], axis=0), ei, n, em)
    z = dense_apply(lp["w"], jnp.concatenate([h, neigh], axis=-1),
                    activation=jax.nn.relu)
    if normalize:
        z = z / jnp.maximum(jnp.linalg.norm(z, axis=-1, keepdims=True),
                            1e-6)
    return z * nm[:, None]


def apply(params, graph, cfg: GraphSAGEConfig):
    """Full-graph mode."""
    h, ei = graph["nodes"], graph["edge_index"]
    nm, em = graph["node_mask"], graph["edge_mask"]
    n = h.shape[0]
    for lp in params["layers"]:
        h = _sage_layer(lp, h, ei, n, nm, em, normalize=cfg.normalize)
    return dense_apply(params["head"], h)


def apply_sampled(params, batch, cfg: GraphSAGEConfig):
    """Sampled-minibatch mode. batch:
      feats   (N_total, d_in)  — all frontier node features, layered layout
      edges   list of (2, E_l) per layer, frontier l+1 -> frontier l
      sizes   static tuple of frontier sizes [n0 (targets), n1, n2]
    Frontier layout: nodes of frontier l occupy [off_l, off_l + n_l).
    """
    sizes = cfg_frontier_sizes(cfg, batch["labels"].shape[0])
    h = batch["feats"]
    offs = [0]
    for s in sizes:
        offs.append(offs[-1] + s)
    # layer l aggregates frontier l+1 into frontier l
    for li, lp in enumerate(params["layers"]):
        new_h = []
        depth = len(sizes) - 1  # frontiers shrink by one per layer
        for f in range(depth):
            ei = batch["edges"][f]          # src in frontier f+1, dst in f
            seg = jnp.take(h, offs[f] + jnp.arange(sizes[f]), axis=0)
            src = jnp.take(h, ei[0], axis=0)
            msum = jax.ops.segment_sum(src, ei[1] - offs[f],
                                       num_segments=sizes[f])
            cnt = jax.ops.segment_sum(jnp.ones((ei.shape[1],), h.dtype),
                                      ei[1] - offs[f],
                                      num_segments=sizes[f])
            neigh = msum / jnp.maximum(cnt, 1.0)[:, None]
            z = dense_apply(lp["w"],
                            jnp.concatenate([seg, neigh], -1),
                            activation=jax.nn.relu)
            if cfg.normalize:
                z = z / jnp.maximum(
                    jnp.linalg.norm(z, axis=-1, keepdims=True), 1e-6)
            new_h.append(z)
        h = jnp.concatenate(new_h, axis=0)
        sizes = sizes[:len(new_h)]
        offs = [0]
        for s in sizes:
            offs.append(offs[-1] + s)
    return dense_apply(params["head"], h[:sizes[0]])


def to_graph(params, cfg: GraphSAGEConfig):
    """Export the full-graph mode as a dataflow graph for the
    deployment flow (repro.core.pipeline) — numerically identical in
    fp mode (tested).

    The mean aggregator lowers to a ``gather_edge`` (source endpoint)
    feeding an ``edge_aggregate`` with ``reduce='mean'`` — the same
    Pallas one-hot-incidence kernel the gated models use, with the
    masked edge-count epilogue. Sampled-minibatch mode has a dynamic
    frontier layout and does not export."""
    g = Graph()

    g.add(Operator(name="nodes", op_type="input", out_dim=cfg.d_in,
                   attrs={"feature": "nodes"}))
    g.add(Operator(name="edge_index", op_type="input", out_dim=2,
                   attrs={"feature": "edge_index"}))
    g.add(Operator(name="node_mask", op_type="input", out_dim=1,
                   attrs={"feature": "node_mask"}))
    g.add(Operator(name="edge_mask", op_type="input", out_dim=1,
                   attrs={"feature": "edge_mask"}))
    h, d = "nodes", cfg.d_in
    for i, lp in enumerate(params["layers"]):
        g.add(Operator(name=f"l{i}_hj", op_type="gather_edge",
                       inputs=[h, "edge_index"],
                       attrs={"endpoint": "src"}, out_dim=d))
        g.add(Operator(name=f"l{i}_neigh", op_type="edge_aggregate",
                       inputs=[f"l{i}_hj", "edge_index", "edge_mask"],
                       attrs={"reduce": "mean"}, out_dim=d))
        g.add(Operator(name=f"l{i}_cat", op_type="concat",
                       inputs=[h, f"l{i}_neigh"], out_dim=2 * d))
        g.add(Operator(name=f"l{i}_z", op_type="linear",
                       inputs=[f"l{i}_cat"], params=dict(lp["w"]),
                       out_dim=cfg.d_hidden))
        g.add(Operator(name=f"l{i}_zr", op_type="relu",
                       inputs=[f"l{i}_z"], out_dim=cfg.d_hidden))
        z = f"l{i}_zr"
        if cfg.normalize:
            g.add(Operator(name=f"l{i}_n", op_type="eltwise",
                           inputs=[z], attrs={"fn": "l2norm"},
                           out_dim=cfg.d_hidden))
            z = f"l{i}_n"
        g.add(Operator(name=f"l{i}_h", op_type="eltwise",
                       inputs=[z, "node_mask"], attrs={"fn": "mask"},
                       out_dim=cfg.d_hidden))
        h, d = f"l{i}_h", cfg.d_hidden
    g.add(Operator(name="head", op_type="linear", inputs=[h],
                   params=dict(params["head"]), out_dim=cfg.n_classes))
    g.add(Operator(name="out", op_type="output", inputs=["head"],
                   attrs={"head_names": ["logits"]},
                   out_dim=cfg.n_classes))
    g.validate()
    g.meta["config"] = cfg
    return g


def cfg_frontier_sizes(cfg: GraphSAGEConfig, batch_nodes: int):
    sizes = [batch_nodes]
    for f in cfg.sample_sizes:
        sizes.append(sizes[-1] * f)
    return tuple(sizes)


def loss_fn(params, graph, cfg: GraphSAGEConfig, *, sampled=False):
    if sampled:
        logits = apply_sampled(params, graph, cfg)
        labels = graph["labels"]
        nm = jnp.ones((logits.shape[0],), jnp.float32)
    else:
        logits = apply(params, graph, cfg)
        labels = graph["labels"]
        nm = graph["node_mask"] * graph.get(
            "train_mask", jnp.ones_like(graph["node_mask"]))
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ce = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    loss = (ce * nm).sum() / jnp.maximum(nm.sum(), 1.0)
    acc = ((logits.argmax(-1) == labels) * nm).sum() / \
        jnp.maximum(nm.sum(), 1.0)
    return loss, {"loss": loss, "acc": acc}


register_exporter("graphsage", to_graph)
