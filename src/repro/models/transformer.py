"""Decoder-only transformer family covering the five assigned LM archs.

Features: GQA/MQA + RoPE, RMSNorm or OLMo-style non-parametric LayerNorm,
gated or plain MLP, GShard-style top-k MoE (einsum dispatch; optional
scatter dispatch as a perf variant), blockwise (flash-style) causal
attention, KV-cache decode, layer-stacked params with ``lax.scan`` (keeps
the HLO one-layer-sized for 88-layer models), remat, and logical-axis
sharding constraints (dp/tp) translated per-mesh.

Cost-model note (EXPERIMENTS.md §Roofline): XLA's ``cost_analysis`` counts
a scan body ONCE, so roofline terms are composed from per-layer unrolled
sub-lowerings × n_layers (``layer_fwd`` / ``layer_step`` are exported for
exactly that purpose) while the deliverable train/serve steps keep scan.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import DP, TP, logical_to_physical
from repro.nn.layers import nonparametric_layernorm, rmsnorm_apply


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    group_size: int = 512
    dispatch: str = "einsum"       # 'einsum' (GShard) | 'scatter'
    shared_experts: int = 0
    vmap_groups: bool = False      # vmap instead of lax.map over groups
                                   # (exact cost_analysis; lowering-only)


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                 # 0 -> d_model // n_heads
    norm: str = "rmsnorm"           # 'rmsnorm' | 'nonparametric'
    gated_mlp: bool = True
    activation: str = "silu"
    moe: MoEConfig | None = None
    rope_theta: float = 500000.0
    block_q: int = 512              # attention q-chunk
    attn_mode: str = "scan"         # 'full' | 'scan' | 'unrolled_tri'
    remat: bool = True
    remat_policy: str = "full"      # 'full' | 'dots' (save projection
                                    # dots, recompute attention/softmax)
    seq_parallel: bool = False      # shard the residual stream's seq dim
                                    # over tp between blocks (Korthikanti
                                    # SP; GSPMD inserts AG/RS at attn)
    unroll_layers: bool = False     # python loop over layers (exact
                                    # cost_analysis; roofline lowerings)
    loss_chunk: int = 1024          # CE computed in seq chunks
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    kv_cache_int8: bool = False     # int8 KV cache w/ per-token scales
                                    # (halves decode cache traffic)

    @property
    def dh(self) -> int:
        return self.d_head or self.d_model // self.n_heads


# ------------------------------------------------------------------ params ----
def _layer_shapes(cfg: TransformerConfig):
    d, dh = cfg.d_model, cfg.dh
    s = {
        "wq": (d, cfg.n_heads * dh),
        "wk": (d, cfg.n_kv_heads * dh),
        "wv": (d, cfg.n_kv_heads * dh),
        "wo": (cfg.n_heads * dh, d),
    }
    if cfg.norm == "rmsnorm":
        s["attn_norm"] = (d,)
        s["ffn_norm"] = (d,)
    if cfg.moe is None:
        s["w_up"] = (d, cfg.d_ff)
        s["w_down"] = (cfg.d_ff, d)
        if cfg.gated_mlp:
            s["w_gate"] = (d, cfg.d_ff)
    else:
        e = cfg.moe.n_experts
        s["router"] = (d, e)
        s["moe_up"] = (e, d, cfg.d_ff)
        s["moe_down"] = (e, cfg.d_ff, d)
        if cfg.gated_mlp:
            s["moe_gate"] = (e, d, cfg.d_ff)
        if cfg.moe.shared_experts:
            f_sh = cfg.d_ff * cfg.moe.shared_experts
            s["sh_up"] = (d, f_sh)
            s["sh_down"] = (f_sh, d)
            if cfg.gated_mlp:
                s["sh_gate"] = (d, f_sh)
    return s


def abstract_params(cfg: TransformerConfig):
    L = cfg.n_layers
    dt = cfg.param_dtype
    layers = {k: jax.ShapeDtypeStruct((L, *v), dt)
              for k, v in _layer_shapes(cfg).items()}
    return {
        "embed": jax.ShapeDtypeStruct((cfg.vocab, cfg.d_model), dt),
        "layers": layers,
        "final_norm": jax.ShapeDtypeStruct((cfg.d_model,), dt),
        "lm_head": jax.ShapeDtypeStruct((cfg.d_model, cfg.vocab), dt),
    }


def init_params(key, cfg: TransformerConfig):
    """Real initialization (use for smoke-scale configs only)."""
    shapes = abstract_params(cfg)
    flat, treedef = jax.tree_util.tree_flatten(shapes)
    keys = jax.random.split(key, len(flat))

    def mk(k, sds):
        fan_in = sds.shape[-2] if len(sds.shape) >= 2 else sds.shape[-1]
        if len(sds.shape) == 1:
            return jnp.ones(sds.shape, sds.dtype)
        std = 1.0 / math.sqrt(fan_in)
        return (std * jax.random.truncated_normal(k, -2, 2, sds.shape)
                ).astype(sds.dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [mk(k, s) for k, s in zip(keys, flat)])


PARAM_RULES = [
    (r"embed", P(TP, DP)),
    (r"lm_head", P(DP, TP)),
    (r"final_norm", P()),
    (r"(attn|ffn)_norm", P(None)),
    (r"layers/w[qkv]$", P(None, DP, TP)),
    (r"layers/wo", P(None, TP, DP)),
    (r"layers/w_(gate|up)", P(None, DP, TP)),
    (r"layers/w_down", P(None, TP, DP)),
    (r"layers/router", P(None, DP, None)),
    (r"layers/moe_(gate|up)", P(None, TP, DP, None)),
    (r"layers/moe_down", P(None, TP, None, DP)),
    (r"layers/sh_(gate|up)", P(None, DP, TP)),
    (r"layers/sh_down", P(None, TP, DP)),
]


def _cst(x, mesh, *axes):
    """with_sharding_constraint using logical axis names ('dp'/'tp')."""
    if mesh is None:
        return x
    spec = logical_to_physical(P(*axes), mesh)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


# --------------------------------------------------------------- attention ----
def _rope(x, positions, theta):
    """x: (..., S, H, Dh); positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (math.log(theta) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    ang = ang[..., None, :]                                  # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def _attn_chunk(q, k, v, q_off, *, causal, lengths=None):
    """q: (B,Bq,Kv,G,Dh)  k,v: (B,T,Kv,Dh) -> (B,Bq,Kv,G,Dh).

    Grouped-query attention without materializing repeated KV heads.
    ``q_off`` is the absolute position of q[0] (causal masking);
    ``lengths`` (B,) masks a KV cache during decode."""
    dh = q.shape[-1]
    scores = jnp.einsum("bqkgd,btkd->bkgqt", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(dh)
    t_idx = jnp.arange(k.shape[1])
    if causal:
        q_idx = q_off + jnp.arange(q.shape[1])
        mask = t_idx[None, :] <= q_idx[:, None]              # (Bq, T)
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    if lengths is not None:
        lm = t_idx[None, :] < lengths[:, None]               # (B, T)
        scores = jnp.where(lm[:, None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgqt,btkd->bqkgd", p, v)


def attention(q, k, v, cfg: TransformerConfig, *, causal=True, q_off=0,
              lengths=None, mode=None):
    """q: (B,S,Kv,G,Dh), k/v: (B,T,Kv,Dh)."""
    mode = mode or cfg.attn_mode
    b, s = q.shape[:2]
    bq = min(cfg.block_q, s)
    if mode == "full" or s <= bq:
        return _attn_chunk(q, k, v, q_off, causal=causal, lengths=lengths)
    assert s % bq == 0, (s, bq)
    nq = s // bq
    if mode == "unrolled_tri":
        # exact triangular FLOPs: static python loop, kv sliced per chunk
        outs = []
        for i in range(nq):
            hi = (i + 1) * bq
            outs.append(_attn_chunk(q[:, i * bq:hi], k[:, :hi], v[:, :hi],
                                    q_off + i * bq, causal=causal,
                                    lengths=lengths))
        return jnp.concatenate(outs, axis=1)
    assert mode == "scan", mode
    qc = q.reshape(b, nq, bq, *q.shape[2:]).swapaxes(0, 1)

    def step(_, xs):
        i, qb = xs
        o = _attn_chunk(qb, k, v, q_off + i * bq, causal=causal,
                        lengths=lengths)
        return None, o

    _, o = jax.lax.scan(step, None, (jnp.arange(nq), qc))
    return o.swapaxes(0, 1).reshape(b, s, *q.shape[2:])


# --------------------------------------------------------------------- MoE ----
def _moe_einsum(x, lp, cfg: TransformerConfig, mesh):
    """GShard-style einsum dispatch. x: (T, D) -> (T, D)."""
    mo = cfg.moe
    t, d = x.shape
    gs = min(mo.group_size, t)
    ng = t // gs
    xg = x.reshape(ng, gs, d)
    e, k = mo.n_experts, mo.top_k
    cap = max(4, int(math.ceil(k * gs * mo.capacity_factor / e)))

    def group(xs):
        logits = (xs @ lp["router"].astype(jnp.float32))        # (gs, E)
        probs = jax.nn.softmax(logits, axis=-1)
        topv, topi = jax.lax.top_k(probs, k)                    # (gs, k)
        topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
        oh = jax.nn.one_hot(topi, e, dtype=jnp.float32)         # (gs, k, E)
        flat = oh.reshape(gs * k, e)  # slot-major within token
        pos = jnp.cumsum(flat, axis=0) - flat                   # rank in queue
        pos = (pos * flat).sum(-1).reshape(gs, k).astype(jnp.int32)
        keep = (pos < cap).astype(jnp.float32)
        posh = jax.nn.one_hot(pos, cap, dtype=jnp.float32)      # (gs,k,C)
        disp = jnp.einsum("ske,skc,sk->sec", oh, posh, keep)    # (gs,E,C)
        comb = jnp.einsum("sec,sk,ske->sec", disp, topv * keep, oh)
        xe = jnp.einsum("sec,sd->ecd", disp.astype(cfg.compute_dtype), xs)
        up = jnp.einsum("ecd,edf->ecf", xe, lp["moe_up"])
        if cfg.gated_mlp:
            gate = jnp.einsum("ecd,edf->ecf", xe, lp["moe_gate"])
            h = _act(cfg)(gate) * up
        else:
            h = _act(cfg)(up)
        ye = jnp.einsum("ecf,efd->ecd", h, lp["moe_down"])
        out = jnp.einsum("sec,ecd->sd", comb.astype(cfg.compute_dtype), ye)
        # aux load-balancing loss (Switch): mean(prob_e * frac_e) * E
        frac = oh.sum(1).mean(0)
        aux = (probs.mean(0) * frac).sum() * e
        return out, aux

    if ng == 1:
        out, aux = group(xg[0])
        out = out[None]
    elif mo.vmap_groups:
        out, aux = jax.vmap(group)(xg)
        aux = aux.mean()
    else:
        out, aux = jax.lax.map(group, xg)
        aux = aux.mean()
    y = out.reshape(t, d)
    if mo.shared_experts:
        up = x @ lp["sh_up"]
        h = (_act(cfg)(x @ lp["sh_gate"]) * up if cfg.gated_mlp
             else _act(cfg)(up))
        y = y + h @ lp["sh_down"]
    return y, aux


def _moe_scatter(x, lp, cfg: TransformerConfig, mesh):
    """Sort/scatter dispatch: O(T·k·D) data movement, no dispatch einsum
    FLOPs — the beyond-paper variant for small-d_ff MoEs (granite-moe)."""
    mo = cfg.moe
    t, d = x.shape
    e, k = mo.n_experts, mo.top_k
    cap = max(4, int(math.ceil(k * t * mo.capacity_factor / e)))
    logits = (x @ lp["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    fe = topi.reshape(-1)                                   # (T*k,)
    fw = topv.reshape(-1)
    ft = jnp.repeat(jnp.arange(t), k)
    oh = jax.nn.one_hot(fe, e, dtype=jnp.int32)
    pos = (jnp.cumsum(oh, axis=0) - oh)
    pos = (pos * oh).sum(-1)                                # (T*k,)
    keep = pos < cap
    buf = jnp.zeros((e, cap, d), cfg.compute_dtype)
    buf = buf.at[jnp.where(keep, fe, e - 1),
                 jnp.where(keep, pos, cap - 1)].add(
        x[ft] * keep[:, None].astype(cfg.compute_dtype))
    up = jnp.einsum("ecd,edf->ecf", buf, lp["moe_up"])
    if cfg.gated_mlp:
        h = _act(cfg)(jnp.einsum("ecd,edf->ecf", buf, lp["moe_gate"])) * up
    else:
        h = _act(cfg)(up)
    ye = jnp.einsum("ecf,efd->ecd", h, lp["moe_down"])      # (E,C,D)
    gathered = ye[jnp.where(keep, fe, 0), jnp.where(keep, pos, 0)]
    contrib = gathered * (fw * keep)[:, None].astype(cfg.compute_dtype)
    y = jax.ops.segment_sum(contrib, ft, num_segments=t)
    frac = jax.nn.one_hot(topi, e).sum(1).mean(0)
    aux = (probs.mean(0) * frac).sum() * e
    if mo.shared_experts:
        up_sh = x @ lp["sh_up"]
        h = (_act(cfg)(x @ lp["sh_gate"]) * up_sh if cfg.gated_mlp
             else _act(cfg)(up_sh))
        y = y + h @ lp["sh_down"]
    return y, aux


# ------------------------------------------------------------------- layer ----
def _act(cfg):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[cfg.activation]


def _norm(lp, name, x, cfg):
    if cfg.norm == "nonparametric":
        return nonparametric_layernorm(x)
    return rmsnorm_apply({"scale": lp[f"{name}_norm"]}, x)


def layer_fwd(lp, x, cfg: TransformerConfig, mesh=None, *, positions=None,
              cache=None, attn_mode=None, return_kv=False):
    """One transformer layer. x: (B,S,D). cache: None or dict with
    k/v (B,T,Kv,Dh) + 'pos' (B,) for decode. Returns (y, aux, new_cache)."""
    b, s, d = x.shape
    kv, dh = cfg.n_kv_heads, cfg.dh
    g = cfg.n_heads // kv
    lp = jax.tree_util.tree_map(
        lambda a: a.astype(cfg.compute_dtype)
        if a.dtype != jnp.int8 else a, lp)
    xc = x.astype(cfg.compute_dtype)
    if positions is None:
        positions = jnp.arange(s)[None, :].astype(jnp.int32)

    h = _norm(lp, "attn", xc, cfg)
    q = (h @ lp["wq"]).reshape(b, s, kv, g, dh)
    k = (h @ lp["wk"]).reshape(b, s, kv, dh)
    v = (h @ lp["wv"]).reshape(b, s, kv, dh)
    # Attention-internal sharding policy (measured in §Perf A/B and the
    # post-opt sweep — non-divisible constraints trigger GSPMD
    # "involuntary full rematerialization"; sharding a contracted dim
    # (d_head) costs score psums that are negligible at decode but
    # catastrophic at prefill/train scale):
    #   decode (s==1): shard d_head — consistent with the cache specs.
    #   prefill/train: kv-shard if divisible; else group-shard; else
    #     repeat kv to flat heads (H=kv·g) when that divides; else
    #     replicate attention internals over tp (redundant attention
    #     compute beats terabytes of collectives).
    tp_n = max(dict(zip(mesh.axis_names, mesh.devices.shape)
                    ).get("model", 1) if mesh is not None else 1, 1)
    flat_g = None
    if cache is not None or s == 1:
        q = _cst(q, mesh, DP, None, None, None, TP)
        k = _cst(k, mesh, DP, None, None, TP)
        v = _cst(v, mesh, DP, None, None, TP)
    elif kv % tp_n == 0:
        q = _cst(q, mesh, DP, None, TP, None, None)
        k = _cst(k, mesh, DP, None, TP, None)
        v = _cst(v, mesh, DP, None, TP, None)
    elif g % tp_n == 0:
        q = _cst(q, mesh, DP, None, None, TP, None)
        k = _cst(k, mesh, DP, None, None, None)
        v = _cst(v, mesh, DP, None, None, None)
    elif (kv * g) % tp_n == 0:
        # flat-head form: repeat kv, attend as MHA sharded on H
        flat_g = g
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        q = q.reshape(b, s, kv * g, 1, dh)
        kv, g = kv * g, 1
        q = _cst(q, mesh, DP, None, TP, None, None)
        k = _cst(k, mesh, DP, None, TP, None)
        v = _cst(v, mesh, DP, None, TP, None)
    else:
        q = _cst(q, mesh, DP, None, None, None, None)
        k = _cst(k, mesh, DP, None, None, None)
        v = _cst(v, mesh, DP, None, None, None)
    q = _rope(q.reshape(b, s, kv * g, dh), positions,
              cfg.rope_theta).reshape(b, s, kv, g, dh)
    k = _rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        # decode: append into the cache at pos, attend with length mask
        pos = cache["pos"]                                    # (B,)

        def upd(c, u, p):
            return jax.vmap(lambda cc, uu, pp: jax.lax.dynamic_update_slice(
                cc, uu, (pp,) + (0,) * (cc.ndim - 1)))(c, u, p)

        if cfg.kv_cache_int8:
            def quant(u):                       # (B,s,kv,dh)
                sc = jnp.max(jnp.abs(u), axis=-1, keepdims=True) / 127.0
                sc = jnp.maximum(sc, 1e-8)
                qv = jnp.clip(jnp.round(u / sc), -127, 127
                              ).astype(jnp.int8)
                return qv, sc[..., 0].astype(jnp.float32)

            kq, ks_ = quant(k)
            vq, vs_ = quant(v)
            ck_q = upd(cache["k"], kq, pos)
            cv_q = upd(cache["v"], vq, pos)
            cks = upd(cache["k_scale"], ks_, pos)
            cvs = upd(cache["v_scale"], vs_, pos)
            ck = (ck_q.astype(cfg.compute_dtype)
                  * cks[..., None].astype(cfg.compute_dtype))
            cv = (cv_q.astype(cfg.compute_dtype)
                  * cvs[..., None].astype(cfg.compute_dtype))
            new_cache = {"k": ck_q, "v": cv_q, "k_scale": cks,
                         "v_scale": cvs, "pos": pos + s}
        else:
            ck = upd(cache["k"], k, pos)
            cv = upd(cache["v"], v, pos)
            new_cache = {"k": ck, "v": cv, "pos": pos + s}
        o = _attn_chunk(q, ck, cv, 0, causal=False, lengths=pos + s)
    else:
        o = attention(q, k, v, cfg, causal=True, mode=attn_mode)
        if return_kv:
            # post-RoPE k/v, matching decode convention; under flat-head
            # repeat, recover the unrepeated kv heads (every flat_g-th)
            if flat_g:
                new_cache = (k[:, :, ::flat_g], v[:, :, ::flat_g])
            else:
                new_cache = (k, v)
    o = o.reshape(b, s, kv * g * dh)
    xc = xc + (o @ lp["wo"])
    xc = _cst(xc, mesh, DP, TP if cfg.seq_parallel and s > 1 else None,
              None)

    h = _norm(lp, "ffn", xc, cfg)
    aux = jnp.float32(0.0)
    if cfg.moe is None:
        up = h @ lp["w_up"]
        if cfg.gated_mlp:
            ff = _act(cfg)(h @ lp["w_gate"]) * up
        else:
            ff = _act(cfg)(up)
        ff = _cst(ff, mesh, DP, None, TP)
        y = ff @ lp["w_down"]
    else:
        fn = _moe_scatter if cfg.moe.dispatch == "scatter" else _moe_einsum
        y2d, aux = fn(h.reshape(b * s, d), lp, cfg, mesh)
        y = y2d.reshape(b, s, d)
    xc = xc + y
    xc = _cst(xc, mesh, DP, TP if cfg.seq_parallel and s > 1 else None,
              None)
    return xc.astype(x.dtype), aux, new_cache


# -------------------------------------------------------------- full model ----
def forward(params, tokens, cfg: TransformerConfig, mesh=None):
    """tokens: (B,S) -> final hidden states (B,S,D) + aux loss."""
    x = jnp.take(params["embed"], tokens, axis=0
                 ).astype(cfg.compute_dtype)
    x = _cst(x, mesh, DP, TP if cfg.seq_parallel else None, None)

    def body(carry, lp):
        x, aux = carry
        y, a, _ = layer_fwd(lp, x, cfg, mesh)
        return (y, aux + a), None

    step = body
    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots" else None)
        step = jax.checkpoint(body, prevent_cse=False, policy=policy)
    if cfg.unroll_layers:
        carry = (x, jnp.float32(0.0))
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            carry, _ = step(carry, lp)
        x, aux = carry
    else:
        (x, aux), _ = jax.lax.scan(step, (x, jnp.float32(0.0)),
                                   params["layers"])
    if cfg.norm == "nonparametric":
        x = nonparametric_layernorm(x)
    else:
        x = rmsnorm_apply({"scale": params["final_norm"].astype(
            cfg.compute_dtype)}, x)
    return x, aux / cfg.n_layers


def loss_fn(params, batch, cfg: TransformerConfig, mesh=None):
    """Chunked cross-entropy; batch: {'tokens','labels'} (B,S)."""
    x, aux = forward(params, batch["tokens"], cfg, mesh)
    head = params["lm_head"].astype(cfg.compute_dtype)
    b, s, d = x.shape
    ck = min(cfg.loss_chunk, s)
    nc = s // ck

    def chunk(carry, xs):
        xb, yb = xs                                     # (B,ck,D), (B,ck)
        logits = (xb @ head).astype(jnp.float32)
        logits = _cst(logits, mesh, DP, None, TP)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yb[..., None], -1)[..., 0]
        return carry + (logz - gold).sum(), None

    xc = x.reshape(b, nc, ck, d).swapaxes(0, 1)
    yc = batch["labels"].reshape(b, nc, ck).swapaxes(0, 1)
    tot, _ = jax.lax.scan(chunk, jnp.float32(0.0), (xc, yc))
    ce = tot / (b * s)
    return ce + 0.01 * aux, {"ce": ce, "aux": aux}


def prefill(params, tokens, cfg: TransformerConfig, mesh=None):
    """Process a full prompt: returns (last-position logits (B,V), cache).

    The KV cache is emitted as scan ys — (L, B, S, Kv, Dh) — ready for
    ``decode_step``."""
    b, s = tokens.shape
    kv, dh = cfg.n_kv_heads, cfg.dh
    x = jnp.take(params["embed"], tokens, axis=0
                 ).astype(cfg.compute_dtype)
    x = _cst(x, mesh, DP, None, None)
    positions = jnp.arange(s)[None, :].astype(jnp.int32)

    def body(x, lp):
        y, _, (k, v) = layer_fwd(lp, x, cfg, mesh, positions=positions,
                                 return_kv=True)
        return y, (k, v)

    if cfg.unroll_layers:
        ks, vs = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            x, (k, v) = body(x, lp)
            ks.append(k)
            vs.append(v)
        ks, vs = jnp.stack(ks), jnp.stack(vs)
    else:
        x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    if cfg.norm == "nonparametric":
        x = nonparametric_layernorm(x)
    else:
        x = rmsnorm_apply({"scale": params["final_norm"].astype(
            cfg.compute_dtype)}, x)
    logits = (x[:, -1] @ params["lm_head"].astype(cfg.compute_dtype))
    cache = {"k": ks, "v": vs,
             "pos": jnp.full((cfg.n_layers, b), s, jnp.int32)}
    return logits.astype(jnp.float32), cache


# ------------------------------------------------------------------ decode ----
def init_cache(cfg: TransformerConfig, batch: int, max_len: int,
               dtype=None):
    dtype = dtype or cfg.compute_dtype
    kv, dh, L = cfg.n_kv_heads, cfg.dh, cfg.n_layers
    if cfg.kv_cache_int8:
        return {
            "k": jnp.zeros((L, batch, max_len, kv, dh), jnp.int8),
            "v": jnp.zeros((L, batch, max_len, kv, dh), jnp.int8),
            "k_scale": jnp.zeros((L, batch, max_len, kv), jnp.float32),
            "v_scale": jnp.zeros((L, batch, max_len, kv), jnp.float32),
            "pos": jnp.zeros((L, batch), jnp.int32),
        }
    return {
        "k": jnp.zeros((L, batch, max_len, kv, dh), dtype),
        "v": jnp.zeros((L, batch, max_len, kv, dh), dtype),
        "pos": jnp.zeros((L, batch), jnp.int32),
    }


def cache_specs(cfg: TransformerConfig, *, seq_shard: bool = False):
    """PartitionSpecs for the KV cache. ``seq_shard=True`` shards the
    sequence axis over dp (flash-decoding style; for long_500k batch=1)."""
    if seq_shard:
        kvspec = P(None, None, DP, TP, None)
    else:
        kvspec = P(None, DP, None, TP, None)
    return {"k": kvspec, "v": kvspec, "pos": P(None, None)}


def decode_step(params, cache, tokens, cfg: TransformerConfig, mesh=None):
    """tokens: (B, 1) -> (logits (B,V), new_cache). Scan over layers."""
    b = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0
                 ).astype(cfg.compute_dtype)          # (B,1,D)
    positions = cache["pos"][0][:, None]              # (B,1) absolute pos

    def body(x, layer):
        lp, ck = layer
        y, _, nc = layer_fwd(lp, x, cfg, mesh, positions=positions,
                             cache=ck)
        return y, nc

    if cfg.unroll_layers:
        ncs = []
        for i in range(cfg.n_layers):
            sl = lambda a: a[i]  # noqa: E731
            x, nc = body(x, (jax.tree_util.tree_map(sl, params["layers"]),
                             jax.tree_util.tree_map(sl, cache)))
            ncs.append(nc)
        new_cache = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *ncs)
    else:
        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    if cfg.norm == "nonparametric":
        x = nonparametric_layernorm(x)
    else:
        x = rmsnorm_apply({"scale": params["final_norm"].astype(
            cfg.compute_dtype)}, x)
    logits = (x[:, 0] @ params["lm_head"].astype(cfg.compute_dtype))
    return logits.astype(jnp.float32), new_cache


# per-layer decode for the roofline composition
def layer_decode(lp, x, cache_l, cfg: TransformerConfig, mesh=None):
    positions = cache_l["pos"][:, None]
    return layer_fwd(lp, x, cfg, mesh, positions=positions, cache=cache_l)


def model_flops(cfg: TransformerConfig, batch: int, seq: int,
                *, training: bool, decode: bool = False,
                kv_len: int = 0) -> float:
    """Analytic MODEL_FLOPS: 6·N·D (dense) / 6·N_active·D (MoE) style,
    attention added explicitly."""
    d, dh = cfg.d_model, cfg.dh
    tok = batch * seq
    per_layer = 2 * d * (cfg.n_heads + 2 * cfg.n_kv_heads) * dh \
        + 2 * cfg.n_heads * dh * d
    if cfg.moe is None:
        per_layer += 2 * d * cfg.d_ff * (3 if cfg.gated_mlp else 2)
    else:
        per_layer += 2 * d * cfg.d_ff * (3 if cfg.gated_mlp else 2) \
            * (cfg.moe.top_k + cfg.moe.shared_experts)
        per_layer += 2 * d * cfg.moe.n_experts  # router
    attn_ctx = kv_len if decode else seq / 2  # causal average
    attn = 2 * 2 * cfg.n_heads * dh * attn_ctx
    embed_head = 2 * d * cfg.vocab  # lm head matmul (embed is gather)
    fwd = tok * (cfg.n_layers * (per_layer + attn) + embed_head)
    return fwd * (3.0 if training else 1.0)
