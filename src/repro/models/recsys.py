"""MIND: Multi-Interest Network with Dynamic routing (arXiv:1904.08030).

Config: embed_dim=64, n_interests=4, capsule_iters=3, multi-interest
interaction. Pipeline:

  item/user-tag embedding lookup      (the recsys hot path — JAX has no
      EmbeddingBag, so ``embedding_bag`` here implements it with
      ``jnp.take`` + ``jax.ops.segment_sum``, multi-hot with per-sample
      weights, exactly as the taxonomy prescribes)
  → B2I dynamic capsule routing (3 iterations, squash nonlinearity,
      behavior-masked, softmax over capsules)
  → label-aware attention (training; pow-2 sharpened)
  → sampled-softmax over in-batch negatives (training)
  → retrieval scoring: max over interests of capsule·candidate
      (``retrieval_cand``: one user vs 10⁶ candidates — a single batched
      matmul, never a loop).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import DP, TP
from repro.nn import dense_init, dense_apply, normal_init


@dataclasses.dataclass(frozen=True)
class MINDConfig:
    name: str = "mind"
    n_items: int = 1_000_000
    n_user_tags: int = 100_000
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    hist_len: int = 50
    tag_bag: int = 16
    label_pow: float = 2.0


def init(key, cfg: MINDConfig):
    ks = jax.random.split(key, 5)
    d = cfg.embed_dim
    return {
        "item_emb": normal_init(ks[0], (cfg.n_items, d), std=0.02),
        "tag_emb": normal_init(ks[1], (cfg.n_user_tags, d), std=0.02),
        "bilinear_s": normal_init(ks[2], (d, d), std=0.05),
        "proj": dense_init(ks[3], 2 * d, d),
    }


PARAM_RULES = [
    (r"item_emb", P(TP, None)),
    (r"tag_emb", P(TP, None)),
    (r"bilinear_s", P(None, None)),
    (r"proj/w", P(DP, TP)),
]


# ---------------------------------------------------------- embedding bag ----
def embedding_bag(table, ids, *, weights=None, segment_ids=None,
                  num_segments=None, mode="mean"):
    """EmbeddingBag: ragged multi-hot gather-reduce.

    ids: (L,) flat indices into table; segment_ids: (L,) bag assignment
    (monotonic not required); weights: optional per-sample weights.
    Padding convention: weight 0 (or id < 0 -> treated as weight 0).
    """
    valid = (ids >= 0).astype(table.dtype)
    w = valid if weights is None else weights * valid
    rows = jnp.take(table, jnp.maximum(ids, 0), axis=0)       # (L, D)
    rows = rows * w[:, None]
    s = jax.ops.segment_sum(rows, segment_ids, num_segments=num_segments)
    if mode == "sum":
        return s
    cnt = jax.ops.segment_sum(w, segment_ids, num_segments=num_segments)
    if mode == "mean":
        return s / jnp.maximum(cnt, 1.0)[:, None]
    raise ValueError(mode)


# --------------------------------------------------------- capsule routing ----
def _squash(z, axis=-1, eps=1e-9):
    n2 = jnp.sum(z * z, axis=axis, keepdims=True)
    return (n2 / (1.0 + n2)) * z / jnp.sqrt(n2 + eps)


def extract_interests(params, behav_ids, behav_mask, cfg: MINDConfig):
    """B2I dynamic routing. behav_ids: (B, H) -> capsules (B, K, D)."""
    b, h = behav_ids.shape
    k, d = cfg.n_interests, cfg.embed_dim
    e = jnp.take(params["item_emb"], jnp.maximum(behav_ids, 0), axis=0)
    e = e * behav_mask[..., None]
    e_hat = e @ params["bilinear_s"]                             # (B,H,D)
    e_hat_sg = jax.lax.stop_gradient(e_hat)   # paper: routing w/o gradient
    # deterministic per-(capsule, position) init logits
    key = jax.random.PRNGKey(17)
    blogit = jnp.broadcast_to(
        jax.random.normal(key, (1, k, h)), (b, k, h))

    # python loop (2 iters): keeps cost_analysis exact (no scan body)
    for _ in range(cfg.capsule_iters - 1):
        w = jax.nn.softmax(blogit, axis=1)                       # over K
        w = w * behav_mask[:, None, :]
        u = _squash(jnp.einsum("bkh,bhd->bkd", w, e_hat_sg))
        blogit = blogit + jnp.einsum("bkd,bhd->bkh", u, e_hat_sg)
    # final iteration WITH gradient to the embeddings
    w = jax.nn.softmax(blogit, axis=1) * behav_mask[:, None, :]
    u = _squash(jnp.einsum("bkh,bhd->bkd", w, e_hat))
    return u                                                     # (B,K,D)


def user_capsules(params, batch, cfg: MINDConfig):
    """Interests conditioned on profile tags (embedding-bag side input)."""
    u = extract_interests(params, batch["behav_ids"],
                          batch["behav_mask"], cfg)              # (B,K,D)
    b = u.shape[0]
    tags = embedding_bag(
        params["tag_emb"], batch["tag_ids"].reshape(-1),
        segment_ids=jnp.repeat(jnp.arange(b), cfg.tag_bag),
        num_segments=b, mode="mean")                             # (B,D)
    tagk = jnp.broadcast_to(tags[:, None, :], u.shape)
    mixed = dense_apply(params["proj"],
                        jnp.concatenate([u, tagk], axis=-1),
                        activation=jax.nn.relu)
    return mixed                                                 # (B,K,D)


# ---------------------------------------------------------------- training ----
def label_aware_attention(u, target_e, cfg: MINDConfig):
    """u: (B,K,D), target_e: (B,D) -> user vector (B,D)."""
    scores = jnp.einsum("bkd,bd->bk", u, target_e)
    attn = jax.nn.softmax(cfg.label_pow * scores, axis=-1)
    return jnp.einsum("bk,bkd->bd", attn, u)


def loss_fn(params, batch, cfg: MINDConfig, mesh=None):
    """In-batch sampled softmax. batch: behav_ids (B,H), behav_mask,
    tag_ids (B,tag_bag), target (B,)."""
    u = user_capsules(params, batch, cfg)
    tgt = jnp.take(params["item_emb"], batch["target"], axis=0)  # (B,D)
    uv = label_aware_attention(u, tgt, cfg)                      # (B,D)
    logits = (uv @ tgt.T).astype(jnp.float32)                    # (B,B)
    labels = jnp.arange(uv.shape[0])
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.take_along_axis(logp, labels[:, None], -1)[:, 0]
    loss = ce.mean()
    acc = (logits.argmax(-1) == labels).mean()
    return loss, {"loss": loss, "in_batch_acc": acc}


# ----------------------------------------------------------------- serving ----
def score_candidates(params, batch, cfg: MINDConfig):
    """Multi-interest retrieval scoring (serve shapes).

    batch: behav_ids (B,H), behav_mask, tag_ids, cand_ids (B, C) or a
    shared candidate set (C,). Returns (B, C) scores = max over interests.
    """
    u = user_capsules(params, batch, cfg)                        # (B,K,D)
    cand = batch["cand_ids"]
    ce = jnp.take(params["item_emb"], cand, axis=0)              # (C,D)/(B,C,D)
    if ce.ndim == 2:
        scores = jnp.einsum("bkd,cd->bkc", u, ce)
    else:
        scores = jnp.einsum("bkd,bcd->bkc", u, ce)
    return scores.max(axis=1)                                    # (B,C)


def serve_topk(params, batch, cfg: MINDConfig, *, k: int = 100):
    scores = score_candidates(params, batch, cfg)
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx
