"""Deployment pipeline: run the flow's passes and emit an executable.

``deploy(graph, Requirements)`` mirrors the paper's design flow end-to-end
and supports the three evaluated design points:

  ① partitioned baseline — no fusion, P=1, looped kernels, one compiled
    executable *per pipeline segment* (each FPGA↔AIE boundary is a real
    dispatch boundary — reproducing the heterogeneous overhead that made
    design ① slower than the FPGA-only baseline);
  ② + operator fusion + spatial parallelization (P search);
  ③ + kernel-level optimizations (flattened kernels, retile cancellation,
    int8 chain fusion) and a single whole-pipeline executable.

Precision: 'mixed' applies the paper's policy (bf16 boundary segments,
int8 interior with per-channel weight scales and calibrated activation
scales); int8 matmuls use exact integer arithmetic (the same math the
Pallas int8 kernel executes on TPU — bit-agreement is tested).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import caloclusternet as ccn
from repro.core.graph_ir import Graph
from repro.core.passes.fusion import fuse
from repro.core.passes.kernel_opt import kernel_optimize
from repro.core.passes.mapping import LANE, map_templates
from repro.core.passes.parallelize import (Requirements, op_cost,
                                           parallelize, segment_time)
from repro.core.passes.partition import partition, segments
from repro.core.quantization import (activation_scale, apply_precision_policy,
                                     quantize_weight)
from repro.kernels import ops as kops
from repro.launch import mesh as hw


class QTensor(NamedTuple):
    """int8 activation + its (static) dequantization scale."""
    q: jax.Array
    scale: float


def _as_fp(v, dtype=jnp.float32):
    if isinstance(v, QTensor):
        return (v.q.astype(jnp.float32) * v.scale).astype(dtype)
    return v.astype(dtype)


def _pad_last(v, mult):
    d = v.shape[-1]
    r = (-d) % mult
    if r == 0:
        return v
    pw = [(0, 0)] * v.ndim
    pw[-1] = (0, r)
    return jnp.pad(v, pw)


# ---------------------------------------------------------------- executor ----
class _Executor:
    def __init__(self, graph: Graph, req: Requirements, backend: str):
        self.g = graph
        self.req = req
        self.backend = backend
        self.cfg = graph.meta.get("config")

    # -- single-op execution ------------------------------------------------
    def run_op(self, op, vals, feeds, *, force_fp=False, record=None):
        t = op.op_type
        prec = "fp" if force_fp else op.precision
        if t == "input":
            out = feeds[op.attrs["feature"]]
        elif t in ("dense", "linear"):
            out = self._dense(op, vals[0], prec)
        elif t == "relu":
            v = vals[0]
            out = (QTensor(jnp.maximum(v.q, 0), v.scale)
                   if isinstance(v, QTensor) else jnp.maximum(v, 0.0))
        elif t == "concat":
            if (all(isinstance(v, QTensor) for v in vals)
                    and len({v.scale for v in vals}) == 1):
                out = QTensor(jnp.concatenate([v.q for v in vals], -1),
                              vals[0].scale)
            else:
                out = jnp.concatenate([_as_fp(v) for v in vals], -1)
        elif t == "slice":
            st, sz = op.attrs["start"], op.attrs["size"]
            v = vals[0]
            if isinstance(v, QTensor):
                out = QTensor(v.q[..., st:st + sz], v.scale)
            else:
                out = v[..., st:st + sz]
        elif t == "retile":
            v = vals[0]
            if op.attrs["to"] == "lane128":
                out = (QTensor(_pad_last(v.q, LANE), v.scale)
                       if isinstance(v, QTensor) else _pad_last(v, LANE))
            else:
                d = op.out_dim
                out = (QTensor(v.q[..., :d], v.scale)
                       if isinstance(v, QTensor) else v[..., :d])
        elif t == "gravnet_aggregate":
            out = self._gravnet(op, vals, prec)
        elif t == "knn_build":
            out = self._knn_build(op, vals)
        elif t == "knn_aggregate":
            out = self._knn_aggregate(op, vals, prec)
        elif t == "gravnet_block":
            out = self._gravnet_block(op, vals, prec)
        elif t == "attention":
            out = self._attention(op, vals)
        elif t == "gather_edge":
            out = self._gather_edge(op, vals)
        elif t == "edge_aggregate":
            out = self._edge_aggregate(op, vals)
        elif t == "eltwise":
            out = self._eltwise(op, vals)
        elif t == "batchnorm":
            out = self._batchnorm(op, vals)
        elif t == "cps":
            out = self._cps(op, vals)
        elif t == "output":
            names = op.attrs["head_names"]
            out = {n: _as_fp(vals[i]) for i, n in enumerate(names)}
            if len(vals) > len(names):  # cps result dict
                out["cps"] = vals[len(names)]
        else:
            from repro.core.op_registry import op_spec
            hint = ("registered but not lowered by this executor"
                    if op_spec(t) is not None else "unknown op type")
            raise ValueError(f"no executor for op {op.name!r} "
                             f"({t!r}: {hint})")
        # knn_build's value is an (idx, d2) index tuple, not an
        # activation — nothing to record (and _as_fp would reject it)
        if record is not None and t not in ("cps", "output", "input",
                                            "knn_build"):
            record[op.name] = float(jnp.max(jnp.abs(_as_fp(out))))
        return out

    def _dense(self, op, x, prec):
        w = op.params["w"]
        b = op.params.get("b")
        act = op.attrs.get("activation", "none")
        variant = op.attrs_opt.get("variant", "looped")
        lead = None
        if prec == "int8" and "w_q" in (op.params or {}):
            if isinstance(x, QTensor):
                xq, in_scale = x.q, x.scale
            else:
                in_scale = op.attrs["in_scale"]
                xq = jnp.clip(jnp.round(x / in_scale), -127, 127
                              ).astype(jnp.int8)
            lead = xq.shape[:-1]
            xq2 = xq.reshape(-1, xq.shape[-1])
            wq, wscale = op.params["w_q"], op.params["w_scale"]
            if xq2.shape[-1] > wq.shape[0]:  # lane128-padded input
                wq = jnp.pad(wq, ((0, xq2.shape[-1] - wq.shape[0]), (0, 0)))
            emit8 = op.attrs_opt.get("emit_int8", False)
            out_scale = op.attrs.get("act_scale", 1.0)
            # autotuned block shapes bind here only when the config was
            # actually searched ('tuned'); the heuristic's fp-oriented
            # blocks never silently replace the int8 wrapper defaults
            blocks = {}
            if op.attrs_opt.get("tuned"):
                blocks = {"bm": op.attrs_opt.get("bm", 128),
                          "bn": op.attrs_opt.get("bn", 128),
                          "bk": op.attrs_opt.get("bk", 512)}
            y = kops.fused_dense_int8(
                xq2, wq, b, jnp.asarray(in_scale, jnp.float32).reshape(1, 1),
                wscale,
                activation=act, out_dtype=jnp.int8 if emit8 else jnp.float32,
                out_scale=out_scale, backend=self.backend, **blocks)
            y = y.reshape(*lead, y.shape[-1])
            return QTensor(y, out_scale) if emit8 else y
        # float path (fp/bf16 or uncalibrated int8 falls back to fp)
        dt = jnp.bfloat16 if prec == "bf16" else jnp.float32
        xf = _as_fp(x, dt)
        if xf.shape[-1] > w.shape[0]:   # lane128-padded input
            w = jnp.pad(w, ((0, xf.shape[-1] - w.shape[0]), (0, 0)))
        kw = dict(activation=act, variant=variant,
                  bm=op.attrs_opt.get("bm", 128),
                  bn=op.attrs_opt.get("bn", 128),
                  bk=op.attrs_opt.get("bk", 512), backend=self.backend)
        wd = w.astype(dt)
        bd = None if b is None else b.astype(dt)
        if xf.ndim == 3 and variant == "looped":
            # row-packs the micro-batch into the SAME (B·hits, d) looped
            # launch the autotuner times for this op's cache key
            return kops.fused_dense_batched(xf, wd, bd, **kw)
        # flattened stays row-packed into one whole-operand cell — the
        # problem shape the tuner measured; the grid-(B,) per-event form
        # is for callers wanting per-event cell residency (see
        # docs/kernels.md)
        lead = xf.shape[:-1]
        y = kops.fused_dense(xf.reshape(-1, xf.shape[-1]), wd, bd, **kw)
        return y.reshape(*lead, y.shape[-1])

    def _gravnet(self, op, vals, prec):
        s, f, mask = vals
        ds, df = op.attrs["d_s"], op.attrs["d_f"]
        sf = _as_fp(s)[..., :ds]
        ff = _as_fp(f)[..., :df]
        # one batched launch for the whole micro-batch (leading event
        # grid dim, per-event masking keeps selection block-diagonal)
        agg = kops.gravnet_aggregate_batched(
            sf, ff, mask, k=op.attrs["k"], scale=op.attrs["scale"],
            bm=op.attrs_opt.get("bm"), backend=self.backend)
        if prec == "int8" and "act_scale" in op.attrs:
            # model 8-bit FPGA-fabric arithmetic: snap to the int8 grid
            sc = op.attrs["act_scale"]
            agg = jnp.clip(jnp.round(agg / sc), -127, 127) * sc
        return agg

    def _knn_build(self, op, vals):
        """Ragged neighbor selection over bin-packed events: one
        batched launch per micro-batch of bins. Returns the (idx, d2)
        tuple the paired knn_aggregate consumes."""
        s, segids = vals
        sf = _as_fp(s)[..., :op.attrs["d_s"]]   # lane128-padded producer
        return kops.knn_build_batched(
            sf, segids.astype(jnp.int32), k=op.attrs["k"],
            bm=op.attrs_opt.get("bm"), backend=self.backend)

    def _knn_aggregate(self, op, vals, prec):
        f, knn = vals
        idx, d2 = knn
        ff = _as_fp(f)[..., :op.attrs["d_f"]]
        agg = kops.knn_aggregate_batched(
            ff, idx, d2, scale=op.attrs["scale"],
            bm=op.attrs_opt.get("bm"), backend=self.backend)
        if prec == "int8" and "act_scale" in op.attrs:
            # mirror gravnet_aggregate's 8-bit fabric arithmetic
            sc = op.attrs["act_scale"]
            agg = jnp.clip(jnp.round(agg / sc), -127, 127) * sc
        return agg

    def _gravnet_block(self, op, vals, prec="fp"):
        """One fused GravNet block — a single megakernel launch for the
        whole micro-batch. A calibrated int8 block (``ws_q`` present)
        launches the quantized megakernel with its baked scales; the fp
        path (and any uncalibrated int8 block) runs the f32 kernel."""
        x, mask = vals
        if op.attrs.get("ragged"):
            # raggedized block: the mask slot carries segment ids and
            # the launch covers a micro-batch of packed bins
            p = op.params
            xf = _as_fp(x)[..., :p["ws"].shape[0]]
            return kops.gravnet_block_ragged(
                xf, mask.astype(jnp.int32), p["ws"], p["bs"], p["wf"],
                p["bf"], p["wo"], p["bo"], k=op.attrs["k"],
                scale=op.attrs["scale"],
                activation=op.attrs.get("activation", "none"),
                concat_x=op.attrs.get("concat_x", True),
                bm=op.attrs_opt.get("bm"), backend=self.backend)
        p = op.params
        dh = p["ws"].shape[0]
        xf = _as_fp(x)[..., :dh]        # lane128-padded producer
        kw = {kn: op.attrs_opt[kn] for kn in ("bm", "bn", "bk")
              if kn in op.attrs_opt}
        if prec == "int8" and "ws_q" in p:
            # f32 in, f32 out: the kernel quantizes on entry with the
            # producer's calibrated scale and dequantizes the epilogue,
            # matching the unfused chain's boundary arithmetic exactly
            return kops.gravnet_block_int8_batched(
                xf, mask, p["ws_q"], p["bs"], p["wf_q"], p["bf"],
                p["wo_q"], p["bo"], p["ws_scale"], p["wf_scale"],
                p["wo_scale"], x_scale=op.attrs["in_scale"],
                agg_scale=op.attrs["agg_scale"],
                h_scale=op.attrs["h_scale"], k=op.attrs["k"],
                scale=op.attrs["scale"],
                activation=op.attrs.get("activation", "none"),
                concat_x=op.attrs.get("concat_x", True),
                backend=self.backend, **kw)
        return kops.gravnet_block_batched(
            xf, mask, p["ws"], p["bs"], p["wf"], p["bf"], p["wo"],
            p["bo"], k=op.attrs["k"], scale=op.attrs["scale"],
            activation=op.attrs.get("activation", "none"),
            concat_x=op.attrs.get("concat_x", True),
            backend=self.backend, **kw)

    def _gather_edge(self, op, vals):
        """Endpoint gather by the edge list: x:(B,N,d), ei:(B,2,E) ->
        (B,E,d). Data-dependent, so it stays on the xla target."""
        x, ei = vals
        d = op.out_dim
        xf = _as_fp(x)[..., :d]         # lane128-padded producer
        idx = ei[:, 0 if op.attrs["endpoint"] == "src" else 1, :]
        return jnp.take_along_axis(xf, idx[:, :, None].astype(jnp.int32),
                                   axis=1)

    def _edge_aggregate(self, op, vals):
        """Masked segment-sum/mean of per-edge messages into nodes —
        one batched one-hot-incidence kernel launch per micro-batch."""
        msgs, ei = vals[0], vals[1]
        mask = _as_fp(vals[2]) if len(vals) > 2 else None
        d = op.out_dim
        mf = _as_fp(msgs)[..., :d]
        n_nodes = int(op.attrs.get("n_nodes") or self.req.n_hits)
        return kops.edge_aggregate_batched(
            mf, ei.astype(jnp.int32), n_nodes, mask,
            reduce=op.attrs.get("reduce", "sum"),
            bm=op.attrs_opt.get("bm"), be=op.attrs_opt.get("be"),
            backend=self.backend)

    def _eltwise(self, op, vals):
        """N-ary elementwise algebra; ``fn`` picks the operation."""
        fn = op.attrs["fn"]
        d = op.out_dim
        if fn == "mask":                # x:(B,R,d) * mask:(B,R)
            x, m = _as_fp(vals[0])[..., :d], _as_fp(vals[1])
            return x * m[..., None]
        xs = [_as_fp(v)[..., :d] for v in vals]
        if fn == "add":
            y = xs[0]
            for v in xs[1:]:
                y = y + v
            return y
        if fn == "mul":
            y = xs[0]
            for v in xs[1:]:
                y = y * v
            return y
        if fn == "div":
            return xs[0] / xs[1]
        if fn == "sigmoid":
            return jax.nn.sigmoid(xs[0])
        if fn == "relu":
            return jnp.maximum(xs[0], 0.0)
        if fn == "add_const":
            return xs[0] + op.attrs["const"]
        if fn == "l2norm":
            return xs[0] / jnp.maximum(
                jnp.linalg.norm(xs[0], axis=-1, keepdims=True), 1e-6)
        raise ValueError(f"{op.name}: unknown eltwise fn {fn!r}")

    def _batchnorm(self, op, vals):
        """Masked per-event batch normalization (the benchmarking-gnns
        training-mode statistics, vectorized over the micro-batch):
        x:(B,R,d), mask:(B,R)."""
        x, mask = vals
        d = op.out_dim
        xf = _as_fp(x)[..., :d]
        m = _as_fp(mask)[..., None]
        n = jnp.maximum(m.sum(axis=1, keepdims=True), 1.0)
        mu = (xf * m).sum(axis=1, keepdims=True) / n
        var = (((xf - mu) ** 2) * m).sum(axis=1, keepdims=True) / n
        eps = op.attrs.get("eps", 1e-5)
        return (xf - mu) * jax.lax.rsqrt(var + eps) * m

    def _attention(self, op, vals):
        d = op.out_dim
        q, k_, v = (_as_fp(t)[..., :d] for t in vals)
        kw = {kn: op.attrs_opt[kn] for kn in ("bq", "bk")
              if kn in op.attrs_opt}
        return kops.flash_attention(q, k_, v,
                                    causal=op.attrs.get("causal", True),
                                    backend=self.backend, **kw)

    def _cps(self, op, vals):
        names = op.attrs["head_names"]
        hv = {n: _as_fp(vals[i]) for i, n in enumerate(names)}
        if op.attrs.get("ragged"):
            return self._cps_ragged(hv, vals[-2], vals[-1])
        mask = vals[-1]
        outputs = {
            "beta_logit": hv["beta"][..., 0],
            "coords": hv["coords"],
            "energy": hv["energy"][..., 0],
        }
        return ccn.cps(outputs, mask, self.cfg)

    def _cps_ragged(self, hv, segids, slots):
        """Scatter packed rows back to per-event (E, n_hits) layout,
        then run the unchanged per-event condensation. JAX *wraps*
        negative scatter indices even under ``mode="drop"``, so pad
        rows (segid −1) are first remapped to the out-of-bounds index
        ``e_max`` — which drop then discards."""
        e_max = int(self.g.meta["ragged_max_events"])
        n = self.req.n_hits
        seg = segids.reshape(-1).astype(jnp.int32)
        slot = slots.reshape(-1).astype(jnp.int32)
        seg = jnp.where(seg < 0, e_max, seg)

        def scatter(h):
            h2 = h.reshape(-1, *h.shape[2:])
            out = jnp.zeros((e_max, n, *h2.shape[1:]), h2.dtype)
            return out.at[seg, slot].set(h2, mode="drop")

        mask = jnp.zeros((e_max, n), jnp.float32
                         ).at[seg, slot].set(1.0, mode="drop")
        outputs = {
            "beta_logit": scatter(hv["beta"])[..., 0],
            "coords": scatter(hv["coords"]),
            "energy": scatter(hv["energy"])[..., 0],
        }
        return ccn.cps(outputs, mask, self.cfg)

    # -- full-graph execution -------------------------------------------------
    def run(self, feeds, *, force_fp=False, record=None):
        env: dict[str, Any] = {}
        result = None
        for op in self.g:
            vals = [env[i] for i in op.inputs]
            env[op.name] = self.run_op(op, vals, feeds, force_fp=force_fp,
                                       record=record)
            if op.op_type == "output":
                result = env[op.name]
        return result, env


# ---------------------------------------------------------- compiled object ----
class CompiledPipeline:
    def __init__(self, graph: Graph, req: Requirements, backend: str,
                 *, batch: int = 1):
        self.graph = graph
        self.req = req
        self.backend = backend
        self.segments = segments(graph)
        par = graph.meta.get("parallelization",
                             {"P_mxu": 1, "P_xla": 1, "microbatch": 1})
        # batch > 1 pins a *batch-packed* executable: the whole
        # micro-batch runs through every segment in one launch (no
        # P-chunking), matching the batched kernel grid shapes that
        # kernel_optimize(batch=...) keyed the tuning cache with.
        self.batch_packed = batch > 1
        self.microbatch = batch if self.batch_packed else par["microbatch"]
        self.par = par
        self._ex = _Executor(graph, req, backend)
        self._fused = bool(graph.meta.get("fuse_pipeline"))
        self._build()

    # build jitted executables --------------------------------------------
    def _build(self):
        ex = self._ex
        g = self.graph

        def seg_needs(seg):
            names = set(seg["ops"])
            ins, outs = [], []
            for op in g:
                if op.name in names:
                    ins += [i for i in op.inputs if i not in names
                            and i not in ins]
                else:
                    outs += [i for i in op.inputs
                             if i in names and i not in outs]
            # final outputs
            for op in g.outputs():
                if op.name in names and op.name not in outs:
                    outs.append(op.name)
            return ins, outs

        def make_seg_fn(seg, ins, outs):
            ops_ = [g[n] for n in seg["ops"]]
            p_seg = ops_[0].attrs_opt.get("P", 1)

            def body(env_in, feeds):
                env = dict(env_in)
                for op in ops_:
                    vals = [env[i] if i in env else None for i in op.inputs]
                    env[op.name] = ex.run_op(op, vals, feeds)
                return {o: env[o] for o in outs}

            mb = self.microbatch

            def fn(env_in, feeds):
                if self.batch_packed or p_seg >= mb or mb == 1:
                    return body(env_in, feeds)
                nchunk = mb // p_seg

                def split(v):
                    return jax.tree_util.tree_map(
                        lambda a: a.reshape(nchunk, p_seg, *a.shape[1:]), v)

                def join(v):
                    return jax.tree_util.tree_map(
                        lambda a: a.reshape(nchunk * p_seg, *a.shape[1:]), v)

                out = jax.lax.map(lambda ef: body(ef[0], ef[1]),
                                  (split(env_in), split(feeds)))
                return join(out)

            return fn

        plans = []
        for seg in self.segments:
            ins, outs = seg_needs(seg)
            plans.append((seg, ins, outs, make_seg_fn(seg, ins, outs)))
        self._plans = plans

        if self._fused:
            def whole(feeds):
                env: dict[str, Any] = {}
                for seg, ins, outs, fn in plans:
                    env.update(fn({i: env[i] for i in ins if i in env},
                                  feeds))
                return env[g.outputs()[0].name]
            self._whole = jax.jit(whole)
            self._seg_fns = None
        else:
            self._whole = None
            self._seg_fns = [(seg, ins, outs, jax.jit(fn))
                             for seg, ins, outs, fn in plans]

    # calibration + weight quantization ------------------------------------
    def calibrate(self, feeds):
        """Run fp over a calibration batch, set activation scales, quantize
        int8 weights (per-output-channel)."""
        record: dict[str, float] = {}
        _, env = self._ex.run(feeds, force_fp=True, record=record)
        for op in self.graph:
            if op.name in record:
                op.attrs["act_scale"] = activation_scale(record[op.name])
        for op in self.graph:
            if op.op_type in ("dense", "linear") and op.precision == "int8":
                prod = op.inputs[0]
                op.attrs["in_scale"] = self.graph[prod].attrs.get(
                    "act_scale", 1.0)
                wq, ws = quantize_weight(op.params["w"])
                op.params["w_q"], op.params["w_scale"] = wq, ws
            elif (op.op_type == "gravnet_block"
                  and op.precision == "int8"):
                self._calibrate_block(op, env)
        self._build()  # re-close over updated params/attrs

    def _calibrate_block(self, op, env):
        """Derive the fused int8 block's baked activation scales from
        the fp calibration run. The fused op hides the chain's interior
        tensors from the recording pass, so the two interior scales are
        recomputed here from the block's fp input via the same oracles
        the unfused chain executes: ``in_scale`` is the producer's
        recorded activation scale (quantizes x on kernel entry),
        ``agg_scale`` the fp aggregate's absmax (the aggregate op's
        snap in the unfused chain), and ``h_scale`` the absmax of
        ``concat(x, agg)`` (the concat's scale, which the unfused
        output dense quantizes with). Weights quantize per channel."""
        from repro.kernels import ref as kref
        a, p = op.attrs, op.params
        prod = op.inputs[0]
        a["in_scale"] = self.graph[prod].attrs.get("act_scale", 1.0)
        dh = p["ws"].shape[0]
        x = _as_fp(env[prod])[..., :dh]
        mask = _as_fp(env[op.inputs[1]])
        s = kref.fused_dense_ref(x, p["ws"], p["bs"], activation="none",
                                 out_dtype=jnp.float32)
        f = kref.fused_dense_ref(x, p["wf"], p["bf"], activation="none",
                                 out_dtype=jnp.float32)

        def agg_one(ss, ff, mm):
            return kref.gravnet_aggregate_ref(ss, ff, mm, k=a["k"],
                                              scale=a["scale"],
                                              out_dtype=jnp.float32)

        agg = (jax.vmap(agg_one)(s, f, mask) if x.ndim == 3
               else agg_one(s, f, mask))
        a["agg_scale"] = activation_scale(float(jnp.max(jnp.abs(agg))))
        h = (jnp.concatenate([x, agg], axis=-1)
             if a.get("concat_x", True) else agg)
        a["h_scale"] = activation_scale(float(jnp.max(jnp.abs(h))))
        for nm in ("ws", "wf", "wo"):
            p[nm + "_q"], p[nm + "_scale"] = quantize_weight(p[nm])

    # inference -------------------------------------------------------------
    def __call__(self, feeds):
        b = next(iter(feeds.values())).shape[0]
        mb = self.microbatch
        chunks = []
        pad = (-b) % mb
        if pad:
            feeds = jax.tree_util.tree_map(
                lambda a: jnp.concatenate(
                    [a, jnp.zeros((pad, *a.shape[1:]), a.dtype)]), feeds)
        total = b + pad
        for s in range(0, total, mb):
            chunk = jax.tree_util.tree_map(lambda a: a[s:s + mb], feeds)
            if self._fused:
                chunks.append(self._whole(chunk))
            else:
                env: dict[str, Any] = {}
                for seg, ins, outs, fn in self._seg_fns:
                    env.update(fn({i: env[i] for i in ins if i in env},
                                  chunk))
                chunks.append(env[self.graph.outputs()[0].name])
        out = jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, axis=0), *chunks)
        if pad:
            out = jax.tree_util.tree_map(lambda a: a[:b], out)
        return out

    # reporting ---------------------------------------------------------------
    def resource_report(self):
        """Table-I analogue: per-segment FLOPs/bytes/VMEM occupancy."""
        n = self.req.n_hits
        rows = []
        for seg in self.segments:
            ops_ = [self.graph[o] for o in seg["ops"]]
            p = ops_[0].attrs_opt.get("P", 1)
            fl = by = wb = 0.0
            for op in ops_:
                f_, a_, w_ = op_cost(op, n)
                fl += f_
                by += a_
                wb += w_
            vmem = wb + p * by
            rows.append({
                "segment": seg["id"], "target": seg["target"], "P": p,
                "ops": len(ops_), "flops_per_event": fl,
                "act_bytes_per_event": by, "weight_bytes": wb,
                "vmem_working_set": vmem,
                "vmem_util": vmem / hw.VMEM_BYTES,
                "time_s_per_step": segment_time(ops_, n, p,
                                                self.req.platform),
            })
        return rows

    def model_throughput(self):
        total = 0.0
        for r in self.resource_report():
            chunks = max(1, self.microbatch // r["P"])
            total += chunks * r["time_s_per_step"]
        return self.microbatch / total if total else float("inf")

    def model_latency(self):
        return sum(r["time_s_per_step"] for r in self.resource_report())


# -------------------------------------------------------------------- deploy ----
def deploy(model_graph: Graph, req: Requirements, *,
           calibration_feeds=None, kernel_backend: str | None = None,
           tuning_cache=None, batch: int = 1,
           fuse_gravnet_block: bool = True,
           fuse_int8: bool = True, ragged: bool = False,
           max_events: int | None = None):
    """Run the design flow and emit one executable.

    ``batch > 1`` emits a *batch-packed* executable: kernels are bound
    (and tuning-cache keys derived) for the shapes one whole
    micro-batch launches, and the compiled object processes ``batch``
    events per launch with no per-segment chunking. ``batch=1`` is the
    legacy per-event-shaped executable.

    ``fuse_gravnet_block`` (default on) collapses every fusable
    dense(S)/dense(F) → gravnet_aggregate [→ concat] → dense(out)
    chain into one ``gravnet_block`` megakernel launch at design
    points ≥ 2. The fp path is bitwise-equal to the unfused chain
    (tested); ``False`` is the escape hatch and reproduces the legacy
    graphs — and their tuning-cache keys — bit-for-bit. Under the
    mixed precision policy the fused blocks run the *quantized*
    megakernel: ``calibrate`` bakes the chain's activation scales into
    the kernel and the block matches the unfused calibrated int8 chain
    within calibration tolerance (tested). ``fuse_int8=False`` is the
    int8-specific escape hatch — mixed deployments keep the legacy
    unfused int8 dense chain and its tuning keys bit-for-bit while fp
    deployments still fuse.

    ``ragged=True`` emits a *padding-free* executable: after fusion
    the graph is raggedized (``passes.ragged``) to consume the
    bin-packed event layout of ``data/ragged.py`` — whole events
    first-fit packed into ``req.n_hits``-row bins, kNN neighbors
    selected on-device by the ``knn_build`` kernel with segment
    masking. ``batch`` then means *bins per launch* (not events), and
    ``max_events`` fixes the static per-launch event capacity of the
    condensation scatter (default ``2 * batch`` — a launch holding
    more events is split, never truncated). The returned
    ``RaggedPipeline`` accepts either a ``data.ragged.RaggedBatch`` or
    the padded ``{hits, mask}`` feeds and reproduces the padded
    pipeline's output structure."""
    import os as _os
    backend = (kernel_backend or _os.environ.get("REPRO_BACKEND")
               or ("pallas" if req.platform == "tpu" else "xla"))
    if ragged and req.precision_policy == "mixed":
        raise NotImplementedError(
            "deploy(ragged=True) does not support the mixed precision "
            "policy yet (no quantized ragged megakernel)")
    from repro.core.passes.verify import verify
    verify(model_graph)  # legality check before any rewrite
    g = model_graph
    if req.design_point >= 2:
        # mixed precision fuses only when calibration data will arrive
        # to bake the quantized megakernel's scales (an uncalibrated
        # mixed deploy raises below anyway)
        block = fuse_gravnet_block and (
            req.precision_policy != "mixed"
            or (fuse_int8 and calibration_feeds is not None))
        g = fuse(g, gravnet_block=block)
        verify(g)        # fusion must preserve well-formedness
    if ragged:
        from repro.core.passes.ragged import raggedize
        g = raggedize(g)
        verify(g)    # the rewrite must preserve well-formedness too
        g.meta["ragged_max_events"] = int(max_events or 2 * batch)
    g = partition(g, tpu_native_gravnet=req.tpu_native_gravnet)
    g = apply_precision_policy(
        g, policy="mixed" if req.precision_policy == "mixed" else "fp")
    g = map_templates(g)
    if req.design_point >= 2:
        g = parallelize(g, req)
    else:
        for op in g:
            op.attrs_opt["P"] = 1
        g.meta["parallelization"] = {"P_mxu": 1, "P_xla": 1, "microbatch": 1,
                                     "model_throughput_ev_s": None,
                                     "target": req.target_throughput}
    if req.design_point >= 3:
        g = kernel_optimize(g, n_rows=req.n_hits, batch=batch,
                            tuning_cache=tuning_cache, backend=backend)
    pipe = CompiledPipeline(g, req, backend, batch=batch)
    if req.precision_policy == "mixed":
        if calibration_feeds is None:
            raise ValueError("mixed precision requires calibration_feeds")
        pipe.calibrate(calibration_feeds)
    if ragged:
        return RaggedPipeline(pipe, batch=batch,
                              max_events=g.meta["ragged_max_events"],
                              capacity=req.n_hits,
                              example_feeds=calibration_feeds)
    return pipe


# ----------------------------------------------------- bucketed deployment ----
def _cut_hits(feeds: dict, n: int) -> dict:
    """Slice (or zero-pad) every feed's hit axis (axis 1) to exactly
    ``n`` rows. Events are energy-sorted upstream (data/belle2), so an
    overflow slice keeps the hardest hits. Already-cut feeds (the
    serving dispatch path — ``submit`` cuts per event) pass through
    untouched, so the hot path pays no copy."""
    out = {}
    for key, v in feeds.items():
        if v.shape[1] == n:
            out[key] = v
        elif v.shape[1] > n:
            out[key] = v[:, :n]
        else:
            pw = [(0, 0)] * v.ndim
            pw[1] = (0, n - v.shape[1])
            out[key] = jnp.pad(jnp.asarray(v), pw)
    return out


class BucketedPipeline:
    """Occupancy-bucketed, batch-packed deployment.

    One ``CompiledPipeline`` per (bucket, microbatch) pair: events are
    classified by non-zero hit count and run through the smallest
    bucket executable that fits them (overflow → largest bucket), so
    low-occupancy events stop paying the full-detector launch.
    ``__call__`` reproduces the single-pipeline API — it classifies a
    feed batch, packs each bucket's events into ``microbatch``-wide
    launches, and reassembles results in submission order (per-hit
    output heads are zero-padded up to the widest bucket used so the
    batch stacks). Serving integrates through ``infer_fns()`` +
    ``classify()`` (see ``serving.ShardedTriggerService(buckets=…)``).
    """

    def __init__(self, pipes: dict[int, CompiledPipeline], *,
                 microbatch: int, mask_feed: str = "mask",
                 example_feeds: dict | None = None):
        if not pipes:
            raise ValueError("BucketedPipeline: no bucket executables")
        self.pipes = {b: pipes[b] for b in sorted(pipes)}
        self.buckets = tuple(sorted(pipes))
        self.microbatch = microbatch
        self.mask_feed = mask_feed
        # example feeds (calibration slice) drive warmup compilation
        self._example = example_feeds

    # ------------------------------------------------------- classification --
    def classify(self, occupancy: int) -> int:
        from repro.serving.router import pick_bucket_sorted
        return pick_bucket_sorted(occupancy, self.buckets)

    def _occupancies(self, feeds):
        import numpy as np
        return np.count_nonzero(
            np.asarray(feeds[self.mask_feed]) > 0, axis=1)

    # --------------------------------------------------------------- infer --
    def __call__(self, feeds):
        import numpy as np
        occ = self._occupancies(feeds)
        b_total = occ.shape[0]
        groups: dict[int, list[int]] = {}
        for i, o in enumerate(occ):
            groups.setdefault(self.classify(int(o)), []).append(i)
        per_bucket = []
        for bucket, idxs in sorted(groups.items()):
            sub = jax.tree_util.tree_map(
                lambda a: jnp.asarray(a)[jnp.asarray(idxs)], feeds)
            out = self.pipes[bucket](_cut_hits(sub, bucket))
            per_bucket.append((idxs, out))
        # reassemble in submission order; pad differing per-hit axes
        # (axis 1) up to the widest bucket used in this call
        leaves0, tdef = jax.tree_util.tree_flatten(per_bucket[0][1])
        flat = [(idxs, jax.tree_util.tree_flatten(out)[0])
                for idxs, out in per_bucket]
        result_leaves = []
        for li in range(len(leaves0)):
            parts = [(idxs, np.asarray(ls[li])) for idxs, ls in flat]
            widest = max(p.shape[1] if p.ndim >= 2 else 0
                         for _, p in parts)
            buf = None
            for idxs, p in parts:
                if p.ndim >= 2 and p.shape[1] < widest:
                    pw = [(0, 0)] * p.ndim
                    pw[1] = (0, widest - p.shape[1])
                    p = np.pad(p, pw)
                if buf is None:
                    buf = np.zeros((b_total, *p.shape[1:]), p.dtype)
                buf[np.asarray(idxs)] = p
            result_leaves.append(buf)
        return jax.tree_util.tree_unflatten(tdef, result_leaves)

    # ------------------------------------------------------------- serving --
    def infer_fns(self) -> dict:
        """{bucket: infer_fn} for the serving layer; each fn expects
        feeds already cut to its bucket's hit count (the service slices
        on submit) and runs one batch-packed launch."""
        return {b: (lambda feeds, _p=self.pipes[b], _b=b:
                    _p(_cut_hits(feeds, _b)))
                for b in self.buckets}

    def warmup_one(self, bucket: int) -> int:
        """Pre-compile one bucket's (bucket, microbatch) executable;
        returns 1 when warmed (0 with no example feeds). The serving
        layer calls this once per (device, bucket) so a bucket's
        replicas never pay for their siblings' shapes."""
        if self._example is None:
            return 0
        ex = jax.tree_util.tree_map(
            lambda a: jnp.asarray(a)[:self.microbatch], self._example)
        # CompiledPipeline.__call__ pads any batch up to the microbatch
        # multiple, so a short example still compiles the served shape
        jax.block_until_ready(jax.tree_util.tree_leaves(
            self.pipes[bucket](_cut_hits(ex, bucket))))
        return 1

    def warmup(self) -> int:
        """Pre-compile every (bucket, microbatch) executable so the
        first real event of any occupancy never pays jit tracing.
        Returns the number of bucket executables warmed."""
        return sum(self.warmup_one(b) for b in self.buckets)

    # ----------------------------------------------------------- reporting --
    def resource_report(self):
        return {b: p.resource_report() for b, p in self.pipes.items()}


def deploy_bucketed(model_graph: Graph, req: Requirements, *,
                    buckets=(32, 64, 128), microbatch: int = 8,
                    calibration_feeds=None,
                    kernel_backend: str | None = None,
                    tuning_cache=None,
                    fuse_gravnet_block: bool = True,
                    fuse_int8: bool = True) -> BucketedPipeline:
    """Run the design flow once per occupancy bucket.

    Each bucket b gets its own batch-packed executable deployed at
    ``n_hits=b`` (kernel bindings, tuning keys, and precision
    calibration all see the bucket's true shape). ``calibration_feeds``
    are sliced to each bucket's hit count, so int8 activation scales
    are calibrated on the occupancy tier they will serve."""
    import dataclasses as _dc
    bs = sorted(set(int(b) for b in buckets))
    if not bs or bs[0] <= 0:
        raise ValueError(f"invalid buckets {buckets!r}")
    pipes = {}
    for b in bs:
        req_b = _dc.replace(req, n_hits=b)
        calib_b = None if calibration_feeds is None \
            else _cut_hits(calibration_feeds, b)
        pipes[b] = deploy(model_graph, req_b, calibration_feeds=calib_b,
                          kernel_backend=kernel_backend,
                          tuning_cache=tuning_cache, batch=microbatch,
                          fuse_gravnet_block=fuse_gravnet_block,
                          fuse_int8=fuse_int8)
    return BucketedPipeline(pipes, microbatch=microbatch,
                            example_feeds=calibration_feeds)


# ------------------------------------------------------- ragged deployment ----
class RaggedPipeline:
    """Padding-free bin-packed deployment (see ``deploy(ragged=True)``).

    Wraps one raggedized ``CompiledPipeline`` whose launch shape is a
    fixed number of ``capacity``-row bins. ``__call__`` accepts either
    a ``data.ragged.RaggedBatch`` (concatenated hits + CSR offsets) or
    the padded ``{hits, mask}`` feeds; events are first-fit packed
    whole into bins, launches are capped at the executable's bin count
    *and* at ``max_events`` events (the condensation scatter's static
    event capacity — overflow splits launches, never truncates an
    event), and per-event results are scattered back so the output
    matches the dense pipeline's structure:
    ``{head: (n_events, capacity, d), 'cps': {…: (n_events, …)}}``.
    """

    def __init__(self, pipe: CompiledPipeline, *, batch: int,
                 max_events: int, capacity: int,
                 example_feeds: dict | None = None):
        if not pipe.graph.meta.get("ragged"):
            raise ValueError("RaggedPipeline needs a raggedized graph "
                             "(deploy(ragged=True) builds one)")
        self.pipe = pipe
        # bins per launch = the executable's microbatch, so every call
        # is exactly one chunk (no zero-padding: an all-zero pad bin
        # would alias segment id 0)
        self.batch = int(pipe.microbatch)
        self.max_events = int(max_events)
        self.capacity = int(capacity)
        self._example = example_feeds

    # ------------------------------------------------------------ planning --
    def _plan_launches(self, counts) -> list[tuple[int, int]]:
        """Split the event stream into contiguous ``[i, j)`` launch
        ranges by simulating the same first-fit packing ``bin_pack``
        performs, closing a launch when the next event would need a
        ``batch+1``-th bin or exceed ``max_events``."""
        launches = []
        start, n_ev, free = 0, 0, []
        for e, c in enumerate(counts):
            c = int(c)
            if c > self.capacity:
                raise ValueError(
                    f"event {e} has {c} hits > bin capacity "
                    f"{self.capacity} — it cannot be packed")
            placed = False
            for i, f in enumerate(free):
                if c <= f:
                    free[i] -= c
                    placed = True
                    break
            needs_bin = not placed
            if (needs_bin and len(free) == self.batch) \
                    or n_ev == self.max_events:
                launches.append((start, e))
                start, n_ev, free = e, 0, []
                needs_bin = True
            if needs_bin:
                free.append(self.capacity - c)
            n_ev += 1
        if n_ev or not launches:
            launches.append((start, start + n_ev))
        return launches

    # --------------------------------------------------------------- infer --
    def __call__(self, feeds):
        import numpy as np

        from repro.data.ragged import (RaggedBatch, bin_pack, pack_events,
                                       unpack_binned)
        if isinstance(feeds, RaggedBatch):
            rb = feeds
        else:
            rb = pack_events(np.asarray(feeds["hits"]),
                             np.asarray(feeds["mask"]))
        counts = rb.counts()
        offs = np.asarray(rb.offsets)
        parts = []
        for i, j in self._plan_launches(counts):
            sub = RaggedBatch(feats=rb.feats[offs[i]:offs[j]],
                              offsets=offs[i:j + 1] - offs[i])
            bp = bin_pack(sub, self.capacity, n_bins=self.batch)
            mask = (np.asarray(bp.segids) >= 0).astype(np.float32)
            out = self.pipe({"hits": jnp.asarray(bp.feats),
                             "mask": jnp.asarray(mask),
                             "segids": jnp.asarray(bp.segids),
                             "slots": jnp.asarray(bp.slots)})
            n_ev = j - i
            part = {}
            for name, v in out.items():
                if name == "cps":
                    part[name] = {k: np.asarray(a)[:n_ev]
                                  for k, a in v.items()}
                else:
                    part[name] = unpack_binned(
                        np.asarray(v), np.asarray(bp.segids),
                        np.asarray(bp.slots), n_ev, self.capacity)
            parts.append(part)
        if len(parts) == 1:
            return parts[0]
        return jax.tree_util.tree_map(
            lambda *xs: np.concatenate(xs, axis=0), *parts)

    # -------------------------------------------------------------- warmup --
    def warmup(self) -> int:
        """Pre-compile the (batch × capacity)-bin executable so the
        first real submission never pays jit tracing. Uses the example
        feeds when given, else a synthetic full-occupancy batch."""
        import numpy as np
        if self._example is not None:
            feeds = {k: np.asarray(v) for k, v in self._example.items()
                     if k in ("hits", "mask")}
        else:
            rng = np.random.default_rng(0)
            d = self.pipe.graph["hits"].out_dim
            feeds = {"hits": rng.normal(size=(self.batch, self.capacity,
                                              d)).astype(np.float32),
                     "mask": np.ones((self.batch, self.capacity),
                                     np.float32)}
        jax.block_until_ready(jax.tree_util.tree_leaves(
            jax.tree_util.tree_map(jnp.asarray, self(feeds))))
        return 1

    # ----------------------------------------------------------- reporting --
    def resource_report(self):
        return self.pipe.resource_report()
