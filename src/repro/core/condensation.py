"""Object-condensation loss (Kieseler, arXiv:2002.03605) for CaloClusterNet.

Per-hit labels: ``object_id`` ∈ {-1 (noise), 0..K-1} and per-hit truth
(energy, class). Charges q_i = arctanh²(β_i) + q_min; each object k is
represented by its highest-charge hit α_k. Losses:

  L_V    = mean_i q_i [ Σ_k M_ik · V_att(i,α_k) + (1-M_ik) · V_rep(i,α_k) ]
           with V_att = d²·q_αk, V_rep = max(0, 1-d)·q_αk
  L_beta = mean_k (1 - β_αk)  +  s_B · mean_{noise} β_i
  L_E    = masked Huber on per-hit energy at object hits
  L_cls  = masked cross-entropy at object hits
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CondensationWeights:
    q_min: float = 0.1
    s_beta_noise: float = 1.0
    w_potential: float = 1.0
    w_beta: float = 1.0
    w_energy: float = 0.2
    w_cls: float = 0.2


def condensation_loss(outputs, labels, mask, *, k_max: int,
                      w: CondensationWeights = CondensationWeights()):
    """outputs: apply() dict (B,N,...); labels: {'object_id' (B,N) int32,
    'energy' (B,N), 'cls' (B,N) int32}; mask (B,N). Returns (loss, metrics).
    """
    beta = jax.nn.sigmoid(outputs["beta_logit"]) * mask
    beta = jnp.clip(beta, 1e-6, 1.0 - 1e-6)
    coords = outputs["coords"]
    obj = labels["object_id"]
    is_hit = (obj >= 0) & (mask > 0)
    is_noise = (obj < 0) & (mask > 0)

    q = jnp.arctanh(beta) ** 2 + w.q_min                      # (B,N)

    def per_event(beta_e, q_e, xy_e, obj_e, hit_e, noise_e):
        n = beta_e.shape[0]
        # one-hot membership M (N, K)
        m = (obj_e[:, None] == jnp.arange(k_max)[None, :]) & hit_e[:, None]
        obj_exists = jnp.any(m, axis=0)                        # (K,)
        # alpha_k = argmax_i q_i within object k
        q_masked = jnp.where(m, q_e[:, None], -1.0)
        alpha = jnp.argmax(q_masked, axis=0)                   # (K,)
        xy_a = xy_e[alpha]                                     # (K, 2)
        q_a = q_e[alpha] * obj_exists                          # (K,)
        b_a = beta_e[alpha]
        d = jnp.linalg.norm(xy_e[:, None, :] - xy_a[None, :, :] + 1e-9,
                            axis=-1)                           # (N, K)
        v_att = (d ** 2) * q_a[None, :]
        v_rep = jnp.maximum(0.0, 1.0 - d) * q_a[None, :]
        mf = m.astype(jnp.float32)
        active = (hit_e | noise_e).astype(jnp.float32)
        pot = (mf * v_att + (1.0 - mf) * v_rep
               * obj_exists[None, :]).sum(axis=1) * q_e * active
        l_v = pot.sum() / jnp.maximum(active.sum(), 1.0)
        n_obj = jnp.maximum(obj_exists.sum(), 1.0)
        l_beta = (((1.0 - b_a) * obj_exists).sum() / n_obj
                  + w.s_beta_noise
                  * (beta_e * noise_e).sum()
                  / jnp.maximum(noise_e.sum(), 1.0))
        return l_v, l_beta

    l_v, l_beta = jax.vmap(per_event)(
        beta, q, coords, obj, is_hit, is_noise)

    # energy (Huber) + class CE at object hits
    hit_f = is_hit.astype(jnp.float32)
    e_err = outputs["energy"] - labels["energy"]
    huber = jnp.where(jnp.abs(e_err) < 1.0, 0.5 * e_err ** 2,
                      jnp.abs(e_err) - 0.5)
    l_e = (huber * hit_f).sum() / jnp.maximum(hit_f.sum(), 1.0)
    logp = jax.nn.log_softmax(outputs["cls_logits"], axis=-1)
    ce = -jnp.take_along_axis(
        logp, jnp.maximum(labels["cls"], 0)[..., None], axis=-1)[..., 0]
    l_cls = (ce * hit_f).sum() / jnp.maximum(hit_f.sum(), 1.0)

    loss = (w.w_potential * l_v.mean() + w.w_beta * l_beta.mean()
            + w.w_energy * l_e + w.w_cls * l_cls)
    metrics = {"loss": loss, "l_potential": l_v.mean(),
               "l_beta": l_beta.mean(), "l_energy": l_e, "l_cls": l_cls}
    return loss, metrics
