"""CaloClusterNet — the dynamic GNN the paper deploys (refs [10]/[14]).

Per event: up to ``n_hits`` non-zero sparse calorimeter hits (of
``n_crystals`` crystals; 128/8736 post-upgrade, 32/576 current detector),
each with features (energy, θ, φ, t). The network is GravNet-based
(Qasim et al. 1902.07987) with object-condensation outputs
(Kieseler 2002.03605):

  encoder Dense×2 → [GravNet block]×2 → decoder Dense×2 →
  per-hit heads: β, cluster coords (2), energy, class logits (3)
  → CPS (condensation point selection) → ≤ k_max clusters + trigger bit.

Two synchronized forms:
- ``init/apply``: functional, differentiable (training path, jnp ref ops);
- ``to_graph``: the dataflow-IR export consumed by the deployment flow
  (repro.core.pipeline) — numerically identical in fp mode (tested).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.graph_ir import Graph, Operator, register_exporter
from repro.kernels import ref as kref
from repro.nn import dense_init, dense_apply


@dataclasses.dataclass(frozen=True)
class CCNConfig:
    n_hits: int = 128           # max nonzero inputs per event (upgrade)
    n_crystals: int = 8736
    d_in: int = 4               # (E, theta, phi, t)
    d_hidden: int = 64
    n_gravnet_blocks: int = 2
    d_s: int = 4                # learned spatial dims
    d_flr: int = 22             # learned feature dims
    k: int = 8                  # neighbors
    potential_scale: float = 10.0
    d_decoder: int = 32
    n_classes: int = 3          # photon / hadron / beam-background
    k_max: int = 8              # max condensation points per event
    t_beta: float = 0.3
    t_dist: float = 0.5         # min distance between condensation points
    e_trigger: float = 0.1      # GeV threshold on cluster energy
    gravnet_impl: str = "topk"  # 'topk' (gather) | 'onehot' (MXU-native)
    compute_dtype: str = "f32"  # 'f32' | 'bf16' (serving activations)

    @property
    def head_dims(self):
        # beta, coords(2), energy, class logits
        return {"beta": 1, "coords": 2, "energy": 1,
                "cls": self.n_classes}


def current_detector_config() -> CCNConfig:
    return dataclasses.replace(CCNConfig(), n_hits=32, n_crystals=576)


# ------------------------------------------------------------------ init ----
def init(key, cfg: CCNConfig):
    ks = jax.random.split(key, 16)
    p = {}
    p["enc1"] = dense_init(ks[0], cfg.d_in, cfg.d_hidden)
    p["enc2"] = dense_init(ks[1], cfg.d_hidden, cfg.d_hidden)
    for i in range(cfg.n_gravnet_blocks):
        p[f"gn{i}_s"] = dense_init(ks[2 + 3 * i], cfg.d_hidden, cfg.d_s)
        p[f"gn{i}_flr"] = dense_init(ks[3 + 3 * i], cfg.d_hidden, cfg.d_flr)
        p[f"gn{i}_out"] = dense_init(ks[4 + 3 * i],
                                     cfg.d_hidden + 2 * cfg.d_flr,
                                     cfg.d_hidden)
    p["dec1"] = dense_init(ks[10], cfg.d_hidden, cfg.d_hidden)
    p["dec2"] = dense_init(ks[11], cfg.d_hidden, cfg.d_decoder)
    for j, (h, d) in enumerate(cfg.head_dims.items()):
        p[f"head_{h}"] = dense_init(ks[12 + j], cfg.d_decoder, d)
    return p


# ----------------------------------------------------------------- apply ----
def apply(params, feats, mask, cfg: CCNConfig):
    """feats: (B, N, d_in), mask: (B, N) -> per-hit output dict.

    Differentiable; uses the jnp reference ops (kernels/ref.py).
    """
    if cfg.compute_dtype == "bf16":
        feats = feats.astype(jnp.bfloat16)
        params = jax.tree_util.tree_map(
            lambda a: a.astype(jnp.bfloat16), params)
    x = dense_apply(params["enc1"], feats, activation=jax.nn.relu)
    x = dense_apply(params["enc2"], x, activation=jax.nn.relu)
    gn_ref = (kref.gravnet_aggregate_onehot_ref
              if cfg.gravnet_impl == "onehot"
              else kref.gravnet_aggregate_ref)
    agg_fn = jax.vmap(
        lambda s, f, m: gn_ref(
            s, f, m, k=cfg.k, scale=cfg.potential_scale))
    for i in range(cfg.n_gravnet_blocks):
        s = dense_apply(params[f"gn{i}_s"], x)
        flr = dense_apply(params[f"gn{i}_flr"], x)
        agg = agg_fn(s, flr, mask)
        x = dense_apply(params[f"gn{i}_out"],
                        jnp.concatenate([x, agg], axis=-1),
                        activation=jax.nn.relu)
    x = dense_apply(params["dec1"], x, activation=jax.nn.relu)
    x = dense_apply(params["dec2"], x, activation=jax.nn.relu)
    out = {h: dense_apply(params[f"head_{h}"], x)
           for h in cfg.head_dims}
    return {
        "beta_logit": out["beta"][..., 0],
        "coords": out["coords"],
        "energy": out["energy"][..., 0],
        "cls_logits": out["cls"],
    }


# ------------------------------------------------------------------- CPS ----
def cps(outputs, mask, cfg: CCNConfig):
    """Condensation Point Selection (vmapped over the batch).

    Greedy over hits in decreasing β: select hits with β > t_beta that are
    at least t_dist away (in learned cluster-coordinate space) from every
    already-selected point; at most k_max points. Fixed shapes throughout
    (jit/hardware friendly — the paper runs this on FPGA fabric; here it
    is the canonical 'irregular' op pinned to the XLA partition).
    """
    def one_event(beta_logit, coords, energy, mask_e):
        n = beta_logit.shape[0]
        beta = jax.nn.sigmoid(beta_logit) * mask_e
        order = jnp.argsort(-beta)
        big = jnp.float32(1e30)

        def body(t, carry):
            sel_xy, sel_e, sel_b, count = carry
            idx = order[t]
            b = beta[idx]
            c = coords[idx]
            d2 = jnp.sum((sel_xy - c[None, :]) ** 2, axis=1)
            d2 = jnp.where(jnp.arange(cfg.k_max) < count, d2, big)
            ok = ((b > cfg.t_beta)
                  & (jnp.min(d2) > cfg.t_dist ** 2)
                  & (count < cfg.k_max))
            slot = count
            sel_xy = jnp.where(ok, sel_xy.at[slot].set(c), sel_xy)
            sel_e = jnp.where(ok, sel_e.at[slot].set(energy[idx]), sel_e)
            sel_b = jnp.where(ok, sel_b.at[slot].set(b), sel_b)
            count = count + jnp.where(ok, 1, 0)
            return sel_xy, sel_e, sel_b, count

        init = (jnp.zeros((cfg.k_max, 2), jnp.float32),
                jnp.zeros((cfg.k_max,), jnp.float32),
                jnp.zeros((cfg.k_max,), jnp.float32),
                jnp.int32(0))
        sel_xy, sel_e, sel_b, count = jax.lax.fori_loop(0, n, body, init)
        valid = jnp.arange(cfg.k_max) < count
        trigger = jnp.any(valid & (sel_e > cfg.e_trigger))
        return {"cluster_xy": sel_xy, "cluster_e": sel_e,
                "cluster_beta": sel_b, "cluster_valid": valid,
                "n_clusters": count, "trigger": trigger}

    return jax.vmap(one_event)(
        outputs["beta_logit"].astype(jnp.float32),
        outputs["coords"].astype(jnp.float32),
        outputs["energy"].astype(jnp.float32),
        mask.astype(jnp.float32))


# -------------------------------------------------------------- IR export ----
def to_graph(params, cfg: CCNConfig) -> Graph:
    """Export as a dataflow graph for the deployment flow.

    Every layer is one operator; GravNet blocks expand to
    (linear_s ∥ linear_flr) → gravnet_aggregate → concat → linear → relu,
    exposing exactly the fusion opportunities the paper exploits."""
    g = Graph()

    def lin(name, inp, d_out):
        g.add(Operator(name=name, op_type="linear", inputs=[inp],
                       params=dict(params[name]), out_dim=d_out))
        return name

    def relu(name, inp, d):
        g.add(Operator(name=name, op_type="relu", inputs=[inp], out_dim=d))
        return name

    g.add(Operator(name="hits", op_type="input", out_dim=cfg.d_in,
                   attrs={"feature": "hits"}))
    g.add(Operator(name="mask", op_type="input", out_dim=1,
                   attrs={"feature": "mask"}))
    x = relu("enc1_relu", lin("enc1", "hits", cfg.d_hidden), cfg.d_hidden)
    x = relu("enc2_relu", lin("enc2", x, cfg.d_hidden), cfg.d_hidden)
    for i in range(cfg.n_gravnet_blocks):
        s = lin(f"gn{i}_s", x, cfg.d_s)
        f = lin(f"gn{i}_flr", x, cfg.d_flr)
        agg = f"gn{i}_agg"
        g.add(Operator(name=agg, op_type="gravnet_aggregate",
                       inputs=[s, f, "mask"],
                       attrs={"k": cfg.k, "scale": cfg.potential_scale,
                              "d_s": cfg.d_s, "d_f": cfg.d_flr},
                       out_dim=2 * cfg.d_flr))
        cat = f"gn{i}_cat"
        g.add(Operator(name=cat, op_type="concat", inputs=[x, agg],
                       out_dim=cfg.d_hidden + 2 * cfg.d_flr))
        x = relu(f"gn{i}_out_relu", lin(f"gn{i}_out", cat, cfg.d_hidden),
                 cfg.d_hidden)
    x = relu("dec1_relu", lin("dec1", x, cfg.d_hidden), cfg.d_hidden)
    x = relu("dec2_relu", lin("dec2", x, cfg.d_decoder), cfg.d_decoder)
    heads = []
    for h, d in cfg.head_dims.items():
        heads.append(lin(f"head_{h}", x, d))
    g.add(Operator(name="cps", op_type="cps",
                   inputs=heads + ["mask"],
                   attrs={"k_max": cfg.k_max, "t_beta": cfg.t_beta,
                          "t_dist": cfg.t_dist, "e_trigger": cfg.e_trigger,
                          "head_names": list(cfg.head_dims)},
                   out_dim=cfg.k_max))
    g.add(Operator(name="out", op_type="output",
                   inputs=heads + ["cps"],
                   attrs={"head_names": list(cfg.head_dims)},
                   out_dim=sum(cfg.head_dims.values())))
    g.validate()
    g.meta["config"] = cfg
    return g


register_exporter("caloclusternet", to_graph)
