"""Partitioning pass (paper §III-A "Partitioning").

Greedy scheme prioritizing the high-density systolic target ("AIE" → MXU):
every operator with a regular, statically-scheduled access pattern is
assigned ``target='mxu'``; irregular data-dependent operators
(gravnet_aggregate kNN, CPS, the DDR-facing input/output ops) stay on
``target='xla'`` (the "FPGA fabric" analogue — XLA/VPU handles dynamic
gathers, sorts and control flow). Because only regular ops are MXU-eligible
the space of valid assignments is tiny and greedy is exhaustive-equivalent,
as the paper argues.

After assignment, consecutive runs of same-target ops in topological order
form pipeline *segments* (the paper derives 7: 4 FPGA + 3 AIE for
CaloClusterNet).

Regularity is *declared*, not hard-coded: each op type's registry spec
(``core/op_registry.py``) carries ``regular`` / ``tpu_native_regular``
flags and this pass just reads them, so a new op family partitions
correctly the moment it registers. ``tpu_native_gravnet=True``
reclassifies the ops whose specs opt in (gravnet_aggregate,
gravnet_block, edge_aggregate) as regular — the TPU-specific
beyond-paper move enabled by the argmin/one-hot-matmul kernels (see
kernels/gravnet.py, kernels/edge_aggregate.py); for CaloClusterNet it
reduces the segment count and removes two boundary crossings per
GravNet block.
"""
from __future__ import annotations

from repro.core.graph_ir import Graph, is_regular


def partition(g: Graph, *, tpu_native_gravnet: bool = False) -> Graph:
    g = g.clone()
    for op in g:
        op.target = ("mxu" if is_regular(op, tpu_native_gravnet=tpu_native_gravnet)
                     else "xla")
    # segmentation: consecutive same-target ops share a segment id
    seg = -1
    prev = None
    for op in g:
        if op.target != prev:
            seg += 1
            prev = op.target
        op.segment = seg
    return g


def segments(g: Graph) -> list[dict]:
    """Segment table: [{'id', 'target', 'ops': [names]}] in pipeline order."""
    table: list[dict] = []
    for op in g:
        if not table or table[-1]["id"] != op.segment:
            table.append({"id": op.segment, "target": op.target, "ops": []})
        table[-1]["ops"].append(op.name)
    return table
