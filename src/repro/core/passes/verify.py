"""Graph-verification pass: shape/feature-dim inference over the IR.

Run before deploy(): walks the dataflow graph in topo order, infers each
operator's output feature dim from its inputs + params, and raises on
inconsistencies (dangling inputs, dense weight-shape mismatches, concat
dim errors, slice out of range, CPS head wiring). The paper's flow is
"semi-automated" — this is the automated legality check that makes the
rest safe to automate.
"""
from __future__ import annotations

from repro.core.graph_ir import Graph


class GraphVerificationError(ValueError):
    pass


def verify(g: Graph) -> dict:
    """Returns {op_name: inferred_out_dim}; raises on malformed graphs."""
    dims: dict[str, int] = {}
    for op in g:
        ins = op.inputs
        for i in ins:
            if i not in dims:
                raise GraphVerificationError(
                    f"{op.name}: input {i!r} not yet defined (topo order)")
        t = op.op_type
        if t == "input":
            if op.out_dim is None:
                raise GraphVerificationError(f"{op.name}: input needs "
                                             "out_dim")
            dims[op.name] = op.out_dim
        elif t in ("linear", "dense"):
            if not op.params or "w" not in op.params:
                raise GraphVerificationError(f"{op.name}: missing weight")
            d_in, d_out = op.params["w"].shape
            got = dims[ins[0]]
            if got != d_in:
                raise GraphVerificationError(
                    f"{op.name}: weight expects d_in={d_in}, producer "
                    f"{ins[0]!r} provides {got}")
            if "b" in op.params and op.params["b"].shape != (d_out,):
                raise GraphVerificationError(f"{op.name}: bias shape "
                                             f"{op.params['b'].shape}")
            dims[op.name] = d_out
        elif t in ("relu", "quant", "dequant"):
            dims[op.name] = dims[ins[0]]
        elif t == "retile":
            dims[op.name] = op.out_dim or dims[ins[0]]
        elif t == "concat":
            dims[op.name] = sum(dims[i] for i in ins)
        elif t == "slice":
            st, sz = op.attrs["start"], op.attrs["size"]
            if st + sz > dims[ins[0]]:
                raise GraphVerificationError(
                    f"{op.name}: slice [{st}:{st + sz}] exceeds producer "
                    f"dim {dims[ins[0]]}")
            dims[op.name] = sz
        elif t == "gravnet_aggregate":
            if len(ins) != 3:
                raise GraphVerificationError(
                    f"{op.name}: needs (s, f, mask) inputs")
            ds, df = op.attrs.get("d_s"), op.attrs.get("d_f")
            if dims[ins[0]] != ds or dims[ins[1]] != df:
                raise GraphVerificationError(
                    f"{op.name}: S/FLR dims ({dims[ins[0]]},{dims[ins[1]]})"
                    f" != attrs ({ds},{df})")
            dims[op.name] = 2 * df
        elif t == "gravnet_block":
            if len(ins) != 2:
                raise GraphVerificationError(
                    f"{op.name}: needs (x, mask) inputs")
            need = ("ws", "bs", "wf", "bf", "wo", "bo")
            if not op.params or any(p not in op.params for p in need):
                raise GraphVerificationError(
                    f"{op.name}: gravnet_block needs params {need}")
            dh = op.attrs.get("d_hidden")
            ds, df = op.attrs.get("d_s"), op.attrs.get("d_f")
            if dims[ins[0]] != dh:
                raise GraphVerificationError(
                    f"{op.name}: x provides {dims[ins[0]]}, expects "
                    f"d_hidden={dh}")
            if op.params["ws"].shape != (dh, ds):
                raise GraphVerificationError(
                    f"{op.name}: ws shape {op.params['ws'].shape} != "
                    f"({dh},{ds})")
            if op.params["wf"].shape != (dh, df):
                raise GraphVerificationError(
                    f"{op.name}: wf shape {op.params['wf'].shape} != "
                    f"({dh},{df})")
            dcat = (dh + 2 * df if op.attrs.get("concat_x", True)
                    else 2 * df)
            if op.params["wo"].shape[0] != dcat:
                raise GraphVerificationError(
                    f"{op.name}: wo expects {op.params['wo'].shape[0]} "
                    f"inputs, block provides {dcat}")
            dims[op.name] = int(op.params["wo"].shape[1])
        elif t == "attention":
            if len(ins) != 3:
                raise GraphVerificationError(
                    f"{op.name}: needs (q, k, v) inputs")
            if len({dims[i] for i in ins}) != 1:
                raise GraphVerificationError(
                    f"{op.name}: q/k/v dims differ: "
                    f"{[dims[i] for i in ins]}")
            dims[op.name] = dims[ins[0]]
        elif t == "cps":
            heads = op.attrs.get("head_names", [])
            if len(ins) != len(heads) + 1:
                raise GraphVerificationError(
                    f"{op.name}: expects {len(heads)} heads + mask, got "
                    f"{len(ins)} inputs")
            dims[op.name] = op.out_dim or 1
        elif t == "output":
            dims[op.name] = sum(dims[i] for i in ins
                                if g[i].op_type != "cps")
        else:
            raise GraphVerificationError(f"{op.name}: unknown op {t!r}")
        if op.out_dim is not None and dims[op.name] != op.out_dim \
                and t not in ("output",):
            raise GraphVerificationError(
                f"{op.name}: declared out_dim {op.out_dim} != inferred "
                f"{dims[op.name]}")
    if not g.outputs():
        raise GraphVerificationError("graph has no output operator")
    return dims
