"""Graph-verification pass: shape/feature-dim inference over the IR.

Run before deploy(): walks the dataflow graph in topo order, infers each
operator's output feature dim via the op registry's per-type ``infer``
hooks (``core/op_registry.py``), and raises on inconsistencies (dangling
inputs, dense weight-shape mismatches, concat dim errors, slice out of
range, CPS head wiring, unregistered op types). The paper's flow is
"semi-automated" — this is the automated legality check that makes the
rest safe to automate. Opening the flow to a new op family means
registering an :class:`~repro.core.op_registry.OpSpec` with an ``infer``
hook, not editing this pass.
"""
from __future__ import annotations

from repro.core.graph_ir import Graph
from repro.core.op_registry import (GraphVerificationError,  # noqa: F401
                                    UnknownOperatorError, require_spec)


def verify(g: Graph) -> dict:
    """Returns {op_name: inferred_out_dim}; raises on malformed graphs."""
    dims: dict[str, int] = {}
    for op in g:
        for i in op.inputs:
            if i not in dims:
                raise GraphVerificationError(
                    f"{op.name}: input {i!r} not yet defined (topo order)")
        spec = require_spec(op)  # unknown op types raise here
        if spec.infer is None:
            raise UnknownOperatorError(
                f"{op.name}: op {op.op_type!r} is registered without a "
                "shape-inference hook")
        dims[op.name] = spec.infer(op, dims, g)
        if op.out_dim is not None and dims[op.name] != op.out_dim \
                and op.op_type not in ("output",):
            raise GraphVerificationError(
                f"{op.name}: declared out_dim {op.out_dim} != inferred "
                f"{dims[op.name]}")
    if not g.outputs():
        raise GraphVerificationError("graph has no output operator")
    return dims
