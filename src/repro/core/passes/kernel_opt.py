"""Kernel-level optimization pass (paper §III-A "Kernel-Level Optimizations").

The paper's insight: at trigger-scale matrix sizes, per-iteration loop
scheduling overhead dominates kernel runtime, so they replace AIE loop
pipelining with loop *flattening* (``chess_flatten_loop``), trading program
memory for issue efficiency. Design ③ applies exactly this at identical
resource allocation.

TPU analogues applied here (design ③):

1. **Kernel binding** — every op's launch knobs are bound by the binder
   its registry spec declares (``op_registry.bind_kernels``): MXU dense
   ops below a size threshold switch from the grid-looped Pallas variant
   to the single-cell 'flattened' variant (whole operand in VMEM, no K
   loop), larger ops get tuned (bm, bn, bk) block shapes; gravnet /
   gravnet_block / edge_aggregate / attention bind cache-only knobs.
2. **Retile cancellation / layout propagation** — adjacent retiles that
   undo each other (lane128 → compact → lane128) are bypassed so a chain
   of MXU kernels hands tensors over in padded layout without copies.
3. **Int8 chain fusion** — inside an 8-bit partition, a dense feeding
   another dense emits int8 directly (requantized in the epilogue with
   the consumer's input scale) instead of dequant→requant through f32;
   scales are folded (the paper's bit-exact 8-bit interior handoff).
   Which consumers may sit on an 8-bit handoff is declared per op spec
   (``OpSpec.int8_passthrough``).
4. **Whole-pipeline jit** — the executor compiles the entire graph as one
   XLA program instead of one dispatch per segment (removes the
   heterogeneous-boundary overhead the paper measured in design ①).

Variant/block selection consults the persistent tuning cache
(``repro.tuning``) when one is supplied: a cached winner for the exact
(kernel, shape, dtype, backend) problem overrides the heuristic,
because LL-GNN-style studies show the latency-optimal config is
shape-dependent and must be searched. With no cache (or on any miss)
the heuristic is used unchanged — an empty cache reproduces today's
bindings bit-for-bit (tested).
"""
from __future__ import annotations

from repro.core.graph_ir import Graph
from repro.core.op_registry import BindContext, bind_kernels, op_spec

FLATTEN_ROWS = 512        # rows (hits × microbatch) below which we flatten
FLATTEN_DIM = 1024        # max feature dim for the flattened variant

_FUSED_DENSE_KNOBS = ("variant", "bm", "bn", "bk")


def _pick_block(v: int, cap: int) -> int:
    p = 1
    while p * 2 <= min(v, cap):
        p *= 2
    return p


def fused_dense_shape(op, n_rows: int, batch: int = 1) -> tuple[int, int, int]:
    """(rows, d_in, d_out) of the matmul this op launches per step —
    the tuning-cache problem shape (shared with the autotuner).

    ``batch`` is the micro-batch width of a *batch-packed* executable
    (occupancy-bucketed serving): dense kernels row-pack events, so the
    batch dimension folds into ``rows`` (one launch sees batch·n_rows
    rows). ``batch=1`` is the legacy per-step shape, where rows scale
    with the segment's spatial parallelization P instead."""
    d_in = op.params["w"].shape[0]
    d_out = op.out_dim or op.params["w"].shape[1]
    if batch > 1:
        rows = n_rows * batch
    else:
        rows = n_rows * op.attrs_opt.get("P", 1)
    return rows, d_in, d_out


def fused_dense_dtype(op) -> str:
    """The dtype the executor will actually run this dense in."""
    if op.precision == "int8":
        return "int8"
    if op.precision == "bf16":
        return "bf16"
    return "float32"


def kernel_optimize(g: Graph, *, n_rows: int = 128, batch: int = 1,
                    tuning_cache=None, backend: str = "xla") -> Graph:
    """``n_rows`` is the per-event graph size (the occupancy bucket when
    bucketed); ``batch`` the packed micro-batch width (1 = per-event
    executable, unchanged legacy bindings and cache keys)."""
    g = g.clone()

    # 1. per-op kernel binding, dispatched through the registry
    # (cached winner > heuristic; cache-only binders leave a miss
    # untouched → identical bindings)
    ctx = BindContext(n_rows=n_rows, batch=batch, cache=tuning_cache,
                      backend=backend)
    for op in g:
        bind_kernels(op, ctx)

    # 2. retile cancellation: retile(B->A) after retile(A->B) bypasses both
    changed = True
    while changed:
        changed = False
        for op in list(g):
            if op.op_type != "retile":
                continue
            src = g[op.inputs[0]]
            if (src.op_type == "retile"
                    and src.attrs["from"] == op.attrs["to"]
                    and src.attrs["to"] == op.attrs["from"]):
                g.rewire(op.name, src.inputs[0])
                if not g.successors(op.name):
                    g.remove(op.name)
                if not g.successors(src.name):
                    g.remove(src.name)
                changed = True
                break

    # 3. int8 chain fusion: a dense may emit int8 straight into
    # consumers whose specs declare an 8-bit passthrough
    for op in g:
        if op.precision != "int8" or op.op_type != "dense":
            continue
        succ = g.successors(op.name)
        if succ and all(s.precision == "int8"
                        and getattr(op_spec(s.op_type),
                                    "int8_passthrough", False)
                        for s in succ):
            op.attrs_opt["emit_int8"] = True

    # 4. whole-pipeline jit
    g.meta["fuse_pipeline"] = True
    return g
