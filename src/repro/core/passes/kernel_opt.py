"""Kernel-level optimization pass (paper §III-A "Kernel-Level Optimizations").

The paper's insight: at trigger-scale matrix sizes, per-iteration loop
scheduling overhead dominates kernel runtime, so they replace AIE loop
pipelining with loop *flattening* (``chess_flatten_loop``), trading program
memory for issue efficiency. Design ③ applies exactly this at identical
resource allocation.

TPU analogues applied here (design ③):

1. **Kernel flattening** — MXU dense ops below a size threshold switch
   from the grid-looped Pallas variant to the single-cell 'flattened'
   variant (whole operand in VMEM, no K loop). Larger ops get tuned
   (bm, bn, bk) block shapes instead.
2. **Retile cancellation / layout propagation** — adjacent retiles that
   undo each other (lane128 → compact → lane128) are bypassed so a chain
   of MXU kernels hands tensors over in padded layout without copies.
3. **Int8 chain fusion** — inside an 8-bit partition, a dense feeding
   another dense emits int8 directly (requantized in the epilogue with
   the consumer's input scale) instead of dequant→requant through f32;
   scales are folded (the paper's bit-exact 8-bit interior handoff).
4. **Whole-pipeline jit** — the executor compiles the entire graph as one
   XLA program instead of one dispatch per segment (removes the
   heterogeneous-boundary overhead the paper measured in design ①).

Variant/block selection consults the persistent tuning cache
(``repro.tuning``) when one is supplied: a cached winner for the exact
(kernel, shape, dtype, backend) problem overrides the heuristic below,
because LL-GNN-style studies show the latency-optimal config is
shape-dependent and must be searched. With no cache (or on any miss)
the heuristic is used unchanged — an empty cache reproduces today's
bindings bit-for-bit (tested).
"""
from __future__ import annotations

from repro.core.graph_ir import Graph

FLATTEN_ROWS = 512        # rows (hits × microbatch) below which we flatten
FLATTEN_DIM = 1024        # max feature dim for the flattened variant

_FUSED_DENSE_KNOBS = ("variant", "bm", "bn", "bk")


def _pick_block(v: int, cap: int) -> int:
    p = 1
    while p * 2 <= min(v, cap):
        p *= 2
    return p


def fused_dense_shape(op, n_rows: int, batch: int = 1) -> tuple[int, int, int]:
    """(rows, d_in, d_out) of the matmul this op launches per step —
    the tuning-cache problem shape (shared with the autotuner).

    ``batch`` is the micro-batch width of a *batch-packed* executable
    (occupancy-bucketed serving): dense kernels row-pack events, so the
    batch dimension folds into ``rows`` (one launch sees batch·n_rows
    rows). ``batch=1`` is the legacy per-step shape, where rows scale
    with the segment's spatial parallelization P instead."""
    d_in = op.params["w"].shape[0]
    d_out = op.out_dim or op.params["w"].shape[1]
    if batch > 1:
        rows = n_rows * batch
    else:
        rows = n_rows * op.attrs_opt.get("P", 1)
    return rows, d_in, d_out


def fused_dense_dtype(op) -> str:
    """The dtype the executor will actually run this dense in."""
    if op.precision == "int8":
        return "int8"
    if op.precision == "bf16":
        return "bf16"
    return "float32"


def kernel_optimize(g: Graph, *, n_rows: int = 128, batch: int = 1,
                    tuning_cache=None, backend: str = "xla") -> Graph:
    """``n_rows`` is the per-event graph size (the occupancy bucket when
    bucketed); ``batch`` the packed micro-batch width (1 = per-event
    executable, unchanged legacy bindings and cache keys)."""
    g = g.clone()

    # 1. variant selection / block tuning (cached winner > heuristic)
    for op in g:
        if op.template != "fused_dense":
            continue
        rows, d_in, d_out = fused_dense_shape(op, n_rows, batch)
        tuned = None
        if tuning_cache is not None:
            from repro.tuning.cache import fused_dense_key
            tuned = tuning_cache.lookup(fused_dense_key(
                rows, d_in, d_out, fused_dense_dtype(op), backend))
        if tuned is not None:
            for knob in _FUSED_DENSE_KNOBS:
                if knob in tuned:
                    op.attrs_opt[knob] = tuned[knob]
            # provenance: the executor only overrides its built-in int8
            # block defaults for configs that were actually searched
            op.attrs_opt["tuned"] = True
        elif rows <= FLATTEN_ROWS and max(d_in, d_out) <= FLATTEN_DIM:
            op.attrs_opt["variant"] = "flattened"
        else:
            op.attrs_opt["variant"] = "looped"
            op.attrs_opt["bm"] = _pick_block(rows, 512)
            op.attrs_opt["bn"] = _pick_block(d_out, 512)
            op.attrs_opt["bk"] = _pick_block(d_in, 2048)

    # 1b. gravnet row-tile: cache-only (the kernel's own default is the
    # heuristic; a miss leaves attrs_opt untouched → identical bindings)
    if tuning_cache is not None:
        from repro.tuning.cache import (flash_attention_key,
                                        gravnet_block_int8_key,
                                        gravnet_block_key, gravnet_key)
        for op in g:
            if op.op_type != "gravnet_aggregate":
                continue
            tuned = tuning_cache.lookup(gravnet_key(
                n_rows, op.attrs["d_s"], op.attrs["d_f"], op.attrs["k"],
                "float32", backend, batch=batch))
            if tuned is not None and "bm" in tuned:
                op.attrs_opt["bm"] = tuned["bm"]

        # 1c. fused GravNet block: cache-only (bm, bn, bk) bindings —
        # the 5-dim batched key (batch, n, d_hidden, d_f, k); a miss
        # keeps the wrapper's bitwise-safe defaults (whole-operand
        # epilogue, bm = min(n, 128)). An int8 block keys with the
        # dtype-tagged gravnet_block_int8 family — the quantized
        # megakernel's winners never bind onto the f32 kernel or vice
        # versa.
        for op in g:
            if op.op_type != "gravnet_block":
                continue
            if op.precision == "int8":
                key = gravnet_block_int8_key(
                    n_rows, op.attrs["d_hidden"], op.attrs["d_f"],
                    op.attrs["k"], backend, batch=batch)
            else:
                key = gravnet_block_key(
                    n_rows, op.attrs["d_hidden"], op.attrs["d_f"],
                    op.attrs["k"], "float32", backend, batch=batch)
            tuned = tuning_cache.lookup(key)
            if tuned is not None:
                for knob in ("bm", "bn", "bk"):
                    if knob in tuned:
                        op.attrs_opt[knob] = tuned[knob]

        # 1d. attention → flash_attention (bq, bk): cache-only
        for op in g:
            if op.op_type != "attention":
                continue
            tuned = tuning_cache.lookup(flash_attention_key(
                batch, n_rows, n_rows, op.out_dim or 128, "float32",
                backend))
            if tuned is not None:
                for knob in ("bq", "bk"):
                    if knob in tuned:
                        op.attrs_opt[knob] = tuned[knob]

    # 2. retile cancellation: retile(B->A) after retile(A->B) bypasses both
    changed = True
    while changed:
        changed = False
        for op in list(g):
            if op.op_type != "retile":
                continue
            src = g[op.inputs[0]]
            if (src.op_type == "retile"
                    and src.attrs["from"] == op.attrs["to"]
                    and src.attrs["to"] == op.attrs["from"]):
                g.rewire(op.name, src.inputs[0])
                if not g.successors(op.name):
                    g.remove(op.name)
                if not g.successors(src.name):
                    g.remove(src.name)
                changed = True
                break

    # 3. int8 chain fusion
    for op in g:
        if op.precision != "int8" or op.op_type != "dense":
            continue
        succ = g.successors(op.name)
        if succ and all(s.precision == "int8" and s.op_type in
                        ("dense", "relu", "slice", "concat") for s in succ):
            op.attrs_opt["emit_int8"] = True

    # 4. whole-pipeline jit
    g.meta["fuse_pipeline"] = True
    return g
