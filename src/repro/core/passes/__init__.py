from repro.core.passes.fusion import fuse
from repro.core.passes.partition import partition
from repro.core.passes.mapping import map_templates
from repro.core.passes.parallelize import parallelize
from repro.core.passes.kernel_opt import kernel_optimize
from repro.core.passes.verify import verify
