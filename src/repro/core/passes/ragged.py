"""Raggedize pass: rewrite a model graph for padding-free events.

The bucketed deploy path pads every event to its bucket's hit budget;
high-variance occupancy mixes then pay bucket-quantization on every
event (an event with ``cap+1`` hits occupies the next bucket's full
width). ``raggedize`` instead retargets the graph at the **bin-packed
ragged layout** (``data/ragged.py``): whole events first-fit packed
into fixed ``n_hits``-row bins, identified per row by a segment id
(event index, −1 padding) and an in-event slot. A micro-batch of bins
then packs *actual hits*, not bucket-max padding.

Rewrites (model-IR level — the pass runs after fusion, before
partitioning, so every later pass handles the new op family through
its registered :class:`~repro.core.op_registry.OpSpec` generically):

- two new input ops, ``segids`` and ``slots`` (int32 per packed row);
- every ``gravnet_aggregate`` splits into the ragged kernel pair:
  ``knn_build`` (neighbor selection over the learned coordinates,
  masked by segment equality) feeding ``knn_aggregate`` (which keeps
  the aggregate's *name*, so consumers rewire for free);
- every fused ``gravnet_block`` swaps its mask input for ``segids``
  and marks ``attrs["ragged"]`` — the executor dispatches it onto
  ``kernels.ops.gravnet_block_ragged``;
- ``cps`` consumes ``(heads..., segids, slots)`` and marks
  ``attrs["ragged"]`` — the executor scatters packed rows back to
  per-event layout before condensation (whose per-event math is
  unchanged);
- ``batchnorm`` is refused: masked per-event statistics are not
  segment-aware on the packed layout, so raggedizing one would change
  numerics silently.

Dense/eltwise ops are row-independent and pass through untouched —
that row independence (plus bin packing preserving within-event column
order, hence every kNN tie-break) is why the ragged executable matches
the padded one within f32 tolerances on real rows (tested).
"""
from __future__ import annotations

from repro.core.graph_ir import Graph, Operator
from repro.core.op_registry import GraphVerificationError

RAGGED_INPUTS = ("segids", "slots")


def raggedize(g: Graph) -> Graph:
    """The ragged rewrite of ``g`` (a new graph; ``g`` is untouched)."""
    for nm in RAGGED_INPUTS:
        if nm in g.ops:
            raise GraphVerificationError(
                f"raggedize: graph already has an op named {nm!r}")
    for op in g:
        if op.op_type == "batchnorm":
            raise GraphVerificationError(
                f"raggedize: {op.name}: batchnorm statistics are "
                "per-event, not segment-aware — this graph cannot be "
                "raggedized")

    out = Graph()
    for nm in RAGGED_INPUTS:
        out.add(Operator(name=nm, op_type="input", out_dim=1,
                         attrs={"feature": nm}))
    renamed: dict[str, str] = {}
    for op in g:
        if op.op_type == "gravnet_aggregate":
            s_name, f_name, _mask = op.inputs
            knn = Operator(
                name=op.name + ".knn", op_type="knn_build",
                inputs=[renamed.get(s_name, s_name), "segids"],
                attrs={"k": op.attrs["k"], "d_s": op.attrs["d_s"]},
                out_dim=op.attrs["k"], precision=op.precision)
            out.add(knn)
            agg = Operator(
                # keeps the aggregate's name: consumers rewire for free
                name=op.name, op_type="knn_aggregate",
                inputs=[renamed.get(f_name, f_name), knn.name],
                attrs={"k": op.attrs["k"], "scale": op.attrs["scale"],
                       "d_f": op.attrs["d_f"]},
                out_dim=2 * op.attrs["d_f"], precision=op.precision)
            out.add(agg)
            renamed[op.name] = agg.name
        elif op.op_type == "gravnet_block":
            c = op.clone()
            x_name = op.inputs[0]
            c.inputs = [renamed.get(x_name, x_name), "segids"]
            c.attrs["ragged"] = True
            out.add(c)
            renamed[op.name] = c.name
        elif op.op_type == "cps":
            c = op.clone()
            heads = op.inputs[:-1]          # (heads..., mask)
            c.inputs = ([renamed.get(h, h) for h in heads]
                        + ["segids", "slots"])
            c.attrs["ragged"] = True
            out.add(c)
            renamed[op.name] = c.name
        else:
            c = op.clone()
            c.inputs = [renamed.get(i, i) for i in c.inputs]
            out.add(c)
            renamed[op.name] = c.name
    out.meta = dict(g.meta)
    out.meta["ragged"] = True
    out.validate()
    return out
