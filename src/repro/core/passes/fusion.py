"""Operator-fusion pass (paper §III-A "Operator Fusion").

Every rewrite is a registered :class:`~repro.core.op_registry.FusionRule`
keyed on graph-IR op patterns — ``fuse()`` replays the registry in
registration order and knows nothing about any particular model. The
GravNet-block collapse below is simply one registered (opt-in) pattern;
new op families add rules via ``op_registry.register_fusion_rule``
without touching this pass.

Three registered rewrites, all semantics-preserving:

1. **Linear+ReLU → Dense**: a ``linear`` whose *only* consumer is a
   ``relu`` is replaced by one ``dense`` operator carrying the activation
   in its epilogue (lowered onto the fused_dense kernel).

2. **GravNet-block fusion** (opt-in via ``fuse(g, gravnet_block=True)``;
   ``deploy`` enables it by default): the whole

       dense(S-proj) ∥ dense(F-proj) → gravnet_aggregate
           [→ concat(x, agg)] → dense(out)

   chain collapses into ONE ``gravnet_block`` operator, lowered onto the
   Pallas megakernel (``kernels/gravnet_block.py``) — one launch per
   block, zero HBM round-trips for the S/F/aggregate intermediates.
   The rewrite runs *before* the parallel-dense merge (so the S/F
   projections are still separate operators) and refuses chains it
   cannot fuse losslessly: a projection or aggregate output with an
   extra consumer (e.g. a monitor tap), activations on the
   projections, or missing biases all keep the chain unfused. The
   precision guard is *set*-aware: uniform fp/bf16 chains lower onto
   the f32 megakernel, uniform int8 chains with calibration present
   (quantized weights + activation scales) lower onto the quantized
   megakernel, and only genuinely mixed member precisions — or
   uncalibrated int8 (which executes as fp fallback op by op) — keep
   the chain unfused.

3. **Parallel-Dense merge**: sibling ``linear``/``dense`` operators that
   read the same single predecessor with the same activation and precision
   are merged into one operator whose weight matrix is the column-wise
   concatenation; consumers are rewired onto zero-cost ``slice`` views.
   This removes the multicast on the predecessor — on Versal that saved
   scarce AIE memory buffers; on TPU it turns two half-width matmuls into
   one MXU-efficient wide matmul and removes a reread of the activations
   from HBM/VMEM.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.graph_ir import Graph, Operator
from repro.core.op_registry import fusion_rules, register_fusion_rule


def _fuse_linear_relu(g: Graph) -> Graph:
    out = Graph()
    # map from old name -> new name for rewiring
    renamed: dict[str, str] = {}
    ops = list(g.ops.values())
    consumed: set[str] = set()
    for op in ops:
        if op.name in consumed:
            continue
        succ = g.successors(op.name)
        if (op.op_type == "linear" and len(succ) == 1
                and succ[0].op_type == "relu"):
            relu = succ[0]
            fused = op.clone()
            fused.op_type = "dense"
            fused.attrs["activation"] = "relu"
            fused.name = op.name + "+relu"
            fused.inputs = [renamed.get(i, i) for i in op.inputs]
            out.add(fused)
            renamed[op.name] = fused.name
            renamed[relu.name] = fused.name
            consumed.add(relu.name)
        else:
            c = op.clone()
            c.inputs = [renamed.get(i, i) for i in c.inputs]
            if c.op_type == "linear":
                c.op_type = "dense"
                c.attrs.setdefault("activation", "none")
            out.add(c)
            renamed[op.name] = c.name
    out.meta = dict(g.meta)
    out.validate()
    return out


def _match_gravnet_block(g: Graph, agg: Operator):
    """Match the fusable chain around one ``gravnet_aggregate``; returns
    (s_op, f_op, out_op, concat_x, member_names) or None. Every reject
    condition is a *lossless-fusion* guard — see the module docstring."""
    if agg.op_type != "gravnet_aggregate" or len(agg.inputs) != 3:
        return None
    s_name, f_name, _mask_name = agg.inputs
    if s_name == f_name:
        return None
    s_op, f_op = g[s_name], g[f_name]
    for proj in (s_op, f_op):
        if (proj.op_type != "dense" or len(proj.inputs) != 1
                or proj.attrs.get("activation", "none") != "none"
                or not proj.params or "w" not in proj.params
                or "b" not in proj.params):
            return None
        # a projection with another consumer (e.g. a monitor tap on the
        # learned coordinates) must stay materialized
        if [c.name for c in g.successors(proj.name)] != [agg.name]:
            return None
    if s_op.inputs != f_op.inputs:
        return None
    x_name = s_op.inputs[0]
    succ = g.successors(agg.name)
    if len(succ) != 1:     # aggregate output tapped elsewhere
        return None
    nxt = succ[0]
    if nxt.op_type == "concat":
        # the CaloClusterNet shape: out dense consumes concat(x, agg)
        if nxt.inputs != [x_name, agg.name]:
            return None
        csucc = g.successors(nxt.name)
        if len(csucc) != 1:
            return None
        out_op, concat_x = csucc[0], True
        members = [s_name, f_name, agg.name, nxt.name, out_op.name]
    elif nxt.op_type == "dense":
        out_op, concat_x = nxt, False
        members = [s_name, f_name, agg.name, out_op.name]
    else:
        return None
    if (out_op.op_type != "dense" or len(out_op.inputs) != 1
            or not out_op.params or "w" not in out_op.params
            or "b" not in out_op.params):
        return None
    # precision-set-aware guard: a chain is fusable when its members
    # run ONE precision. Uniform fp/bf16 lowers onto the f32 megakernel;
    # uniform int8 lowers onto the quantized megakernel — but only when
    # every dense member is actually *calibrated* (quantized weights
    # present), since an uncalibrated int8 chain executes as fp fallback
    # op by op and fusing it would freeze that accident into one kernel.
    # Genuinely mixed member precisions always stay unfused.
    precs = {s_op.precision, f_op.precision, agg.precision,
             out_op.precision}
    if len(precs) != 1:
        return None
    if precs == {"int8"}:
        calibrated = (all("w_q" in (o.params or {})
                          for o in (s_op, f_op, out_op))
                      and "act_scale" in agg.attrs
                      and "in_scale" in s_op.attrs
                      and "in_scale" in out_op.attrs)
        if not calibrated:
            return None
    return s_op, f_op, out_op, concat_x, members


def _fuse_gravnet_block(g: Graph) -> Graph:
    # collect non-overlapping matches keyed by the chain's last op
    matches: dict[str, tuple] = {}
    drop: set[str] = set()
    for op in g.ops.values():
        m = _match_gravnet_block(g, op)
        if m is None:
            continue
        s_op, f_op, out_op, concat_x, members = m
        if any(n in drop for n in members):
            continue
        matches[out_op.name] = (op, s_op, f_op, out_op, concat_x)
        drop.update(members)
    if not matches:
        return g

    out = Graph()
    renamed: dict[str, str] = {}
    for op in g.ops.values():
        if op.name in matches:
            agg, s_op, f_op, out_op, concat_x = matches[op.name]
            x_name, mask_name = s_op.inputs[0], agg.inputs[2]
            fused = Operator(
                name=agg.name + ".block",
                op_type="gravnet_block",
                inputs=[renamed.get(x_name, x_name),
                        renamed.get(mask_name, mask_name)],
                attrs={
                    "k": agg.attrs["k"], "scale": agg.attrs["scale"],
                    "d_s": agg.attrs["d_s"], "d_f": agg.attrs["d_f"],
                    "d_hidden": int(s_op.params["w"].shape[0]),
                    "activation": out_op.attrs.get("activation", "none"),
                    "concat_x": concat_x,
                },
                params={
                    "ws": s_op.params["w"], "bs": s_op.params["b"],
                    "wf": f_op.params["w"], "bf": f_op.params["b"],
                    "wo": out_op.params["w"], "bo": out_op.params["b"],
                },
                out_dim=out_op.out_dim,
                precision=out_op.precision,
            )
            if out_op.precision == "int8" and "w_q" in out_op.params:
                # already-calibrated chain (fusing post-calibrate):
                # carry the quantized weights and the chain's scales so
                # the fused block is executable without re-calibrating.
                # In the deploy flow fusion runs before calibration and
                # CompiledPipeline.calibrate derives these instead.
                for src, nm in ((s_op, "ws"), (f_op, "wf"), (out_op, "wo")):
                    fused.params[nm + "_q"] = src.params["w_q"]
                    fused.params[nm + "_scale"] = src.params["w_scale"]
                fused.attrs["in_scale"] = s_op.attrs["in_scale"]
                fused.attrs["agg_scale"] = agg.attrs["act_scale"]
                fused.attrs["h_scale"] = out_op.attrs["in_scale"]
                if "act_scale" in out_op.attrs:
                    fused.attrs["act_scale"] = out_op.attrs["act_scale"]
            out.add(fused)
            renamed[out_op.name] = fused.name
        elif op.name in drop:
            continue
        else:
            c = op.clone()
            c.inputs = [renamed.get(i, i) for i in c.inputs]
            out.add(c)
            renamed[op.name] = c.name
    out.meta = dict(g.meta)
    out.validate()
    return out


def _merge_parallel_dense(g: Graph) -> Graph:
    out = Graph()
    renamed: dict[str, str] = {}
    consumed: set[str] = set()
    for op in g.ops.values():
        if op.name in consumed:
            continue
        # find mergeable siblings: dense ops with identical single input,
        # same activation + precision
        if op.op_type == "dense" and len(op.inputs) == 1:
            sibs = [s for s in g.ops.values()
                    if s.op_type == "dense" and s.name != op.name
                    and s.name not in consumed
                    and s.inputs == op.inputs
                    and s.attrs.get("activation") == op.attrs.get("activation")
                    and s.precision == op.precision]
            if sibs:
                group = [op] + sibs
                w = jnp.concatenate([x.params["w"] for x in group], axis=1)
                has_b = all("b" in (x.params or {}) for x in group)
                params = {"w": w}
                if has_b:
                    params["b"] = jnp.concatenate(
                        [x.params["b"] for x in group], axis=0)
                merged = Operator(
                    name="+".join(x.name for x in group),
                    op_type="dense",
                    inputs=[renamed.get(op.inputs[0], op.inputs[0])],
                    attrs=dict(op.attrs),
                    params=params,
                    precision=op.precision,
                    out_dim=sum(x.out_dim for x in group),
                )
                out.add(merged)
                # slice views for each original output
                off = 0
                for x in group:
                    sl = Operator(
                        name=x.name + ".view", op_type="slice",
                        inputs=[merged.name],
                        attrs={"start": off, "size": x.out_dim},
                        out_dim=x.out_dim, precision=x.precision)
                    out.add(sl)
                    renamed[x.name] = sl.name
                    consumed.add(x.name)
                    off += x.out_dim
                continue
        c = op.clone()
        c.inputs = [renamed.get(i, i) for i in c.inputs]
        out.add(c)
        renamed[op.name] = c.name
    out.meta = dict(g.meta)
    out.validate()
    return out


# registration order IS application order: linear+relu first (so the
# block rewrite sees denses carrying their activation), the opt-in
# GravNet-block collapse second (before the merge, so the S/F
# projections are still separate operators), the parallel-dense merge
# last, iterated to a fixed point.
register_fusion_rule("linear_relu", _fuse_linear_relu)
register_fusion_rule("gravnet_block", _fuse_gravnet_block, opt_in=True)
register_fusion_rule("parallel_dense", _merge_parallel_dense,
                     fixpoint=True)


def fuse(g: Graph, *, gravnet_block: bool = False,
         enable: tuple[str, ...] = ()) -> Graph:
    """Replay the registered fusion rules in registration order.

    Opt-in rules run only when named in ``enable`` (or, for the
    GravNet-block collapse, via the legacy ``gravnet_block=True``
    switch, which ``deploy`` sets by default): every fusable
    dense(S)/dense(F) → gravnet_aggregate [→ concat] → dense(out) chain
    then collapses into one ``gravnet_block`` operator.
    ``gravnet_block=False`` reproduces the legacy graphs bit-for-bit.
    """
    enabled = set(enable)
    if gravnet_block:
        enabled.add("gravnet_block")
    for rule in fusion_rules():
        if rule.opt_in and rule.name not in enabled:
            continue
        if rule.fixpoint:
            prev = -1
            while len(g) != prev:
                prev = len(g)
                g = rule.fn(g)
        else:
            g = rule.fn(g)
    return g
