"""Operator-fusion pass (paper §III-A "Operator Fusion").

Two rewrites, both semantics-preserving:

1. **Linear+ReLU → Dense**: a ``linear`` whose *only* consumer is a
   ``relu`` is replaced by one ``dense`` operator carrying the activation
   in its epilogue (lowered onto the fused_dense kernel).

2. **Parallel-Dense merge**: sibling ``linear``/``dense`` operators that
   read the same single predecessor with the same activation and precision
   are merged into one operator whose weight matrix is the column-wise
   concatenation; consumers are rewired onto zero-cost ``slice`` views.
   This removes the multicast on the predecessor — on Versal that saved
   scarce AIE memory buffers; on TPU it turns two half-width matmuls into
   one MXU-efficient wide matmul and removes a reread of the activations
   from HBM/VMEM.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.graph_ir import Graph, Operator


def _fuse_linear_relu(g: Graph) -> Graph:
    out = Graph()
    # map from old name -> new name for rewiring
    renamed: dict[str, str] = {}
    ops = list(g.ops.values())
    consumed: set[str] = set()
    for op in ops:
        if op.name in consumed:
            continue
        succ = g.successors(op.name)
        if (op.op_type == "linear" and len(succ) == 1
                and succ[0].op_type == "relu"):
            relu = succ[0]
            fused = op.clone()
            fused.op_type = "dense"
            fused.attrs["activation"] = "relu"
            fused.name = op.name + "+relu"
            fused.inputs = [renamed.get(i, i) for i in op.inputs]
            out.add(fused)
            renamed[op.name] = fused.name
            renamed[relu.name] = fused.name
            consumed.add(relu.name)
        else:
            c = op.clone()
            c.inputs = [renamed.get(i, i) for i in c.inputs]
            if c.op_type == "linear":
                c.op_type = "dense"
                c.attrs.setdefault("activation", "none")
            out.add(c)
            renamed[op.name] = c.name
    out.meta = dict(g.meta)
    out.validate()
    return out


def _merge_parallel_dense(g: Graph) -> Graph:
    out = Graph()
    renamed: dict[str, str] = {}
    consumed: set[str] = set()
    for op in g.ops.values():
        if op.name in consumed:
            continue
        # find mergeable siblings: dense ops with identical single input,
        # same activation + precision
        if op.op_type == "dense" and len(op.inputs) == 1:
            sibs = [s for s in g.ops.values()
                    if s.op_type == "dense" and s.name != op.name
                    and s.name not in consumed
                    and s.inputs == op.inputs
                    and s.attrs.get("activation") == op.attrs.get("activation")
                    and s.precision == op.precision]
            if sibs:
                group = [op] + sibs
                w = jnp.concatenate([x.params["w"] for x in group], axis=1)
                has_b = all("b" in (x.params or {}) for x in group)
                params = {"w": w}
                if has_b:
                    params["b"] = jnp.concatenate(
                        [x.params["b"] for x in group], axis=0)
                merged = Operator(
                    name="+".join(x.name for x in group),
                    op_type="dense",
                    inputs=[renamed.get(op.inputs[0], op.inputs[0])],
                    attrs=dict(op.attrs),
                    params=params,
                    precision=op.precision,
                    out_dim=sum(x.out_dim for x in group),
                )
                out.add(merged)
                # slice views for each original output
                off = 0
                for x in group:
                    sl = Operator(
                        name=x.name + ".view", op_type="slice",
                        inputs=[merged.name],
                        attrs={"start": off, "size": x.out_dim},
                        out_dim=x.out_dim, precision=x.precision)
                    out.add(sl)
                    renamed[x.name] = sl.name
                    consumed.add(x.name)
                    off += x.out_dim
                continue
        c = op.clone()
        c.inputs = [renamed.get(i, i) for i in c.inputs]
        out.add(c)
        renamed[op.name] = c.name
    out.meta = dict(g.meta)
    out.validate()
    return out


def fuse(g: Graph) -> Graph:
    """Run both fusion rewrites to a fixed point."""
    g = _fuse_linear_relu(g)
    prev = -1
    while len(g) != prev:
        prev = len(g)
        g = _merge_parallel_dense(g)
    return g
