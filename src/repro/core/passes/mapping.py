"""Mapping pass (paper §III-A "Mapping").

Pattern-matches every operator onto an architecture template and
*legalizes layouts*: when the output layout of a producer does not match
the expected input layout of a consumer, a ``retile`` operator is inserted
on that edge (the paper's Retile kernel).

Templates:
    mxu  dense            -> 'fused_dense'   (Pallas kernel; variant picked
                                              by the kernel-opt pass)
    xla  dense            -> 'xla_dense'
    mxu  gravnet_aggregate-> 'gravnet_kernel' (only with tpu_native_gravnet)
    xla  gravnet_aggregate-> 'xla_gravnet'
    *    cps              -> 'xla_cps'
    *    relu/concat/...  -> 'xla_eltwise' / 'xla_concat' / 'xla_slice'

Layouts: MXU templates exchange tensors in ``lane128`` layout (feature dim
zero-padded to a multiple of 128 — the VREG lane width, the analogue of
the AIE window format); XLA templates exchange ``compact`` tensors. A
retile is a real pad or slice op: design point ① pays for every crossing,
the kernel-opt pass later cancels adjacent pad/slice pairs (layout
propagation).
"""
from __future__ import annotations

from repro.core.graph_ir import Graph, Operator

LANE = 128

_TEMPLATES = {
    ("dense", "mxu"): "fused_dense",
    ("dense", "xla"): "xla_dense",
    ("linear", "mxu"): "fused_dense",   # design ① (pre-fusion) linears
    ("linear", "xla"): "xla_dense",
    ("gravnet_aggregate", "mxu"): "gravnet_kernel",
    ("gravnet_aggregate", "xla"): "xla_gravnet",
    ("gravnet_block", "mxu"): "gravnet_block_kernel",
    ("gravnet_block", "xla"): "xla_gravnet_block",
    ("attention", "mxu"): "flash_attention",
    ("attention", "xla"): "xla_attention",
    ("cps", "mxu"): "xla_cps",
    ("cps", "xla"): "xla_cps",
    ("relu", "mxu"): "xla_eltwise",
    ("relu", "xla"): "xla_eltwise",
    ("concat", "mxu"): "xla_concat",
    ("concat", "xla"): "xla_concat",
    ("slice", "mxu"): "xla_slice",
    ("slice", "xla"): "xla_slice",
    ("quant", "mxu"): "xla_quant",
    ("quant", "xla"): "xla_quant",
    ("dequant", "mxu"): "xla_quant",
    ("dequant", "xla"): "xla_quant",
    ("input", "xla"): "io",
    ("output", "xla"): "io",
    ("retile", "mxu"): "xla_retile",
    ("retile", "xla"): "xla_retile",
}

# layout each template produces / expects on its data edges; the fused
# gravnet_block hands tensors over in the MXU lane128 layout on BOTH
# targets (its executor slices/pads its own operands), so a
# dense → block → dense chain needs no retiles at all — the unfused
# chain's concat→dense retile is exactly the layout crossing the
# megakernel eliminates
_PRODUCES = {"fused_dense": "lane128", "gravnet_kernel": "lane128",
             "gravnet_block_kernel": "lane128",
             "xla_gravnet_block": "lane128"}
_EXPECTS = {"fused_dense": "lane128", "gravnet_kernel": "lane128",
            "gravnet_block_kernel": "lane128",
            "xla_gravnet_block": "lane128"}


def map_templates(g: Graph, *, legalize_layouts: bool = True) -> Graph:
    g = g.clone()
    for op in g:
        key = (op.op_type, op.target or "xla")
        if key not in _TEMPLATES:
            raise ValueError(f"no template for {key}")
        op.template = _TEMPLATES[key]
        op.attrs.setdefault("layout",
                            _PRODUCES.get(op.template, "compact"))
    if not legalize_layouts:
        return g

    # insert retile ops on layout-mismatched edges
    out = Graph()
    renamed: dict[str, dict[str, str]] = {}  # producer -> {layout: name}
    for op in g:
        want = _EXPECTS.get(op.template, "compact")
        new_inputs = []
        for inp in op.inputs:
            prod = out[renamed[inp]["_self"]]
            have = prod.attrs.get("layout", "compact")
            if have == want or prod.op_type in ("input",):
                new_inputs.append(prod.name)
                continue
            cache = renamed[inp]
            if want in cache:
                new_inputs.append(cache[want])
                continue
            rt = Operator(
                name=f"{prod.name}->{want}", op_type="retile",
                inputs=[prod.name],
                attrs={"from": have, "to": want, "layout": want},
                out_dim=prod.out_dim, precision=prod.precision,
                target=op.target, segment=op.segment,
            )
            rt.template = "xla_retile"
            out.add(rt)
            cache[want] = rt.name
            new_inputs.append(rt.name)
        c = op.clone()
        c.inputs = new_inputs
        out.add(c)
        renamed[op.name] = {"_self": c.name}
    out.meta = dict(g.meta)
    out.validate()
    return out
