"""Mapping pass (paper §III-A "Mapping").

Maps every operator onto the architecture template its registry spec
declares for the partitioner's target (``OpSpec.templates`` in
``core/op_registry.py``) and *legalizes layouts*: when the output layout
of a producer does not match the expected input layout of a consumer, a
``retile`` operator is inserted on that edge (the paper's Retile
kernel). The pass knows no op type by name — a new op family joins by
declaring its per-target templates in its spec.

Templates (declared per op spec):
    mxu  dense            -> 'fused_dense'   (Pallas kernel; variant picked
                                              by the kernel-opt pass)
    xla  dense            -> 'xla_dense'
    mxu  gravnet_aggregate-> 'gravnet_kernel' (only with tpu_native_gravnet)
    xla  gravnet_aggregate-> 'xla_gravnet'
    mxu  edge_aggregate   -> 'edge_aggregate_kernel' (tpu_native only)
    xla  edge_aggregate   -> 'xla_edge_aggregate'
    *    cps              -> 'xla_cps'
    *    relu/eltwise/... -> 'xla_eltwise' / 'xla_concat' / 'xla_slice'

Layouts come from ``op_registry.template_layout``: MXU templates
exchange tensors in ``lane128`` layout (feature dim zero-padded to a
multiple of 128 — the VREG lane width, the analogue of the AIE window
format); XLA templates exchange ``compact`` tensors. A retile is a real
pad or slice op: design point ① pays for every crossing, the kernel-opt
pass later cancels adjacent pad/slice pairs (layout propagation).
"""
from __future__ import annotations

from repro.core.graph_ir import Graph, Operator
from repro.core.op_registry import (LANE, require_spec,  # noqa: F401
                                    template_layout)


def map_templates(g: Graph, *, legalize_layouts: bool = True) -> Graph:
    g = g.clone()
    for op in g:
        target = op.target or "xla"
        template = require_spec(op).templates.get(target)
        if template is None:
            raise ValueError(f"no template for {(op.op_type, target)}")
        op.template = template
        op.attrs.setdefault("layout", template_layout(op.template))
    if not legalize_layouts:
        return g

    # insert retile ops on layout-mismatched edges
    out = Graph()
    renamed: dict[str, dict[str, str]] = {}  # producer -> {layout: name}
    for op in g:
        want = template_layout(op.template)
        new_inputs = []
        for inp in op.inputs:
            prod = out[renamed[inp]["_self"]]
            have = prod.attrs.get("layout", "compact")
            if have == want or prod.op_type in ("input",):
                new_inputs.append(prod.name)
                continue
            cache = renamed[inp]
            if want in cache:
                new_inputs.append(cache[want])
                continue
            rt = Operator(
                name=f"{prod.name}->{want}", op_type="retile",
                inputs=[prod.name],
                attrs={"from": have, "to": want, "layout": want},
                out_dim=prod.out_dim, precision=prod.precision,
                target=op.target, segment=op.segment,
            )
            rt.template = "xla_retile"
            out.add(rt)
            cache[want] = rt.name
            new_inputs.append(rt.name)
        c = op.clone()
        c.inputs = new_inputs
        out.add(c)
        renamed[op.name] = {"_self": c.name}
    out.meta = dict(g.meta)
    out.validate()
    return out
