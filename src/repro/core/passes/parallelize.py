"""Spatial-parallelization pass (paper §III-A "Spatial Parallelization").

Each partition's operator chain is replicated P ∈ {2^n} times; we run an
exhaustive search for the smallest per-target P that satisfies the target
throughput, minimizing resource use — exactly the paper's scheme, driven
by an analytic throughput model instead of HLS reports.

TPU reinterpretation (DESIGN.md §2 A5): replicas process independent
*events*, so P maps to the event micro-batch width a segment consumes per
step. Segments with smaller P process the pipeline micro-batch in
``B/P`` sequential chunks (a hardware replica draining a stream); the
executor realizes this with ``lax.scan`` over chunks, so the choice is
both faithful and actually executable/benchmarkable.

Cost model per op (per event): peak-normalized max(compute, memory) with a
size-derived MXU efficiency factor (small matrices underfill the 128×128
systolic array — the TPU analogue of the paper's observation that loop
overhead dominates tiny AIE kernels). Weights are VMEM-resident and
amortized across the micro-batch; activations stream per event. The
per-op-type formulas are declared on the op registry specs
(``OpSpec.cost`` / ``OpSpec.mxu_eff`` in ``core/op_registry.py``); this
pass only interprets them.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.graph_ir import Graph
from repro.core.op_registry import default_cost, require_spec
from repro.launch import mesh as hw

VPU_PEAK = 4e12  # v5e vector unit, FLOP/s (non-MXU ops)


@dataclasses.dataclass
class Requirements:
    """The design flow's second input (paper: 'a set of hardware
    requirements such as the target throughput and platform')."""
    target_throughput: float = 1.0e6     # events / s / replica-group
    max_latency_s: float | None = None   # trigger budget (paper: 10 µs)
    platform: str = "tpu"                # 'tpu' | 'cpu'
    design_point: int = 3                # ① ② ③
    n_hits: int = 128                    # graph size per event
    precision_policy: str = "mixed"      # 'fp' | 'mixed' (paper: 16b/8b)
    tpu_native_gravnet: bool = False     # beyond-paper partitioning
    max_p: int = 256


def op_cost(op, n_hits: int, *, precision_bytes: float = 1.0):
    """(flops, act_bytes, weight_bytes) per event, from the op's
    registered cost hook."""
    cost = require_spec(op).cost or default_cost
    return cost(op, n_hits, precision_bytes)


def _mxu_efficiency(op, n_rows: int, n_hits: int = 128) -> float:
    """Fraction of MXU peak a matmul of this size can use."""
    eff = require_spec(op).mxu_eff
    return eff(op, n_rows, n_hits) if eff is not None else 1.0


def segment_time(ops, n_hits: int, p: int, platform: str = "tpu") -> float:
    """Seconds for one segment step processing p events."""
    if platform == "tpu":
        peak_mxu, peak_vpu, bw = hw.PEAK_FLOPS_BF16, VPU_PEAK, hw.HBM_BW
    else:  # calibrated-order-of-magnitude CPU constants (relative use only)
        peak_mxu = peak_vpu = 5e10
        bw = 2e10
    t = 0.0
    for op in ops:
        flops, act, wb = op_cost(op, n_hits)
        is_mm = require_spec(op).mxu_matmul and op.target == "mxu"
        eff = _mxu_efficiency(op, n_hits * p, n_hits) if is_mm else 1.0
        peak = peak_mxu if is_mm else peak_vpu
        t_compute = p * flops / (eff * peak)
        t_mem = (p * act + wb) / bw
        t += max(t_compute, t_mem) + 1e-7  # fixed per-op issue overhead
    return t


def parallelize(g: Graph, req: Requirements) -> Graph:
    """Pick the smallest (P_mxu, P_xla) meeting the throughput target."""
    g = g.clone()
    segs: dict[int, list] = {}
    for op in g:
        segs.setdefault(op.segment or 0, []).append(op)

    def model(p_mxu: int, p_xla: int):
        # Versal runs segments as concurrent spatial pipeline stages; on a
        # single TPU chip (and on CPU) segments serialize, so throughput is
        # micro-batch / TOTAL time (DESIGN.md §2 A5), and the total IS the
        # per-event decision latency the trigger budget constrains.
        # Cross-stage pipelining returns at pod scale via data replicas.
        b = max(p_mxu, p_xla)  # pipeline micro-batch width
        total = 0.0
        for ops in segs.values():
            tgt = ops[0].target
            p = p_mxu if tgt == "mxu" else p_xla
            chunks = b // p
            total += chunks * segment_time(ops, req.n_hits, p, req.platform)
        return (b / total if total > 0 else float("inf")), total

    max_lat = req.max_latency_s or float("inf")
    pows = [2 ** i for i in range(int(math.log2(req.max_p)) + 1)]
    best = None
    fallback = None
    for p_mxu in pows:
        for p_xla in pows:
            if max(p_mxu, p_xla) % min(p_mxu, p_xla) != 0:
                continue
            tp, lat = model(p_mxu, p_xla)
            if lat <= max_lat and (fallback is None or tp > fallback[3]):
                fallback = (p_mxu + p_xla, p_mxu, p_xla, tp, lat)
            if tp >= req.target_throughput and lat <= max_lat:
                cost = p_mxu + p_xla  # resource proxy (paper: minimize P)
                if best is None or cost < best[0]:
                    best = (cost, p_mxu, p_xla, tp, lat)
    if best is None:
        # target unreachable within the latency budget: best-throughput
        # latency-feasible point (or P=1 if even that busts the budget)
        best = fallback or (2, 1, 1) + model(1, 1)
    _, p_mxu, p_xla, tp, lat = best
    for op in g:
        op.attrs_opt["P"] = p_mxu if op.target == "mxu" else p_xla
    g.meta["parallelization"] = {
        "P_mxu": p_mxu, "P_xla": p_xla, "microbatch": max(p_mxu, p_xla),
        "model_throughput_ev_s": tp, "model_latency_s": lat,
        "target": req.target_throughput, "max_latency_s": max_lat,
    }
    return g
