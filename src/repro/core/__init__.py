"""The paper's primary contribution: a semi-automated deployment flow
(operator fusion -> partitioning -> mapping -> spatial parallelization ->
kernel-level optimization) for real-time dynamic-GNN trigger inference,
plus CaloClusterNet itself and the object-condensation machinery."""
from repro.core.graph_ir import Graph, Operator
from repro.core.passes.parallelize import Requirements
from repro.core.pipeline import (BucketedPipeline, CompiledPipeline, deploy,
                                 deploy_bucketed)
from repro.core import caloclusternet, condensation, quantization
