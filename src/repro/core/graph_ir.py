"""Dataflow-graph IR for the deployment flow.

Mirrors the paper's internal representation: nodes are operators
(individual layers), edges are data dependencies. Every pass
(fusion, partitioning, mapping, spatial parallelization, kernel-level
optimization) transforms this graph until it is lowered to an executable.

Operator taxonomy (paper §III-A):
  regular, statically-scheduled access  -> eligible for the MXU ("AIE")
      linear, dense (fused linear+act), relu, concat, slice, retile,
      quant, dequant
  irregular, data-dependent access      -> pinned to XLA/VPU ("FPGA")
      gravnet_aggregate (kNN gather), cps (condensation point selection),
      input, output (DDR interface analogues)

The TPU-native GravNet kernel (argmin + one-hot matmul) makes
``gravnet_aggregate`` statically schedulable; the partitioner can be told
so via ``tpu_native_gravnet=True`` — that reclassification is a
beyond-paper optimization measured separately in the benchmarks.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any

# Operator types with regular (statically scheduled) access patterns.
REGULAR_OPS = frozenset({
    "linear", "dense", "relu", "concat", "slice", "retile", "quant",
    "dequant", "attention",
})
# Irregular / data-dependent ops (the paper pins these to the FPGA).
# ``gravnet_block`` (the fused dense→aggregate→dense megakernel) carries
# the aggregation's data-dependent selection, so it classifies exactly
# like ``gravnet_aggregate``: irregular faithfully, regular under the
# TPU-native reformulation.
IRREGULAR_OPS = frozenset({"gravnet_aggregate", "gravnet_block", "cps",
                           "input", "output"})


@dataclass
class Operator:
    name: str
    op_type: str
    inputs: list[str] = field(default_factory=list)
    attrs: dict[str, Any] = field(default_factory=dict)
    params: dict[str, Any] | None = None      # jnp arrays (w, b, scales)
    target: str | None = None                 # 'mxu' | 'xla' (partitioner)
    segment: int | None = None                # pipeline segment id
    out_dim: int | None = None                # feature dim of the output
    precision: str = "fp"                     # 'fp' | 'bf16' | 'int8'
    template: str | None = None               # mapping result
    attrs_opt: dict[str, Any] = field(default_factory=dict)  # kernel knobs

    def clone(self) -> "Operator":
        return dataclasses.replace(
            self,
            inputs=list(self.inputs),
            attrs=dict(self.attrs),
            params=None if self.params is None else dict(self.params),
            attrs_opt=dict(self.attrs_opt),
        )


class Graph:
    """Ordered operator graph. Insertion order must be a topological order
    (validated); passes keep it that way."""

    def __init__(self, ops: list[Operator] | None = None):
        self.ops: dict[str, Operator] = {}
        self.meta: dict[str, Any] = {}
        for op in ops or []:
            self.add(op)

    # ------------------------------------------------------------ build ----
    def add(self, op: Operator) -> Operator:
        if op.name in self.ops:
            raise ValueError(f"duplicate operator {op.name}")
        for inp in op.inputs:
            if inp not in self.ops:
                raise ValueError(
                    f"{op.name} depends on undefined {inp} (topo order)")
        self.ops[op.name] = op
        return op

    def clone(self) -> "Graph":
        g = Graph([op.clone() for op in self.ops.values()])
        g.meta = dict(self.meta)
        return g

    # ------------------------------------------------------------ query ----
    def __iter__(self):
        return iter(self.ops.values())

    def __getitem__(self, name: str) -> Operator:
        return self.ops[name]

    def __len__(self):
        return len(self.ops)

    def successors(self, name: str) -> list[Operator]:
        return [op for op in self.ops.values() if name in op.inputs]

    def topo_order(self) -> list[Operator]:
        return list(self.ops.values())

    def inputs(self) -> list[Operator]:
        return [op for op in self.ops.values() if op.op_type == "input"]

    def outputs(self) -> list[Operator]:
        return [op for op in self.ops.values() if op.op_type == "output"]

    # -------------------------------------------------------- transforms ----
    def rewire(self, old: str, new: str) -> None:
        """Point every consumer of ``old`` at ``new``."""
        for op in self.ops.values():
            op.inputs = [new if i == old else i for i in op.inputs]

    def remove(self, name: str) -> None:
        if self.successors(name):
            raise ValueError(f"cannot remove {name}: has consumers")
        del self.ops[name]

    def insert_after(self, anchor: str, op: Operator) -> Operator:
        """Insert ``op`` right after ``anchor`` in the order (op must only
        depend on ops at or before anchor)."""
        items = list(self.ops.items())
        idx = [i for i, (n, _) in enumerate(items) if n == anchor][0]
        items.insert(idx + 1, (op.name, op))
        self.ops = dict(items)
        return op

    def validate(self) -> None:
        seen: set[str] = set()
        for op in self.ops.values():
            for inp in op.inputs:
                if inp not in seen:
                    raise ValueError(f"{op.name} reads {inp} before def")
            seen.add(op.name)

    # ------------------------------------------------------------ stats ----
    def multicast_ops(self) -> list[str]:
        """Operators whose output fans out to >1 consumer (the paper's
        AIE-buffer-pressure hazard that fusion removes)."""
        return [op.name for op in self.ops.values()
                if len(self.successors(op.name)) > 1
                and op.op_type not in ("input",)]

    def summary(self) -> str:
        lines = []
        for op in self.ops.values():
            tgt = op.target or "?"
            seg = "-" if op.segment is None else str(op.segment)
            lines.append(f"{op.name:28s} {op.op_type:18s} tgt={tgt:3s} "
                         f"seg={seg:2s} prec={op.precision:5s} "
                         f"in={','.join(op.inputs)}")
        return "\n".join(lines)


def is_regular(op: Operator, *, tpu_native_gravnet: bool = False) -> bool:
    if op.op_type in REGULAR_OPS:
        return True
    if tpu_native_gravnet and op.op_type in ("gravnet_aggregate",
                                             "gravnet_block"):
        return True
    return False
