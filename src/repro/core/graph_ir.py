"""Dataflow-graph IR for the deployment flow.

Mirrors the paper's internal representation: nodes are operators
(individual layers), edges are data dependencies. Every pass
(fusion, partitioning, mapping, spatial parallelization, kernel-level
optimization) transforms this graph until it is lowered to an executable.

Operator taxonomy (paper §III-A): every op type is *declared once* in
``repro.core.op_registry`` (regular vs irregular access, per-target
templates, shape inference, cost model, kernel binders), and the passes
dispatch on those declarations. ``REGULAR_OPS``/``IRREGULAR_OPS`` below
are live views of the registry, kept for callers of the original API.

The TPU-native GravNet kernel (argmin + one-hot matmul) makes
``gravnet_aggregate`` statically schedulable; the partitioner can be told
so via ``tpu_native_gravnet=True`` — that reclassification is a
beyond-paper optimization measured separately in the benchmarks.

Models enter the flow through the **exporter protocol**: a model module
ships a ``to_graph(params, cfg) -> Graph`` function and registers it
with :func:`register_exporter`, after which the whole deploy → serving
stack can host it by name (see ``launch/serve.py --model``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Protocol, runtime_checkable

from repro.core import op_registry as _reg

# live views of the registry, for callers of the original constants
REGULAR_OPS = _reg.regular_ops()
IRREGULAR_OPS = _reg.irregular_ops()


@dataclass
class Operator:
    name: str
    op_type: str
    inputs: list[str] = field(default_factory=list)
    attrs: dict[str, Any] = field(default_factory=dict)
    params: dict[str, Any] | None = None      # jnp arrays (w, b, scales)
    target: str | None = None                 # 'mxu' | 'xla' (partitioner)
    segment: int | None = None                # pipeline segment id
    out_dim: int | None = None                # feature dim of the output
    precision: str = "fp"                     # 'fp' | 'bf16' | 'int8'
    template: str | None = None               # mapping result
    attrs_opt: dict[str, Any] = field(default_factory=dict)  # kernel knobs

    def clone(self) -> "Operator":
        return dataclasses.replace(
            self,
            inputs=list(self.inputs),
            attrs=dict(self.attrs),
            params=None if self.params is None else dict(self.params),
            attrs_opt=dict(self.attrs_opt),
        )


class Graph:
    """Ordered operator graph. Insertion order must be a topological order
    (validated); passes keep it that way."""

    def __init__(self, ops: list[Operator] | None = None):
        self.ops: dict[str, Operator] = {}
        self.meta: dict[str, Any] = {}
        for op in ops or []:
            self.add(op)

    # ------------------------------------------------------------ build ----
    def add(self, op: Operator) -> Operator:
        if op.name in self.ops:
            raise ValueError(f"duplicate operator {op.name}")
        for inp in op.inputs:
            if inp not in self.ops:
                raise ValueError(
                    f"{op.name} depends on undefined {inp} (topo order)")
        self.ops[op.name] = op
        return op

    def clone(self) -> "Graph":
        g = Graph([op.clone() for op in self.ops.values()])
        g.meta = dict(self.meta)
        return g

    # ------------------------------------------------------------ query ----
    def __iter__(self):
        return iter(self.ops.values())

    def __getitem__(self, name: str) -> Operator:
        return self.ops[name]

    def __len__(self):
        return len(self.ops)

    def successors(self, name: str) -> list[Operator]:
        return [op for op in self.ops.values() if name in op.inputs]

    def topo_order(self) -> list[Operator]:
        return list(self.ops.values())

    def inputs(self) -> list[Operator]:
        return [op for op in self.ops.values() if op.op_type == "input"]

    def outputs(self) -> list[Operator]:
        return [op for op in self.ops.values() if op.op_type == "output"]

    # -------------------------------------------------------- transforms ----
    def rewire(self, old: str, new: str) -> None:
        """Point every consumer of ``old`` at ``new``."""
        for op in self.ops.values():
            op.inputs = [new if i == old else i for i in op.inputs]

    def remove(self, name: str) -> None:
        if self.successors(name):
            raise ValueError(f"cannot remove {name}: has consumers")
        del self.ops[name]

    def insert_after(self, anchor: str, op: Operator) -> Operator:
        """Insert ``op`` right after ``anchor`` in the order (op must only
        depend on ops at or before anchor)."""
        items = list(self.ops.items())
        idx = [i for i, (n, _) in enumerate(items) if n == anchor][0]
        items.insert(idx + 1, (op.name, op))
        self.ops = dict(items)
        return op

    def validate(self) -> None:
        seen: set[str] = set()
        for op in self.ops.values():
            for inp in op.inputs:
                if inp not in seen:
                    raise ValueError(f"{op.name} reads {inp} before def")
            seen.add(op.name)

    # ------------------------------------------------------------ stats ----
    def multicast_ops(self) -> list[str]:
        """Operators whose output fans out to >1 consumer (the paper's
        AIE-buffer-pressure hazard that fusion removes)."""
        return [op.name for op in self.ops.values()
                if len(self.successors(op.name)) > 1
                and op.op_type not in ("input",)]

    def summary(self) -> str:
        lines = []
        for op in self.ops.values():
            tgt = op.target or "?"
            seg = "-" if op.segment is None else str(op.segment)
            lines.append(f"{op.name:28s} {op.op_type:18s} tgt={tgt:3s} "
                         f"seg={seg:2s} prec={op.precision:5s} "
                         f"in={','.join(op.inputs)}")
        return "\n".join(lines)


def is_regular(op: Operator, *, tpu_native_gravnet: bool = False) -> bool:
    return _reg.is_regular(op, tpu_native_gravnet=tpu_native_gravnet)


# ------------------------------------------------------------------------
# exporter protocol: how a model joins the deploy flow
@runtime_checkable
class GraphExporter(Protocol):
    """A model-side export entry point: build the dataflow IR for one
    trained parameter set. Implementations must return a validated
    graph whose op types are all registered in ``core.op_registry``
    and set ``g.meta['config']`` to the model config."""

    def __call__(self, params: Any, cfg: Any) -> Graph: ...


_EXPORTERS: dict[str, GraphExporter] = {}


def register_exporter(name: str, fn: GraphExporter) -> GraphExporter:
    """Register a model's ``to_graph`` under a stable name."""
    if name in _EXPORTERS:
        raise ValueError(f"exporter {name!r} already registered")
    _EXPORTERS[name] = fn
    return fn


def exporters() -> tuple[str, ...]:
    return tuple(sorted(_EXPORTERS))


def export_graph(name: str, params: Any, cfg: Any) -> Graph:
    """Export a registered model to graph IR, rejecting graphs with op
    types no pass recognizes (same preflight ``deploy()`` runs)."""
    if name not in _EXPORTERS:
        raise KeyError(f"no exporter {name!r}; registered: "
                       f"{', '.join(exporters()) or '(none)'}")
    g = _EXPORTERS[name](params, cfg)
    bad = _reg.unknown_ops(g)
    if bad:
        listing = ", ".join(f"{n} ({t!r})" for n, t in bad)
        raise _reg.UnknownOperatorError(
            f"exporter {name!r} emitted unregistered op types: {listing}")
    return g
