"""Operator registry — the declarations the deploy passes dispatch on.

Every graph-IR op type is described once, here, by an :class:`OpSpec`:
whether its access pattern is regular (MXU-eligible), which
architecture template it lowers onto per target, how to infer its
output feature dim (verification), its analytic cost model
(parallelization), and how it binds kernel launch knobs / tuning-cache
keys (kernel-level optimization). The passes in ``core/passes`` are
pattern-keyed interpreters over these declarations: none of them knows
any model by name, and opening the flow to a new op family (e.g. the
edge-based message-passing GNNs) means registering specs — not editing
five pass bodies.

Fusion is the same story at the subgraph level: rewrites such as the
GravNet-block collapse register as :class:`FusionRule` entries
(``core/passes/fusion.py``) and ``fuse()`` replays them in
registration order.

Registered op families:

- classic dataflow: ``input``/``output``, ``linear``/``dense``,
  ``relu``, ``concat``, ``slice``, ``retile``, ``quant``/``dequant``
- CaloClusterNet irregulars: ``gravnet_aggregate``, ``gravnet_block``
  (the fused megakernel), ``cps``
- attention: ``attention`` (flash kernel)
- edge-based message passing: ``gather_edge`` (endpoint gather by an
  explicit edge list), ``edge_aggregate`` (masked segment-sum/mean of
  per-edge messages into nodes), ``eltwise`` (n-ary elementwise
  algebra), ``batchnorm`` (masked per-event batch normalization)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable


class GraphVerificationError(ValueError):
    """A graph failed shape/legality checks (see passes/verify.py)."""


class UnknownOperatorError(GraphVerificationError):
    """An op type absent from the registry — no pass can handle it."""


@dataclasses.dataclass(frozen=True)
class BindContext:
    """What the kernel-opt pass knows when binding launch knobs."""
    n_rows: int
    batch: int = 1
    cache: Any = None        # repro.tuning.cache.TuningCache | None
    backend: str = "xla"


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """Declarative description of one op type, consumed by the passes.

    ``infer(op, dims, g)``     -> output feature dim (verify pass)
    ``cost(op, n_hits, pb)``   -> (flops, act_bytes, weight_bytes)
    ``mxu_eff(op, rows, n)``   -> fraction of MXU peak (matmuls only)
    ``bind(op, ctx)``          -> write launch knobs into op.attrs_opt
    ``tuning_key(op, n, be, b)``-> KernelKey | None (autotuner problems)
    """
    op_type: str
    regular: bool = False            # statically scheduled -> MXU-eligible
    tpu_native_regular: bool = False  # regular under tpu_native_gravnet
    templates: dict[str, str] = dataclasses.field(default_factory=dict)
    infer: Callable | None = None
    cost: Callable | None = None
    mxu_matmul: bool = False         # cost model treats it as a matmul
    mxu_eff: Callable | None = None
    bind: Callable | None = None
    tuning_key: Callable | None = None
    int8_passthrough: bool = False   # int8 chain fusion may emit through it


@dataclasses.dataclass(frozen=True)
class FusionRule:
    """One registered subgraph rewrite, replayed by ``fuse()`` in
    registration order. ``opt_in`` rules run only when the caller
    enables them by name; ``fixpoint`` rules iterate until the graph
    stops shrinking."""
    name: str
    fn: Callable  # Graph -> Graph
    opt_in: bool = False
    fixpoint: bool = False


_REGISTRY: dict[str, OpSpec] = {}
_FUSION_RULES: list[FusionRule] = []


def register_op(spec: OpSpec) -> OpSpec:
    if spec.op_type in _REGISTRY:
        raise ValueError(f"op type {spec.op_type!r} already registered")
    _REGISTRY[spec.op_type] = spec
    return spec


def op_spec(op_type: str) -> OpSpec | None:
    return _REGISTRY.get(op_type)


def require_spec(op) -> OpSpec:
    """Spec for ``op`` (an Operator), or the canonical unknown-op error."""
    spec = _REGISTRY.get(op.op_type)
    if spec is None:
        raise UnknownOperatorError(
            f"{op.name}: unknown op {op.op_type!r}")
    return spec


def registered_ops() -> frozenset[str]:
    return frozenset(_REGISTRY)


def regular_ops() -> frozenset[str]:
    return frozenset(t for t, s in _REGISTRY.items() if s.regular)


def irregular_ops() -> frozenset[str]:
    return frozenset(t for t, s in _REGISTRY.items() if not s.regular)


def is_regular(op, *, tpu_native_gravnet: bool = False) -> bool:
    spec = require_spec(op)
    return spec.regular or (tpu_native_gravnet and spec.tpu_native_regular)


def unknown_ops(g) -> list[tuple[str, str]]:
    """(node name, op type) for every op the registry does not know."""
    return [(op.name, op.op_type) for op in g
            if op.op_type not in _REGISTRY]


def register_fusion_rule(name: str, fn: Callable, *, opt_in: bool = False,
                         fixpoint: bool = False) -> FusionRule:
    if any(r.name == name for r in _FUSION_RULES):
        raise ValueError(f"fusion rule {name!r} already registered")
    rule = FusionRule(name, fn, opt_in=opt_in, fixpoint=fixpoint)
    _FUSION_RULES.append(rule)
    return rule


def fusion_rules() -> tuple[FusionRule, ...]:
    return tuple(_FUSION_RULES)


# ------------------------------------------------------------------------
# template layouts: what each template produces / expects on data edges.
# MXU templates exchange ``lane128`` tensors (feature dim zero-padded to
# the VREG lane width); everything else exchanges ``compact`` tensors.
# The fused gravnet_block hands tensors over in lane128 on BOTH targets
# (its executor slices/pads its own operands) — see passes/mapping.py.
LANE = 128
TEMPLATE_LAYOUT = {"fused_dense": "lane128", "gravnet_kernel": "lane128",
                   "gravnet_block_kernel": "lane128",
                   "xla_gravnet_block": "lane128"}


def template_layout(template: str | None) -> str:
    return TEMPLATE_LAYOUT.get(template, "compact")


# ========================================================================
# shape inference (verify pass arms)
# ========================================================================
def _infer_input(op, dims, g):
    if op.out_dim is None:
        raise GraphVerificationError(f"{op.name}: input needs out_dim")
    return op.out_dim


def _infer_dense(op, dims, g):
    if not op.params or "w" not in op.params:
        raise GraphVerificationError(f"{op.name}: missing weight")
    d_in, d_out = op.params["w"].shape
    got = dims[op.inputs[0]]
    if got != d_in:
        raise GraphVerificationError(
            f"{op.name}: weight expects d_in={d_in}, producer "
            f"{op.inputs[0]!r} provides {got}")
    if "b" in op.params and op.params["b"].shape != (d_out,):
        raise GraphVerificationError(f"{op.name}: bias shape "
                                     f"{op.params['b'].shape}")
    return d_out


def _infer_same(op, dims, g):
    return dims[op.inputs[0]]


def _infer_retile(op, dims, g):
    return op.out_dim or dims[op.inputs[0]]


def _infer_concat(op, dims, g):
    return sum(dims[i] for i in op.inputs)


def _infer_slice(op, dims, g):
    st, sz = op.attrs["start"], op.attrs["size"]
    if st + sz > dims[op.inputs[0]]:
        raise GraphVerificationError(
            f"{op.name}: slice [{st}:{st + sz}] exceeds producer "
            f"dim {dims[op.inputs[0]]}")
    return sz


def _infer_gravnet_aggregate(op, dims, g):
    ins = op.inputs
    if len(ins) != 3:
        raise GraphVerificationError(
            f"{op.name}: needs (s, f, mask) inputs")
    ds, df = op.attrs.get("d_s"), op.attrs.get("d_f")
    if dims[ins[0]] != ds or dims[ins[1]] != df:
        raise GraphVerificationError(
            f"{op.name}: S/FLR dims ({dims[ins[0]]},{dims[ins[1]]})"
            f" != attrs ({ds},{df})")
    return 2 * df


def _infer_gravnet_block(op, dims, g):
    ins = op.inputs
    if len(ins) != 2:
        raise GraphVerificationError(
            f"{op.name}: needs (x, mask) inputs")
    need = ("ws", "bs", "wf", "bf", "wo", "bo")
    if not op.params or any(p not in op.params for p in need):
        raise GraphVerificationError(
            f"{op.name}: gravnet_block needs params {need}")
    dh = op.attrs.get("d_hidden")
    ds, df = op.attrs.get("d_s"), op.attrs.get("d_f")
    if dims[ins[0]] != dh:
        raise GraphVerificationError(
            f"{op.name}: x provides {dims[ins[0]]}, expects "
            f"d_hidden={dh}")
    if op.params["ws"].shape != (dh, ds):
        raise GraphVerificationError(
            f"{op.name}: ws shape {op.params['ws'].shape} != "
            f"({dh},{ds})")
    if op.params["wf"].shape != (dh, df):
        raise GraphVerificationError(
            f"{op.name}: wf shape {op.params['wf'].shape} != "
            f"({dh},{df})")
    dcat = (dh + 2 * df if op.attrs.get("concat_x", True)
            else 2 * df)
    if op.params["wo"].shape[0] != dcat:
        raise GraphVerificationError(
            f"{op.name}: wo expects {op.params['wo'].shape[0]} "
            f"inputs, block provides {dcat}")
    return int(op.params["wo"].shape[1])


def _infer_attention(op, dims, g):
    ins = op.inputs
    if len(ins) != 3:
        raise GraphVerificationError(
            f"{op.name}: needs (q, k, v) inputs")
    if len({dims[i] for i in ins}) != 1:
        raise GraphVerificationError(
            f"{op.name}: q/k/v dims differ: "
            f"{[dims[i] for i in ins]}")
    return dims[ins[0]]


def _infer_cps(op, dims, g):
    heads = op.attrs.get("head_names", [])
    # ragged form (passes/ragged.py) consumes (heads..., segids, slots)
    aux = 2 if op.attrs.get("ragged") else 1
    if len(op.inputs) != len(heads) + aux:
        raise GraphVerificationError(
            f"{op.name}: expects {len(heads)} heads + "
            f"{'segids/slots' if aux == 2 else 'mask'}, got "
            f"{len(op.inputs)} inputs")
    return op.out_dim or 1


def _infer_output(op, dims, g):
    return sum(dims[i] for i in op.inputs
               if g[i].op_type != "cps")


def _infer_knn_build(op, dims, g):
    if len(op.inputs) != 2:
        raise GraphVerificationError(
            f"{op.name}: needs (s, segids) inputs")
    ds = op.attrs.get("d_s")
    if dims[op.inputs[0]] != ds:
        raise GraphVerificationError(
            f"{op.name}: S dim {dims[op.inputs[0]]} != attrs d_s={ds}")
    return op.attrs["k"]


def _infer_knn_aggregate(op, dims, g):
    if len(op.inputs) != 2:
        raise GraphVerificationError(
            f"{op.name}: needs (f, knn) inputs")
    df = op.attrs.get("d_f")
    if dims[op.inputs[0]] != df:
        raise GraphVerificationError(
            f"{op.name}: FLR dim {dims[op.inputs[0]]} != attrs "
            f"d_f={df}")
    if g[op.inputs[1]].op_type != "knn_build":
        raise GraphVerificationError(
            f"{op.name}: neighbor input {op.inputs[1]!r} must be a "
            "knn_build op")
    return 2 * df


def _infer_gather_edge(op, dims, g):
    if len(op.inputs) != 2:
        raise GraphVerificationError(
            f"{op.name}: needs (nodes, edge_index) inputs")
    if op.attrs.get("endpoint") not in ("src", "dst"):
        raise GraphVerificationError(
            f"{op.name}: endpoint must be 'src' or 'dst', got "
            f"{op.attrs.get('endpoint')!r}")
    return dims[op.inputs[0]]


def _infer_edge_aggregate(op, dims, g):
    if len(op.inputs) not in (2, 3):
        raise GraphVerificationError(
            f"{op.name}: needs (messages, edge_index[, edge_mask]) "
            "inputs")
    if op.attrs.get("reduce", "sum") not in ("sum", "mean"):
        raise GraphVerificationError(
            f"{op.name}: reduce must be 'sum' or 'mean', got "
            f"{op.attrs.get('reduce')!r}")
    return dims[op.inputs[0]]


_ELTWISE_FNS = ("add", "mul", "div", "sigmoid", "relu", "mask",
                "add_const", "l2norm")


def _infer_eltwise(op, dims, g):
    fn = op.attrs.get("fn")
    if fn not in _ELTWISE_FNS:
        raise GraphVerificationError(
            f"{op.name}: eltwise fn must be one of {_ELTWISE_FNS}, "
            f"got {fn!r}")
    if fn in ("add", "mul", "div"):
        if len({dims[i] for i in op.inputs}) != 1:
            raise GraphVerificationError(
                f"{op.name}: eltwise {fn} operand dims differ: "
                f"{[dims[i] for i in op.inputs]}")
    if fn == "mask" and len(op.inputs) != 2:
        raise GraphVerificationError(
            f"{op.name}: eltwise mask needs (x, mask) inputs")
    return dims[op.inputs[0]]


def _infer_batchnorm(op, dims, g):
    if len(op.inputs) != 2:
        raise GraphVerificationError(
            f"{op.name}: needs (x, mask) inputs")
    return dims[op.inputs[0]]


# ========================================================================
# analytic cost model (parallelize pass arms): (flops, act, wb) / event
# ========================================================================
def _cost_dense(op, n_hits, pb):
    d_out = op.out_dim or 1
    d_in = op.params["w"].shape[0] if op.params else d_out
    flops = 2.0 * n_hits * d_in * d_out
    act = n_hits * (d_in + d_out) * pb
    wb = d_in * d_out * pb
    return flops, act, wb


def _cost_gravnet_aggregate(op, n_hits, pb):
    d_out = op.out_dim or 1
    ds = op.attrs.get("d_s", 4)
    df = op.attrs.get("d_f", d_out // 2)
    k = op.attrs.get("k", 8)
    flops = 2.0 * n_hits * n_hits * (ds + k * df) + 10.0 * n_hits * k
    act = n_hits * (ds + df + d_out) * pb
    return flops, act, 0.0


def _cost_gravnet_block(op, n_hits, pb):
    # fused dense(S)∥dense(F) → aggregate → dense(out): compute is
    # the sum of the parts, but only x and the block output touch
    # HBM — the S/F/aggregate intermediates stay in VMEM (the point
    # of the megakernel)
    d_out = op.out_dim or 1
    dh = op.attrs.get("d_hidden", 64)
    ds = op.attrs.get("d_s", 4)
    df = op.attrs.get("d_f", d_out // 2)
    k = op.attrs.get("k", 8)
    dcat = dh + 2 * df if op.attrs.get("concat_x", True) else 2 * df
    flops = (2.0 * n_hits * dh * (ds + df)              # prologue
             + 2.0 * n_hits * n_hits * (ds + k * df)    # aggregate
             + 10.0 * n_hits * k
             + 2.0 * n_hits * dcat * d_out)             # epilogue
    act = n_hits * (dh + d_out) * pb
    wb = (dh * (ds + df) + dcat * d_out) * pb
    return flops, act, wb


def _cost_attention(op, n_hits, pb):
    d = op.out_dim or 1
    flops = 4.0 * n_hits * n_hits * d + 10.0 * n_hits * n_hits
    act = n_hits * 4.0 * d * pb
    return flops, act, 0.0


def _cost_cps(op, n_hits, pb):
    kmax = op.attrs.get("k_max", 8)
    flops = 20.0 * n_hits * kmax + 10.0 * n_hits * math.log2(max(n_hits, 2))
    act = n_hits * 8.0 * pb
    return flops, act, 0.0


def _cost_knn_build(op, n_hits, pb):
    # gravnet_aggregate's selection half: the (n, n) distance matmul
    # plus k argmin/knockout sweeps
    ds = op.attrs.get("d_s", 4)
    k = op.attrs.get("k", 8)
    flops = 2.0 * n_hits * n_hits * ds + 10.0 * n_hits * k
    act = n_hits * (ds + 2.0 * k) * pb
    return flops, act, 0.0


def _cost_knn_aggregate(op, n_hits, pb):
    # gravnet_aggregate's aggregation half: k one-hot (n, n) @ (n, df)
    # selection matmuls plus the weighting sweeps
    d_out = op.out_dim or 1
    df = op.attrs.get("d_f", d_out // 2)
    k = op.attrs.get("k", 8)
    flops = 2.0 * n_hits * n_hits * k * df + 10.0 * n_hits * k
    act = n_hits * (df + d_out + 2.0 * k) * pb
    return flops, act, 0.0


def _cost_eltwise_like(op, n_hits, pb):
    d_out = op.out_dim or 1
    flops = 1.0 * n_hits * d_out
    act = 2.0 * n_hits * d_out * pb
    return flops, act, 0.0


def _n_edges(op, n_hits):
    # exporters record the padded edge count; fall back to a sparse
    # power-law-ish estimate when absent
    return int(op.attrs.get("n_edges") or 4 * n_hits)


def _cost_gather_edge(op, n_hits, pb):
    d_out = op.out_dim or 1
    e = _n_edges(op, n_hits)
    flops = 1.0 * e * d_out
    act = (n_hits * d_out + e * (d_out + 2.0)) * pb
    return flops, act, 0.0


def _cost_edge_aggregate(op, n_hits, pb):
    d_out = op.out_dim or 1
    e = _n_edges(op, n_hits)
    flops = 2.0 * e * d_out + 1.0 * n_hits * d_out
    act = (e * d_out + n_hits * d_out) * pb
    return flops, act, 0.0


def _cost_batchnorm(op, n_hits, pb):
    d_out = op.out_dim or 1
    flops = 10.0 * n_hits * d_out
    act = 2.0 * n_hits * d_out * pb
    return flops, act, 0.0


def default_cost(op, n_hits, pb):
    return 0.0, n_hits * (op.out_dim or 1) * pb, 0.0


# MXU-efficiency factors (fraction of systolic-array peak a matmul of
# this size can use; consulted only for mxu-targeted matmul ops)
def _eff_dense(op, n_rows, n_hits):
    d_in = op.params["w"].shape[0] if op.params else 128
    d_out = op.out_dim or 128
    return (min(d_in, 128) / 128.0) * (min(d_out, 128) / 128.0) * \
        min(1.0, n_rows / 8.0)


def _eff_gravnet(op, n_rows, n_hits):
    # one-hot selection matmuls: (rows, n_hits) @ (n_hits, d_f)
    df = op.attrs.get("d_f", 32)
    return (min(n_hits, 128) / 128.0) * (min(df, 128) / 128.0)


def _eff_attention(op, n_rows, n_hits):
    d = op.out_dim or 128
    return (min(n_hits, 128) / 128.0) * (min(d, 128) / 128.0)


# ========================================================================
# kernel-opt binders + tuning-cache problem keys
# ========================================================================
def _bind_fused_dense(op, ctx: BindContext):
    """Variant selection / block tuning for the fused_dense template
    (cached winner > heuristic) — see passes/kernel_opt.py."""
    from repro.core.passes.kernel_opt import (FLATTEN_DIM, FLATTEN_ROWS,
                                              _FUSED_DENSE_KNOBS,
                                              _pick_block,
                                              fused_dense_dtype,
                                              fused_dense_shape)
    if op.template != "fused_dense":
        return
    rows, d_in, d_out = fused_dense_shape(op, ctx.n_rows, ctx.batch)
    tuned = None
    if ctx.cache is not None:
        from repro.tuning.cache import fused_dense_key
        tuned = ctx.cache.lookup(fused_dense_key(
            rows, d_in, d_out, fused_dense_dtype(op), ctx.backend))
    if tuned is not None:
        for knob in _FUSED_DENSE_KNOBS:
            if knob in tuned:
                op.attrs_opt[knob] = tuned[knob]
        # provenance: the executor only overrides its built-in int8
        # block defaults for configs that were actually searched
        op.attrs_opt["tuned"] = True
    elif rows <= FLATTEN_ROWS and max(d_in, d_out) <= FLATTEN_DIM:
        op.attrs_opt["variant"] = "flattened"
    else:
        op.attrs_opt["variant"] = "looped"
        op.attrs_opt["bm"] = _pick_block(rows, 512)
        op.attrs_opt["bn"] = _pick_block(d_out, 512)
        op.attrs_opt["bk"] = _pick_block(d_in, 2048)


def _bind_gravnet_aggregate(op, ctx: BindContext):
    # cache-only (the kernel's own default is the heuristic; a miss
    # leaves attrs_opt untouched → identical bindings)
    if ctx.cache is None:
        return
    from repro.tuning.cache import gravnet_key
    tuned = ctx.cache.lookup(gravnet_key(
        ctx.n_rows, op.attrs["d_s"], op.attrs["d_f"], op.attrs["k"],
        "float32", ctx.backend, batch=ctx.batch))
    if tuned is not None and "bm" in tuned:
        op.attrs_opt["bm"] = tuned["bm"]


def _bind_gravnet_block(op, ctx: BindContext):
    # cache-only (bm, bn, bk) bindings; a miss keeps the wrapper's
    # bitwise-safe defaults. An int8 block keys with the dtype-tagged
    # gravnet_block_int8 family — the quantized megakernel's winners
    # never bind onto the f32 kernel or vice versa.
    if ctx.cache is None:
        return
    from repro.tuning.cache import (gravnet_block_int8_key,
                                    gravnet_block_key)
    if op.precision == "int8":
        key = gravnet_block_int8_key(
            ctx.n_rows, op.attrs["d_hidden"], op.attrs["d_f"],
            op.attrs["k"], ctx.backend, batch=ctx.batch)
    else:
        key = gravnet_block_key(
            ctx.n_rows, op.attrs["d_hidden"], op.attrs["d_f"],
            op.attrs["k"], "float32", ctx.backend, batch=ctx.batch)
    tuned = ctx.cache.lookup(key)
    if tuned is not None:
        for knob in ("bm", "bn", "bk"):
            if knob in tuned:
                op.attrs_opt[knob] = tuned[knob]


def _bind_attention(op, ctx: BindContext):
    if ctx.cache is None:
        return
    from repro.tuning.cache import flash_attention_key
    tuned = ctx.cache.lookup(flash_attention_key(
        ctx.batch, ctx.n_rows, ctx.n_rows, op.out_dim or 128, "float32",
        ctx.backend))
    if tuned is not None:
        for knob in ("bq", "bk"):
            if knob in tuned:
                op.attrs_opt[knob] = tuned[knob]


def _bind_edge_aggregate(op, ctx: BindContext):
    if ctx.cache is None:
        return
    from repro.tuning.cache import edge_aggregate_key
    tuned = ctx.cache.lookup(edge_aggregate_key(
        ctx.n_rows, _n_edges(op, ctx.n_rows), op.out_dim or 1,
        "float32", ctx.backend, batch=ctx.batch))
    if tuned is not None:
        for knob in ("bm", "be"):
            if knob in tuned:
                op.attrs_opt[knob] = tuned[knob]


def _bind_knn_build(op, ctx: BindContext):
    # cache-only bm binding (the wrapper's own default is the
    # heuristic; a miss leaves attrs_opt untouched)
    if ctx.cache is None:
        return
    from repro.tuning.cache import knn_build_key
    tuned = ctx.cache.lookup(knn_build_key(
        ctx.n_rows, op.attrs["d_s"], op.attrs["k"], "float32",
        ctx.backend, batch=ctx.batch))
    if tuned is not None and "bm" in tuned:
        op.attrs_opt["bm"] = tuned["bm"]


def _bind_knn_aggregate(op, ctx: BindContext):
    if ctx.cache is None:
        return
    from repro.tuning.cache import knn_aggregate_key
    tuned = ctx.cache.lookup(knn_aggregate_key(
        ctx.n_rows, op.attrs["d_f"], op.attrs["k"], "float32",
        ctx.backend, batch=ctx.batch))
    if tuned is not None and "bm" in tuned:
        op.attrs_opt["bm"] = tuned["bm"]


def _key_fused_dense(op, n_rows, backend, batch):
    from repro.core.passes.kernel_opt import (fused_dense_dtype,
                                              fused_dense_shape)
    from repro.tuning.cache import fused_dense_key
    rows, d_in, d_out = fused_dense_shape(op, n_rows, batch)
    return fused_dense_key(rows, d_in, d_out, fused_dense_dtype(op),
                           backend)


def _key_gravnet_aggregate(op, n_rows, backend, batch):
    from repro.tuning.cache import gravnet_key
    return gravnet_key(n_rows, op.attrs["d_s"], op.attrs["d_f"],
                       op.attrs["k"], "float32", backend, batch=batch)


def _key_gravnet_block(op, n_rows, backend, batch):
    from repro.tuning.cache import (gravnet_block_int8_key,
                                    gravnet_block_key)
    if op.precision == "int8":
        return gravnet_block_int8_key(n_rows, op.attrs["d_hidden"],
                                      op.attrs["d_f"], op.attrs["k"],
                                      backend, batch=batch)
    return gravnet_block_key(n_rows, op.attrs["d_hidden"],
                             op.attrs["d_f"], op.attrs["k"],
                             "float32", backend, batch=batch)


def _key_attention(op, n_rows, backend, batch):
    # the executor launches one (B, N, d) flash call per micro-batch:
    # bh = the packed batch, s = t = n_rows
    from repro.tuning.cache import flash_attention_key
    return flash_attention_key(batch, n_rows, n_rows, op.out_dim or 128,
                               "float32", backend)


def _key_edge_aggregate(op, n_rows, backend, batch):
    from repro.tuning.cache import edge_aggregate_key
    return edge_aggregate_key(n_rows, _n_edges(op, n_rows),
                              op.out_dim or 1, "float32", backend,
                              batch=batch)


def _key_knn_build(op, n_rows, backend, batch):
    from repro.tuning.cache import knn_build_key
    return knn_build_key(n_rows, op.attrs["d_s"], op.attrs["k"],
                         "float32", backend, batch=batch)


def _key_knn_aggregate(op, n_rows, backend, batch):
    from repro.tuning.cache import knn_aggregate_key
    return knn_aggregate_key(n_rows, op.attrs["d_f"], op.attrs["k"],
                             "float32", backend, batch=batch)


# templates whose binder/tuning key is picked by the *template* the
# mapper chose, not the op type (a dense on the xla target has no
# tuning problem; the same dense on the MXU does)
TEMPLATE_BINDERS = {"fused_dense": _bind_fused_dense}
TEMPLATE_TUNING_KEYS = {"fused_dense": _key_fused_dense}


def bind_kernels(op, ctx: BindContext) -> None:
    """Kernel-opt dispatch for one op: template binder first, then the
    op-type binder from its spec."""
    binder = TEMPLATE_BINDERS.get(op.template)
    if binder is not None:
        binder(op, ctx)
        return
    spec = require_spec(op)
    if spec.bind is not None:
        spec.bind(op, ctx)


def tuning_problem(op, *, n_rows: int, backend: str, batch: int = 1):
    """The tuning-cache key this op's bound kernel launches with, or
    None for ops with no searchable launch config."""
    keyer = TEMPLATE_TUNING_KEYS.get(op.template)
    if keyer is None:
        keyer = require_spec(op).tuning_key
    if keyer is None:
        return None
    return keyer(op, n_rows, backend, batch)


# ========================================================================
# the registry
# ========================================================================
def _both(template: str) -> dict[str, str]:
    return {"mxu": template, "xla": template}


register_op(OpSpec(
    "input", templates={"xla": "io"}, infer=_infer_input))
register_op(OpSpec(
    "output", templates={"xla": "io"}, infer=_infer_output))
register_op(OpSpec(
    "linear", regular=True,
    templates={"mxu": "fused_dense", "xla": "xla_dense"},
    infer=_infer_dense, cost=_cost_dense, mxu_matmul=True,
    mxu_eff=_eff_dense))
register_op(OpSpec(
    "dense", regular=True,
    templates={"mxu": "fused_dense", "xla": "xla_dense"},
    infer=_infer_dense, cost=_cost_dense, mxu_matmul=True,
    mxu_eff=_eff_dense, int8_passthrough=True))
register_op(OpSpec(
    "relu", regular=True, templates=_both("xla_eltwise"),
    infer=_infer_same, cost=_cost_eltwise_like, int8_passthrough=True))
register_op(OpSpec(
    "concat", regular=True, templates=_both("xla_concat"),
    infer=_infer_concat, cost=_cost_eltwise_like, int8_passthrough=True))
register_op(OpSpec(
    "slice", regular=True, templates=_both("xla_slice"),
    infer=_infer_slice, cost=_cost_eltwise_like, int8_passthrough=True))
register_op(OpSpec(
    "retile", regular=True, templates=_both("xla_retile"),
    infer=_infer_retile, cost=_cost_eltwise_like))
register_op(OpSpec(
    "quant", regular=True, templates=_both("xla_quant"),
    infer=_infer_same, cost=_cost_eltwise_like))
register_op(OpSpec(
    "dequant", regular=True, templates=_both("xla_quant"),
    infer=_infer_same, cost=_cost_eltwise_like))
register_op(OpSpec(
    "attention", regular=True,
    templates={"mxu": "flash_attention", "xla": "xla_attention"},
    infer=_infer_attention, cost=_cost_attention, mxu_matmul=True,
    mxu_eff=_eff_attention, bind=_bind_attention,
    tuning_key=_key_attention))
register_op(OpSpec(
    "gravnet_aggregate", tpu_native_regular=True,
    templates={"mxu": "gravnet_kernel", "xla": "xla_gravnet"},
    infer=_infer_gravnet_aggregate, cost=_cost_gravnet_aggregate,
    mxu_matmul=True, mxu_eff=_eff_gravnet,
    bind=_bind_gravnet_aggregate, tuning_key=_key_gravnet_aggregate))
register_op(OpSpec(
    # the fused dense→aggregate→dense megakernel carries the
    # aggregation's data-dependent selection, so it classifies exactly
    # like gravnet_aggregate: irregular faithfully, regular under the
    # TPU-native reformulation
    "gravnet_block", tpu_native_regular=True,
    templates={"mxu": "gravnet_block_kernel", "xla": "xla_gravnet_block"},
    infer=_infer_gravnet_block, cost=_cost_gravnet_block,
    mxu_matmul=True, mxu_eff=_eff_gravnet,
    bind=_bind_gravnet_block, tuning_key=_key_gravnet_block))
register_op(OpSpec(
    "cps", templates=_both("xla_cps"),
    infer=_infer_cps, cost=_cost_cps))

# --- ragged / padding-free event path (passes/ragged.py) ----------------
register_op(OpSpec(
    # neighbor selection over bin-packed ragged events: data-dependent
    # like gravnet_aggregate, and regular under the same TPU-native
    # reformulation (iterated argmin over a dense distance matrix).
    # Both templates exchange COMPACT tensors — the op's value is an
    # (idx, d2) index tuple, which no retile may ever land on (see
    # passes/mapping.py).
    "knn_build", tpu_native_regular=True,
    templates={"mxu": "knn_build_kernel", "xla": "xla_knn_build"},
    infer=_infer_knn_build, cost=_cost_knn_build,
    mxu_matmul=True, mxu_eff=_eff_gravnet,
    bind=_bind_knn_build, tuning_key=_key_knn_build))
register_op(OpSpec(
    # Gaussian-potential aggregation over knn_build's indices: one-hot
    # selection matmuls, same classification as gravnet_aggregate.
    # Compact layout on both targets (its knn input is a tuple).
    "knn_aggregate", tpu_native_regular=True,
    templates={"mxu": "knn_agg_kernel", "xla": "xla_knn_agg"},
    infer=_infer_knn_aggregate, cost=_cost_knn_aggregate,
    mxu_matmul=True, mxu_eff=_eff_gravnet,
    bind=_bind_knn_aggregate, tuning_key=_key_knn_aggregate))

# --- edge-based message passing (GatedGCN / GraphSAGE family) -----------
register_op(OpSpec(
    # data-dependent gather of node rows by an explicit edge list —
    # irregular, like the kNN gather
    "gather_edge", templates=_both("xla_gather"),
    infer=_infer_gather_edge, cost=_cost_gather_edge))
register_op(OpSpec(
    # masked segment-sum/mean of per-edge messages into node slots; the
    # one-hot-matmul Pallas kernel (kernels/edge_aggregate.py) makes it
    # statically schedulable, so like gravnet_aggregate it reclassifies
    # as regular under tpu_native_gravnet
    "edge_aggregate", tpu_native_regular=True,
    templates={"mxu": "edge_aggregate_kernel",
               "xla": "xla_edge_aggregate"},
    infer=_infer_edge_aggregate, cost=_cost_edge_aggregate,
    bind=_bind_edge_aggregate, tuning_key=_key_edge_aggregate))
register_op(OpSpec(
    "eltwise", regular=True, templates=_both("xla_eltwise"),
    infer=_infer_eltwise, cost=_cost_eltwise_like))
register_op(OpSpec(
    "batchnorm", regular=True, templates=_both("xla_batchnorm"),
    infer=_infer_batchnorm, cost=_cost_batchnorm))
