"""QKeras-analogue quantization for the deployment flow.

The paper's models are trained with QKeras ``quantized_bits`` and deployed
8-bit everywhere except the system-boundary partitions (A, G) which use
16-bit to preserve inference quality. We mirror that:

- ``fake_quant``          : symmetric uniform fake-quantization with a
                            straight-through estimator — used during QAT.
- ``calibrate``           : per-op activation scales from max-abs over a
                            calibration batch.
- ``quantize_weight``     : per-output-channel int8 weights + f32 scales.
- ``apply_precision_policy``: paper's mixed policy — first/last pipeline
                            segments bf16, interior segments int8.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.custom_vjp
def _ste_round(x):
    return jnp.round(x)


def _ste_fwd(x):
    return jnp.round(x), None


def _ste_bwd(_, g):
    return (g,)


_ste_round.defvjp(_ste_fwd, _ste_bwd)


def fake_quant(x, *, bits: int = 8, scale=None):
    """Symmetric fake quantization with STE gradients (QAT)."""
    qmax = 2.0 ** (bits - 1) - 1.0
    if scale is None:
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / qmax
        scale = jax.lax.stop_gradient(scale)
    q = jnp.clip(_ste_round(x / scale), -qmax, qmax)
    return q * scale


def quantize_weight(w, *, bits: int = 8):
    """Per-output-channel symmetric int8 quantization. w: (d_in, d_out)."""
    qmax = 2.0 ** (bits - 1) - 1.0
    scale = jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-8) / qmax  # (d_out,)
    w_q = jnp.clip(jnp.round(w / scale[None, :]), -qmax, qmax).astype(jnp.int8)
    return w_q, scale.astype(jnp.float32)


def activation_scale(absmax: float, *, bits: int = 8) -> float:
    qmax = 2.0 ** (bits - 1) - 1.0
    return max(float(absmax), 1e-8) / qmax


def apply_precision_policy(g, *, policy: str = "mixed"):
    """Set per-op precision from the paper's policy.

    'fp'    — everything float (the FPGA-only 8-bit baseline is modelled
              separately; 'fp' is the numerics reference).
    'mixed' — boundary segments (first and last, the paper's A and G)
              run bf16; all interior segments run int8.
    """
    g = g.clone()
    if policy == "fp":
        for op in g:
            op.precision = "fp"
        return g
    assert policy == "mixed", policy
    seg_ids = sorted({op.segment for op in g})
    first, last = seg_ids[0], seg_ids[-1]
    for op in g:
        if op.segment in (first, last):
            op.precision = "bf16"
        else:
            op.precision = "int8"
        # io/cps ops keep fp interface semantics regardless
        if op.op_type in ("input", "output", "cps"):
            op.precision = "bf16"
    g.meta["precision_policy"] = policy
    return g
