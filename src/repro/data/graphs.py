"""Synthetic graph data: generators (power-law, geometric, molecules),
CSR neighbor sampler (GraphSAGE minibatch training), DimeNet triplet
builder. All outputs are padded to static budgets with masks.
"""
from __future__ import annotations

import numpy as np

from repro.data.ragged import group_by_segment


# ----------------------------------------------------------- generators ----
def powerlaw_graph(n_nodes: int, n_edges: int, *, d_feat: int,
                   n_classes: int, seed: int):
    """Preferential-attachment-flavored random graph with features whose
    class signal propagates over edges (so GNNs beat MLPs on it)."""
    rng = np.random.default_rng(seed)
    # power-law-ish degree: sample endpoints with prob ∝ (rank)^-0.7
    p = (np.arange(1, n_nodes + 1) ** -0.7)
    p /= p.sum()
    src = rng.choice(n_nodes, size=n_edges, p=p).astype(np.int32)
    dst = rng.integers(0, n_nodes, size=n_edges).astype(np.int32)
    labels = rng.integers(0, n_classes, size=n_nodes).astype(np.int32)
    centers = rng.normal(size=(n_classes, d_feat)).astype(np.float32)
    feats = centers[labels] + 0.8 * rng.normal(
        size=(n_nodes, d_feat)).astype(np.float32)
    return {"nodes": feats, "edge_index": np.stack([src, dst]),
            "labels": labels,
            "node_mask": np.ones(n_nodes, np.float32),
            "edge_mask": np.ones(n_edges, np.float32)}


def geometric_graph(n_nodes: int, *, cutoff: float, box: float,
                    n_species: int, seed: int, max_edges: int):
    """Random atoms in a box, radius graph, synthetic smooth energy."""
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0, box, size=(n_nodes, 3)).astype(np.float32)
    d2 = ((pos[:, None] - pos[None, :]) ** 2).sum(-1)
    np.fill_diagonal(d2, np.inf)
    src, dst = np.nonzero(d2 < cutoff ** 2)
    if src.size > max_edges:
        keep = np.argsort(d2[src, dst])[:max_edges]
        src, dst = src[keep], dst[keep]
    e = src.size
    ei = np.zeros((2, max_edges), np.int32)
    ei[0, :e], ei[1, :e] = src, dst
    em = np.zeros(max_edges, np.float32)
    em[:e] = 1.0
    species = rng.integers(0, n_species, size=n_nodes).astype(np.int32)
    # smooth synthetic energy: pairwise morse-ish + species offsets
    d = np.sqrt(d2[src, dst])
    energy = float(np.exp(-d).sum() * 0.5 + 0.1 * species.sum())
    return {"positions": pos, "species": species, "edge_index": ei,
            "node_mask": np.ones(n_nodes, np.float32), "edge_mask": em,
            "energy": np.float32(energy)}


def build_triplets(edge_index, edge_mask, *, max_triplets: int):
    """(kj_edge, ji_edge) pairs with shared middle node j, k != i."""
    src, dst = edge_index
    e = int(edge_mask.sum())
    by_dst: dict[int, list[int]] = {}
    for eid in range(e):
        by_dst.setdefault(int(dst[eid]), []).append(eid)
    kj, ji = [], []
    for eid in range(e):
        j = int(src[eid])           # edge j->i
        for kj_e in by_dst.get(j, ()):
            if int(src[kj_e]) != int(dst[eid]):
                kj.append(kj_e)
                ji.append(eid)
                if len(kj) >= max_triplets:
                    break
        if len(kj) >= max_triplets:
            break
    t = len(kj)
    trips = np.zeros((2, max_triplets), np.int32)
    trips[0, :t] = kj
    trips[1, :t] = ji
    tm = np.zeros(max_triplets, np.float32)
    tm[:t] = 1.0
    return trips, tm


def molecule_batch(batch: int, *, n_nodes: int, max_edges: int,
                   max_triplets: int, n_species: int, seed: int,
                   with_triplets: bool):
    gs = []
    for i in range(batch):
        g = geometric_graph(n_nodes, cutoff=1.6, box=3.0,
                            n_species=n_species, seed=seed * 10007 + i,
                            max_edges=max_edges)
        if with_triplets:
            g["triplets"], g["triplet_mask"] = build_triplets(
                g["edge_index"], g["edge_mask"],
                max_triplets=max_triplets)
        gs.append(g)
    return {k: np.stack([g[k] for g in gs]) for k in gs[0]}


# -------------------------------------------------------------- sampler ----
class NeighborSampler:
    """CSR fixed-fanout layered neighbor sampler (GraphSAGE §3.1).

    Builds in-neighbor CSR once; ``sample(seeds)`` returns the layered
    frontier batch consumed by ``graphsage.apply_sampled``: features laid
    out frontier-by-frontier, per-layer (2, E) edge lists pointing
    frontier l+1 → frontier l. Sampling is with replacement (constant
    fanout — static shapes, the production trick for recompile-free
    steps)."""

    def __init__(self, edge_index, n_nodes: int, feats, labels,
                 *, fanouts, seed: int = 0):
        src, dst = np.asarray(edge_index)
        # in-neighbor CSR: the same grouping the ragged event packer
        # uses (data/ragged.py), segments = destination nodes
        self.nbr, self.offs = group_by_segment(src, dst, n_nodes)
        self.feats = feats
        self.labels = labels
        self.fanouts = tuple(fanouts)
        self.rng = np.random.default_rng(seed)
        self.n_nodes = n_nodes

    def _sample_neighbors(self, nodes, fanout):
        lo = self.offs[nodes]
        hi = self.offs[nodes + 1]
        deg = np.maximum(hi - lo, 1)
        r = self.rng.integers(0, 1 << 62, size=(nodes.size, fanout))
        idx = lo[:, None] + (r % deg[:, None])
        has = (hi > lo)[:, None]
        nb = np.where(has, self.nbr[np.minimum(idx, self.offs[-1] - 1)],
                      nodes[:, None])  # isolated nodes self-loop
        return nb.astype(np.int32)

    def sample(self, seeds):
        seeds = np.asarray(seeds, np.int32)
        frontiers = [seeds]
        edges = []
        offs = [0, seeds.size]
        for f in self.fanouts:
            cur = frontiers[-1]
            nb = self._sample_neighbors(cur, f)     # (n_cur, f)
            frontiers.append(nb.reshape(-1))
            offs.append(offs[-1] + frontiers[-1].size)
        # layered edge lists in frontier-local coordinates
        off = 0
        for li, f in enumerate(self.fanouts):
            n_cur = frontiers[li].size
            dst_local = off + np.repeat(np.arange(n_cur, dtype=np.int32), f)
            src_local = offs[li + 1] + np.arange(n_cur * f, dtype=np.int32)
            edges.append(np.stack([src_local, dst_local]))
            off = offs[li + 1]
        all_nodes = np.concatenate(frontiers)
        return {"feats": self.feats[all_nodes],
                "edges": edges,
                "labels": self.labels[seeds]}
