"""Synthetic LM token pipeline: Zipf-distributed tokens with a Markov
flavor so the loss has learnable structure; deterministic per (seed, step)
so checkpoint-resume replays the exact stream (fault-tolerance invariant).
"""
from __future__ import annotations

import numpy as np


def lm_batch(vocab: int, batch: int, seq: int, *, seed: int, step: int):
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    # zipf-ish marginal
    base = rng.zipf(1.3, size=(batch, seq + 1)).astype(np.int64)
    toks = (base % vocab).astype(np.int32)
    # inject local structure: with p=0.3, next token = (prev*7+3) % vocab
    rep = rng.uniform(size=(batch, seq)) < 0.3
    nxt = (toks[:, :-1] * 7 + 3) % vocab
    toks[:, 1:] = np.where(rep, nxt, toks[:, 1:])
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def lm_stream(vocab: int, batch: int, seq: int, *, seed: int = 0,
              start_step: int = 0):
    step = start_step
    while True:
        yield lm_batch(vocab, batch, seq, seed=seed, step=step)
        step += 1
