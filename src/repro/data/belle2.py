"""Synthetic Belle II ECL trigger events.

The detector is modeled as a cylindrical crystal grid (θ × φ); the current
trigger reads 576 cells (24×24), the upgraded detector 8736 (56×156).
Each event contains 0..max_clusters electromagnetic clusters (photon- or
hadron-like transverse profiles) over beam-background noise hits; the
trigger front-end reads out the ``n_hits`` highest-energy crystals
(zero-padded when fewer fire — matching the paper's zero-padding of up to
128 of 8736 sparse non-zero inputs).

Per-hit features: (E, θ_norm, φ_norm, t). Per-hit labels for object
condensation: object_id (cluster idx or −1 for noise), true cluster
energy, class (0 photon, 1 hadron, 2 background).

Occupancy knob: by default an event's non-zero hit count is whatever
physics produced (clusters + noise, capped at ``n_hits``) — with the
default cluster/noise rates that clusters tightly near the cap, so
every event looks like a maximum-occupancy event and an
occupancy-bucketed serving path (``deploy_bucketed``) is untestable.
``Belle2Config.occupancy`` fixes that: a tuple of ``(max_hits, weight)``
pairs defines a per-event distribution over occupancy caps; each event
draws a cap (weights normalized) and keeps only its ``cap``
highest-energy hits, emulating the real detector's occupancy spread
(most trigger events fire a small fraction of the readout). Example::

    cfg = dataclasses.replace(current_detector(),
                              occupancy=((8, 0.5), (16, 0.3), (32, 0.2)))

``occupancy=None`` (default) preserves the legacy behavior exactly;
``with_occupancy(cfg, buckets, weights)`` builds the tuple for a
bucket list. Draws consume the same seeded generator as the rest of
the event, so generation stays deterministic per seed.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Belle2Config:
    n_crystals: int = 8736
    grid: tuple = (56, 156)          # θ × φ; 24×24 for the 576-cell trigger
    n_hits: int = 128
    max_clusters: int = 6
    mean_clusters: float = 2.0
    noise_rate: float = 40.0         # expected background hits / event
    e_min: float = 0.05              # GeV
    e_scale: float = 0.8
    cluster_sigma: float = 1.1       # crystals
    hadron_frac: float = 0.3
    time_jitter: float = 0.2
    # per-event occupancy-cap distribution: ((max_hits, weight), ...);
    # None = legacy behavior (no cap below n_hits). See module docstring.
    occupancy: tuple | None = None


def current_detector() -> Belle2Config:
    return Belle2Config(n_crystals=576, grid=(24, 24), n_hits=32,
                        noise_rate=8.0)


def with_occupancy(cfg: Belle2Config, buckets, weights=None) -> Belle2Config:
    """Config copy whose events spread over ``buckets`` occupancy caps
    (uniform weights unless given) — the natural companion of an
    occupancy-bucketed deployment over the same bucket list."""
    bs = [int(b) for b in buckets]
    ws = [1.0] * len(bs) if weights is None else [float(w) for w in weights]
    if len(ws) != len(bs):
        raise ValueError(f"{len(bs)} buckets but {len(ws)} weights")
    return dataclasses.replace(cfg, occupancy=tuple(zip(bs, ws)))


def generate(cfg: Belle2Config, batch: int, seed: int):
    """Returns dict of numpy arrays: feats (B,N,4), mask (B,N),
    object_id (B,N), energy (B,N), cls (B,N), trigger_truth (B,)."""
    rng = np.random.default_rng(seed)
    nt, nph = cfg.grid
    b, n = batch, cfg.n_hits
    caps, cap_p = None, None
    if cfg.occupancy is not None:
        caps = np.asarray([c for c, _ in cfg.occupancy], np.int64)
        w = np.asarray([w for _, w in cfg.occupancy], np.float64)
        if caps.size == 0 or (w < 0).any() or w.sum() <= 0:
            raise ValueError(f"invalid occupancy profile {cfg.occupancy!r}")
        cap_p = w / w.sum()
    feats = np.zeros((b, n, 4), np.float32)
    mask = np.zeros((b, n), np.float32)
    obj = np.full((b, n), -1, np.int32)
    energy = np.zeros((b, n), np.float32)
    cls = np.full((b, n), 2, np.int32)
    trigger = np.zeros((b,), np.float32)

    for ev in range(b):
        e_grid = np.zeros((nt, nph), np.float32)
        id_grid = np.full((nt, nph), -1, np.int32)
        cls_grid = np.full((nt, nph), 2, np.int32)
        eobj_grid = np.zeros((nt, nph), np.float32)
        k = min(rng.poisson(cfg.mean_clusters), cfg.max_clusters)
        for c in range(k):
            ct = rng.uniform(2, nt - 2)
            cp = rng.uniform(0, nph)
            e_c = cfg.e_min + rng.exponential(cfg.e_scale)
            is_hadron = rng.uniform() < cfg.hadron_frac
            sig = cfg.cluster_sigma * (1.6 if is_hadron else 1.0)
            n_dep = rng.poisson(9 if is_hadron else 7) + 3
            dts = rng.normal(0, sig, size=n_dep)
            dps = rng.normal(0, sig, size=n_dep)
            fr = rng.dirichlet(np.ones(n_dep) * (0.5 if is_hadron else 1.5))
            for d in range(n_dep):
                t_i = int(np.clip(round(ct + dts[d]), 0, nt - 1))
                p_i = int(round(cp + dps[d])) % nph
                e_grid[t_i, p_i] += e_c * fr[d]
                if e_c * fr[d] > eobj_grid[t_i, p_i]:
                    id_grid[t_i, p_i] = c
                    cls_grid[t_i, p_i] = 1 if is_hadron else 0
                    eobj_grid[t_i, p_i] = e_c
        # beam background noise
        n_noise = rng.poisson(cfg.noise_rate)
        tn = rng.integers(0, nt, size=n_noise)
        pn = rng.integers(0, nph, size=n_noise)
        np.add.at(e_grid, (tn, pn), rng.exponential(0.02, size=n_noise))

        flat = e_grid.reshape(-1)
        nz = np.flatnonzero(flat > 0.01)
        cap = n if caps is None else min(n, int(rng.choice(caps, p=cap_p)))
        order = nz[np.argsort(-flat[nz])][:cap]
        m = order.size
        t_idx, p_idx = np.unravel_index(order, (nt, nph))
        feats[ev, :m, 0] = flat[order]
        feats[ev, :m, 1] = t_idx / nt - 0.5
        feats[ev, :m, 2] = p_idx / nph - 0.5
        feats[ev, :m, 3] = rng.normal(0, cfg.time_jitter, size=m)
        mask[ev, :m] = 1.0
        obj[ev, :m] = id_grid.reshape(-1)[order]
        energy[ev, :m] = eobj_grid.reshape(-1)[order]
        cls[ev, :m] = cls_grid.reshape(-1)[order]
        trigger[ev] = float(k > 0)

    return {"feats": feats, "mask": mask, "object_id": obj,
            "energy": energy, "cls": cls, "trigger_truth": trigger}


def event_stream(cfg: Belle2Config, batch: int, *, seed0: int = 0):
    step = 0
    while True:
        yield generate(cfg, batch, seed0 + step)
        step += 1


def generate_ragged(cfg: Belle2Config, batch: int, seed: int):
    """One ragged (CSR) batch: the padded batch with its padding
    stripped. Returns ``{"ragged": RaggedBatch, "trigger_truth": (B,)}``
    plus the per-hit truth arrays concatenated in the same CSR order
    (``object_id``, ``energy``, ``cls`` — each ``(R,)``).

    Round-trips exactly against the padded form:
    ``ragged.unpack_events(out["ragged"], cfg.n_hits)`` reproduces
    ``generate(...)``'s feats/mask bit-for-bit (tested), because
    generated events are hit-prefix-packed already.
    """
    from repro.data.ragged import pack_events

    data = generate(cfg, batch, seed)
    rb = pack_events(data["feats"], data["mask"])
    ev, hit = np.nonzero(data["mask"] > 0)
    return {"ragged": rb,
            "object_id": data["object_id"][ev, hit],
            "energy": data["energy"][ev, hit],
            "cls": data["cls"][ev, hit],
            "trigger_truth": data["trigger_truth"]}


def event_stream_ragged(cfg: Belle2Config, batch: int, *, seed0: int = 0):
    """Ragged (CSR) companion of :func:`event_stream`: yields
    :func:`generate_ragged` batches — concatenated hits + per-event
    offsets, no padding on the wire. Seeded identically, so stream
    step ``t`` here is the padded stream's step ``t`` minus its
    padding."""
    step = 0
    while True:
        yield generate_ragged(cfg, batch, seed0 + step)
        step += 1
