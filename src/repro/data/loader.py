"""Prefetching loader with straggler mitigation.

A background thread keeps ``depth`` batches ready; ``get()`` enforces a
deadline — if generation stalls (slow host, the straggler case), it
returns the last good batch and records the incident instead of blocking
the accelerator step. Deterministic streams (seeded per step) make
checkpoint-resume exact: pass ``start_step`` when resuming.
"""
from __future__ import annotations

import queue
import threading
import time


class Prefetcher:
    def __init__(self, gen, *, depth: int = 2, deadline_s: float = 30.0):
        self._gen = gen
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._deadline = deadline_s
        self._stop = threading.Event()
        self._exc = None
        self.stats = {"batches": 0, "stragglers": 0}
        self._last = None
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        try:
            for item in self._gen:
                if self._stop.is_set():
                    return
                while True:
                    try:
                        self._q.put(item, timeout=0.5)
                        break
                    except queue.Full:
                        if self._stop.is_set():
                            return
        except Exception as e:  # surfaced on next get()
            self._exc = e

    def get(self):
        if self._exc is not None:
            raise self._exc
        try:
            item = self._q.get(timeout=self._deadline)
            self._last = item
            self.stats["batches"] += 1
            return item
        except queue.Empty:
            if self._last is None:
                raise TimeoutError("data pipeline produced nothing "
                                   f"within {self._deadline}s")
            # straggler mitigation: reuse last batch, don't stall the step
            self.stats["stragglers"] += 1
            return self._last

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()


def timed(gen):
    """Wrap a generator yielding (batch, gen_seconds)."""
    for item in gen:
        t0 = time.perf_counter()
        yield item, time.perf_counter() - t0
