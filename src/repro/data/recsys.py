"""Synthetic MIND data: users with latent multi-interest structure —
each user draws 1..K interests; behaviors are items clustered by
interest, so multi-interest capsules genuinely help (single-vector
models mix interests). Deterministic per (seed, step)."""
from __future__ import annotations

import numpy as np


def mind_batch(*, n_items: int, n_user_tags: int, hist_len: int,
               tag_bag: int, batch: int, n_interest_clusters: int = 64,
               seed: int, step: int):
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    items_per = n_items // n_interest_clusters
    n_user_interests = rng.integers(1, 4, size=batch)
    behav = np.zeros((batch, hist_len), np.int32)
    target = np.zeros((batch,), np.int32)
    for u in range(batch):
        ints = rng.choice(n_interest_clusters, size=n_user_interests[u],
                          replace=False)
        which = rng.choice(ints, size=hist_len + 1)
        offs = rng.integers(0, items_per, size=hist_len + 1)
        seq = which * items_per + offs
        behav[u] = seq[:-1]
        target[u] = seq[-1]
    behav_mask = (rng.uniform(size=(batch, hist_len)) < 0.9
                  ).astype(np.float32)
    tags = rng.integers(0, n_user_tags, size=(batch, tag_bag)
                        ).astype(np.int32)
    return {"behav_ids": behav, "behav_mask": behav_mask,
            "tag_ids": tags, "target": target}


def mind_stream(cfg, batch: int, *, seed: int = 0, start_step: int = 0):
    step = start_step
    while True:
        yield mind_batch(n_items=cfg.n_items, n_user_tags=cfg.n_user_tags,
                         hist_len=cfg.hist_len, tag_bag=cfg.tag_bag,
                         batch=batch, seed=seed, step=step)
        step += 1
