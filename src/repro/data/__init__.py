from repro.data.loader import Prefetcher
