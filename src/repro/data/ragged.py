"""CSR ragged-event utilities: padding-free event batches.

Two layouts cooperate here (see docs/design_flow.md "Ragged
deployment"):

- **CSR stream layout** — the wire format a ragged event stream emits
  (``belle2.event_stream_ragged``): one concatenated hit matrix
  ``feats (R, d)`` plus monotone per-event ``offsets (B+1,)`` with
  ``offsets[e]..offsets[e+1]`` delimiting event ``e``'s hits.
  Zero-hit events are legal (empty slices); within-event hit order is
  preserved exactly (events are energy-sorted upstream).

- **Binned device layout** — what the ragged executable actually
  launches on. Events are first-fit packed *whole* into bins of
  ``capacity`` rows (the detector's ``n_hits`` max, so every event
  fits one bin). Companion index planes make the packing reversible
  and let kernels keep selection block-diagonal *per event* even when
  several events share a bin:

      feats  (n_bins, capacity, d)   packed hit features
      mask   (n_bins, capacity)      1.0 on real hits
      segids (n_bins, capacity) i32  global event index; −1 on padding
      slots  (n_bins, capacity) i32  hit index within its event

  Because events are packed contiguously and never split, a hit's
  within-event neighbors occupy the same bin with their relative
  order intact — the property the kNN kernel's lowest-index tie-break
  relies on for bitwise ragged-vs-padded agreement (tested).

Everything here is NumPy and runs *outside* jit: packing maps
arbitrary occupancy mixes onto one fixed ``(n_bins, capacity, ·)``
executable shape, so variable event sizes never retrace.

The CSR offset plumbing (``offsets_from_counts`` /
``group_by_segment``) is shared with the GraphSAGE neighbor sampler
(``data/graphs.py``), which builds the same structure over edge lists.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


# ------------------------------------------------------------ CSR helpers ----
def offsets_from_counts(counts) -> np.ndarray:
    """Monotone CSR offsets (len+1,) from per-segment counts."""
    counts = np.asarray(counts, np.int64)
    if counts.ndim != 1 or (counts < 0).any():
        raise ValueError(f"counts must be 1-D non-negative, got "
                         f"shape {counts.shape}")
    return np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)


def group_by_segment(values, segments, n_segments: int):
    """Stable-group ``values`` rows by their segment id.

    Returns ``(grouped, offsets)``: ``grouped`` is ``values`` reordered
    so each segment's rows are contiguous (original relative order
    preserved — stable sort), ``offsets`` the CSR delimiters. This is
    the one CSR builder shared by the ragged event packer and the
    GraphSAGE in-neighbor sampler.
    """
    values = np.asarray(values)
    segments = np.asarray(segments)
    if segments.shape[0] != values.shape[0]:
        raise ValueError(f"{values.shape[0]} values vs "
                         f"{segments.shape[0]} segment ids")
    order = np.argsort(segments, kind="stable")
    counts = np.bincount(segments, minlength=n_segments)
    if len(counts) > n_segments:
        raise ValueError(f"segment id {segments.max()} >= "
                         f"n_segments {n_segments}")
    return values[order], offsets_from_counts(counts)


# ----------------------------------------------------------- CSR batches ----
class RaggedBatch(NamedTuple):
    """Concatenated hits + per-event CSR offsets (the stream layout)."""
    feats: np.ndarray      # (R, d)
    offsets: np.ndarray    # (B+1,) monotone, offsets[0]=0, offsets[-1]=R

    @property
    def n_events(self) -> int:
        return self.offsets.shape[0] - 1

    def counts(self) -> np.ndarray:
        return np.diff(self.offsets)

    def event(self, e: int) -> np.ndarray:
        return self.feats[self.offsets[e]:self.offsets[e + 1]]


def validate_ragged(rb: RaggedBatch) -> None:
    """Raise ValueError unless offsets are monotone and consistent."""
    offs = np.asarray(rb.offsets)
    if offs.ndim != 1 or offs.shape[0] < 1:
        raise ValueError(f"offsets must be 1-D non-empty, got {offs.shape}")
    if offs[0] != 0:
        raise ValueError(f"offsets[0] must be 0, got {offs[0]}")
    if (np.diff(offs) < 0).any():
        raise ValueError("offsets must be monotone non-decreasing")
    if offs[-1] != rb.feats.shape[0]:
        raise ValueError(f"offsets[-1]={offs[-1]} != "
                         f"feats rows {rb.feats.shape[0]}")


def pack_events(feats, mask) -> RaggedBatch:
    """Padded ``feats (B, N, d)`` + ``mask (B, N)`` → CSR.

    Keeps only rows with mask > 0, preserving within-event order. The
    exact inverse of :func:`unpack_events` for feeds whose real hits
    are a prefix of the hit axis (how data/belle2 generates them).
    """
    feats = np.asarray(feats)
    mask = np.asarray(mask)
    if feats.ndim != 3 or mask.shape != feats.shape[:2]:
        raise ValueError(f"feats {feats.shape} vs mask {mask.shape}")
    ev, hit = np.nonzero(mask > 0)
    # np.nonzero is row-major: already stable-grouped by event with
    # within-event order intact, but events with zero hits still need
    # offsets — bincount covers them.
    counts = np.bincount(ev, minlength=feats.shape[0])
    return RaggedBatch(feats=feats[ev, hit],
                       offsets=offsets_from_counts(counts))


def unpack_events(rb: RaggedBatch, n_hits: int):
    """CSR → padded ``(B, n_hits, d)`` feats + ``(B, n_hits)`` mask."""
    validate_ragged(rb)
    b = rb.n_events
    d = rb.feats.shape[1]
    feats = np.zeros((b, n_hits, d), rb.feats.dtype)
    mask = np.zeros((b, n_hits), np.float32)
    counts = rb.counts()
    if (counts > n_hits).any():
        raise ValueError(f"event with {counts.max()} hits exceeds "
                         f"n_hits={n_hits}")
    ev = np.repeat(np.arange(b), counts)
    slot = np.arange(rb.feats.shape[0]) - np.repeat(rb.offsets[:-1], counts)
    feats[ev, slot] = rb.feats
    mask[ev, slot] = 1.0
    return feats, mask


# --------------------------------------------------------- binned packing ----
class BinPacked(NamedTuple):
    """The ragged executable's device layout (see module docstring)."""
    feats: np.ndarray      # (n_bins, capacity, d)
    mask: np.ndarray       # (n_bins, capacity) f32
    segids: np.ndarray     # (n_bins, capacity) i32; −1 on padding
    slots: np.ndarray      # (n_bins, capacity) i32; hit idx within event
    n_events: int


def bins_needed(counts, capacity: int) -> int:
    """Number of bins first-fit packing will open for these counts."""
    fill: list[int] = []
    for c in np.asarray(counts, np.int64):
        c = int(c)
        if c == 0:
            continue
        for i, f in enumerate(fill):
            if f + c <= capacity:
                fill[i] = f + c
                break
        else:
            fill.append(c)
    return len(fill)


def bin_pack(rb: RaggedBatch, capacity: int, *,
             n_bins: int | None = None) -> BinPacked:
    """First-fit pack whole events into ``capacity``-row bins.

    Events are never split; an event larger than ``capacity`` raises
    (``capacity`` is the detector max, so upstream data cannot produce
    one). ``n_bins`` pins the output's leading dim (zero-padded empty
    bins) so one executable shape serves every occupancy mix; packing
    that needs more bins raises — the caller splits into multiple
    launches (see ``pipeline.RaggedPipeline``).
    """
    validate_ragged(rb)
    counts = rb.counts()
    if counts.size and counts.max() > capacity:
        raise ValueError(f"event with {counts.max()} hits exceeds bin "
                         f"capacity {capacity}")
    # first-fit assignment: bin id + row offset per event
    fill: list[int] = []
    ev_bin = np.zeros(rb.n_events, np.int64)
    ev_row = np.zeros(rb.n_events, np.int64)
    for e, c in enumerate(counts):
        c = int(c)
        if c == 0:
            ev_bin[e] = -1
            continue
        for i, f in enumerate(fill):
            if f + c <= capacity:
                ev_bin[e], ev_row[e] = i, f
                fill[i] = f + c
                break
        else:
            ev_bin[e], ev_row[e] = len(fill), 0
            fill.append(c)
    nb = max(len(fill), 1)
    if n_bins is not None:
        if nb > n_bins:
            raise ValueError(f"packing needs {nb} bins > n_bins={n_bins}")
        nb = n_bins
    d = rb.feats.shape[1]
    feats = np.zeros((nb, capacity, d), rb.feats.dtype)
    mask = np.zeros((nb, capacity), np.float32)
    segids = np.full((nb, capacity), -1, np.int32)
    slots = np.zeros((nb, capacity), np.int32)
    total = rb.feats.shape[0]
    if total:
        nz = counts > 0
        evs = np.flatnonzero(nz)
        hit_ev = np.repeat(evs, counts[nz])
        hit_slot = (np.arange(total)
                    - np.repeat(rb.offsets[:-1][nz], counts[nz]))
        hit_bin = ev_bin[hit_ev]
        hit_row = ev_row[hit_ev] + hit_slot
        feats[hit_bin, hit_row] = rb.feats
        mask[hit_bin, hit_row] = 1.0
        segids[hit_bin, hit_row] = hit_ev
        slots[hit_bin, hit_row] = hit_slot
    return BinPacked(feats=feats, mask=mask, segids=segids, slots=slots,
                     n_events=rb.n_events)


def unpack_binned(values, segids, slots, n_events: int, n_hits: int):
    """Scatter packed per-hit ``values (n_bins, capacity, ...)`` back to
    the padded per-event layout ``(n_events, n_hits, ...)``; padding
    rows (segid −1) are dropped."""
    values = np.asarray(values)
    segids = np.asarray(segids)
    slots = np.asarray(slots)
    out = np.zeros((n_events, n_hits, *values.shape[2:]), values.dtype)
    sel = segids >= 0
    out[segids[sel], slots[sel]] = values[sel]
    return out
