"""Deterministic fault injection for the serving stack (chaos testing).

The paper's trigger is a hard real-time system: a wedged or failing
unit must degrade the stream gracefully, never stall it.  That
behavior is only engineerable if the failure modes themselves are
reproducible — so every chaos test, CI leg and degradation benchmark
here drives the *same* seeded ``FaultPlan`` and replays the same fault
sequence bit-identically.

A ``FaultPlan`` is a list of ``FaultSpec`` clauses plus a seed.  Each
replica derives its own stateful injector (``for_replica``) with an
independent, seed-derived RNG stream; the injector wraps the replica's
``infer_fn`` so both the deadline loop (``replica.py``) and the
streaming loop (``streaming.py``) inject at the same point — the
batch dispatch — without either loop knowing the fault kinds.

Fault kinds (per *batch*, the serving fault domain):

  fail     raise ``InjectedFault`` instead of running the batch —
           exercises the batch-failure path, breaker and failover;
  stall    sleep ``s`` seconds before running — a straggler, for
           hedging and tail-latency tests;
  wedge    hang until ``plan.release()`` — a dead device lane; the
           wait is poll-based so ``close()`` stays reachable once
           released;
  corrupt  run the batch, then poison the outputs (NaN floats,
           min-sentinel ints) — silent data corruption;
  kill     die in the *batcher/launcher thread* before dispatch (the
           loop fails the collected batch exactly once, then the
           thread exits) — exercises shutdown-under-load.

Spec grammar (``FaultPlan.parse``, also ``serve.py --inject-faults``)::

    SPEC    := clause (';' clause)*
    clause  := 'seed=' INT
             | KIND ['@' N (',' N)*] [':' kv (',' kv)*]
    kv      := 'p=' FLOAT        # per-batch probability
             | 's=' FLOAT        # stall seconds / wedge cap
             | 'replica=' INT ('+' INT)*   # target lanes (default all)

Examples: ``fail@3`` (fail batch 3 everywhere), ``fail:p=0.1``
(10% of batches), ``fail:p=1.0,replica=2`` (replica 2 is dead),
``stall:p=0.05,s=0.02;corrupt:p=0.01;seed=7``.

Determinism: each injector draws exactly one RNG value per rate-bearing
clause per batch, in clause order, under a lock — the per-replica
decision *stream* is a pure function of ``(seed, replica_id)``.  With a
serialized dispatch (``inflight=1``) the batch-index -> fault mapping
is exact; with concurrent dispatch the multiset of injected faults over
N batches is still exactly reproducible.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from random import Random

import numpy as np

FAULT_KINDS = ("fail", "stall", "wedge", "corrupt", "kill")

# wedge waits poll the release gate at this granularity so a released
# plan unblocks promptly without a busy spin
_WEDGE_POLL_S = 0.02


class InjectedFault(RuntimeError):
    """A deliberately injected serving failure (``fail``/``kill``)."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault clause: what to inject, when, and where.

    ``at`` names explicit 0-based batch indices; ``rate`` adds a
    per-batch probability; ``replicas`` restricts the clause to the
    named lanes (``None`` = every replica)."""
    kind: str
    rate: float = 0.0
    at: tuple = ()
    replicas: tuple | None = None
    duration_s: float | None = None

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"fault rate must be in [0, 1], "
                             f"got {self.rate}")
        if self.kind == "kill" and self.rate:
            raise ValueError("kill faults are index-triggered only "
                             "(use kill@N, not p=)")

    def describe(self) -> str:
        parts = [self.kind]
        if self.at:
            parts.append("@" + ",".join(str(n) for n in self.at))
        kv = []
        if self.rate:
            kv.append(f"p={self.rate:g}")
        if self.duration_s is not None:
            kv.append(f"s={self.duration_s:g}")
        if self.replicas is not None:
            kv.append("replica=" + "+".join(str(r) for r in self.replicas))
        return "".join(parts) + (":" + ",".join(kv) if kv else "")


def _parse_clause(text: str) -> FaultSpec:
    head, _, tail = text.partition(":")
    head = head.strip()
    at: tuple = ()
    if "@" in head:
        kind, _, idxs = head.partition("@")
        at = tuple(int(n) for n in idxs.split(","))
    else:
        kind = head
    rate, dur, replicas = 0.0, None, None
    if tail.strip():
        for kv in tail.split(","):
            key, _, val = kv.partition("=")
            key, val = key.strip(), val.strip()
            if key == "p":
                rate = float(val)
            elif key == "s":
                dur = float(val)
            elif key in ("replica", "replicas"):
                replicas = tuple(int(r) for r in val.split("+"))
            else:
                raise ValueError(f"unknown fault-spec key {key!r} in "
                                 f"{text!r} (expected p=, s=, replica=)")
    return FaultSpec(kind.strip(), rate=rate, at=at, replicas=replicas,
                     duration_s=dur)


class FaultPlan:
    """A seeded set of fault clauses shared by every replica of a
    service; ``for_replica`` derives the per-lane injector."""

    def __init__(self, specs=(), *, seed: int = 0):
        self.specs = tuple(specs)
        self.seed = int(seed)
        self._release_gate = threading.Event()
        self._lock = threading.Lock()
        self._injectors: dict[int, ReplicaFaultInjector] = {}

    @classmethod
    def parse(cls, text: str, *, seed: int = 0) -> "FaultPlan":
        """Build a plan from the spec grammar (module docstring); a
        ``seed=N`` clause overrides the ``seed`` argument."""
        specs = []
        for clause in text.split(";"):
            clause = clause.strip()
            if not clause:
                continue
            if clause.startswith("seed="):
                seed = int(clause[len("seed="):])
                continue
            specs.append(_parse_clause(clause))
        return cls(specs, seed=seed)

    def describe(self) -> str:
        body = ";".join(s.describe() for s in self.specs)
        return f"{body};seed={self.seed}" if body else f"seed={self.seed}"

    def for_replica(self, replica_id: int) -> "ReplicaFaultInjector":
        with self._lock:
            inj = self._injectors.get(replica_id)
            if inj is None:
                inj = ReplicaFaultInjector(self, replica_id)
                self._injectors[replica_id] = inj
            return inj

    # ------------------------------------------------------------- wedges ----
    def release(self):
        """Release every wedged call, current and future.  Call before
        ``close()``/``drain()`` when the plan contains wedge clauses —
        a wedged dispatch holds its in-flight slot until released."""
        self._release_gate.set()

    @property
    def released(self) -> bool:
        return self._release_gate.is_set()

    @property
    def wedged(self) -> int:
        """Calls currently hanging on the wedge gate, fleet-wide."""
        with self._lock:
            return sum(i.wedged_now for i in self._injectors.values())

    def counts(self) -> dict:
        """Fleet-wide injected-fault counts by kind."""
        out = {k: 0 for k in FAULT_KINDS}
        with self._lock:
            injectors = list(self._injectors.values())
        for inj in injectors:
            for k, n in inj.counts.items():
                out[k] += n
        return out


class ReplicaFaultInjector:
    """Per-replica fault state: a seed-derived RNG stream, batch
    counters, and the decision log chaos tests replay against."""

    def __init__(self, plan: FaultPlan, replica_id: int):
        self.plan = plan
        self.replica_id = replica_id
        # integer-arithmetic seed derivation: hash() of tuples is
        # process-randomized (PYTHONHASHSEED) and would break replay
        self._rng = Random(plan.seed * 1_000_003 + replica_id + 1)
        self._lock = threading.Lock()
        self.batches = 0          # wrapped infer calls seen
        self.batcher_cycles = 0   # batcher/launcher kill checkpoints
        self.wedged_now = 0
        self.counts = {k: 0 for k in FAULT_KINDS}
        self.log: list[tuple[int, str]] = []   # (batch_index, kind)

    def _targets_me(self, spec: FaultSpec) -> bool:
        return spec.replicas is None or self.replica_id in spec.replicas

    def _decide(self) -> list[FaultSpec]:
        """One deterministic decision round: exactly one RNG draw per
        rate-bearing clause that targets this replica, in clause
        order."""
        with self._lock:
            n = self.batches
            self.batches += 1
            hits = []
            for spec in self.plan.specs:
                if spec.kind == "kill" or not self._targets_me(spec):
                    continue
                hit = n in spec.at
                if spec.rate > 0.0:
                    hit = (self._rng.random() < spec.rate) or hit
                if hit:
                    hits.append(spec)
                    self.counts[spec.kind] += 1
                    self.log.append((n, spec.kind))
            return hits

    def batcher_kill_due(self) -> bool:
        """Called by the batcher/launcher thread once per collected
        batch; True when a ``kill@N`` clause names this checkpoint."""
        with self._lock:
            n = self.batcher_cycles
            self.batcher_cycles += 1
            for spec in self.plan.specs:
                if (spec.kind == "kill" and self._targets_me(spec)
                        and n in spec.at):
                    self.counts["kill"] += 1
                    self.log.append((n, "kill"))
                    return True
        return False

    def _wait_released(self, spec: FaultSpec):
        with self._lock:
            self.wedged_now += 1
        try:
            t0 = time.perf_counter()
            while not self.plan._release_gate.wait(timeout=_WEDGE_POLL_S):
                if (spec.duration_s is not None
                        and time.perf_counter() - t0 >= spec.duration_s):
                    return   # capped wedge: proceed after s seconds
        finally:
            with self._lock:
                self.wedged_now -= 1

    def wrap(self, infer_fn):
        """Wrap ``infer_fn`` with this injector: stalls first, then
        wedges, then failures; corruption applies to a completed
        output."""

        def faulted(feeds):
            hits = self._decide()
            for spec in hits:
                if spec.kind == "stall":
                    time.sleep(spec.duration_s
                               if spec.duration_s is not None else 0.05)
            for spec in hits:
                if spec.kind == "wedge":
                    self._wait_released(spec)
            for spec in hits:
                if spec.kind == "fail":
                    raise InjectedFault(
                        f"injected batch failure "
                        f"(replica {self.replica_id}, "
                        f"batch {self.batches - 1})")
            out = infer_fn(feeds)
            if any(s.kind == "corrupt" for s in hits):
                out = _poison(out)
            return out

        return faulted


def _poison(out):
    """Corrupt every leaf of an output pytree: NaN floats, dtype-min
    ints, all-True bools — loud enough that any downstream consumer
    (monitor, client) can detect the corruption."""
    import jax

    leaves, tdef = jax.tree_util.tree_flatten(out)
    bad = []
    for leaf in leaves:
        a = np.array(leaf)
        if np.issubdtype(a.dtype, np.floating):
            a[...] = np.nan
        elif np.issubdtype(a.dtype, np.bool_):
            a[...] = True
        elif np.issubdtype(a.dtype, np.integer):
            a[...] = np.iinfo(a.dtype).min
        bad.append(a)
    return jax.tree_util.tree_unflatten(tdef, bad)
