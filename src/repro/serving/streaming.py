"""Streaming dataflow replica loop (the DGNNFlow direction).

The deadline loop in ``replica.py`` tears down and re-forms a
micro-batch every tick: collect until a batch boundary or the window
deadline, stack fresh arrays, dispatch, wait, repeat.  That is the
request/response shape DGNNFlow (arXiv 2603.20364) argues against for
trigger systems — the paper's 7.15 µs / 2.94 M events/s figure is a
*continuously streaming* pipeline's number.  ``StreamingReplicaEngine``
replaces the tick with a persistent, device-resident pipeline of four
overlapped stages:

  intake   — ``enqueue`` appends to the bounded queue (the router
             contract is unchanged; backpressure still applies);
  assemble — the launcher thread copies queued events straight into a
             preallocated staging slot of the **input ring**
             (``inflight + 1`` slots of shape ``(microbatch, …)``,
             allocated once from the first event) and launches as soon
             as at least one event is staged and the pipeline has a
             free in-flight slot.  There is no deadline tick and no
             batch-boundary wait: an event that arrives while a launch
             is in flight joins the *next* launch, and the batch width
             self-regulates with the offered load (near 1 when idle,
             up to ``microbatch`` at saturation);
  compute  — launches are handed to the dispatch pool and run
             asynchronously; the launcher never blocks on a result and
             ``jax.block_until_ready`` never runs on the hot path;
  harvest  — a dedicated thread polls completed launch futures in FIFO
             order, copies device results into the preallocated host
             **output ring** (the D2H stage), taps the monitor, and
             hands each event to the shared ``InOrderReleaser``.

Stage overlap: while launch k computes, launch k+1 assembles in the
next input-ring slot and launch k-1 drains through the output ring —
the double-buffered Load/compute/Store of the paper's dataflow engine,
reproduced at the serving layer.  Ring safety needs no per-slot locks:
the in-flight semaphore bounds concurrent launches to ``inflight``, so
by the time the launcher cycles back to a slot (``inflight + 1``
launches later) its previous occupant has been harvested.

Global in-order release, per-bucket routing (each bucket group's
replicas own their own rings), the ``record_raw`` monitor tap, and
tuning-cache warm-up all behave exactly as in the deadline loop.
Hedged dispatch is deadline-only: the streaming loop keeps the
pipeline full instead of re-dispatching stragglers.
"""
from __future__ import annotations

import queue
import threading
import time
from collections import deque
from concurrent.futures import TimeoutError as FuturesTimeout

import numpy as np

from repro.serving.replica import EventTiming, ReplicaEngine

# replica loop flavors a ShardedTriggerService can run
LOOPS = ("deadline", "streaming")

# poll granularity for the stop-responsive waits (semaphore, compute
# futures, device buffers); the hot path itself never sleeps on this.
_POLL_S = 0.05


class StreamingReplicaEngine(ReplicaEngine):
    """One persistent streaming lane: bounded queue -> rolling batch
    assembly into the input ring -> async compute -> harvested D2H
    through the output ring -> shared in-order releaser."""

    loop = "streaming"

    def __init__(self, infer_fn, releaser, *, microbatch: int,
                 window_s: float = 1e-3, queue_depth: int = 1024,
                 hedge_after_s: float | None = None, device=None,
                 replica_id: int = 0, inflight: int = 2,
                 warmup_fn=None, monitor=None, truth_map=None,
                 faults=None, health=None, on_batch_failure=None,
                 shed: bool = False):
        if hedge_after_s is not None:
            raise ValueError(
                "hedge_after_s is a deadline-loop feature; the "
                "streaming loop keeps the pipeline full instead of "
                "re-dispatching stragglers (use loop='deadline')")
        # window_s is accepted for constructor compatibility but the
        # streaming loop has no deadline tick to apply it to.
        super().__init__(infer_fn, releaser, microbatch=microbatch,
                         window_s=window_s, queue_depth=queue_depth,
                         hedge_after_s=None, device=device,
                         replica_id=replica_id, inflight=inflight,
                         warmup_fn=warmup_fn, monitor=monitor,
                         truth_map=truth_map, faults=faults,
                         health=health,
                         on_batch_failure=on_batch_failure, shed=shed)

    # ------------------------------------------------------------- setup ----
    def _setup_loop(self):
        # input ring: inflight staging slots may sit under in-flight
        # launches while one more is being assembled.
        self._n_slots = self.inflight + 1
        self._slots: list[dict | None] = [None] * self._n_slots
        self._slot_idx = 0
        # output ring: host-side landing buffers for harvested leaves;
        # written and consumed by the single harvest thread, so
        # ``inflight`` slots keep the D2H stage from ever waiting on
        # buffer reuse.
        self._out_ring: list[list | None] = [None] * max(self.inflight, 1)
        self._out_idx = 0
        # FIFO of in-flight launch records, drained by the harvester.
        self._records: deque[dict] = deque()
        self._rec_cond = threading.Condition()
        self._harvester = threading.Thread(
            target=self._harvest_loop, daemon=True,
            name=f"replica{self.replica_id}-harvest")
        self._harvester.start()

    # ---------------------------------------------------------- launcher ----
    def _run(self):
        """Launcher: pop the first waiting event, gate on a free
        in-flight slot, then sweep everything else that queued in the
        meantime into the same launch (rolling batching)."""
        while not self._stop.is_set():
            try:
                seq, t_submit, event, fut = self._q.get(timeout=_POLL_S)
            except queue.Empty:
                continue
            dl = getattr(fut, "deadline", None)
            if dl is not None and time.perf_counter() > dl:
                self._shed_items([(seq, t_submit, event, fut)],
                                 "deadline expired in queue")
                continue
            if self._faults is not None \
                    and self._faults.batcher_kill_due():
                # chaos: the launcher dies mid-batch; the popped event
                # is failed exactly once, close() sweeps the rest.
                from repro.serving.faults import InjectedFault
                self._resolve_err([(seq, t_submit, event, fut)],
                                  InjectedFault(
                                      f"injected launcher kill "
                                      f"(replica {self.replica_id})"))
                return
            staged = [(seq, t_submit, time.perf_counter(), event, fut)]
            acquired = False
            while not (acquired := self._inflight_sem.acquire(
                    timeout=_POLL_S)):
                if self._stop.is_set():
                    break
            if not acquired:
                self._fail_items(staged)   # closing: don't strand futures
                return
            now = time.perf_counter()
            while len(staged) < self.microbatch:
                try:
                    s, t, ev, f = self._q.get_nowait()
                except queue.Empty:
                    break
                dl = getattr(f, "deadline", None)
                if dl is not None and now > dl:
                    self._shed_items([(s, t, ev, f)],
                                     "deadline expired in queue")
                    continue
                staged.append((s, t, now, ev, f))
            try:
                self._launch(staged)
            except Exception:  # noqa: BLE001 — a malformed event (e.g.
                # missing feed key) fails its own launch, never the lane
                self._inflight_sem.release()
                self._fail_items(staged)

    def _pack(self, items, slot_i: int) -> dict:
        """Copy the staged events into input-ring slot ``slot_i`` and
        zero the padded tail.  The slot is allocated once, from the
        first event's feed shapes; a heterogeneous event (shape or
        dtype drift within one replica — never the bucketed path,
        which cuts feeds to the bucket shape) falls back to a fresh
        stack for this launch only."""
        mb = self.microbatch
        n = len(items)
        ev0 = items[0][3]
        try:
            slot = self._slots[slot_i]
            if slot is None:
                slot = self._slots[slot_i] = {
                    k: np.zeros((mb, *np.asarray(v).shape),
                                np.asarray(v).dtype)
                    for k, v in ev0.items()}
            for k, buf in slot.items():
                for i, it in enumerate(items):
                    v = np.asarray(it[3][k])
                    if v.shape != buf.shape[1:] or v.dtype != buf.dtype:
                        raise ValueError("feed drift")
                    buf[i, ...] = v
                if n < mb:
                    buf[n:] = 0
            return slot
        except (KeyError, ValueError, TypeError):
            feeds = {}
            for k in ev0:
                stacked = np.stack([np.asarray(it[3][k]) for it in items])
                if n < mb:
                    z = np.zeros((mb - n, *stacked.shape[1:]),
                                 stacked.dtype)
                    stacked = np.concatenate([stacked, z])
                feeds[k] = stacked
            return feeds

    def _launch(self, items):
        slot_i = self._slot_idx
        self._slot_idx = (slot_i + 1) % self._n_slots
        feeds = self._pack(items, slot_i)
        with self._count_lock:
            self.stats.batches += 1
            self.stats.padded_events += self.microbatch - len(items)
        if self.device is not None:
            import jax
            feeds = jax.device_put(feeds, self.device)
        rec = {"items": items, "t_dispatch": time.perf_counter()}

        def _call(feeds=feeds, rec=rec):
            rec["t_dispatch"] = time.perf_counter()
            return self._infer(feeds)

        # async dispatch: the launcher hands the launch off and goes
        # straight back to assembling the next one.
        rec["fut"] = self._dispatch_pool.submit(_call)
        with self._rec_cond:
            self._records.append(rec)
            self._rec_cond.notify()

    # --------------------------------------------------------- harvester ----
    def _harvest_loop(self):
        """Drain in-flight launches in FIFO order.  Keeps running past
        ``close()`` until every launched record has been released —
        exactly-once release is the launcher/harvester contract."""
        while True:
            with self._rec_cond:
                while not self._records:
                    if self._stop.is_set() and not self._batcher.is_alive():
                        return
                    self._rec_cond.wait(timeout=_POLL_S)
                rec = self._records.popleft()
            try:
                self._harvest(rec)
            finally:
                self._inflight_sem.release()   # frees the input slot

    def _poll_result(self, fut):
        """Poll the launch future (never an unbounded block, so a
        wedged backend can't make shutdown unresponsive)."""
        while True:
            try:
                return fut.result(timeout=_POLL_S)
            except FuturesTimeout:
                continue

    def _to_host_ring(self, leaves) -> list:
        """D2H stage: poll the device buffers, then copy every leaf
        into the preallocated host output-ring slot."""
        if leaves and hasattr(leaves[0], "is_ready"):
            while not all(l.is_ready() for l in leaves):
                time.sleep(5e-5)
        views = [np.asarray(l) for l in leaves]
        out_i = self._out_idx
        self._out_idx = (out_i + 1) % len(self._out_ring)
        slot = self._out_ring[out_i]
        if (slot is None or len(slot) != len(views)
                or any(s.shape != v.shape or s.dtype != v.dtype
                       for s, v in zip(slot, views))):
            slot = self._out_ring[out_i] = [np.empty(v.shape, v.dtype)
                                            for v in views]
        for s, v in zip(slot, views):
            np.copyto(s, v)
        return slot

    def _harvest(self, rec):
        items = rec["items"]
        try:
            out = self._poll_result(rec["fut"])
        except Exception as exc:  # noqa: BLE001 — fault isolation: fail
            # the launch, not the lane; breaker + failover as in the
            # deadline loop's batch-failure path
            self._fail_batch(items, exc, rec["t_dispatch"])
            return
        if self._health is not None:
            self._health.record_success()
        import jax
        leaves, tdef = jax.tree_util.tree_flatten(out)
        host = self._to_host_ring(leaves)
        t_done = time.perf_counter()
        if self._monitor is not None:
            truths = [self._truth_map.pop(it[0], None) for it in items] \
                if self._truth_map else None
            outv = jax.tree_util.tree_unflatten(tdef, host)
            cps = outv.get("cps", outv) if isinstance(outv, dict) else None
            # copies, not views: the output-ring slot is reused while
            # the monitor's staged record is folded lazily much later.
            md = {k: np.array(v) for k, v in cps.items()
                  if not isinstance(v, dict)} \
                if isinstance(cps, dict) else None
            self._monitor.record_raw(
                md, [(it[0], it[1]) for it in items], t_done, truths)
        for i, (seq, t_submit, t_collect, _, fut) in enumerate(items):
            # per-event copies for the same reason: futures outlive the
            # ring slot's next reuse.
            res = jax.tree_util.tree_unflatten(
                tdef, [np.array(l[i]) for l in host])
            timing = EventTiming(self.replica_id, t_submit, t_collect,
                                 rec["t_dispatch"], t_done)
            self._releaser.complete(seq, ("ok", res), timing, fut)

    # ----------------------------------------------------------- control ----
    def close(self):
        self._stop.set()
        self._batcher.join(timeout=5)
        # in-flight launches complete and release normally; everything
        # never launched is failed exactly once below.
        self._dispatch_pool.shutdown(wait=True)
        with self._rec_cond:
            self._rec_cond.notify_all()
        self._harvester.join(timeout=10)
        self._fail_queued()
