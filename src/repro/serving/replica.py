"""Per-replica serving machinery: stats, the merged in-order release
stage, and the replica micro-batch loop.

A ``ReplicaEngine`` is one lane of the sharded service: it owns a
bounded event queue, a micro-batching collector (batch launches when
``microbatch`` events are queued *or* ``window_s`` has elapsed — the
paper's bounded-decision-latency deadline), and a double-buffered
dispatch loop (up to ``inflight`` batches executing while the next
fills, the FPGA analogue of overlapping Load/compute/Store).  Replicas
never release results themselves: every completion is handed to a
shared ``InOrderReleaser`` keyed on the *global* submission sequence
number, so strict submission order is preserved across replicas no
matter how their batches interleave.

This module implements the **deadline** loop (the original
request/response-shaped micro-batcher); ``streaming.py`` subclasses
``ReplicaEngine`` with the persistent streaming-dataflow loop
(preallocated input/output rings, rolling batching, no deadline tick).
The service selects between them with ``loop=``.

Latency budget accounting (paper §III): each event's end-to-end latency
is split into

  queue_wait — submit() until the collector pops the event;
  dispatch   — batch assembly: fill-window residency after the pop,
               stacking/zero-padding, and device placement;
  compute    — the inference call itself (including any hedged retry).

Fault tolerance (docs/serving.md): ``faults=`` wraps the replica's
``infer_fn`` with a deterministic injector (``serving.faults``),
``health=`` feeds a circuit breaker (``serving.health``) one outcome
per batch, ``on_batch_failure=`` lets the service re-dispatch a failed
batch's events to a healthy sibling before they fail to the client,
and ``shed=`` plus a per-event deadline turn the blocking enqueue into
fail-fast admission control (``ShedError``).  All four default off,
reproducing the original behavior bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from collections import deque
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor
from concurrent.futures import TimeoutError as FuturesTimeout
from concurrent.futures import wait as futures_wait

import numpy as np

# per-replica sliding window for latency/budget samples; counters stay
# exact, percentiles reflect the most recent window.
STAT_WINDOW = 65536


class ShedError(RuntimeError):
    """The service refused an event instead of blocking: its lane's
    bounded queue was full under a shed policy, or its deadline
    expired before dispatch.  Load-shedding admission control — the
    client sees the rejection immediately and can drop or resubmit."""


@dataclasses.dataclass
class EventTiming:
    """perf_counter timestamps for one event's trip through a replica."""
    replica_id: int
    t_submit: float
    t_collect: float
    t_dispatch: float
    t_done: float

    @property
    def latency_s(self):
        return self.t_done - self.t_submit

    @property
    def queue_wait_s(self):
        return self.t_collect - self.t_submit

    @property
    def dispatch_s(self):
        return self.t_dispatch - self.t_collect

    @property
    def compute_s(self):
        return self.t_done - self.t_dispatch


def _pct(xs, p):
    return float(np.percentile(np.fromiter(xs, float), p)) if xs \
        else float("nan")


def _stat_window():
    return deque(maxlen=STAT_WINDOW)


@dataclasses.dataclass
class ServingStats:
    """Per-replica counters + bounded sliding-window latency samples
    (the counters are exact for the lifetime of the replica; the
    sample deques hold the last ``STAT_WINDOW`` events so a
    long-running service neither grows without bound nor slows down
    ``summary()``).

    ``latencies_s``/``completed`` are updated by the release stage (so
    they observe strict release order); the batch counters are updated
    by the replica's dispatch loop.  Readers (``summary``, monitoring
    threads) must go through ``samples()``, which snapshots a deque
    under the stats lock — iterating a deque while the releaser
    appends to it raises RuntimeError.
    """
    replica_id: int = 0
    submitted: int = 0
    completed: int = 0
    failed: int = 0
    batches: int = 0
    hedged: int = 0
    padded_events: int = 0
    # fault-tolerance counters: events refused by admission control,
    # events this replica accepted as failover retries, and events a
    # failed batch handed off to a healthy sibling (all 0 on the
    # healthy path).
    shed: int = 0
    retried: int = 0
    failed_over: int = 0
    latencies_s: deque = dataclasses.field(default_factory=_stat_window)
    queue_wait_s: deque = dataclasses.field(default_factory=_stat_window)
    dispatch_s: deque = dataclasses.field(default_factory=_stat_window)
    compute_s: deque = dataclasses.field(default_factory=_stat_window)
    # throughput clock: stamped by the first enqueue, not construction,
    # so a replica built long before traffic reports an honest rate.
    started_at: float | None = None
    lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)

    def samples(self, field: str) -> list:
        """Consistent copy of one sample deque, safe against a live
        release stage."""
        with self.lock:
            return list(getattr(self, field))

    def percentile(self, p):
        return _pct(self.samples("latencies_s"), p)

    def record_release(self, timing: EventTiming):
        with self.lock:
            self.completed += 1
            self.latencies_s.append(timing.latency_s)
            self.queue_wait_s.append(timing.queue_wait_s)
            self.dispatch_s.append(timing.dispatch_s)
            self.compute_s.append(timing.compute_s)

    def throughput_ev_s(self):
        if self.started_at is None:
            return 0.0
        dt = time.perf_counter() - self.started_at
        return self.completed / dt if dt > 0 else 0.0

    def budget(self):
        """Mean per-event latency-budget split, in µs."""
        def mean_us(xs):
            return float(np.fromiter(xs, float).mean()) * 1e6 \
                if xs else None
        return {
            "queue_wait_us_mean": mean_us(self.samples("queue_wait_s")),
            "dispatch_us_mean": mean_us(self.samples("dispatch_s")),
            "compute_us_mean": mean_us(self.samples("compute_s")),
        }

    def summary(self):
        lat = self.samples("latencies_s")
        return {
            "replica_id": self.replica_id,
            "submitted": self.submitted,
            "completed": self.completed,
            "failed": self.failed,
            "batches": self.batches,
            "hedged": self.hedged,
            "padded_events": self.padded_events,
            "shed": self.shed,
            "retried": self.retried,
            "failed_over": self.failed_over,
            "p50_us": _pct(lat, 50) * 1e6 if lat else None,
            "p99_us": _pct(lat, 99) * 1e6 if lat else None,
            "mean_us": float(np.fromiter(lat, float).mean()) * 1e6
            if lat else None,
            "throughput_ev_s": self.throughput_ev_s(),
            "budget": self.budget(),
        }


class InOrderReleaser:
    """Merged release stage: completes futures in global submission
    order regardless of which replica finished first.

    ``complete`` may be called from any replica's dispatch thread; the
    shared lock serializes releases, and a completion for sequence
    number ``k`` is only released once every ``j < k`` has been."""

    def __init__(self, on_release):
        # on_release(seq, outcome, timing, fut); outcome is
        # ("ok", value) or ("err", exception).
        self._on_release = on_release
        self._next = 0
        self._held: dict[int, tuple] = {}
        self._lock = threading.Condition()
        self.released = 0

    def complete(self, seq: int, outcome, timing: EventTiming, fut):
        with self._lock:
            if seq < self._next:
                # exactly-once backstop: a late duplicate (e.g. a buggy
                # failover hook) must not park a stale entry in _held
                # and wedge drain() forever.
                return
            self._held[seq] = (outcome, timing, fut)
            while self._next in self._held:
                out, tm, f = self._held.pop(self._next)
                try:
                    self._on_release(self._next, out, tm, f)
                except Exception:  # noqa: BLE001 — a client-cancelled
                    pass  # future (InvalidStateError) or a bad done-
                    #       callback must not wedge every later seq
                self._next += 1
                self.released += 1
            self._lock.notify_all()

    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._held)


class ReplicaEngine:
    """One serving lane: bounded queue -> deadline micro-batcher ->
    double-buffered dispatch -> shared in-order releaser."""

    loop = "deadline"

    def __init__(self, infer_fn, releaser: InOrderReleaser, *,
                 microbatch: int, window_s: float = 1e-3,
                 queue_depth: int = 1024, hedge_after_s: float | None = None,
                 device=None, replica_id: int = 0, inflight: int = 2,
                 warmup_fn=None, monitor=None, truth_map=None,
                 faults=None, health=None, on_batch_failure=None,
                 shed: bool = False):
        # chaos wrapping happens here — before either loop flavor sees
        # ``self._infer`` — so deadline and streaming dispatch inject
        # at the same point.  ``health`` is this lane's ReplicaHealth
        # (one outcome per batch); ``on_batch_failure(replica, items,
        # exc) -> remaining`` is the service's failover hook; ``shed``
        # turns a full queue into a fast ShedError instead of blocking.
        self._faults = None
        if faults is not None:
            self._faults = faults.for_replica(replica_id)
            self._infer = self._faults.wrap(infer_fn)
        else:
            self._infer = infer_fn
        self._health = health
        self._on_batch_failure = on_batch_failure
        self.shed = bool(shed)
        self._releaser = releaser
        # optional per-replica TriggerMonitor: fed one record_batch per
        # completed micro-batch (vectorized, off the per-event path);
        # truth_map is the service-level {seq: truth} side channel,
        # consumed here so in-flight entries can't outlive their batch.
        self._monitor = monitor
        self._truth_map = truth_map
        self.microbatch = microbatch
        self.window = window_s
        self.hedge_after = hedge_after_s
        self.device = device
        self.inflight = inflight
        self.replica_id = replica_id
        self.stats = ServingStats(replica_id=replica_id)
        # warm-up (e.g. replaying tuning-cache winners so the jit cache
        # is hot) runs BEFORE the batcher thread starts accepting work:
        # the first real event must never pay compilation. Best-effort —
        # a failing warm-up must not kill the lane.
        self.warmed = 0
        if warmup_fn is not None:
            try:
                if self.device is not None:
                    import jax
                    with jax.default_device(self.device):
                        out = warmup_fn()
                else:
                    out = warmup_fn()
                self.warmed = int(out) if isinstance(out, int) else 1
            except Exception:  # noqa: BLE001
                self.warmed = 0
        self._q: queue.Queue = queue.Queue(maxsize=queue_depth)
        self._stop = threading.Event()
        self._count_lock = threading.Lock()
        self._inflight_sem = threading.Semaphore(inflight)
        self._dispatch_pool = ThreadPoolExecutor(
            max_workers=inflight,
            thread_name_prefix=f"replica{replica_id}-dispatch")
        self._hedge_pool = ThreadPoolExecutor(
            max_workers=2 * inflight,
            thread_name_prefix=f"replica{replica_id}-hedge") \
            if hedge_after_s is not None else None
        self._batcher = threading.Thread(
            target=self._run, daemon=True,
            name=f"replica{replica_id}-batcher")
        # loop-specific state (e.g. the streaming engine's rings and
        # harvest thread) must exist before the batcher thread runs.
        self._setup_loop()
        self._batcher.start()

    def _setup_loop(self):
        """Hook for subclasses to build loop state (rings, extra
        stage threads) before the batcher thread starts."""

    # ------------------------------------------------------------ intake ----
    def enqueue(self, seq: int, t_submit: float, event: dict, fut):
        """Blocks when the bounded queue is full (the paper's limited
        buffer capacity -> backpressure on the client).  A close() that
        happens while we are blocked (or raced with the put) fails this
        event's future instead of stranding it in a dead queue.

        With ``shed=True`` a full queue sheds the event immediately
        (``ShedError``) instead of spinning; an event whose deadline
        (stamped on the future by ``submit(deadline_s=)``) has already
        expired is shed regardless of the policy."""
        with self._count_lock:
            self.stats.submitted += 1
            if self.stats.started_at is None:
                self.stats.started_at = t_submit
        item = (seq, t_submit, event, fut)
        dl = getattr(fut, "deadline", None)
        if dl is not None and time.perf_counter() > dl:
            self._shed_items([item], "deadline expired before enqueue")
            return
        if self.shed:
            try:
                self._q.put_nowait(item)
            except queue.Full:
                self._shed_items(
                    [item], f"replica {self.replica_id} queue full "
                            f"({self._q.maxsize} events)")
                return
            if self._stop.is_set():
                self._fail_queued()   # put may have landed after close()
            return
        placed = False
        while not placed and not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.1)
                placed = True
            except queue.Full:
                continue
        if not placed:
            self._fail_items([item])
        elif self._stop.is_set():
            self._fail_queued()   # put may have landed after close()

    def requeue(self, seq: int, t_submit: float, event: dict,
                fut) -> bool:
        """Failover intake: accept an event from another replica's
        failed batch without ever blocking.  False (caller keeps
        ownership of the event) when this lane is stopping or full."""
        if self._stop.is_set():
            return False
        try:
            self._q.put_nowait((seq, t_submit, event, fut))
        except queue.Full:
            return False
        with self._count_lock:
            self.stats.submitted += 1
            self.stats.retried += 1
            if self.stats.started_at is None:
                self.stats.started_at = t_submit
        if self._stop.is_set():
            self._fail_queued()   # close() raced the put; still released
        return True

    def load(self) -> int:
        """Events accepted but not yet released — the least-loaded
        router's ranking signal.  Failed-over events were released by
        a *different* replica, so they are subtracted here to keep the
        signal from drifting."""
        return self.stats.submitted - self.stats.completed \
            - self.stats.failed - self.stats.failed_over

    @property
    def stopping(self) -> bool:
        return self._stop.is_set()

    @property
    def queued(self) -> int:
        return self._q.qsize()

    # ----------------------------------------------------------- batcher ----
    def _collect(self):
        items = []
        deadline = None
        while len(items) < self.microbatch and not self._stop.is_set():
            timeout = self.window if deadline is None else \
                max(1e-4, deadline - time.perf_counter())
            try:
                seq, t_submit, event, fut = self._q.get(timeout=timeout)
            except queue.Empty:
                if items:
                    break
                continue
            dl = getattr(fut, "deadline", None)
            if dl is not None and time.perf_counter() > dl:
                self._shed_items([(seq, t_submit, event, fut)],
                                 "deadline expired in queue")
                continue
            items.append((seq, t_submit, time.perf_counter(), event, fut))
            if deadline is None:
                deadline = time.perf_counter() + self.window
            if deadline and time.perf_counter() > deadline:
                break
        return items

    def _run(self):
        while not self._stop.is_set():
            items = self._collect()
            if not items:
                continue
            if self._faults is not None \
                    and self._faults.batcher_kill_due():
                # chaos: the batcher thread dies mid-batch.  The
                # collected items are failed exactly once first (a
                # stranded future would hold every later seq hostage);
                # later arrivals queue until close() sweeps them.
                from repro.serving.faults import InjectedFault
                self._resolve_err(items, InjectedFault(
                    f"injected batcher kill "
                    f"(replica {self.replica_id})"))
                return
            # double buffering: hand the batch to the dispatch pool and
            # immediately go back to collecting the next one; the
            # semaphore bounds how many batches are in flight.
            acquired = False
            while not (acquired := self._inflight_sem.acquire(timeout=0.1)):
                if self._stop.is_set():
                    break
            if not acquired:
                self._fail_items(items)   # closing: don't strand futures
                return
            self._dispatch_pool.submit(self._dispatch, items)

    def _fail_items(self, items):
        """Fail events that will never be dispatched — routed through
        the shared releaser so their sequence numbers still advance
        ``_next``; bypassing it would hold every later sequence (on any
        replica) hostage forever."""
        self._resolve_err(items, RuntimeError(
            "serving replica closed before dispatch"))

    def _shed_items(self, items, reason: str):
        """Admission control: release refused events (full queue or
        expired deadline) as ``ShedError`` — fail fast, never block,
        sequence numbers still advance."""
        with self._count_lock:
            self.stats.shed += len(items)
        self._resolve_err(items, ShedError(reason))

    def _resolve_err(self, items, exc):
        """Release every item as ``("err", exc)``.  Accepts both queue
        items (seq, t_submit, event, fut) and collected items
        (seq, t_submit, t_collect, event, fut)."""
        now = time.perf_counter()
        for it in items:
            seq, t_submit, fut = it[0], it[1], it[-1]
            if self._truth_map is not None:
                self._truth_map.pop(seq, None)
            t_collect = it[2] if len(it) == 5 else now
            timing = EventTiming(self.replica_id, t_submit, t_collect,
                                 now, now)
            self._releaser.complete(seq, ("err", exc), timing, fut)

    def _dispatch(self, items):
        try:
            self._run_batch(items)
        finally:
            self._inflight_sem.release()

    def _run_batch(self, items):
        n = len(items)
        pad = self.microbatch - n
        feeds = {}
        for key in items[0][3]:
            stacked = np.stack([it[3][key] for it in items])
            if pad:
                z = np.zeros((pad, *stacked.shape[1:]), stacked.dtype)
                stacked = np.concatenate([stacked, z])
            feeds[key] = stacked
        with self._count_lock:
            # batches counts *launched* batches — a failing inference
            # below still launched one.
            self.stats.batches += 1
            self.stats.padded_events += pad
        if self.device is not None:
            import jax
            feeds = jax.device_put(feeds, self.device)
        t_dispatch = time.perf_counter()
        try:
            out = self._call(feeds)
        except Exception as exc:  # noqa: BLE001 — fault isolation: fail
            self._fail_batch(items, exc, t_dispatch)   # the batch, not
            return                                     # the replica
        if self._health is not None:
            self._health.record_success()
        import jax
        leaves, tdef = jax.tree_util.tree_flatten(out)
        # materialize BEFORE stamping t_done: under jax async dispatch
        # the call above returns unfinished arrays, and the compute
        # budget must include the actual device time.
        np_leaves = [np.asarray(l) for l in leaves]
        t_done = time.perf_counter()
        if self._monitor is not None:
            # one deque append; the truth pops stay here (not in the
            # deferred fold) so the side-channel map stays bounded by
            # in-flight events even if no reader ever drains.  Only
            # the CPS subtree is staged — np.asarray after the
            # materialization above is a cheap view, and staging the
            # full result/items would pin inputs and futures.
            truths = [self._truth_map.pop(it[0], None) for it in items] \
                if self._truth_map else None
            cps = out.get("cps", out) if isinstance(out, dict) else None
            rec = {k: np.asarray(v) for k, v in cps.items()
                   if not isinstance(v, dict)} \
                if isinstance(cps, dict) else None
            self._monitor.record_raw(
                rec, [(it[0], it[1]) for it in items], t_done, truths)
        for i, (seq, t_submit, t_collect, _, fut) in enumerate(items):
            res = jax.tree_util.tree_unflatten(
                tdef, [l[i] for l in np_leaves])
            timing = EventTiming(self.replica_id, t_submit, t_collect,
                                 t_dispatch, t_done)
            self._releaser.complete(seq, ("ok", res), timing, fut)

    def _fail_batch(self, items, exc, t_dispatch):
        """Batch-failure path: feed the breaker, offer the events to
        the service's failover hook (bounded re-dispatch to a healthy
        sibling in the same group), then fail whatever could not be
        moved — each event is released exactly once either way."""
        if self._health is not None:
            self._health.record_failure()
        remaining = items
        if self._on_batch_failure is not None:
            try:
                remaining = self._on_batch_failure(self, items, exc)
            except Exception:  # noqa: BLE001 — a broken hook must not
                remaining = items  # strand the batch
        moved = len(items) - len(remaining)
        if moved:
            with self._count_lock:
                self.stats.failed_over += moved
        if not remaining:
            return
        t_done = time.perf_counter()
        for seq, t_submit, t_collect, _, fut in remaining:
            if self._truth_map is not None:
                self._truth_map.pop(seq, None)
            timing = EventTiming(self.replica_id, t_submit, t_collect,
                                 t_dispatch, t_done)
            self._releaser.complete(seq, ("err", exc), timing, fut)

    def _call(self, feeds):
        if self.hedge_after is None:
            return self._infer(feeds)
        # a close() can race an in-flight dispatch: the hedge pool is
        # already shut down and submit() raises RuntimeError.  Route
        # that to the batch-failure path (clean per-batch failure)
        # instead of leaking an unresolved future.
        try:
            primary = self._hedge_pool.submit(self._infer, feeds)
        except RuntimeError as exc:
            raise RuntimeError(
                "hedge pool shut down during dispatch") from exc
        try:
            return primary.result(timeout=self.hedge_after)
        except FuturesTimeout:
            pass  # straggler: hedge below. Real faults propagate to
            #       the batch-failure path instead of being re-run.
        with self._count_lock:
            self.stats.hedged += 1
        # re-dispatch to the backup lane and take whichever lane
        # returns first (duplicate-safe because inference is pure);
        # a lane that *fails* defers to the other one.
        try:
            backup = self._hedge_pool.submit(self._infer, feeds)
        except RuntimeError:
            backup = None   # closing: ride the primary out alone
        lanes = {primary, backup} if backup is not None else {primary}
        last_exc = None
        while lanes:
            done, lanes = futures_wait(lanes, return_when=FIRST_COMPLETED)
            for lane in done:
                if lane.exception() is None:
                    return lane.result()
                last_exc = lane.exception()
        raise last_exc

    # ----------------------------------------------------------- control ----
    def _fail_queued(self):
        """Fail anything still queued so no client hangs in
        fut.result(); idempotent — also called from a racing enqueue."""
        leftovers = []
        while True:
            try:
                leftovers.append(self._q.get_nowait())
            except queue.Empty:
                break
        if leftovers:
            self._fail_items(leftovers)

    def close(self):
        self._stop.set()
        self._batcher.join(timeout=5)
        self._dispatch_pool.shutdown(wait=True)
        if self._hedge_pool is not None:
            self._hedge_pool.shutdown(wait=False)
        self._fail_queued()
