"""Dependency-free streaming endpoint for the trigger monitor (the
webserver half of the paper's §III-B visualization pipeline).

``MonitorServer`` serves, from a daemon thread on stdlib
``http.server`` only:

  ``/snapshot``   one JSON ``MonitorSnapshot`` (fleet view);
  ``/events``     NDJSON tail of the event-display ring
                  (``?n=K`` limits the tail length);
  ``/``           a self-contained HTML/SVG live event display that
                  polls the two endpoints — no external assets, so it
                  works on an air-gapped control-room machine.

The server only *reads* monitor state (snapshot/display aggregation
runs on its request threads, never on the serving hot path), so it can
be attached to a live ``ShardedTriggerService`` with bounded overhead.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlparse

__all__ = ["MonitorServer"]

_PAGE = """<!DOCTYPE html>
<html><head><meta charset="utf-8"><title>trigger monitor</title>
<style>
 body{font:13px/1.4 monospace;background:#111;color:#ddd;margin:1em}
 table{border-collapse:collapse;margin-bottom:1em}
 td{border:1px solid #444;padding:2px 8px}
 svg{background:#181818;border:1px solid #444}
 .trig{fill:#ffb347}.notrig{fill:#5b9bd5}
</style></head><body>
<h3>real-time trigger monitor</h3>
<table id="stats"></table>
<svg id="disp" width="640" height="360"></svg>
<div id="cap"></div>
<script>
const FIELDS=["events","window_events","rate_ev_s","trigger_rate",
 "clusters_per_event","cluster_e_mean","latency_p50_us",
 "latency_p99_us","efficiency","fake_rate"];
function fmt(v){return v==null?"–":(typeof v=="number"?
 (Number.isInteger(v)?v:v.toPrecision(4)):v)}
async function tick(){
 try{
  const s=await (await fetch("snapshot")).json();
  document.getElementById("stats").innerHTML=FIELDS.map(
   k=>`<tr><td>${k}</td><td>${fmt(s[k])}</td></tr>`).join("");
  const txt=await (await fetch("events?n=1")).text();
  const lines=txt.trim().split("\\n").filter(x=>x);
  if(lines.length){
   const ev=JSON.parse(lines[lines.length-1]);
   const svg=document.getElementById("disp");
   const [nt,nph]=ev.grid||[56,156];
   const W=svg.getAttribute("width"),H=svg.getAttribute("height");
   svg.innerHTML=ev.clusters.map(c=>{
    const x=c.phi/nph*W, y=(1-c.theta/nt)*H,
          r=3+6*Math.min(1,c.energy);
    return `<circle cx="${x}" cy="${y}" r="${r}" `+
     `class="${ev.trigger?"trig":"notrig"}" opacity="${0.35+0.65*c.beta}">`+
     `<title>E=${c.energy.toFixed(3)} β=${c.beta.toFixed(2)}</title>`+
     `</circle>`}).join("");
   document.getElementById("cap").textContent=
    `event ${ev.event} · trigger=${ev.trigger}`+
    (("truth" in ev)?` · truth=${ev.truth}`:"")+
    ` · ${ev.clusters.length} cluster(s) · grid ${nt}×${nph}`;
  }
 }catch(e){/* service draining; keep polling */}
 setTimeout(tick,500);
}
tick();
</script></body></html>
"""


class _Handler(BaseHTTPRequestHandler):
    # the snapshot/events callables are attached to the *server*
    # instance so one handler class serves any monitor.
    def _send(self, code: int, ctype: str, body: bytes):
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self):  # noqa: N802 — http.server API
        url = urlparse(self.path)
        try:
            if url.path in ("/", "/index.html", "/display"):
                self._send(200, "text/html; charset=utf-8",
                           _PAGE.encode())
            elif url.path == "/snapshot":
                snap = self.server.snapshot_fn()
                self._send(200, "application/json",
                           json.dumps(snap).encode())
            elif url.path == "/events":
                qs = parse_qs(url.query)
                n = int(qs["n"][0]) if "n" in qs else None
                recs = self.server.events_fn(n)
                body = "".join(json.dumps(r) + "\n" for r in recs)
                self._send(200, "application/x-ndjson", body.encode())
            else:
                self._send(404, "text/plain", b"not found\n")
        except BrokenPipeError:
            pass                       # client went away mid-reply
        except Exception as exc:  # noqa: BLE001 — a bad read must not
            try:                  # kill the serving process's thread
                self._send(500, "text/plain",
                           f"monitor error: {exc}\n".encode())
            except OSError:
                pass

    def log_message(self, *args):      # stay quiet on the hot console
        pass


class MonitorServer:
    """Serve a monitor (or monitored service) over HTTP.

    ``snapshot_fn`` returns a JSON-ready dict; ``events_fn(n)`` returns
    the last ``n`` (all when ``None``) event-display records.  Use
    ``MonitorServer.for_service(svc)`` to wire both to a
    ``ShardedTriggerService(monitor=...)``.  ``port=0`` binds an
    ephemeral port (read it back from ``.port``/``.url``).
    """

    def __init__(self, snapshot_fn, events_fn, *, port: int = 0,
                 host: str = "127.0.0.1"):
        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._httpd.daemon_threads = True
        self._httpd.snapshot_fn = snapshot_fn
        self._httpd.events_fn = events_fn
        self.host, self.port = self._httpd.server_address[:2]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, daemon=True,
            name=f"monitor-server:{self.port}")
        self._thread.start()

    @classmethod
    def for_service(cls, service, *, port: int = 0,
                    host: str = "127.0.0.1") -> "MonitorServer":
        if not getattr(service, "monitoring", False):
            raise RuntimeError(
                "service has no monitors; construct it with "
                "monitor=True")
        return cls(service.monitor_snapshot, service.event_displays,
                   port=port, host=host)

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def close(self):
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
