"""Monitoring/visualization substrate (paper §III-B: the demonstrator's
postprocessing + event-display pipeline).

- ``TriggerMonitor``: rolling trigger-rate / cluster-occupancy /
  latency / truth-matched efficiency statistics plus a bounded ring
  buffer of event-display records.  ``record()`` is the hot-path entry
  point and does O(1) work — it stages a reference and a timestamp and
  returns; all numpy conversion, windowed aggregation, and display-dict
  building is deferred to ``snapshot()``/``displays()``, which run on
  the monitoring thread (the paper streams these to an external
  client, not through the trigger path).
- ``MonitorSnapshot``: one monitor's statistics as a plain JSON-ready
  dict; ``MonitorSnapshot.merge`` pools several per-replica monitors
  into the fleet view.
- ``event_display``: one event's display payload (cluster positions in
  detector (θ, φ) coordinates, energies, β) as a JSON-serializable
  dict.  The grid comes from the detector config — never hard-coded.
"""
from __future__ import annotations

import collections
import json
import threading
import time

import numpy as np

__all__ = ["MonitorSnapshot", "TriggerMonitor", "detector_grid",
           "event_display", "write_display"]

# θ × φ crystal grids of the two Belle II ECL readouts the repo models
# (see data.belle2): keyed by crystal count so either a Belle2Config
# (which carries .grid) or a CCNConfig (which carries .n_crystals)
# identifies its detector.
_GRIDS_BY_CRYSTALS = {576: (24, 24), 8736: (56, 156)}
_DEFAULT_GRID = (56, 156)          # the upgraded detector (paper target)


def detector_grid(detector=None) -> tuple[int, int]:
    """(n_θ, n_φ) for a detector/CCN config: ``Belle2Config.grid`` when
    present, else inferred from ``n_crystals``; ``None`` means the
    upgraded-detector default."""
    if detector is None:
        return _DEFAULT_GRID
    grid = getattr(detector, "grid", None)
    if grid is not None:
        nt, nph = grid
        return int(nt), int(nph)
    n = getattr(detector, "n_crystals", None)
    if n in _GRIDS_BY_CRYSTALS:
        return _GRIDS_BY_CRYSTALS[n]
    raise ValueError(
        f"cannot infer a (θ, φ) grid from {detector!r}: expected a "
        f".grid attribute or n_crystals in {sorted(_GRIDS_BY_CRYSTALS)}")


def event_display(cps_result, *, event_id: int, detector=None,
                  grid=None, truth: bool | None = None) -> dict:
    """One event's display record: cluster (θ, φ) detector coordinates,
    energy and β per condensation point.

    ``cluster_xy`` are learned normalized coordinates nominally in
    [-0.5, 0.5] (hit features are ``idx/n - 0.5``); they are clipped to
    that extent before mapping onto the grid, so a cluster the network
    places slightly outside the detector renders at the edge instead of
    off-screen.  Pass the detector (or CCN) config so the grid matches
    the geometry that produced the event — 24×24 for the current
    trigger, 56×156 for the upgrade.
    """
    if grid is None:
        grid = detector_grid(detector)
    nt, nph = int(grid[0]), int(grid[1])
    valid = np.asarray(cps_result["cluster_valid"]) > 0
    xy = np.clip(np.asarray(cps_result["cluster_xy"], np.float64),
                 -0.5, 0.5)
    e = np.asarray(cps_result["cluster_e"])
    beta = np.asarray(cps_result["cluster_beta"])
    rec = {
        "event": int(event_id),
        "trigger": bool(np.asarray(cps_result["trigger"])),
        "grid": [nt, nph],
        "clusters": [
            {"theta": float((xy[i, 0] + 0.5) * nt),
             "phi": float((xy[i, 1] + 0.5) * nph),
             "energy": float(e[i]),
             "beta": float(beta[i])}
            for i in range(valid.size) if valid[i]],
    }
    if truth is not None:
        rec["truth"] = bool(truth)
    return rec


class MonitorSnapshot(dict):
    """One monitor's statistics as a plain dict (JSON-ready).

    ``merge`` pools the raw windowed samples of several per-replica
    monitors into one fleet-level snapshot, so percentiles and rates
    are computed over the union of windows rather than averaged
    averages."""

    @classmethod
    def merge(cls, monitors) -> "MonitorSnapshot":
        monitors = list(monitors)
        pooled = [m._pooled_samples() for m in monitors]
        now = monitors[0]._clock() if monitors else time.perf_counter()

        def tot(key):
            return sum(p[key] for p in pooled)

        firsts = [p["first_time"] for p in pooled
                  if p["first_time"] is not None]
        return cls(_snapshot_from(
            events=tot("events"),
            window_events=tot("window_events"),
            first_time=min(firsts) if firsts else None,
            trig_sum=tot("trig_sum"), trig_n=tot("trig_n"),
            nclus_sum=tot("nclus_sum"), nclus_n=tot("nclus_n"),
            e_sum=tot("e_sum"), e_n=tot("e_n"),
            lat=np.concatenate([np.asarray(p["lat"], np.float64)
                                for p in pooled])
            if pooled else np.empty(0),
            sig=tot("sig"), sig_fired=tot("sig_fired"),
            bkg=tot("bkg"), bkg_fired=tot("bkg_fired"),
            t0=min((p["t0"] for p in pooled), default=now),
            now=now))


def _snapshot_from(*, events, window_events, first_time, trig_sum,
                   trig_n, nclus_sum, nclus_n, e_sum, e_n, lat, sig,
                   sig_fired, bkg, bkg_fired, t0, now) -> dict:
    """Assemble the snapshot dict from windowed running sums.  ``now``
    is the single wall-clock reading every derived quantity shares —
    ``wall_s``, ``window_s`` and ``rate_ev_s`` can never disagree
    about what time it is."""
    window_s = (now - first_time) if first_time is not None else 0.0
    lat_a = np.asarray(lat, np.float64) if len(lat) else None
    return {
        "events": events,                       # lifetime counter
        "window_events": window_events,         # everything below is
        "wall_s": now - t0,                     # over this window
        "window_s": window_s,
        "rate_ev_s": window_events / window_s if window_s > 0 else 0.0,
        "trigger_rate": trig_sum / trig_n if trig_n else None,
        "clusters_per_event": nclus_sum / nclus_n if nclus_n else None,
        "cluster_e_mean": e_sum / e_n if e_n else None,
        "latency_p50_us": float(np.percentile(lat_a, 50)) * 1e6
        if lat_a is not None else None,
        "latency_p99_us": float(np.percentile(lat_a, 99)) * 1e6
        if lat_a is not None else None,
        "truth_events": int(sig + bkg),
        "efficiency": sig_fired / sig if sig else None,
        "fake_rate": bkg_fired / bkg if bkg else None,
    }


class _Ring:
    """Fixed-capacity numpy ring with a running sum.  Writes are
    vectorized slice assignments that subtract the overwritten segment
    from the sum in the same step, so windowed means are O(1) and
    eviction costs no per-element Python at all."""

    __slots__ = ("buf", "cap", "head", "count", "sum", "_writes")

    def __init__(self, cap: int, dtype=np.float64):
        self.buf = np.zeros(cap, dtype)
        self.cap = cap
        self.head = 0
        self.count = 0
        self.sum = 0.0
        self._writes = 0

    def extend(self, vals):
        vals = np.asarray(vals, self.buf.dtype)
        m = vals.size
        if m == 0:
            return
        if m >= self.cap:
            vals = vals[-self.cap:]
            m = self.cap
        i, end = self.head, self.head + m
        if end <= self.cap:
            seg = self.buf[i:end]
            self.sum += float(vals.sum()) - float(seg.sum())
            seg[:] = vals
        else:
            k = self.cap - i
            lo, hi = self.buf[i:], self.buf[:end - self.cap]
            self.sum += (float(vals.sum()) - float(lo.sum())
                         - float(hi.sum()))
            lo[:] = vals[:k]
            hi[:] = vals[k:]
        self.head = end % self.cap
        self.count = min(self.count + m, self.cap)
        self._writes += 1
        if self._writes % 4096 == 0:    # float-drift resync (cheap;
            self.sum = float(self.buf.sum())   # exact for 0/1 data)

    def append(self, v):
        self.extend(np.asarray([v], self.buf.dtype))

    def window(self) -> np.ndarray:
        """The live values (unordered — fine for means/percentiles)."""
        return self.buf[:self.count] if self.count < self.cap \
            else self.buf


class TriggerMonitor:
    """Rolling trigger statistics with hot-path-cheap recording.

    The hot-path entry points — ``record()`` per event,
    ``record_batch()``/``record_raw()`` per micro-batch — only append
    a reference tuple to a bounded staging deque and bump the lifetime
    counter; no numpy runs on the serving path.  Staged entries are
    folded lazily, under ``_agg_lock``, whenever a reader calls
    ``snapshot()``/``displays()``: windowed statistics live in
    fixed-size numpy rings with running sums (vectorized eviction,
    O(1) means), the windowed rate comes from per-fold
    ``(timestamp, events)`` marks, and display dicts are only built
    for the records a reader actually asks for.  If no reader ever
    shows up the staging deque just wraps (bounded at ``window``
    staged entries — an entry is one event or one micro-batch of CPS
    arrays, so a wrap on the batch path drops that whole batch's
    samples), and nothing unbounded accumulates.

    ``display_every`` thins the event-display ring (keep every k-th
    event, by event id, on both the per-event and the batch paths);
    ``clock`` is injectable for deterministic tests.
    """

    def __init__(self, *, window: int = 4096, display_n: int = 64,
                 display_every: int = 1, detector=None, grid=None,
                 clock=time.perf_counter):
        self.window = window
        self.grid = tuple(grid) if grid is not None \
            else detector_grid(detector)
        self.display_every = max(1, int(display_every))
        self._clock = clock
        self.total = 0
        # the lifetime counter is bumped from concurrent dispatch
        # workers (a replica runs up to `inflight` batches at once);
        # a bare += would lose increments.
        self._total_lock = threading.Lock()
        self.t0 = clock()
        self._pending = collections.deque(maxlen=window)
        self._display = collections.deque(maxlen=display_n)
        # rate marks: one (timestamp, events-folded-before) pair per
        # folded record/batch; the windowed rate spans the retained
        # marks, costing one deque append per batch instead of one
        # timestamped entry per event.
        self._marks = collections.deque(maxlen=window)
        self._folded = 0
        # windowed state lives in numpy rings: O(1) means, vectorized
        # eviction, and the latency percentile reads the ring buffer
        # directly without a copy.
        self._lat = _Ring(window)
        self._trig = _Ring(window)        # 0/1 trigger decisions
        self._nclus = _Ring(window)       # clusters per event
        self._energy = _Ring(window)      # per-cluster energies
        # truth-matched windows (per event *with* a truth bit):
        self._tr_sig = _Ring(window)      # 1 if truth-signal
        self._tr_sigf = _Ring(window)     # fired & truth-signal
        self._tr_bkgf = _Ring(window)     # fired & truth-background
        self._agg_lock = threading.Lock()

    # ------------------------------------------------------------ hot path --
    def record(self, cps_result, latency_s: float | None = None, *,
               truth: bool | None = None, event_id: int | None = None):
        """Stage one event's CPS result (or a full result dict holding
        a ``"cps"`` key).  O(1): two appends, no numpy."""
        with self._total_lock:
            self.total += 1
        self._pending.append(("e", self._clock(), cps_result, latency_s,
                              truth, event_id))

    def record_batch(self, cps_batch, n: int, *, latencies_s=None,
                     truths=None, event_ids=None, t: float | None = None):
        """Stage one *batch* of CPS results — dict of arrays with a
        leading batch dim, of which the first ``n`` rows are real
        events (the rest is zero-padding).  This is the serving path:
        one O(1) append per micro-batch, and the fold is vectorized
        over the batch at drain time, so monitoring cost per event is a
        fraction of a microsecond instead of a Python-loop body.

        ``latencies_s``/``truths``/``event_ids`` are per-event
        sequences of length ``n`` (``truths`` entries may be ``None``
        for events submitted without a truth bit)."""
        with self._total_lock:
            self.total += n
        self._pending.append(("b", t if t is not None else self._clock(),
                              cps_batch, n, latencies_s, truths,
                              event_ids))

    def record_raw(self, rec, pairs, t_done: float, truths):
        """Serving-internal variant of ``record_batch``: the replica
        batch loop hands over the batch's CPS dict (numpy arrays,
        padding rows included) plus (seq, t_submit) pairs for the real
        events; latency/event-id extraction is deferred to the fold.
        Staging only the CPS arrays — not the full result pytree, the
        input events, or the futures — bounds what an unread staging
        deque can pin.  ``truths`` is a per-event list (or ``None``)."""
        with self._total_lock:
            self.total += len(pairs)
        self._pending.append(("r", t_done, rec, pairs, truths))

    # ----------------------------------------------------------- readers ----
    def _drain(self):
        """Fold staged entries into the windowed rings; caller holds
        ``_agg_lock``.  ``popleft`` racing a concurrent ``record`` is
        safe — deque ops are atomic — and an eviction on the staging
        side only drops the oldest staged entry (one event, or one
        batch's samples on the batch path)."""
        while True:
            try:
                entry = self._pending.popleft()
            except IndexError:
                break
            if entry[0] == "b":
                self._fold_batch(*entry[1:])
            elif entry[0] == "r":
                self._fold_raw(*entry[1:])
            else:
                self._fold_event(*entry[1:])

    def _fold_raw(self, t_done, rec, pairs, truths):
        """Fold a staged raw batch (see ``record_raw``); the per-event
        latency/id extraction the hot path skipped happens here, on
        the reader's thread."""
        self._fold_batch(
            t_done, rec, len(pairs),
            [t_done - p[1] for p in pairs], truths,
            [p[0] for p in pairs])

    def _mark(self, t, n):
        """Advance the rate window by one fold of ``n`` events; trim
        marks so the retained span tracks ``window`` events — the same
        population the stat rings cover."""
        self._marks.append((t, self._folded))
        self._folded += n
        while len(self._marks) > 1 and \
                self._folded - self._marks[1][1] >= self.window:
            self._marks.popleft()

    def _fold_event(self, t, rec, latency_s, truth, event_id):
        if isinstance(rec, dict) and "cps" in rec:
            rec = rec["cps"]
        self._mark(t, 1)
        if latency_s is not None:
            self._lat.append(latency_s)
        if not isinstance(rec, dict):
            return                # CPS-less payload: rate/latency only
        # plain bool()/int() — the release path hands us numpy
        # scalars, and np.asarray wrappers here are pure overhead
        fired = None
        if "trigger" in rec:
            fired = bool(rec["trigger"])
            self._trig.append(fired)
        if "n_clusters" in rec:
            n = int(rec["n_clusters"])
            self._nclus.append(n)
            if n and "cluster_e" in rec:
                e = np.asarray(rec["cluster_e"])
                v = np.asarray(rec["cluster_valid"]) > 0
                self._energy.extend(e[v])
        if truth is not None and fired is not None:
            truth = bool(truth)
            self._tr_sig.append(truth)
            self._tr_sigf.append(fired and truth)
            self._tr_bkgf.append(fired and not truth)
        eid = event_id if event_id is not None \
            else self.total - len(self._pending) - 1
        if "cluster_xy" in rec and eid % self.display_every == 0:
            # stage the reference; the display dict is built only when
            # a reader actually asks (``displays()``), so at most
            # ``display_n`` dicts are built per read instead of one
            # per event.
            self._display.append(("e", rec, eid, truth))

    def _fold_batch(self, t, rec, n, latencies_s, truths, event_ids):
        """Vectorized fold of one staged micro-batch (first ``n`` rows
        real)."""
        if isinstance(rec, dict) and "cps" in rec:
            rec = rec["cps"]
        self._mark(t, n)
        if latencies_s is not None:
            self._lat.extend(latencies_s)
        if not isinstance(rec, dict):
            return
        fired = None
        if "trigger" in rec:
            fired = np.asarray(rec["trigger"][:n], bool)
            self._trig.extend(fired)
        if "n_clusters" in rec:
            self._nclus.extend(np.asarray(rec["n_clusters"][:n]))
            if "cluster_e" in rec:
                e = np.asarray(rec["cluster_e"][:n])
                v = np.asarray(rec["cluster_valid"][:n]) > 0
                self._energy.extend(e[v])
        if truths is not None and fired is not None:
            if None in truths:      # mixed: fold only the truth-carrying
                pairs = [(f, tr) for f, tr in zip(fired.tolist(), truths)
                         if tr is not None]
                if pairs:
                    f_arr = np.asarray([p[0] for p in pairs], bool)
                    t_arr = np.asarray([p[1] for p in pairs], bool)
                else:
                    f_arr = t_arr = None
            else:
                f_arr = fired
                t_arr = np.asarray(truths, bool)
            if t_arr is not None:
                self._tr_sig.extend(t_arr)
                self._tr_sigf.extend(f_arr & t_arr)
                self._tr_bkgf.extend(f_arr & ~t_arr)
        if "cluster_xy" in rec:
            # stage references only (display dicts are built lazily by
            # displays(), bounded by its limit); an entry pins one
            # micro-batch's CPS arrays until evicted — compact, since
            # the serving path stages just the CPS subtree.
            base = self._folded - n
            ids = event_ids if event_ids is not None \
                else range(base, base + n)
            if self.display_every == 1:
                rows = range(n)
            else:
                rows = [i for i in range(n)
                        if ids[i] % self.display_every == 0]
            if rows:
                self._display.append(("b", rec, rows, truths, ids))

    def _stat_kwargs(self) -> dict:
        """Windowed running sums + the latency window; caller holds
        the lock and has drained.  Everything here is O(1) except the
        latency buffer, which is handed over as the ring's live view
        (readers only reduce it)."""
        sig = self._tr_sig.sum
        n_truth = self._tr_sig.count
        if self._marks:
            t_first, folded_before = self._marks[0]
        else:
            t_first, folded_before = None, self._folded
        return {
            "events": self.total,
            "window_events": self._folded - folded_before,
            "first_time": t_first,
            "trig_sum": self._trig.sum, "trig_n": self._trig.count,
            "nclus_sum": self._nclus.sum, "nclus_n": self._nclus.count,
            "e_sum": self._energy.sum, "e_n": self._energy.count,
            "lat": self._lat.window(),
            "sig": sig, "sig_fired": self._tr_sigf.sum,
            "bkg": n_truth - sig, "bkg_fired": self._tr_bkgf.sum,
            "t0": self.t0,
        }

    def _pooled_samples(self) -> dict:
        """Consistent copy of the windowed state (drains staging
        first) — the merge substrate.  The latency buffer is copied:
        ``merge`` reduces it after this lock is released, and another
        reader's fold could be overwriting the live ring by then."""
        with self._agg_lock:
            self._drain()
            kw = self._stat_kwargs()
            kw["lat"] = kw["lat"].copy()
            return kw

    def snapshot(self) -> MonitorSnapshot:
        """Windowed statistics.  The clock is read exactly once, so
        ``wall_s``, ``window_s`` and ``rate_ev_s`` are mutually
        consistent, and the rate is windowed (recent events / window
        span) — only ``events`` is a lifetime counter."""
        with self._agg_lock:
            self._drain()
            now = self._clock()
            return MonitorSnapshot(
                _snapshot_from(now=now, **self._stat_kwargs()))

    _ROW_KEYS = ("trigger", "cluster_valid", "cluster_xy", "cluster_e",
                 "cluster_beta")

    def displays(self, n: int | None = None) -> list[dict]:
        """Most recent event-display records, oldest first.  Display
        dicts are built here, newest-first until the limit is hit, so
        reads touch at most ``n`` (default ``display_n``) events no
        matter how much is staged."""
        limit = n if n is not None else self._display.maxlen
        if limit <= 0:
            return []
        with self._agg_lock:
            self._drain()
            staged = list(self._display)
        out: list[dict] = []
        for entry in reversed(staged):
            if entry[0] == "e":
                _, rec, eid, truth = entry
                out.append(event_display(rec, event_id=eid,
                                         grid=self.grid, truth=truth))
            else:
                _, rec, rows, truths, eids = entry
                for i in reversed(rows):
                    row = {k: rec[k][i] for k in self._ROW_KEYS
                           if k in rec}
                    out.append(event_display(
                        row, event_id=eids[i], grid=self.grid,
                        truth=truths[i] if truths is not None
                        else None))
                    if len(out) >= limit:
                        break
            if len(out) >= limit:
                break
        out.reverse()
        return out


def write_display(path: str, records: list[dict]):
    with open(path, "w") as f:
        json.dump(records, f, indent=1)
