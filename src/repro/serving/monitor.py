"""Monitoring/visualization substrate (paper §III-B: the demonstrator's
postprocessing + event-display pipeline, minus the webserver).

- ``TriggerMonitor``: rolling trigger-rate / cluster-occupancy /
  latency statistics with fixed-size reservoirs (cheap enough for the
  hot path; the paper streams these to an external client).
- ``event_display``: the 3-D event-display payload (cluster positions in
  detector coordinates, energies, β) as JSON-serializable dicts.
"""
from __future__ import annotations

import collections
import json
import time

import numpy as np


class TriggerMonitor:
    def __init__(self, *, window: int = 4096):
        self.window = window
        self._trig = collections.deque(maxlen=window)
        self._nclus = collections.deque(maxlen=window)
        self._energy = collections.deque(maxlen=window)
        self._lat = collections.deque(maxlen=window)
        self.total = 0
        self.t0 = time.perf_counter()

    def record(self, cps_result, latency_s: float | None = None):
        """cps_result: one event's CPS dict (numpy-compatible leaves)."""
        self.total += 1
        self._trig.append(bool(np.asarray(cps_result["trigger"])))
        n = int(np.asarray(cps_result["n_clusters"]))
        self._nclus.append(n)
        if n:
            e = np.asarray(cps_result["cluster_e"])
            v = np.asarray(cps_result["cluster_valid"]) > 0
            self._energy.extend(e[v].tolist())
        if latency_s is not None:
            self._lat.append(latency_s)

    def snapshot(self) -> dict:
        lat = np.asarray(self._lat) if self._lat else None
        return {
            "events": self.total,
            "wall_s": time.perf_counter() - self.t0,
            "rate_ev_s": self.total / max(time.perf_counter() - self.t0,
                                          1e-9),
            "trigger_rate": float(np.mean(self._trig)) if self._trig
            else None,
            "clusters_per_event": float(np.mean(self._nclus))
            if self._nclus else None,
            "cluster_e_mean": float(np.mean(self._energy))
            if self._energy else None,
            "latency_p50_us": float(np.percentile(lat, 50)) * 1e6
            if lat is not None else None,
            "latency_p99_us": float(np.percentile(lat, 99)) * 1e6
            if lat is not None else None,
        }


def event_display(cps_result, *, event_id: int, grid=(56, 156),
                  truth: bool | None = None) -> dict:
    """One event's display record: cluster (θ, φ) detector coordinates
    (cluster_xy are normalized learned coords ∈ detector units here),
    energy and β per condensation point."""
    valid = np.asarray(cps_result["cluster_valid"]) > 0
    xy = np.asarray(cps_result["cluster_xy"])
    rec = {
        "event": int(event_id),
        "trigger": bool(np.asarray(cps_result["trigger"])),
        "clusters": [
            {"theta": float((xy[i, 0] + 0.5) * grid[0]),
             "phi": float((xy[i, 1] + 0.5) * grid[1]),
             "energy": float(np.asarray(cps_result["cluster_e"])[i]),
             "beta": float(np.asarray(cps_result["cluster_beta"])[i])}
            for i in range(valid.size) if valid[i]],
    }
    if truth is not None:
        rec["truth"] = bool(truth)
    return rec


def write_display(path: str, records: list[dict]):
    with open(path, "w") as f:
        json.dump(records, f, indent=1)
