"""Per-replica health tracking and circuit breaking.

A replica whose ``infer_fn`` fails permanently must stop receiving its
full share of traffic — the paper's trigger degrades gracefully or not
at all.  ``ReplicaHealth`` tracks three signals per lane, fed by the
batch loops on every batch outcome:

  * EWMA failure rate (``ewma_alpha`` smoothing over batch outcomes);
  * consecutive-failure count;
  * last-success clock (monotonic).

They drive a standard three-state circuit breaker:

  closed     healthy: full traffic.  Trips to *open* after
             ``fail_threshold`` consecutive failures, or when the EWMA
             failure rate crosses ``ewma_threshold`` (with at least
             ``min_samples`` outcomes observed);
  open       no traffic for a cool-down (``open_s``); the router skips
             the lane entirely.  When the cool-down expires the
             breaker moves to *half-open*;
  half-open  probe: the router may send ``half_open_probes`` batches
             through.  A success closes the breaker; a failure
             re-opens it with an exponentially longer cool-down
             (``backoff``×, capped at ``max_open_s``) — the bounded
             exponential backoff of the failover path.

``Router.pick`` (``router.py``) consumes this via ``available()`` /
``score()``: skip open lanes, tie-break by health among the healthy,
fall back to the least-bad lane when every breaker is open (the
trigger must keep deciding, even degraded).  All state transitions are
lock-protected and clock-injected, so tests drive them with a fake
clock.
"""
from __future__ import annotations

import dataclasses
import threading
import time

BREAKER_STATES = ("closed", "open", "half_open")


@dataclasses.dataclass(frozen=True)
class BreakerConfig:
    """Circuit-breaker tuning; the defaults suit sub-ms batch loops
    (trip fast, probe fast, back off to ``max_open_s``)."""
    fail_threshold: int = 3       # consecutive failures -> open
    ewma_alpha: float = 0.25      # failure-rate smoothing
    ewma_threshold: float = 0.6   # smoothed failure rate -> open
    min_samples: int = 4          # outcomes before the EWMA can trip
    open_s: float = 0.25          # first cool-down before half-open
    backoff: float = 2.0          # cool-down growth per re-open
    max_open_s: float = 10.0      # cool-down cap
    half_open_probes: int = 1     # probe batches per half-open window


class ReplicaHealth:
    """One replica's health signals + breaker state machine.

    ``record_success``/``record_failure`` are called by the batch
    loops (one call per batch outcome); ``available``/``score``/
    ``note_dispatch`` are called by the router under the service's
    sequence lock.  ``clock`` is injectable for deterministic tests.
    """

    def __init__(self, replica_id: int = 0,
                 config: BreakerConfig | None = None, *,
                 clock=time.perf_counter):
        self.replica_id = replica_id
        self.config = config or BreakerConfig()
        self._clock = clock
        self._lock = threading.Lock()
        self._state = "closed"
        self._ewma = 0.0
        self._outcomes = 0
        self._consecutive = 0
        self._last_success: float | None = None
        self._opened_at = 0.0
        self._cooldown_s = self.config.open_s
        self._probes_left = 0
        self.trips = 0            # closed/half-open -> open transitions

    # ---------------------------------------------------------- outcomes ----
    def record_success(self):
        with self._lock:
            self._outcomes += 1
            self._consecutive = 0
            self._ewma *= 1.0 - self.config.ewma_alpha
            self._last_success = self._clock()
            if self._resolve_state() == "half_open":
                # probe succeeded: close and reset the backoff
                self._state = "closed"
                self._cooldown_s = self.config.open_s
                self._probes_left = 0

    def record_failure(self):
        cfg = self.config
        with self._lock:
            self._outcomes += 1
            self._consecutive += 1
            self._ewma += cfg.ewma_alpha * (1.0 - self._ewma)
            state = self._resolve_state()
            if state == "half_open":
                # probe failed: re-open with exponential backoff
                self._cooldown_s = min(self._cooldown_s * cfg.backoff,
                                       cfg.max_open_s)
                self._trip()
            elif state == "closed" and (
                    self._consecutive >= cfg.fail_threshold
                    or (self._outcomes >= cfg.min_samples
                        and self._ewma >= cfg.ewma_threshold)):
                self._cooldown_s = cfg.open_s
                self._trip()

    def _trip(self):
        self._state = "open"
        self._opened_at = self._clock()
        self._probes_left = 0
        self.trips += 1

    def _resolve_state(self) -> str:
        """Lazily advance open -> half-open when the cool-down has
        expired (no timer thread; callers hold the lock)."""
        if (self._state == "open"
                and self._clock() - self._opened_at >= self._cooldown_s):
            self._state = "half_open"
            self._probes_left = self.config.half_open_probes
        return self._state

    # ------------------------------------------------------------ router ----
    def state(self) -> str:
        with self._lock:
            return self._resolve_state()

    def available(self) -> bool:
        """May the router send this lane traffic right now?"""
        with self._lock:
            st = self._resolve_state()
            if st == "closed":
                return True
            if st == "half_open":
                return self._probes_left > 0
            return False

    def note_dispatch(self):
        """Router picked this lane; consumes a half-open probe token."""
        with self._lock:
            if self._resolve_state() == "half_open" \
                    and self._probes_left > 0:
                self._probes_left -= 1

    def score(self) -> tuple:
        """Health ordering key (lower = healthier): breaker-state rank,
        then smoothed failure rate, then consecutive failures."""
        with self._lock:
            rank = BREAKER_STATES.index(self._resolve_state())
            return (rank, self._ewma, self._consecutive)

    # --------------------------------------------------------- reporting ----
    def snapshot(self) -> dict:
        with self._lock:
            st = self._resolve_state()
            since = None if self._last_success is None \
                else self._clock() - self._last_success
            return {
                "replica_id": self.replica_id,
                "state": st,
                "ewma_failure_rate": self._ewma,
                "consecutive_failures": self._consecutive,
                "outcomes": self._outcomes,
                "since_last_success_s": since,
                "trips": self.trips,
                "cooldown_s": self._cooldown_s,
            }
