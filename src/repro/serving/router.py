"""Event sharding across replica engines.

Two policies, both O(1) per event:

  round_robin  — event ``seq`` goes to replica ``seq % N``; perfectly
                 even, deterministic (the testable default);
  least_loaded — event goes to the replica with the fewest accepted-
                 but-unreleased events (ties break by replica index),
                 which absorbs skew when one replica hedges or runs on
                 a slower device.
"""
from __future__ import annotations

POLICIES = ("round_robin", "least_loaded")


class Router:
    def __init__(self, replicas, policy: str = "round_robin"):
        if policy not in POLICIES:
            raise ValueError(
                f"unknown shard policy {policy!r}; expected one of "
                f"{POLICIES}")
        self.replicas = list(replicas)
        self.policy = policy

    def pick(self, seq: int):
        if self.policy == "round_robin":
            return self.replicas[seq % len(self.replicas)]
        return min(self.replicas, key=lambda r: (r.load(), r.replica_id))
