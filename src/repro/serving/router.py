"""Event sharding across replica engines.

Two policies, both O(1) per event:

  round_robin  — event ``seq`` goes to replica ``seq % N``; perfectly
                 even, deterministic (the testable default);
  least_loaded — event goes to the replica with the fewest accepted-
                 but-unreleased events (ties break by replica index),
                 which absorbs skew when one replica hedges or runs on
                 a slower device.

Occupancy bucketing: with a bucketed deployment (one batch-packed
executable per n_hits tier — see ``core.pipeline.deploy_bucketed``)
the service classifies each event by its non-zero hit count
(``event_occupancy``) and dispatches to the replica group serving the
smallest bucket that fits (``pick_bucket``); events overflowing the
largest bucket fall back to it (hits are energy-sorted upstream, so
truncation drops the softest hits first). Classification is O(hits)
numpy on the submit path — no jax, no copies.
"""
from __future__ import annotations

import numpy as np

POLICIES = ("round_robin", "least_loaded")


def pick_bucket(occupancy: int, buckets) -> int:
    """Smallest bucket >= ``occupancy``; overflow → largest bucket.

    ``buckets`` must be a non-empty iterable of positive ints; a 0-hit
    event lands in the smallest bucket (a real launch shape — padding
    handles it like the paper's zero-padded missing inputs)."""
    bs = sorted(buckets)
    if not bs:
        raise ValueError("pick_bucket: no buckets")
    for b in bs:
        if occupancy <= b:
            return b
    return bs[-1]


def event_occupancy(event: dict, mask_feed: str = "mask") -> int:
    """Non-zero hit count of one (un-batched) event dict."""
    return int(np.count_nonzero(np.asarray(event[mask_feed]) > 0))


class Router:
    def __init__(self, replicas, policy: str = "round_robin"):
        if policy not in POLICIES:
            raise ValueError(
                f"unknown shard policy {policy!r}; expected one of "
                f"{POLICIES}")
        self.replicas = list(replicas)
        self.policy = policy

    def pick(self, seq: int):
        if self.policy == "round_robin":
            return self.replicas[seq % len(self.replicas)]
        return min(self.replicas, key=lambda r: (r.load(), r.replica_id))
