"""Event sharding across replica engines.

Two policies, both O(1) per event:

  round_robin  — event ``seq`` goes to replica ``seq % N``; perfectly
                 even, deterministic (the testable default);
  least_loaded — event goes to the replica with the fewest accepted-
                 but-unreleased events (ties break by replica index),
                 which absorbs skew when one replica hedges or runs on
                 a slower device.

Occupancy bucketing: with a bucketed deployment (one batch-packed
executable per n_hits tier — see ``core.pipeline.deploy_bucketed``)
the service classifies each event by its non-zero hit count
(``event_occupancy``) and dispatches to the replica group serving the
smallest bucket that fits (``pick_bucket``); events overflowing the
largest bucket fall back to it (hits are energy-sorted upstream, so
truncation drops the softest hits first). Classification is O(hits)
numpy on the submit path — no jax, no copies.

Health-aware routing: when the service runs with a circuit breaker
(``serving.health``) the router is handed the per-replica
``ReplicaHealth`` objects and ``pick`` skips lanes whose breaker is
open, tie-breaks by health among the healthy, and falls back to the
least-bad lane when every breaker is open — the trigger keeps
deciding, degraded, rather than stalling the stream.  Without health
objects both policies are bit-identical to the original behavior.
"""
from __future__ import annotations

from bisect import bisect_left

import numpy as np

POLICIES = ("round_robin", "least_loaded")


def pick_bucket_sorted(occupancy: int, sorted_buckets) -> int:
    """``pick_bucket`` over an already-sorted sequence: O(log n)
    bisect, no per-event allocation — the submit-path variant."""
    i = bisect_left(sorted_buckets, occupancy)
    return sorted_buckets[i] if i < len(sorted_buckets) \
        else sorted_buckets[-1]


def pick_bucket(occupancy: int, buckets) -> int:
    """Smallest bucket >= ``occupancy``; overflow → largest bucket.

    ``buckets`` must be a non-empty iterable of positive ints; a 0-hit
    event lands in the smallest bucket (a real launch shape — padding
    handles it like the paper's zero-padded missing inputs).  Callers
    on a per-event path should sort once and use
    ``pick_bucket_sorted``."""
    bs = sorted(buckets)
    if not bs:
        raise ValueError("pick_bucket: no buckets")
    return pick_bucket_sorted(occupancy, bs)


def event_occupancy(event: dict, mask_feed: str = "mask") -> int:
    """Non-zero hit count of one (un-batched) event dict."""
    return int(np.count_nonzero(np.asarray(event[mask_feed]) > 0))


class Router:
    def __init__(self, replicas, policy: str = "round_robin",
                 healths=None):
        if policy not in POLICIES:
            raise ValueError(
                f"unknown shard policy {policy!r}; expected one of "
                f"{POLICIES}")
        self.replicas = list(replicas)
        self.policy = policy
        # healths: {replica_id: ReplicaHealth} covering (at least) this
        # router's replicas; aligned once here so pick() never indexes
        # a dict on the per-event path.
        self._healths = None if healths is None else \
            [healths[r.replica_id] for r in self.replicas]

    def pick(self, seq: int):
        if self._healths is None:
            if self.policy == "round_robin":
                return self.replicas[seq % len(self.replicas)]
            return min(self.replicas,
                       key=lambda r: (r.load(), r.replica_id))
        pairs = [(r, h) for r, h in zip(self.replicas, self._healths)
                 if h.available()]
        if not pairs:
            # every breaker open: the least-bad lane keeps serving
            # (degraded) — a trigger must not stall the event stream.
            r, h = min(zip(self.replicas, self._healths),
                       key=lambda rh: (rh[1].score(),
                                       rh[0].replica_id))
        elif self.policy == "round_robin":
            r, h = pairs[seq % len(pairs)]
        else:
            r, h = min(pairs, key=lambda rh: (rh[0].load(),
                                              rh[1].score(),
                                              rh[0].replica_id))
        h.note_dispatch()   # consumes a half-open probe token
        return r
