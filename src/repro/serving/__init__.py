from repro.serving.engine import (AggregateStats, ServingStats,
                                  ShardedTriggerService,
                                  TriggerServingEngine)
from repro.serving.faults import (FAULT_KINDS, FaultPlan, FaultSpec,
                                  InjectedFault)
from repro.serving.health import (BREAKER_STATES, BreakerConfig,
                                  ReplicaHealth)
from repro.serving.monitor import (MonitorSnapshot, TriggerMonitor,
                                   detector_grid, event_display,
                                   write_display)
from repro.serving.monitor_server import MonitorServer
from repro.serving.replica import (InOrderReleaser, ReplicaEngine,
                                   ShedError)
from repro.serving.router import (POLICIES, Router, event_occupancy,
                                  pick_bucket, pick_bucket_sorted)
from repro.serving.streaming import LOOPS, StreamingReplicaEngine

__all__ = ["AggregateStats", "BREAKER_STATES", "BreakerConfig",
           "FAULT_KINDS", "FaultPlan", "FaultSpec", "InOrderReleaser",
           "InjectedFault", "LOOPS", "MonitorServer", "MonitorSnapshot",
           "POLICIES", "ReplicaEngine", "ReplicaHealth", "Router",
           "ServingStats", "ShardedTriggerService", "ShedError",
           "StreamingReplicaEngine", "TriggerMonitor",
           "TriggerServingEngine", "detector_grid", "event_display",
           "event_occupancy", "pick_bucket", "pick_bucket_sorted",
           "write_display"]
