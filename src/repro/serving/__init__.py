from repro.serving.engine import (AggregateStats, ServingStats,
                                  ShardedTriggerService,
                                  TriggerServingEngine)
from repro.serving.monitor import (MonitorSnapshot, TriggerMonitor,
                                   detector_grid, event_display,
                                   write_display)
from repro.serving.monitor_server import MonitorServer
from repro.serving.replica import InOrderReleaser, ReplicaEngine
from repro.serving.router import (POLICIES, Router, event_occupancy,
                                  pick_bucket)
from repro.serving.streaming import LOOPS, StreamingReplicaEngine

__all__ = ["AggregateStats", "InOrderReleaser", "LOOPS", "MonitorServer",
           "MonitorSnapshot", "POLICIES", "ReplicaEngine", "Router",
           "ServingStats", "ShardedTriggerService",
           "StreamingReplicaEngine", "TriggerMonitor",
           "TriggerServingEngine", "detector_grid", "event_display",
           "event_occupancy", "pick_bucket", "write_display"]
