from repro.serving.engine import (AggregateStats, ServingStats,
                                  ShardedTriggerService,
                                  TriggerServingEngine)
from repro.serving.replica import InOrderReleaser, ReplicaEngine
from repro.serving.router import POLICIES, Router

__all__ = ["AggregateStats", "InOrderReleaser", "POLICIES",
           "ReplicaEngine", "Router", "ServingStats",
           "ShardedTriggerService", "TriggerServingEngine"]
