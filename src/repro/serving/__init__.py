from repro.serving.engine import ServingStats, TriggerServingEngine
