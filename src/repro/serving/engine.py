"""Sharded real-time trigger serving.

Mirrors the paper's demonstrator runtime (§III-B) and scales it out:
the paper sustains 2.94 M events/s by spatially parallelizing one
dataflow pipeline; here a ``ShardedTriggerService`` owns N replica
engines (each wrapping a ``deploy()``-produced executable), a router
that shards incoming events across them, and one merged release stage
so the three hard requirements from §I survive replication:

  (1) bounded decision latency  → per-replica micro-batching window
      with a deadline (zero-padded, like the paper's padding of
      missing inputs);
  (2) throughput                → batched dispatch + double buffering
      per replica, and replication across devices (``jax.device_put``
      placement when more than one device exists, thread-backed
      virtual replicas otherwise);
  (3) strict in-order results   → a single ``InOrderReleaser`` keyed
      on the global submission sequence, so results complete in
      submission order no matter which replica finishes first.

Straggler mitigation: ``hedge_after_s`` re-dispatches a batch to a
backup lane if the primary hasn't returned in time; first result wins
(duplicate-safe because inference is pure).

``TriggerServingEngine`` (the original single-replica API) is kept as
a thin shim over a 1-replica service.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future

import numpy as np

from repro.serving.health import BreakerConfig, ReplicaHealth
from repro.serving.monitor import MonitorSnapshot, TriggerMonitor
from repro.serving.replica import (EventTiming, InOrderReleaser,
                                   ReplicaEngine, ServingStats,
                                   ShedError)
from repro.serving.router import (POLICIES, Router, event_occupancy,
                                  pick_bucket_sorted)
from repro.serving.streaming import LOOPS, StreamingReplicaEngine

__all__ = ["AggregateStats", "ServingStats", "ShardedTriggerService",
           "ShedError", "TriggerServingEngine", "POLICIES", "LOOPS"]


class AggregateStats:
    """Merged view over the per-replica ``ServingStats``.

    The throughput clock starts at the *first submission*, not at
    construction — a service built early (e.g. before event generation)
    must not report a diluted rate."""

    def __init__(self, replicas):
        self._replicas = replicas
        self.first_submit_at: float | None = None

    def note_submission(self, t: float):
        """Called (under the service's sequence lock) on every submit;
        only the first one starts the clock."""
        if self.first_submit_at is None:
            self.first_submit_at = t

    # aggregate counters mirror the ServingStats field names so callers
    # can treat the two uniformly.
    def _sum(self, field):
        return sum(getattr(r.stats, field) for r in self._replicas)

    @property
    def completed(self):
        return self._sum("completed")

    @property
    def batches(self):
        return self._sum("batches")

    @property
    def hedged(self):
        return self._sum("hedged")

    @property
    def padded_events(self):
        return self._sum("padded_events")

    @property
    def latencies_s(self):
        out = []
        for r in self._replicas:
            out.extend(r.stats.samples("latencies_s"))
        return out

    def percentile(self, p):
        lat = self.latencies_s
        return float(np.percentile(lat, p)) if lat else float("nan")

    def throughput_ev_s(self):
        if self.first_submit_at is None:
            return 0.0
        dt = time.perf_counter() - self.first_submit_at
        return self.completed / dt if dt > 0 else 0.0

    def summary(self):
        lat = np.asarray(self.latencies_s)   # one merged copy per call

        def merged_mean_us(field):
            xs = []
            for r in self._replicas:
                xs.extend(r.stats.samples(field))
            return float(np.mean(xs)) * 1e6 if xs else None

        agg = {
            "replicas": len(self._replicas),
            "completed": self.completed,
            "failed": self._sum("failed"),
            "batches": self.batches,
            "hedged": self.hedged,
            "padded_events": self.padded_events,
            "shed": self._sum("shed"),
            "retried": self._sum("retried"),
            "failed_over": self._sum("failed_over"),
            "p50_us": float(np.percentile(lat, 50)) * 1e6
            if lat.size else None,
            "p99_us": float(np.percentile(lat, 99)) * 1e6
            if lat.size else None,
            "mean_us": float(lat.mean()) * 1e6 if lat.size else None,
            "throughput_ev_s": self.throughput_ev_s(),
            "budget": {
                "queue_wait_us_mean": merged_mean_us("queue_wait_s"),
                "dispatch_us_mean": merged_mean_us("dispatch_s"),
                "compute_us_mean": merged_mean_us("compute_s"),
            },
        }
        agg["per_replica"] = [r.stats.summary() for r in self._replicas]
        return agg


class ShardedTriggerService:
    """N replica engines behind a sharding router and one merged
    in-order release stage.

    ``infer_fn`` maps a dict of stacked numpy feeds (B=microbatch) to
    an output pytree with a leading batch dim, and must be pure
    (hedging re-executes).  Pass one callable shared by every replica,
    or a list of N callables (e.g. per-device executables).

    ``devices``: ``"auto"`` places replica i on local device
    ``i % n_devices`` via ``jax.device_put`` when more than one device
    exists (see ``launch.mesh.replica_devices``); ``None`` keeps every
    replica on the default device (thread-backed virtual replicas); a
    list pins replicas explicitly.

    ``warmup_fn``: optional no-arg callable run at startup, before
    traffic — pass ``repro.tuning.make_warmup(cache)`` so engines
    pre-compile every kernel shape the tuning cache knows about
    instead of paying jit tracing on the first real event. It runs
    once per *distinct device* (the jit cache is per-device, so
    thread-backed replicas sharing one device would re-execute an
    already-hot cache N times for nothing). Best-effort: failures are
    swallowed and the replicas start anyway.

    ``monitor``: opt-in real-time monitoring (paper §III-B's
    visualization pipeline). ``True`` attaches one ``TriggerMonitor``
    per replica, fed one O(1) ``record_batch`` per completed
    micro-batch on the result-release path of its batch loop — the hot
    loop never blocks on aggregation, which runs vectorized on the
    reader's thread; a dict is forwarded to each ``TriggerMonitor``
    (e.g. ``{"window": 8192, "detector": cfg}``).
    Read the fleet view with ``monitor_snapshot()`` /
    ``event_displays()``, and pass ``truth=`` to ``submit`` to get
    online truth-matched efficiency / fake-rate in the snapshot.

    ``loop``: the replica hot-loop flavor. ``"deadline"`` (default —
    the original behavior, bit-for-bit) launches a micro-batch when it
    fills or ``window_s`` elapses; ``"streaming"`` runs the persistent
    streaming-dataflow pipeline (``streaming.py``): rolling batching
    into preallocated input rings, async launch dispatch, and a
    harvest stage draining a host output ring — no deadline tick, so
    an arriving event joins the next in-flight launch instead of
    waiting for a batch boundary. Hedging is deadline-only.

    ``buckets``: occupancy-bucketed dispatch (paper-adjacent: size the
    datapath to per-event occupancy instead of the detector maximum).
    Pass a ``core.pipeline.BucketedPipeline`` (its per-bucket
    batch-packed executables and warm-up are wired automatically) or a
    ``{n_hits: infer_fn}`` dict. Each bucket gets its own group of
    ``n_replicas`` replicas behind its own router; ``submit`` counts an
    event's non-zero hits (``mask_feed``), slices its feeds to the
    smallest bucket that fits (overflow falls back to the largest —
    hits are energy-sorted upstream, so truncation sheds the softest
    hits), and dispatches to that group. The shared in-order releaser
    spans *all* groups, so global submission order survives bucketing.

    ``routes``: heterogeneous-model dispatch. Pass a ``{name:
    infer_fn}`` dict — each named model gets its own group of
    ``n_replicas`` replicas behind its own router, and ``submit(event,
    route=name)`` picks the group (with a single route the argument may
    be omitted). Unlike ``buckets`` (one model, many launch shapes)
    this serves *different deployed pipelines* side by side — e.g. the
    CCN trigger next to an edge-based GNN — behind one shared in-order
    releaser, so global submission order survives heterogeneous
    routing. ``warmup_fn`` may be a ``{name: callable}`` dict to warm
    each route's kernels separately. Mutually exclusive with
    ``infer_fn`` and ``buckets``. Read per-route intake/completion with
    ``route_summary()``.

    ``ragged``: padding-free dispatch. Pass a
    ``core.pipeline.RaggedPipeline`` (from ``deploy(ragged=True)``) —
    submissions of *any* occupancy share one replica group, and each
    micro-batch bin-packs the events' actual hits on dispatch instead
    of padding every event to a bucket cap. High-variance occupancy
    mixes stop paying bucket quantization, and an event larger than
    every bucket cap is served exactly (no overflow-to-largest-bucket
    truncation). Host-side the protocol still stacks events at the
    detector's full hit capacity; the packing happens before the
    device launch, where the padding actually costs. Mutually
    exclusive with ``infer_fn``, ``buckets`` and ``routes``.
    """

    def __init__(self, infer_fn=None, *, n_replicas: int = 1,
                 microbatch: int, window_s: float = 1e-3,
                 queue_depth: int = 1024,
                 hedge_after_s: float | None = None,
                 policy: str = "round_robin", devices="auto",
                 inflight: int = 2, warmup_fn=None, monitor=False,
                 buckets=None, mask_feed: str = "mask",
                 routes=None, ragged=None, loop: str = "deadline",
                 faults=None, breaker=None, max_retries: int = 0,
                 shed: bool = False):
        if n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")
        if loop not in LOOPS:
            raise ValueError(f"unknown replica loop {loop!r}; expected "
                             f"one of {LOOPS}")
        if max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        self.loop = loop
        # fault tolerance (docs/serving.md): a seeded FaultPlan to
        # inject deterministic chaos, a circuit-breaker config (True
        # for defaults), bounded failover re-dispatch, and fast-fail
        # load shedding.  All default off — healthy-path behavior is
        # bit-identical without them.
        self.faults = faults
        if breaker is None or breaker is False:
            self.breaker = None
        elif breaker is True:
            self.breaker = BreakerConfig()
        elif isinstance(breaker, BreakerConfig):
            self.breaker = breaker
        else:
            raise TypeError("breaker= expects True/False/None or a "
                            "health.BreakerConfig")
        self.max_retries = int(max_retries)
        self.shed = bool(shed)
        self._retry_counts: dict[int, int] = {}
        self._retry_lock = threading.Lock()
        engine_cls = StreamingReplicaEngine if loop == "streaming" \
            else ReplicaEngine
        self.mask_feed = mask_feed
        bucket_warmups = None
        route_warmups = None
        self.routes = ()
        self.ragged = ragged is not None
        if ragged is not None:
            if (infer_fn is not None or buckets is not None
                    or routes is not None):
                raise ValueError(
                    "pass exactly one of infer_fn, buckets=, routes= "
                    "or ragged= — a ragged service dispatches all "
                    "traffic through the padding-free executable")
            if not hasattr(ragged, "capacity"):
                raise TypeError(
                    "ragged= expects a core.pipeline.RaggedPipeline "
                    "(deploy(ragged=True) builds one)")
            self._ragged_capacity = int(ragged.capacity)
            if warmup_fn is None and hasattr(ragged, "warmup"):
                warmup_fn = ragged.warmup
            self.buckets = ()
            infer_fns = [ragged] * n_replicas
        elif routes is not None:
            if infer_fn is not None or buckets is not None:
                raise ValueError(
                    "pass exactly one of infer_fn, buckets= or routes= "
                    "— routed services dispatch all traffic through "
                    "the named route executables")
            route_fns = dict(routes)
            if not route_fns:
                raise ValueError("routes must name at least one route")
            self.routes = tuple(route_fns)
            if isinstance(warmup_fn, dict):
                route_warmups = {r: warmup_fn.get(r) for r in self.routes}
                warmup_fn = None
            infer_fns = [route_fns[r]
                         for r in self.routes for _ in range(n_replicas)]
            self.buckets = ()
        elif buckets is not None:
            if infer_fn is not None:
                raise ValueError(
                    "pass either infer_fn or buckets=, not both — "
                    "bucketed services route all traffic through the "
                    "bucket executables")
            if hasattr(buckets, "infer_fns"):     # BucketedPipeline
                bucket_fns = buckets.infer_fns()
                if warmup_fn is None and hasattr(buckets, "warmup_one"):
                    # each bucket group warms ONLY its own executable
                    # (once per distinct device), not the whole tier set
                    bucket_warmups = {
                        b: (lambda _b=b: buckets.warmup_one(_b))
                        for b in bucket_fns}
                elif warmup_fn is None and hasattr(buckets, "warmup"):
                    warmup_fn = buckets.warmup
            else:
                bucket_fns = {int(b): fn for b, fn in dict(buckets).items()}
            if not bucket_fns:
                raise ValueError("buckets must name at least one bucket")
            self.buckets = tuple(sorted(bucket_fns))
            infer_fns = [bucket_fns[b]
                         for b in self.buckets for _ in range(n_replicas)]
        else:
            if infer_fn is None:
                raise ValueError(
                    "infer_fn is required unless buckets= or routes= "
                    "is given")
            self.buckets = ()
            infer_fns = infer_fn if isinstance(infer_fn, (list, tuple)) \
                else [infer_fn] * n_replicas
            if len(infer_fns) != n_replicas:
                raise ValueError(f"got {len(infer_fns)} infer_fns for "
                                 f"{n_replicas} replicas")
        total = len(infer_fns)
        if devices == "auto":
            from repro.launch.mesh import replica_devices
            devices = replica_devices(total)
        elif devices is None:
            devices = [None] * total
        if len(devices) != total:
            raise ValueError(
                f"got {len(devices)} devices for {total} replicas")

        self.microbatch = microbatch
        self.window = window_s
        self.hedge_after = hedge_after_s
        self._seq = 0
        self._seq_lock = threading.Lock()
        self._releaser = InOrderReleaser(self._on_release)
        if monitor:
            mkw = dict(monitor) if isinstance(monitor, dict) else {}
            self.monitors = [TriggerMonitor(**mkw)
                             for _ in range(total)]
        else:
            self.monitors = []
        # seq -> truth bit for in-flight events (monitoring only);
        # written by submit, consumed by the replica batch loops.
        self._truth: dict[int, bool] = {}
        if bucket_warmups is not None:
            warmup_fns = [bucket_warmups[b]
                          for b in self.buckets for _ in range(n_replicas)]
        elif route_warmups is not None:
            warmup_fns = [route_warmups[r]
                          for r in self.routes for _ in range(n_replicas)]
        else:
            warmup_fns = [warmup_fn] * total
        # per-replica health drives the breaker-aware router and the
        # failover target choice; None when the breaker is disabled.
        self.healths = {i: ReplicaHealth(i, self.breaker)
                        for i in range(total)} if self.breaker else None
        on_batch_failure = self._handle_batch_failure \
            if self.max_retries > 0 else None
        self.replicas = []
        warmed = set()   # (device, warmup identity): jit caches are
        #                  per-device, and bucket groups warm per-bucket
        for i, (fn, dev) in enumerate(zip(infer_fns, devices)):
            key = (dev, id(warmup_fns[i]))
            wf = warmup_fns[i] if key not in warmed else None
            warmed.add(key)
            self.replicas.append(
                engine_cls(fn, self._releaser, microbatch=microbatch,
                           window_s=window_s, queue_depth=queue_depth,
                           hedge_after_s=hedge_after_s, device=dev,
                           replica_id=i, inflight=inflight,
                           warmup_fn=wf,
                           monitor=self.monitors[i]
                           if self.monitors else None,
                           truth_map=self._truth
                           if self.monitors else None,
                           faults=faults,
                           health=self.healths[i]
                           if self.healths else None,
                           on_batch_failure=on_batch_failure,
                           shed=shed))
        if self.buckets:
            self._bucket_groups = {
                b: self.replicas[gi * n_replicas:(gi + 1) * n_replicas]
                for gi, b in enumerate(self.buckets)}
            self._bucket_routers = {
                b: Router(grp, policy, healths=self.healths)
                for b, grp in self._bucket_groups.items()}
            # per-bucket intake counters double as gap-free round-robin
            # indices within each bucket's replica group.
            self.bucket_counts = {b: 0 for b in self.buckets}
            self.router = None
            groups = self._bucket_groups.items()
            labels = {b: f"bucket {b}" for b in self.buckets}
        elif self.routes:
            self._route_groups = {
                r: self.replicas[gi * n_replicas:(gi + 1) * n_replicas]
                for gi, r in enumerate(self.routes)}
            self._route_routers = {
                r: Router(grp, policy, healths=self.healths)
                for r, grp in self._route_groups.items()}
            self.route_counts = {r: 0 for r in self.routes}
            self.router = None
            groups = self._route_groups.items()
            labels = {r: f"route {r}" for r in self.routes}
        else:
            self.router = Router(self.replicas, policy,
                                 healths=self.healths)
            groups = [(None, self.replicas)]
            labels = {None: ""}
        # replica_id -> (its failover group, human label) — failover
        # stays within the group (same executable/launch shape), and
        # drain() names the group when a lane wedges.
        self._group_of = {r.replica_id: grp
                          for g, grp in groups for r in grp}
        self._label_of = {r.replica_id: labels[g]
                          for g, grp in groups for r in grp}
        self._agg = AggregateStats(self.replicas)

    # ------------------------------------------------------------ client ----
    @staticmethod
    def _cut_event(event: dict, n: int) -> dict:
        """Slice (or zero-pad) every per-event feed's hit axis (axis 0)
        to exactly ``n`` rows — the chosen bucket's launch shape."""
        out = {}
        for key, v in event.items():
            v = np.asarray(v)
            if v.shape[0] >= n:
                out[key] = v[:n]
            else:
                pw = [(0, n - v.shape[0])] + [(0, 0)] * (v.ndim - 1)
                out[key] = np.pad(v, pw)
        return out

    def classify(self, event: dict) -> int:
        """The occupancy bucket this event would dispatch to."""
        if not self.buckets:
            raise RuntimeError("service is not occupancy-bucketed")
        # self.buckets is a sorted tuple -> allocation-free lookup
        return pick_bucket_sorted(
            event_occupancy(event, self.mask_feed), self.buckets)

    def submit(self, event: dict, *, truth: bool | None = None,
               route: str | None = None,
               deadline_s: float | None = None) -> Future:
        """Shard the event to a replica; returns a Future that resolves
        in global submission order.  Blocks (backpressure) when the
        chosen replica's bounded queue is full.

        With ``buckets``, the event is first classified by non-zero hit
        count and its feeds cut to the bucket's launch shape; dispatch
        then round-robins (or least-loads) within that bucket's replica
        group. Ordering is still global across buckets.

        With ``routes``, ``route`` names the model group the event
        dispatches to (optional when only one route is configured).
        Ordering is still global across routes.

        ``truth``: optional ground-truth trigger bit; with monitoring
        enabled it is matched against the model's decision on release,
        feeding the snapshot's online efficiency / fake-rate.

        ``deadline_s``: optional per-event latency budget measured
        from this submit; an event still undispatched when it expires
        is shed (``ShedError``) instead of served late.  Combine with
        the service-level ``shed=True`` to also fail fast on a full
        lane queue."""
        t_submit = time.perf_counter()
        bucket = None
        if self.routes:
            if route is None:
                if len(self.routes) > 1:
                    raise ValueError(
                        "route= is required on a multi-route service; "
                        f"routes: {', '.join(self.routes)}")
                route = self.routes[0]
            if route not in self._route_groups:
                raise KeyError(f"unknown route {route!r}; routes: "
                               f"{', '.join(self.routes)}")
        elif route is not None:
            raise ValueError("service has no routes= configured")
        if self.buckets:
            # classify outside the sequence lock (O(hits) numpy count;
            # self.buckets is pre-sorted, the lookup allocates nothing)
            bucket = pick_bucket_sorted(
                event_occupancy(event, self.mask_feed), self.buckets)
            event = self._cut_event(event, bucket)
        elif self.ragged:
            # normalize every submission to the full hit capacity so
            # the batch loop can stack mixed occupancies; the ragged
            # executable re-packs actual hits before the launch
            event = self._cut_event(event, self._ragged_capacity)
        with self._seq_lock:
            seq = self._seq
            self._seq += 1
            self._agg.note_submission(t_submit)
            # pick under the lock so round-robin sees a gap-free seq
            # and least-loaded sees a consistent load snapshot.
            if bucket is not None:
                idx = self.bucket_counts[bucket]
                self.bucket_counts[bucket] = idx + 1
                replica = self._bucket_routers[bucket].pick(idx)
            elif route is not None:
                idx = self.route_counts[route]
                self.route_counts[route] = idx + 1
                replica = self._route_routers[route].pick(idx)
            else:
                replica = self.router.pick(seq)
        if truth is not None and self.monitors:
            self._truth[seq] = bool(truth)   # before enqueue: release
            #                      can only happen after the enqueue.
        fut: Future = Future()
        if deadline_s is not None:
            # stamped on the future (always the item tuple's last
            # element) so neither loop's item shapes change
            fut.deadline = t_submit + deadline_s
        replica.enqueue(seq, t_submit, event, fut)
        return fut

    # ----------------------------------------------------------- release ----
    def _on_release(self, seq: int, outcome, timing: EventTiming,
                    fut: Future):
        # monitoring does NOT happen here: the replica batch loop has
        # already record_raw()ed this event, so the serialized release
        # stage stays monitoring-free.
        if self.max_retries:
            with self._retry_lock:
                self._retry_counts.pop(seq, None)
        st = self.replicas[timing.replica_id].stats
        kind, value = outcome
        if kind == "ok":
            st.record_release(timing)
            if not fut.cancelled():   # client gave up; stats still count
                fut.set_result(value)
        else:
            st.failed += 1
            if not fut.cancelled():
                fut.set_exception(value)

    # ---------------------------------------------------------- failover ----
    def _failover_target(self, source):
        """A healthy sibling in the failing replica's group, or None
        when the batch must fail to the client."""
        group = self._group_of[source.replica_id]
        cands = [r for r in group if r is not source and not r.stopping]
        if not cands:
            return None
        if self.healths is not None:
            cands = [r for r in cands
                     if self.healths[r.replica_id].available()]
            if not cands:
                return None
            return min(cands, key=lambda r: (
                r.load(), self.healths[r.replica_id].score(),
                r.replica_id))
        return min(cands, key=lambda r: (r.load(), r.replica_id))

    def _handle_batch_failure(self, replica, items, exc):
        """Failover hook (runs on the failing replica's dispatch or
        harvest thread): re-dispatch each event of a failed batch to a
        healthy sibling, bounded by ``max_retries`` per event; returns
        the items that could not be moved — the replica releases those
        as errors, so every event still resolves exactly once."""
        remaining = []
        for it in items:
            try:
                seq, t_submit, event, fut = it[0], it[1], it[-2], it[-1]
                with self._retry_lock:
                    n = self._retry_counts.get(seq, 0)
                    if n >= self.max_retries:
                        remaining.append(it)
                        continue
                    self._retry_counts[seq] = n + 1
                target = self._failover_target(replica)
                if target is None or not target.requeue(
                        seq, t_submit, event, fut):
                    remaining.append(it)
            except Exception:  # noqa: BLE001 — failover is best-effort;
                remaining.append(it)   # the event fails to the client
        return remaining

    # -------------------------------------------------------- monitoring ----
    @property
    def monitoring(self) -> bool:
        return bool(self.monitors)

    def monitor_snapshot(self) -> MonitorSnapshot:
        """Fleet-level monitoring snapshot, pooled across the
        per-replica monitors."""
        if not self.monitors:
            raise RuntimeError(
                "monitoring is off; construct the service with "
                "monitor=True")
        snap = MonitorSnapshot.merge(self.monitors)
        # fault-path counters ride along so the /snapshot HTTP payload
        # (monitor_server.py) exposes shed/retry/breaker state too
        snap["serving"] = self.fault_tolerance_summary()
        return snap

    def fault_tolerance_summary(self) -> dict:
        """Shed / retried / failed-over counters plus per-replica
        breaker state — the fault-path view (also embedded in
        ``monitor_snapshot()`` under ``"serving"``)."""
        states = {str(i): h.state()
                  for i, h in (self.healths or {}).items()}
        return {
            "shed": sum(r.stats.shed for r in self.replicas),
            "retried": sum(r.stats.retried for r in self.replicas),
            "failed_over": sum(r.stats.failed_over
                               for r in self.replicas),
            "max_retries": self.max_retries,
            "breaker": {
                "enabled": self.healths is not None,
                "open": sum(1 for s in states.values() if s == "open"),
                "half_open": sum(1 for s in states.values()
                                 if s == "half_open"),
                "states": states,
            },
        }

    def event_displays(self, n: int | None = None) -> list[dict]:
        """Most recent event-display records across all replicas, in
        submission order."""
        if n is not None and n <= 0:
            return []
        recs = [r for m in self.monitors for r in m.displays()]
        recs.sort(key=lambda r: r["event"])
        return recs if n is None else recs[-n:]

    def route_summary(self) -> list[dict]:
        """Per-route intake/completion view (empty when unrouted)."""
        out = []
        for r in self.routes:
            grp = self._route_groups[r]
            out.append({
                "route": r,
                "replicas": len(grp),
                "submitted": self.route_counts[r],
                "completed": sum(e.stats.completed for e in grp),
                "batches": sum(e.stats.batches for e in grp),
                "padded_events": sum(e.stats.padded_events for e in grp),
            })
        return out

    def bucket_summary(self) -> list[dict]:
        """Per-bucket intake/completion view (empty when unbucketed)."""
        out = []
        for b in self.buckets:
            grp = self._bucket_groups[b]
            out.append({
                "bucket": b,
                "replicas": len(grp),
                "submitted": self.bucket_counts[b],
                "completed": sum(r.stats.completed for r in grp),
                "batches": sum(r.stats.batches for r in grp),
                "padded_events": sum(r.stats.padded_events for r in grp),
            })
        return out

    # ----------------------------------------------------------- control ----
    @property
    def stats(self) -> AggregateStats:
        return self._agg

    def drain(self, timeout: float = 30.0):
        t0 = time.perf_counter()
        while (any(r.queued for r in self.replicas)
               or self._releaser.pending
               or self._releaser.released < self._seq):
            if time.perf_counter() - t0 > timeout:
                raise TimeoutError("serving service drain timeout: "
                                   + self._drain_report())
            time.sleep(1e-3)

    def _drain_report(self) -> str:
        """Name the stuck lanes (id, group, queued/in-flight counts)
        so a wedged replica is identifiable from the exception
        alone."""
        parts = []
        for r in self.replicas:
            queued = r.queued
            in_flight = r.load() - queued
            if queued or in_flight > 0:
                label = self._label_of.get(r.replica_id, "")
                where = f" ({label})" if label else ""
                parts.append(f"replica {r.replica_id}{where}: "
                             f"queued={queued} in_flight={in_flight}")
        if not parts:
            parts.append("no replica reports load")
        parts.append(f"releaser: released={self._releaser.released} "
                     f"pending={self._releaser.pending} "
                     f"submitted={self._seq}")
        return "; ".join(parts)

    def close(self):
        for r in self.replicas:
            r.close()


class TriggerServingEngine(ShardedTriggerService):
    """Single-replica engine — the original demonstrator-style API.

    ``stats`` is the replica's own ``ServingStats`` (mutable counters +
    raw latency lists), exactly as before the sharded refactor."""

    def __init__(self, infer_fn, *, microbatch: int, window_s: float = 1e-3,
                 queue_depth: int = 1024,
                 hedge_after_s: float | None = None, monitor=False,
                 loop: str = "deadline", faults=None, breaker=None,
                 max_retries: int = 0, shed: bool = False):
        super().__init__(infer_fn, n_replicas=1, microbatch=microbatch,
                         window_s=window_s, queue_depth=queue_depth,
                         hedge_after_s=hedge_after_s, devices=None,
                         monitor=monitor, loop=loop, faults=faults,
                         breaker=breaker, max_retries=max_retries,
                         shed=shed)

    @property
    def stats(self) -> ServingStats:
        return self.replicas[0].stats
