"""Real-time trigger serving engine.

Mirrors the paper's demonstrator runtime (§III-B): a dataflow pipeline
that processes inference requests without host intervention, with three
hard requirements from §I:

  (1) bounded decision latency  → micro-batching window with a deadline:
      a batch is launched when either ``microbatch`` events are queued or
      ``window_s`` has elapsed (zero-padded, like the paper's padding of
      missing inputs);
  (2) throughput               → batched dispatch + double buffering
      (one batch in flight while the next fills — the FPGA pipeline
      analogue of overlapping Load/compute/Store);
  (3) strict in-order results  → a release stage that completes futures
      in submission order no matter how batches finish.

Straggler mitigation: ``hedge_after_s`` re-dispatches a batch to the
backup executor if the primary hasn't returned in time; first result
wins (duplicate-safe because inference is pure).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor

import numpy as np


@dataclasses.dataclass
class ServingStats:
    completed: int = 0
    batches: int = 0
    hedged: int = 0
    padded_events: int = 0
    latencies_s: list = dataclasses.field(default_factory=list)

    def percentile(self, p):
        return float(np.percentile(self.latencies_s, p)) \
            if self.latencies_s else float("nan")

    def summary(self):
        lat = self.latencies_s
        return {
            "completed": self.completed, "batches": self.batches,
            "hedged": self.hedged,
            "p50_us": self.percentile(50) * 1e6 if lat else None,
            "p99_us": self.percentile(99) * 1e6 if lat else None,
            "mean_us": float(np.mean(lat)) * 1e6 if lat else None,
        }


class TriggerServingEngine:
    def __init__(self, infer_fn, *, microbatch: int, window_s: float = 1e-3,
                 queue_depth: int = 1024, hedge_after_s: float | None = None):
        """infer_fn: dict of stacked numpy feeds (B=microbatch) -> outputs
        pytree with leading batch dim. Must be pure (hedging re-executes).
        """
        self._infer = infer_fn
        self.microbatch = microbatch
        self.window = window_s
        self.hedge_after = hedge_after_s
        self._q: queue.Queue = queue.Queue(maxsize=queue_depth)
        self._stop = threading.Event()
        self.stats = ServingStats()
        self._next_release = 0
        self._done: dict[int, tuple] = {}
        self._release_lock = threading.Condition()
        self._seq = 0
        self._seq_lock = threading.Lock()
        self._pool = ThreadPoolExecutor(max_workers=2)  # primary + hedge
        self._batcher = threading.Thread(target=self._run, daemon=True)
        self._batcher.start()

    # ------------------------------------------------------------ client ----
    def submit(self, event: dict) -> Future:
        """Backpressure: blocks when the bounded queue is full (the
        paper's limited buffer capacity)."""
        with self._seq_lock:
            seq = self._seq
            self._seq += 1
        fut: Future = Future()
        self._q.put((seq, time.perf_counter(), event, fut))
        return fut

    # ----------------------------------------------------------- batcher ----
    def _collect(self):
        items = []
        deadline = None
        while len(items) < self.microbatch and not self._stop.is_set():
            timeout = self.window if deadline is None else \
                max(1e-4, deadline - time.perf_counter())
            try:
                it = self._q.get(timeout=timeout)
            except queue.Empty:
                if items:
                    break
                continue
            items.append(it)
            if deadline is None:
                deadline = time.perf_counter() + self.window
            if deadline and time.perf_counter() > deadline:
                break
        return items

    def _run_batch(self, items):
        n = len(items)
        pad = self.microbatch - n
        feeds = {}
        for key in items[0][2]:
            arrs = [it[2][key] for it in items]
            stacked = np.stack(arrs)
            if pad:
                z = np.zeros((pad, *stacked.shape[1:]), stacked.dtype)
                stacked = np.concatenate([stacked, z])
            feeds[key] = stacked
        self.stats.padded_events += pad

        def call():
            return self._infer(feeds)

        if self.hedge_after is not None:
            primary = self._pool.submit(call)
            try:
                out = primary.result(timeout=self.hedge_after)
            except Exception:
                self.stats.hedged += 1
                backup = self._pool.submit(call)
                out = backup.result()
        else:
            out = call()
        self.stats.batches += 1
        now = time.perf_counter()
        import jax
        leaves, tdef = jax.tree_util.tree_flatten(out)
        for i, (seq, t0, _, fut) in enumerate(items):
            res = jax.tree_util.tree_unflatten(
                tdef, [np.asarray(l)[i] for l in leaves])
            with self._release_lock:
                self._done[seq] = (res, t0, now, fut)
                # strict in-order release
                while self._next_release in self._done:
                    r, t0r, t1r, f = self._done.pop(self._next_release)
                    f.set_result(r)
                    self.stats.latencies_s.append(t1r - t0r)
                    self.stats.completed += 1
                    self._next_release += 1
                self._release_lock.notify_all()

    def _run(self):
        while not self._stop.is_set():
            items = self._collect()
            if items:
                self._run_batch(items)

    # ----------------------------------------------------------- control ----
    def drain(self, timeout: float = 30.0):
        t0 = time.perf_counter()
        while (self._q.qsize() or self._done or
               self.stats.completed < self._seq):
            if time.perf_counter() - t0 > timeout:
                raise TimeoutError("serving engine drain timeout")
            time.sleep(1e-3)

    def close(self):
        self._stop.set()
        self._batcher.join(timeout=5)
        self._pool.shutdown(wait=False)
