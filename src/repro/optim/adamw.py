"""AdamW with optional int8 block-quantized moments (bitsandbytes-style).

The int8 states are what make llama4-maverick-400b trainable on a single
256-chip v5e pod: fp32 m+v would cost 3.2 TB; int8 blockwise (block=256,
fp32 absmax scale per block → 1.016 bytes/param/moment) costs 0.8 TB.

States are plain pytrees → checkpointable and re-shardable like params.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

BLOCK = 256


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    quantize_states: bool = False


# ------------------------------------------------------- int8 block quant ----
def _q8_pack(x):
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.size
    nb = -(-n // BLOCK)
    pad = nb * BLOCK - n
    flat = jnp.pad(flat, (0, pad)).reshape(nb, BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(flat), axis=1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(flat / scale[:, None]), -127, 127
                 ).astype(jnp.int8)
    return {"q": q.reshape(-1), "scale": scale}


def _q8_unpack(s, shape):
    n = 1
    for d in shape:
        n *= d
    nb = s["scale"].shape[0]
    flat = (s["q"].reshape(nb, BLOCK).astype(jnp.float32)
            * s["scale"][:, None]).reshape(-1)[:n]
    return flat.reshape(shape)


# ------------------------------------------------------------- optimizer ----
def adamw_init(params, cfg: AdamWConfig):
    if cfg.quantize_states:
        m = jax.tree_util.tree_map(lambda p: _q8_pack(jnp.zeros_like(
            p, jnp.float32)), params)
        v = jax.tree_util.tree_map(lambda p: _q8_pack(jnp.zeros_like(
            p, jnp.float32)), params)
    else:
        m = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        v = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": m, "v": v, "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def adamw_update(grads, state, params, *, lr, cfg: AdamWConfig):
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-12))
    grads = jax.tree_util.tree_map(
        lambda g: g.astype(jnp.float32) * scale, grads)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        if cfg.quantize_states:
            mf = _q8_unpack(m, p.shape)
            vf = _q8_unpack(v, p.shape) ** 2   # v stored in sqrt domain
        else:
            mf, vf = m, v
        mf = b1 * mf + (1 - b1) * g
        vf = b2 * vf + (1 - b2) * g * g
        u = (mf / c1) / (jnp.sqrt(vf / c2) + cfg.eps)
        # bound the per-coordinate step (guards against quantization
        # underflow in the int8 second moment; near-no-op for fp32)
        u = jnp.clip(u, -20.0, 20.0)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        if cfg.quantize_states:
            return newp, _q8_pack(mf), _q8_pack(jnp.sqrt(vf))
        return newp, mf, vf

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    is_q = cfg.quantize_states
    leafdef = (lambda x: isinstance(x, dict) and "q" in x) if is_q else None
    flat_m = jax.tree_util.tree_flatten(
        state["m"], is_leaf=leafdef)[0] if is_q else tdef.flatten_up_to(
        state["m"])
    flat_v = jax.tree_util.tree_flatten(
        state["v"], is_leaf=leafdef)[0] if is_q else tdef.flatten_up_to(
        state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gn}


def opt_state_specs(param_specs, cfg: AdamWConfig):
    """PartitionSpecs mirroring the optimizer state tree."""
    from jax.sharding import PartitionSpec as P
    if cfg.quantize_states:
        def qspec(ps):
            # quantized buffers are flat: shard on the first (only) dim
            # with the param's first sharded axis if any, else replicate
            first = next((a for a in ps if a is not None), None)
            return {"q": P(first), "scale": P(first)}
        m = jax.tree_util.tree_map(qspec, param_specs,
                                   is_leaf=lambda x: isinstance(x, P))
    else:
        m = param_specs
    from jax.sharding import PartitionSpec
    return {"m": m, "v": m, "step": PartitionSpec()}
