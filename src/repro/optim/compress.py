"""Gradient compression for cross-pod reduction: int8 all-reduce with
error feedback (1-bit-Adam-family trick, arXiv:1905.10936 lineage).

Inside a ``shard_map`` over the gradient-reduction axis, each shard
quantizes its local gradient to int8 (per-tensor scale), psums the int32
representation (exact — no quantization noise from the reduction itself),
dequantizes, and accumulates the local quantization residual into an
error-feedback buffer that is added back before the next quantization —
keeping the optimizer unbiased over time.

Bandwidth: 4× less DCI traffic than fp32 all-reduce (the inter-pod link
is the scarce resource on multi-pod meshes; see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def error_feedback_init(grads):
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(x, err, axis_name: str, n_shards: int):
    """One tensor: returns (mean-reduced x̂, new error-feedback buffer).

    Call inside shard_map. Scheme: (1) pmax a shared absmax (scalar
    collective), (2) quantize locally with the shared scale, accumulating
    the residual into the error buffer, (3) exact int32 psum of the int8
    payload (|q·n| ≤ 127·n fits easily), (4) dequantize once.
    Payload on the wire is 1 byte/elem (+4-byte scalar) vs 4 — the saving
    targets the inter-pod DCI axis."""
    xf = x.astype(jnp.float32) + err
    gmax = jax.lax.pmax(jnp.max(jnp.abs(xf)), axis_name)
    scale = jnp.maximum(gmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127)
    new_err = xf - q * scale
    s = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return s.astype(jnp.float32) * scale / n_shards, new_err


def compressed_tree_psum(grads, err_state, axis_name: str, n_shards: int):
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(err_state)
    outs = [compressed_psum(g, e, axis_name, n_shards)
            for g, e in zip(flat_g, flat_e)]
    g2 = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    e2 = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    return g2, e2
