from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import cosine_warmup
from repro.optim.compress import compressed_psum, error_feedback_init
