from repro.dist.sharding import (DP, TP, logical_to_physical,
                                 specs_from_rules)

__all__ = ["DP", "TP", "logical_to_physical", "specs_from_rules"]
