"""Logical sharding axes and their resolution against a physical mesh.

Model code annotates params/activations with *logical* axes (``DP`` for
the batch/data dimension, ``TP`` for the model/tensor dimension) via
``PartitionSpec``; ``logical_to_physical`` resolves those names against
whatever mesh the launcher built.  This keeps the model modules
mesh-agnostic: the same ``PARAM_RULES`` lower on the 1-device host mesh
(axes simply vanish), on the (data, model) production mesh, and on the
multi-pod (pod, data, model) mesh where DP spans pod×data.
"""
from __future__ import annotations

import re

from jax.sharding import PartitionSpec as P

# Logical axis names used in PARAM_RULES / with_sharding_constraint calls.
DP = "dp"      # data / batch parallel
TP = "tp"      # tensor / model parallel

# logical -> ordered physical candidates; only the ones present in the
# mesh survive (so the host 1-device ("data","model") mesh and the
# multi-pod ("pod","data","model") mesh both resolve).
_LOGICAL_TO_MESH = {
    DP: ("pod", "data"),
    TP: ("model",),
}


def logical_to_physical(spec, mesh):
    """Resolve a logical PartitionSpec into a physical one for ``mesh``.

    Entries may be ``None``, a logical name ('dp'/'tp'), a physical mesh
    axis name (passed through if the mesh has it), or a tuple of either.
    Logical axes missing from the mesh are dropped (replicated).
    """
    if mesh is None:
        return P(*([None] * len(spec)))
    mesh_axes = set(mesh.axis_names)

    def resolve_entry(entry):
        if entry is None:
            return None
        names = entry if isinstance(entry, (tuple, list)) else (entry,)
        phys = []
        for name in names:
            for axis in _LOGICAL_TO_MESH.get(name, (name,)):
                if axis in mesh_axes and axis not in phys:
                    phys.append(axis)
        if not phys:
            return None
        return phys[0] if len(phys) == 1 else tuple(phys)

    return P(*[resolve_entry(e) for e in spec])


def _path_str(key_path) -> str:
    parts = []
    for k in key_path:
        if hasattr(k, "key"):            # DictKey
            parts.append(str(k.key))
        elif hasattr(k, "idx"):          # SequenceKey
            parts.append(str(k.idx))
        elif hasattr(k, "name"):         # GetAttrKey
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def specs_from_rules(params, rules):
    """Pytree of logical PartitionSpecs from (regex, spec) rules.

    Each leaf's "/"-joined key path is matched against the rules in
    order; the first ``re.search`` hit wins, unmatched leaves are
    replicated (``P()``).  Specs are truncated to the leaf rank so a
    rule written for the stacked (scanned) variant of a weight also
    applies to its unstacked form.
    """
    import jax

    compiled = [(re.compile(pat), spec) for pat, spec in rules]

    def assign(key_path, leaf):
        path = _path_str(key_path)
        ndim = len(getattr(leaf, "shape", ()))
        for pat, spec in compiled:
            if pat.search(path):
                entries = list(spec)[:ndim] if ndim else list(spec)
                return P(*entries)
        return P()

    return jax.tree_util.tree_map_with_path(assign, params)
