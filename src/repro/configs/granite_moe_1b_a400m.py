"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base]."""
import jax.numpy as jnp

from repro.configs import lm_common
from repro.models import transformer as tr

ARCH_ID = "granite-moe-1b-a400m"
FAMILY = "lm"
SHAPES = list(lm_common.SHAPES)


def full_config():
    return tr.TransformerConfig(
        name=ARCH_ID, n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
        d_ff=512, vocab=49155, rope_theta=1e4, norm="rmsnorm",
        gated_mlp=True, activation="silu",
        moe=tr.MoEConfig(n_experts=32, top_k=8, group_size=512))


def smoke_config():
    return tr.TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=32, vocab=128, rope_theta=1e4, block_q=8,
        loss_chunk=8, compute_dtype=jnp.float32,
        moe=tr.MoEConfig(n_experts=4, top_k=2, group_size=16))


def cell(shape):
    return lm_common.cells_for(ARCH_ID, full_config())[shape]()


def smoke_run(seed=0):
    return lm_common.smoke_lm(smoke_config(), seed)
