"""nequip [gnn]: n_layers=5 d_hidden=32 l_max=2 n_rbf=8 cutoff=5
equivariance=E(3)-tensor-product [arXiv:2101.03164; paper]."""
import jax
import jax.numpy as jnp

from repro.configs import gnn_common as G
from repro.models.gnn import nequip as model

ARCH_ID = "nequip"
FAMILY = "gnn"
SHAPES = list(G.SHAPES)


def full_config(shape="full_graph_sm"):
    return model.NequIPConfig(n_layers=5, mult=32, l_max=2, n_rbf=8,
                              cutoff=5.0)


def smoke_config():
    return model.NequIPConfig(n_layers=2, mult=8, l_max=2, n_rbf=4)


def _flops(meta, cfg):
    n, e = meta["n"], meta["e"]
    m = cfg.mult
    # ~12 TP paths × CG contraction (m × ~45 mults) + radial MLP
    per_layer = (2.0 * e * 12 * m * 45
                 + 2.0 * e * (cfg.n_rbf * cfg.radial_hidden
                              + cfg.radial_hidden * 12 * m)
                 + 2.0 * n * 5 * m * m)
    return 3.0 * cfg.n_layers * per_layer


def cell(shape):
    meta = G.SHAPES[shape]
    cfg = full_config(shape)
    if shape == "molecule":
        b = meta["batch"]
        g = G.graph_sds(meta, geometric=True, triplets=False, batch=b)
        specs = G.graph_specs(g, batch=True)
        return G.make_batched_train_cell(
            ARCH_ID, model, cfg, g, specs,
            model_flops=_flops(meta, cfg) * b)
    g = G.graph_sds(meta, geometric=True, triplets=False)
    specs = G.graph_specs(g, edge_dp=True)
    return G.make_train_cell(ARCH_ID, shape, model, cfg, g, specs,
                             model_flops=_flops(meta, cfg))


def smoke_run(seed=0):
    from repro.data.graphs import geometric_graph
    cfg = smoke_config()
    gg = geometric_graph(20, cutoff=1.8, box=3.0, n_species=4, seed=seed,
                         max_edges=96)
    g = {k: jnp.asarray(v) for k, v in gg.items()}
    p = model.init(jax.random.PRNGKey(seed), cfg)
    loss, m = model.loss_fn(p, g, cfg, force_weight=0.1)
    f = model.forces(p, g, cfg)
    return {"loss": loss, "forces": f, "metrics": m}
