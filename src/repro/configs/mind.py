"""mind [recsys]: embed_dim=64 n_interests=4 capsule_iters=3
interaction=multi-interest [arXiv:1904.08030; unverified].

Shapes: train_batch B=65,536 (in-batch sampled softmax), serve_p99 B=512
(online re-rank, 1,024 candidates each), serve_bulk B=262,144 (offline
scoring, 128 candidates each), retrieval_cand B=1 vs 1,000,000 candidates
(single batched matmul + top-k, never a loop)."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import Cell, sds
from repro.dist.sharding import DP, specs_from_rules
from repro.models import recsys as model
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_warmup
from repro.optim.adamw import opt_state_specs

ARCH_ID = "mind"
FAMILY = "recsys"
SHAPES = ["train_batch", "serve_p99", "serve_bulk", "retrieval_cand"]

_META = {
    "train_batch": {"kind": "train", "batch": 65536},
    "serve_p99": {"kind": "serve", "batch": 512, "cands": 1024},
    "serve_bulk": {"kind": "serve", "batch": 262144, "cands": 128},
    "retrieval_cand": {"kind": "serve", "batch": 1, "cands": 1_000_000,
                       "shared_cands": True, "topk": 100},
}

OCFG = AdamWConfig(weight_decay=0.0)
LR = cosine_warmup(peak_lr=1e-3, warmup_steps=100, total_steps=20000)


def full_config():
    return model.MINDConfig(n_items=1_000_000, n_user_tags=100_000,
                            embed_dim=64, n_interests=4, capsule_iters=3,
                            hist_len=50, tag_bag=16)


def smoke_config():
    return model.MINDConfig(n_items=300, n_user_tags=60, embed_dim=16,
                            n_interests=4, capsule_iters=3, hist_len=8,
                            tag_bag=4)


def _user_feed(cfg, b):
    return {
        "behav_ids": sds((b, cfg.hist_len), jnp.int32),
        "behav_mask": sds((b, cfg.hist_len), jnp.float32),
        "tag_ids": sds((b, cfg.tag_bag), jnp.int32),
    }


def _user_specs(cfg, b):
    bp = P(DP, None) if b > 1 else P(None, None)
    return {"behav_ids": bp, "behav_mask": bp, "tag_ids": bp}


def _train_flops(cfg, b):
    d, k, h = cfg.embed_dim, cfg.n_interests, cfg.hist_len
    routing = b * (2 * h * d * d + cfg.capsule_iters * 4 * k * h * d)
    proj = b * k * 2 * 2 * d * d
    logits = 2.0 * b * b * d
    return 3.0 * (routing + proj + logits)


def cell(shape):
    cfg = full_config()
    meta = _META[shape]
    b = meta["batch"]
    if shape == "train_batch":
        return _train_cell(cfg, b)
    return _serve_cell(cfg, shape, meta)


def _train_cell(cfg, b):
    def make_step(mesh):
        def step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: model.loss_fn(p, batch, cfg),
                has_aux=True)(params)
            new_p, new_s, aux = adamw_update(
                grads, opt_state, params, lr=LR(opt_state["step"]),
                cfg=OCFG)
            return new_p, new_s, {**metrics, **aux}
        return step

    def abstract_args():
        params = jax.eval_shape(
            lambda: model.init(jax.random.PRNGKey(0), cfg))
        opt = jax.eval_shape(lambda p: adamw_init(p, OCFG), params)
        batch = dict(_user_feed(cfg, b), target=sds((b,), jnp.int32))
        return (params, opt, batch)

    def spec_args():
        params = jax.eval_shape(
            lambda: model.init(jax.random.PRNGKey(0), cfg))
        pspecs = specs_from_rules(params, model.PARAM_RULES)
        ospecs = opt_state_specs(pspecs, OCFG)
        bspecs = dict(_user_specs(cfg, b), target=P(DP))
        return (pspecs, ospecs, bspecs)

    return Cell(arch=ARCH_ID, shape="train_batch", kind="train",
                make_step=make_step, abstract_args=abstract_args,
                spec_args=spec_args, model_flops=_train_flops(cfg, b))


def _serve_cell(cfg, shape, meta):
    b, c = meta["batch"], meta["cands"]
    shared = meta.get("shared_cands", False)
    topk = meta.get("topk")

    def make_step(mesh):
        def step(params, batch):
            if topk:
                return model.serve_topk(params, batch, cfg, k=topk)
            return model.score_candidates(params, batch, cfg)
        return step

    def abstract_args():
        params = jax.eval_shape(
            lambda: model.init(jax.random.PRNGKey(0), cfg))
        batch = _user_feed(cfg, b)
        batch["cand_ids"] = sds((c,) if shared else (b, c), jnp.int32)
        return (params, batch)

    def spec_args():
        params = jax.eval_shape(
            lambda: model.init(jax.random.PRNGKey(0), cfg))
        pspecs = specs_from_rules(params, model.PARAM_RULES)
        bspecs = _user_specs(cfg, b)
        bspecs["cand_ids"] = P(DP) if shared else (
            P(DP, None) if b > 1 else P(None, None))
        return (pspecs, bspecs)

    d, k, h = cfg.embed_dim, cfg.n_interests, cfg.hist_len
    user_tower = b * (2 * h * d * d
                      + cfg.capsule_iters * 4 * k * h * d
                      + k * 2 * 2 * d * d)
    mf = 2.0 * b * k * c * d + user_tower
    return Cell(arch=ARCH_ID, shape=shape, kind="serve",
                make_step=make_step, abstract_args=abstract_args,
                spec_args=spec_args, model_flops=mf)


def smoke_run(seed=0):
    from repro.data.recsys import mind_batch
    cfg = smoke_config()
    p = model.init(jax.random.PRNGKey(seed), cfg)
    batch = {k: jnp.asarray(v) for k, v in mind_batch(
        n_items=cfg.n_items, n_user_tags=cfg.n_user_tags,
        hist_len=cfg.hist_len, tag_bag=cfg.tag_bag, batch=16,
        seed=seed, step=0).items()}
    loss, m = model.loss_fn(p, batch, cfg)
    batch["cand_ids"] = jnp.arange(cfg.n_items, dtype=jnp.int32)
    scores = model.score_candidates(p, batch, cfg)
    return {"loss": loss, "scores": scores, "metrics": m}
