"""dimenet [gnn]: n_blocks=6 d_hidden=128 n_bilinear=8 n_spherical=7
n_radial=6 [arXiv:2003.03123; unverified]. Geometric arch: every shape
carries synthetic positions/species; triplet budgets per gnn_common."""
import jax
import jax.numpy as jnp

from repro.configs import gnn_common as G
from repro.models.gnn import dimenet as model

ARCH_ID = "dimenet"
FAMILY = "gnn"
SHAPES = list(G.SHAPES)


def full_config(shape="full_graph_sm"):
    return model.DimeNetConfig(n_blocks=6, d_hidden=128, n_bilinear=8,
                               n_spherical=7, n_radial=6, cutoff=5.0)


def smoke_config():
    return model.DimeNetConfig(n_blocks=2, d_hidden=16, n_bilinear=4,
                               n_spherical=3, n_radial=3)


def _flops(meta, cfg):
    n, e, t = meta["n"], meta["e"], meta["trip"]
    d, nb = cfg.d_hidden, cfg.n_bilinear
    per_block = (2.0 * e * d * d * 4                 # edge denses
                 + 2.0 * t * nb * d * d / d          # sbf proj ~ t*nsr*nb
                 + 2.0 * t * nb * d * d              # bilinear einsum
                 + 2.0 * n * d * d)                  # output mlp
    return 3.0 * cfg.n_blocks * per_block


def cell(shape):
    meta = G.SHAPES[shape]
    cfg = full_config(shape)
    if shape == "molecule":
        b = meta["batch"]
        g = G.graph_sds(meta, geometric=True, triplets=True, batch=b)
        specs = G.graph_specs(g, batch=True)
        return G.make_batched_train_cell(
            ARCH_ID, model, cfg, g, specs,
            model_flops=_flops(meta, cfg) * b)
    g = G.graph_sds(meta, geometric=True, triplets=True)
    specs = G.graph_specs(g, edge_dp=True)
    return G.make_train_cell(ARCH_ID, shape, model, cfg, g, specs,
                             model_flops=_flops(meta, cfg))


def smoke_run(seed=0):
    from repro.data.graphs import build_triplets, geometric_graph
    cfg = smoke_config()
    gg = geometric_graph(24, cutoff=1.8, box=3.0, n_species=4, seed=seed,
                         max_edges=128)
    trips, tm = build_triplets(gg["edge_index"], gg["edge_mask"],
                               max_triplets=512)
    g = {k: jnp.asarray(v) for k, v in gg.items()}
    g["triplets"], g["triplet_mask"] = jnp.asarray(trips), jnp.asarray(tm)
    p = model.init(jax.random.PRNGKey(seed), cfg)
    loss, m = model.loss_fn(p, g, cfg)
    grads = jax.grad(lambda q: model.loss_fn(q, g, cfg)[0])(p)
    gn = sum(float(jnp.sum(jnp.abs(x)))
             for x in jax.tree_util.tree_leaves(grads))
    return {"loss": loss, "grad_l1": gn, "metrics": m}
