"""olmo-1b [dense]: 16L d_model=2048 16H (MHA kv=16) d_ff=8192 vocab=50304
— non-parametric LayerNorm, non-gated SwiGLU-free MLP
[arXiv:2402.00838; hf]."""
import jax.numpy as jnp

from repro.configs import lm_common
from repro.models import transformer as tr

ARCH_ID = "olmo-1b"
FAMILY = "lm"
SHAPES = list(lm_common.SHAPES)


def full_config():
    return tr.TransformerConfig(
        name=ARCH_ID, n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=8192, vocab=50304, rope_theta=1e4, norm="nonparametric",
        gated_mlp=False, activation="silu")


def smoke_config():
    return tr.TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=128, rope_theta=1e4, block_q=8,
        loss_chunk=8, norm="nonparametric", gated_mlp=False,
        compute_dtype=jnp.float32)


def cell(shape):
    return lm_common.cells_for(ARCH_ID, full_config())[shape]()


def smoke_run(seed=0):
    return lm_common.smoke_lm(smoke_config(), seed)
