"""granite-34b [dense]: 88L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152 — llama-arch, code [arXiv:2405.04324; hf].
(granite-34b-code uses non-gated GELU MLP — d_ff=24576 is the full
expansion.)"""
import jax.numpy as jnp

from repro.configs import lm_common
from repro.models import transformer as tr

ARCH_ID = "granite-34b"
FAMILY = "lm"
SHAPES = list(lm_common.SHAPES)


def full_config():
    return tr.TransformerConfig(
        name=ARCH_ID, n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
        d_ff=24576, vocab=49152, rope_theta=1e7, norm="rmsnorm",
        gated_mlp=False, activation="gelu")


def smoke_config():
    return tr.TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=1, d_ff=128, vocab=128, rope_theta=1e4, block_q=8,
        loss_chunk=8, gated_mlp=False, activation="gelu",
        compute_dtype=jnp.float32)


def cell(shape):
    return lm_common.cells_for(ARCH_ID, full_config())[shape]()


def smoke_run(seed=0):
    return lm_common.smoke_lm(smoke_config(), seed)
