"""yi-9b [dense]: 48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000
— llama-arch GQA [arXiv:2403.04652; hf]."""
import jax.numpy as jnp

from repro.configs import lm_common
from repro.models import transformer as tr

ARCH_ID = "yi-9b"
FAMILY = "lm"
SHAPES = list(lm_common.SHAPES)


def full_config():
    return tr.TransformerConfig(
        name=ARCH_ID, n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4,
        d_ff=11008, vocab=64000, rope_theta=5e6, norm="rmsnorm",
        gated_mlp=True, activation="silu")


def smoke_config():
    return tr.TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=96, vocab=128, rope_theta=1e4, block_q=8,
        loss_chunk=8, compute_dtype=jnp.float32)


def cell(shape):
    return lm_common.cells_for(ARCH_ID, full_config())[shape]()


def smoke_run(seed=0):
    return lm_common.smoke_lm(smoke_config(), seed)
