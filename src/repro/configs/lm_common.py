"""Shared cell builders for the five LM architectures.

Shapes (assigned): train_4k (train, S=4096 B=256), prefill_32k
(inference prefill, S=32768 B=32), decode_32k (one token against a 32k KV
cache, B=128), long_500k (one token against a 524288 KV cache, B=1,
sequence-sharded cache — flash-decoding-style; decode is O(S), so this is
runnable for full-attention archs, see DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import Cell, sds
from repro.dist.sharding import DP, TP, specs_from_rules
from repro.models import transformer as tr
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_warmup
from repro.optim.adamw import opt_state_specs

SHAPES = {
    "train_4k": {"kind": "train", "seq": 4096, "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32768, "batch": 32},
    "decode_32k": {"kind": "decode", "seq": 32768, "batch": 128},
    "long_500k": {"kind": "decode", "seq": 524288, "batch": 1,
                  "seq_shard": True},
}


def opt_config(cfg: tr.TransformerConfig, *, quantize: bool):
    return AdamWConfig(quantize_states=quantize)


def _param_trees(cfg):
    params = tr.abstract_params(cfg)
    pspecs = specs_from_rules(params, tr.PARAM_RULES)
    return params, pspecs


def train_cell(arch: str, cfg: tr.TransformerConfig, *, quantize_opt=False,
               batch=None, seq=None, grad_accum: int = 1,
               shape_name: str = "train_4k"):
    meta = SHAPES["train_4k"]
    b = batch or meta["batch"]
    s = seq or meta["seq"]
    ocfg = opt_config(cfg, quantize=quantize_opt)
    lr = cosine_warmup(peak_lr=3e-4, warmup_steps=100, total_steps=10000)

    def make_step(mesh):
        def grads_of(params, batch_):
            return jax.value_and_grad(tr.loss_fn, has_aux=True)(
                params, batch_, cfg, mesh)

        def step(params, opt_state, batch_):
            if grad_accum > 1:
                mb = {k: v.reshape(grad_accum, b // grad_accum, s)
                      for k, v in batch_.items()}

                def acc(carry, mbatch):
                    (loss, metrics), grads = grads_of(params, mbatch)
                    carry = jax.tree_util.tree_map(
                        lambda a, g: a + g / grad_accum, carry, grads)
                    return carry, (loss, metrics)

                zero = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                grads, (losses, ms) = jax.lax.scan(acc, zero, mb)
                loss = losses.mean()
                metrics = jax.tree_util.tree_map(lambda x: x.mean(), ms)
            else:
                (loss, metrics), grads = grads_of(params, batch_)
            new_p, new_s, aux = adamw_update(
                grads, opt_state, params,
                lr=lr(opt_state["step"]), cfg=ocfg)
            return new_p, new_s, {**metrics, **aux, "loss": loss}
        return step

    def abstract_args():
        params, _ = _param_trees(cfg)
        opt = jax.eval_shape(lambda p: adamw_init(p, ocfg), params)
        batch_ = {"tokens": sds((b, s), jnp.int32),
                  "labels": sds((b, s), jnp.int32)}
        return (params, opt, batch_)

    def spec_args():
        _, pspecs = _param_trees(cfg)
        ospecs = opt_state_specs(pspecs, ocfg)
        bspecs = {"tokens": P(DP, None), "labels": P(DP, None)}
        return (pspecs, ospecs, bspecs)

    return Cell(arch=arch, shape=shape_name, kind="train",
                make_step=make_step, abstract_args=abstract_args,
                spec_args=spec_args,
                model_flops=tr.model_flops(cfg, b, s, training=True))


def _serving_specs(pspecs):
    """Inference param layout: TP-only (dp replicated) — kills the
    per-step FSDP all-gathers that dominate decode (§Perf)."""
    def drop_dp(spec):
        return P(*[None if e == DP
                   else (tuple(x for x in e if x != DP) or None
                         if isinstance(e, tuple) else e)
                   for e in spec])
    return jax.tree_util.tree_map(drop_dp, pspecs,
                                  is_leaf=lambda x: isinstance(x, P))


def prefill_cell(arch: str, cfg: tr.TransformerConfig, *,
                 serving_shardings: bool = False):
    meta = SHAPES["prefill_32k"]
    b, s = meta["batch"], meta["seq"]

    def make_step(mesh):
        def step(params, tokens):
            return tr.prefill(params, tokens, cfg, mesh)
        return step

    def abstract_args():
        params, _ = _param_trees(cfg)
        return (params, sds((b, s), jnp.int32))

    def spec_args():
        _, pspecs = _param_trees(cfg)
        if serving_shardings:
            pspecs = _serving_specs(pspecs)
        return (pspecs, P(DP, None))

    return Cell(arch=arch, shape="prefill_32k", kind="prefill",
                make_step=make_step, abstract_args=abstract_args,
                spec_args=spec_args,
                model_flops=tr.model_flops(cfg, b, s, training=False))


def decode_cell(arch: str, cfg: tr.TransformerConfig, shape: str, *,
                serving_shardings: bool = False):
    meta = SHAPES[shape]
    b, s = meta["batch"], meta["seq"]
    seq_shard = meta.get("seq_shard", False)

    def make_step(mesh):
        def step(params, cache, tokens):
            return tr.decode_step(params, cache, tokens, cfg, mesh)
        return step

    def abstract_args():
        params, _ = _param_trees(cfg)
        cache = jax.eval_shape(
            lambda: tr.init_cache(cfg, b, s))
        return (params, cache, sds((b, 1), jnp.int32))

    def spec_args():
        _, pspecs = _param_trees(cfg)
        if serving_shardings:
            pspecs = _serving_specs(pspecs)
        # kv-head counts are rarely divisible by tp=16; shard d_head
        kvspec = (P(None, None, DP, None, TP) if seq_shard
                  else P(None, DP, None, None, TP))
        scspec = (P(None, None, DP, None) if seq_shard
                  else P(None, DP, None, None))

        def cspec(leaf):
            if leaf.ndim == 5:
                return kvspec
            if leaf.ndim == 4:
                return scspec
            return P(None, None)

        cache = jax.eval_shape(lambda: tr.init_cache(cfg, b, s))
        cspecs = jax.tree_util.tree_map(cspec, cache)
        tokspec = P() if b == 1 else P(DP, None)
        return (pspecs, cspecs, tokspec)

    # decode: one token, attention reads the full cache
    mf = tr.model_flops(cfg, b, 1, training=False, decode=True, kv_len=s)
    return Cell(arch=arch, shape=shape, kind="decode",
                make_step=make_step, abstract_args=abstract_args,
                spec_args=spec_args, model_flops=mf)


def cells_for(arch: str, cfg: tr.TransformerConfig, *, quantize_opt=False,
              serving_shardings=False, grad_accum=1):
    return {
        "train_4k": lambda: train_cell(arch, cfg,
                                       quantize_opt=quantize_opt,
                                       grad_accum=grad_accum),
        "prefill_32k": lambda: prefill_cell(
            arch, cfg, serving_shardings=serving_shardings),
        "decode_32k": lambda: decode_cell(
            arch, cfg, "decode_32k", serving_shardings=serving_shardings),
        "long_500k": lambda: decode_cell(
            arch, cfg, "long_500k", serving_shardings=serving_shardings),
    }


# ------------------------------------------------- cost (roofline) cells ----
def _cost_cfg(cfg: tr.TransformerConfig, n_layers: int):
    """Scan-free-cost variant: XLA's cost_analysis counts scan bodies
    once, so roofline lowerings (a) drop the attention q-chunk scan
    ('full' mode — identical FLOPs, no loop), (b) disable loss chunking,
    (c) vmap MoE groups, (d) use reduced n_layers ∈ {2,4} — the layer
    scan is corrected by affine extrapolation F(L) = a + b·L (see
    benchmarks/roofline.py). Memory comes from the full-L deploy
    lowering, not from these."""
    kw = dict(cfg.__dict__)
    # keep remat as deployed: recompute FLOPs are real roofline cost
    kw.update(n_layers=n_layers, attn_mode="full", loss_chunk=1 << 30,
              unroll_layers=True)
    if cfg.moe is not None:
        mkw = dict(cfg.moe.__dict__)
        mkw.update(vmap_groups=True)
        kw["moe"] = tr.MoEConfig(**mkw)
    return tr.TransformerConfig(**kw)


def cost_cells(arch: str, cfg: tr.TransformerConfig, shape: str, *,
               quantize_opt=False, **cell_kwargs):
    """Two reduced-L cells + the true L, for affine FLOP extrapolation."""
    out = {}
    for lred in (2, 4):
        c2 = _cost_cfg(cfg, lred)
        builder = cells_for(arch, c2, quantize_opt=quantize_opt,
                            **cell_kwargs)[shape]
        out[lred] = builder()
    return out, cfg.n_layers


# --------------------------------------------------------------- smoke ----
def smoke_lm(cfg_small: tr.TransformerConfig, seed=0):
    """One real train step + one decode step on CPU at reduced scale."""
    key = jax.random.PRNGKey(seed)
    params = tr.init_params(key, cfg_small)
    toks = jax.random.randint(key, (2, 16), 0, cfg_small.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    ocfg = AdamWConfig()
    opt = adamw_init(params, ocfg)
    (loss, metrics), grads = jax.value_and_grad(
        tr.loss_fn, has_aux=True)(params, batch, cfg_small, None)
    params2, opt2, _ = adamw_update(grads, opt, params, lr=1e-3, cfg=ocfg)
    cache = tr.init_cache(cfg_small, 2, 24, dtype=jnp.float32)
    logits, cache = tr.decode_step(params2, cache, toks[:, :1], cfg_small)
    return {"loss": loss, "logits": logits,
            "params_delta": jax.tree_util.tree_reduce(
                lambda a, x: a + float(jnp.sum(jnp.abs(x))),
                jax.tree_util.tree_map(lambda a, b_: a - b_, params2,
                                       params), 0.0)}
