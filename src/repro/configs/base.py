"""Cell abstraction: one (architecture × input-shape) dry-run unit.

Every config module exposes ``cell(shape_name) -> Cell``; the launcher
lowers ``jit(make_step(mesh), in_shardings=resolve(spec_args))`` against
``abstract_args()`` (pure ShapeDtypeStructs — nothing is allocated).
``model_flops`` is the analytic useful-FLOPs estimate used for the
MODEL_FLOPS / HLO_FLOPs ratio in §Roofline.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.sharding import logical_to_physical


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str                                  # train|prefill|decode|serve
    make_step: Callable[[Any], Callable]       # mesh -> step fn
    abstract_args: Callable[[], tuple]         # () -> pytree of SDS
    spec_args: Callable[[], tuple]             # () -> pytree of logical P
    model_flops: float = 0.0
    sublowerings: Callable | None = None       # for scan-corrected costs

    @property
    def name(self):
        return f"{self.arch}:{self.shape}"

    def resolve_shardings(self, mesh):
        """Logical specs -> NamedShardings, sanitized against the actual
        argument shapes: pjit input shardings must divide dimensions
        exactly, so axes whose mesh extent does not divide the dim are
        dropped (e.g. vocab=49155 vs tp=16, d_in=1433 vs dp), and specs
        are truncated to the value rank."""
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

        def extent(entry):
            if entry is None:
                return 1
            names = entry if isinstance(entry, (tuple, list)) else (entry,)
            n = 1
            for nm in names:
                n *= sizes[nm]
            return n

        def fix(spec, arg):
            phys = list(logical_to_physical(spec, mesh))[:len(arg.shape)]
            out = []
            for i, e in enumerate(phys):
                out.append(e if e is None
                           or arg.shape[i] % extent(e) == 0 else None)
            return NamedSharding(mesh, P(*out))

        return jax.tree_util.tree_map(
            fix, self.spec_args(), self.abstract_args(),
            is_leaf=lambda x: isinstance(x, P))

    def lower(self, mesh):
        step = self.make_step(mesh)
        shardings = self.resolve_shardings(mesh)
        return jax.jit(step, in_shardings=shardings).lower(
            *self.abstract_args())


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)
