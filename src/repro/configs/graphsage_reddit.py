"""graphsage-reddit [gnn]: n_layers=2 d_hidden=128 aggregator=mean
sample_sizes=25-10 [arXiv:1706.02216; paper].

minibatch_lg uses the REAL layered neighbor sampler
(repro.data.graphs.NeighborSampler) with the assigned fanout 15-10,
grouped 32×32 seeds so the group axis shards over dp."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import gnn_common as G
from repro.configs.base import Cell, sds
from repro.dist.sharding import DP, specs_from_rules
from repro.models.gnn import graphsage as model
from repro.optim import adamw_init, adamw_update
from repro.optim.adamw import opt_state_specs

ARCH_ID = "graphsage-reddit"
FAMILY = "gnn"
SHAPES = list(G.SHAPES)


def full_config(shape="full_graph_sm"):
    meta = G.SHAPES[shape]
    fanout = meta.get("fanout", (25, 10))
    return model.GraphSAGEConfig(
        n_layers=2, d_hidden=128, d_in=meta["d_feat"],
        n_classes=max(meta["classes"], 2), sample_sizes=fanout)


def smoke_config():
    return model.GraphSAGEConfig(n_layers=2, d_hidden=16, d_in=8,
                                 n_classes=3, sample_sizes=(3, 2))


def _flops(meta, cfg, n=None):
    n = n or meta["n"]
    d = cfg.d_hidden
    fl = 2.0 * n * 2 * meta["d_feat"] * d + 2.0 * n * 2 * d * d
    return 3.0 * fl


def _flops_sampled(meta, cfg, groups, seeds):
    """Layered-frontier work: layer l transforms frontiers 0..depth-l."""
    d = cfg.d_hidden
    sizes = model.cfg_frontier_sizes(cfg, seeds)
    fl = 0.0
    din = meta["d_feat"]
    for li in range(cfg.n_layers):
        # frontiers 0..depth-1 are transformed at layer li
        depth = len(sizes) - 1 - li
        active = sum(sizes[:depth])
        fl += 2.0 * active * 2 * din * d
        din = d
    return 3.0 * groups * fl


def cell(shape):
    meta = G.SHAPES[shape]
    cfg = full_config(shape)
    if shape == "minibatch_lg":
        return _sampled_cell(cfg, meta)
    if shape == "molecule":
        b = meta["batch"]
        g = G.graph_sds(meta, geometric=False, triplets=False, batch=b)
        specs = G.graph_specs(g, batch=True)
        return G.make_batched_train_cell(
            ARCH_ID, model, cfg, g, specs,
            model_flops=_flops(meta, cfg) * b)
    g = G.graph_sds(meta, geometric=False, triplets=False)
    specs = G.graph_specs(g, edge_dp=True)
    return G.make_train_cell(ARCH_ID, shape, model, cfg, g, specs,
                             model_flops=_flops(meta, cfg))


def _sampled_cell(cfg, meta):
    groups, seeds = G.GROUPS, G.SEEDS_PER_GROUP
    sizes = model.cfg_frontier_sizes(cfg, seeds)     # (32, 480, 4800)
    ntot = sum(sizes)

    def abstract_args():
        params = jax.eval_shape(
            lambda: model.init(jax.random.PRNGKey(0), cfg))
        opt = jax.eval_shape(lambda p: adamw_init(p, G.OCFG), params)
        batch = {
            "feats": sds((groups, ntot, cfg.d_in), jnp.float32),
            "edges": [sds((groups, 2, sizes[i] * cfg.sample_sizes[i]),
                          jnp.int32) for i in range(len(sizes) - 1)],
            "labels": sds((groups, seeds), jnp.int32),
        }
        return (params, opt, batch)

    def spec_args():
        params = jax.eval_shape(
            lambda: model.init(jax.random.PRNGKey(0), cfg))
        pspecs = specs_from_rules(params, model.PARAM_RULES)
        ospecs = opt_state_specs(pspecs, G.OCFG)
        bspecs = {"feats": P(DP, None, None),
                  "edges": [P(DP, None, None)] * (len(sizes) - 1),
                  "labels": P(DP, None)}
        return (pspecs, ospecs, bspecs)

    def make_step(mesh):
        def step(params, opt_state, batch):
            def lf(p):
                losses, metrics = jax.vmap(lambda b: model.loss_fn(
                    p, b, cfg, sampled=True))(batch)
                return losses.mean(), {k: v.mean()
                                       for k, v in metrics.items()}
            (loss, metrics), grads = jax.value_and_grad(
                lf, has_aux=True)(params)
            new_p, new_s, aux = adamw_update(
                grads, opt_state, params,
                lr=G.LR(opt_state["step"]), cfg=G.OCFG)
            return new_p, new_s, {**metrics, **aux}
        return step

    mf = _flops_sampled(meta, cfg, groups, seeds)
    return Cell(arch=ARCH_ID, shape="minibatch_lg", kind="train",
                make_step=make_step, abstract_args=abstract_args,
                spec_args=spec_args, model_flops=mf)


def smoke_run(seed=0):
    import numpy as np
    from repro.data.graphs import NeighborSampler, powerlaw_graph
    cfg = smoke_config()
    gg = powerlaw_graph(64, 256, d_feat=8, n_classes=3, seed=seed)
    sampler = NeighborSampler(gg["edge_index"], 64, gg["nodes"],
                              gg["labels"], fanouts=cfg.sample_sizes,
                              seed=seed)
    batch = sampler.sample(np.arange(8))
    batch = jax.tree_util.tree_map(jnp.asarray, batch)
    p = model.init(jax.random.PRNGKey(seed), cfg)
    loss, m = model.loss_fn(p, batch, cfg, sampled=True)
    g = {k: jnp.asarray(v) for k, v in gg.items()}
    loss_full, _ = model.loss_fn(p, g, cfg)
    return {"loss": loss, "loss_full": loss_full, "metrics": m}
