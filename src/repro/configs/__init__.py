"""Architecture registry: ``--arch <id>`` resolution for launchers,
benchmarks and tests. 10 assigned archs + the paper's own."""
from __future__ import annotations

import importlib

_MODULES = {
    "yi-9b": "repro.configs.yi_9b",
    "granite-34b": "repro.configs.granite_34b",
    "olmo-1b": "repro.configs.olmo_1b",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b_a400m",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b_a17b",
    "dimenet": "repro.configs.dimenet",
    "gatedgcn": "repro.configs.gatedgcn",
    "graphsage-reddit": "repro.configs.graphsage_reddit",
    "nequip": "repro.configs.nequip",
    "mind": "repro.configs.mind",
    "caloclusternet": "repro.configs.caloclusternet",
}

ASSIGNED = [a for a in _MODULES if a != "caloclusternet"]


def get_arch(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {list(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id])


def all_cells(include_paper: bool = False):
    """Yield every (arch, shape) Cell — 40 assigned (+3 paper)."""
    ids = list(ASSIGNED) + (["caloclusternet"] if include_paper else [])
    for arch_id in ids:
        mod = get_arch(arch_id)
        for shape in mod.SHAPES:
            yield arch_id, shape, mod
