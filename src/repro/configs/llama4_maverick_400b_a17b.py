"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128 experts top-1 + 1 shared expert
[hf:meta-llama/Llama-4; unverified]. int8-quantized Adam moments make the
optimizer state fit a single 256-chip v5e pod (see optim/adamw.py)."""
import jax.numpy as jnp

from repro.configs import lm_common
from repro.models import transformer as tr

ARCH_ID = "llama4-maverick-400b-a17b"
FAMILY = "lm"
SHAPES = list(lm_common.SHAPES)


def full_config():
    return tr.TransformerConfig(
        name=ARCH_ID, n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=8192, vocab=202048, rope_theta=5e5, norm="rmsnorm",
        gated_mlp=True, activation="silu",
        moe=tr.MoEConfig(n_experts=128, top_k=1, group_size=512,
                         shared_experts=1))


def smoke_config():
    return tr.TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=48, vocab=128, rope_theta=1e4, block_q=8,
        loss_chunk=8, compute_dtype=jnp.float32,
        moe=tr.MoEConfig(n_experts=8, top_k=1, group_size=16,
                         shared_experts=1))


def cell(shape):
    return lm_common.cells_for(ARCH_ID, full_config(),
                               quantize_opt=True)[shape]()


def smoke_run(seed=0):
    return lm_common.smoke_lm(smoke_config(), seed)
