"""gatedgcn [gnn]: n_layers=16 d_hidden=70 aggregator=gated
[arXiv:2003.00982; paper]."""
import dataclasses

import jax.numpy as jnp

from repro.configs import gnn_common as G
from repro.configs.base import sds
from repro.models.gnn import gatedgcn as model

ARCH_ID = "gatedgcn"
FAMILY = "gnn"
SHAPES = list(G.SHAPES)


def full_config(shape="full_graph_sm"):
    meta = G.SHAPES[shape]
    return model.GatedGCNConfig(
        n_layers=16, d_hidden=70, d_in=meta["d_feat"],
        n_classes=max(meta["classes"], 2),
        readout="graph" if shape == "molecule" else "node")


def smoke_config():
    return model.GatedGCNConfig(n_layers=2, d_hidden=16, d_in=8,
                                n_classes=3)


def _flops(meta, cfg):
    n, e = meta["n"], meta["e"]
    d = cfg.d_hidden
    per_layer = 2.0 * d * d * (4 * e + n) + 10.0 * e * d
    emb = 2.0 * n * cfg.d_in * d
    return 3.0 * (cfg.n_layers * per_layer + emb)  # fwd+bwd


def cell(shape):
    meta = G.SHAPES[shape]
    cfg = full_config(shape)
    if shape == "molecule":
        b = meta["batch"]
        g = G.graph_sds(meta, geometric=False, triplets=False, batch=b)
        g["labels"] = sds((b,), jnp.int32)  # graph-level labels
        specs = G.graph_specs(g, batch=True)
        return G.make_batched_train_cell(
            ARCH_ID, model, cfg, g, specs,
            model_flops=_flops(meta, cfg) * b)

    g = G.graph_sds(meta, geometric=False, triplets=False)
    specs = G.graph_specs(g, edge_dp=True)
    return G.make_train_cell(ARCH_ID, shape, model, cfg, g, specs,
                             model_flops=_flops(meta, cfg))


def smoke_run(seed=0):
    import jax
    import numpy as np
    from repro.data.graphs import powerlaw_graph
    from repro.optim import AdamWConfig, adamw_init, adamw_update
    cfg = smoke_config()
    gg = powerlaw_graph(32, 96, d_feat=8, n_classes=3, seed=seed)
    g = {k: jnp.asarray(v) for k, v in gg.items()}
    p = model.init(jax.random.PRNGKey(seed), cfg)
    ocfg = AdamWConfig()
    s = adamw_init(p, ocfg)
    (loss, m), grads = jax.value_and_grad(
        lambda q: model.loss_fn(q, g, cfg), has_aux=True)(p)
    p2, s, _ = adamw_update(grads, s, p, lr=1e-3, cfg=ocfg)
    logits = model.apply(p2, g, cfg)
    return {"loss": loss, "logits": logits, "metrics": m}
