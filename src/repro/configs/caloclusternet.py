"""caloclusternet [trigger] — the paper's own architecture.

Variants: 'upgrade' (128 of 8736 inputs — the paper's target) and
'current' (32 of 576 — the deployed detector). Shapes: trigger_serve
(streaming inference, the hardware-trigger path incl. CPS) and
condensation_train (object-condensation training)."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import Cell, sds
from repro.core import caloclusternet as ccn
from repro.core.condensation import condensation_loss
from repro.dist.sharding import DP, specs_from_rules
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_warmup
from repro.optim.adamw import opt_state_specs

ARCH_ID = "caloclusternet"
FAMILY = "trigger"
SHAPES = ["trigger_serve", "trigger_serve_current", "condensation_train"]

_META = {
    "trigger_serve": {"kind": "serve", "batch": 4096, "variant": "upgrade"},
    "trigger_serve_current": {"kind": "serve", "batch": 4096,
                              "variant": "current"},
    "condensation_train": {"kind": "train", "batch": 1024,
                           "variant": "upgrade"},
}

PARAM_RULES = [(r".*/w", P(DP, None))]
OCFG = AdamWConfig(weight_decay=0.01)
LR = cosine_warmup(peak_lr=1e-3, warmup_steps=200, total_steps=20000)


def full_config(variant="upgrade"):
    if variant == "current":
        return ccn.current_detector_config()
    return ccn.CCNConfig()


def smoke_config():
    return ccn.CCNConfig(n_hits=16, n_crystals=576, d_hidden=24,
                         d_flr=8, d_s=3, k=4, d_decoder=12)


def _flops(cfg, b):
    n, d = cfg.n_hits, cfg.d_hidden
    per_ev = (2 * n * (cfg.d_in * d + d * d)               # encoder
              + cfg.n_gravnet_blocks * (
                  2 * n * d * (cfg.d_s + cfg.d_flr)
                  + 2 * n * n * (cfg.d_s + cfg.k * cfg.d_flr)
                  + 2 * n * (d + 2 * cfg.d_flr) * d)
              + 2 * n * (d * d + d * cfg.d_decoder)
              + 2 * n * cfg.d_decoder * sum(cfg.head_dims.values()))
    return per_ev * b


def cell(shape):
    meta = _META[shape]
    cfg = full_config(meta["variant"])
    b = meta["batch"]
    if meta["kind"] == "serve":
        return _serve_cell(cfg, shape, b)
    return _train_cell(cfg, shape, b)


def _feeds(cfg, b, train=False):
    f = {"feats": sds((b, cfg.n_hits, cfg.d_in), jnp.float32),
         "mask": sds((b, cfg.n_hits), jnp.float32)}
    if train:
        f["object_id"] = sds((b, cfg.n_hits), jnp.int32)
        f["energy"] = sds((b, cfg.n_hits), jnp.float32)
        f["cls"] = sds((b, cfg.n_hits), jnp.int32)
    return f


def _feed_specs(fd):
    return {k: P(DP, *([None] * (len(v.shape) - 1)))
            for k, v in fd.items()}


def _serve_cell(cfg, shape, b):
    def make_step(mesh):
        def step(params, batch):
            out = ccn.apply(params, batch["feats"], batch["mask"], cfg)
            return ccn.cps(out, batch["mask"], cfg)
        return step

    def abstract_args():
        params = jax.eval_shape(
            lambda: ccn.init(jax.random.PRNGKey(0), cfg))
        return (params, _feeds(cfg, b))

    def spec_args():
        params = jax.eval_shape(
            lambda: ccn.init(jax.random.PRNGKey(0), cfg))
        return (specs_from_rules(params, PARAM_RULES),
                _feed_specs(_feeds(cfg, b)))

    return Cell(arch=ARCH_ID, shape=shape, kind="serve",
                make_step=make_step, abstract_args=abstract_args,
                spec_args=spec_args, model_flops=_flops(cfg, b))


def _train_cell(cfg, shape, b):
    def make_step(mesh):
        def step(params, opt_state, batch):
            def lf(p):
                out = ccn.apply(p, batch["feats"], batch["mask"], cfg)
                labels = {"object_id": batch["object_id"],
                          "energy": batch["energy"], "cls": batch["cls"]}
                return condensation_loss(out, labels, batch["mask"],
                                         k_max=cfg.k_max)
            (loss, metrics), grads = jax.value_and_grad(
                lf, has_aux=True)(params)
            new_p, new_s, aux = adamw_update(
                grads, opt_state, params, lr=LR(opt_state["step"]),
                cfg=OCFG)
            return new_p, new_s, {**metrics, **aux}
        return step

    def abstract_args():
        params = jax.eval_shape(
            lambda: ccn.init(jax.random.PRNGKey(0), cfg))
        opt = jax.eval_shape(lambda p: adamw_init(p, OCFG), params)
        return (params, opt, _feeds(cfg, b, train=True))

    def spec_args():
        params = jax.eval_shape(
            lambda: ccn.init(jax.random.PRNGKey(0), cfg))
        pspecs = specs_from_rules(params, PARAM_RULES)
        return (pspecs, opt_state_specs(pspecs, OCFG),
                _feed_specs(_feeds(cfg, b, train=True)))

    return Cell(arch=ARCH_ID, shape=shape, kind="train",
                make_step=make_step, abstract_args=abstract_args,
                spec_args=spec_args, model_flops=_flops(cfg, b) * 3)


def smoke_run(seed=0):
    from repro.data.belle2 import Belle2Config, generate
    cfg = smoke_config()
    gen = Belle2Config(n_crystals=576, grid=(24, 24), n_hits=cfg.n_hits,
                       noise_rate=4.0)
    b = generate(gen, 8, seed=seed)
    params = ccn.init(jax.random.PRNGKey(seed), cfg)
    feats = jnp.asarray(b["feats"])
    mask = jnp.asarray(b["mask"])
    out = ccn.apply(params, feats, mask, cfg)
    labels = {"object_id": jnp.asarray(b["object_id"]),
              "energy": jnp.asarray(b["energy"]),
              "cls": jnp.asarray(b["cls"])}
    loss, m = condensation_loss(out, labels, mask, k_max=cfg.k_max)
    res = ccn.cps(out, mask, cfg)
    return {"loss": loss, "cps": res, "out": out, "metrics": m}
