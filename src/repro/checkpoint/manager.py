"""Fault-tolerant checkpointing: async, atomic, elastic.

- Leaves are written as .npy files + a JSON manifest (tree paths, shapes,
  dtypes, crc32 checksums, step). Writes go to ``<dir>.tmp`` and are
  committed by an atomic rename — a crash mid-write never corrupts the
  latest checkpoint.
- ``async_=True`` snapshots to host memory synchronously (cheap) and does
  file I/O on a background thread, keeping checkpointing off the step
  critical path.
- ``restore(..., mesh, specs)`` re-shards onto ANY mesh (elastic scaling:
  leaves are stored unsharded/global, so a 512-chip checkpoint restores
  onto 256 chips or 1 CPU without conversion).
- ``CheckpointManager`` rotates the last ``keep`` checkpoints and verifies
  checksums on restore (detects partial/bit-rotten files).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib

import jax
import numpy as np


def _paths(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        parts = []
        for p in path:
            key = getattr(p, "key", getattr(p, "idx", getattr(p, "name",
                                                              None)))
            parts.append(str(key))
        out.append(("/".join(parts), leaf))
    return out


def save(ckpt_dir: str, step: int, tree, *, async_: bool = False):
    """Write one checkpoint at <ckpt_dir>/step_<step>."""
    entries = _paths(tree)
    host = [(name, np.asarray(leaf)) for name, leaf in entries]

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "leaves": []}
        for i, (name, arr) in enumerate(host):
            fn = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"].append({
                "path": name, "file": fn, "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
            })
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def latest_step(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like_tree, *, mesh=None,
            shardings=None, verify: bool = True):
    """Load checkpoint ``step`` shaped like ``like_tree`` (any pytree with
    the same structure; leaves may be ShapeDtypeStructs). If ``mesh`` and
    ``shardings`` (a matching pytree of NamedSharding/PartitionSpec) are
    given, leaves are device_put with those shardings (elastic restore)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    names = [n for n, _ in _paths(like_tree)]
    flat_like, tdef = jax.tree_util.tree_flatten(like_tree)
    shard_flat = (tdef.flatten_up_to(shardings)
                  if shardings is not None else [None] * len(flat_like))
    out = []
    for name, like, shd in zip(names, flat_like, shard_flat):
        e = by_path[name]
        arr = np.load(os.path.join(d, e["file"]))
        if verify:
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != e["crc"]:
                raise IOError(f"checksum mismatch for {name} in {d}")
        if shd is not None:
            if mesh is not None and not hasattr(shd, "mesh"):
                shd = jax.sharding.NamedSharding(mesh, shd)
            arr = jax.device_put(arr, shd)
        out.append(arr)
    return jax.tree_util.tree_unflatten(tdef, out), manifest["step"]


class CheckpointManager:
    def __init__(self, ckpt_dir: str, *, keep: int = 3,
                 async_: bool = True):
        self.dir = ckpt_dir
        self.keep = keep
        self.async_ = async_
        self._pending: threading.Thread | None = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def save(self, step: int, tree):
        self.wait()
        self._pending = save(self.dir, step, tree, async_=self.async_)
        if not self.async_:
            self._gc()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None
            self._gc()

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def latest(self):
        return latest_step(self.dir)

    def restore_latest(self, like_tree, *, mesh=None, shardings=None):
        self.wait()
        s = self.latest()
        if s is None:
            return None, None
        return restore(self.dir, s, like_tree, mesh=mesh,
                       shardings=shardings)
