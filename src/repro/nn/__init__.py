from repro.nn.init import lecun_normal, normal_init, truncated_normal, zeros_init, ones_init
from repro.nn.layers import (
    dense_init, dense_apply,
    layernorm_init, layernorm_apply, rmsnorm_init, rmsnorm_apply,
    nonparametric_layernorm,
    embedding_init, embedding_lookup,
    mlp_init, mlp_apply,
)
