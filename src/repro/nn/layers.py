"""Functional NN layers: params are plain dict pytrees, applies are pure fns.

Convention: ``<layer>_init(key, ...) -> params`` and
``<layer>_apply(params, x, ...) -> y``. No module objects, no state — this
keeps everything jit/scan/shard_map friendly and makes the dataflow-graph
compiler in ``repro.core`` able to treat layers as plain operators.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.init import lecun_normal, normal_init, ones_init, zeros_init


# ---------------------------------------------------------------- dense ----
def dense_init(key, d_in: int, d_out: int, *, bias: bool = True,
               dtype=jnp.float32, init=lecun_normal):
    kw, kb = jax.random.split(key)
    p = {"w": init(kw, (d_in, d_out), dtype=dtype)}
    if bias:
        p["b"] = zeros_init(kb, (d_out,), dtype=dtype)
    return p


def dense_apply(params, x, *, activation=None):
    y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    if activation is not None:
        y = activation(y)
    return y


# ----------------------------------------------------------------- norms ----
def layernorm_init(key, dim: int, dtype=jnp.float32):
    return {"scale": ones_init(key, (dim,), dtype), "bias": zeros_init(key, (dim,), dtype)}


def layernorm_apply(params, x, *, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y * params["scale"] + params["bias"]


def rmsnorm_init(key, dim: int, dtype=jnp.float32):
    return {"scale": ones_init(key, (dim,), dtype)}


def rmsnorm_apply(params, x, *, eps: float = 1e-6):
    # compute in fp32 for stability regardless of activation dtype
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def nonparametric_layernorm(x, *, eps: float = 1e-5):
    """OLMo-style LayerNorm with no learnable affine parameters."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


# ------------------------------------------------------------- embedding ----
def embedding_init(key, vocab: int, dim: int, *, std=0.02, dtype=jnp.float32):
    return {"table": normal_init(key, (vocab, dim), std=std, dtype=dtype)}


def embedding_lookup(params, ids):
    return jnp.take(params["table"], ids, axis=0)


# ------------------------------------------------------------------- mlp ----
def mlp_init(key, dims, *, bias: bool = True, dtype=jnp.float32):
    """dims = [d_in, h1, ..., d_out]; returns list of dense params."""
    keys = jax.random.split(key, len(dims) - 1)
    return [dense_init(k, a, b, bias=bias, dtype=dtype)
            for k, a, b in zip(keys, dims[:-1], dims[1:])]


def mlp_apply(params, x, *, activation=jax.nn.relu, final_activation=None):
    for i, p in enumerate(params):
        act = activation if i < len(params) - 1 else final_activation
        x = dense_apply(p, x, activation=act)
    return x
