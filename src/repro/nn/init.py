"""Parameter initializers (pure functions over jax.random keys)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def lecun_normal(key, shape, dtype=jnp.float32, in_axis: int = -2):
    """LeCun-normal (fan-in) initialization — QKeras/Keras default for Dense."""
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    std = math.sqrt(1.0 / max(1, fan_in))
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def normal_init(key, shape, std=0.02, dtype=jnp.float32):
    return (std * jax.random.normal(key, shape)).astype(dtype)


def truncated_normal(key, shape, std=0.02, dtype=jnp.float32):
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def zeros_init(key, shape, dtype=jnp.float32):
    del key
    return jnp.zeros(shape, dtype)


def ones_init(key, shape, dtype=jnp.float32):
    del key
    return jnp.ones(shape, dtype)
