"""Autotuned kernel variant search with a persistent tuning cache.

Three pieces:

- ``cache`` — the JSON ``TuningCache`` keyed by
  (kernel, shape, dtype, backend), with graceful fallback to heuristic
  defaults when the file is absent, corrupt, or stale;
- ``candidates``/``autotune`` — the per-kernel search spaces and the
  measuring loop (``autotune_graph`` tunes every shape a
  deploy-optimized IR graph emits);
- ``warmup`` — replica startup warm-up that replays cached winners so
  serving never pays first-request compilation.

Consumers: ``core/passes/kernel_opt.py`` binds cached winners at
design point ③; ``serving`` warms engines from the cache;
``launch/serve.py`` exposes ``--tune`` / ``--tuning-cache``.
"""
from repro.tuning.autotune import (autotune_graph, graph_kernel_problems,
                                   tune_flash_attention, tune_fused_dense,
                                   tune_gravnet, tune_gravnet_block,
                                   tune_knn_aggregate, tune_knn_build)
from repro.tuning.cache import (SCHEMA_VERSION, KernelKey, TuningCache,
                                TuningEntry, flash_attention_key,
                                fused_dense_key, gravnet_block_int8_key,
                                gravnet_block_key, gravnet_key,
                                knn_aggregate_key, knn_build_key)
from repro.tuning.warmup import make_warmup, warm_from_cache

__all__ = [
    "SCHEMA_VERSION", "KernelKey", "TuningCache", "TuningEntry",
    "autotune_graph", "flash_attention_key", "fused_dense_key",
    "graph_kernel_problems", "gravnet_block_int8_key",
    "gravnet_block_key", "gravnet_key", "knn_aggregate_key",
    "knn_build_key", "make_warmup", "tune_flash_attention",
    "tune_fused_dense", "tune_gravnet", "tune_gravnet_block",
    "tune_knn_aggregate", "tune_knn_build", "warm_from_cache",
]
