"""Persistent kernel-tuning cache.

The paper's kernel-level optimization study (looped vs. flattened AIE
kernels) shows the latency-optimal kernel configuration is
shape-dependent; LL-GNN (arXiv:2209.14065) makes the same point for
FPGA GNN layers. This module stores *searched* winners so the design
flow stops guessing: a JSON file maps a ``KernelKey``
(kernel, shape, dtype, backend) to the winning launch configuration
(variant / block shapes) plus its measured time.

Design constraints:

- **Graceful degradation** — a missing, corrupt, or stale (schema
  mismatch) cache file loads as an *empty* cache; every consumer falls
  back to the current heuristic defaults, so tuning is always an
  overlay, never a dependency.
- **Determinism** — ``save()`` writes sorted keys with a fixed layout,
  so cache files round-trip byte-for-byte and diff cleanly in review.
- **Memoized lookups** — entries decode once; the serving hot path
  (warm-up, kernel binding) never re-parses JSON.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile

SCHEMA_VERSION = 1

_KEY_SEP = "|"


@dataclasses.dataclass(frozen=True)
class KernelKey:
    """Identity of one tuning problem.

    ``shape`` is the kernel's *logical* problem shape (the one the
    deploy pipeline emits), not the padded launch shape — both the
    autotuner and ``kernel_opt`` derive it the same way so keys agree.
    """
    kernel: str               # 'fused_dense' | 'gravnet' | 'flash_attention'
    shape: tuple[int, ...]
    dtype: str                # 'float32' | 'bf16' | 'int8' | ...
    backend: str              # 'xla' | 'pallas' | 'pallas_interpret'

    def encode(self) -> str:
        dims = "x".join(str(d) for d in self.shape)
        return _KEY_SEP.join((self.kernel, dims, self.dtype, self.backend))

    @classmethod
    def decode(cls, s: str) -> "KernelKey":
        kernel, dims, dtype, backend = s.split(_KEY_SEP)
        shape = tuple(int(d) for d in dims.split("x")) if dims else ()
        return cls(kernel, shape, dtype, backend)


def fused_dense_key(rows: int, d_in: int, d_out: int, dtype: str,
                    backend: str) -> KernelKey:
    """Dense kernels row-pack micro-batches, so the batch/bucket
    dimensions fold into ``rows``: a batch-packed bucket executable
    keys with rows = microbatch × bucket_n_hits (see
    ``kernel_opt.fused_dense_shape``)."""
    return KernelKey("fused_dense", (rows, d_in, d_out), dtype, backend)


def gravnet_key(n: int, d_s: int, d_f: int, k: int, dtype: str,
                backend: str, batch: int = 1) -> KernelKey:
    """``n`` is the per-event graph size (= the occupancy bucket);
    ``batch`` the packed micro-batch width of the batched kernel's
    leading event grid dimension. ``batch=1`` keeps the legacy 4-dim
    shape so existing caches and per-event lookups stay hits."""
    if batch > 1:
        return KernelKey("gravnet", (batch, n, d_s, d_f, k), dtype, backend)
    return KernelKey("gravnet", (n, d_s, d_f, k), dtype, backend)


def gravnet_block_key(n: int, d_hidden: int, d_f: int, k: int, dtype: str,
                      backend: str, batch: int = 1) -> KernelKey:
    """Key for the fused GravNet-block megakernel. Mirrors
    ``gravnet_key``: ``n`` is the per-event graph size (= the occupancy
    bucket), ``batch`` the leading event grid dimension of a
    batch-packed executable — 5-dim shape when batched, 4-dim
    per-event. ``d_hidden`` (the x operand width) and ``d_f`` pin the
    prologue and (with ``concat_x``) the epilogue K; the remaining
    block dims (d_s, d_out) ride along inside the cached config so
    warm-up can replay the exact problem."""
    if batch > 1:
        return KernelKey("gravnet_block", (batch, n, d_hidden, d_f, k),
                         dtype, backend)
    return KernelKey("gravnet_block", (n, d_hidden, d_f, k), dtype, backend)


def gravnet_block_int8_key(n: int, d_hidden: int, d_f: int, k: int,
                           backend: str, batch: int = 1) -> KernelKey:
    """Key for the *quantized* GravNet-block megakernel — a distinct
    kernel family (``gravnet_block_int8|…|int8|backend``), not a dtype
    variation of the f32 key: the int8 kernel has its own launch
    surface (per-channel scale operands, baked requant constants) and
    its own candidate space, so winners must never cross-pollinate.
    Shape layout mirrors ``gravnet_block_key`` (5-dim batched, 4-dim
    per-event)."""
    if batch > 1:
        return KernelKey("gravnet_block_int8",
                         (batch, n, d_hidden, d_f, k), "int8", backend)
    return KernelKey("gravnet_block_int8", (n, d_hidden, d_f, k), "int8",
                     backend)


def edge_aggregate_key(n: int, e: int, d: int, dtype: str, backend: str,
                       batch: int = 1) -> KernelKey:
    """Key for the edge-aggregation (segment-sum/mean) kernel. ``n`` is
    the per-event node count, ``e`` the padded edge count, ``d`` the
    message feature width. Mirrors ``gravnet_key``: ``batch`` prepends
    the packed micro-batch width (5-dim shape) while ``batch=1`` keeps
    the per-event 3-dim shape."""
    if batch > 1:
        return KernelKey("edge_aggregate", (batch, n, e, d), dtype, backend)
    return KernelKey("edge_aggregate", (n, e, d), dtype, backend)


def flash_attention_key(bh: int, s: int, t: int, d: int, dtype: str,
                        backend: str) -> KernelKey:
    return KernelKey("flash_attention", (bh, s, t, d), dtype, backend)


def knn_build_key(n: int, d_s: int, k: int, dtype: str, backend: str,
                  batch: int = 1) -> KernelKey:
    """Key for the ragged-path neighbor-selection kernel. ``n`` is the
    packed bin capacity (= the detector's n_hits), ``batch`` the bin
    count of the batched launch. Mirrors ``gravnet_key``: 4-dim shape
    batched, 3-dim per-bin."""
    if batch > 1:
        return KernelKey("knn_build", (batch, n, d_s, k), dtype, backend)
    return KernelKey("knn_build", (n, d_s, k), dtype, backend)


def knn_aggregate_key(n: int, d_f: int, k: int, dtype: str, backend: str,
                      batch: int = 1) -> KernelKey:
    """Key for the ragged-path aggregation kernel (same shape layout as
    ``knn_build_key``)."""
    if batch > 1:
        return KernelKey("knn_aggregate", (batch, n, d_f, k), dtype,
                         backend)
    return KernelKey("knn_aggregate", (n, d_f, k), dtype, backend)


@dataclasses.dataclass
class TuningEntry:
    """One cached winner: the launch config plus search provenance."""
    config: dict
    us: float | None = None          # measured microseconds of the winner
    default_us: float | None = None  # the heuristic default's time
    candidates: int = 0              # how many configs were searched

    def to_json(self) -> dict:
        return {"config": dict(self.config), "us": self.us,
                "default_us": self.default_us,
                "candidates": self.candidates}

    @classmethod
    def from_json(cls, d: dict) -> "TuningEntry":
        return cls(config=dict(d["config"]), us=d.get("us"),
                   default_us=d.get("default_us"),
                   candidates=int(d.get("candidates", 0)))


class TuningCache:
    """In-memory view of the JSON tuning cache.

    ``lookup`` returns the winning config dict for a key, or ``None``
    (cache miss → caller keeps its heuristic default). ``put`` +
    ``save`` persist new winners.
    """

    def __init__(self, path: str | os.PathLike | None = None):
        self.path = None if path is None else os.fspath(path)
        self._entries: dict[KernelKey, TuningEntry] = {}
        self.load_error: str | None = None   # why the file was ignored

    # ------------------------------------------------------------- I/O ----
    @classmethod
    def load(cls, path: str | os.PathLike) -> "TuningCache":
        """Load a cache file; any problem yields an *empty* cache whose
        ``load_error`` says why (missing file is not an error)."""
        cache = cls(path)
        p = os.fspath(path)
        if not os.path.exists(p):
            return cache
        try:
            with open(p) as f:
                raw = json.load(f)
        except (OSError, json.JSONDecodeError, UnicodeDecodeError) as e:
            cache.load_error = f"unreadable tuning cache {p}: {e}"
            return cache
        if not isinstance(raw, dict):
            cache.load_error = f"tuning cache {p} is not a JSON object"
            return cache
        if raw.get("schema") != SCHEMA_VERSION:
            cache.load_error = (
                f"tuning cache {p} has schema {raw.get('schema')!r}, "
                f"expected {SCHEMA_VERSION} (stale — ignored)")
            return cache
        entries = raw.get("entries", {})
        if not isinstance(entries, dict):
            cache.load_error = f"tuning cache {p}: 'entries' is not a dict"
            return cache
        for enc, body in entries.items():
            try:
                key = KernelKey.decode(enc)
                entry = TuningEntry.from_json(body)
            except (ValueError, KeyError, TypeError, AttributeError):
                # one malformed entry does not poison the rest
                continue
            cache._entries[key] = entry
        return cache

    def save(self, path: str | os.PathLike | None = None) -> str:
        p = os.fspath(path) if path is not None else self.path
        if p is None:
            raise ValueError("TuningCache.save: no path given")
        payload = {
            "schema": SCHEMA_VERSION,
            "entries": {k.encode(): e.to_json()
                        for k, e in sorted(self._entries.items(),
                                           key=lambda kv: kv[0].encode())},
        }
        # atomic replace: a crashed writer never leaves a torn file for
        # the graceful-degradation path to reject
        d = os.path.dirname(os.path.abspath(p)) or "."
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".tuning_cache_")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
                f.write("\n")
            os.replace(tmp, p)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        self.path = p
        return p

    # ----------------------------------------------------------- access ----
    def lookup(self, key: KernelKey) -> dict | None:
        e = self._entries.get(key)
        return None if e is None else e.config

    def entry(self, key: KernelKey) -> TuningEntry | None:
        return self._entries.get(key)

    def put(self, key: KernelKey, config: dict, *, us: float | None = None,
            default_us: float | None = None, candidates: int = 0) -> None:
        self._entries[key] = TuningEntry(config=dict(config), us=us,
                                         default_us=default_us,
                                         candidates=candidates)

    def entries(self) -> dict[KernelKey, TuningEntry]:
        return dict(self._entries)

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: KernelKey) -> bool:
        return key in self._entries

    def __bool__(self) -> bool:   # empty caches are still real caches
        return True
